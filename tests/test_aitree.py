"""AI-tree / AI+R hybrid behaviour tests: exactness, routing, grid, fallback."""
import dataclasses
import numpy as np
import pytest
import jax.numpy as jnp

from repro.data import synth
from repro.core.rtree import RTree
from repro.core import device_tree as dt, labels, build, grid as gridlib
from repro.core.aitree import ai_query, make_aitree
from repro.core.hybrid import hybrid_query
from repro.core import geometry as geo
from repro.core.classifiers import knn as knnlib
from repro.core import celldata


@pytest.fixture(scope="module")
def world():
    pts = synth.tweets_like(20_000, seed=3)
    tree = RTree(max_entries=32).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 2e-4, 600, seed=4)
    wl = labels.make_workload(dtree, qs)
    hyb, rep = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6,))
    return pts, dtree, wl, hyb, rep


def test_knn_reaches_perfect_training_fit(world):
    *_, rep = world
    assert rep.exact_fit == 1.0


def test_ai_path_is_exact_on_training_workload(world):
    pts, dtree, wl, hyb, _ = world
    B = 128
    q = jnp.asarray(wl.queries[:B])
    res = hybrid_query(hyb, q, force_path="ai", max_results=1024)
    for i in range(B):
        exp = np.flatnonzero(geo.np_contains_point(
            wl.queries[i], pts.astype(np.float32)))
        got = sorted(x for x in np.asarray(res.result_ids[i]).tolist()
                     if x >= 0)
        assert got == sorted(exp.tolist()), i


def test_hybrid_is_exact_on_unseen_queries(world):
    """Unseen queries: kNN misses → fallback → still exact (paper §III-C)."""
    pts, dtree, wl, hyb, _ = world
    unseen = synth.synth_queries(pts, 2e-4, 64, seed=99)
    res = hybrid_query(hyb, jnp.asarray(unseen), force_path="ai",
                       max_results=1024)
    for i in range(64):
        exp = np.flatnonzero(geo.np_contains_point(
            unseen[i], pts.astype(np.float32)))
        got = sorted(x for x in np.asarray(res.result_ids[i]).tolist()
                     if x >= 0)
        assert got == sorted(exp.tolist()), i


def test_ai_path_reduces_leaf_accesses_for_high_overlap(world):
    _, dtree, wl, hyb, _ = world
    high = wl.alpha <= 0.5
    if high.sum() < 10:
        pytest.skip("workload has too few high-overlap queries")
    q = jnp.asarray(wl.queries[high][:64])
    res = hybrid_query(hyb, q, force_path="ai")
    r = hybrid_query(hyb, q, force_path="r")
    assert np.asarray(res.leaf_accesses).mean() < \
        np.asarray(r.leaf_accesses).mean()


def test_hybrid_router_dispatch(world):
    _, dtree, wl, hyb, _ = world
    q = jnp.asarray(wl.queries[:128])
    res = hybrid_query(hyb, q)
    high = np.asarray(res.routed_high)
    used = np.asarray(res.used_ai)
    assert (~used | high).all()         # AI only used when routed high
    # auto cost never exceeds forced-R cost by more than prediction overhead
    r = hybrid_query(hyb, q, force_path="r")
    assert np.asarray(res.n_results).tolist() == \
        np.asarray(r.n_results).tolist()


def test_empty_prediction_triggers_fallback(world):
    """A bank that never predicts anything must always fall back."""
    pts, dtree, wl, hyb, _ = world
    bank = hyb.ait.bank
    broken = dataclasses.replace(
        bank, labels=jnp.zeros_like(bank.labels))
    ait = make_aitree(hyb.ait.grid, broken, max_cells=4,
                      max_pred=hyb.ait.max_pred)
    res = ai_query(ait, dtree, jnp.asarray(wl.queries[:32]))
    assert np.asarray(res.fallback).all()


def test_misprediction_triggers_fallback(world):
    """A bank predicting a wrong (empty-yield) leaf must fall back."""
    pts, dtree, wl, hyb, _ = world
    bank = hyb.ait.bank
    # every stored query predicts an extraneous far-away leaf as well
    lab = np.asarray(bank.labels).copy()
    lab[..., 0] = 1.0  # first local label slot always on
    broken = dataclasses.replace(bank, labels=jnp.asarray(lab))
    ait = make_aitree(hyb.ait.grid, broken, max_cells=4,
                      max_pred=hyb.ait.max_pred)
    res = ai_query(ait, dtree, jnp.asarray(wl.queries[:64]))
    fb = np.asarray(res.fallback)
    # some prediction now includes a leaf with zero qualifying entries
    assert fb.any()


def test_grid_cells_match_bruteforce(world):
    pts, *_ = world
    g = gridlib.fit_grid(pts, 7)
    rng = np.random.default_rng(5)
    lo = rng.uniform(pts.min(0), pts.max(0), size=(100, 2))
    w = rng.uniform(0, (pts.max(0) - pts.min(0)) * 0.2, size=(100, 2))
    qs = np.concatenate([lo, lo + w], axis=1).astype(np.float32)
    ids, valid, overflow = gridlib.bucket_queries_by_cell(g, qs, 16)
    bbox = np.asarray(g.bbox)
    cw = (bbox[2] - bbox[0]) / g.g
    ch = (bbox[3] - bbox[1]) / g.g
    for i in range(100):
        exp = set()
        for cx in range(g.g):
            for cy in range(g.g):
                cell = np.array([bbox[0] + cx * cw, bbox[1] + cy * ch,
                                 bbox[0] + (cx + 1) * cw,
                                 bbox[1] + (cy + 1) * ch])
                # half-open cell ownership matches floor-based routing
                q = qs[i]
                if (q[0] < cell[2] and q[2] >= cell[0]
                        and q[1] < cell[3] and q[3] >= cell[1]):
                    exp.add(cy * g.g + cx)
        got = set(ids[i][valid[i]].tolist())
        if overflow[i]:
            continue  # overflowing queries take the exact path anyway
        assert got == exp, (i, sorted(got), sorted(exp))


def test_router_accuracy_reasonable(world):
    *_, hyb, rep = world if len(world) == 5 else (None,) * 5
    # router trained on a mixed-α workload should beat the base rate
    r = rep.router
    assert r.test_acc >= max(0.6, min(r.base_rate, 1 - r.base_rate))


def test_workload_alpha_buckets(world):
    _, _, wl, _, _ = world
    b = wl.bucket()
    centers = np.array([0.1, 0.25, 0.5, 0.75, 1.0])
    assert np.abs(b[:, None] - centers[None, :]).min(axis=1).max() < 1e-6
    hi = wl.high_overlap(0.75)
    assert ((wl.alpha <= 0.75) == hi).all()


def test_router_feature_parity():
    """The trainer's numpy features and the device path's jnp features are
    the same function — ``router_features`` is a host wrapper over the
    shared ``router_features_jnp`` (they used to be two inline copies)."""
    from repro.core.classifiers.router import (router_features,
                                               router_features_jnp)
    rng = np.random.default_rng(12)
    lo = rng.uniform(-5, 5, (200, 2))
    w = rng.uniform(0, 3, (200, 2))
    q = np.concatenate([lo, lo + w], axis=1).astype(np.float32)
    host = router_features(q)
    dev = np.asarray(router_features_jnp(jnp.asarray(q)))
    assert host.shape == (200, 6)
    np.testing.assert_array_equal(host, dev)
    # the feature semantics the router was trained on: corners + w/h
    np.testing.assert_allclose(host[:, 4], q[:, 2] - q[:, 0], rtol=1e-6)
    np.testing.assert_allclose(host[:, 5], q[:, 3] - q[:, 1], rtol=1e-6)


def test_celldata_label_maps_are_consistent(world):
    _, dtree, wl, hyb, _ = world
    g = hyb.ait.grid
    ds = celldata.build_cell_datasets(g, wl, max_cells_per_query=4)
    # every mapped global label must be a real leaf id
    lm = ds.label_map[ds.lmask]
    assert (lm >= 0).all() and (lm < dtree.n_leaves).all()
    # label multi-hots only light up valid label slots
    assert not ds.labels[~np.broadcast_to(
        ds.lmask[:, None, :], ds.labels.shape)].any()
