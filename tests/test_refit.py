"""The online instance-optimization loop's contracts.

* ``build.refit_cells`` ≡ a from-scratch ``fit_airtree`` on the new
  tree — bank rows, label maps, guard flags and served results all
  bit-compatible — across host-tree insert sequences, in one call or
  chunked in any order (the property the per-cell training pipeline's
  determinism was built to buy);
* zero-query cells install guarded (``cell_ok=False``) — an untrained
  cell must never serve the AI path;
* the serving loop recovers the AI path after an online repack through
  incremental refit chunks alone — no full ``fit_airtree`` on the
  serve path;
* monitor policy mechanics: rolling-median signals, span-diff repack
  accounting, demote/promote levers.

Runs under real hypothesis when installed, else the fixed-seed example
fallback in ``tests/helpers/hypo.py``.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp
from helpers.hypo import given, settings, st

from repro.core import build, device_tree as dt, labels, schedule
from repro.core import spans as spanslib
from repro.core.hybrid import hybrid_query
from repro.core.grid import Grid
from repro.core.monitor import (DefaultPolicy, FreshnessMonitor,
                                FreshServer, MaintenanceDecision)
from repro.core.rtree import RTree
from repro.data import synth

LKW = {"max_results": 2048}


def _world(seed, n_pts=2000, n_q=100):
    pts = synth.tweets_like(n_pts, seed=seed)
    tree = RTree(max_entries=32).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 1e-3, n_q, seed=seed + 1)
    wl = labels.make_workload(dtree, qs, **LKW)
    return pts, tree, dtree, qs, wl


def _fit(dtree, wl, kind, state=None):
    """Pinned-pad fit: a refit comparator must train in the exact shape
    world (label/query pads) the incremental path inherited."""
    kw = dict(kind=kind, grid_sizes=(4,), label_kwargs=LKW)
    if kind == "mlp":
        kw.update(mlp_hidden=16, mlp_epochs=800)
    if state is not None:
        kw.update(max_labels=state.cl, max_queries=state.qp)
    return build.fit_airtree(dtree, wl, **kw)


def _insert_corner(pts, tree, seed, m):
    """Host-tree inserts clustered in one data corner — the localized
    change that leaves most cell spans untouched."""
    rng = np.random.default_rng(seed)
    lo, hi = pts.min(axis=0), pts.max(axis=0)
    corner = lo + rng.uniform(0.0, 0.1, 2) * (hi - lo)
    newp = (corner + np.abs(rng.normal(0, 0.004, (m, 2)))).astype(np.float32)
    tree.insert_all(newp)
    return newp


def _assert_same_bank(a, b, kind):
    fields = (("w1", "b1", "w2", "b2") if kind == "mlp"
              else ("feats", "labels"))
    for f in fields + ("label_map", "lmask"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"bank field {f} diverged")


def _assert_same_serving(h_refit, h_full, qs):
    """Served results bit-compatible once the router is held fixed
    (refit deliberately keeps the original router — it generalizes
    over α, and retraining it is the policy's business, not refit's)."""
    h_full = dataclasses.replace(h_full, router=h_refit.router)
    a = hybrid_query(h_refit, jnp.asarray(qs), max_visited=256,
                     max_results=512)
    b = hybrid_query(h_full, jnp.asarray(qs), max_visited=256,
                     max_results=512)
    for f in ("used_ai", "n_results", "result_ids", "guarded",
              "leaf_accesses", "mispredict"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"served field {f} diverged")


# ---------------------------------------------------------------------------
# refit ≡ full fit
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(5, 40))
def test_refit_cells_equals_full_fit_knn(seed, m):
    pts, tree, dtree, qs, wl = _world(seed % 1000)
    hyb, rep = _fit(dtree, wl, "knn")
    state = rep.fit_state

    _insert_corner(pts, tree, seed, m)
    dtree2 = dt.flatten(tree)
    hyb2 = dataclasses.replace(hyb, tree=dtree2)
    hyb_r, state_r, rrep = build.refit_cells(hyb2, state)
    assert rrep.cells_stale_left == 0

    wl2 = labels.make_workload(dtree2, qs, **LKW)
    hyb_f, rep_f = _fit(dtree2, wl2, "knn", state)
    _assert_same_bank(hyb_r.ait.bank, hyb_f.ait.bank, "knn")
    np.testing.assert_array_equal(np.asarray(hyb_r.ait.cell_ok),
                                  np.asarray(hyb_f.ait.cell_ok))
    ok = np.asarray(state_r.exact_valid)
    assert ok.all(), "a drained refit must certify every query"
    np.testing.assert_array_equal(np.asarray(state_r.exact),
                                  np.asarray(rep_f.fit_state.exact))
    _assert_same_serving(hyb_r, hyb_f, qs)


def test_refit_cells_equals_full_fit_mlp():
    """One fixed mlp case (training dominates the runtime): the per-cell
    decoupled pipeline must splice retrained rows bit-identically to a
    from-scratch fit of the whole bank."""
    pts, tree, dtree, qs, wl = _world(3)
    hyb, rep = _fit(dtree, wl, "mlp")
    state = rep.fit_state

    _insert_corner(pts, tree, seed=7, m=25)
    dtree2 = dt.flatten(tree)
    hyb2 = dataclasses.replace(hyb, tree=dtree2)
    hyb_r, state_r, rrep = build.refit_cells(hyb2, state)
    assert 0 < rrep.cells_changed < state.n_cells, \
        "scenario must exercise a *partial* refit"

    wl2 = labels.make_workload(dtree2, qs, **LKW)
    hyb_f, rep_f = _fit(dtree2, wl2, "mlp", state)
    _assert_same_bank(hyb_r.ait.bank, hyb_f.ait.bank, "mlp")
    np.testing.assert_array_equal(np.asarray(hyb_r.ait.cell_ok),
                                  np.asarray(hyb_f.ait.cell_ok))
    _assert_same_serving(hyb_r, hyb_f, qs)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_chunked_refit_order_invariant(seed):
    """Spreading the stale set over chunks — in any order — lands on the
    same final state as one drain: certificates converge and the spliced
    bank is identical."""
    pts, tree, dtree, qs, wl = _world(seed % 1000)
    hyb, rep = _fit(dtree, wl, "knn")
    state = rep.fit_state
    _insert_corner(pts, tree, seed, 30)
    dtree2 = dt.flatten(tree)
    hyb2 = dataclasses.replace(hyb, tree=dtree2)

    sigs2 = spanslib.leaf_signatures(dtree2)
    spans2 = spanslib.cell_spans(dtree2, hyb.ait.grid, sigs=sigs2)
    changed, _ = spanslib.diff_spans(state.spans, spans2, state.sigs, sigs2)
    ch = np.flatnonzero(changed)
    if ch.size < 2:
        return      # nothing to chunk — vacuous example

    h_one, s_one, _ = build.refit_cells(hyb2, state)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(ch)
    cut = int(rng.integers(1, ch.size))
    h_c, s_c = hyb2, state
    for chunk in (perm[:cut], perm[cut:]):
        h_c, s_c, _ = build.refit_cells(h_c, s_c, chunk)
    assert s_c.cell_stale.sum() == 0
    _assert_same_bank(h_c.ait.bank, h_one.ait.bank, "knn")
    np.testing.assert_array_equal(np.asarray(h_c.ait.cell_ok),
                                  np.asarray(h_one.ait.cell_ok))
    np.testing.assert_array_equal(s_c.exact & s_c.exact_valid,
                                  s_one.exact & s_one.exact_valid)


# ---------------------------------------------------------------------------
# zero-query cells
# ---------------------------------------------------------------------------

def test_zero_query_cells_install_guarded():
    """A grid cell no training query touches has no evidence and no
    trained model — it must come out of the build with ``cell_ok=False``
    so the guard demotes its queries to the exact R path."""
    pts = synth.tweets_like(2000, seed=11)
    tree = RTree(max_entries=32).insert_all(pts)
    dtree = dt.flatten(tree)
    # confine the workload to the lower-left data quadrant: with a 4×4
    # grid over the *query* bbox this still leaves upper cells empty of
    # anchors only if we skew hard — so synthesize in a thin strip
    lo, hi = pts.min(axis=0), pts.max(axis=0)
    strip = pts[(pts[:, 1] <= lo[1] + 0.2 * (hi[1] - lo[1]))]
    qs = synth.synth_queries(strip, 1e-3, 80, seed=12)
    # widen the grid frame well past the strip so upper rows see nothing
    wl = labels.make_workload(dtree, qs, **LKW)
    hyb, rep = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(4,),
                                 label_kwargs=LKW)
    g = hyb.ait.grid
    st_ = rep.fit_state
    touched = np.zeros((g.n_cells,), bool)
    ids, valid = st_.cell_ids, st_.cell_valid
    touched[ids[valid]] = True
    assert not touched.all(), "scenario must leave some cells query-free"
    ok = np.asarray(hyb.ait.cell_ok)
    assert not ok[~touched].any(), \
        "zero-query cells must install with cell_ok=False"


# ---------------------------------------------------------------------------
# recovery without a full refit on the serve path
# ---------------------------------------------------------------------------

def test_mixed_stream_recovers_without_full_fit(monkeypatch):
    pts, tree, dtree, qs, wl = _world(21, n_pts=3000, n_q=150)
    hyb, rep = _fit(dtree, wl, "knn")

    def _no_full_fit(*a, **k):     # the loop's core guarantee
        raise AssertionError("full fit_airtree ran on the serve path")
    monkeypatch.setattr(build, "fit_airtree", _no_full_fit)

    srv = FreshServer(pts, hyb, delta_cap=256, max_visited=256,
                      max_results=512, fit_state=rep.fit_state,
                      policy=DefaultPolicy(refit_chunk=4, repack_at=0.1))
    stream = np.tile(qs, (4, 1))
    rng = np.random.default_rng(5)
    lo, hi = pts.min(axis=0), pts.max(axis=0)
    corner = lo + 0.02 * (hi - lo)
    ins = (corner + np.abs(rng.normal(0, 0.004, (200, 2)))
           ).astype(np.float32)
    mixed = schedule.serve_mixed_workload(srv, stream, ins, batch=50,
                                          insert_every=1, repack_every=0)

    n_repacks = sum(d.repack for _, d in mixed.maintenance)
    assert n_repacks >= 1, "the policy must have repacked mid-stream"
    assert any(r.cells_refit > 0 for r in srv.refits), \
        "recovery must run through incremental refit chunks"
    # the AI path must come back after a repack knocked it out: some
    # segment *after* the first policy repack serves AI-path queries
    first_rp = next(s for s, d in mixed.maintenance if d.repack)
    u = np.asarray(mixed.stats.used_ai)
    post = [u[lo:hi].mean() for s, (lo, hi) in enumerate(mixed.seg_bounds)
            if s > first_rp]
    assert max(post) > 0.2, f"AI path never recovered: {post}"
    # and serving stayed exact throughout
    for (qlo, qhi), visible in schedule.visible_segments(mixed, pts):
        q = stream[qlo:qhi]
        got = np.asarray(mixed.stats.n_results)[qlo:qhi]
        inside = ((visible[None, :, 0] >= q[:, None, 0])
                  & (visible[None, :, 0] <= q[:, None, 2])
                  & (visible[None, :, 1] >= q[:, None, 1])
                  & (visible[None, :, 1] <= q[:, None, 3]))
        np.testing.assert_array_equal(inside.sum(axis=1), got)


# ---------------------------------------------------------------------------
# monitor policy mechanics
# ---------------------------------------------------------------------------

def _grid4():
    return Grid(bbox=jnp.asarray([0., 0., 1., 1.]), g=2)


class _FakeStats:
    def __init__(self, cell_id, **k):
        self.cell_id = np.asarray(cell_id)
        n = self.cell_id.shape[0]
        for f in ("guarded", "mispredict", "used_ai", "delta_hits"):
            setattr(self, f, np.asarray(k.get(f, np.zeros(n, np.int64))))


def test_rolling_median_rates():
    mon = FreshnessMonitor(_grid4(), np.ones(4, bool), window=3)
    # cell 0: mispredict rates 0, 1, 0 across three segments → median 0
    # cell 1: rates 1, 1, 0 → median 1; cell 2: no traffic → 0
    for mis0, mis1 in ((0, 1), (1, 1), (0, 0)):
        mon.note_serve(_FakeStats([0, 1], mispredict=[mis0, mis1]))
        mon.roll_segment()
    r = mon.rolling("mispredict")
    np.testing.assert_allclose(r[:3], [0.0, 1.0, 0.0])
    assert mon.traffic()[0] == 1.0 and mon.traffic()[2] == 0.0
    # overflow rows (cell_id = -1) are dropped, not attributed
    mon.note_serve(_FakeStats([-1, -1]))
    mon.roll_segment()
    assert mon._window[-1]["n"].sum() == 0


def test_note_repack_span_diff_vs_legacy():
    mon = FreshnessMonitor(_grid4(), np.ones(4, bool))
    mon.note_inserts(np.asarray([[0.1, 0.1]]))
    assert not mon.cell_ok()[0]
    # legacy: whole bank stale
    mon.note_repack()
    assert not mon.cell_ok().any()
    # span-diff: only the changed cells; insert counters fold in
    mon2 = FreshnessMonitor(_grid4(), np.ones(4, bool))
    mon2.note_inserts(np.asarray([[0.1, 0.1]]))
    mon2.note_repack(changed=np.asarray([True, False, False, False]))
    np.testing.assert_array_equal(mon2.cell_ok(), [False, True, True, True])
    assert mon2.stats().span_stale_cells == 1
    # a refit chunk drains it
    mon2.note_refit_cells(np.ones(4, bool), np.zeros(4, bool))
    assert mon2.cell_ok().all()


def test_force_demote_and_policy_promote():
    mon = FreshnessMonitor(_grid4(), np.ones(4, bool), window=2)
    pol = DefaultPolicy(refit_chunk=2, demote_mispredict=0.25,
                        min_traffic=2.0, promote_after=2)
    # two segments of heavy mispredict traffic on cell 3
    for _ in range(2):
        mon.note_serve(_FakeStats([3] * 4, mispredict=[1, 1, 0, 1]))
        mon.roll_segment()
    d = pol.decide(mon, delta_fill=0, delta_capacity=100)
    np.testing.assert_array_equal(d.demote, [3])
    mon.force_demote(d.demote)
    assert not mon.cell_ok()[3] and mon.stats().demoted_cells == 1
    # demoted cells stop accruing evidence; after promote_after segments
    # the policy schedules a forced refit and readmission
    mon.roll_segment()
    mon.roll_segment()
    d2 = pol.decide(mon, delta_fill=0, delta_capacity=100)
    np.testing.assert_array_equal(d2.promote, [3])
    mon.clear_demote(d2.promote)
    assert mon.cell_ok()[3]


def test_policy_refit_chunk_prefers_hot_cells():
    mon = FreshnessMonitor(_grid4(), np.ones(4, bool), window=2)
    mon.span_stale[:] = [True, True, True, False]
    for _ in range(2):
        mon.note_serve(_FakeStats([2, 2, 2, 0]))
        mon.roll_segment()
    d = DefaultPolicy(refit_chunk=2).decide(mon, delta_fill=0,
                                            delta_capacity=100)
    assert 2 in d.refit and d.refit.size == 2, d.refit
    assert isinstance(d, MaintenanceDecision)
    # repack trips on fill fraction
    assert DefaultPolicy(repack_at=0.5).decide(
        mon, delta_fill=50, delta_capacity=100).repack
    assert not DefaultPolicy(repack_at=0.5).decide(
        mon, delta_fill=49, delta_capacity=100).repack
