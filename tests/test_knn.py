"""kNN serving: distance browsing on the slot-table contract.

Both kernel forms of ``kernels.knn_browse`` must be bit-identical to the
jnp oracle; ``knn_query`` must match the all-pairs brute-force oracle
bit-for-bit on every non-truncated row (the d2 arithmetic is evaluated
under jit on both sides, so XLA's FMA contraction is identical), and on
the in-radius *prefix* of truncated rows; the radius-doubling wide tier
resolves flagged rows through the same two-tier ``serve_workload``
machinery the range path uses; and the kernel path's lowered HLO carries
no dense [B, L] visited mask.
"""
import functools
import re

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import device_tree as dt, knn, schedule, traversal
from repro.core.device_tree import DeviceTree, Level
from repro.core.rtree import RTree
from repro.kernels import knn_browse as kb, ops, ref
from tests.helpers.hypo import given, settings, st


@functools.lru_cache(maxsize=None)
def _world(n=2500, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, 2))
    dtree = dt.flatten(RTree.str_bulk(pts, max_entries=16))
    return pts, dtree


def _centers(pts, rng, n):
    c = pts[rng.integers(0, pts.shape[0], n)].astype(np.float32)
    return c + rng.normal(scale=1e-3, size=c.shape).astype(np.float32)


def _degenerate(centers):
    return np.concatenate([centers, centers], axis=1).astype(np.float32)


@functools.partial(jax.jit)
def _d2(pts, centers):
    dx = pts[..., 0] - centers[:, None, 0]
    dy = pts[..., 1] - centers[:, None, 1]
    return dx * dx + dy * dy


# ---------------------------------------------------------------------------
# kernel forms vs jnp oracle
# ---------------------------------------------------------------------------

def test_kernel_forms_bit_identical():
    """TPU grid form, folded form, the jnp oracle, and the ops wrapper
    all agree bit-for-bit on a real visited set."""
    pts, tree = _world()
    rng = np.random.default_rng(1)
    centers = _centers(pts, rng, 32)
    r = knn.default_radius(tree, 8)
    box = np.concatenate([centers - r, centers + r], 1).astype(np.float32)
    cv = traversal.visited_leaves_compact(tree, jnp.asarray(box), 32,
                                          use_kernel=False)
    c3 = jnp.asarray(np.concatenate(
        [centers, np.full((32, 1), r * r, np.float32)], 1))
    ex = tree.leaf_entries[..., 0]
    ey = tree.leaf_entries[..., 1]
    safe = jnp.clip(cv.leaf_idx, 0, ex.shape[0] - 1)
    # the oracle must run under jit: eager jax dispatches op-by-op and
    # never FMA-contracts dx*dx + dy*dy, so it differs from any jitted
    # form by 1 ulp wherever XLA fuses the multiply-add
    want = np.asarray(jax.jit(ref.knn_browse)(c3, ex, ey, safe, cv.valid))
    assert np.isfinite(want).any(), "fixture too weak: no in-radius hits"
    for fold in (False, True):
        got = kb.knn_browse(c3, ex, ey, safe, cv.valid, interpret=True,
                            fold_k=fold)
        np.testing.assert_array_equal(np.asarray(got), want,
                                      err_msg=f"fold_k={fold}")
    got = ops.knn_browse(c3, tree.leaf_entries, cv.leaf_idx, cv.valid)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_padded_slots_are_inert():
    """Invalid slots (valid == 0) come back +inf on every form even when
    their clipped leaf index aliases a real leaf; inside a valid slot,
    the leaf tile's own entry padding is inert too (exactly
    ``leaf_counts`` finite candidates)."""
    pts, tree = _world()
    rng = np.random.default_rng(2)
    centers = _centers(pts, rng, 8)
    # huge radius: every real entry is in range — only `valid` and the
    # tile's entry padding can mask candidates out
    c3 = jnp.asarray(np.concatenate(
        [centers, np.full((8, 1), 1e9, np.float32)], 1))
    K = 8
    idx = jnp.zeros((8, K), jnp.int32)          # all alias leaf 0
    valid = jnp.zeros((8, K), jnp.int32).at[:, :2].set(1)
    ex = tree.leaf_entries[..., 0]
    ey = tree.leaf_entries[..., 1]
    n0 = int(tree.leaf_counts[0])
    assert 0 < n0 < tree.leaf_entries.shape[1], "fixture: want a padded tile"
    for form in ("oracle", "tpu", "folded"):
        if form == "oracle":
            d2 = jax.jit(ref.knn_browse)(c3, ex, ey, idx, valid)
        else:
            d2 = kb.knn_browse(c3, ex, ey, idx, valid, interpret=True,
                               fold_k=form == "folded")
        d2 = np.asarray(d2)
        assert (np.isfinite(d2[:, :2]).sum(axis=-1) == n0).all(), form
        assert not np.isfinite(d2[:, 2:]).any(), form


# ---------------------------------------------------------------------------
# knn_query vs brute force
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_knn_query_matches_brute(use_kernel):
    pts, tree = _world()
    rng = np.random.default_rng(3)
    centers = _centers(pts, rng, 48)
    r = knn.default_radius(tree, 8)
    res = knn.knn_query(tree, jnp.asarray(_degenerate(centers)), k=8,
                        radius=r, max_visited=64, use_kernel=use_kernel)
    bd2, _ = knn.knn_brute(pts, centers, 8)
    tr = np.asarray(res.truncated)
    nw = np.asarray(res.n_within)
    got = np.asarray(res.neighbor_d2)
    assert (~tr).sum() >= 32, "fixture too weak: mostly truncated"
    np.testing.assert_array_equal(got[~tr], bd2[~tr])
    # truncated rows: the in-radius neighbors are exactly the brute
    # prefix (anything closer than an in-radius point is also in radius)
    for j in np.flatnonzero(tr):
        kk = min(int(nw[j]), 8)
        np.testing.assert_array_equal(got[j, :kk], bd2[j, :kk])
    # ids point at the distances they claim (recomputed under jit)
    ids = np.asarray(res.neighbor_ids)
    hit = np.isfinite(got)
    assert (ids[hit] >= 0).all() and (ids[~hit] == -1).all()
    d2c = np.asarray(_d2(jnp.asarray(pts.astype(np.float32))[
        np.clip(ids, 0, None)], jnp.asarray(centers)))
    np.testing.assert_array_equal(d2c[hit], got[hit])


def test_knn_accepts_point_queries():
    """[B, 2] point input and the equivalent degenerate rect agree."""
    pts, tree = _world()
    rng = np.random.default_rng(4)
    centers = _centers(pts, rng, 16)
    r = knn.default_radius(tree, 4)
    a = knn.knn_query(tree, jnp.asarray(centers), k=4, radius=r)
    b = knn.knn_query(tree, jnp.asarray(_degenerate(centers)), k=4,
                      radius=r)
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


@given(st.integers(1, 32), st.integers(1, 12), st.integers(0, 4))
@settings(max_examples=12, deadline=None)
def test_knn_prefix_property(n, k, seed):
    """Property: for any (batch, k, seed), every row's reported
    neighbors are a bit-exact prefix of the brute kNN — full length on
    non-truncated rows, the in-radius prefix otherwise. Zero silent
    drops by construction."""
    pts, tree = _world()
    rng = np.random.default_rng(seed)
    centers = _centers(pts, rng, n)
    r = knn.default_radius(tree, k)
    res = knn.knn_query(tree, jnp.asarray(_degenerate(centers)), k=k,
                        radius=r, max_visited=64)
    bd2, _ = knn.knn_brute(pts, centers, k)
    got = np.asarray(res.neighbor_d2)
    tr = np.asarray(res.truncated)
    nw = np.asarray(res.n_within)
    for j in range(n):
        kk = k if not tr[j] else min(int(nw[j]), k)
        np.testing.assert_array_equal(got[j, :kk], bd2[j, :kk])


# ---------------------------------------------------------------------------
# two-tier radius doubling
# ---------------------------------------------------------------------------

def test_two_tier_radius_doubling():
    """A deliberately tight narrow radius truncates rows; the wide tier
    (2x radius, wider slot table) resolves them through the standard
    serve_workload re-serve, leaving non-truncated rows untouched."""
    pts, tree = _world()
    rng = np.random.default_rng(5)
    centers = _centers(pts, rng, 64)
    q = _degenerate(centers)
    r = knn.default_radius(tree, 16, margin=1.0)
    narrow, wide = knn.make_knn_steps(tree, k=16, radius=r,
                                      max_visited=64)
    rep_n = schedule.serve_workload(narrow, q, batch=16, sort="hilbert")
    tr = np.asarray(rep_n.stats.truncated)
    assert tr.any(), "fixture too weak: nothing truncated"
    assert not tr.all(), "fixture too weak: everything truncated"
    rep = schedule.serve_workload(narrow, q, batch=16, sort="hilbert",
                                  wide_fn=wide, trunc_field="truncated")
    assert rep.n_reserved == int(tr.sum())
    tr2 = np.asarray(rep.stats.truncated)
    assert tr2.sum() < tr.sum(), "wide tier resolved nothing"
    bd2, _ = knn.knn_brute(pts, centers, 16)
    np.testing.assert_array_equal(
        np.asarray(rep.stats.neighbor_d2)[~tr2], bd2[~tr2])
    keep = ~tr
    for f in type(rep.stats)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rep.stats, f))[keep],
            np.asarray(getattr(rep_n.stats, f))[keep], err_msg=f)


def test_sorted_knn_stream_bit_identical():
    pts, tree = _world()
    rng = np.random.default_rng(6)
    centers = _centers(pts, rng, 53)
    q = _degenerate(centers)
    r = knn.default_radius(tree, 8)
    narrow, wide = knn.make_knn_steps(tree, k=8, radius=r)
    base = schedule.serve_workload(narrow, q, batch=16, sort="none",
                                   wide_fn=wide, trunc_field="truncated")
    srt = schedule.serve_workload(narrow, q, batch=16, sort="hilbert",
                                  wide_fn=wide, trunc_field="truncated")
    for f in type(base.stats)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(base.stats, f)),
            np.asarray(getattr(srt.stats, f)), err_msg=f)


# ---------------------------------------------------------------------------
# HLO contract: no dense [B, L] mask on the kernel path
# ---------------------------------------------------------------------------

def _synth_tree(L=1000, M=8):
    from repro.data.synth_tree import synth_levels
    rng = np.random.default_rng(0)
    mbrs, parents = synth_levels(L, 4, rng)
    return DeviceTree(
        levels=tuple(Level(mbrs=jnp.asarray(m), parent=jnp.asarray(p))
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.zeros((L, M, 2), jnp.float32),
        leaf_entry_ids=jnp.zeros((L, M), jnp.int32),
        leaf_counts=jnp.zeros((L,), jnp.int32),
        n_points=0, max_entries=4)


def test_knn_hlo_no_dense_mask():
    """The kernel-path kNN serving HLO must carry no [B, L]-shaped
    tensor (L = 1000, padded 1024); the jnp oracle rung is the positive
    control."""
    tree = _synth_tree()
    B = 256
    q = jnp.zeros((B, 4), jnp.float32)

    def lowered(uk):
        return jax.jit(lambda t, qq: knn.knn_query(
            t, qq, k=8, radius=0.1, max_visited=64, use_kernel=uk,
            tile_b=128)).lower(tree, q).as_text()

    dense = re.compile(r"<256x(1000|1024)x")
    assert not dense.search(lowered(True)), \
        "kNN kernel path materialized the dense [B, L] mask"
    assert dense.search(lowered(False)), \
        "oracle control lost its dense mask"
