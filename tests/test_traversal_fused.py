"""Fused-traversal kernel + sort-free compaction equivalence tests.

The fused single-pass kernel (interpret mode on CPU) must produce
bit-identical visited masks to the level-by-level jnp oracle, and the
sort-free cumsum/scatter compaction must match the ``top_k``-based
implementations it replaced — including on adversarial shapes: leaf counts
that are not tile multiples, all-dead frontiers, and overflow rows.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import device_tree as dt, traversal
from repro.core.device_tree import DeviceTree, Level
from repro.core.rtree import RTree
from repro.kernels import ops, ref


RNG = np.random.default_rng(7)


def mk_rects(n, rng=RNG, scale=1.0, width=1.0):
    lo = rng.uniform(-scale, scale, size=(n, 2))
    w = rng.uniform(0, width, size=(n, 2))
    return np.concatenate([lo, lo + w], axis=1).astype(np.float32)


def synth_levels(L, fanout, rng=RNG):
    """Synthetic hierarchy with wide leaf MBRs (dense visited sets)."""
    from repro.data.synth_tree import synth_levels as _synth
    mbrs, parents = _synth(L, fanout, rng, leaf_width=1.0)
    return ([jnp.asarray(m) for m in mbrs],
            [jnp.asarray(p) for p in parents])


# ---------------------------------------------------------------------------
# fused traversal vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,fanout,B", [
    (37, 4, 7),       # L, B both far from tile multiples
    (130, 3, 64),     # deep tree (6 levels), non-power-of-two everything
    (512, 8, 33),
    (2048, 8, 256),   # multi-leaf-tile grid, multi-query-tile
    (1, 4, 5),        # degenerate: root == single leaf (no fusion possible)
])
def test_fused_matches_oracle(L, fanout, B):
    mbrs, parents = synth_levels(L, fanout)
    q = jnp.asarray(mk_rects(B, width=0.4))
    out = np.asarray(ops.traverse_fused(q, mbrs, parents))
    exp = np.asarray(ref.traverse_fused(q, mbrs, parents))
    np.testing.assert_array_equal(out, exp)


def test_fused_all_dead_frontier():
    """Queries disjoint from the root MBR: the frontier dies at level 0 and
    every leaf tile must take the early-exit path to an all-false mask."""
    mbrs, parents = synth_levels(640, 4)
    q = jnp.asarray(np.tile(np.array([[90.0, 90.0, 91.0, 91.0]], np.float32),
                            (32, 1)))
    out = np.asarray(ops.traverse_fused(q, mbrs, parents))
    assert out.shape == (32, 640) and not out.any()


def test_fused_mixed_dead_and_live_rows():
    """Dead and live queries in one batch tile must not contaminate each
    other through the shared VMEM frontier scratch."""
    mbrs, parents = synth_levels(300, 5)
    live = mk_rects(8, width=2.0)
    dead = np.tile(np.array([[90.0, 90.0, 91.0, 91.0]], np.float32), (8, 1))
    q = jnp.asarray(np.concatenate([dead, live, dead], 0))
    out = np.asarray(ops.traverse_fused(q, mbrs, parents))
    exp = np.asarray(ref.traverse_fused(q, mbrs, parents))
    np.testing.assert_array_equal(out, exp)
    assert not out[:8].any() and not out[16:].any()


def test_fused_on_flattened_rtree():
    """End to end against a real host-built tree: fused visited mask ==
    per-level oracle == visited_leaf_mask(use_kernel=True)."""
    pts = RNG.normal(size=(3000, 2))
    tree = RTree(max_entries=16).insert_all(pts)
    dtree = dt.flatten(tree)
    q = jnp.asarray(mk_rects(41, width=0.5))
    exp = np.asarray(traversal.visited_leaf_mask_per_level(dtree, q))
    fused = np.asarray(traversal.visited_leaf_mask(dtree, q, use_kernel=True))
    np.testing.assert_array_equal(fused, exp)


@pytest.mark.parametrize("L,fanout,B,tl", [
    (2048, 8, 64, 512),   # multi-leaf-tile grid: scratch persists across j
    (300, 5, 16, 128),
])
def test_tpu_form_kernel_matches_oracle(L, fanout, B, tl):
    """The hardware graph (one-hot MXU expansion, pl.when-guarded walk +
    early exit, VMEM-resident frontier scratch) — validated via interpret
    with ``tpu_form=True``, since plain interpret runs the branch-free
    gather form."""
    from repro.kernels import traverse_fused as tf
    mbrs, parents = synth_levels(L, fanout)
    q = jnp.asarray(np.concatenate([
        mk_rects(B - 4, width=0.5),
        np.tile(np.array([[90.0, 90.0, 91.0, 91.0]], np.float32), (4, 1)),
    ]))
    never = jnp.asarray([np.inf, np.inf, -np.inf, -np.inf], jnp.float32)

    def pad_level(m, p, mult):
        n = m.shape[0]
        padn = (-n) % mult
        if padn:
            m = jnp.concatenate([m, jnp.tile(never[None], (padn, 1))])
            p = jnp.concatenate([p, jnp.zeros((padn,), jnp.int32)])
        return m.T.astype(jnp.float32), p[None, :].astype(jnp.int32)

    int_m, int_p = [], []
    for i in range(len(mbrs) - 1):
        mt, pt = pad_level(mbrs[i], parents[i], tf.LANE)
        int_m.append(mt)
        if i > 0:
            int_p.append(pt)
    leaf_m, leaf_p = pad_level(mbrs[-1], parents[-1], tl)
    tb = (B + 7) // 8 * 8
    qp = jnp.concatenate(
        [q, jnp.zeros((tb - B, 4), jnp.float32)]) if tb != B else q
    out = tf.traverse_fused_t(qp.T, tuple(int_m), tuple(int_p), leaf_m,
                              leaf_p, tb=tb, tl=tl, interpret=True,
                              tpu_form=True)
    exp = np.asarray(ref.traverse_fused(q, mbrs, parents))
    np.testing.assert_array_equal(np.asarray(out)[:B, :L], exp)


def test_fused_escape_hatch(monkeypatch):
    """REPRO_KERNELS=off must route through the jnp oracle (still exact)."""
    monkeypatch.setenv("REPRO_KERNELS", "off")
    mbrs, parents = synth_levels(64, 4)
    q = jnp.asarray(mk_rects(9))
    out = np.asarray(ops.traverse_fused(q, mbrs, parents))
    exp = np.asarray(ref.traverse_fused(q, mbrs, parents))
    np.testing.assert_array_equal(out, exp)


def test_fused_vmem_gate_falls_back():
    """Trees whose estimated working set exceeds the VMEM budget route to
    the kernel-accelerated per-level loop — still exact."""
    from repro.kernels import traverse_fused as tf
    mbrs, parents = synth_levels(64, 4)
    q = jnp.asarray(mk_rects(5))
    exp = np.asarray(ref.traverse_fused(q, mbrs, parents))
    real_budget = tf.VMEM_BUDGET
    try:
        tf.VMEM_BUDGET = 1      # force every tree over the budget
        out = np.asarray(ops.traverse_fused(q, mbrs, parents))
    finally:
        tf.VMEM_BUDGET = real_budget
    np.testing.assert_array_equal(out, exp)


def test_vmem_estimate_counts_onehot_operands():
    """The gate must bound the one-hot matmul operands, not just the
    frontier: a wide consecutive level pair dominates the estimate."""
    from repro.kernels import traverse_fused as tf
    # widths 2048 → 8192: the (2048, 8192) one-hot alone is 64 MiB
    est = tf.vmem_estimate([128, 2048, 8192], tb=256, tl=512)
    assert est > 2048 * 8192 * 4
    assert est > tf.VMEM_BUDGET


# ---------------------------------------------------------------------------
# sort-free compaction vs top_k oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,L,k", [
    (16, 100, 8),
    (4, 7, 16),       # k > L
    (32, 257, 4),     # heavy overflow
    (3, 5, 5),        # k == L
    (1, 1, 1),
])
def test_compact_mask_matches_topk(B, L, k):
    mask = jnp.asarray(RNG.uniform(size=(B, L)) < 0.3)
    mask = mask.at[0].set(False)      # all-dead row
    mask = mask.at[-1].set(True)      # overflow row (count == L)
    i_new, v_new = traversal.compact_mask(mask, k)
    i_old, v_old = traversal.compact_mask_topk(mask, k)
    np.testing.assert_array_equal(np.asarray(v_new), np.asarray(v_old))
    # invalid slots carry arbitrary indices in the top_k version — compare
    # only through the validity mask
    np.testing.assert_array_equal(np.asarray(i_new * v_new),
                                  np.asarray(i_old * v_old))


def test_compact_mask_orders_by_leaf_id():
    mask = jnp.asarray([[False, True, False, True, True, False, True]])
    idx, valid = traversal.compact_mask(mask, 3)
    assert idx.tolist() == [[1, 3, 4]]       # first three set bits, in order
    assert valid.tolist() == [[True, True, True]]
    assert bool(traversal.overflowed(mask, 3)[0])


def test_gather_result_ids_matches_topk():
    rng = np.random.default_rng(3)
    B, K, M, L, mr = 12, 6, 16, 30, 20
    inside = jnp.asarray(rng.uniform(size=(B, K, M)) < 0.25)
    inside = inside.at[0].set(False)                       # empty row
    inside = inside.at[1].set(True)                        # overflow row
    leaf_idx = jnp.asarray(rng.integers(0, L, (B, K)), jnp.int32)
    valid = jnp.asarray(rng.uniform(size=(B, K)) > 0.2)
    refine = traversal.RefineResult(
        counts=jnp.sum(inside.astype(jnp.int32), -1),
        inside=inside, leaf_idx=leaf_idx, valid=valid)

    class FakeTree:
        leaf_entry_ids = jnp.asarray(rng.integers(0, 10_000, (L, M)),
                                     jnp.int32)

    new_ids, new_tr = traversal.gather_result_ids(FakeTree, refine, mr)
    old_ids, old_tr = traversal.gather_result_ids_topk(FakeTree, refine, mr)
    np.testing.assert_array_equal(np.asarray(new_ids), np.asarray(old_ids))
    np.testing.assert_array_equal(np.asarray(new_tr), np.asarray(old_tr))


def test_range_query_kernel_path_matches_jnp():
    """Full pipeline (fused traversal + sort-free compaction + kernels) is
    indistinguishable from the pure-jnp reference path."""
    rng = np.random.default_rng(11)
    pts = rng.normal(size=(4000, 2))
    tree = RTree(max_entries=16).insert_all(pts)
    dtree = dt.flatten(tree)
    q = jnp.asarray(mk_rects(41, rng, width=0.4))
    r_jnp = traversal.range_query(dtree, q, use_kernel=False)
    r_ker = traversal.range_query(dtree, q, use_kernel=True)
    for f in r_jnp._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(r_jnp, f)), np.asarray(getattr(r_ker, f)),
            err_msg=f)


# ---------------------------------------------------------------------------
# fused traversal + compaction epilogue (traverse_compact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,fanout,B,k", [
    (37, 4, 7, 8),        # L, B far from tile multiples
    (130, 3, 64, 16),     # deep tree, non-power-of-two everything
    (512, 8, 33, 4),      # heavy overflow (k tiny)
    (2048, 8, 256, 64),   # multi-query-tile
    (1, 4, 5, 4),         # degenerate: root == single leaf
])
def test_traverse_compact_matches_oracle(L, fanout, B, k):
    """ops.traverse_compact == compact_mask_counted(jnp oracle mask)."""
    mbrs, parents = synth_levels(L, fanout)
    q = jnp.asarray(mk_rects(B, width=0.4))
    got = ops.traverse_compact(q, mbrs, parents, k)
    exp = traversal.compact_mask_counted(
        ref.traverse_fused(q, mbrs, parents), k)
    for g, e, name in zip(got, exp, ("idx", "valid", "count")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e),
                                      err_msg=name)


@pytest.mark.parametrize("tpu_form", [True, False])
@pytest.mark.parametrize("L,fanout,B,tl,k", [
    (2048, 8, 64, 512, 64),   # multi-leaf-tile: rank base carried across j
    (300, 5, 16, 128, 16),
])
def test_traverse_compact_kernel_forms(L, fanout, B, tl, k, tpu_form):
    """Both kernel forms of the compaction epilogue (chunked rank-equality
    scatter on the TPU graph, rowwise binary search on the interpret graph)
    against the jnp oracle, with the running rank base exercised across
    multiple leaf tiles and dead rows mixed in."""
    from repro.kernels import traverse_fused as tf
    mbrs, parents = synth_levels(L, fanout)
    q = jnp.asarray(np.concatenate([
        mk_rects(B - 4, width=0.5),
        np.tile(np.array([[90.0, 90.0, 91.0, 91.0]], np.float32), (4, 1)),
    ]))
    never = jnp.asarray([np.inf, np.inf, -np.inf, -np.inf], jnp.float32)

    def pad_level(m, p, mult):
        n = m.shape[0]
        padn = (-n) % mult
        if padn:
            m = jnp.concatenate([m, jnp.tile(never[None], (padn, 1))])
            p = jnp.concatenate([p, jnp.zeros((padn,), jnp.int32)])
        return m.T.astype(jnp.float32), p[None, :].astype(jnp.int32)

    int_m, int_p = [], []
    for i in range(len(mbrs) - 1):
        mt, pt = pad_level(mbrs[i], parents[i], tf.LANE)
        int_m.append(mt)
        if i > 0:
            int_p.append(pt)
    leaf_m, leaf_p = pad_level(mbrs[-1], parents[-1], tl)
    tb = (B + 7) // 8 * 8
    qp = jnp.concatenate(
        [q, jnp.zeros((tb - B, 4), jnp.float32)]) if tb != B else q
    idx, cnt = tf.traverse_compact_t(
        qp.T, tuple(int_m), tuple(int_p), leaf_m, leaf_p,
        k=k, tb=tb, tl=tl, interpret=True, tpu_form=tpu_form)
    exp_i, exp_v, exp_c = traversal.compact_mask_counted(
        ref.traverse_fused(q, mbrs, parents), k)
    count = np.asarray(cnt)[:B, 0]
    np.testing.assert_array_equal(count, np.asarray(exp_c))
    valid = np.arange(k)[None, :] < count[:, None]
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(idx)[:B, :k], 0), np.asarray(exp_i))
    # contract: slots past the count are zero in both forms
    assert (np.asarray(idx)[:B, :k][~valid] == 0).all()


def test_traverse_compact_escape_hatch_and_vmem_gate(monkeypatch):
    """Kernels-off and over-VMEM-budget fallbacks stay bit-identical."""
    from repro.kernels import traverse_fused as tf
    mbrs, parents = synth_levels(64, 4)
    q = jnp.asarray(mk_rects(9))
    exp = traversal.compact_mask_counted(
        ref.traverse_fused(q, mbrs, parents), 8)

    monkeypatch.setenv("REPRO_KERNELS", "off")
    got_off = ops.traverse_compact(q, mbrs, parents, 8)
    monkeypatch.delenv("REPRO_KERNELS")
    real_budget = tf.VMEM_BUDGET
    try:
        tf.VMEM_BUDGET = 1
        got_gate = ops.traverse_compact(q, mbrs, parents, 8)
    finally:
        tf.VMEM_BUDGET = real_budget
    for got in (got_off, got_gate):
        for g, e in zip(got, exp):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


def _workload_queries(rng, B):
    """uniform / spatially clustered / all-dead query batches."""
    lo = rng.uniform(-1, 1, (B, 2))
    w = rng.uniform(0, 0.3, (B, 2))
    uniform = np.concatenate([lo, lo + w], 1).astype(np.float32)
    c = rng.uniform(-0.8, 0.6, (1, 2))
    lo = c + rng.uniform(0, 0.15, (B, 2))
    w = rng.uniform(0, 0.05, (B, 2))
    clustered = np.concatenate([lo, lo + w], 1).astype(np.float32)
    alldead = np.tile(np.array([[90.0, 90.0, 91.0, 91.0]], np.float32),
                      (B, 1))
    return {"uniform": uniform, "clustered": clustered, "alldead": alldead}


@pytest.mark.parametrize("workload", ["uniform", "clustered", "alldead"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_range_query_compact_matches_range_query(workload, use_kernel):
    """The serving pipeline (fused traverse+compact → refine) is per-field
    bit-identical to the full-mask range_query oracle."""
    rng = np.random.default_rng(11)
    pts = rng.normal(size=(3000, 2))
    tree = RTree(max_entries=16).insert_all(pts)
    dtree = dt.flatten(tree)
    q = jnp.asarray(_workload_queries(rng, 48)[workload])
    full = traversal.range_query(dtree, q, max_visited=64,
                                 use_kernel=False)
    comp = traversal.range_query_compact(dtree, q, max_visited=64,
                                         use_kernel=use_kernel)
    exp_i, exp_v, _ = traversal.compact_mask_counted(
        jnp.asarray(np.asarray(full.visited)), 64)
    np.testing.assert_array_equal(np.asarray(comp.leaf_idx),
                                  np.asarray(exp_i))
    np.testing.assert_array_equal(np.asarray(comp.valid), np.asarray(exp_v))
    for f in ("n_visited", "n_true", "n_results", "result_ids", "truncated"):
        np.testing.assert_array_equal(
            np.asarray(getattr(comp, f)), np.asarray(getattr(full, f)),
            err_msg=f"{workload}/{f}")


def test_range_query_compact_never_materializes_mask():
    """On the kernel path the lowered HLO must not contain any [B, L]- or
    [B, L_pad]-shaped tensor: the visited mask exists only tile-by-tile
    inside the kernel. (range_query, by contrast, does materialize it.)"""
    import re
    from repro.core.device_tree import DeviceTree, Level

    rng = np.random.default_rng(0)
    L, B = 1000, 256          # L_pad = 1024; tile_b = 128 < B
    mbrs, parents = synth_levels(L, 4)
    dtree = DeviceTree(
        levels=tuple(Level(mbrs=m, parent=p)
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.zeros((L, 8, 2), jnp.float32),
        leaf_entry_ids=jnp.zeros((L, 8), jnp.int32),
        leaf_counts=jnp.zeros((L,), jnp.int32),
        n_points=0, max_entries=4)
    q = jnp.zeros((B, 4), jnp.float32)

    def lowered(fn):
        return jax.jit(lambda t, qq: fn(t, qq)).lower(dtree, q).as_text()

    txt_c = lowered(lambda t, qq: traversal.range_query_compact(
        t, qq, max_visited=64, use_kernel=True, tile_b=128))
    txt_f = lowered(lambda t, qq: traversal.range_query(
        t, qq, max_visited=64, use_kernel=True))
    full_mask = re.compile(r"<256x(1000|1024)x")
    assert not full_mask.search(txt_c), "compact path materialized the mask"
    assert full_mask.search(txt_f), "oracle should materialize the mask"


def test_visited_leaves_compact_oracle_matches_kernel():
    """visited_leaves_compact: jnp path == kernel path on a real tree."""
    rng = np.random.default_rng(3)
    pts = rng.normal(size=(2000, 2))
    tree = RTree(max_entries=16).insert_all(pts)
    dtree = dt.flatten(tree)
    q = jnp.asarray(mk_rects(23, rng, width=0.6))
    a = traversal.visited_leaves_compact(dtree, q, 32, use_kernel=False)
    b = traversal.visited_leaves_compact(dtree, q, 32, use_kernel=True)
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
