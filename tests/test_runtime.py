"""Open-loop streaming runtime: offline equivalence + deadline contracts.

The runtime's whole promise is two-sided: (1) when no deadline forces a
degraded row, its results are *bit-identical* to offline
``serve_workload`` over the same admitted queries — batch grouping is
invisible; (2) when a deadline does fire, the affected rows keep their
best-effort narrow results and are flagged (degraded + still truncated),
never silently dropped. Everything here runs with an injected
``service_time`` model, so the simulated clock — and therefore every
dispatch/degrade decision — is deterministic.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import runtime, schedule, traversal
from repro.data import arrivals
from repro.data.synth_tree import synth_levels
from repro.core.device_tree import DeviceTree, Level


def teardown_module(module):
    # This module jits many one-off (batch, k) serve shapes; drop them so
    # later modules' large kernel compiles don't run on top of the pile.
    jax.clear_caches()


def _queries(n, seed=0, big_frac=0.0):
    rng = np.random.default_rng(seed)
    lo = rng.uniform(-1, 1, (n, 2))
    w = rng.uniform(0, 0.1, (n, 2))
    big = rng.uniform(size=n) < big_frac
    w[big] = rng.uniform(0.5, 1.5, (int(big.sum()), 2))
    return np.concatenate([lo, lo + w], 1).astype(np.float32)


def _tree(L=64, fanout=4, seed=0):
    rng = np.random.default_rng(seed)
    mbrs, parents = synth_levels(L, fanout, rng, str_pack=True)
    return DeviceTree(
        levels=tuple(Level(mbrs=jnp.asarray(m), parent=jnp.asarray(p))
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.asarray(rng.uniform(-1, 1, (L, 8, 2)), jnp.float32),
        leaf_entry_ids=jnp.arange(L * 8, dtype=jnp.int32).reshape(L, 8),
        leaf_counts=jnp.full((L,), 8, jnp.int32),
        n_points=L * 8, max_entries=fanout)


def _serve_fn(tree, k=8, max_results=256):
    return lambda q: traversal.range_query_compact(
        tree, q, max_visited=k, max_results=max_results, use_kernel=False)


def _assert_same(a, b):
    for f in type(a)._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


def _const_cost(narrow=0.01, wide=0.03):
    return lambda n_valid, tier: narrow if tier == "narrow" else wide


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

def test_poisson_arrivals_rate_and_determinism():
    a = arrivals.poisson_arrivals(20_000, rate=50.0, seed=3)
    assert a.shape == (20_000,) and np.all(np.diff(a) >= 0) and a[0] > 0
    assert abs(20_000 / a[-1] - 50.0) / 50.0 < 0.05
    np.testing.assert_array_equal(
        a, arrivals.poisson_arrivals(20_000, rate=50.0, seed=3))


def test_bursty_arrivals_same_mean_higher_variance():
    p = arrivals.poisson_arrivals(20_000, rate=100.0, seed=0)
    b = arrivals.bursty_arrivals(20_000, rate=100.0, seed=0)
    assert np.all(np.diff(b) >= 0)
    # mean rate normalized to target (sum of gaps is exact; diff drops
    # the lead-in gap, so compare end-to-end)
    assert abs(b[-1] - 20_000 * 0.01) < 1e-6
    # burstiness: gap coefficient of variation strictly above Poisson's
    cv = lambda x: np.diff(x).std() / np.diff(x).mean()
    assert cv(b) > 1.3 * cv(p)


def test_trace_roundtrip(tmp_path):
    src = arrivals.poisson_arrivals(500, rate=10.0, seed=1)
    path = str(tmp_path / "trace.npy")
    arrivals.save_trace(path, src)
    # truncate, tile, and rescale
    t = arrivals.load_trace(path, n=200)
    assert t.shape == (200,) and np.all(np.diff(t) >= 0) and t[0] > 0
    t2 = arrivals.load_trace(path, n=1200, rate=40.0)
    assert t2.shape == (1200,) and np.all(np.diff(t2) >= 0)
    assert abs(1200 / t2[-1] - 40.0) / 40.0 < 0.01
    with pytest.raises(ValueError):
        arrivals.make_arrivals("trace", 10, 1.0)      # no path
    with pytest.raises(ValueError):
        arrivals.make_arrivals("nope", 10, 1.0)


# ---------------------------------------------------------------------------
# offline equivalence: no deadline pressure → bit-identical
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("formation", ["deadline", "full"])
@pytest.mark.parametrize("rate", [200.0, 2000.0])
def test_runtime_bit_identical_to_offline(formation, rate):
    """Any batch grouping the open loop produces — partial dispatches,
    urgency-centered curve windows, immediate wide re-serves — must be
    invisible in the per-query results when deadlines never bind."""
    tree = _tree()
    q = _queries(150, seed=5, big_frac=0.2)
    arr = arrivals.poisson_arrivals(150, rate=rate, seed=2)
    narrow = _serve_fn(tree, k=4)
    wide = _serve_fn(tree, k=64)
    rep = runtime.run_stream(
        narrow, q, arr, batch=32, deadline_s=1e9, wide_fn=wide,
        trunc_field="truncated", formation=formation,
        service_time=_const_cost())
    off = schedule.serve_workload(narrow, q, batch=32, sort="hilbert",
                                  wide_fn=wide, trunc_field="truncated")
    assert rep.n_degraded == 0
    _assert_same(rep.stats, off.stats)
    # zero silent drops: every query completed after it arrived
    assert np.all(rep.done_s > rep.arrival_s)
    assert rep.goodput == 1.0


def test_runtime_no_wide_fn_matches_offline_narrow():
    tree = _tree()
    q = _queries(60, seed=7, big_frac=0.3)
    arr = arrivals.poisson_arrivals(60, rate=500.0, seed=0)
    narrow = _serve_fn(tree, k=4)
    rep = runtime.run_stream(narrow, q, arr, batch=16, deadline_s=1e9,
                             trunc_field="truncated",
                             service_time=_const_cost())
    off = schedule.serve_workload(narrow, q, batch=16, sort="hilbert")
    _assert_same(rep.stats, off.stats)
    assert rep.n_wide_batches == 0


def test_runtime_single_query_and_tiny_batches():
    tree = _tree()
    q = _queries(1, seed=1)
    arr = arrivals.poisson_arrivals(1, rate=10.0)
    rep = runtime.run_stream(_serve_fn(tree), q, arr, batch=8,
                             deadline_s=1e9, service_time=_const_cost())
    off = schedule.serve_workload(_serve_fn(tree), q, batch=8,
                                  sort="hilbert")
    _assert_same(rep.stats, off.stats)
    assert rep.n_batches == 1 and rep.mean_fill == pytest.approx(1 / 8)


# ---------------------------------------------------------------------------
# deadline behavior
# ---------------------------------------------------------------------------

def test_deadline_formation_dispatches_partial_batches():
    """Sparse arrivals + binding deadlines: the open loop must ship
    partially-full batches on time instead of waiting to fill — the
    fixed-full-batch baseline blows every early deadline instead."""
    tree = _tree()
    q = _queries(40, seed=3)
    arr = arrivals.poisson_arrivals(40, rate=100.0, seed=4)   # ~10ms gaps
    cost = _const_cost(narrow=0.005, wide=0.005)
    dl = runtime.run_stream(_serve_fn(tree), q, arr, batch=32,
                            deadline_s=0.05, formation="deadline",
                            service_time=cost)
    fb = runtime.run_stream(_serve_fn(tree), q, arr, batch=32,
                            deadline_s=0.05, formation="full",
                            service_time=cost)
    assert dl.mean_fill < 1.0
    assert dl.n_missed < fb.n_missed
    assert dl.goodput > fb.goodput
    assert dl.telemetry["latency_s"]["p99"] \
        < fb.telemetry["latency_s"]["p99"]
    # and the underlying answers still agree row-for-row
    _assert_same(dl.stats, fb.stats)


def test_degraded_rows_flagged_never_dropped():
    """Tight deadlines + expensive wide tier: truncated rows whose
    re-serve would blow the deadline keep their narrow best-effort
    answer, stay flagged truncated, and are marked degraded; rows with
    slack still get exact wide answers."""
    tree = _tree()
    q = _queries(80, seed=11, big_frac=0.5)
    arr = arrivals.poisson_arrivals(80, rate=5000.0, seed=1)
    narrow = _serve_fn(tree, k=4)
    wide = _serve_fn(tree, k=64)
    # wide steps cost more than the whole deadline → every truncated
    # row must degrade
    rep = runtime.run_stream(
        narrow, q, arr, batch=16, deadline_s=0.05, wide_fn=wide,
        trunc_field="truncated", formation="deadline",
        service_time=_const_cost(narrow=0.001, wide=10.0))
    off_n = schedule.serve_workload(narrow, q, batch=16, sort="hilbert")
    trunc = np.asarray(off_n.stats.truncated).astype(bool)
    assert trunc.any(), "fixture too weak: nothing overflowed"
    assert rep.n_wide_batches == 0
    assert rep.n_degraded == int(trunc.sum())
    np.testing.assert_array_equal(rep.degraded, trunc)
    # degraded rows: narrow answers, truncation flag intact
    _assert_same(rep.stats, off_n.stats)
    # zero drops: every row has a completion stamp and a result row
    assert np.all(rep.done_s > 0)

    # generous wide cost → the same rows re-serve and match offline
    rep2 = runtime.run_stream(
        narrow, q, arr, batch=16, deadline_s=1e9, wide_fn=wide,
        trunc_field="truncated", formation="deadline",
        service_time=_const_cost())
    off_w = schedule.serve_workload(narrow, q, batch=16, sort="hilbert",
                                    wide_fn=wide, trunc_field="truncated")
    assert rep2.n_degraded == 0
    _assert_same(rep2.stats, off_w.stats)


def test_degrade_is_per_row_not_per_batch():
    """Per-query deadlines: within one narrow batch, only the rows whose
    own slack fails the wide-cost test degrade."""
    tree = _tree()
    q = _queries(30, seed=13, big_frac=1.0)    # everything truncates @k=4
    arr = np.full((30,), 0.001)
    deadlines = np.where(np.arange(30) % 2 == 0, 10.0, 1e-4)
    rep = runtime.run_stream(
        _serve_fn(tree, k=4), q, arr, batch=30, deadline_s=deadlines,
        wide_fn=_serve_fn(tree, k=64), trunc_field="truncated",
        formation="deadline", service_time=_const_cost(0.01, 0.05))
    off_n = schedule.serve_workload(_serve_fn(tree, k=4), q, batch=30,
                                    sort="hilbert")
    trunc = np.asarray(off_n.stats.truncated).astype(bool)
    odd = np.arange(30) % 2 == 1
    assert (trunc & odd).sum() > 5, "fixture too weak"
    np.testing.assert_array_equal(rep.degraded, trunc & odd)
    # even-index rows got exact wide answers
    off_w = schedule.serve_workload(
        _serve_fn(tree, k=4), q, batch=30, sort="hilbert",
        wide_fn=_serve_fn(tree, k=64), trunc_field="truncated")
    sel = ~rep.degraded
    for f in type(rep.stats)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rep.stats, f))[sel],
            np.asarray(getattr(off_w.stats, f))[sel], err_msg=f)


def test_runtime_telemetry_and_validation():
    tree = _tree()
    q = _queries(20, seed=0)
    arr = arrivals.poisson_arrivals(20, rate=100.0)
    rep = runtime.run_stream(_serve_fn(tree), q, arr, batch=8,
                             deadline_s=1.0, service_time=_const_cost())
    t = rep.telemetry
    assert t["latency_s"]["n"] == 20
    assert t["latency_s"]["p50"] <= t["latency_s"]["p99"]
    assert t["ewma_narrow_s"] == pytest.approx(0.01)
    assert t["queue_depth"]["n"] == rep.n_batches
    with pytest.raises(ValueError):
        runtime.run_stream(_serve_fn(tree), q, arr[:-1], batch=8,
                           deadline_s=1.0)
    with pytest.raises(ValueError):
        runtime.run_stream(_serve_fn(tree), q, arr, batch=8,
                           deadline_s=1.0, formation="nope")
    with pytest.raises(ValueError):
        runtime.run_stream(_serve_fn(tree), q, arr[::-1], batch=8,
                           deadline_s=1.0)
