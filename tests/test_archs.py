"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: one forward/train step — output shapes + no NaNs —
plus a decode-vs-forward consistency check that validates the KV-cache
serving path against the training forward.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import transformer as tf
from repro.models.layers import rmsnorm
from repro.models.transformer import _mlp
import repro.models.attention as attn
from repro.serving import kvcache, decode

RNG = np.random.default_rng(0)


def make_batch(r, B=2, S=16):
    batch = {"tokens": jnp.asarray(RNG.integers(1, r.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(RNG.integers(1, r.vocab, (B, S)),
                                   jnp.int32)}
    if r.family == "encdec":
        batch["frames"] = jnp.asarray(
            RNG.normal(size=(B, r.enc_seq, r.d_model)), jnp.float32)
    return batch


def encode_and_fill_cross(r, params, frames, cache):
    """Build encoder output + cross k/v cache (whisper serving prep)."""
    B = frames.shape[0]
    f = frames + params["enc_pos"][None, :r.enc_seq]

    def enc_body(h, lp):
        hn = rmsnorm(h, lp["norm1"], r.norm_eps)
        q, k, v = attn.gqa_qkv(r, lp["attn"], hn,
                               positions=jnp.zeros((B, r.enc_seq), jnp.int32))
        o = attn.blockwise_attention(q, k, v, causal=False, window=0)
        o = o.transpose(0, 2, 1, 3).reshape(B, r.enc_seq, r.q_dim)
        h = h + jnp.einsum("bsq,qd->bsd", o, lp["attn"]["wo"])
        hn = rmsnorm(h, lp["norm2"], r.norm_eps)
        return h + _mlp(r, lp["mlp"], hn), None

    e, _ = jax.lax.scan(enc_body, f, params["enc_layers"])
    enc = rmsnorm(e, params["enc_norm"], r.norm_eps)

    def fill(_, lp):
        k = jnp.einsum("bsd,dk->bsk", enc, lp["xattn"]["wk"]).reshape(
            B, r.enc_seq, r.n_kv_heads, r.d_head).transpose(0, 2, 1, 3)
        v = jnp.einsum("bsd,dk->bsk", enc, lp["xattn"]["wv"]).reshape(
            B, r.enc_seq, r.n_kv_heads, r.d_head).transpose(0, 2, 1, 3)
        return None, (k, v)

    _, (xk, xv) = jax.lax.scan(fill, None, params["layers"])
    cache["xk"], cache["xv"] = xk, xv
    return cache


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_smoke(arch):
    r = configs.reduced(configs.get_config(arch))
    params = tf.init_params(r, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = make_batch(r)
    logits = tf.forward(r, params, batch, remat_policy=None)
    assert logits.shape == (2, 16, r.vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    loss = tf.loss_fn(r, params, batch, remat_policy=None)
    assert np.isfinite(float(loss))


@pytest.mark.slow
@pytest.mark.parametrize("arch", configs.ARCHS)
def test_train_grad_smoke(arch):
    r = configs.reduced(configs.get_config(arch))
    params = tf.init_params(r, jax.random.PRNGKey(1), dtype=jnp.float32)
    batch = make_batch(r, B=2, S=8)
    g = jax.grad(lambda p: tf.loss_fn(r, p, batch, remat_policy="dots"))(
        params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in flat)
    # at least the embedding gradient must be non-zero
    assert float(jnp.abs(g["embed"]).sum()) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_matches_forward(arch):
    cfg = configs.get_config(arch)
    r = configs.reduced(cfg)
    if r.family == "moe":   # drop-free capacity for an exact comparison
        r = dataclasses.replace(r, capacity_factor=float(r.n_experts))
    params = tf.init_params(r, jax.random.PRNGKey(0), dtype=jnp.float32)
    B, S = 2, 12
    batch = make_batch(r, B, S)
    fwd = tf.forward(r, params, batch, remat_policy=None)
    cache = kvcache.make_cache(r, B, seq_len=16, dtype=jnp.float32)
    if r.family == "encdec":
        cache = encode_and_fill_cross(r, params, batch["frames"], cache)
    logits, _ = decode.prefill_via_decode(r, params, cache,
                                          batch["tokens"])
    ref = fwd[:, -1]
    rel = float(jnp.abs(logits - ref).max()) / \
        (float(jnp.abs(ref).max()) + 1e-9)
    assert rel < 2e-2, f"{arch}: decode diverges from forward (rel {rel})"


def test_sub_quadratic_flags():
    # long_500k policy (DESIGN.md §Arch-applicability)
    expect = {"rwkv6_3b": True, "hymba_1_5b": True, "h2o_danube3_4b": True,
              "llama3_405b": False, "qwen2_72b": False, "gemma2_9b": False,
              "whisper_small": False, "qwen2_vl_72b": False,
              "deepseek_moe_16b": False, "deepseek_v2_236b": False}
    for arch, want in expect.items():
        assert configs.get_config(arch).sub_quadratic == want, arch


def test_param_count_sanity():
    # published total parameter counts, loose tolerance (±25%)
    approx = {"llama3_405b": 405e9, "qwen2_72b": 72e9, "gemma2_9b": 9e9,
              "rwkv6_3b": 3e9, "deepseek_moe_16b": 16e9,
              "deepseek_v2_236b": 236e9, "hymba_1_5b": 1.5e9,
              "h2o_danube3_4b": 4e9}
    for arch, want in approx.items():
        got = configs.get_config(arch).n_params()
        assert 0.7 * want < got < 1.35 * want, (arch, got / 1e9)
