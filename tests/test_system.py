"""End-to-end system behaviour tests for the AI+R-tree framework.

Covers the integration paths that unit tests don't: full build→serve flows,
distributed engine equivalence (subprocess with 8 fake host devices), and a
single dry-run cell lowering (subprocess so the 512-device XLA flag never
leaks into this process).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import build, device_tree as dt, labels
from repro.core.hybrid import hybrid_query
from repro.core.rtree import RTree
from repro.data import synth

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


@pytest.fixture(scope="module")
def system():
    pts = synth.crimes_like(25_000, seed=11)
    tree = RTree(max_entries=48).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 1e-4, 1500, seed=12)
    wl = labels.make_workload(dtree, qs)
    hyb, rep = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6, 10))
    return pts, dtree, wl, hyb, rep


def test_end_to_end_hybrid_beats_classical_cost(system):
    """Under the paper's cost model, hybrid ≤ classical on a mixed workload."""
    _, _, wl, hyb, _ = system
    q = jnp.asarray(wl.queries[:512])
    hybrid = hybrid_query(hyb, q)
    classical = hybrid_query(hyb, q, force_path="r")
    io = 13.0
    cost_h = io * float(np.asarray(hybrid.leaf_accesses).mean())
    cost_r = io * float(np.asarray(classical.leaf_accesses).mean())
    assert cost_h <= cost_r * 1.01


def test_alpha_identifies_improvable_queries(system):
    """Leaf-access savings concentrate on high-overlap (low α) queries."""
    _, _, wl, hyb, _ = system
    lo = wl.alpha <= 0.5
    hi = wl.alpha > 0.9
    if lo.sum() < 20 or hi.sum() < 20:
        pytest.skip("degenerate α split")
    q_lo = jnp.asarray(wl.queries[lo][:128])
    q_hi = jnp.asarray(wl.queries[hi][:128])
    save = []
    for q in (q_lo, q_hi):
        ai = hybrid_query(hyb, q, force_path="ai")
        r = hybrid_query(hyb, q, force_path="r")
        save.append(1 - float(np.asarray(ai.leaf_accesses).mean())
                    / max(float(np.asarray(r.leaf_accesses).mean()), 1e-9))
    assert save[0] > save[1]


def test_router_discriminates_by_overlap(system):
    """The router must send low-α (high-overlap) queries to the AI path
    more often than high-α ones — discrimination, not an absolute rate
    (the absolute rate tracks the workload's base rate, per the paper)."""
    _, _, wl, hyb, _ = system
    hi_alpha = wl.alpha > 0.95          # clearly low-overlap queries
    lo_alpha = wl.alpha <= 0.5          # clearly high-overlap queries
    if hi_alpha.sum() < 20 or lo_alpha.sum() < 20:
        pytest.skip("degenerate α split")
    r_hi = hybrid_query(hyb, jnp.asarray(wl.queries[hi_alpha][:128]))
    r_lo = hybrid_query(hyb, jnp.asarray(wl.queries[lo_alpha][:128]))
    assert np.asarray(r_lo.routed_high).mean() > \
        np.asarray(r_hi.routed_high).mean()


@pytest.mark.slow
def test_distributed_engine_equivalence_subprocess(system):
    """shard_map engine == single-device hybrid, on 8 fake host devices."""
    script = os.path.join(REPO, "tests", "helpers", "engine_equiv.py")
    out = subprocess.run([sys.executable, script], env=ENV,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "EQUIVALENT" in out.stdout


def test_dryrun_single_cell_subprocess():
    """One small-arch cell lowers+compiles on the 512-device production mesh."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "hymba-1.5b", "--shape", "decode_32k"],
        env=ENV, capture_output=True, text=True, timeout=560, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ok in" in out.stdout, out.stdout
    rec_path = os.path.join(REPO, "benchmarks", "results", "dryrun",
                            "hymba-1.5b__decode_32k__16x16.json")
    with open(rec_path) as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["cost"].get("flops", 0) > 0
