"""Unit tests for the shared compaction epilogues (``kernels.epilogue``).

The extraction out of ``traverse_fused`` is pure code motion: both forms
must stay bit-identical to the canonical ``compact_mask_counted`` scheme
when driven over a multi-tile column sweep, and the old private names must
remain importable from ``traverse_fused`` (back-compat for any caller
still reaching through the kernel module).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import traversal
from repro.kernels import epilogue as ep


def _run_epilogue(mask: np.ndarray, kp: int, tl: int, form: str,
                  kc: int = 8):
    """Drive one epilogue form over a (1, n_tiles) grid of column tiles,
    exactly as the fused kernels do: both output blocks map to ``(i, 0)``
    so they carry the running rank state across the sweep."""
    B, N = mask.shape
    assert N % tl == 0
    n_j = N // tl

    def kernel(m_ref, idx_ref, cnt_ref):
        j = pl.program_id(0)
        m = m_ref[:, :] != 0
        if form == "tpu":
            @pl.when(j == 0)
            def _init():
                idx_ref[:, :] = jnp.zeros((B, kp), jnp.int32)
                cnt_ref[:, :] = jnp.zeros((B, 1), jnp.int32)
            col = j * tl + jax.lax.broadcasted_iota(jnp.int32, (B, tl), 1)
            ep.compact_epilogue_tpu(m, col, idx_ref, cnt_ref, kp, kc)
        else:
            ep.compact_epilogue_interp(m, j, tl, kp, idx_ref, cnt_ref)

    idx, cnt = pl.pallas_call(
        kernel,
        grid=(n_j,),
        in_specs=[pl.BlockSpec((B, tl), lambda j: (0, j))],
        out_specs=[pl.BlockSpec((B, kp), lambda j: (0, 0)),
                   pl.BlockSpec((B, 1), lambda j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, kp), jnp.int32),
                   jax.ShapeDtypeStruct((B, 1), jnp.int32)],
        interpret=True,
    )(jnp.asarray(mask, jnp.int32))
    return np.asarray(idx), np.asarray(cnt)[:, 0]


def _masks(seed: int, B: int = 16, N: int = 64):
    rng = np.random.default_rng(seed)
    dense = rng.random((B, N)) < 0.3
    sparse = rng.random((B, N)) < 0.02
    empty = np.zeros((B, N), bool)
    full = np.ones((B, N), bool)
    onerow = np.zeros((B, N), bool)
    onerow[0] = rng.random(N) < 0.5
    return {"dense": dense, "sparse": sparse, "empty": empty,
            "full": full, "onerow": onerow}


@pytest.mark.parametrize("form", ["tpu", "interp"])
@pytest.mark.parametrize("tl", [16, 32, 64])
@pytest.mark.parametrize("kp", [8, 16])
def test_epilogue_matches_compact_mask_counted(form, tl, kp):
    # kc never exceeds kp in real callers (COMPACT_KC=8 vs max_pred/
    # max_visited bounds); the chunk loop slices kc-wide ref windows
    for name, mask in _masks(0).items():
        idx, cnt = _run_epilogue(mask, kp, tl, form)
        ref_idx, ref_valid, ref_cnt = jax.jit(
            traversal.compact_mask_counted, static_argnums=1)(
                jnp.asarray(mask), kp)
        ref_idx, ref_valid, ref_cnt = (np.asarray(ref_idx),
                                       np.asarray(ref_valid),
                                       np.asarray(ref_cnt))
        np.testing.assert_array_equal(cnt, ref_cnt, err_msg=f"{name} count")
        # the kernels only define slots of rank < count; invalid slots are
        # zero-initialized in the tpu form and unspecified-but-masked in
        # the reference — compare the masked table
        np.testing.assert_array_equal(
            np.where(ref_valid, idx, 0), np.where(ref_valid, ref_idx, 0),
            err_msg=f"{name} slots ({form}, tl={tl}, kp={kp})")


@pytest.mark.parametrize("tl", [16, 64])
def test_epilogue_forms_agree(tl):
    """The two forms are bit-identical to each other on defined slots."""
    for name, mask in _masks(1).items():
        kp = 8
        idx_t, cnt_t = _run_epilogue(mask, kp, tl, "tpu")
        idx_i, cnt_i = _run_epilogue(mask, kp, tl, "interp")
        valid = np.arange(kp)[None, :] < cnt_t[:, None]
        np.testing.assert_array_equal(cnt_t, cnt_i, err_msg=name)
        np.testing.assert_array_equal(np.where(valid, idx_t, 0),
                                      np.where(valid, idx_i, 0),
                                      err_msg=name)


def test_overflow_rows_keep_first_kp():
    """Rows with more set lanes than slots keep the first kp in column
    order and report the exact total count (the overflow signal)."""
    mask = np.zeros((4, 64), bool)
    mask[2, ::2] = True            # 32 set lanes, kp = 8
    for form in ("tpu", "interp"):
        idx, cnt = _run_epilogue(mask, 8, 16, form)
        assert cnt[2] == 32
        np.testing.assert_array_equal(idx[2], np.arange(0, 16, 2))


def test_backcompat_names_are_the_shared_helpers():
    """``traverse_fused`` re-exports the moved helpers — same objects, so
    the kernels cannot drift from the shared implementation."""
    from repro.kernels import traverse_fused as tf
    assert tf._compact_epilogue_tpu is ep.compact_epilogue_tpu
    assert tf._compact_epilogue_interp is ep.compact_epilogue_interp
    from repro.kernels import delta_probe as dp
    from repro.kernels import mlp_infer as mi
    assert dp._compact_epilogue_tpu is ep.compact_epilogue_tpu
    assert mi._compact_epilogue_interp is ep.compact_epilogue_interp
