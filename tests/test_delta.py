"""Freshness subsystem tests: delta store, probe kernel, guard, repack.

The correctness anchor: serving with a populated delta buffer must be
bit-identical to serving a from-scratch ``str_bulk`` tree containing the
same points (result counts and result-id sets — structural stats like
visit counts legitimately differ between the two trees), and the online
repack must preserve that. The delta path must add no dense ``[B, cap]``
containment mask to the serving HLO. The guard must recover the silently
dropped hits of an ``exact_fit < 1`` bank while leaving exact-fit banks'
dispatch unchanged.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import build, device_tree as dt, engine, labels
from repro.core import delta as deltalib
from repro.core import geometry as geo
from repro.core.hybrid import hybrid_query
from repro.core.monitor import FreshServer, FreshnessMonitor
from repro.core.rtree import RTree
from repro.data import synth
from repro.kernels import delta_probe as dpk
from repro.kernels import ops, ref
from tests.helpers.hypo import given, settings, st

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def _buffer(rng, cap, fill):
    pts = np.full((cap, 2), np.inf, np.float32)
    pts[:fill] = rng.uniform(-1, 1, (fill, 2))
    return jnp.asarray(pts)


def _rects(rng, B, w=0.5):
    lo = rng.uniform(-1, 1, (B, 2))
    wd = rng.uniform(0, w, (B, 2))
    return jnp.asarray(np.concatenate([lo, lo + wd], 1), jnp.float32)


# ---------------------------------------------------------------------------
# kernel vs oracle, both forms + ops wrapper
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,cap,fill,k", [
    (37, 300, 211, 8),     # nothing tile-aligned, partial fill
    (64, 1000, 1000, 16),  # full buffer, multi-tile shapes
    (8, 100, 0, 4),        # empty buffer
])
def test_ops_wrapper_matches_oracle(B, cap, fill, k):
    rng = np.random.default_rng(3)
    q = _rects(rng, B)
    pts = _buffer(rng, cap, fill)
    exp = ref.delta_probe(q, pts, k)
    got = ops.delta_probe(q, pts, k=k)
    for g, e, name in zip(got, exp, ("idx", "valid", "count")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e),
                                      err_msg=name)
    if fill == 0:
        assert not np.asarray(got[2]).any(), "empty buffer must hit nothing"


@pytest.mark.parametrize("tpu_form", [True, False])
@pytest.mark.parametrize("cap,tn", [
    (1000, 256),   # multi-buffer-tile: rank base carried across j
    (200, 128),
])
def test_kernel_forms_match_oracle(cap, tn, tpu_form):
    """Both kernel forms (chunked rank-equality scatter on the TPU graph,
    searchsorted on the interpret graph) against the dense oracle, with
    the compaction rank base exercised across buffer tiles, a no-hit row
    and padding tiles (+inf) mixed in."""
    rng = np.random.default_rng(5)
    B, k, fill = 21, 8, cap - cap // 4
    q = _rects(rng, B)
    q = q.at[0].set(jnp.asarray([5.0, 5.0, 6.0, 6.0]))  # hits nothing
    pts = _buffer(rng, cap, fill)
    exp = ref.delta_probe(q, pts, k)

    tb = (B + 7) // 8 * 8
    qp = jnp.concatenate([q, jnp.zeros((tb - B, 4), jnp.float32)])
    Np = (cap + tn - 1) // tn * tn
    pp = jnp.concatenate(
        [pts, jnp.full((Np - cap, 2), jnp.inf, jnp.float32)])
    idx, cnt = dpk.delta_probe_t(qp.T, pp.T, k=k, tb=tb, tn=tn,
                                 interpret=True, tpu_form=tpu_form)
    count = np.asarray(cnt)[:B, 0]
    np.testing.assert_array_equal(count, np.asarray(exp[2]))
    valid = np.arange(k)[None, :] < count[:, None]
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(idx)[:B, :k], 0), np.asarray(exp[0]))
    assert (np.asarray(idx)[:B, :k][~valid] == 0).all()
    assert not count[0], "no-hit row must probe empty"


def test_exactly_k_and_overflow_boundary():
    """A row hitting exactly k buffer points must not overflow; k-1 slots
    must — and the count stays the *full* hit total either way (result
    counts never truncate)."""
    rng = np.random.default_rng(7)
    cap, m = 64, 5
    pts = np.full((cap, 2), np.inf, np.float32)
    pts[:m] = rng.uniform(0.2, 0.4, (m, 2))        # all inside the query
    pts = jnp.asarray(pts)
    q = jnp.asarray([[0.0, 0.0, 1.0, 1.0]], jnp.float32)
    for k, over in ((m, False), (m - 1, True)):
        idx, valid, count = ops.delta_probe(q, pts, k=k)
        assert int(count[0]) == m
        assert int(np.asarray(valid).sum()) == min(m, k)
        assert bool(count[0] > k) == over


def test_escape_hatch_and_vmem_gate(monkeypatch):
    """Kernels-off and over-VMEM-budget rungs of the fallback ladder stay
    bit-identical to the kernel path."""
    from repro.kernels import traverse_fused as tf
    rng = np.random.default_rng(11)
    q = _rects(rng, 19)
    pts = _buffer(rng, 250, 180)
    base = ops.delta_probe(q, pts, k=8)
    monkeypatch.setenv("REPRO_KERNELS", "off")
    got_off = ops.delta_probe(q, pts, k=8)
    monkeypatch.delenv("REPRO_KERNELS")
    real = tf.VMEM_BUDGET
    try:
        tf.VMEM_BUDGET = 1
        got_gate = ops.delta_probe(q, pts, k=8)
    finally:
        tf.VMEM_BUDGET = real
    for got in (got_off, got_gate):
        for g, e in zip(got, base):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


# ---------------------------------------------------------------------------
# store mechanics
# ---------------------------------------------------------------------------

def test_stage_append_ids_and_overflow():
    rng = np.random.default_rng(0)
    store = deltalib.make_delta(16, base=100)
    a = rng.uniform(-1, 1, (10, 2))
    store = deltalib.stage_inserts(store, a)
    assert store.n == 10 and store.base == 100
    np.testing.assert_allclose(deltalib.staged_points(store),
                               a.astype(np.float32))
    assert np.isinf(np.asarray(store.xy)[10:]).all()
    store = deltalib.stage_inserts(store, rng.uniform(-1, 1, (6, 2)))
    assert store.n == 16
    with pytest.raises(ValueError, match="overflow"):
        deltalib.stage_inserts(store, rng.uniform(-1, 1, (1, 2)))
    # probe ids continue the tree's numbering: base + slot
    q = jnp.asarray([[-1, -1, 1, 1]], jnp.float32)
    hits = deltalib.probe(store.xy, q, k=16, base=store.base)
    ids = np.asarray(hits.ids)[0]
    assert set(ids[ids >= 0]) == set(range(100, 116))


def test_merge_hybrid_result_placement_and_truncation():
    """Delta ids land in the result table's -1 padding after the tree's
    ids; counts add exactly; rows whose merged ids no longer fit (or
    whose hits overflow the probe slots) raise ``truncated``."""
    from repro.core.hybrid import HybridResult
    B, mr, k = 3, 6, 4
    z = jnp.zeros((B,), jnp.int32)
    zb = jnp.zeros((B,), bool)
    rid = jnp.asarray([[7, 8, -1, -1, -1, -1],
                       [-1] * 6,
                       [1, 2, 3, 4, 5, -1]], jnp.int32)
    res = HybridResult(routed_high=zb, used_ai=zb,
                       n_results=jnp.asarray([2, 0, 5], jnp.int32),
                       result_ids=rid, leaf_accesses=z, n_visited_r=z,
                       n_true=z, truncated=zb, guarded=zb,
                       mispredict=zb, cell_id=z - 1)
    hits = deltalib.DeltaHits(
        slot_idx=jnp.asarray([[0, 1, 0, 0], [2, 0, 0, 0], [0, 1, 2, 3]],
                             jnp.int32),
        valid=jnp.asarray([[1, 1, 0, 0], [1, 0, 0, 0], [1, 1, 1, 1]],
                          bool),
        count=jnp.asarray([2, 1, 9], jnp.int32),
        ids=jnp.asarray([[100, 101, -1, -1], [102, -1, -1, -1],
                         [100, 101, 102, 103]], jnp.int32))
    out = deltalib.merge_hybrid_result(res, hits)
    np.testing.assert_array_equal(np.asarray(out.n_results), [4, 1, 14])
    np.testing.assert_array_equal(
        np.asarray(out.result_ids[0]), [7, 8, 100, 101, -1, -1])
    np.testing.assert_array_equal(
        np.asarray(out.result_ids[1]), [102, -1, -1, -1, -1, -1])
    np.testing.assert_array_equal(
        np.asarray(out.result_ids[2]), [1, 2, 3, 4, 5, 100])
    np.testing.assert_array_equal(np.asarray(out.truncated),
                                  [False, False, True])


def test_monitor_staleness_and_repack():
    from repro.core.grid import Grid
    grid = Grid(bbox=jnp.asarray([0.0, 0.0, 1.0, 1.0], jnp.float32), g=2)
    mon = FreshnessMonitor(grid, np.asarray([True, True, False, True]))
    assert mon.cell_ok().tolist() == [True, True, False, True]
    mon.note_inserts(np.asarray([[0.1, 0.1], [0.9, 0.1]]))  # cells 0, 1
    assert mon.cell_ok().tolist() == [False, False, False, True]
    mon.note_repack()      # bulk reload renumbers every leaf: all stale
    assert not mon.cell_ok().any()
    mon.note_refit(np.asarray([True, False, True, True]))
    assert mon.cell_ok().tolist() == [True, False, True, True]
    # out-of-bbox inserts clamp into edge cells (conservative)
    mon.note_inserts(np.asarray([[5.0, 5.0]]))
    assert mon.cell_ok().tolist() == [True, False, True, False]


# ---------------------------------------------------------------------------
# the correctness anchor: inserts→serve ≡ rebuild→serve, repack ≡ rebuild
# ---------------------------------------------------------------------------

def _synth_fresh_world(rng, n_base, n_ins, n_q):
    """Untrained hybrid over a real STR tree: the bank never predicts
    (all queries fall back to the exact R path), so the property is
    pinned on serving mechanics, not training quality."""
    from tests.test_mlp_infer import synth_bank
    from repro.core.aitree import make_aitree
    from repro.core.classifiers.router import Router
    from repro.core.grid import Grid
    from repro.core.hybrid import HybridTree
    pts = rng.uniform(-1, 1, (n_base + n_ins, 2))
    base, extra = pts[:n_base], pts[n_base:]
    dtree = dt.flatten(RTree.str_bulk(base, max_entries=8))
    bank = synth_bank(rng, 9, dtree.n_leaves, pos_bias=-30.0)
    ait = make_aitree(
        Grid(bbox=jnp.asarray([-1, -1, 1, 1], jnp.float32), g=3), bank,
        max_cells=4, max_pred=8)
    router = Router(
        feat_idx=jnp.asarray(rng.integers(0, 6, (4, 3)), jnp.int32),
        thresh=jnp.asarray(rng.uniform(-1, 1, (4, 3)), jnp.float32),
        tables=jnp.asarray(rng.uniform(0, 1, (4, 8, 1)), jnp.float32),
        tau=0.75)
    hyb = HybridTree(tree=dtree, ait=ait, router=router)
    lo = rng.uniform(-1, 0.8, (n_q, 2))
    w = rng.uniform(0, 0.4, (n_q, 2))
    q = np.concatenate([lo, lo + w], 1).astype(np.float32)
    return base, extra, hyb, q


def _id_sets(result_ids):
    return [sorted(int(x) for x in row if x >= 0)
            for row in np.asarray(result_ids)]


@settings(max_examples=10, deadline=None)
@given(st.integers(40, 300), st.integers(1, 120), st.integers(0, 2**31 - 1))
def test_fresh_serving_equals_rebuild(n_base, n_ins, seed):
    """Property: serve(base tree + staged buffer) ≡ serve(str_bulk over
    all points) — result counts bit-identical, result-id sets identical —
    and after repack the serve is bit-identical on *every* field."""
    rng = np.random.default_rng(seed)
    base, extra, hyb, q = _synth_fresh_world(rng, n_base, n_ins, 16)
    srv = FreshServer(base, hyb, delta_cap=max(8, n_ins),
                      max_visited=256, max_results=512)
    srv.insert(extra)
    qj = jnp.asarray(q)
    fresh = srv.serve(qj)

    rebuilt_tree = dt.flatten(
        RTree.str_bulk(np.concatenate([base, extra]), max_entries=8))
    hyb2 = dataclasses.replace(hyb, tree=rebuilt_tree)
    rebuilt = hybrid_query(hyb2, qj, max_visited=256, max_results=512)
    np.testing.assert_array_equal(np.asarray(fresh.n_results),
                                  np.asarray(rebuilt.n_results))
    assert _id_sets(fresh.result_ids) == _id_sets(rebuilt.result_ids)

    # repack ≡ rebuild: bit-identical on every shared field. The
    # comparator carries the server's own post-repack guard state (all
    # cells stale until a refit — by design), so what's under test is
    # exactly that the swapped tree is a fresh bulk load of the same
    # points.
    srv.repack()
    packed = srv.serve(qj)
    rebuilt2 = hybrid_query(
        dataclasses.replace(srv.hybrid, tree=rebuilt_tree), qj,
        max_visited=256, max_results=512)
    for f in type(rebuilt2)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(packed, f)),
            np.asarray(getattr(rebuilt2, f)),
            err_msg=f"repack vs rebuild: {f}")


def test_fresh_serving_trained_world():
    """Integration on a *trained* world (real router traffic, AI-path
    answers live): a mixed stream's counts match brute-force containment
    over each segment's visible points (``serve_mixed_workload`` +
    two-tier + guard all engaged)."""
    from repro.core import schedule
    pts = synth.tweets_like(6000, seed=0)
    base, extra = pts[:5400], pts[5400:]
    dtree = dt.flatten(RTree.str_bulk(base, max_entries=32))
    qs = synth.synth_queries(pts, 2e-4, 300, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, rep = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6,))
    srv = FreshServer(base, hyb, delta_cap=1024, max_visited=64,
                      max_results=256, wide_factor=8)
    mixed = schedule.serve_mixed_workload(
        srv, wl.queries, extra, batch=64, sort="hilbert", insert_every=1,
        repack_every=400)
    assert mixed.n_repacks >= 1
    assert int(np.asarray(mixed.stats.delta_hits).sum()) > 0
    # visibility from the scheduler's own staging report, not re-derived
    got = np.asarray(mixed.stats.n_results)
    for (lo, hi), visible in schedule.visible_segments(mixed, base):
        exp = geo.np_contains_point(
            wl.queries[lo:hi][:, None, :], visible[None, :, :]).sum(axis=1)
        np.testing.assert_array_equal(got[lo:hi], exp,
                                      err_msg=f"segment {lo}:{hi}")


def test_repack_refit_restores_ai_service():
    """Without a refit the whole bank stays guarded after a repack (its
    labels refer to the dead tree). With ``refit_fn`` the monitor resets
    and AI-path service resumes on the rebuilt tree — still exact."""
    pts = synth.tweets_like(3000, seed=5)
    base, extra = pts[:2700], pts[2700:]
    dtree = dt.flatten(RTree(max_entries=32).insert_all(base))
    # selectivity high enough that the refit router still finds
    # high-overlap traffic on the STR-packed post-repack tree
    qs = synth.synth_queries(pts, 5e-4, 200, seed=6)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6,))

    def refit(dtree_new):
        wl_new = labels.make_workload(dtree_new, qs)
        # a *different* grid size than the initial build: the monitor
        # must re-anchor to the refit hybrid's grid, not assume shapes
        h2, r2 = build.fit_airtree(dtree_new, wl_new, kind="knn",
                                   grid_sizes=(4,))
        return h2, r2.cell_fit

    srv = FreshServer(base, hyb, delta_cap=512, max_visited=256,
                      max_results=512, refit_fn=refit)
    srv.insert(extra)
    assert srv.stats().stale_cells > 0
    srv.repack()
    fs = srv.stats()
    assert fs.n_repacks == 1 and fs.stale_cells == 0 and fs.delta_fill == 0
    out = srv.serve(jnp.asarray(wl.queries))
    assert np.asarray(out.used_ai).any(), "refit must restore AI service"
    exp = geo.np_contains_point(
        wl.queries[:, None, :],
        np.concatenate([base, extra]).astype(np.float32)[None, :, :]
    ).sum(axis=1)
    np.testing.assert_array_equal(np.asarray(out.n_results), exp)


def test_mixed_single_segment_still_stages_inserts():
    """A stream that fits in one segment has no interleave point — the
    inserts must still land in the server (staged after the stream), not
    be silently dropped."""
    from repro.core import schedule
    rng = np.random.default_rng(13)
    base, extra, hyb, q = _synth_fresh_world(rng, 120, 40, 32)
    srv = FreshServer(base, hyb, delta_cap=64, max_visited=256,
                      max_results=512)
    mixed = schedule.serve_mixed_workload(srv, q, extra, batch=64,
                                          sort="none", insert_every=8)
    assert mixed.n_segments == 1
    assert mixed.n_inserts == 40 and srv.delta_fill == 40
    # no query of this stream saw them (visibility is per later segment):
    # the stream matches read-only serving of the base tree exactly
    assert not np.asarray(mixed.stats.delta_hits).any()
    np.testing.assert_array_equal(
        np.asarray(mixed.stats.n_results),
        np.asarray(hybrid_query(hyb, jnp.asarray(q), max_visited=256,
                                max_results=512).n_results))


def test_engine_delta_matches_rebuild():
    """The engine's ``_delta_path`` (1×1×1 mesh, kernel + oracle rungs):
    n_results with a populated buffer == rebuild; delta_hits nonzero."""
    from repro.launch import mesh as pmesh
    pts = synth.tweets_like(6000, seed=2)
    base, extra = pts[:5500], pts[5500:]
    dtree = dt.flatten(RTree.str_bulk(base, max_entries=32))
    qs = synth.synth_queries(pts, 2e-4, 300, seed=3)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6,))
    store = deltalib.stage_inserts(
        deltalib.make_delta(1024, base=base.shape[0]), extra)
    _, dtree2, _, _ = deltalib.repack(base, store, max_entries=32)
    # the comparator's bank is stale against the rebuilt tree (leaf ids
    # renumbered) — guard every cell so it answers on the exact R path,
    # exactly what the monitor does to a served repack without a refit
    hyb2 = dataclasses.replace(
        hyb, tree=dtree2,
        ait=dataclasses.replace(hyb.ait,
                                cell_ok=jnp.zeros_like(hyb.ait.cell_ok)))
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    q = jnp.asarray(wl.queries[:64])
    for uk in (False, True):
        step = engine.make_serve_step(mesh, engine.EngineConfig(
            max_visited=256, max_pred=32, use_kernel=uk), kind="knn")
        with pmesh.set_mesh(mesh):
            with_delta = step(hyb, q, store.xy)
            rebuilt = step(hyb2, q)
        np.testing.assert_array_equal(np.asarray(with_delta.n_results),
                                      np.asarray(rebuilt.n_results),
                                      err_msg=f"use_kernel={uk}")
        assert int(np.asarray(with_delta.delta_hits).sum()) > 0


@pytest.mark.slow
def test_distributed_delta_equivalence_subprocess():
    """Engine freshness equivalence on 8 fake devices at a 2×2×2 mesh."""
    script = os.path.join(REPO, "tests", "helpers", "delta_equiv.py")
    out = subprocess.run([sys.executable, script], env=ENV,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "EQUIVALENT" in out.stdout


# ---------------------------------------------------------------------------
# HLO contract: no dense [B, cap] containment mask on the serving path
# ---------------------------------------------------------------------------

def test_delta_probe_never_materializes_mask():
    """On the kernel path the lowered HLO must contain no [B, cap]-shaped
    tensor (cap deliberately not lane-aligned so in-kernel padded tiles
    stay distinguishable); the jnp oracle rung is the positive control."""
    import re
    rng = np.random.default_rng(9)
    B, cap = 256, 600
    q = _rects(rng, B, w=0.1)
    pts = _buffer(rng, cap, 500)

    txt_k = jax.jit(
        lambda qq, pp: ops.delta_probe(qq, pp, k=16, tb=128)
    ).lower(q, pts).as_text()
    txt_o = jax.jit(
        lambda qq, pp: ref.delta_probe(qq, pp, 16)).lower(q, pts).as_text()
    dense = re.compile(r"<256x600x")
    assert not dense.search(txt_k), "kernel path materialized the mask"
    assert dense.search(txt_o), "oracle should materialize the mask"


def test_engine_delta_path_hlo_stays_compact():
    """The engine serve step with a delta buffer (kernel path, topk
    union) lowers without the [B, cap] probe mask AND still without the
    [B, L] score/visited tables — the freshness stage joins the compact
    slot-table contract instead of breaking it."""
    import re
    from repro.launch import mesh as pmesh
    from tests.test_mlp_infer import _synth_hybrid
    rng = np.random.default_rng(10)
    hyb = _synth_hybrid(rng)                  # L = 1000
    cap = 600
    pts = _buffer(rng, cap, 300)
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    B = 256
    lo = rng.uniform(-1, 0.9, (B, 2))
    q = jnp.asarray(np.concatenate([lo, lo + 0.05], 1), jnp.float32)
    step = engine.make_serve_step(mesh, engine.EngineConfig(
        max_visited=64, max_pred=16, use_kernel=True, score_union="topk"),
        kind="mlp")
    with pmesh.set_mesh(mesh):
        txt = jax.jit(step).lower(hyb, q, pts).as_text()
    assert not re.search(r"<256x600x", txt), \
        "delta path materialized the [B, cap] mask"
    assert not re.search(r"<256x100[01]x", txt), \
        "serve step regressed to dense [B, L] tables"


# ---------------------------------------------------------------------------
# the guard: under-prediction blind spot closed, exact-fit unchanged
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def under_trained_world():
    """A deliberately under-trained MLP bank (exact_fit ≪ 1) whose AI
    path silently drops results on some queries: predictions are a strict
    subset of the true leaves with every predicted leaf still yielding
    hits, so no fallback signal fires."""
    pts = synth.tweets_like(4000, seed=0)
    tree = RTree(max_entries=32).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 1e-3, 200, seed=1)
    wl = labels.make_workload(dtree, qs, max_results=2048)
    hyb, rep = build.fit_airtree(dtree, wl, kind="mlp", grid_sizes=(4,),
                                 mlp_hidden=16, mlp_epochs=800)
    return hyb, rep, wl


def test_under_trained_bank_silently_drops_without_guard(
        under_trained_world):
    """Pin the blind spot itself: with the guard off, served results
    disagree with the exact labels on some rows (silent drops reach the
    router-dispatched output); fit < 1 and some cells are flagged."""
    hyb, rep, wl = under_trained_world
    assert rep.exact_fit < 1.0
    assert not rep.cell_fit.all()
    # the public refit-path evaluation reproduces what the build installed
    fit, exact, cell_ok = build.eval_cell_fit(hyb.ait, hyb.tree, wl)
    assert fit == pytest.approx(rep.exact_fit)
    np.testing.assert_array_equal(cell_ok, rep.cell_fit)
    np.testing.assert_array_equal(cell_ok, np.asarray(hyb.ait.cell_ok))
    q = jnp.asarray(wl.queries)
    off = hybrid_query(hyb, q, max_visited=256, max_results=2048,
                       guard=False)
    mism = np.asarray(off.n_results) != wl.n_results
    assert mism.any(), "fixture must exhibit silent drops unguarded"
    # the drops are the blind spot, not truncation or fallbacks
    assert not np.asarray(off.truncated)[mism].any()
    assert np.asarray(off.used_ai)[mism].all()


def test_guard_recovers_dropped_hits(under_trained_world):
    """The fix: guard on (the default) demotes the under-fit cells'
    queries to the exact R path — every previously-dropped hit is
    recovered and the stream matches the labels exactly."""
    hyb, rep, wl = under_trained_world
    q = jnp.asarray(wl.queries)
    on = hybrid_query(hyb, q, max_visited=256, max_results=2048)
    np.testing.assert_array_equal(np.asarray(on.n_results), wl.n_results)
    assert np.asarray(on.guarded).any(), "guard must have fired"


def test_guard_leaves_exact_fit_dispatch_unchanged():
    """An exact-fit bank (memorization-complete kNN, fit 1.0): guard on
    == guard off on every field, and the AI path still answers."""
    pts = synth.tweets_like(3000, seed=5)
    # dynamic (paper-path) build: overlapping leaves give a mixed-α
    # workload, so the router genuinely sends traffic to the AI path
    dtree = dt.flatten(RTree(max_entries=32).insert_all(pts))
    qs = synth.synth_queries(pts, 1e-4, 200, seed=6)
    wl = labels.make_workload(dtree, qs)
    hyb, rep = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6,))
    assert rep.exact_fit == 1.0
    q = jnp.asarray(wl.queries)
    a = hybrid_query(hyb, q)
    b = hybrid_query(hyb, q, guard=False)
    for f in type(a)._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)
    assert np.asarray(a.used_ai).any()


def test_engine_guard_matches_hybrid(under_trained_world):
    """The engine's shard-local guard (psum over expert shards) agrees
    with the single-device hybrid row for row, and EngineConfig.guard
    defaults on."""
    from repro.launch import mesh as pmesh
    hyb, _, wl = under_trained_world
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    q = jnp.asarray(wl.queries[:64])
    ref_res = hybrid_query(hyb, q, max_visited=256)
    assert engine.EngineConfig().guard
    step = engine.make_serve_step(mesh, engine.EngineConfig(
        max_visited=256, max_pred=64), kind="mlp")
    with pmesh.set_mesh(mesh):
        stats = step(hyb, q)
    for f in ("n_results", "used_ai", "guarded", "leaf_accesses"):
        np.testing.assert_array_equal(np.asarray(getattr(stats, f)),
                                      np.asarray(getattr(ref_res, f)),
                                      err_msg=f)
