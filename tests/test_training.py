"""Training substrate tests: optimizer, accumulation, checkpoint round-trip,
elastic resume, compression unbiasedness, fault-tolerance control plane."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from helpers.hypo import given, settings, st

from repro import configs
from repro.training import (checkpoint, compression, fault_tolerance,
                            optimizer as opt, train_loop)


def tiny_cfg():
    import dataclasses
    return dataclasses.replace(
        configs.reduced(configs.get_config("h2o_danube3_4b")),
        n_layers=2, d_ff=64, vocab=128)


def make_batch(cfg, B=4, S=8, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)),
                                  jnp.int32)}


def test_loss_decreases_over_steps():
    cfg = tiny_cfg()
    ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=0, decay_steps=1000,
                           weight_decay=0.0)
    state = train_loop.init_train_state(cfg, jax.random.PRNGKey(0),
                                        dtype=jnp.float32, opt_cfg=ocfg)
    step = jax.jit(train_loop.make_train_step(cfg, opt_cfg=ocfg))
    batch = make_batch(cfg)  # overfit one batch
    losses = []
    for _ in range(30):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[:3] + losses[-3:]


def test_grad_accumulation_matches_full_batch():
    cfg = tiny_cfg()
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=0.0,
                           weight_decay=0.0)
    s0 = train_loop.init_train_state(cfg, jax.random.PRNGKey(1),
                                     dtype=jnp.float32, opt_cfg=ocfg)
    batch = make_batch(cfg, B=8)
    full = jax.jit(train_loop.make_train_step(cfg, opt_cfg=ocfg,
                                              accum_steps=1))
    acc = jax.jit(train_loop.make_train_step(cfg, opt_cfg=ocfg,
                                             accum_steps=4))
    s_full, m_full = full(s0, batch)
    s_acc, m_acc = acc(s0, batch)
    np.testing.assert_allclose(float(m_full["loss"]), float(m_acc["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(s_full.params),
                    jax.tree.leaves(s_acc.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-5)


def test_bf16_optimizer_state_runs():
    cfg = tiny_cfg()
    ocfg = opt.AdamWConfig(lr=1e-3, state_dtype=jnp.bfloat16)
    state = train_loop.init_train_state(cfg, jax.random.PRNGKey(0),
                                        dtype=jnp.float32, opt_cfg=ocfg)
    step = jax.jit(train_loop.make_train_step(cfg, opt_cfg=ocfg))
    state, m = step(state, make_batch(cfg))
    assert np.isfinite(float(m["loss"]))
    assert jax.tree.leaves(state.opt.m)[0].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    state = train_loop.init_train_state(cfg, jax.random.PRNGKey(2),
                                        dtype=jnp.float32)
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 7, state, extra={"data_position": 123})
    template = train_loop.init_train_state(cfg, jax.random.PRNGKey(99),
                                           dtype=jnp.float32)
    restored, manifest = checkpoint.restore(d, template)
    assert manifest["step"] == 7
    assert manifest["extra"]["data_position"] == 123
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prune_and_latest(tmp_path):
    cfg = tiny_cfg()
    state = train_loop.init_train_state(cfg, jax.random.PRNGKey(2),
                                        dtype=jnp.float32)
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        checkpoint.save(d, s, state, keep=2)
    assert checkpoint.latest_step(d) == 5
    kept = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert len(kept) == 2


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    cfg = tiny_cfg()
    state = train_loop.init_train_state(cfg, jax.random.PRNGKey(2),
                                        dtype=jnp.float32)
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, state)
    import dataclasses
    cfg2 = dataclasses.replace(cfg, d_ff=96)
    template = train_loop.init_train_state(cfg2, jax.random.PRNGKey(0),
                                           dtype=jnp.float32)
    with pytest.raises(ValueError):
        checkpoint.restore(d, template)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(1e-6, 1e3))
def test_compression_unbiased_and_bounded(seed, scale):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, scale, (64,)), jnp.float32)
    q, s = compression.encode(g, jax.random.PRNGKey(seed))
    deq = compression.decode(q, s)
    # bounded quantization error: one quantum
    assert float(jnp.abs(deq - g).max()) <= float(s) * 1.001
    # unbiased in expectation over rounding draws
    keys = jax.random.split(jax.random.PRNGKey(seed), 64)
    deqs = jnp.stack([compression.decode(*compression.encode(g, k))
                      for k in keys])
    bias = float(jnp.abs(deqs.mean(0) - g).max())
    assert bias < float(s) * 0.25


def test_straggler_monitor_fake_clock():
    t = [0.0]
    mon = fault_tolerance.StragglerMonitor(threshold=1.5,
                                           clock=lambda: t[0])
    for step in range(10):
        t[0] += 1.0
        for h in ("h0", "h1", "h2", "h3"):
            mon.beat(h, 1.0 if h != "h3" else 2.5)
    assert mon.stragglers() == ["h3"]
    t[0] += 100.0
    mon.beat("h0", 1.0)
    assert set(mon.dead(timeout=50)) == {"h1", "h2", "h3"}


def test_preemption_flag_checkpoint_flow(tmp_path):
    cfg = tiny_cfg()
    state = train_loop.init_train_state(cfg, jax.random.PRNGKey(0),
                                        dtype=jnp.float32)
    step = jax.jit(train_loop.make_train_step(cfg))
    handler = fault_tolerance.PreemptionHandler()
    d = str(tmp_path / "ckpt")
    batch = make_batch(cfg)
    for i in range(5):
        state, _ = step(state, batch)
        if i == 2:
            handler.request()        # simulated SIGTERM
        if handler.preempted():
            checkpoint.save(d, i, state,
                            extra=fault_tolerance.RunState(
                                step=i, data_position=i * 4).to_dict())
            break
    assert checkpoint.latest_step(d) == 2
    restored, manifest = checkpoint.restore(
        d, train_loop.init_train_state(cfg, jax.random.PRNGKey(9),
                                       dtype=jnp.float32))
    rs = fault_tolerance.RunState.from_dict(manifest["extra"])
    assert rs.step == 2 and rs.data_position == 8
