"""Regression tests for trace-replay edge cases (``data.arrivals``).

Empty, single-arrival, and duplicate-stamp traces must round-trip
through save/load/tile/rescale without crashes, NaN inter-arrival gaps,
or overlapping repetitions.
"""
import numpy as np
import pytest

from repro.data import arrivals


def _gaps(a: np.ndarray) -> np.ndarray:
    return np.diff(np.concatenate([[0.0], a]))


def _roundtrip(tmp_path, src, name, **kw):
    path = str(tmp_path / name)
    arrivals.save_trace(path, np.asarray(src, np.float64))
    return arrivals.load_trace(path, **kw)


@pytest.mark.parametrize("ext", ["npy", "txt"])
def test_empty_trace_roundtrips_empty(tmp_path, ext):
    a = _roundtrip(tmp_path, [], f"t.{ext}")
    assert a.shape == (0,) and a.dtype == np.float64
    # rescale on an empty stream is a no-op, not a division by a[-1]
    a = _roundtrip(tmp_path, [], f"t2.{ext}", rate=10.0)
    assert a.shape == (0,)


def test_empty_trace_with_demand_raises(tmp_path):
    with pytest.raises(ValueError, match="empty trace"):
        _roundtrip(tmp_path, [], "t.npy", n=10)


def test_zero_demand_truncates_to_empty(tmp_path):
    for src in ([], [3.0], [1.0, 2.0, 5.0]):
        a = _roundtrip(tmp_path, src, "t.npy", n=0)
        assert a.shape == (0,) and a.dtype == np.float64


def test_single_arrival_tiles_without_nan(tmp_path):
    a = _roundtrip(tmp_path, [7.5], "t.npy", n=6)
    assert a.shape == (6,)
    g = _gaps(a)
    assert np.all(np.isfinite(g)) and np.all(g > 0)


def test_single_arrival_rescale(tmp_path):
    a = _roundtrip(tmp_path, [7.5], "t.npy", n=100, rate=50.0)
    assert a.shape == (100,)
    assert np.all(np.isfinite(a)) and np.all(_gaps(a) > 0)
    assert a[-1] == pytest.approx(100 / 50.0)


def test_duplicate_stamps_tile_strictly_increasing(tmp_path):
    # gap0 == 0: the per-rep shift must floor, not stack reps in place
    a = _roundtrip(tmp_path, [2.0, 2.0, 2.0], "t.npy", n=12)
    assert a.shape == (12,)
    assert np.all(np.isfinite(a))
    assert np.unique(a).size == np.unique(np.round(a, 12)).size
    # repetitions advance: each rep's first stamp is past the previous last
    assert a[-1] > a[2]


def test_trace_rhythm_preserved_on_tile(tmp_path):
    src = np.array([0.0, 1.0, 3.0])
    a = _roundtrip(tmp_path, src, "t.npy", n=6)
    g = _gaps(a)
    # the second repetition repeats the first's internal gaps
    np.testing.assert_allclose(g[4:6], g[1:3])
    assert np.all(g > 0)


def test_rescaled_mean_rate(tmp_path):
    src = np.cumsum(np.full(200, 0.02))
    a = _roundtrip(tmp_path, src, "t.npy", rate=25.0)
    assert a.size / a[-1] == pytest.approx(25.0)


def test_make_arrivals_trace_empty_demand(tmp_path):
    path = str(tmp_path / "t.npy")
    arrivals.save_trace(path, np.zeros((0,), np.float64))
    a = arrivals.make_arrivals("trace", 0, 0.0, trace=path)
    assert a.shape == (0,)
