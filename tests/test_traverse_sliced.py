"""Ancestor-sliced fused traversal: parity, dispatch ladder, and the
at-scale HLO acceptance gate.

The sliced form (``kernels.traverse_fused.traverse_fused_sliced_t`` /
``traverse_compact_sliced_t``) must be **bit-identical** to the jnp oracle
and to the full-VMEM fused form wherever both run — same visited sets,
same compact slot tables, same counts. The dispatch ladder in
``kernels.ops`` must route over-budget trees to it (per-level kernel loop
only as last resort), and at a tree size past ``VMEM_BUDGET`` the lowered
serving step must carry neither a dense ``[B, L]`` mask nor per-level
frontier round-trips — asserted on HLO text with the per-level fallback as
the positive control.
"""
import re

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from tests.helpers.hypo import given, settings, st
from repro.core.device_tree import DeviceTree, Level, build_ancestor_table
from repro.core import traversal
from repro.core.traversal import compact_mask_counted
from repro.data.synth_tree import synth_levels
from repro.kernels import ops, ref
from repro.kernels import traverse_fused as tf


def _tree(L, fanout, rng, slice_tl=None):
    mbrs, parents = synth_levels(L, fanout, rng, str_pack=True)
    lm = [jnp.asarray(m) for m in mbrs]
    lp = [jnp.asarray(p) for p in parents]
    sl = build_ancestor_table(parents, tl=slice_tl)
    return lm, lp, sl


def _device_tree(lm, lp, sl):
    L = lm[-1].shape[0]
    return DeviceTree(
        levels=tuple(Level(mbrs=m, parent=p) for m, p in zip(lm, lp)),
        leaf_entries=jnp.full((L, 8, 2), jnp.inf, jnp.float32),
        leaf_entry_ids=jnp.full((L, 8), -1, jnp.int32),
        leaf_counts=jnp.zeros((L,), jnp.int32),
        n_points=0, max_entries=8, aslices=sl)


def _queries(B, rng, dead_rows=True):
    lo = rng.uniform(-1, 1, (B, 2))
    w = rng.uniform(0, 0.08, (B, 2))
    q = np.concatenate([lo, lo + w], 1).astype(np.float32)
    if dead_rows and B >= 4:
        q[1] = [50.0, 50.0, 51.0, 51.0]        # misses everything
        q[3] = [-2.0, -2.0, 2.0, 2.0]          # hits everything
    return jnp.asarray(q)


@pytest.fixture
def budget_guard():
    """Restore the VMEM budget after tests that force ladder rungs."""
    orig = tf.VMEM_BUDGET
    yield
    tf.VMEM_BUDGET = orig


# ---------------------------------------------------------------------------
# Table + oracle semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("L,fanout,tl", [(700, 4, 128), (2048, 8, 256),
                                         (4096, 4, 512)])
def test_sliced_oracle_matches_full(L, fanout, tl):
    """The windowed oracle under a built table equals the full walk —
    i.e. every tile's true ancestors land inside its windows."""
    rng = np.random.default_rng(L)
    lm, lp, sl = _tree(L, fanout, rng, slice_tl=tl)
    assert sl is not None and sl.tl == tl
    q = _queries(32, rng)
    full = np.asarray(ref.traverse_fused(q, lm, lp))
    sliced = np.asarray(ref.traverse_fused_sliced(
        q, lm, lp, sl.starts, sl.widths, sl.tl))[:, :L]
    np.testing.assert_array_equal(full, sliced)


def test_table_shapes_and_degenerates():
    rng = np.random.default_rng(0)
    _, lp, sl = _tree(1000, 4, rng, slice_tl=128)
    assert sl.starts.shape == (len(lp) - 1, -(-1000 // 128))
    assert len(sl.widths) == len(lp) - 1
    assert all(w >= tf.LANE and w % tf.LANE == 0 for w in sl.widths)
    # root == leaf: nothing to slice
    assert build_ancestor_table([np.zeros(5, np.int32)]) is None


# ---------------------------------------------------------------------------
# Kernel parity (both forms) against oracle and full-VMEM form
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tpu_form", [False, True])
@pytest.mark.parametrize("L,fanout,tl", [(1000, 4, 128), (4096, 4, 512)])
def test_sliced_kernel_bit_identical(tpu_form, L, fanout, tl):
    rng = np.random.default_rng(7)
    lm, lp, sl = _tree(L, fanout, rng, slice_tl=tl)
    B, k = 24, 32
    q = _queries(B, rng)
    oracle = np.asarray(ref.traverse_fused(q, lm, lp))

    qp, imt, ipar, lmt, lpt = ops._sliced_operands(q, lm, lp, sl, 8)
    out = tf.traverse_fused_sliced_t(
        sl.starts, qp.T, imt, ipar, lmt, lpt, widths=sl.widths, tb=8,
        tl=sl.tl, interpret=True, tpu_form=tpu_form)
    np.testing.assert_array_equal(np.asarray(out)[:B, :L], oracle)

    idx, cnt = tf.traverse_compact_sliced_t(
        sl.starts, qp.T, imt, ipar, lmt, lpt, k=k, widths=sl.widths,
        tb=8, tl=sl.tl, interpret=True, tpu_form=tpu_form)
    ridx, rval, rcnt = compact_mask_counted(jnp.asarray(oracle), k)
    np.testing.assert_array_equal(np.asarray(cnt)[:B, 0], np.asarray(rcnt))
    got = np.where(np.asarray(rval), np.asarray(idx)[:B, :k], 0)
    np.testing.assert_array_equal(got, np.asarray(jnp.where(rval, ridx, 0)))


# ---------------------------------------------------------------------------
# Dispatch ladder
# ---------------------------------------------------------------------------


def _force_budget(between_sliced_and_full, lm, sl, tb=1024):
    """A budget that rejects the full form but admits the sliced one."""
    widths = [int(m.shape[0]) for m in lm[:-1]]
    padded = [n + (-n) % tf.LANE for n in widths]
    full = tf.vmem_estimate(padded, tb, lm[-1].shape[0])
    sliced = tf.vmem_estimate_sliced(sl.widths, tb, sl.tl, tpu_form=False)
    assert sliced < full
    return (full + sliced) // 2 if between_sliced_and_full else 1


def test_ladder_routes_over_budget_to_sliced(budget_guard, monkeypatch):
    rng = np.random.default_rng(3)
    lm, lp, sl = _tree(4096, 4, rng, slice_tl=512)
    q = _queries(16, rng)
    oracle = np.asarray(ref.traverse_fused(q, lm, lp))

    calls = []
    real = tf.traverse_fused_sliced_t

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(tf, "traverse_fused_sliced_t", spy)
    tf.VMEM_BUDGET = _force_budget(True, lm, sl)
    got = np.asarray(ops.traverse_fused(q, lm, lp, slices=sl))
    np.testing.assert_array_equal(got, oracle)
    assert calls, "over-budget dispatch did not take the sliced kernel"


def test_ladder_compact_sliced_and_table_autobuild(budget_guard):
    """Compact wrapper takes the sliced rung; with no table passed, one is
    built on the fly from the (concrete) parent arrays."""
    rng = np.random.default_rng(4)
    lm, lp, sl = _tree(4096, 4, rng, slice_tl=512)
    q = _queries(16, rng)
    k = 32
    ridx, rval, rcnt = compact_mask_counted(
        jnp.asarray(ref.traverse_fused(q, lm, lp)), k)
    tf.VMEM_BUDGET = _force_budget(True, lm, sl)
    for slices in (sl, None):                  # explicit table / autobuild
        gi, gv, gc = ops.traverse_compact(q, lm, lp, k, slices=slices)
        np.testing.assert_array_equal(np.asarray(gc), np.asarray(rcnt))
        np.testing.assert_array_equal(np.asarray(gv), np.asarray(rval))
        np.testing.assert_array_equal(
            np.asarray(gi), np.asarray(jnp.where(rval, ridx, 0)))


def test_ladder_last_resort_per_level(budget_guard):
    """Budget below even the sliced working set → per-level kernel loop,
    still bit-identical."""
    rng = np.random.default_rng(5)
    lm, lp, sl = _tree(2048, 4, rng, slice_tl=256)
    q = _queries(16, rng)
    oracle = np.asarray(ref.traverse_fused(q, lm, lp))
    tf.VMEM_BUDGET = 1
    got = np.asarray(ops.traverse_fused(q, lm, lp, slices=sl))
    np.testing.assert_array_equal(got, oracle)


def test_slices_usable_rejects_mismatched_tables():
    rng = np.random.default_rng(6)
    lm, lp, sl = _tree(1024, 4, rng, slice_tl=128)
    n_levels, L = len(lm), 1024
    assert ops._slices_usable(sl, n_levels, L)
    assert not ops._slices_usable(None, n_levels, L)
    assert not ops._slices_usable(sl, n_levels - 1, L)   # wrong height
    assert not ops._slices_usable(sl, n_levels, 2048)    # wrong leaf count


# ---------------------------------------------------------------------------
# Satellite: REPRO_VMEM_BUDGET env override
# ---------------------------------------------------------------------------


def test_vmem_budget_env_override():
    assert tf._read_vmem_budget({}) == tf.DEF_VMEM_BUDGET
    assert tf._read_vmem_budget({tf.VMEM_BUDGET_ENV: "123456"}) == 123456
    # invalid / non-positive values must not disable every kernel
    assert tf._read_vmem_budget(
        {tf.VMEM_BUDGET_ENV: "8MB"}) == tf.DEF_VMEM_BUDGET
    assert tf._read_vmem_budget(
        {tf.VMEM_BUDGET_ENV: "-4"}) == tf.DEF_VMEM_BUDGET
    assert tf._read_vmem_budget(
        {tf.VMEM_BUDGET_ENV: "0"}) == tf.DEF_VMEM_BUDGET


# ---------------------------------------------------------------------------
# Satellite: hypothesis property — sliced ≡ oracle ≡ full everywhere,
# including trees straddling the budget and degenerate heights
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=5),
       st.integers(min_value=0, max_value=2),
       st.integers(min_value=0, max_value=2),
       st.integers(min_value=0, max_value=10_000))
def test_property_sliced_parity(l_idx, f_idx, t_idx, seed):
    L = (96, 300, 513, 1024, 2048, 4096)[l_idx]
    fanout = (3, 4, 8)[f_idx]
    tl = (128, 256, 512)[t_idx]
    rng = np.random.default_rng(seed)
    lm, lp, sl = _tree(L, fanout, rng, slice_tl=tl)
    B, k = 16, 16
    q = _queries(B, rng)
    oracle = np.asarray(ref.traverse_fused(q, lm, lp))
    ridx, rval, rcnt = compact_mask_counted(jnp.asarray(oracle), k)

    # sliced kernel (interp form exercises the value-level window walk;
    # tpu form the one-hot MXU walk) vs oracle
    qp, imt, ipar, lmt, lpt = ops._sliced_operands(q, lm, lp, sl, 8)
    for tpu_form in (False, True):
        out = tf.traverse_fused_sliced_t(
            sl.starts, qp.T, imt, ipar, lmt, lpt, widths=sl.widths, tb=8,
            tl=sl.tl, interpret=True, tpu_form=tpu_form)
        np.testing.assert_array_equal(np.asarray(out)[:B, :L], oracle)
    idx, cnt = tf.traverse_compact_sliced_t(
        sl.starts, qp.T, imt, ipar, lmt, lpt, k=k, widths=sl.widths,
        tb=8, tl=sl.tl, interpret=True, tpu_form=False)
    np.testing.assert_array_equal(np.asarray(cnt)[:B, 0], np.asarray(rcnt))
    np.testing.assert_array_equal(
        np.where(np.asarray(rval), np.asarray(idx)[:B, :k], 0),
        np.asarray(jnp.where(rval, ridx, 0)))

    # full-VMEM fused form (the ladder's in-budget rung) on the same tree
    full = np.asarray(ops.traverse_fused(q, lm, lp))
    np.testing.assert_array_equal(full, oracle)


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=64),
       st.integers(min_value=0, max_value=10_000))
def test_property_degenerate_heights(L, seed):
    """root==leaf (no table) and single-internal-level trees survive the
    ladder under a forced-tiny budget."""
    rng = np.random.default_rng(seed)
    lo = rng.uniform(-1, 1, (L, 2))
    w = rng.uniform(0.05, 0.3, (L, 2))
    leaf = jnp.asarray(np.concatenate([lo, lo + w], 1).astype(np.float32))
    q = _queries(8, rng, dead_rows=False)

    orig = tf.VMEM_BUDGET
    try:
        tf.VMEM_BUDGET = 1
        # root == leaf: single level, table is None, ladder takes the
        # plain intersection rung
        got = np.asarray(ops.traverse_fused(
            q, [leaf], [jnp.zeros((L,), jnp.int32)]))
        np.testing.assert_array_equal(
            got, np.asarray(ref.mbr_intersect(q, leaf)))

        # single internal level (root + leaves)
        root = jnp.asarray(np.concatenate([
            np.min(np.asarray(leaf)[:, :2], 0),
            np.max(np.asarray(leaf)[:, 2:], 0)])[None].astype(np.float32))
        lm = [root, leaf]
        lp = [jnp.zeros((1,), jnp.int32), jnp.zeros((L,), jnp.int32)]
        sl = build_ancestor_table([np.asarray(p) for p in lp], tl=128)
        got = np.asarray(ops.traverse_fused(q, lm, lp, slices=sl))
        np.testing.assert_array_equal(
            got, np.asarray(ref.traverse_fused(q, lm, lp)))
    finally:
        tf.VMEM_BUDGET = orig


# ---------------------------------------------------------------------------
# Acceptance: at 64k leaves the serving step's HLO has no dense [B, L]
# mask and no per-level frontier round-trip; per-level fallback is the
# positive control; results bit-identical to the oracle at that shape.
# ---------------------------------------------------------------------------

# fanout 4 → widest internal level 16384: the full-VMEM form's frontier
# alone (256×16384×4B = 16 MB) is past the default budget, so the ladder
# must pick the sliced kernel with no forcing
_SCALE_L, _SCALE_FANOUT, _SCALE_TL = 65536, 4, 2048


def _scale_tree(slice_tl=_SCALE_TL):
    rng = np.random.default_rng(11)
    lm, lp, sl = _tree(_SCALE_L, _SCALE_FANOUT, rng, slice_tl=slice_tl)
    return _device_tree(lm, lp, sl), rng


def _lower_compact(tree, B, k=64):
    fn = jax.jit(lambda t, q: traversal.visited_leaves_compact(
        t, q, k, use_kernel=True))
    q = jnp.zeros((B, 4), jnp.float32)
    return fn.lower(tree, q).as_text()


def test_hlo_no_dense_mask_at_scale():
    tree, _ = _scale_tree()
    B = 256
    widths = [lv.mbrs.shape[0] + (-lv.mbrs.shape[0]) % tf.LANE
              for lv in tree.levels[:-1]]
    # this shape is past the *default* budget — no budget forcing here
    assert tf.vmem_estimate(widths, B, 512) > tf.VMEM_BUDGET

    hlo = _lower_compact(tree, B)
    # StableHLO spells shapes tensor<256x65536xi1>
    dense = re.compile(rf"<{B}x{_SCALE_L}x")
    frontier = re.compile(rf"<{B}x16384x")      # [B, N_l] at the widest
    assert not dense.search(hlo), "dense [B, L] mask present at scale"
    assert not frontier.search(hlo), "per-level frontier present at scale"

    # positive control: drop the table and force the per-level fallback
    # (under jit the parents are tracers, so no on-the-fly table either)
    import dataclasses
    control = dataclasses.replace(tree, aslices=None)
    hlo_pl = _lower_compact(control, B)
    assert dense.search(hlo_pl), "control lost its dense mask"
    assert frontier.search(hlo_pl), "control lost its frontier"


def test_scale_bit_identical_to_oracle():
    tree, rng = _scale_tree()
    B, k = 32, 64
    q = _queries(B, rng)
    lm = [lv.mbrs for lv in tree.levels]
    lp = [lv.parent for lv in tree.levels]
    oracle = np.asarray(ref.traverse_fused(q, lm, lp))
    ridx, rval, rcnt = compact_mask_counted(jnp.asarray(oracle), k)
    cv = traversal.visited_leaves_compact(tree, q, k, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(cv.n_visited),
                                  np.asarray(rcnt))
    np.testing.assert_array_equal(np.asarray(cv.valid), np.asarray(rval))
    np.testing.assert_array_equal(np.asarray(cv.leaf_idx),
                                  np.asarray(jnp.where(rval, ridx, 0)))


# ---------------------------------------------------------------------------
# Engine: sharding pad re-anchors (or drops) the table
# ---------------------------------------------------------------------------


def test_pad_rebuild_keeps_windows_tight():
    """Padding the leaf axis (engine sharding) re-derives a table whose
    real-lane windows still satisfy the oracle equality; the pad lanes'
    repeated last parent keeps the final tile's window from stretching."""
    rng = np.random.default_rng(9)
    mbrs, parents = synth_levels(1000, 4, rng, str_pack=True)
    # simulate pad_tree_for_sharding's leaf padding to 1024
    pad = 24
    never = np.array([np.inf, np.inf, -np.inf, -np.inf], np.float32)
    mbrs = mbrs[:-1] + [np.concatenate(
        [mbrs[-1], np.tile(never[None], (pad, 1))]).astype(np.float32)]
    parents = parents[:-1] + [np.concatenate(
        [parents[-1], np.full((pad,), parents[-1][-1], np.int32)])]
    sl = build_ancestor_table(parents, tl=128)
    assert sl.starts.shape[1] == 1024 // 128
    lm = [jnp.asarray(m) for m in mbrs]
    lp = [jnp.asarray(p) for p in parents]
    q = _queries(16, rng)
    np.testing.assert_array_equal(
        np.asarray(ref.traverse_fused(q, lm, lp)),
        np.asarray(ref.traverse_fused_sliced(
            q, lm, lp, sl.starts, sl.widths, sl.tl)))
