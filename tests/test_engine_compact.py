"""Serving-path edge cases exposed by the compacted query pipeline.

Covers the single-level (root == leaf) traversal regression — the former
``make_serve_step``-local visited loop unconditionally applied the leaf
``parent`` gather, self-gathering the root mask's column 0 across the row;
the serve step now routes through ``traversal.visited_leaves_compact`` /
``visited_leaf_mask``, which these tests pin on the degenerate shape — and
the engine R path's fused traverse+compact adoption (``use_kernel=True``
must be bit-identical to the mask-based path, ServeStats field for field).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import engine, geometry as geo, traversal
from repro.core.device_tree import DeviceTree, Level
from repro.kernels import ops


def _single_level_tree(L=6, seed=5):
    """A degenerate tree whose only level is the leaf level (root == leaf),
    the shape a 1-deep build or a sharding-padded leaf row produces."""
    rng = np.random.default_rng(seed)
    lo = rng.uniform(-1, 1, (L, 2))
    w = rng.uniform(0.1, 0.5, (L, 2))
    mbrs = jnp.asarray(np.concatenate([lo, lo + w], 1).astype(np.float32))
    tree = DeviceTree(
        levels=(Level(mbrs=mbrs, parent=jnp.zeros((L,), jnp.int32)),),
        leaf_entries=jnp.full((L, 8, 2), jnp.inf, jnp.float32),
        leaf_entry_ids=jnp.full((L, 8), -1, jnp.int32),
        leaf_counts=jnp.zeros((L,), jnp.int32),
        n_points=0, max_entries=8)
    # one query per leaf, slightly inflated so query i covers leaf i (and
    # possibly neighbours — the point is rows differ from column 0)
    q = jnp.asarray(np.concatenate([lo - 0.01, lo + w + 0.01], 1)
                    .astype(np.float32))
    return tree, q, mbrs


@pytest.mark.parametrize("use_kernel", [False, True])
def test_single_level_tree_visited_mask(use_kernel):
    """Regression: a 1-level tree's visited mask is the plain intersection;
    the old engine-inline loop returned column 0 broadcast across the
    row. The serve step's traversal entry point must handle the shape."""
    tree, q, mbrs = _single_level_tree()
    exp = np.asarray(geo.jnp_cross_intersects(q, mbrs))
    got = np.asarray(
        traversal.visited_leaf_mask(tree, q, use_kernel=use_kernel))
    np.testing.assert_array_equal(got, exp)
    # the bug was invisible only when every row matched column 0 — make
    # sure this fixture actually discriminates
    buggy = exp[:, [0] * exp.shape[1]] & exp
    assert not np.array_equal(buggy, exp), "fixture too weak to catch bug"


def test_single_level_tree_per_level_and_compact():
    """visited_leaf_mask_per_level and the compacted variants agree on the
    degenerate single-level shape (audit from the same regression)."""
    tree, q, mbrs = _single_level_tree()
    exp = np.asarray(geo.jnp_cross_intersects(q, mbrs))
    np.testing.assert_array_equal(
        np.asarray(traversal.visited_leaf_mask_per_level(tree, q)), exp)
    np.testing.assert_array_equal(
        np.asarray(ops.traverse_fused(
            q, [lv.mbrs for lv in tree.levels],
            [lv.parent for lv in tree.levels])), exp)
    exp_i, exp_v, exp_c = traversal.compact_mask_counted(jnp.asarray(exp), 4)
    for use_kernel in (False, True):
        cv = traversal.visited_leaves_compact(tree, q, 4,
                                              use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(cv.leaf_idx),
                                      np.asarray(exp_i))
        np.testing.assert_array_equal(np.asarray(cv.valid),
                                      np.asarray(exp_v))
        np.testing.assert_array_equal(np.asarray(cv.n_visited),
                                      np.asarray(exp_c))


def test_engine_r_path_kernel_bit_identical():
    """make_serve_step with use_kernel=True (fused traverse+compact +
    scalar-prefetch refine) == use_kernel=False, every ServeStats field.

    Deliberately NOT marked slow: this is the only in-process coverage of
    the rewired shard_map serve path, so it must run in the per-PR fast
    selection (the 8-fake-device subprocess equivalence stays nightly).
    """
    from repro.core import build, device_tree as dt, labels
    from repro.core.rtree import RTree
    from repro.data import synth
    from repro.launch import mesh as pmesh

    pts = synth.tweets_like(3000, seed=0)
    tree = RTree(max_entries=32).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 1e-4, 200, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6,))
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    q = jnp.asarray(wl.queries[:64])
    stats = {}
    for uk in (False, True):
        step = engine.make_serve_step(mesh, engine.EngineConfig(
            max_visited=64, max_pred=32, use_kernel=uk), kind="knn")
        with pmesh.set_mesh(mesh):
            stats[uk] = step(hyb, q)
    for f in stats[False]._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(stats[False], f)),
            np.asarray(getattr(stats[True], f)), err_msg=f)
