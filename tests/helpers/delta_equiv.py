"""Subprocess helper: distributed engine freshness equivalence.

Run with 8 fake host devices at a 2×2×2 mesh; prints EQUIVALENT when
serving with a populated delta buffer matches a from-scratch ``str_bulk``
rebuild over the same points (result counts — the structural stats
legitimately differ between the two trees), and the post-repack store
serves bit-identically to the rebuild on every ServeStats field.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses  # noqa: E402
import sys  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import build, device_tree as dt, engine, labels  # noqa: E402
from repro.core import delta as deltalib  # noqa: E402
from repro.core.rtree import RTree  # noqa: E402
from repro.data import synth  # noqa: E402
from repro.launch import mesh as pmesh  # noqa: E402


def main() -> int:
    pts = synth.tweets_like(22_000, seed=0)
    base, extra = pts[:20_000], pts[20_000:]
    dtree = dt.flatten(RTree.str_bulk(base, max_entries=32))
    qs = synth.synth_queries(pts, 1e-4, 800, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(8,))

    store = deltalib.stage_inserts(
        deltalib.make_delta(4096, base=base.shape[0]), extra)
    tree2, dtree2, allp, empty = deltalib.repack(base, store,
                                                 max_entries=32)
    # guard every cell on the rebuilt side: the bank's labels refer to
    # the old tree (the monitor would do the same to a served repack)
    hyb2 = dataclasses.replace(
        hyb, tree=dtree2,
        ait=dataclasses.replace(hyb.ait,
                                cell_ok=jnp.zeros_like(hyb.ait.cell_ok)))

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    hyb_p = engine.pad_tree_for_sharding(hyb, 2)
    hyb2_p = engine.pad_tree_for_sharding(hyb2, 2)
    q = jnp.asarray(wl.queries[:64])
    cfg = engine.EngineConfig(max_visited=256, max_pred=32)
    step = engine.make_serve_step(mesh, cfg, kind="knn")
    ok = True
    with pmesh.set_mesh(mesh):
        with_delta = step(hyb_p, q, store.xy)
        rebuilt = step(hyb2_p, q)
        repacked = step(hyb2_p, q, empty.xy)
    if not np.array_equal(np.asarray(with_delta.n_results),
                          np.asarray(rebuilt.n_results)):
        print("MISMATCH: delta-serving n_results != rebuild")
        ok = False
    if not int(np.asarray(with_delta.delta_hits).sum()) > 0:
        print("DEGENERATE: no delta hits — fixture exercises nothing")
        ok = False
    # post-repack (empty buffer) must be bit-identical to the rebuild on
    # every field: the swapped tree IS a fresh bulk load
    for f in type(rebuilt)._fields:
        if not np.array_equal(np.asarray(getattr(repacked, f)),
                              np.asarray(getattr(rebuilt, f))):
            print(f"MISMATCH: repack vs rebuild field {f}")
            ok = False
    if ok:
        print("EQUIVALENT")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
