"""Optional-import shim for ``hypothesis``.

When hypothesis is installed (see requirements-dev.txt), this module
re-exports the real ``given``/``settings``/``strategies`` unchanged. When it
is not, property tests degrade to **fixed-seed example tests**: each
``@given`` decorator draws a deterministic batch of examples from the
declared strategies with a seeded numpy generator and runs the test body on
each. Coverage is thinner than real shrinking/property search, but the test
modules stay collectable and the example sweep still exercises the code.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by whichever env runs
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 8

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            # NB: no functools.wraps — copying __wrapped__ would make pytest
            # introspect the original argument list and demand fixtures.
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(_FALLBACK_EXAMPLES):
                    fn(*(s.example(rng) for s in strategies))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(**_kwargs):
        def deco(fn):
            return fn
        return deco
