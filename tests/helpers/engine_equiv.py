"""Subprocess helper: distributed engine vs single-device hybrid equivalence.

Run with 8 fake host devices; prints EQUIVALENT on success.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys  # noqa: E402

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import build, device_tree as dt, engine, labels  # noqa: E402
from repro.core.hybrid import hybrid_query  # noqa: E402
from repro.core.rtree import RTree  # noqa: E402
from repro.data import synth  # noqa: E402
from repro.launch import mesh as pmesh  # noqa: E402


def main() -> int:
    pts = synth.tweets_like(25_000, seed=0)
    tree = RTree(max_entries=32).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 1e-4, 1000, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(8,))

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    hyb_p = engine.pad_tree_for_sharding(hyb, 2)
    B = 64
    q = jnp.asarray(wl.queries[:B])
    ref = hybrid_query(hyb, q, max_visited=128)
    ok = True
    for union in ("pmax", "topk"):
        step = engine.make_serve_step(mesh, engine.EngineConfig(
            max_visited=64, max_pred=32, score_union=union), kind="knn")
        with pmesh.set_mesh(mesh):
            stats = step(hyb_p, q)
        checks = {
            "n_results": np.array_equal(np.asarray(stats.n_results),
                                        np.asarray(ref.n_results)),
            "used_ai": np.array_equal(np.asarray(stats.used_ai),
                                      np.asarray(ref.used_ai)),
            "leaf_accesses": np.array_equal(
                np.asarray(stats.leaf_accesses),
                np.asarray(ref.leaf_accesses)),
        }
        if not all(checks.values()):
            print(f"MISMATCH ({union}):", checks)
            ok = False
    if ok:
        print("EQUIVALENT")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
