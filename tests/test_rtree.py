"""R-tree substrate tests: invariants, host/device equivalence, α."""
import numpy as np
import pytest
import jax.numpy as jnp
from helpers.hypo import given, settings, st

from repro.core.rtree import RTree
from repro.core import device_tree as dt, traversal
from repro.core import geometry as geo


def brute_force(points, rect):
    m = geo.np_contains_point(rect, points)
    return np.flatnonzero(m)


def mk_queries(rng, n, scale=1.0):
    lo = rng.uniform(-scale, scale, size=(n, 2))
    w = rng.uniform(0, 0.5 * scale, size=(n, 2))
    return np.concatenate([lo, lo + w], axis=1).astype(np.float32)


@pytest.fixture(scope="module")
def small_tree():
    rng = np.random.default_rng(7)
    pts = rng.normal(size=(5000, 2))
    tree = RTree(max_entries=16).insert_all(pts)
    return tree, dt.flatten(tree), pts


def test_invariants_dynamic(small_tree):
    tree, _, _ = small_tree
    tree.check_invariants()


def test_invariants_str():
    rng = np.random.default_rng(8)
    pts = rng.normal(size=(3000, 2))
    tree = RTree.str_bulk(pts, max_entries=16)
    # STR trees respect max fill and MBR tightness (min fill can differ in
    # the last group of a slice, so check MBRs + coverage only).
    dtree = dt.flatten(tree)
    q = mk_queries(rng, 50, 2.0)
    res = traversal.range_query(dtree, jnp.asarray(q), max_visited=512,
                                max_results=4096)
    for i in range(50):
        exp = brute_force(pts, q[i].astype(np.float64))
        got = sorted(x for x in np.asarray(res.result_ids[i]).tolist()
                     if x >= 0)
        assert got == sorted(exp.tolist())


def test_query_matches_brute_force(small_tree):
    tree, dtree, pts = small_tree
    rng = np.random.default_rng(9)
    q = mk_queries(rng, 100, 2.0)
    res = traversal.range_query(dtree, jnp.asarray(q), max_visited=512,
                                max_results=4096)
    for i in range(100):
        exp = brute_force(pts, q[i].astype(np.float64))
        got = sorted(x for x in np.asarray(res.result_ids[i]).tolist()
                     if x >= 0)
        assert got == sorted(exp.tolist()), i


def test_device_visited_equals_host(small_tree):
    tree, dtree, _ = small_tree
    rng = np.random.default_rng(10)
    q = mk_queries(rng, 40, 2.0)
    res = traversal.range_query(dtree, jnp.asarray(q), max_visited=512,
                                max_results=4096)
    leaf_map = dt.dfs_leaf_index(tree)
    for i in range(40):
        vh, th, _ = tree.query(q[i].astype(np.float64))
        assert sorted(leaf_map[n] for n in vh) == sorted(
            np.flatnonzero(np.asarray(res.visited[i])).tolist())
        assert sorted(leaf_map[n] for n in th) == sorted(
            np.flatnonzero(np.asarray(res.true_leaves[i])).tolist())


def test_alpha_range_and_definition(small_tree):
    _, dtree, _ = small_tree
    rng = np.random.default_rng(11)
    q = mk_queries(rng, 64, 2.0)
    res = traversal.range_query(dtree, jnp.asarray(q), max_visited=512,
                                max_results=4096)
    a = np.asarray(traversal.alpha(res.n_true, res.n_visited))
    assert ((a >= 0) & (a <= 1)).all()
    nv = np.asarray(res.n_visited)
    nt = np.asarray(res.n_true)
    np.testing.assert_allclose(a[nv > 0], (nt / np.maximum(nv, 1))[nv > 0])
    assert (nt <= nv).all()  # true leaves are a subset of visited


def test_dfs_leaf_ids_consecutive_siblings(small_tree):
    tree, _, _ = small_tree
    order = tree.leaves_dfs()
    pos = {n: i for i, n in enumerate(order)}
    # siblings (same parent) occupy a contiguous ID range
    for node in range(tree.n_nodes):
        if not tree.is_leaf[node]:
            kid_leaves = [c for c in tree.children[node] if tree.is_leaf[c]]
            if kid_leaves:
                ids = sorted(pos[c] for c in kid_leaves)
                assert ids == list(range(ids[0], ids[0] + len(ids)))


@settings(max_examples=10, deadline=None)
@given(st.integers(50, 400), st.integers(4, 24), st.integers(0, 2**31 - 1))
def test_property_build_and_query(n, M, seed):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-1, 1, size=(n, 2))
    tree = RTree(max_entries=M).insert_all(pts)
    tree.check_invariants()
    dtree = dt.flatten(tree)
    q = mk_queries(rng, 10)
    res = traversal.range_query(dtree, jnp.asarray(q), max_visited=512,
                                max_results=1024)
    for i in range(10):
        exp = brute_force(pts, q[i].astype(np.float64))
        got = sorted(x for x in np.asarray(res.result_ids[i]).tolist()
                     if x >= 0)
        assert got == sorted(exp.tolist())


def test_insert_after_bulk_query_still_exact():
    rng = np.random.default_rng(13)
    pts1 = rng.uniform(-1, 1, size=(500, 2))
    pts2 = rng.uniform(-1, 1, size=(300, 2))
    tree = RTree(max_entries=8).insert_all(pts1).insert_all(pts2)
    tree.check_invariants()
    all_pts = np.concatenate([pts1, pts2])
    dtree = dt.flatten(tree)
    q = mk_queries(rng, 20)
    res = traversal.range_query(dtree, jnp.asarray(q), max_visited=512,
                                max_results=1024)
    for i in range(20):
        exp = brute_force(all_pts, q[i].astype(np.float64))
        got = sorted(x for x in np.asarray(res.result_ids[i]).tolist()
                     if x >= 0)
        assert got == sorted(exp.tolist())
