"""Spatial batch scheduler: permutation identity + two-tier re-serve.

The scheduler's whole contract is that it is *invisible* in the results:
key-sorted serving must be a bit-identical permutation of unsorted serving
(per-query results and counts), including ragged tails and the degenerate
root == leaf tree, and the wide-tier re-serve must clear ``r_truncated``
without touching non-overflow rows.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import engine, schedule, traversal
from repro.core.device_tree import DeviceTree, Level
from repro.kernels import ops, ref
from tests.helpers.hypo import given, settings, st


def _queries(n, seed=0, big_frac=0.0, span=2.0):
    rng = np.random.default_rng(seed)
    lo = rng.uniform(-1, 1, (n, 2))
    w = rng.uniform(0, 0.1, (n, 2))
    big = rng.uniform(size=n) < big_frac
    w[big] = rng.uniform(0.5, span, (int(big.sum()), 2))
    return np.concatenate([lo, lo + w], 1).astype(np.float32)


def _tree(L=64, fanout=4, seed=0):
    from repro.data.synth_tree import synth_levels
    rng = np.random.default_rng(seed)
    mbrs, parents = synth_levels(L, fanout, rng, str_pack=True)
    entries = jnp.asarray(rng.uniform(-1, 1, (L, 8, 2)), jnp.float32)
    return DeviceTree(
        levels=tuple(Level(mbrs=jnp.asarray(m), parent=jnp.asarray(p))
                     for m, p in zip(mbrs, parents)),
        leaf_entries=entries,
        leaf_entry_ids=jnp.arange(L * 8, dtype=jnp.int32).reshape(L, 8),
        leaf_counts=jnp.full((L,), 8, jnp.int32),
        n_points=L * 8, max_entries=fanout)


def _single_level_tree(L=6, seed=5):
    rng = np.random.default_rng(seed)
    lo = rng.uniform(-1, 1, (L, 2))
    w = rng.uniform(0.1, 0.5, (L, 2))
    mbrs = jnp.asarray(np.concatenate([lo, lo + w], 1).astype(np.float32))
    return DeviceTree(
        levels=(Level(mbrs=mbrs, parent=jnp.zeros((L,), jnp.int32)),),
        leaf_entries=jnp.asarray(
            rng.uniform(-1, 1, (L, 8, 2)), jnp.float32),
        leaf_entry_ids=jnp.arange(L * 8, dtype=jnp.int32).reshape(L, 8),
        leaf_counts=jnp.full((L,), 8, jnp.int32),
        n_points=L * 8, max_entries=8)


# ---------------------------------------------------------------------------
# spatial_key kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("curve", ["hilbert", "morton"])
@pytest.mark.parametrize("n", [1, 7, 128, 333])
def test_spatial_key_kernel_matches_ref(curve, n):
    """ops.spatial_key (padded kernel dispatch) == jnp reference."""
    q = jnp.asarray(_queries(n, seed=3))
    bbox = jnp.asarray(schedule.workload_bbox(np.asarray(q)))
    got = np.asarray(ops.spatial_key(q, bbox=bbox, curve=curve))
    c = (q[:, :2] + q[:, 2:]) / 2
    span = jnp.maximum(bbox[2:] - bbox[:2], 1e-12)
    cxy = (c - bbox[None, :2]) / span[None, :]
    exp = np.asarray(ref.spatial_key(cxy, curve=curve))
    np.testing.assert_array_equal(got, exp)


def test_hilbert_sort_improves_locality():
    """Sorted-adjacent query centers are much closer than arrival order —
    the property the whole scheduling layer exists to manufacture."""
    q = _queries(512, seed=1)
    c = (q[:, :2] + q[:, 2:]) / 2
    d_arrival = np.linalg.norm(np.diff(c, axis=0), axis=1).mean()
    for curve in ("hilbert", "morton"):
        sched = schedule.make_schedule(q, batch=64, sort=curve)
        d_sorted = np.linalg.norm(
            np.diff(c[sched.order], axis=0), axis=1).mean()
        assert d_sorted < 0.5 * d_arrival, (curve, d_sorted, d_arrival)


# ---------------------------------------------------------------------------
# schedule formation
# ---------------------------------------------------------------------------

@given(st.integers(1, 200), st.integers(1, 70), st.booleans())
@settings(max_examples=25, deadline=None)
def test_schedule_is_permutation(n, batch, hilbert):
    q = _queries(n, seed=n)
    sort = "hilbert" if hilbert else "morton"
    sched = schedule.make_schedule(q, batch=batch, sort=sort)
    assert sorted(sched.order.tolist()) == list(range(n))
    np.testing.assert_array_equal(sched.order[sched.inv], np.arange(n))
    assert sched.n_batches == -(-n // batch)
    # batches tile the sorted stream exactly once, tail padded
    seen = []
    for chunk, n_valid in schedule.iter_batches(q, sched):
        assert chunk.shape == (sched.batch, 4)
        seen.append(chunk[:n_valid])
    np.testing.assert_array_equal(np.concatenate(seen), q[sched.order])


def test_sort_none_preserves_arrival_order():
    q = _queries(37)
    sched = schedule.make_schedule(q, batch=8, sort="none")
    np.testing.assert_array_equal(sched.order, np.arange(37))


# ---------------------------------------------------------------------------
# sorted serving ≡ unsorted serving (bit-identical permutation)
# ---------------------------------------------------------------------------

import functools


@functools.lru_cache(maxsize=None)
def _tree64():
    return _tree(L=64)


def _serve_fn(tree, k=8, max_results=32):
    # range_query_compact is itself jit'd with static bounds, so reusing
    # it across property examples hits the same trace cache
    return lambda q: traversal.range_query_compact(
        tree, q, max_visited=k, max_results=max_results, use_kernel=False)


def _assert_same(a, b):
    for f in type(a)._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


@given(st.integers(3, 90), st.integers(2, 40), st.booleans())
@settings(max_examples=10, deadline=None)
def test_sorted_serving_bit_identical(n, batch, hilbert):
    """Property: for any stream length / batch size (ragged tails
    included), sorted serving returns exactly what unsorted serving
    returns, row for row, field for field."""
    tree = _tree64()
    q = _queries(n, seed=n, big_frac=0.1)
    fn = _serve_fn(tree)
    sort = "hilbert" if hilbert else "morton"
    base = schedule.serve_workload(fn, q, batch=batch, sort="none")
    srt = schedule.serve_workload(fn, q, batch=batch, sort=sort)
    _assert_same(base.stats, srt.stats)
    # and the unsorted scheduled stream equals direct whole-batch serving
    direct = jax.tree.map(np.asarray, fn(jnp.asarray(q[:batch])))
    head = jax.tree.map(lambda a: np.asarray(a)[:batch], base.stats)
    _assert_same(head, jax.tree.map(lambda a: a[:min(batch, n)], direct))


def test_sorted_serving_single_level_tree():
    """Degenerate root == leaf tree through the full scheduler path."""
    tree = _single_level_tree()
    q = _queries(23, seed=9, span=1.0, big_frac=0.3)
    fn = _serve_fn(tree, k=4)
    base = schedule.serve_workload(fn, q, batch=8, sort="none")
    for sort in ("morton", "hilbert"):
        srt = schedule.serve_workload(fn, q, batch=8, sort=sort)
        _assert_same(base.stats, srt.stats)


def test_stream_serves_every_query():
    """No-drop oracle: aggregate n_results over the scheduled stream ==
    unscheduled ground truth for every query (ragged tail included)."""
    tree = _tree64()
    q = _queries(71, seed=2)
    oracle = traversal.range_query(tree, jnp.asarray(q), max_visited=64,
                                   max_results=64, use_kernel=False)
    rep = schedule.serve_workload(_serve_fn(tree, k=64, max_results=64), q,
                                  batch=16, sort="hilbert")
    assert rep.n_queries == 71 and rep.n_batches == 5
    np.testing.assert_array_equal(np.asarray(rep.stats.n_results),
                                  np.asarray(oracle.n_results))


# ---------------------------------------------------------------------------
# two-tier re-serve
# ---------------------------------------------------------------------------

def test_wide_tier_clears_truncation_without_touching_rest():
    """Regression for the ServeStats.r_truncated contract (here at the
    range_query_compact level: field ``truncated``): overflow rows get
    exact wide-tier answers, non-overflow rows are byte-identical."""
    tree = _tree64()
    q = _queries(60, seed=4, big_frac=0.4)   # big rects overflow k=4
    narrow = _serve_fn(tree, k=4, max_results=256)
    wide = _serve_fn(tree, k=64, max_results=256)
    rep_n = schedule.serve_workload(narrow, q, batch=16, sort="hilbert")
    trunc = np.asarray(rep_n.stats.truncated)
    assert trunc.any(), "fixture too weak: nothing overflowed"
    assert not trunc.all(), "fixture too weak: everything overflowed"
    rep = schedule.serve_workload(narrow, q, batch=16, sort="hilbert",
                                  wide_fn=wide, trunc_field="truncated")
    assert rep.n_reserved == int(trunc.sum())
    assert not np.asarray(rep.stats.truncated).any()
    # overflow rows now exact
    oracle = traversal.range_query(tree, jnp.asarray(q), max_visited=64,
                                   max_results=256, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(rep.stats.n_results),
                                  np.asarray(oracle.n_results))
    # non-overflow rows untouched by the merge
    keep = ~trunc
    for f in type(rep.stats)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rep.stats, f))[keep],
            np.asarray(getattr(rep_n.stats, f))[keep], err_msg=f)


def test_engine_two_tier_clears_r_truncated():
    """End-to-end ServeStats contract: make_two_tier_steps + scheduler.
    The narrow tier's r_truncated rows are re-served wide; merged stats
    carry exact counts everywhere and no residual truncation."""
    from repro.core import build, device_tree as dt, labels
    from repro.core.rtree import RTree
    from repro.data import synth
    from repro.launch import mesh as pmesh

    pts = synth.tweets_like(3000, seed=0)
    rtree = RTree(max_entries=16).insert_all(pts)
    dtree = dt.flatten(rtree)
    qs = synth.synth_queries(pts, 2e-3, 120, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6,))
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    cfg = engine.EngineConfig(max_visited=4, max_pred=32)
    narrow, wide = engine.make_two_tier_steps(mesh, cfg, kind="knn",
                                              wide_factor=64)
    with pmesh.set_mesh(mesh):
        nf = jax.jit(lambda q: narrow(hyb, q))
        wf = jax.jit(lambda q: wide(hyb, q))
        rep_n = schedule.serve_workload(nf, wl.queries, batch=32,
                                        sort="hilbert")
        trunc = np.asarray(rep_n.stats.r_truncated)
        assert trunc.any(), "fixture too weak: nothing overflowed"
        rep = schedule.serve_workload(nf, wl.queries, batch=32,
                                      sort="hilbert", wide_fn=wf)
    assert rep.n_reserved == int(trunc.sum())
    assert not np.asarray(rep.stats.r_truncated).any()
    np.testing.assert_array_equal(np.asarray(rep.stats.n_results),
                                  wl.n_results)
    keep = ~trunc
    for f in type(rep.stats)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rep.stats, f))[keep],
            np.asarray(getattr(rep_n.stats, f))[keep], err_msg=f)


# ---------------------------------------------------------------------------
# degenerate workloads: zero-extent bbox must still produce valid keys
# ---------------------------------------------------------------------------

def test_workload_bbox_guards_zero_extent():
    """A single query / coincident centers collapse the center bbox to a
    point; the guard must widen it to positive area (a zero span would
    push the key normalization onto an epsilon clamp that amplifies f32
    rounding into arbitrary key orderings)."""
    q = _queries(1, seed=0)
    bbox = schedule.workload_bbox(q)
    assert bbox[2] - bbox[0] > 0 and bbox[3] - bbox[1] > 0
    # coincident centers along one axis only: that axis alone widens
    qx = _queries(8, seed=1)
    qx[:, 1] = 0.25
    qx[:, 3] = 0.25     # all centers share y = 0.25
    bbox = schedule.workload_bbox(qx)
    assert bbox[3] - bbox[1] == pytest.approx(1.0)
    c = (qx[:, :2] + qx[:, 2:]) / 2
    assert bbox[2] - bbox[0] == pytest.approx(
        c[:, 0].max() - c[:, 0].min(), abs=1e-5)


@pytest.mark.parametrize("curve", ["hilbert", "morton"])
def test_degenerate_workload_keys_valid(curve):
    """All-coincident centers → one shared key; single query → key
    computable; a degenerate caller-passed bbox gets the same guard."""
    q1 = _queries(1, seed=2)
    k1 = schedule.spatial_keys(q1, curve)
    assert k1.shape == (1,) and k1.dtype == np.int32
    qc = np.repeat(q1, 7, axis=0)
    kc = schedule.spatial_keys(qc, curve)
    assert np.unique(kc).size == 1     # coincident centers, one curve cell
    # caller-passed zero-extent bbox (not via workload_bbox)
    flat = np.array([0.5, 0.5, 0.5, 0.5], np.float32)
    kf = schedule.spatial_keys(qc, curve, bbox=flat)
    np.testing.assert_array_equal(kf, np.full((7,), kf[0]))
    # and the full scheduling + serving path stays well-formed
    tree = _tree64()
    sched = schedule.make_schedule(qc, batch=4, sort=curve)
    assert sorted(sched.order.tolist()) == list(range(7))
    rep = schedule.serve_workload(_serve_fn(tree), qc, batch=4, sort=curve)
    base = schedule.serve_workload(_serve_fn(tree), qc, batch=4,
                                   sort="none")
    _assert_same(rep.stats, base.stats)


# ---------------------------------------------------------------------------
# serve_workload edges the streaming runtime leans on
# ---------------------------------------------------------------------------

def test_two_tier_with_empty_truncated_set():
    """wide_fn wired but nothing overflows: the wide tier must not fire
    (no re-served rows, no wide batches) and results must equal the
    narrow-only stream byte for byte."""
    tree = _tree64()
    q = _queries(40, seed=6)            # small rects: k=64 never overflows
    narrow = _serve_fn(tree, k=64, max_results=256)
    calls = []

    def wide(batch_q):
        calls.append(1)
        return narrow(batch_q)

    rep = schedule.serve_workload(narrow, q, batch=16, sort="hilbert",
                                  wide_fn=wide, trunc_field="truncated")
    assert not np.asarray(rep.stats.truncated).any()
    assert rep.n_reserved == 0 and rep.wide_batches == 0
    assert not calls, "wide tier served an empty re-serve set"
    base = schedule.serve_workload(narrow, q, batch=16, sort="hilbert")
    _assert_same(rep.stats, base.stats)


def test_serve_workload_batch_one():
    """batch=1: every batch is a single query (the runtime's deadline
    dispatch degenerates to this under extreme pressure) — permutation,
    two-tier merge, and padding must all hold."""
    tree = _tree64()
    q = _queries(13, seed=8, big_frac=0.4)
    narrow = _serve_fn(tree, k=4, max_results=256)
    wide = _serve_fn(tree, k=64, max_results=256)
    rep = schedule.serve_workload(narrow, q, batch=1, sort="hilbert",
                                  wide_fn=wide, trunc_field="truncated")
    assert rep.n_batches == 13
    ref = schedule.serve_workload(narrow, q, batch=8, sort="none",
                                  wide_fn=wide, trunc_field="truncated")
    _assert_same(rep.stats, ref.stats)
    assert not np.asarray(rep.stats.truncated).any()


def test_heterogeneous_point_range_stream_bit_identical():
    """A mixed point/range stream served through a per-row dispatching
    step (degenerate rects take narrowed bounds, range rects the full
    ones) keeps the scheduler contract: sorted serving is a bit-identical
    inverse-permutation of unsorted serving, and both equal direct
    whole-stream serving — batch composition (which rows of each type
    land together) must not leak into any result field."""
    from repro.core import hybrid as hybmod
    from tests.test_point_query import _world

    pts, hyb = _world()
    rng = np.random.default_rng(13)
    q = _queries(57, seed=13)
    pt = rng.uniform(size=57) < 0.5
    # point rows: degenerate rects at real dataset points (so the point
    # path has hits); range rows keep their rects
    hitp = pts[rng.integers(0, pts.shape[0], int(pt.sum()))].astype(
        np.float32)
    q[pt, :2] = hitp
    q[pt, 2:] = hitp
    assert pt.any() and not pt.all()
    np.testing.assert_array_equal(schedule.point_query_mask(q), pt)

    def fn(batch_q):
        isp = hybmod.is_point_query(batch_q)
        pr = hybmod.point_query(hyb, batch_q, max_visited=16,
                                max_results=32)
        rr = hybmod.hybrid_query(hyb, batch_q, max_visited=64,
                                 max_results=32)
        return jax.tree.map(
            lambda a, b: jnp.where(
                isp.reshape((-1,) + (1,) * (a.ndim - 1)), a, b),
            pr, rr)

    base = schedule.serve_workload(fn, q, batch=16, sort="none")
    for sort in ("hilbert", "morton"):
        srt = schedule.serve_workload(fn, q, batch=16, sort=sort)
        _assert_same(base.stats, srt.stats)
    # inverse-permutation restoration == direct whole-stream serving
    direct = jax.tree.map(np.asarray, fn(jnp.asarray(q)))
    _assert_same(base.stats, direct)
    # the dispatch is actually heterogeneous *within* sorted batches,
    # not just across the stream — otherwise this tests nothing new
    sched = schedule.make_schedule(q, batch=16, sort="hilbert")
    per_batch = [pt[sched.order[i:i + 16]]
                 for i in range(0, 57, 16)]
    assert any(m.any() and not m.all() for m in per_batch), \
        "fixture too weak: batches are type-homogeneous"


def test_two_tier_final_ragged_batch_all_overflow():
    """The final ragged batch overflows on every valid row: the merge
    must replace exactly those rows (pad rows dropped, non-overflow rows
    from earlier batches untouched)."""
    tree = _tree64()
    q_small = _queries(16, seed=10)                  # fills one batch
    q_big = _queries(3, seed=12, big_frac=1.0)       # ragged tail
    q_big[:, 2:] = q_big[:, :2] + 1.5                # guarantee overflow
    q = np.concatenate([q_small, q_big])
    narrow = _serve_fn(tree, k=2, max_results=256)
    wide = _serve_fn(tree, k=64, max_results=256)
    rep_n = schedule.serve_workload(narrow, q, batch=16, sort="none")
    trunc = np.asarray(rep_n.stats.truncated).astype(bool)
    assert trunc[16:].all(), "fixture too weak: tail row not truncated"
    rep = schedule.serve_workload(narrow, q, batch=16, sort="none",
                                  wide_fn=wide, trunc_field="truncated")
    assert rep.n_reserved == int(trunc.sum())
    assert not np.asarray(rep.stats.truncated).any()
    keep = ~trunc
    for f in type(rep.stats)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rep.stats, f))[keep],
            np.asarray(getattr(rep_n.stats, f))[keep], err_msg=f)
    # overflow rows exact vs the unbounded oracle
    oracle = traversal.range_query(tree, jnp.asarray(q), max_visited=64,
                                   max_results=256, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(rep.stats.n_results),
                                  np.asarray(oracle.n_results))
