"""Property tests for the traversal/compaction contracts.

Pins down conventions that previously lived only in docstrings:

* ``alpha``'s empty-visit convention — queries that visit no leaves get
  α = 1 exactly (nothing was extraneous), and α ∈ [0, 1] whenever
  TN ≤ VN;
* ``compact_mask`` / ``compact_mask_counted`` at the overflow boundary —
  rows with exactly ``k``, ``k ± 1`` set bits, against the ``top_k``
  oracle;
* ``gather_result_ids`` at exactly ``max_results`` qualifying entries,
  against its ``top_k`` oracle.

Runs under real hypothesis when installed, else the fixed-seed example
fallback in ``tests/helpers/hypo.py``.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from helpers.hypo import given, settings, st

from repro.core import traversal


# ---------------------------------------------------------------------------
# alpha
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_alpha_empty_visit_is_one(B, seed):
    """n_visited == 0 ⟹ α == 1 exactly, whatever n_true claims."""
    rng = np.random.default_rng(seed)
    n_true = jnp.asarray(rng.integers(0, 5, B), jnp.int32)
    n_visited = jnp.zeros((B,), jnp.int32)
    a = np.asarray(traversal.alpha(n_true, n_visited))
    np.testing.assert_array_equal(a, np.ones(B, np.float32))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
def test_alpha_bounded_and_exact_on_perfect_overlap(B, seed):
    rng = np.random.default_rng(seed)
    n_visited = jnp.asarray(rng.integers(0, 40, B), jnp.int32)
    n_true = jnp.asarray(
        rng.integers(0, np.asarray(n_visited) + 1), jnp.int32)
    a = np.asarray(traversal.alpha(n_true, n_visited))
    assert ((a >= 0) & (a <= 1)).all()
    # TN == VN > 0 ⟹ α == 1; TN == 0 < VN ⟹ α == 0
    nv = np.asarray(n_visited)
    nt = np.asarray(n_true)
    np.testing.assert_array_equal(a[(nt == nv) | (nv == 0)], 1.0)
    np.testing.assert_array_equal(a[(nt == 0) & (nv > 0)], 0.0)


# ---------------------------------------------------------------------------
# compact_mask at the overflow boundary
# ---------------------------------------------------------------------------

def _mask_with_count(rng, L, count):
    """A [L] bool row with exactly ``count`` set bits, random positions."""
    row = np.zeros(L, bool)
    row[rng.choice(L, size=count, replace=False)] = True
    return row


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 20), st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_compact_mask_overflow_boundary(k, extra, seed):
    """Rows with exactly k-1 / k / k+1 set bits: overflow fires only past
    k, validity tracks min(count, k), and idx matches the top_k oracle."""
    rng = np.random.default_rng(seed)
    L = k + extra
    counts = [max(0, k - 1), k, min(L, k + 1)]
    mask = jnp.asarray(np.stack([_mask_with_count(rng, L, c)
                                 for c in counts]))
    idx, valid, count = traversal.compact_mask_counted(mask, k)
    np.testing.assert_array_equal(np.asarray(count), counts)
    # overflow == count > k: only the k+1 row (when L admits it)
    np.testing.assert_array_equal(np.asarray(count) > k,
                                  [False, False, counts[2] > k])
    np.testing.assert_array_equal(
        np.asarray(valid).sum(1), np.minimum(counts, k))
    i_old, v_old = traversal.compact_mask_topk(mask, k)
    np.testing.assert_array_equal(np.asarray(valid), np.asarray(v_old))
    np.testing.assert_array_equal(np.asarray(idx * valid),
                                  np.asarray(i_old * v_old))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 16), st.integers(1, 120), st.integers(1, 24),
       st.integers(0, 2**31 - 1))
def test_compact_mask_random_matches_topk(B, L, k, seed):
    rng = np.random.default_rng(seed)
    mask = jnp.asarray(rng.uniform(size=(B, L)) < rng.uniform(0, 0.6))
    i_new, v_new, count = traversal.compact_mask_counted(mask, k)
    i_old, v_old = traversal.compact_mask_topk(mask, k)
    np.testing.assert_array_equal(np.asarray(v_new), np.asarray(v_old))
    np.testing.assert_array_equal(np.asarray(i_new * v_new),
                                  np.asarray(i_old * v_old))
    np.testing.assert_array_equal(np.asarray(count),
                                  np.asarray(mask).sum(1))
    np.testing.assert_array_equal(np.asarray(traversal.overflowed(mask, k)),
                                  np.asarray(count) > k)


# ---------------------------------------------------------------------------
# gather_result_ids at the truncation boundary
# ---------------------------------------------------------------------------

class _FakeTree:
    def __init__(self, rng, L, M):
        self.leaf_entry_ids = jnp.asarray(
            rng.integers(0, 10_000, (L, M)), jnp.int32)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(2, 12), st.integers(0, 2**31 - 1))
def test_gather_result_ids_truncation_boundary(K, M, seed):
    """Batches engineered to have exactly mr-1 / mr / mr+1 qualifying
    entries: truncation fires only past mr; ids match the top_k oracle."""
    rng = np.random.default_rng(seed)
    L = 30
    mr = max(2, (K * M) // 2)
    rows = []
    for count in (mr - 1, mr, min(K * M, mr + 1)):
        rows.append(_mask_with_count(rng, K * M, count).reshape(K, M))
    inside = jnp.asarray(np.stack(rows))
    leaf_idx = jnp.asarray(rng.integers(0, L, (3, K)), jnp.int32)
    valid = jnp.ones((3, K), bool)
    refine = traversal.RefineResult(
        counts=jnp.sum(inside.astype(jnp.int32), -1),
        inside=inside, leaf_idx=leaf_idx, valid=valid)
    tree = _FakeTree(rng, L, M)
    new_ids, new_tr = traversal.gather_result_ids(tree, refine, mr)
    old_ids, old_tr = traversal.gather_result_ids_topk(tree, refine, mr)
    np.testing.assert_array_equal(np.asarray(new_ids), np.asarray(old_ids))
    np.testing.assert_array_equal(np.asarray(new_tr), np.asarray(old_tr))
    np.testing.assert_array_equal(
        np.asarray(new_tr), [False, False, min(K * M, mr + 1) > mr])


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 10), st.integers(1, 8), st.integers(1, 30),
       st.integers(0, 2**31 - 1))
def test_gather_result_ids_random_matches_topk(B, K, mr, seed):
    rng = np.random.default_rng(seed)
    L, M = 25, int(rng.integers(2, 16))
    mr = min(mr, K * M)   # the top_k oracle requires mr ≤ flat width
    inside = jnp.asarray(rng.uniform(size=(B, K, M)) < 0.3)
    leaf_idx = jnp.asarray(rng.integers(0, L, (B, K)), jnp.int32)
    valid = jnp.asarray(rng.uniform(size=(B, K)) > 0.2)
    refine = traversal.RefineResult(
        counts=jnp.sum(inside.astype(jnp.int32), -1),
        inside=inside, leaf_idx=leaf_idx, valid=valid)
    tree = _FakeTree(rng, L, M)
    new_ids, new_tr = traversal.gather_result_ids(tree, refine, mr)
    old_ids, old_tr = traversal.gather_result_ids_topk(tree, refine, mr)
    np.testing.assert_array_equal(np.asarray(new_ids), np.asarray(old_ids))
    np.testing.assert_array_equal(np.asarray(new_tr), np.asarray(old_tr))
