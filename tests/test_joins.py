"""Batched spatial join: pair-set exactness and wide-tier preservation.

``join_step`` (both kernel forms) and ``spatial_join`` must reproduce
the brute-force pair set exactly; overflowing rows re-serve on the wide
tier with their pairs kept at that tier's full static width (the
payload-preservation property ``schedule._merge_rows`` alone cannot
give); and the kernel path's serving HLO carries no dense [B, L] mask.
"""
import functools
import re

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import device_tree as dt, joins
from repro.core.device_tree import DeviceTree, Level
from repro.core.rtree import RTree
from tests.helpers.hypo import given, settings, st


@functools.lru_cache(maxsize=None)
def _world(n=2500, seed=0):
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, 2))
    dtree = dt.flatten(RTree.str_bulk(pts, max_entries=16))
    return pts, dtree


def _rects(pts, rng, n, w=0.08):
    lo = pts[rng.integers(0, pts.shape[0], n)].astype(np.float32)
    wd = rng.uniform(0, w, (n, 2)).astype(np.float32)
    return np.concatenate([lo - wd, lo + wd], axis=1)


def _pair_set(stats, rows=None):
    ids = np.asarray(stats.pair_ids)
    nps = np.asarray(stats.n_pairs)
    rows = range(ids.shape[0]) if rows is None else rows
    return {(int(i), int(p)) for i in rows
            for p in ids[i, :min(int(nps[i]), ids.shape[1])]}


# ---------------------------------------------------------------------------
# join_step vs brute force
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_join_step_pairs_match_brute(use_kernel):
    pts, tree = _world()
    rng = np.random.default_rng(1)
    outer = _rects(pts, rng, 48)
    res = joins.join_step(tree, jnp.asarray(outer), max_pairs=64,
                          max_visited=64, use_kernel=use_kernel)
    assert not np.asarray(res.truncated).any(), "fixture: bounds too tight"
    bp = joins.join_brute(pts, outer)
    assert bp.shape[0] > 48, "fixture too weak: joins barely populated"
    assert _pair_set(res) == {tuple(r) for r in bp}
    np.testing.assert_array_equal(np.asarray(res.n_pairs),
                                  np.bincount(bp[:, 0], minlength=48))


def test_join_step_kernel_forms_agree():
    pts, tree = _world()
    rng = np.random.default_rng(2)
    outer = jnp.asarray(_rects(pts, rng, 32))
    a = joins.join_step(tree, outer, max_pairs=32, use_kernel=False)
    b = joins.join_step(tree, outer, max_pairs=32, use_kernel=True)
    for f in a._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)), err_msg=f)


# ---------------------------------------------------------------------------
# spatial_join: exactness, order canonicality, wide-tier preservation
# ---------------------------------------------------------------------------

def test_spatial_join_matches_brute():
    pts, tree = _world()
    rng = np.random.default_rng(3)
    outer = _rects(pts, rng, 120)
    rep = joins.spatial_join(tree, outer, batch=32, max_pairs=16,
                             max_visited=64)
    assert rep.residual_truncated == 0
    bp = joins.join_brute(pts, outer)
    np.testing.assert_array_equal(rep.pairs, bp)
    assert rep.n_pairs == bp.shape[0] and rep.n_outer == 120


def test_spatial_join_order_canonical_across_sorts():
    """The (outer, point)-lexsorted pair array is identical whatever
    curve formed the batches."""
    pts, tree = _world()
    rng = np.random.default_rng(4)
    outer = _rects(pts, rng, 90)
    reps = [joins.spatial_join(tree, outer, batch=16, max_pairs=16,
                               sort=s) for s in ("none", "hilbert",
                                                 "morton")]
    for rep in reps[1:]:
        np.testing.assert_array_equal(rep.pairs, reps[0].pairs)
        assert rep.n_pairs == reps[0].n_pairs


def test_wide_tier_preserves_pairs():
    """Rows overflowing the narrow pair table re-serve wide and keep
    every pair at the wide tier's full width — no silent slicing back
    to the narrow width."""
    pts, tree = _world()
    rng = np.random.default_rng(5)
    outer = _rects(pts, rng, 80, w=0.25)     # fat rects: many pairs/row
    narrow = joins.join_step(tree, jnp.asarray(outer), max_pairs=4,
                             max_visited=64)
    tr = np.asarray(narrow.truncated)
    assert tr.any(), "fixture too weak: nothing overflowed max_pairs=4"
    assert not tr.all(), "fixture too weak: everything overflowed"
    rep = joins.spatial_join(tree, outer, batch=16, max_pairs=4,
                             max_visited=64, wide_factor=64)
    assert rep.n_reserved == int(tr.sum())
    assert rep.residual_truncated == 0
    bp = joins.join_brute(pts, outer)
    np.testing.assert_array_equal(rep.pairs, bp)
    # a truncated row really did carry more pairs than the narrow width
    counts = np.bincount(bp[:, 0], minlength=80)
    assert counts[tr].max() > 4
    # merged per-row stats carry the full counts
    np.testing.assert_array_equal(np.asarray(rep.stats.n_pairs), counts)


@given(st.integers(1, 60), st.integers(2, 30), st.booleans())
@settings(max_examples=10, deadline=None)
def test_join_property_pair_set_exact(n, batch, hilbert):
    """Property: for any stream length / batch size / curve, the join
    reproduces the brute-force pair set exactly (wide tier sized to
    cover everything)."""
    pts, tree = _world()
    rng = np.random.default_rng(n * 31 + batch)
    outer = _rects(pts, rng, n, w=0.15)
    rep = joins.spatial_join(tree, outer, batch=batch, max_pairs=8,
                             max_visited=64, wide_factor=64,
                             sort="hilbert" if hilbert else "none")
    assert rep.residual_truncated == 0
    np.testing.assert_array_equal(rep.pairs, joins.join_brute(pts, outer))


def test_join_empty_result():
    """Outer rects that hit nothing: zero pairs, well-formed report."""
    pts, tree = _world()
    outer = np.tile(np.array([[50.0, 50.0, 51.0, 51.0]], np.float32),
                    (9, 1))
    rep = joins.spatial_join(tree, outer, batch=4)
    assert rep.n_pairs == 0 and rep.pairs.shape == (0, 2)
    assert not np.asarray(rep.stats.n_pairs).any()


# ---------------------------------------------------------------------------
# HLO contract
# ---------------------------------------------------------------------------

def test_join_step_hlo_stays_compact():
    """The kernel-path join batch lowers without any [B, L]-shaped
    tensor; the jnp oracle rung is the positive control."""
    from repro.data.synth_tree import synth_levels
    rng = np.random.default_rng(0)
    L, M, B = 1000, 8, 256
    mbrs, parents = synth_levels(L, 4, rng)
    tree = DeviceTree(
        levels=tuple(Level(mbrs=jnp.asarray(m), parent=jnp.asarray(p))
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.zeros((L, M, 2), jnp.float32),
        leaf_entry_ids=jnp.zeros((L, M), jnp.int32),
        leaf_counts=jnp.zeros((L,), jnp.int32),
        n_points=0, max_entries=4)
    q = jnp.zeros((B, 4), jnp.float32)

    def lowered(uk):
        return jax.jit(lambda t, qq: joins.join_step(
            t, qq, max_pairs=16, max_visited=64, use_kernel=uk,
            tile_b=128)).lower(tree, q).as_text()

    dense = re.compile(r"<256x(1000|1024)x")
    assert not dense.search(lowered(True)), \
        "join kernel path materialized the dense [B, L] mask"
    assert dense.search(lowered(False)), \
        "oracle control lost its dense mask"
