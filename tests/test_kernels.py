"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle.

Sweeps shapes/dtypes per kernel and asserts allclose; includes hypothesis
property tests for the geometric kernels.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from helpers.hypo import given, settings, st

from repro.kernels import ops, ref


RNG = np.random.default_rng(42)


def mk_rects(n, rng=RNG, scale=1.0):
    lo = rng.uniform(-scale, scale, size=(n, 2))
    w = rng.uniform(0, scale, size=(n, 2))
    return np.concatenate([lo, lo + w], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# mbr_intersect
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,N", [(1, 1), (7, 130), (64, 512), (257, 1000),
                                 (1024, 64), (3, 4096)])
def test_mbr_intersect_shapes(B, N):
    q, m = mk_rects(B), mk_rects(N)
    out = ops.mbr_intersect(jnp.asarray(q), jnp.asarray(m))
    exp = ref.mbr_intersect(jnp.asarray(q), jnp.asarray(m))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_mbr_intersect_dtypes(dtype):
    q, m = mk_rects(33).astype(dtype), mk_rects(65).astype(dtype)
    out = ops.mbr_intersect(jnp.asarray(q), jnp.asarray(m))
    exp = ref.mbr_intersect(jnp.asarray(q), jnp.asarray(m))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_mbr_intersect_touching_counts():
    q = np.array([[0.0, 0.0, 1.0, 1.0]], np.float32)
    m = np.array([[1.0, 1.0, 2.0, 2.0],   # corner touch → intersects
                  [1.0000001, 1.0, 2.0, 2.0],  # just past → no
                  [-1.0, -1.0, 0.0, 0.0]], np.float32)
    out = np.asarray(ops.mbr_intersect(jnp.asarray(q), jnp.asarray(m)))
    assert out.tolist() == [[True, False, True]]


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(st.integers(1, 40), st.integers(1, 300), st.integers(0, 2**31 - 1))
def test_mbr_intersect_property(B, N, seed):
    rng = np.random.default_rng(seed)
    q, m = mk_rects(B, rng), mk_rects(N, rng)
    out = np.asarray(ops.mbr_intersect(jnp.asarray(q), jnp.asarray(m)))
    exp = np.asarray(ref.mbr_intersect(jnp.asarray(q), jnp.asarray(m)))
    np.testing.assert_array_equal(out, exp)
    # symmetry: swapping roles transposes the mask
    out_t = np.asarray(ops.mbr_intersect(jnp.asarray(m), jnp.asarray(q)))
    np.testing.assert_array_equal(out_t, exp.T)


# ---------------------------------------------------------------------------
# leaf_refine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,K,L,M", [(1, 1, 1, 8), (9, 5, 40, 16),
                                     (64, 16, 200, 32), (17, 64, 1000, 200)])
def test_leaf_refine_shapes(B, K, L, M):
    q = mk_rects(B)
    entries = RNG.uniform(-1, 1, size=(L, M, 2)).astype(np.float32)
    idx = RNG.integers(0, L, size=(B, K)).astype(np.int32)
    valid = (RNG.uniform(size=(B, K)) > 0.3).astype(np.int32)
    out = ops.leaf_refine(jnp.asarray(q), jnp.asarray(entries),
                          jnp.asarray(idx), jnp.asarray(valid))
    exp = ref.leaf_refine(jnp.asarray(q), jnp.asarray(entries[..., 0]),
                          jnp.asarray(entries[..., 1]), jnp.asarray(idx),
                          jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("B,K,L,M", [(1, 1, 1, 8), (9, 5, 40, 16),
                                     (64, 16, 200, 32), (17, 64, 1000, 200)])
def test_leaf_refine_grid_forms_bit_identical(B, K, L, M):
    """The folded interpret form (whole-array block, XLA-level gather) and
    the (B, K) scalar-prefetch TPU form must agree bit for bit."""
    from repro.kernels import leaf_refine as lr
    q = mk_rects(B)
    entries = RNG.uniform(-1, 1, size=(L, M, 2)).astype(np.float32)
    idx = RNG.integers(0, L, size=(B, K)).astype(np.int32)
    valid = (RNG.uniform(size=(B, K)) > 0.3).astype(np.int32)
    args = (jnp.asarray(q), jnp.asarray(entries[..., 0]),
            jnp.asarray(entries[..., 1]), jnp.asarray(idx),
            jnp.asarray(valid))
    prefetch = lr.leaf_refine(*args, interpret=True, fold_k=False)
    folded = lr.leaf_refine(*args, interpret=True, fold_k=True)
    np.testing.assert_array_equal(np.asarray(prefetch), np.asarray(folded))


def test_leaf_refine_inf_padding_never_matches():
    q = np.array([[-1e30, -1e30, 1e30, 1e30]], np.float32)  # huge query
    entries = np.full((4, 8, 2), np.inf, np.float32)        # all padding
    idx = np.zeros((1, 2), np.int32)
    valid = np.ones((1, 2), np.int32)
    out = np.asarray(ops.leaf_refine(jnp.asarray(q), jnp.asarray(entries),
                                     jnp.asarray(idx), jnp.asarray(valid)))
    assert not out.any()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(1, 12), st.integers(1, 50),
       st.integers(0, 2**31 - 1))
def test_leaf_refine_property(B, K, L, seed):
    rng = np.random.default_rng(seed)
    M = int(rng.integers(4, 40))
    q = mk_rects(B, rng)
    entries = rng.uniform(-1, 1, size=(L, M, 2)).astype(np.float32)
    idx = rng.integers(0, L, size=(B, K)).astype(np.int32)
    valid = (rng.uniform(size=(B, K)) > 0.5).astype(np.int32)
    out = np.asarray(ops.leaf_refine(jnp.asarray(q), jnp.asarray(entries),
                                     jnp.asarray(idx), jnp.asarray(valid)))
    # invalid slots are all-false; valid slots match direct containment
    for b in range(B):
        for k in range(K):
            if not valid[b, k]:
                assert not out[b, k].any()
            else:
                pts = entries[idx[b, k]]
                exp = ((pts[:, 0] >= q[b, 0]) & (pts[:, 0] <= q[b, 2])
                       & (pts[:, 1] >= q[b, 1]) & (pts[:, 1] <= q[b, 3]))
                np.testing.assert_array_equal(out[b, k], exp)


# ---------------------------------------------------------------------------
# forest_infer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,D,C", [(1, 1, 1, 8), (33, 4, 5, 24),
                                     (128, 16, 8, 128), (7, 2, 10, 64)])
def test_forest_infer_shapes(B, T, D, C):
    F = 6
    feats = RNG.uniform(-1, 1, size=(B, F)).astype(np.float32)
    fidx = RNG.integers(0, F, size=(T, D)).astype(np.int32)
    th = RNG.uniform(-1, 1, size=(T, D)).astype(np.float32)
    tables = RNG.uniform(0, 1, size=(T, 2 ** D, C)).astype(np.float32)
    out = ops.forest_infer(jnp.asarray(feats), jnp.asarray(fidx),
                           jnp.asarray(th), jnp.asarray(tables))
    exp = ref.forest_infer(jnp.asarray(feats[:, fidx]), jnp.asarray(th),
                           jnp.asarray(tables))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5)


def test_forest_infer_single_path():
    # One tree, depth 2; feature 0 decides bit0, feature 1 decides bit1.
    feats = np.array([[2.0, -3.0]], np.float32)   # bit0=1 (2>0), bit1=0 → leaf 2
    fidx = np.array([[0, 1]], np.int32)
    th = np.zeros((1, 2), np.float32)
    tables = np.zeros((1, 4, 3), np.float32)
    tables[0, 2] = [1, 2, 3]
    out = np.asarray(ops.forest_infer(jnp.asarray(feats), jnp.asarray(fidx),
                                      jnp.asarray(th), jnp.asarray(tables)))
    np.testing.assert_allclose(out, [[1, 2, 3]])


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("BH,T,dk,dv,chunk", [
    (1, 16, 8, 8, 16), (3, 64, 8, 16, 16), (2, 48, 16, 16, 16),
    (1, 33, 8, 8, 16),  # padded-T path
    (2, 128, 32, 32, 64),
])
def test_wkv6_shapes(BH, T, dk, dv, chunk):
    r = RNG.normal(size=(BH, T, dk)).astype(np.float32)
    k = RNG.normal(size=(BH, T, dk)).astype(np.float32)
    v = RNG.normal(size=(BH, T, dv)).astype(np.float32)
    w = RNG.uniform(0.05, 0.999, size=(BH, T, dk)).astype(np.float32)
    u = RNG.normal(size=(BH, dk)).astype(np.float32)
    out = ops.wkv6(*map(jnp.asarray, (r, k, v, w, u)), chunk=chunk)
    exp = ref.wkv6(*map(jnp.asarray, (r, k, v, w, u)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=5e-4, atol=5e-4)


def test_wkv6_extreme_decay_is_stable():
    """Per-channel ≤0 exponents ⇒ no overflow even for near-zero decay."""
    BH, T, dk, dv = 2, 64, 8, 8
    r = RNG.normal(size=(BH, T, dk)).astype(np.float32)
    k = RNG.normal(size=(BH, T, dk)).astype(np.float32)
    v = RNG.normal(size=(BH, T, dv)).astype(np.float32)
    w = RNG.uniform(1e-8, 0.1, size=(BH, T, dk)).astype(np.float32)
    u = RNG.normal(size=(BH, dk)).astype(np.float32)
    out = ops.wkv6(*map(jnp.asarray, (r, k, v, w, u)), chunk=16)
    exp = ref.wkv6(*map(jnp.asarray, (r, k, v, w, u)))
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=5e-4, atol=5e-4)


def test_wkv6_bf16_inputs():
    BH, T, dk, dv = 1, 32, 8, 8
    r = jnp.asarray(RNG.normal(size=(BH, T, dk)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(BH, T, dk)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(BH, T, dv)), jnp.bfloat16)
    w = jnp.asarray(RNG.uniform(0.3, 0.99, size=(BH, T, dk)), jnp.bfloat16)
    u = jnp.asarray(RNG.normal(size=(BH, dk)), jnp.bfloat16)
    out = ops.wkv6(r, k, v, w, u, chunk=16)
    exp = ref.wkv6(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(exp, dtype=np.float32),
                               rtol=5e-2, atol=5e-2)
