"""Fused AI-path prediction kernel + compact AI query equivalence tests.

The fused kernel (``kernels/mlp_infer.py``) must be bit-identical to the
dense oracle (``predict_scores`` → threshold → ``compact_mask_counted``)
in both kernel forms, including every fallback-signal edge case the
hybrid relies on: *empty* prediction, exactly-``max_pred`` and
overflow-at-``max_pred`` boundaries, grid-routing ``cell_over``, and the
paper's mispredict (zero-count predicted leaf) convention. The serving
pipeline built on it (``ai_query_compact``, the engine's AI slot stage)
must never materialize the dense ``[B, L]`` score table in the lowered
HLO — asserted the way PR 3 pinned the R path's visited mask.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import engine, traversal
from repro.core.aitree import (ai_query, ai_query_compact, make_aitree,
                               predict_compact, predict_scores)
from repro.core.classifiers.mlp import MLPBank
from repro.core.classifiers.router import Router
from repro.core.device_tree import DeviceTree, Level
from repro.core.grid import Grid
from repro.core.hybrid import HybridTree
from repro.kernels import mlp_infer as mi
from repro.kernels import ops, ref
from tests.helpers.hypo import given, settings, st


def synth_bank(rng, C, L, F=4, H=8, Cl=6, pos_bias=0.0):
    """A random (untrained) MLPBank over C cells and L global leaves."""
    lm = rng.integers(0, L, (C, Cl)).astype(np.int32)
    lmask = rng.uniform(size=(C, Cl)) < 0.8
    lm[~lmask] = -1
    return MLPBank(
        w1=jnp.asarray(rng.normal(0, 1.0, (C, F, H)), jnp.float32),
        b1=jnp.asarray(rng.normal(0, 1.0, (C, H)), jnp.float32),
        w2=jnp.asarray(rng.normal(0, 1.0, (C, H, Cl)), jnp.float32),
        b2=jnp.asarray(rng.normal(pos_bias, 0.5, (C, Cl)), jnp.float32),
        mu=jnp.zeros((F,), jnp.float32),
        sd=jnp.ones((F,), jnp.float32),
        label_map=jnp.asarray(lm),
        lmask=jnp.asarray(lmask),
    )


def synth_world(rng, g=3, L=300, M=8, Cl=6, max_pred=16, pos_bias=0.0,
                threshold=0.5):
    """Synthetic (tree, aitree, queries): single-level tree (the AI path
    never traverses), g×g grid, random bank — fast, no training."""
    bank = synth_bank(rng, g * g, L, Cl=Cl, pos_bias=pos_bias)
    grid = Grid(bbox=jnp.asarray([-1.0, -1.0, 1.0, 1.0], jnp.float32), g=g)
    ait = make_aitree(grid, bank, max_cells=4, max_pred=max_pred,
                      threshold=threshold)
    lo = rng.uniform(-1, 1, (L, 2))
    mbrs = jnp.asarray(
        np.concatenate([lo, lo + rng.uniform(0.05, 0.3, (L, 2))], 1),
        jnp.float32)
    tree = DeviceTree(
        levels=(Level(mbrs=mbrs, parent=jnp.zeros((L,), jnp.int32)),),
        leaf_entries=jnp.asarray(rng.uniform(-1, 1, (L, M, 2)), jnp.float32),
        leaf_entry_ids=jnp.asarray(
            np.arange(L * M).reshape(L, M), jnp.int32),
        leaf_counts=jnp.full((L,), M, jnp.int32),
        n_points=L * M, max_entries=M)
    lo = rng.uniform(-1, 0.9, (64, 2))
    w = rng.uniform(0, 0.1, (64, 2))
    q = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    return tree, ait, q


def dense_oracle(ait, queries, n_leaves, k):
    scores, _ = predict_scores(ait, queries, n_leaves)
    return traversal.compact_mask_counted(scores > ait.threshold, k)


# ---------------------------------------------------------------------------
# kernel vs dense oracle, both forms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("C,L,B,Cl,k", [
    (9, 300, 37, 6, 8),      # nothing tile-aligned
    (4, 1000, 64, 3, 16),    # multi-leaf-tile relevant shapes
    (16, 100, 8, 10, 4),     # heavy overflow (k tiny)
])
def test_ops_wrapper_matches_oracle(C, L, B, Cl, k):
    """ops.mlp_predict_compact (interpret form) == dense oracle."""
    rng = np.random.default_rng(3)
    bank = synth_bank(rng, C, L, Cl=Cl)
    q = jnp.asarray(rng.uniform(-1, 1, (B, 4)), jnp.float32)
    cid = jnp.asarray(rng.integers(0, C, (B, 4)), jnp.int32)
    ok = jnp.asarray(rng.uniform(size=(B, 4)) < 0.85)
    x = (q - bank.mu) / bank.sd
    exp = ref.mlp_predict_compact(
        x, cid, ok, bank.w1, bank.b1, bank.w2, bank.b2, bank.label_map,
        bank.lmask, n_leaves=L, k=k, threshold=0.5)
    got = ops.mlp_predict_compact(q, bank, cid, ok, n_leaves=L, k=k,
                                  threshold=0.5)
    for g, e, name in zip(got, exp, ("idx", "valid", "count")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e),
                                      err_msg=name)


@pytest.mark.parametrize("tpu_form", [True, False])
@pytest.mark.parametrize("L,tl", [
    (1000, 256),   # multi-leaf-tile: rank base carried across j
    (200, 128),
])
def test_kernel_forms_match_oracle(L, tl, tpu_form):
    """Both kernel forms (one-hot MXU staging + chunked rank-equality
    epilogue on the TPU graph; value-level gathers + searchsorted on the
    interpret graph) against the dense oracle, with the compaction rank
    base exercised across multiple leaf tiles and empty rows mixed in."""
    rng = np.random.default_rng(5)
    C, Cl, S, B, k = 7, 6, 4, 21, 8
    bank = synth_bank(rng, C, L, Cl=Cl)
    q = jnp.asarray(rng.uniform(-1, 1, (B, 4)), jnp.float32)
    cid = jnp.asarray(rng.integers(0, C, (B, S)), jnp.int32)
    ok = jnp.asarray(rng.uniform(size=(B, S)) < 0.85)
    ok = ok.at[0].set(False)            # empty row (no valid slot)
    x = (q - bank.mu) / bank.sd
    exp = ref.mlp_predict_compact(
        x, cid, ok, bank.w1, bank.b1, bank.w2, bank.b2, bank.label_map,
        bank.lmask, n_leaves=L, k=k, threshold=0.5)

    LANE = mi.LANE
    Cp = (-C) % LANE
    F, H = 4, bank.b1.shape[1]
    pad = lambda a, v=0.0: jnp.concatenate(         # noqa: E731
        [a, jnp.full((Cp,) + a.shape[1:], v, a.dtype)])
    tb = (B + 7) // 8 * 8
    padb = lambda a: jnp.concatenate(               # noqa: E731
        [a, jnp.zeros((tb - B,) + a.shape[1:], a.dtype)])
    lp = ((L + LANE - 1) // LANE * LANE + tl - 1) // tl * tl
    idx, cnt = mi.mlp_predict_compact_t(
        padb(x), padb(cid), padb(ok.astype(jnp.int32)),
        pad(bank.w1.reshape(C, F * H)), pad(bank.b1),
        pad(bank.w2.reshape(C, H * Cl)), pad(bank.b2),
        pad(bank.label_map.astype(jnp.float32), -1.0),
        pad(bank.lmask.astype(jnp.float32)),
        k=k, lp=lp, thr=0.5, tb=tb, tl=tl, interpret=True,
        tpu_form=tpu_form)
    count = np.asarray(cnt)[:B, 0]
    np.testing.assert_array_equal(count, np.asarray(exp[2]))
    valid = np.arange(k)[None, :] < count[:, None]
    np.testing.assert_array_equal(
        np.where(valid, np.asarray(idx)[:B, :k], 0), np.asarray(exp[0]))
    # contract: slots past the count are zero in both forms
    assert (np.asarray(idx)[:B, :k][~valid] == 0).all()
    assert not count[0], "empty-slot row must predict nothing"


def test_escape_hatch_and_vmem_gate(monkeypatch):
    """Kernels-off and over-VMEM-budget rungs of the fallback ladder stay
    bit-identical to the kernel path (dense oracle semantics)."""
    from repro.kernels import traverse_fused as tf
    rng = np.random.default_rng(11)
    bank = synth_bank(rng, 9, 250)
    q = jnp.asarray(rng.uniform(-1, 1, (19, 4)), jnp.float32)
    cid = jnp.asarray(rng.integers(0, 9, (19, 4)), jnp.int32)
    ok = jnp.asarray(rng.uniform(size=(19, 4)) < 0.9)
    base = ops.mlp_predict_compact(q, bank, cid, ok, n_leaves=250, k=8,
                                   threshold=0.5)
    monkeypatch.setenv("REPRO_KERNELS", "off")
    got_off = ops.mlp_predict_compact(q, bank, cid, ok, n_leaves=250, k=8,
                                      threshold=0.5)
    monkeypatch.delenv("REPRO_KERNELS")
    real = tf.VMEM_BUDGET
    try:
        tf.VMEM_BUDGET = 1
        got_gate = ops.mlp_predict_compact(q, bank, cid, ok, n_leaves=250,
                                           k=8, threshold=0.5)
    finally:
        tf.VMEM_BUDGET = real
    for got in (got_off, got_gate):
        for g, e in zip(got, base):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(e))


# ---------------------------------------------------------------------------
# fallback-signal edge cases (the hybrid's exactness contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_empty_prediction_edge(use_kernel):
    """A bank that never crosses the threshold: count 0 everywhere, and
    ai_query_compact raises the *empty* fallback on every row."""
    rng = np.random.default_rng(0)
    tree, ait, q = synth_world(rng, pos_bias=-30.0)   # sigmoid ≈ 0
    _, valid, n_pred, _ = predict_compact(ait, q, tree.n_leaves,
                                          use_kernel=use_kernel)
    assert not np.asarray(n_pred).any() and not np.asarray(valid).any()
    res = ai_query_compact(ait, tree, q, use_kernel=use_kernel)
    assert np.asarray(res.fallback).all()


@pytest.mark.parametrize("use_kernel", [False, True])
def test_exactly_max_pred_boundary(use_kernel):
    """Rows predicting exactly max_pred leaves must NOT overflow; one
    fewer slot must. Exercised by re-binding max_pred to each row's own
    dense count (the compact path's count is the full count, never
    clamped at k — the overflow signal depends on that)."""
    rng = np.random.default_rng(1)
    tree, ait, q = synth_world(rng, pos_bias=2.0)     # dense predictions
    counts = np.asarray(dense_oracle(ait, q, tree.n_leaves,
                                     ait.max_pred)[2])
    row = int(np.argmax(counts >= 3))
    c = int(counts[row])
    assert c >= 3, "fixture must have a multi-leaf prediction row"
    qr = q[row:row + 1]
    for k, over in ((c, False), (c - 1, True)):
        ait_k = dataclasses.replace(ait, max_pred=k)
        idx, valid, n_pred, _ = predict_compact(ait_k, qr, tree.n_leaves,
                                                use_kernel=use_kernel)
        assert int(n_pred[0]) == c          # full count survives overflow
        assert int(np.asarray(valid).sum()) == min(c, k)
        res = ai_query_compact(ait_k, tree, qr, use_kernel=use_kernel)
        ref_res = ai_query(ait_k, tree, qr, use_kernel=use_kernel)
        assert bool(res.fallback[0]) == bool(ref_res.fallback[0])
        if over:
            assert bool(res.fallback[0])


@pytest.mark.parametrize("use_kernel", [False, True])
def test_cell_overflow_edge(use_kernel):
    """Queries spanning more cells than the static window: cell_over set,
    prediction suppressed, fallback raised — identical to the dense path."""
    rng = np.random.default_rng(2)
    tree, ait, _ = synth_world(rng)
    wide = jnp.asarray([[-0.95, -0.95, 0.95, 0.95]], jnp.float32)  # 3x3 cells
    _, valid, n_pred, cell_over = predict_compact(
        ait, wide, tree.n_leaves, use_kernel=use_kernel)
    assert bool(cell_over[0]) and int(n_pred[0]) == 0
    res = ai_query_compact(ait, tree, wide, use_kernel=use_kernel)
    assert bool(res.fallback[0])


@pytest.mark.parametrize("use_kernel", [False, True])
def test_mispredict_zero_count_convention(use_kernel):
    """The paper's misprediction signal: a predicted leaf whose refinement
    finds zero qualifying entries must force fallback — pinned against
    the dense ai_query on a world whose leaf entries never qualify."""
    rng = np.random.default_rng(4)
    tree, ait, q = synth_world(rng, pos_bias=2.0)
    # entries far outside every query: every predicted leaf yields zero
    tree = dataclasses.replace(
        tree, leaf_entries=jnp.full_like(tree.leaf_entries, 50.0))
    res = ai_query_compact(ait, tree, q, use_kernel=use_kernel)
    exp = ai_query(ait, tree, q, use_kernel=use_kernel)
    np.testing.assert_array_equal(np.asarray(res.fallback),
                                  np.asarray(exp.fallback))
    pred_rows = np.asarray(exp.n_pred) > 0
    assert pred_rows.any(), "fixture must predict something"
    assert np.asarray(res.fallback)[pred_rows].all()


# ---------------------------------------------------------------------------
# ai_query_compact == ai_query (the serving pipeline contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_ai_query_compact_matches_dense(use_kernel):
    rng = np.random.default_rng(6)
    tree, ait, q = synth_world(rng, pos_bias=0.5)
    comp = ai_query_compact(ait, tree, q, use_kernel=use_kernel)
    full = ai_query(ait, tree, q, use_kernel=False)
    exp_i, exp_v, _ = traversal.compact_mask_counted(
        full.pred_mask, ait.max_pred)
    np.testing.assert_array_equal(np.asarray(comp.leaf_idx),
                                  np.asarray(exp_i))
    np.testing.assert_array_equal(np.asarray(comp.valid), np.asarray(exp_v))
    for f in ("counts", "n_pred", "n_results", "result_ids", "fallback"):
        np.testing.assert_array_equal(
            np.asarray(getattr(comp, f)), np.asarray(getattr(full, f)),
            err_msg=f)


def test_ai_query_compact_never_materializes_scores():
    """On the kernel path the lowered HLO must contain no [B, L]- or
    [B, L+1]-shaped tensor: the score table exists only tile-by-tile
    inside the kernel (tile_b < B keeps in-kernel tiles distinguishable,
    as PR 3's visited-mask assert did). ai_query, by contrast, does
    materialize it."""
    import re
    rng = np.random.default_rng(7)
    tree, ait, _ = synth_world(rng, L=1000)
    B = 256
    lo = rng.uniform(-1, 0.9, (B, 2))
    q = jnp.asarray(np.concatenate([lo, lo + 0.05], 1), jnp.float32)

    def lowered(fn):
        return jax.jit(lambda t, qq: fn(t, qq)).lower(tree, q).as_text()

    txt_c = lowered(lambda t, qq: ai_query_compact(
        ait, t, qq, use_kernel=True, tile_b=128))
    txt_d = lowered(lambda t, qq: ai_query(ait, t, qq))
    dense = re.compile(r"<256x100[01]x")
    assert not dense.search(txt_c), "compact path materialized the scores"
    assert dense.search(txt_d), "oracle should materialize the scores"


# ---------------------------------------------------------------------------
# compact_candidates (the engine's sort-free candidate-list compaction)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 12), st.integers(1, 24), st.integers(1, 12),
       st.integers(2, 40), st.integers(0, 2**31 - 1))
def test_compact_candidates_matches_mask_compaction(B, N, k, L, seed):
    """compact_candidates == compact_mask_counted of the scattered mask:
    same slots, validity, and distinct count — without the [B, L] table.
    Duplicate ids across candidates (sibling-cell predictions) dedup."""
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, L, (B, N)), jnp.int32)
    ok = jnp.asarray(rng.uniform(size=(B, N)) < 0.6)
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    mask = jnp.zeros((B, L), jnp.int32).at[rows, ids].max(
        ok.astype(jnp.int32)) > 0
    exp = traversal.compact_mask_counted(mask, k)
    got = traversal.compact_candidates(ids, ok, k)
    for g, e, name in zip(got, exp, ("idx", "valid", "count")):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(e),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# engine: AI slot stage, kernel vs oracle, and the HLO contract
# ---------------------------------------------------------------------------

def _synth_hybrid(rng, L=1000, g=3, Cl=6, pos_bias=0.5):
    """Synthetic HybridTree over a 2-level tree (mlp bank, tiny router)."""
    from repro.data.synth_tree import synth_levels
    mbrs, parents = synth_levels(L, 8, rng, str_pack=True)
    M = 8
    tree = DeviceTree(
        levels=tuple(Level(mbrs=jnp.asarray(m), parent=jnp.asarray(p))
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.asarray(rng.uniform(-1, 1, (L, M, 2)), jnp.float32),
        leaf_entry_ids=jnp.asarray(np.arange(L * M).reshape(L, M), jnp.int32),
        leaf_counts=jnp.full((L,), M, jnp.int32),
        n_points=L * M, max_entries=M)
    bank = synth_bank(rng, g * g, L, Cl=Cl, pos_bias=pos_bias)
    grid = Grid(bbox=jnp.asarray([-1.0, -1.0, 1.0, 1.0], jnp.float32), g=g)
    ait = make_aitree(grid, bank, max_cells=4, max_pred=16)
    router = Router(
        feat_idx=jnp.asarray(rng.integers(0, 6, (4, 3)), jnp.int32),
        thresh=jnp.asarray(rng.uniform(-1, 1, (4, 3)), jnp.float32),
        tables=jnp.asarray(rng.uniform(0, 1, (4, 8, 1)), jnp.float32),
        tau=0.75)
    return HybridTree(tree=tree, ait=ait, router=router)


@pytest.fixture(scope="module")
def trained_world():
    """A small *trained* MLP world — genuine AI-path answers (the random
    banks above always mispredict, so used_ai would never fire)."""
    from repro.core import build, device_tree as dt, labels
    from repro.core.rtree import RTree
    from repro.data import synth
    pts = synth.tweets_like(2500, seed=0)
    tree = RTree(max_entries=32).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 2e-4, 150, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="mlp", grid_sizes=(4,),
                               mlp_hidden=16, mlp_epochs=800)
    return hyb, wl


@pytest.mark.parametrize("use_kernel", [False, True])
def test_trained_ai_query_compact_matches_dense(trained_world, use_kernel):
    """Trained-bank integration: ai_query_compact == ai_query on real
    logits (not just the synthetic banks above), both kernel settings."""
    hyb, wl = trained_world
    q = jnp.asarray(wl.queries[:64])
    comp = ai_query_compact(hyb.ait, hyb.tree, q, use_kernel=use_kernel)
    full = ai_query(hyb.ait, hyb.tree, q, use_kernel=False)
    assert not np.asarray(full.fallback).all(), \
        "fixture must answer some rows on the AI path"
    for f in ("counts", "n_pred", "n_results", "result_ids", "fallback"):
        np.testing.assert_array_equal(
            np.asarray(getattr(comp, f)), np.asarray(getattr(full, f)),
            err_msg=f)


@pytest.mark.parametrize("union", ["topk", "pmax"])
def test_engine_ai_path_kernel_bit_identical(trained_world, union):
    """make_serve_step with the fused prediction kernel (use_kernel=True,
    mlp bank) == the jnp oracle stage, every ServeStats field, in both
    score_union modes — on a trained bank so the AI path genuinely
    answers rows (not fallback-everywhere)."""
    from repro.launch import mesh as pmesh
    hyb, wl = trained_world
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    q = jnp.asarray(wl.queries[:64])
    stats = {}
    for uk in (False, True):
        step = engine.make_serve_step(mesh, engine.EngineConfig(
            max_visited=64, max_pred=16, use_kernel=uk, score_union=union),
            kind="mlp")
        with pmesh.set_mesh(mesh):
            stats[uk] = step(hyb, q)
    assert np.asarray(stats[True].used_ai).any(), \
        "fixture must answer some rows on the AI path"
    for f in stats[False]._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(stats[False], f)),
            np.asarray(getattr(stats[True], f)), err_msg=f)


def test_engine_ai_path_never_materializes_scores():
    """The engine's serve step (topk union, kernel path) lowers without
    any [B, L]- or [B, L+1]-shaped tensor: the AI path's only inter-stage
    format is the compact slot table, and the R path is PR 3's compact
    pipeline. (L is deliberately not lane-aligned so in-kernel [B, L_pad]
    tiles stay distinguishable from a dense [B, L] table.)"""
    import re
    from repro.launch import mesh as pmesh
    rng = np.random.default_rng(9)
    hyb = _synth_hybrid(rng)                  # L = 1000
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    B = 256
    lo = rng.uniform(-1, 0.9, (B, 2))
    q = jnp.asarray(np.concatenate([lo, lo + 0.05], 1), jnp.float32)
    step = engine.make_serve_step(mesh, engine.EngineConfig(
        max_visited=64, max_pred=16, use_kernel=True, score_union="topk"),
        kind="mlp")
    with pmesh.set_mesh(mesh):
        txt = jax.jit(step).lower(hyb, q).as_text()
        step_pmax = engine.make_serve_step(mesh, engine.EngineConfig(
            max_visited=64, max_pred=16, use_kernel=True,
            score_union="pmax"), kind="mlp")
        txt_pmax = jax.jit(step_pmax).lower(hyb, q).as_text()
    dense = re.compile(r"<256x100[01]x")
    assert not dense.search(txt), "engine AI path materialized the scores"
    # positive control: the paper-faithful pmax union still goes dense
    assert dense.search(txt_pmax), "pmax union should materialize scores"
