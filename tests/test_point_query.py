"""Point-query fast path: degenerate rects, single-cell AI routing.

A zero-extent query overlaps exactly one grid cell, so ``point_query``
serves it with ``max_cells=1`` and narrowed traversal bounds, and — with
no wide tier behind it — must be *provably* exact: zero truncated rows,
counts matching brute-force f32 containment, and results identical to
the full-width ``hybrid_query`` on the same rows.
"""
import functools

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import build, device_tree as dt, engine, hybrid, labels, \
    schedule
from repro.core import geometry as geo
from repro.core.rtree import RTree
from repro.data import synth
from repro.launch import mesh as pmesh


@functools.lru_cache(maxsize=None)
def _world():
    pts = synth.tweets_like(3000, seed=0)
    dtree = dt.flatten(RTree(max_entries=16).insert_all(pts))
    qs = synth.synth_queries(pts, 2e-3, 160, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6,))
    return pts, hyb


def _point_queries(pts, rng, n, n_miss=8):
    hit = pts[rng.integers(0, pts.shape[0], n - n_miss)].astype(np.float32)
    miss = rng.uniform(200.0, 300.0, (n_miss, 2)).astype(np.float32)
    p = np.concatenate([hit, miss])
    rng.shuffle(p)
    return np.concatenate([p, p], axis=1)


def _brute_counts(pts, q):
    bf = pts.astype(np.float32)
    return geo.np_contains_point(q[:, None, :],
                                 bf[None, :, :]).sum(axis=1)


# ---------------------------------------------------------------------------
# detection twins
# ---------------------------------------------------------------------------

def test_point_mask_twins_agree():
    rng = np.random.default_rng(0)
    lo = rng.uniform(-1, 1, (40, 2)).astype(np.float32)
    w = rng.uniform(0, 0.2, (40, 2)).astype(np.float32)
    w[rng.uniform(size=40) < 0.5] = 0.0
    q = np.concatenate([lo, lo + w], axis=1)
    host = schedule.point_query_mask(q)
    dev = np.asarray(hybrid.is_point_query(jnp.asarray(q)))
    np.testing.assert_array_equal(host, dev)
    assert host.any() and not host.all()


# ---------------------------------------------------------------------------
# exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_point_query_exact(use_kernel):
    pts, hyb = _world()
    rng = np.random.default_rng(1)
    q = _point_queries(pts, rng, 64)
    res = hybrid.point_query(hyb, jnp.asarray(q), use_kernel=use_kernel)
    assert not np.asarray(res.truncated).any(), \
        "point path truncated — narrowed bounds failed to cover"
    exp = _brute_counts(pts, q)
    np.testing.assert_array_equal(np.asarray(res.n_results), exp)
    assert (exp > 0).sum() >= 48 and (exp == 0).any(), "weak fixture"
    # single-cell routing: a degenerate rect can never overflow the
    # max_cells=1 window, so the anchor cell is always resolved
    assert (np.asarray(res.cell_id) >= 0).all()


def test_point_query_matches_full_width_hybrid():
    """The narrowed bounds change cost, not answers: n_results and
    result id sets equal hybrid_query at full width."""
    pts, hyb = _world()
    rng = np.random.default_rng(2)
    q = jnp.asarray(_point_queries(pts, rng, 48))
    a = hybrid.point_query(hyb, q)
    b = hybrid.hybrid_query(hyb, q, max_visited=256, max_results=512)
    np.testing.assert_array_equal(np.asarray(a.n_results),
                                  np.asarray(b.n_results))
    ida, idb = np.asarray(a.result_ids), np.asarray(b.result_ids)
    for j in range(ida.shape[0]):
        assert (set(ida[j][ida[j] >= 0].tolist())
                == set(idb[j][idb[j] >= 0].tolist())), j
    # and it really is cheaper per row on the R-path cost unit
    assert (np.asarray(a.leaf_accesses)
            <= np.asarray(b.leaf_accesses)).all()


def test_point_query_through_scheduler():
    """Full scheduler pass, no wide tier: sorted ≡ unsorted and zero
    truncation (the driver's assert, exercised here)."""
    pts, hyb = _world()
    rng = np.random.default_rng(3)
    q = _point_queries(pts, rng, 53)
    fn = jax.jit(lambda qq: hybrid.point_query(hyb, qq))
    base = schedule.serve_workload(fn, q, batch=16, sort="none")
    srt = schedule.serve_workload(fn, q, batch=16, sort="hilbert")
    for f in type(base.stats)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(base.stats, f)),
            np.asarray(getattr(srt.stats, f)), err_msg=f)
    assert not np.asarray(srt.stats.truncated).any()
    np.testing.assert_array_equal(np.asarray(srt.stats.n_results),
                                  _brute_counts(pts, q))


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_point_config_narrows():
    cfg = engine.EngineConfig(max_visited=64, max_cells=4)
    pc = engine.point_config(cfg)
    assert pc.max_cells == 1 and pc.max_visited == 32
    # an already-narrow config is not widened
    assert engine.point_config(engine.EngineConfig(max_visited=8)) \
        .max_visited == 8


def test_engine_point_serve_step_exact():
    pts, hyb = _world()
    rng = np.random.default_rng(4)
    q = jnp.asarray(_point_queries(pts, rng, 64))
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    cfg = engine.EngineConfig(max_visited=64, max_pred=16)
    step = engine.make_point_serve_step(mesh, cfg, kind="knn")
    with pmesh.set_mesh(mesh):
        out = step(hyb, q)
    assert not np.asarray(out.r_truncated).any()
    np.testing.assert_array_equal(np.asarray(out.n_results),
                                  _brute_counts(pts, np.asarray(q)))
    assert (np.asarray(out.cell_id) >= 0).all()
