"""core.telemetry primitives + the FreshnessMonitor re-point.

The telemetry module is load-bearing twice over: the runtime's dispatch
rule trusts the EWMA, its latency report trusts the reservoir, and the
maintenance policy's signals flow through SegmentWindow — which must
behave exactly like the rolling-window code it replaced in
FreshnessMonitor.
"""
import numpy as np
import pytest

from repro.core import telemetry


# ---------------------------------------------------------------------------
# Ewma
# ---------------------------------------------------------------------------

def test_ewma_default_until_first_observation():
    e = telemetry.Ewma(0.5, default=7.0)
    assert e.value == 7.0
    e.update(1.0)
    assert e.value == 1.0       # bias correction: first sample is exact


def test_ewma_constant_stream_is_exact():
    e = telemetry.Ewma(0.1)
    for _ in range(50):
        e.update(3.25)
    assert e.value == pytest.approx(3.25)


def test_ewma_tracks_shift():
    e = telemetry.Ewma(0.5)
    for _ in range(20):
        e.update(1.0)
    for _ in range(20):
        e.update(9.0)
    assert abs(e.value - 9.0) < 0.01


def test_ewma_rejects_bad_alpha():
    with pytest.raises(ValueError):
        telemetry.Ewma(0.0)
    with pytest.raises(ValueError):
        telemetry.Ewma(1.5)


# ---------------------------------------------------------------------------
# QuantileReservoir
# ---------------------------------------------------------------------------

def test_reservoir_exact_until_full():
    r = telemetry.QuantileReservoir(size=100, seed=0)
    xs = np.arange(50, dtype=np.float64)
    r.extend(xs)
    assert len(r) == 50 and r.n == 50
    assert r.quantile(0.5) == np.quantile(xs, 0.5)
    s = r.summary()
    assert s["p99"] == np.quantile(xs, 0.99)
    assert s["max"] == 49.0


def test_reservoir_empty_is_nan():
    r = telemetry.QuantileReservoir(size=8)
    assert np.isnan(r.quantile(0.5))
    assert r.summary()["n"] == 0


def test_reservoir_bounded_memory_unbiased_enough():
    # 20k-long stream through a 2k reservoir: quantiles of U[0,1] land
    # within a few percent of truth (deterministic under the seed)
    r = telemetry.QuantileReservoir(size=2048, seed=3)
    xs = np.random.default_rng(0).uniform(size=20_000)
    r.extend(xs)
    assert len(r) == 2048 and r.n == 20_000
    assert abs(r.quantile(0.5) - 0.5) < 0.05
    assert abs(r.quantile(0.95) - 0.95) < 0.03


def test_reservoir_deterministic_under_seed():
    a = telemetry.QuantileReservoir(size=64, seed=9)
    b = telemetry.QuantileReservoir(size=64, seed=9)
    xs = np.random.default_rng(1).normal(size=1000)
    a.extend(xs)
    b.extend(xs)
    assert a.quantile(0.9) == b.quantile(0.9)


# ---------------------------------------------------------------------------
# SegmentWindow
# ---------------------------------------------------------------------------

def test_segment_window_rates_and_counts():
    w = telemetry.SegmentWindow(4, ("n", "hit"), window=8)
    # two segments: key 0 sees 4 rows with 2 hits, then 2 rows 2 hits
    w.add(np.array([0, 0, 0, 0]), {"hit": np.array([1, 1, 0, 0])})
    w.roll()
    w.add(np.array([0, 0, 3]), {"hit": np.array([1, 1, 0])})
    w.roll()
    r = w.rate("hit")
    assert r[0] == pytest.approx(np.median([0.5, 1.0]))
    assert r[3] == 0.0          # saw traffic in one segment, zero hits
    assert r[1] == 0.0          # all-quiet key never votes
    np.testing.assert_array_equal(w.count_median(),
                                  np.median([[4, 0, 0, 0], [2, 0, 0, 1]],
                                            axis=0))


def test_segment_window_bounded():
    w = telemetry.SegmentWindow(1, ("n", "x"), window=2)
    for v in (0, 0, 1, 1, 1):
        w.add(np.array([0]), {"x": np.array([v])})
        w.roll()
    assert len(w) == 2
    assert w.rate("x")[0] == 1.0    # the zero segments rolled out


def test_segment_window_clear_resizes():
    w = telemetry.SegmentWindow(2, ("n", "x"))
    w.add(np.array([0]), {"x": np.array([1])})
    w.roll()
    w.clear(n_keys=5)
    assert len(w) == 0
    assert w.rate("x").shape == (5,)


def test_segment_window_rejects_unknown_and_count_field():
    w = telemetry.SegmentWindow(2, ("n", "x"))
    with pytest.raises(ValueError):
        w.rate("n")
    with pytest.raises(ValueError):
        w.rate("nope")
    with pytest.raises(ValueError):
        w.add(np.array([0]), {"n": np.array([1])})


# ---------------------------------------------------------------------------
# FreshnessMonitor re-point: behavior identical to the inline window
# ---------------------------------------------------------------------------

def _monitor(C=9, window=3):
    from repro.core.grid import Grid
    from repro.core.monitor import FreshnessMonitor
    import jax.numpy as jnp
    g = int(np.sqrt(C))
    grid = Grid(bbox=jnp.asarray([0.0, 0.0, 1.0, 1.0], jnp.float32), g=g)
    return FreshnessMonitor(grid, np.ones((C,), bool), window=window)


class _Stats:
    def __init__(self, cell_id, **kw):
        self.cell_id = np.asarray(cell_id)
        for f in ("guarded", "mispredict", "used_ai", "delta_hits"):
            setattr(self, f, np.asarray(
                kw.get(f, np.zeros_like(self.cell_id))))


def test_monitor_rolling_matches_reference_median():
    m = _monitor()
    rng = np.random.default_rng(0)
    ref_segments = []
    for _ in range(5):      # window=3: the first two segments roll out
        cid = rng.integers(-1, 9, size=32)
        mis = rng.integers(0, 2, size=32)
        m.note_serve(_Stats(cid, mispredict=mis))
        keep = cid >= 0
        n = np.zeros(9); v = np.zeros(9)
        np.add.at(n, cid[keep], 1)
        np.add.at(v, cid[keep], mis[keep])
        ref_segments.append((n, v))
        m.roll_segment()
    n = np.stack([s[0] for s in ref_segments[-3:]])
    v = np.stack([s[1] for s in ref_segments[-3:]])
    rates = np.where(n > 0, v / np.maximum(n, 1), np.nan)
    exp = np.zeros(9)
    voters = (n > 0).any(axis=0)
    exp[voters] = np.nanmedian(rates[:, voters], axis=0)
    np.testing.assert_allclose(m.rolling("mispredict"), exp)
    np.testing.assert_allclose(m.traffic(), np.median(n, axis=0))


def test_monitor_rolling_empty_window_zero():
    m = _monitor()
    assert m.rolling("mispredict").sum() == 0
    assert m.traffic().sum() == 0
    with pytest.raises(ValueError):
        m.rolling("n")
