"""Launch-layer tests: sharding rules, input specs, shape policies."""
from types import SimpleNamespace

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import sharding as shd
from repro.launch.specs import (ACCUM, SHAPE_DEFS, cell_supported,
                                decode_specs, input_specs)


FAKE_MESH = SimpleNamespace(axis_names=("data", "model"),
                            devices=np.zeros((16, 16)))


def spec(path, shape):
    return shd.spec_for_leaf(path, shape, FAKE_MESH)


def test_attention_rules():
    assert spec("params/layers/attn/wq", (80, 8192, 8192)) == \
        P(None, "data", "model")
    assert spec("opt/m/layers/attn/wo", (80, 8192, 8192)) == \
        P(None, "model", "data")
    assert spec("params/layers/attn/bq", (80, 8192)) == P(None, "model")


def test_embed_rules_match_under_prefixes():
    assert spec("params/embed", (152064, 8192)) == P("model", "data")
    assert spec("opt/v/embed", (152064, 8192)) == P("model", "data")
    assert spec("params/lm_head", (8192, 152064)) == P("data", "model")


def test_moe_expert_parallel_rules():
    assert spec("params/layers/moe/wi", (27, 64, 2048, 1408)) == \
        P(None, "model", "data", None)
    assert spec("params/layers/moe/wo", (27, 64, 1408, 2048)) == \
        P(None, "model", None, "data")


def test_divisibility_fallback_replicates():
    # 25 heads × 64 = 1600 divides 16; but a hypothetical odd dim must not
    assert spec("params/layers/attn/wq", (32, 1600, 1600)) == \
        P(None, "data", "model")
    assert spec("params/layers/attn/wq", (32, 1602, 1602)) == P(None, None,
                                                                None)


def test_norms_replicated():
    assert spec("params/layers/norm1", (80, 8192)) == P()
    assert spec("params/final_norm", (8192,)) == P()


def test_every_arch_majority_bytes_sharded():
    """For every arch, ≥95% of parameter bytes must shard over the mesh."""
    from repro.models import transformer as tf
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        params = jax.eval_shape(
            lambda c=cfg: tf.init_params(c, jax.random.PRNGKey(0),
                                         dtype=jnp.bfloat16))
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        total = repl = 0
        for path, leaf in flat:
            p = shd.spec_for_leaf(shd._path_str(path), leaf.shape, FAKE_MESH)
            nbytes = leaf.size * leaf.dtype.itemsize
            total += nbytes
            if all(ax is None for ax in (tuple(p) or (None,))):
                repl += nbytes
        assert repl / total < 0.05, (arch, repl / total)


def test_long_500k_policy():
    allowed = {"rwkv6-3b", "hymba-1.5b", "h2o-danube-3-4b"}
    for arch in configs.ARCHS:
        cfg = configs.get_config(arch)
        ok, why = cell_supported(cfg, "long_500k")
        assert ok == (cfg.name in allowed), (arch, why)


def test_input_specs_shapes():
    cfg = configs.get_config("qwen2-72b")
    s = input_specs(cfg, "train_4k")
    assert s["tokens"].shape == (256, 4096)
    assert s["labels"].shape == (256, 4096)
    s = input_specs(cfg, "prefill_32k")
    assert s["tokens"].shape == (32, 32768) and "labels" not in s
    # vision stub: embeds instead of tokens
    v = input_specs(configs.get_config("qwen2-vl-72b"), "train_4k")
    assert v["embeds"].shape == (256, 4096, 8192)
    # audio stub: frames present
    w = input_specs(configs.get_config("whisper-small"), "train_4k")
    assert w["frames"].shape == (256, 1500, 768)


def test_decode_specs_cache_scales():
    cfg = configs.get_config("deepseek-v2-236b")
    tok, cache = decode_specs(cfg, "decode_32k")
    assert tok["tokens"].shape == (128, 1)
    # MLA latent cache: [L, B, S, kv_lora]
    assert cache["ckv"].shape == (60, 128, 32768, 512)
    # rwkv long context: O(1) state, no [S] dim anywhere
    cfg2 = configs.get_config("rwkv6-3b")
    _, cache2 = decode_specs(cfg2, "long_500k")
    assert all(524288 not in leaf.shape
               for leaf in jax.tree.leaves(cache2)
               if hasattr(leaf, "shape"))


def test_accum_divides_batch():
    for arch, accum in ACCUM.items():
        assert SHAPE_DEFS["train_4k"]["global_batch"] % accum == 0
