"""Jit'd public wrappers around the Pallas kernels.

Responsibilities:
  * pad irregular shapes up to kernel tile multiples and slice results back;
  * transpose rectangles to the planar [4, N] kernel layout;
  * dispatch to interpret mode off-TPU (this container is CPU-only — the
    kernels are *targeted* at TPU and *validated* via interpret mode);
  * fall back to the jnp oracle when ``REPRO_KERNELS=off`` (escape hatch).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels import mbr_intersect as _mbr
from repro.kernels import leaf_refine as _refine
from repro.kernels import forest_infer as _forest
from repro.kernels import traverse_fused as _traverse
from repro.kernels import mlp_infer as _mlp
from repro.kernels import delta_probe as _delta
from repro.kernels import knn_browse as _knn
from repro.kernels import spatial_key as _skey
from repro.kernels import wkv6 as _wkv6


def kernels_enabled() -> bool:
    return os.environ.get("REPRO_KERNELS", "on").lower() not in ("off", "0")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def mbr_intersect(queries: jnp.ndarray, mbrs: jnp.ndarray,
                  tb: int | None = None, tn: int | None = None) -> jnp.ndarray:
    """[B, 4] × [N, 4] → [B, N] bool."""
    if not kernels_enabled():
        return ref.mbr_intersect(queries, mbrs)
    B, N = queries.shape[0], mbrs.shape[0]
    tb = tb or min(_mbr.DEF_TB, max(8, B))
    tn = tn or _mbr.DEF_TN
    # pad with rectangles that can never intersect (inverted infinite rects)
    qp = _pad_to(queries.astype(jnp.float32), 0, tb, 0.0)
    never = jnp.asarray([jnp.inf, jnp.inf, -jnp.inf, -jnp.inf], jnp.float32)
    mp = _pad_to(mbrs.astype(jnp.float32), 0, tn, 0.0)
    if mp.shape[0] != N:
        mp = mp.at[N:].set(never)
    out = _mbr.mbr_intersect_t(qp.T, mp.T, tb=tb, tn=tn,
                               interpret=_interpret())
    return out[:B, :N]


_NEVER_RECT = (float("inf"), float("inf"), float("-inf"), float("-inf"))


def _fused_tiles(B: int, L: int, tb: int | None, tl: int | None,
                 n_levels: int | None = None
                 ) -> tuple[int, int, bool, dict]:
    """Tile choice shared by the fused traversal entry points.

    Resolution order per knob: explicit caller override → autotune cache
    entry for this exact (form, B, L, height) shape (see
    ``traverse_fused.tuned_tiles`` / ``benchmarks/autotune.py``) →
    hand-picked default. The defaults: on TPU, DEF_TB×DEF_TL VMEM tiles
    (grid cells are nearly free and pl.when early exit works per tile); in
    interpret mode fold everything into one tile per query-block —
    emulated grid cells are not free, the walk would rerun per leaf tile,
    and the interpret form early-exits on SUB_TL subtiles *inside* the
    kernel instead. Also returns the cache entry so callers can thread the
    epilogue knobs (``sub_tl``, ``kc``) through to the kernel.
    """
    interp = _interpret()
    tune = _traverse.tuned_tiles(B, L, n_levels, interp) \
        if n_levels is not None else {}
    L128 = (max(128, L) + 127) // 128 * 128
    if tb is None:
        tb = tune.get("tb") or min(1024 if interp else _traverse.DEF_TB,
                                   (max(8, B) + 7) // 8 * 8)
    if tl is None:
        tl = tune.get("tl") or (
            L128 if interp and L128 <= 8192 else
            min(_traverse.DEF_TL, L128))
    return tb, tl, interp, tune


def _fused_operands(queries: jnp.ndarray, level_mbrs, level_parents,
                    tb: int, tl: int):
    """Pad + transpose tree levels to the planar kernel layout."""
    never = jnp.asarray(_NEVER_RECT, jnp.float32)

    def pad_level(mbrs, parent, mult):
        n = mbrs.shape[0]
        mp = _pad_to(mbrs.astype(jnp.float32), 0, mult, 0.0)
        if mp.shape[0] != n:
            mp = mp.at[n:].set(never)
        pp = _pad_to(parent.astype(jnp.int32), 0, mult, 0)
        return mp.T, pp[None, :]

    qp = _pad_to(queries.astype(jnp.float32), 0, tb, 0.0)
    int_mbrs_t, int_parents = [], []
    for lvl in range(len(level_mbrs) - 1):
        mt, pt = pad_level(level_mbrs[lvl], level_parents[lvl],
                           _traverse.LANE)
        int_mbrs_t.append(mt)
        if lvl > 0:
            int_parents.append(pt)
    leaf_mt, leaf_pt = pad_level(level_mbrs[-1], level_parents[-1], tl)
    return qp, tuple(int_mbrs_t), tuple(int_parents), leaf_mt, leaf_pt


def _per_level_kernel_mask(queries: jnp.ndarray, level_mbrs,
                           level_parents) -> jnp.ndarray:
    """Kernel-accelerated per-level fallback (frontier masks round-trip
    HBM, but each level's intersection still runs on the kernel)."""
    mask = mbr_intersect(queries, level_mbrs[0])
    for mbrs, parent in zip(level_mbrs[1:], level_parents[1:]):
        mask = mask[:, parent] & mbr_intersect(queries, mbrs)
    return mask


def _slices_usable(sl, n_levels: int, L: int) -> bool:
    """Does this AncestorTable match the tree shape being dispatched?

    A table built for a different padding/sharding of the same logical tree
    (wrong tile count / level count) must be rejected, not trusted."""
    if sl is None:
        return False
    try:
        st = sl.starts
        return (getattr(st, "ndim", 0) == 2
                and st.shape[0] == n_levels - 1
                and len(sl.widths) == n_levels - 1
                and st.shape[1] == -(-L // sl.tl))
    except (AttributeError, TypeError):
        return False


def _build_slices_if_concrete(level_parents, B: int, L: int,
                              n_levels: int, interp: bool):
    """Build an ancestor table on the fly for callers that passed raw
    level arrays (no ``DeviceTree``) — only possible outside a trace,
    where the parent arrays are concrete."""
    if any(isinstance(p, jax.core.Tracer) for p in level_parents):
        return None
    tune = _traverse.tuned_tiles_for_key(
        _traverse.tune_key_sliced(B, L, n_levels, interp))
    from repro.core.device_tree import build_ancestor_table
    return build_ancestor_table(level_parents,
                                tl=tune.get("tl") or _traverse.DEF_TL)


def _sliced_operands(queries: jnp.ndarray, level_mbrs, level_parents,
                     sl, tb: int):
    """Pad + transpose for the sliced kernels: each internal level to a
    multiple of its window width (BlockSpec windows must tile the padded
    axis), the leaf level to the table's tile granularity. Pad lanes carry
    never-intersecting rects, so whatever window they land in they stay
    dead; the leaf parent pad repeats the last real parent so pad lanes
    index in-window (dead via their never-rects, not via wraparound)."""
    never = jnp.asarray(_NEVER_RECT, jnp.float32)

    def pad_level(mbrs, parent, mult, pfill):
        n = mbrs.shape[0]
        mp = _pad_to(mbrs.astype(jnp.float32), 0, mult, 0.0)
        if mp.shape[0] != n:
            mp = mp.at[n:].set(never)
        pp = parent.astype(jnp.int32)
        pad = (-n) % mult
        if pad:
            pp = jnp.concatenate(
                [pp, jnp.full((pad,), pfill, jnp.int32)])
        return mp.T, pp[None, :]

    qp = _pad_to(queries.astype(jnp.float32), 0, tb, 0.0)
    int_mbrs_t, int_parents = [], []
    for lvl in range(len(level_mbrs) - 1):
        mt, pt = pad_level(level_mbrs[lvl], level_parents[lvl],
                           sl.widths[lvl], 0)
        int_mbrs_t.append(mt)
        if lvl > 0:
            int_parents.append(pt)
    leaf_mt, leaf_pt = pad_level(level_mbrs[-1], level_parents[-1], sl.tl,
                                 level_parents[-1][-1])
    return qp, tuple(int_mbrs_t), tuple(int_parents), leaf_mt, leaf_pt


def _sliced_call(queries: jnp.ndarray, level_mbrs, level_parents, sl,
                 tb: int, interp: bool, *, k: int | None = None):
    """Dispatch to the ancestor-sliced kernel form; ``None`` when the
    table is unusable or even the sliced working set exceeds the budget
    (degenerate tables whose windows capped out at full level width).

    ``k=None`` → dense mask [Bp, Lp]; else → ``(idx [Bp, KP], cnt
    [Bp, 1])`` with ``traverse_compact_t``'s slot contract.
    """
    if sl is None:
        return None
    n_levels = len(level_mbrs)
    B = queries.shape[0]
    L = level_mbrs[-1].shape[0]
    stune = _traverse.tuned_tiles_for_key(
        _traverse.tune_key_sliced(B, L, n_levels, interp))
    tb = stune.get("tb") or tb
    sub_tl = stune.get("sub_tl", _traverse.SUB_TL)
    kc = stune.get("kc", _traverse.COMPACT_KC)
    if k is None:
        est = _traverse.vmem_estimate_sliced(sl.widths, tb, sl.tl,
                                             tpu_form=not interp)
    else:
        kp = k if interp else \
            (k + _traverse.LANE - 1) // _traverse.LANE * _traverse.LANE
        est = _traverse.vmem_estimate_sliced_compact(
            sl.widths, tb, sl.tl, kp, tpu_form=not interp, kc=kc)
    if est > _traverse.VMEM_BUDGET:
        return None
    qp, int_mbrs_t, int_parents, leaf_mt, leaf_pt = _sliced_operands(
        queries, level_mbrs, level_parents, sl, tb)
    if k is None:
        return _traverse.traverse_fused_sliced_t(
            sl.starts, qp.T, int_mbrs_t, int_parents, leaf_mt, leaf_pt,
            widths=sl.widths, tb=tb, tl=sl.tl, sub_tl=sub_tl,
            interpret=interp)
    return _traverse.traverse_compact_sliced_t(
        sl.starts, qp.T, int_mbrs_t, int_parents, leaf_mt, leaf_pt,
        k=k, widths=sl.widths, tb=tb, tl=sl.tl, sub_tl=sub_tl, kc=kc,
        interpret=interp)


def traverse_fused(queries: jnp.ndarray, level_mbrs, level_parents,
                   tb: int | None = None, tl: int | None = None,
                   slices=None) -> jnp.ndarray:
    """Fused root→leaf traversal: [B, 4] → visited-leaf mask [B, L] bool.

    ``level_mbrs``: one [N_l, 4] array per tree level, root first, leaf
    level last. ``level_parents``: matching [N_l] i32 index into the level
    above (entry 0 unused). Single ``pallas_call`` — the internal frontier
    stays in VMEM; only the leaf mask is written to HBM. ``slices`` is the
    tree's ``AncestorTable`` (``DeviceTree.aslices``), if the caller has
    one.

    Falls back to the jnp oracle when kernels are off; when the tree is a
    single level (root == leaves) it is one ``mbr_intersect``. When the
    estimated full-replication VMEM working set (frontier scratch +
    replicated internal operands + largest one-hot expansion) exceeds the
    budget, the **ancestor-sliced** form takes over — same fused walk, but
    each leaf tile stages only its scalar-prefetched ancestor windows, so
    the working set no longer grows with the tree (the table comes from
    ``slices``, or is built on the fly when the parent arrays are
    concrete). Only when even that is impossible (tracing without a table,
    or a degenerate table whose windows capped out at full level width)
    does it run the level-by-level loop with the ``mbr_intersect``
    *kernel* per level — never a silent drop to pure jnp.
    """
    n_levels = len(level_mbrs)
    B = queries.shape[0]
    L = level_mbrs[-1].shape[0]
    if not kernels_enabled():
        return ref.traverse_fused(queries, level_mbrs, level_parents)
    if n_levels == 1:
        return mbr_intersect(queries, level_mbrs[0])

    tb, tl, interp, tune = _fused_tiles(B, L, tb, tl, n_levels)
    sub_tl = tune.get("sub_tl", _traverse.SUB_TL)
    widths = [int(m.shape[0]) for m in level_mbrs[:-1]]
    padded = [n + (-n) % _traverse.LANE for n in widths]
    if _traverse.vmem_estimate(padded, tb, tl) > _traverse.VMEM_BUDGET:
        sl = slices if _slices_usable(slices, n_levels, L) else \
            _build_slices_if_concrete(level_parents, B, L, n_levels,
                                      interp)
        out = _sliced_call(queries, level_mbrs, level_parents, sl, tb,
                           interp)
        if out is not None:
            return out[:B, :L]
        return _per_level_kernel_mask(queries, level_mbrs, level_parents)
    qp, int_mbrs_t, int_parents, leaf_mt, leaf_pt = _fused_operands(
        queries, level_mbrs, level_parents, tb, tl)
    out = _traverse.traverse_fused_t(
        qp.T, int_mbrs_t, int_parents, leaf_mt, leaf_pt,
        tb=tb, tl=tl, sub_tl=sub_tl, interpret=interp)
    return out[:B, :L]


def traverse_compact(queries: jnp.ndarray, level_mbrs, level_parents,
                     k: int, tb: int | None = None, tl: int | None = None,
                     slices=None
                     ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused traversal + compaction: [B, 4] → ``(leaf_idx [B, k] i32,
    valid [B, k] bool, count [B] i32)``.

    Semantically ``compact_mask(traverse_fused(...), k)`` plus the per-row
    visited count, but on the kernel path the ``[B, L]`` visited mask never
    leaves VMEM: the traversal kernel's compaction epilogue ranks set
    leaves by exclusive prefix count per leaf tile and scatters the first
    ``k`` leaf ids (leaf-ID order) straight into the ``[B, K]`` slot table.
    This is the serving-path entry point — training/labels keep the dense
    ``traverse_fused`` mask.

    The fallback ladder mirrors ``traverse_fused`` (jnp oracle when kernels
    are off, one ``mbr_intersect`` for single-level trees, the
    ancestor-sliced kernel when over the full-replication VMEM budget, the
    per-level kernel loop only as last resort); the dense-mask fallbacks
    compact with the jnp ``compact_mask`` scheme, so every path is
    bit-identical.
    """
    from repro.core.traversal import compact_mask_counted

    n_levels = len(level_mbrs)
    B = queries.shape[0]
    if not kernels_enabled():
        return compact_mask_counted(
            ref.traverse_fused(queries, level_mbrs, level_parents), k)
    if n_levels == 1:
        return compact_mask_counted(
            mbr_intersect(queries, level_mbrs[0]), k)

    L = level_mbrs[-1].shape[0]
    tb, tl, interp, tune = _fused_tiles(B, L, tb, tl, n_levels)
    sub_tl = tune.get("sub_tl", _traverse.SUB_TL)
    kc = tune.get("kc", _traverse.COMPACT_KC)
    kp = k if interp else \
        (k + _traverse.LANE - 1) // _traverse.LANE * _traverse.LANE
    widths = [int(m.shape[0]) for m in level_mbrs[:-1]]
    padded = [n + (-n) % _traverse.LANE for n in widths]
    if _traverse.vmem_estimate_compact(padded, tb, tl, kp,
                                       tpu_form=not interp, kc=kc) > \
            _traverse.VMEM_BUDGET:
        sl = slices if _slices_usable(slices, n_levels, L) else \
            _build_slices_if_concrete(level_parents, B, L, n_levels,
                                      interp)
        out = _sliced_call(queries, level_mbrs, level_parents, sl, tb,
                           interp, k=k)
        if out is not None:
            idx, cnt = out
            count = cnt[:B, 0]
            valid = jnp.arange(k, dtype=jnp.int32)[None, :] < \
                count[:, None]
            return jnp.where(valid, idx[:B, :k], 0), valid, count
        return compact_mask_counted(
            _per_level_kernel_mask(queries, level_mbrs, level_parents), k)
    qp, int_mbrs_t, int_parents, leaf_mt, leaf_pt = _fused_operands(
        queries, level_mbrs, level_parents, tb, tl)
    idx, cnt = _traverse.traverse_compact_t(
        qp.T, int_mbrs_t, int_parents, leaf_mt, leaf_pt,
        k=k, tb=tb, tl=tl, sub_tl=sub_tl, kc=kc, interpret=interp)
    count = cnt[:B, 0]
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < count[:, None]
    return jnp.where(valid, idx[:B, :k], 0), valid, count


def _mlp_tiles(B: int, n_leaves: int, C: int, Cl: int, interp: bool,
               tb: int | None = None, tl: int | None = None
               ) -> tuple[int, int, int, int]:
    """Tile resolution for the fused prediction kernel: explicit caller
    override → autotune cache entry (``mlp-`` form keys) → hand-picked
    default. Returns ``(tb, tl, kc, Lp)`` with ``Lp`` the lane-padded
    leaf count (the kernel's scatter axis)."""
    tune = _mlp.tuned_tiles_mlp(B, n_leaves, C, Cl, interp)
    Lp = (max(128, n_leaves) + 127) // 128 * 128
    if tb is None:
        tb = tune.get("tb") or min(1024 if interp else _mlp.DEF_TB,
                                   (max(8, B) + 7) // 8 * 8)
    if tl is None:
        # interpret folds the whole (lane-padded) leaf axis into one tile —
        # emulated grid cells are not free and the walk has no scratch there
        tl = tune.get("tl") or (Lp if interp else min(_mlp.DEF_TL, Lp))
    kc = tune.get("kc", _traverse.COMPACT_KC)
    return tb, tl, kc, Lp


def _mlp_gate(B: int, bank, S: int, n_leaves: int, k: int,
              tb: int | None = None, tl: int | None = None,
              n_cells: int | None = None) -> bool:
    """True iff the resolved fused-kernel form fits the VMEM budget.

    The estimate uses the *lane-padded* cell count — the kernel's
    replicated bank operands are padded to the LANE quantum, and the pad
    rows cost VMEM like any others (the sibling ``traverse_compact`` gate
    pads its level widths for the same reason). ``n_cells`` overrides the
    bank's cell count for callers asking about a *shard* of the bank."""
    C, F, H = bank.w1.shape
    C = n_cells or C
    Cl = bank.w2.shape[-1]
    interp = _interpret()
    tb, tl, kc, _ = _mlp_tiles(B, n_leaves, C, Cl, interp, tb, tl)
    kp = k if interp else \
        (k + _traverse.LANE - 1) // _traverse.LANE * _traverse.LANE
    Cp = C + (-C) % _traverse.LANE
    return _mlp.vmem_estimate_mlp(Cp, F, H, Cl, S, tb, tl, kp,
                                  tpu_form=not interp, kc=kc) \
        <= _traverse.VMEM_BUDGET


def mlp_fused_active(B: int, bank, S: int, n_leaves: int, k: int,
                     n_cells: int | None = None) -> bool:
    """Would ``mlp_predict_compact`` take the fused kernel path for this
    shape? (False when kernels are off or the VMEM gate routes to the
    dense oracle — callers reporting 'score table eliminated' must check
    the actual dispatch, not just their own flags.) Pass the *per-shard*
    ``B``/``n_cells``/``n_leaves`` when asking about the sharded engine —
    its dispatch sees shard-local shapes."""
    return kernels_enabled() and _mlp_gate(B, bank, S, n_leaves, k,
                                           n_cells=n_cells)


def mlp_predict_compact(queries: jnp.ndarray, bank, cell_ids: jnp.ndarray,
                        slot_ok: jnp.ndarray, *, n_leaves: int, k: int,
                        threshold: float, tb: int | None = None,
                        tl: int | None = None
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused AI-path prediction: queries [B, 4] + cell routing → compact
    predicted-leaf slots ``(leaf_idx [B, k] i32, valid [B, k] bool,
    count [B] i32)``.

    Semantically ``compact_mask_counted(predict_scores(...) > threshold,
    k)``, but on the kernel path the ``[B, n_leaves]`` score table never
    leaves VMEM: classifier inference, sigmoid+threshold, the
    ``label_map`` scatter/max-union and the cumsum-rank compaction all run
    inside one ``pallas_call`` (``kernels.mlp_infer``). ``bank`` is an
    ``MLPBank``-shaped object (``w1/b1/w2/b2/mu/sd/label_map/lmask`` —
    duck-typed so this module stays core-free); ``cell_ids``/``slot_ok``
    [B, S] come from ``grid.cells_of_queries``. Requires ``threshold ≥ 0``
    (see ``mlp_infer`` module docs).

    Fallback ladder mirrors ``traverse_compact``: the jnp dense oracle
    when kernels are off **or** when the form-aware VMEM estimate (bank
    operands + staging transients + epilogue transient) exceeds the
    budget — never a silent wrong answer, the fallbacks are bit-identical.
    Tile knobs resolve explicit override → autotune cache entry for this
    (form, B, L, C, Cl) shape → hand-picked default.
    """
    assert threshold >= 0, "dense-oracle parity requires threshold >= 0"
    B = queries.shape[0]
    S = cell_ids.shape[1]
    C, F, H = bank.w1.shape
    Cl = bank.w2.shape[-1]
    x = (queries.astype(jnp.float32) - bank.mu) / bank.sd
    cid = jnp.clip(cell_ids.astype(jnp.int32), 0, C - 1)

    def dense():
        return ref.mlp_predict_compact(
            x, cid, slot_ok, bank.w1, bank.b1, bank.w2, bank.b2,
            bank.label_map, bank.lmask, n_leaves=n_leaves, k=k,
            threshold=threshold)

    if not kernels_enabled() or not _mlp_gate(B, bank, S, n_leaves, k,
                                              tb, tl):
        return dense()
    interp = _interpret()
    tb, tl, kc, Lp = _mlp_tiles(B, n_leaves, C, Cl, interp, tb, tl)
    xp = _pad_to(x, 0, tb, 0.0)
    cidp = _pad_to(cid, 0, tb, 0)
    okp = _pad_to(slot_ok.astype(jnp.int32), 0, tb, 0)
    Cp = (-C) % _traverse.LANE
    w1f = bank.w1.reshape(C, F * H)
    w2f = bank.w2.reshape(C, H * Cl)
    b1a, b2a = bank.b1, bank.b2
    lm = bank.label_map.astype(jnp.float32)
    lmk = bank.lmask.astype(jnp.float32)
    if Cp:
        w1f = _pad_to(w1f, 0, _traverse.LANE, 0.0)
        w2f = _pad_to(w2f, 0, _traverse.LANE, 0.0)
        b1a = _pad_to(b1a, 0, _traverse.LANE, 0.0)
        b2a = _pad_to(b2a, 0, _traverse.LANE, 0.0)
        lm = _pad_to(lm, 0, _traverse.LANE, -1.0)
        lmk = _pad_to(lmk, 0, _traverse.LANE, 0.0)
    lpt = Lp + (-Lp) % tl
    idx, cnt = _mlp.mlp_predict_compact_t(
        xp, cidp, okp, w1f, b1a, w2f, b2a, lm, lmk, k=k, lp=lpt,
        thr=float(threshold), tb=tb, tl=tl, kc=kc, interpret=interp)
    count = cnt[:B, 0]
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < count[:, None]
    return jnp.where(valid, idx[:B, :k], 0), valid, count


def _delta_tiles(B: int, cap: int, interp: bool, tb: int | None = None,
                 tn: int | None = None) -> tuple[int, int, int]:
    """Tile resolution for the delta-probe kernel: explicit caller
    override → autotune cache entry (``delta-`` form keys) → hand-picked
    default. Interpret mode folds the whole (lane-padded) buffer into one
    tile, like the other kernels' leaf-axis folds."""
    tune = _delta.tuned_tiles_delta(B, cap, interp)
    Np = (max(128, cap) + 127) // 128 * 128
    if tb is None:
        tb = tune.get("tb") or min(1024 if interp else _delta.DEF_TB,
                                   (max(8, B) + 7) // 8 * 8)
    if tn is None:
        tn = tune.get("tl") or (Np if interp else min(_delta.DEF_TN, Np))
    kc = tune.get("kc", _traverse.COMPACT_KC)
    return tb, tn, kc


def delta_probe(queries: jnp.ndarray, pts: jnp.ndarray, *, k: int,
                tb: int | None = None, tn: int | None = None
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Probe the insert delta buffer: queries [B, 4] × buffer points
    [cap, 2] → compact hit slots ``(slot_idx [B, k] i32, valid [B, k]
    bool, count [B] i32)`` in insertion order.

    Semantically ``compact_mask_counted(contains(queries, pts), k)``, but
    on the kernel path the ``[B, cap]`` containment mask stays in VMEM
    tile-by-tile and never reaches HBM (absent from the lowered HLO — the
    slot-table contract the serving paths share). Unstaged/padding buffer
    slots must hold +inf coordinates (``core.delta`` maintains that);
    ``count`` is the row's full hit total, so overflow (``count > k``)
    survives compaction exactly as the other compact wrappers' counts do.

    Fallback ladder mirrors ``traverse_compact``: the jnp dense oracle
    when kernels are off or the form-aware VMEM estimate exceeds the
    budget — bit-identical either way. Tile knobs resolve explicit
    override → autotune cache entry (``delta-*`` keys) → default.
    """
    B = queries.shape[0]
    cap = pts.shape[0]
    if not kernels_enabled():
        return ref.delta_probe(queries, pts, k)
    interp = _interpret()
    tb, tn, kc = _delta_tiles(B, cap, interp, tb, tn)
    kp = k if interp else \
        (k + _traverse.LANE - 1) // _traverse.LANE * _traverse.LANE
    if _delta.vmem_estimate_delta(tb, tn, kp, tpu_form=not interp,
                                  kc=kc) > _traverse.VMEM_BUDGET:
        return ref.delta_probe(queries, pts, k)
    qp = _pad_to(queries.astype(jnp.float32), 0, tb, 0.0)
    pp = _pad_to(pts.astype(jnp.float32), 0, tn, jnp.inf)
    idx, cnt = _delta.delta_probe_t(qp.T, pp.T, k=k, tb=tb, tn=tn, kc=kc,
                                    interpret=interp)
    count = cnt[:B, 0]
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < count[:, None]
    return jnp.where(valid, idx[:B, :k], 0), valid, count


def spatial_key(queries: jnp.ndarray, bbox: jnp.ndarray | None = None,
                curve: str = "hilbert", order: int = _skey.DEF_ORDER,
                tb: int | None = None) -> jnp.ndarray:
    """Space-filling-curve keys for query rects: [B, 4] → [B] i32.

    Rect centers are normalized by ``bbox`` ([4] xmin/ymin/xmax/ymax —
    pass the *workload* bounding box so keys are comparable across
    batches; defaults to the batch's own extent) and quantized to
    ``order``-bit coordinates before the bit walk. ``curve`` is
    ``"hilbert"`` (better locality) or ``"morton"`` (cheaper).
    """
    q = queries.astype(jnp.float32)
    cx = (q[:, 0] + q[:, 2]) * 0.5
    cy = (q[:, 1] + q[:, 3]) * 0.5
    if bbox is None:
        bbox = jnp.stack([jnp.min(cx), jnp.min(cy),
                          jnp.max(cx), jnp.max(cy)])
    bbox = jnp.asarray(bbox, jnp.float32)
    span = jnp.maximum(bbox[2:] - bbox[:2], 1e-12)
    cxy = (jnp.stack([cx, cy], axis=1) - bbox[None, :2]) / span[None, :]
    if not kernels_enabled():
        return ref.spatial_key(cxy, curve=curve, order=order)
    B = queries.shape[0]
    tb = tb or min(_skey.DEF_TB, (max(128, B) + 127) // 128 * 128)
    cp = _pad_to(cxy, 0, tb, 0.0)
    out = _skey.spatial_key_t(cp.T, curve=curve, order=order, tb=tb,
                              interpret=_interpret())
    return out[0, :B]


def leaf_refine(queries: jnp.ndarray, leaf_entries: jnp.ndarray,
                leaf_idx: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """queries [B,4], leaf_entries [L,M,2], leaf_idx [B,K], valid [B,K]
    → inside [B, K, M] bool."""
    ex = leaf_entries[..., 0]
    ey = leaf_entries[..., 1]
    if not kernels_enabled():
        return ref.leaf_refine(queries, ex, ey, leaf_idx, valid)
    # clamp padded slots to leaf 0 (masked out by ``valid`` in-kernel)
    safe_idx = jnp.clip(leaf_idx, 0, ex.shape[0] - 1)
    return _refine.leaf_refine(queries, ex, ey, safe_idx, valid,
                               interpret=_interpret())


def knn_browse(centers: jnp.ndarray, leaf_entries: jnp.ndarray,
               leaf_idx: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Distance-browse compact visited-leaf slots: centers [B, 3]
    (cx, cy, r²), leaf_entries [L, M, 2], leaf_idx/valid [B, K]
    → d2 [B, K, M] f32 (+inf where masked).

    The kNN serving primitive: only the leaves named in the slot table
    are touched (scalar-prefetched tiles on the TPU form, an XLA gather
    on the folded interpret form — see ``kernels.knn_browse``); the
    caller's top-k over the flat ``[B, K·M]`` view yields the k nearest
    within the probed radius. Fallback ladder mirrors ``leaf_refine``:
    the jnp oracle when kernels are off or the form-aware VMEM estimate
    exceeds the budget — bit-identical either way. The autotune cache is
    consulted under ``knn-*`` keys for a pinned form (``fold_k``).
    """
    ex = leaf_entries[..., 0]
    ey = leaf_entries[..., 1]
    if not kernels_enabled():
        return ref.knn_browse(centers, ex, ey, leaf_idx, valid)
    interp = _interpret()
    B, K = leaf_idx.shape
    M = ex.shape[1]
    tune = _knn.tuned_tiles_knn(B, K, M, interp)
    fold = tune.get("fold_k")
    fold = interp if fold is None else bool(fold)
    if _knn.vmem_estimate_knn(B, K, M, tpu_form=not fold) > \
            _traverse.VMEM_BUDGET:
        return ref.knn_browse(centers, ex, ey, leaf_idx, valid)
    # clamp padded slots to leaf 0 (masked out by ``valid`` in-kernel)
    safe_idx = jnp.clip(leaf_idx, 0, ex.shape[0] - 1)
    return _knn.knn_browse(centers, ex, ey, safe_idx, valid,
                           interpret=interp, fold_k=fold)


def forest_infer(features: jnp.ndarray, feat_idx: jnp.ndarray,
                 thresh: jnp.ndarray, tables: jnp.ndarray,
                 tb: int | None = None) -> jnp.ndarray:
    """features [B,F], feat_idx [T,D] i32, thresh [T,D], tables [T,2^D,C]
    → scores [B,C] (summed votes)."""
    B = features.shape[0]
    sel = features[:, feat_idx]                 # [B, T, D] pre-gather
    if not kernels_enabled():
        return ref.forest_infer(sel, thresh, tables)
    tb = tb or min(_forest.DEF_TB, max(8, B))
    selp = _pad_to(sel, 0, tb, 0.0)
    out = _forest.forest_infer(selp, thresh, tables, tb=tb,
                               interpret=_interpret())
    return out[:B]


def forest_infer_cells(features: jnp.ndarray, feat_idx: jnp.ndarray,
                       thresh: jnp.ndarray, tables: jnp.ndarray,
                       n_cells: int, tb: int | None = None) -> jnp.ndarray:
    """Celled variant: feat_idx/thresh [C·T, D], tables [C·T, 2^D, Cl]
    → votes [B, C, Cl] (per-cell tree-vote sums)."""
    B = features.shape[0]
    sel = features[:, feat_idx]                 # [B, C·T, D]
    if not kernels_enabled():
        T = feat_idx.shape[0] // n_cells
        flat = ref.forest_infer_percell(sel, thresh, tables)
        return flat.reshape(B, n_cells, T, -1).sum(axis=2)
    tb = tb or min(_forest.DEF_TB, max(8, B))
    selp = _pad_to(sel, 0, tb, 0.0)
    out = _forest.forest_infer_cells(selp, thresh, tables, n_cells=n_cells,
                                     tb=tb, interpret=_interpret())
    return out[:B]


def _wkv6_kernel_padded(r, k, v, w, u, chunk):
    T = r.shape[1]
    if T % chunk != 0:
        # pad time with identity steps (w=1, k=0 → state & outputs unaffected)
        pad = (-T) % chunk
        r2 = _pad_to(r, 1, chunk, 0.0)
        k2 = _pad_to(k, 1, chunk, 0.0)
        v2 = _pad_to(v, 1, chunk, 0.0)
        w2 = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        out = _wkv6.wkv6(r2, k2, v2, w2, u, chunk=chunk,
                         interpret=_interpret())
        return out[:, :T]
    return _wkv6.wkv6(r, k, v, w, u, chunk=chunk, interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _wkv6_ad(r, k, v, w, u, chunk):
    return _wkv6_kernel_padded(r, k, v, w, u, chunk)


def _wkv6_fwd(r, k, v, w, u, chunk):
    return _wkv6_kernel_padded(r, k, v, w, u, chunk), (r, k, v, w, u)


def _wkv6_bwd(chunk, res, ct):
    # Backward through the pure-jnp oracle (recompute); a dedicated backward
    # kernel is a known optimization left on the table — see EXPERIMENTS.md.
    _, vjp = jax.vjp(ref.wkv6, *res)
    return vjp(ct)


_wkv6_ad.defvjp(_wkv6_fwd, _wkv6_bwd)


def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
         u: jnp.ndarray, chunk: int = _wkv6.DEF_CHUNK) -> jnp.ndarray:
    """RWKV-6 scan: r/k/w [BH,T,dk], v [BH,T,dv], u [BH,dk] → y [BH,T,dv].

    Differentiable: forward runs the chunked Pallas kernel; the VJP
    recomputes through the sequential reference (checkpoint-style).
    """
    if not kernels_enabled():
        return ref.wkv6(r, k, v, w, u)
    return _wkv6_ad(r, k, v, w, u, chunk)
