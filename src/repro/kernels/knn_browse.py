"""Pallas TPU kernel: distance browsing over compact visited-leaf slots.

The kNN path reuses the range path's I/O discipline: the fused traversal
(probed with the query's ``center ± radius`` box) names at most ``K``
candidate leaves per query in a compact ``[B, K]`` slot table, and this
kernel browses exactly those leaves — only the named ``[1, M]`` entry
tiles move HBM→VMEM (scalar-prefetch BlockSpec index maps), extraneous
leaves generate no memory traffic. Per fetched entry it emits the
squared Euclidean distance to the query center, masked to +inf outside
the probed radius (or on invalid slots / +inf-padded entries), so the
caller's top-k over the ``[B, K·M]`` flat view yields the k nearest
among all points within the radius. The dense ``[B, L]`` visited mask
never exists on this path — the slot table is the only interchange.

Two grid forms, one semantics (the ``leaf_refine`` split):

* ``fold_k=False`` (the TPU form): a ``(B, K)`` grid, one cell per
  (query, leaf slot), each DMA-ing one named ``[1, M]`` leaf tile.
* ``fold_k=True`` (the interpret form): the grid folds away — an XLA
  gather stages the ``[B, K, M]`` slab and the kernel body runs once.
  Bit-identical outputs; the right trade when the "DMA" is an emulated
  memcpy anyway.

Inputs (planar entry layout):
  ``centers``  [B, 3] f32   — query center x, center y, radius²
  ``ex``/``ey``[L, M] f32   — entry coordinates, +inf padded
  ``leaf_idx`` [B, K] i32   — leaves to browse (scalar-prefetched)
  ``valid``    [B, K] i32   — slot validity
Output:
  ``d2``       [B, K, M] f32 — squared distance, +inf where masked

+inf-padded entries are safe by arithmetic, not by branch: their
``dx``/``dy`` are +inf (finite center), so ``d2`` is +inf and the
radius test fails — the same convention the delta-probe buffer uses.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.traverse_fused import tuned_tiles_for_key


def tune_key_knn(B: int, K: int, M: int, interp: bool) -> str:
    """Autotune-cache key for the kNN-browse form space (same cache file
    as the traversal/mlp/delta forms; see ``benchmarks/autotune``)."""
    return f"knn-{'interp' if interp else 'tpu'}:B{B}:K{K}:M{M}"


def tuned_tiles_knn(B: int, K: int, M: int, interp: bool) -> dict:
    return tuned_tiles_for_key(tune_key_knn(B, K, M, interp))


def vmem_estimate_knn(B: int, K: int, M: int, tpu_form: bool = True) -> int:
    """Rough VMEM working-set bytes for one browse dispatch.

    The TPU form's cell working set is one query row + one entry tile +
    one output tile; the folded form stages the whole gathered
    ``[B, K, M]`` slab (gx, gy, out) plus the query/valid blocks.
    """
    if tpu_form:
        return 3 * 4 + 4 + 2 * M * 4 + M * 4
    return B * (3 + K) * 4 + 3 * B * K * M * 4


def _kernel(idx_ref, q_ref, valid_ref, ex_ref, ey_ref, o_ref):
    # q_ref: [1, 3]; ex/ey_ref: [1, M]; valid_ref: [1, 1]; o_ref: [1, 1, M]
    cx = q_ref[0, 0]
    cy = q_ref[0, 1]
    r2 = q_ref[0, 2]
    dx = ex_ref[0, :] - cx
    dy = ey_ref[0, :] - cy
    d2 = dx * dx + dy * dy
    ok = (d2 <= r2) & (valid_ref[0, 0] > 0)
    o_ref[0, 0, :] = jnp.where(ok, d2, jnp.inf)


def _kernel_folded(q_ref, valid_ref, gx_ref, gy_ref, o_ref):
    # whole-array blocks: q [B, 3]; valid [B, K]; gx/gy/o [B, K, M]
    q = q_ref[:, :]
    cx = q[:, 0][:, None, None]
    cy = q[:, 1][:, None, None]
    r2 = q[:, 2][:, None, None]
    dx = gx_ref[:, :, :] - cx
    dy = gy_ref[:, :, :] - cy
    d2 = dx * dx + dy * dy
    ok = (d2 <= r2) & (valid_ref[:, :][:, :, None] > 0)
    o_ref[:, :, :] = jnp.where(ok, d2, jnp.inf)


@functools.partial(jax.jit, static_argnames=("interpret", "fold_k"))
def knn_browse(centers: jnp.ndarray, ex: jnp.ndarray, ey: jnp.ndarray,
               leaf_idx: jnp.ndarray, valid: jnp.ndarray, *,
               interpret: bool = False,
               fold_k: bool | None = None) -> jnp.ndarray:
    """centers [B,3] (cx,cy,r²), ex/ey [L,M], leaf_idx/valid [B,K]
    → d2 [B,K,M] f32 (+inf where masked).

    ``fold_k`` defaults to ``interpret``: the (B, K) scalar-prefetch grid
    on hardware, the folded form when emulating. Both forms are
    bit-identical (tested); pass ``fold_k`` explicitly to pin a form.
    """
    if fold_k is None:
        fold_k = interpret
    B, K = leaf_idx.shape
    L, M = ex.shape
    if fold_k:
        gx = ex[leaf_idx]                       # [B, K, M] XLA-level gather
        gy = ey[leaf_idx]
        return pl.pallas_call(
            _kernel_folded,
            out_shape=jax.ShapeDtypeStruct((B, K, M), jnp.float32),
            interpret=interpret,
        )(centers.astype(jnp.float32), valid.astype(jnp.int32),
          gx.astype(jnp.float32), gy.astype(jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, 3), lambda b, k, idx: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, k, idx: (b, k)),
            pl.BlockSpec((1, M), lambda b, k, idx: (idx[b, k], 0)),
            pl.BlockSpec((1, M), lambda b, k, idx: (idx[b, k], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, M), lambda b, k, idx: (b, k, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, M), jnp.float32),
        interpret=interpret,
    )(leaf_idx.astype(jnp.int32), centers.astype(jnp.float32),
      valid.astype(jnp.int32), ex.astype(jnp.float32),
      ey.astype(jnp.float32))
