"""Pallas TPU kernel: space-filling-curve keys for query rectangles.

The spatial batch scheduler (``repro.core.schedule``) sorts incoming
traffic by Hilbert or Morton key before batching, so each serving batch
covers a compact region of space and the fused traversal kernel's
tile-level early exit (and the compaction epilogue that inherits it) fires
on most leaf tiles. The key computation itself is the only per-query work
the scheduler adds to the hot admission path, so it gets a kernel too.

Input layout: normalized query-rect centers as two planar rows
(``cxy_t`` [2, B] f32 in [0, 1) — ``ops.py`` computes centers and
normalizes by the workload bounding box; a shared bbox is what makes keys
comparable across batches). Output: ``[1, B]`` int32 keys.

Both curves quantize each center to ``order``-bit integer coordinates and
run a static ``order``-iteration bit loop on the VPU — pure element-wise
int32 compare/select/shift ops over the lane dimension, no gathers, no MXU:

* ``morton``  — bit interleave (x high bit first). Cheap, but adjacent keys
  can still be spatially far at quadrant boundaries.
* ``hilbert`` — the classic xy→d walk (per-step quadrant rotation carried
  as compare/selects). Strictly better locality: consecutive keys are
  always adjacent cells, which is exactly what batch formation wants.

``order`` defaults to 15 so the key (2·order = 30 bits) stays inside a
*signed* int32 — keys only need to be sort-stable, not dense, and int32 is
the native sort/compare width on both the VPU and XLA:CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEF_TB = 1024   # query tile (lane axis, multiple of 128)
DEF_ORDER = 15  # bits per dimension; 2·order must stay < 32 (signed keys)


def _quantize(c, order: int):
    """[N] f32 in [0, 1) → [N] i32 in [0, 2^order) (clamped)."""
    n = jnp.int32(1 << order)
    q = (c * n.astype(jnp.float32)).astype(jnp.int32)
    return jnp.clip(q, 0, n - 1)


def _morton_bits(x, y, order: int):
    """Interleave order-bit x/y (x in the odd/high positions) → i32 key."""
    key = jnp.zeros_like(x)
    for i in range(order):
        key = key | (((x >> i) & 1) << (2 * i + 1)) | (((y >> i) & 1)
                                                       << (2 * i))
    return key


def _hilbert_bits(x, y, order: int):
    """Classic xy→d Hilbert walk, vectorized: rotations become selects.

    Per step (s = 2^i, high bit first): d += s²·((3·rx) ^ ry), then the
    standard quadrant rotation — when ry == 0, flip both coords if rx == 1
    and swap x/y. Unrolled ``order`` times (static), all int32 lane ops.
    """
    d = jnp.zeros_like(x)
    for i in range(order - 1, -1, -1):
        s = 1 << i
        rx = (x >> i) & 1
        ry = (y >> i) & 1
        d = d + s * s * ((3 * rx) ^ ry)
        swap = ry == 0
        flip = swap & (rx == 1)
        fx = jnp.where(flip, s - 1 - x, x)
        fy = jnp.where(flip, s - 1 - y, y)
        x = jnp.where(swap, fy, fx)
        y = jnp.where(swap, fx, fy)
    return d


def _make_kernel(order: int, curve: str):
    def kernel(c_ref, o_ref):
        # c_ref: [2, TB] f32 normalized centers; o_ref: [1, TB] i32 keys
        x = _quantize(c_ref[0, :], order)
        y = _quantize(c_ref[1, :], order)
        bits = _hilbert_bits if curve == "hilbert" else _morton_bits
        o_ref[0, :] = bits(x, y, order)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("curve", "order", "tb", "interpret"))
def spatial_key_t(cxy_t: jnp.ndarray, *, curve: str = "hilbert",
                  order: int = DEF_ORDER, tb: int = DEF_TB,
                  interpret: bool = False) -> jnp.ndarray:
    """Transposed-layout entry point: ``cxy_t`` [2, B] f32 → [1, B] i32.

    B must be a multiple of ``tb`` (ops.py pads); padding lanes produce
    ordinary keys and are sliced off by the caller.
    """
    assert curve in ("hilbert", "morton"), curve
    assert 2 * order < 32, order
    _, B = cxy_t.shape
    assert B % tb == 0, (B, tb)
    return pl.pallas_call(
        _make_kernel(order, curve),
        grid=(B // tb,),
        in_specs=[pl.BlockSpec((2, tb), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tb), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, B), jnp.int32),
        interpret=interpret,
    )(cxy_t.astype(jnp.float32))
