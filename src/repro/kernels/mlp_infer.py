"""Pallas TPU kernel: fused AI-path prediction → compact slot table.

The AI path of the "AI+R"-tree turns a range query into multi-label
classification: run the ≤ ``max_cells`` cell experts a query overlaps,
union their per-leaf scores, threshold, and access only the predicted
leaves. Before this kernel the learned side materialized the dense
``[B, L]`` score table in HBM (``predict_scores`` → ``global_scores`` →
threshold → ``compact_mask_counted``) — the paper's *fast* path was the
memory-heavy half of the engine. This kernel fuses the whole prediction
pipeline into one ``pallas_call`` that emits the same ``[B, K]`` slot
table + per-row count contract as ``traverse_compact_t``; the ``[B, L]``
scores never exist outside VMEM tiles.

Stages, all inside the kernel:

* **Cell-routed MLP-bank inference** (once per query tile, ``j == 0``).
  Per-query expert-parameter staging is a lane gather
  (``w1[cell_ids[b]]``), which Mosaic does not vectorize — so, exactly as
  ``traverse_fused`` rewrites frontier expansion, the hardware form stages
  params through **one-hot MXU matmuls**: ``onehot(cell_ids[:, s]) @
  W1.reshape(C, F·H)`` pulls each query's ``[F, H]``/``[H, Cl]`` expert
  block into per-query rows (exact: one-hot f32 matmul selects, never
  mixes). The two layers then run as broadcasted multiply-accumulates over
  the static ``F``/``H`` axes — the per-query weights make the contraction
  batched, which the MXU cannot express directly, but the selections
  themselves are dense MXU work.

* **Sigmoid + threshold** on the ``[TB, Cl]`` logits per cell slot; the
  thresholded candidates and their ``label_map`` targets (selected by the
  same one-hot matmuls) persist in VMEM scratch across the leaf-tile
  sweep: ``[TB, S·Cl]`` — the whole inter-stage state, vs ``[B, L]``.

* **Per-cell → global scatter + max-union.** For each leaf tile, a
  candidate-compare loop ORs each (slot, label) candidate into the tile's
  prediction mask (``tgt == column``): union across a query's cells and
  dedup of sibling-cell duplicates come free from the OR. A ``pl.when``
  guard on the tile's [min, max] candidate-target range skips leaf tiles
  no candidate maps into — predictions are spatially tight, so most tiles
  of most batches are dead (the traversal kernel's early exit, on the
  learned side).

* **Compaction epilogue** — the cumsum-rank scheme shared with
  ``traverse_compact_t`` (``_compact_epilogue_tpu`` / ``_interp``): first
  ``k`` predicted leaf ids in leaf-ID order plus the per-row count, from
  which the caller derives ``valid``, the *empty* and *overflow* fallback
  signals, bit-identical to ``compact_mask_counted`` of the dense path.

Threshold convention: requires ``threshold ≥ 0`` (the dense oracle's
zero-initialized score scatter predicts *every* leaf under a negative
threshold; the candidate union cannot). ``ops.py`` asserts this.

Layout: queries/cell ids arrive row-major (``[B, F]``, ``[B, S]``) — the
query axis stays on sublanes end to end, so no in-kernel transposes.
``ops.py`` pads B to the query tile, the leaf axis to the leaf tile, and
C to the lane quantum (padding cells carry ``label_map = -1``,
``lmask = 0``; clipped ids never select them).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.epilogue import (
    compact_epilogue_interp as _compact_epilogue_interp,
    compact_epilogue_tpu as _compact_epilogue_tpu,
)
from repro.kernels.traverse_fused import (COMPACT_KC, LANE,
                                          tuned_tiles_for_key)

DEF_TB = 256    # query-tile (sublane axis)
DEF_TL = 512    # leaf-tile (lane axis, multiple of 128)


def tune_key_mlp(B: int, L: int, C: int, Cl: int, interp: bool) -> str:
    """Autotune-cache key for the fused prediction kernel's form space
    (same cache file as the traversal forms; see ``benchmarks/autotune``)."""
    return f"mlp-{'interp' if interp else 'tpu'}:B{B}:L{L}:C{C}:Cl{Cl}"


def tuned_tiles_mlp(B: int, L: int, C: int, Cl: int, interp: bool) -> dict:
    return tuned_tiles_for_key(tune_key_mlp(B, L, C, Cl, interp))


def vmem_estimate_mlp(C: int, F: int, H: int, Cl: int, S: int, tb: int,
                      tl: int, kp: int, tpu_form: bool = True,
                      kc: int = COMPACT_KC) -> int:
    """Rough VMEM working-set bytes for the fused prediction kernel.

    Counts the replicated bank operands (the dominant term — ``W2`` is
    ``C·H·Cl`` floats), the per-slot one-hot + staged-parameter
    transients, the candidate scratch, the leaf-tile mask, and the
    compaction epilogue transient (form-dependent, exactly as
    ``vmem_estimate_compact``: the TPU form's chunked rank-equality
    scatter materializes a ``[tb, tl, kc]`` compare; the interpret form's
    binary search only needs the ``[tb, tl]`` prefix count).
    """
    bank = C * (F * H + H + H * Cl + Cl + 2 * Cl) * 4
    est = bank
    # one-hot + staged params for one slot (slots are sequential)
    est += tb * (C + F * H + H + H * Cl + Cl) * 4
    est += 2 * tb * S * Cl * 4                    # candidate prob/tgt scratch
    est += tb * tl * 4                            # prediction mask tile
    est += tb * tl * (kc if tpu_form else 1) * 4  # epilogue transient
    est += tb * (kp + 1) * 4                      # slot table + count
    return est


def _stage_infer_tpu(x_ref, cid_ref, ok_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                     lm_ref, lmk_ref, p_scr, t_scr, S: int, C: int, F: int,
                     H: int, Cl: int, tb: int, thr: float):
    """One-hot MXU inference for every cell slot of a query tile; writes
    the thresholded candidates (0/1) and their global leaf targets (f32,
    invalid parked at -1) to the ``[TB, S·Cl]`` VMEM scratch."""
    dot = functools.partial(jax.lax.dot,
                            preferred_element_type=jnp.float32)
    for s in range(S):
        ohb = (cid_ref[:, s:s + 1] ==
               jax.lax.broadcasted_iota(jnp.int32, (tb, C), 1)) \
            & (ok_ref[:, s:s + 1] > 0)
        oh = ohb.astype(jnp.float32)                    # [TB, C]
        w1s = dot(oh, w1_ref[:, :])                     # [TB, F·H]
        b1s = dot(oh, b1_ref[:, :])                     # [TB, H]
        acc = x_ref[:, 0:1] * w1s[:, :H]
        for f in range(1, F):
            acc = acc + x_ref[:, f:f + 1] * w1s[:, f * H:(f + 1) * H]
        h = jnp.maximum(acc + b1s, 0.0)                 # [TB, H]
        w2s = dot(oh, w2_ref[:, :])                     # [TB, H·Cl]
        b2s = dot(oh, b2_ref[:, :])                     # [TB, Cl]
        acc2 = h[:, 0:1] * w2s[:, :Cl]
        for hh in range(1, H):
            acc2 = acc2 + h[:, hh:hh + 1] * w2s[:, hh * Cl:(hh + 1) * Cl]
        prob = jax.nn.sigmoid(acc2 + b2s)               # [TB, Cl]
        tgt = dot(oh, lm_ref[:, :])                     # [TB, Cl] f32 ids
        okc = dot(oh, lmk_ref[:, :]) > 0.5              # label-slot valid
        cand = okc & (prob > thr)
        p_scr[:, s * Cl:(s + 1) * Cl] = \
            jnp.where(cand, 1.0, 0.0)
        t_scr[:, s * Cl:(s + 1) * Cl] = \
            jnp.where(cand, tgt, -1.0)


def _make_predict_kernel(S: int, C: int, F: int, H: int, Cl: int, tb: int,
                         tl: int, kp: int, thr: float,
                         tpu_form: bool, kc: int = COMPACT_KC):
    """Kernel body: fused cell-routed inference + scatter/union +
    compaction.

    ``tpu_form=True`` is the hardware graph (one-hot MXU staging, VMEM
    candidate scratch persisted across leaf tiles under ``pl.when(j ==
    0)``, range-guarded tile early exit, chunked rank-equality epilogue).
    ``tpu_form=False`` is the branch-free interpret form: value-level
    parameter gathers + the same einsum contraction order as the dense
    oracle (``cell_logits_for``), value-level scatter into the tile, and
    the searchsorted epilogue — interpret mode functionalizes ref-touching
    conds, so the walk recomputes per leaf tile instead of using scratch
    (the interpret default folds the leaf axis into one tile anyway).
    """
    SCl = S * Cl

    def kernel(x_ref, cid_ref, ok_ref, w1_ref, b1_ref, w2_ref, b2_ref,
               lm_ref, lmk_ref, idx_ref, cnt_ref, p_scr, t_scr):
        j = pl.program_id(1)

        if tpu_form:
            @pl.when(j == 0)
            def _init():
                idx_ref[:, :] = jnp.zeros((tb, kp), jnp.int32)
                cnt_ref[:, :] = jnp.zeros((tb, 1), jnp.int32)
                _stage_infer_tpu(x_ref, cid_ref, ok_ref, w1_ref, b1_ref,
                                 w2_ref, b2_ref, lm_ref, lmk_ref, p_scr,
                                 t_scr, S, C, F, H, Cl, tb, thr)

            pv = p_scr[:, :]                             # [TB, S·Cl]
            tv = t_scr[:, :]
            # tile early exit: skip leaf tiles no candidate maps into
            lo = jnp.min(jnp.where(pv > 0, tv, jnp.float32(2 ** 30)))
            hi = jnp.max(tv)                             # invalid are -1
            t0 = jnp.float32(j * tl)

            @pl.when((lo < t0 + tl) & (hi >= t0))
            def _live_tile():
                colf = t0 + jax.lax.broadcasted_iota(
                    jnp.int32, (tb, tl), 1).astype(jnp.float32)
                mask = jnp.zeros((tb, tl), jnp.bool_)
                for kk in range(SCl):
                    mask = mask | ((pv[:, kk:kk + 1] > 0)
                                   & (tv[:, kk:kk + 1] == colf))
                col = j * tl + jax.lax.broadcasted_iota(
                    jnp.int32, (tb, tl), 1)
                _compact_epilogue_tpu(mask, col, idx_ref, cnt_ref, kp, kc)
        else:
            x = x_ref[:, :]                              # [TB, F]
            cid = cid_ref[:, :]                          # [TB, S]
            okr = ok_ref[:, :] > 0
            w1 = w1_ref[:, :].reshape(C, F, H)[cid]      # [TB, S, F, H]
            b1 = b1_ref[:, :][cid]
            w2 = w2_ref[:, :].reshape(C, H, Cl)[cid]
            b2 = b2_ref[:, :][cid]
            h = jnp.maximum(
                jnp.einsum("bf,bsfh->bsh", x, w1) + b1, 0.0)
            logits = jnp.einsum("bsh,bshl->bsl", h, w2) + b2
            prob = jax.nn.sigmoid(logits)                # [TB, S, Cl]
            okc = okr[:, :, None] & (lmk_ref[:, :][cid] > 0.5)
            cand = okc & (prob > thr)
            trel = lm_ref[:, :][cid].astype(jnp.int32) - j * tl
            intile = cand & (trel >= 0) & (trel < tl)
            ti = jnp.where(intile, trel, tl).reshape(tb, SCl)
            rows = jnp.arange(tb, dtype=jnp.int32)[:, None]
            mask = jnp.zeros((tb, tl + 1), jnp.int32).at[rows, ti].max(
                intile.reshape(tb, SCl).astype(jnp.int32))[:, :tl] > 0
            _compact_epilogue_interp(mask, j, tl, kp, idx_ref, cnt_ref)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("k", "lp", "thr", "tb", "tl", "kc",
                                    "interpret", "tpu_form"))
def mlp_predict_compact_t(x: jnp.ndarray, cell_ids: jnp.ndarray,
                          slot_ok: jnp.ndarray, w1f: jnp.ndarray,
                          b1: jnp.ndarray, w2f: jnp.ndarray,
                          b2: jnp.ndarray, lm: jnp.ndarray,
                          lmk: jnp.ndarray, *, k: int, lp: int, thr: float,
                          tb: int = DEF_TB, tl: int = DEF_TL,
                          kc: int = COMPACT_KC, interpret: bool = False,
                          tpu_form: bool | None = None
                          ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused prediction entry point.

    ``x`` [B, F] normalized features; ``cell_ids``/``slot_ok`` [B, S]
    (ids clipped into [0, C)); ``w1f`` [C, F·H], ``b1`` [C, H], ``w2f``
    [C, H·Cl], ``b2`` [C, Cl]; ``lm``/``lmk`` [C, Cl] f32 label map
    (global leaf ids, -1 pads) and label-slot mask. ``lp`` is the
    lane-padded leaf count (the scatter axis); B must be a multiple of
    ``tb``, ``lp`` of ``tl``, C of LANE (ops.py pads). Returns
    ``(leaf_idx [B, KP] i32, count [B, 1] i32)`` with the
    ``traverse_compact_t`` slot contract: KP = ``k`` lane-rounded in the
    TPU form, exactly ``k`` in the interpret form; row ``b``'s first
    ``min(count[b], KP)`` slots hold its predicted leaf ids in leaf-ID
    order, slots past the count are 0.

    ``tpu_form`` defaults to ``not interpret``; pass ``tpu_form=True``
    with ``interpret=True`` to validate the exact hardware graph off-TPU.
    """
    if tpu_form is None:
        tpu_form = not interpret
    B, F = x.shape
    S = cell_ids.shape[1]
    C = w1f.shape[0]
    H = b1.shape[1]
    Cl = b2.shape[1]
    assert B % tb == 0 and lp % tl == 0 and C % LANE == 0, (B, lp, C, tb, tl)
    kp = (k + LANE - 1) // LANE * LANE if tpu_form else k
    assert kp % kc == 0 or not tpu_form, (kp, kc)
    n_j = lp // tl
    grid = (B // tb, n_j)

    rep = lambda shape: pl.BlockSpec(shape, lambda i, j: (0, 0))  # noqa: E731
    in_specs = [
        pl.BlockSpec((tb, F), lambda i, j: (i, 0)),
        pl.BlockSpec((tb, S), lambda i, j: (i, 0)),
        pl.BlockSpec((tb, S), lambda i, j: (i, 0)),
        rep((C, w1f.shape[1])),
        rep((C, H)),
        rep((C, w2f.shape[1])),
        rep((C, Cl)),
        rep((C, Cl)),
        rep((C, Cl)),
    ]

    return pl.pallas_call(
        _make_predict_kernel(S, C, F, H, Cl, tb, tl, kp, thr,
                             tpu_form=tpu_form, kc=kc),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((tb, kp), lambda i, j: (i, 0)),
                   pl.BlockSpec((tb, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, kp), jnp.int32),
                   jax.ShapeDtypeStruct((B, 1), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((tb, S * Cl), jnp.float32),
                        pltpu.VMEM((tb, S * Cl), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), cell_ids.astype(jnp.int32),
      slot_ok.astype(jnp.int32), w1f.astype(jnp.float32),
      b1.astype(jnp.float32), w2f.astype(jnp.float32),
      b2.astype(jnp.float32), lm.astype(jnp.float32),
      lmk.astype(jnp.float32))
