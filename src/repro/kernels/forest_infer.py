"""Pallas TPU kernel: oblivious-decision-forest inference.

The AI-tree's paper-faithful classifier family is decision trees. Pointer
trees do not vectorize, so we use **oblivious** trees (one (feature,
threshold) pair per depth level): evaluating a tree is

    bit_d  = x[feat_d] > thresh_d                (VPU compares)
    leaf   = Σ_d bit_d · 2^(D-1-d)               (integer dot)
    scores = onehot(leaf) @ leaf_table           (MXU matmul)

The [TB, 2^D] one-hot × [2^D, C] table matmul is the hot op and maps straight
onto the MXU. The grid is (B-tiles, T trees) with T innermost so each output
tile accumulates tree votes in VMEM without re-fetching.

Inputs:
  ``sel``    [B, T, D] f32 — pre-gathered feature values per tree/depth
  ``thresh`` [T, D]   f32
  ``tables`` [T, 2^D, C] f32 — per-leaf label votes
Output:
  ``scores`` [B, C] f32 — summed votes (caller normalizes by T)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEF_TB = 256


def _kernel(sel_ref, th_ref, tbl_ref, o_ref):
    t = pl.program_id(1)
    sel = sel_ref[:, 0, :]                      # [TB, D]
    th = th_ref[0, :]                           # [D]
    D = sel.shape[-1]
    bits = (sel > th[None, :]).astype(jnp.float32)
    d_iota = jax.lax.broadcasted_iota(jnp.float32, (1, D), 1)
    powers = jnp.exp2(jnp.float32(D - 1) - d_iota)          # [1, D]
    leaf = jnp.sum(bits * powers, axis=-1).astype(jnp.int32)  # [TB]
    n_leaves = tbl_ref.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (sel.shape[0], n_leaves), 1)
    onehot = (iota == leaf[:, None]).astype(jnp.float32)               # [TB, 2^D]
    votes = jnp.dot(onehot, tbl_ref[0, :, :],
                    preferred_element_type=jnp.float32)                # [TB, C]

    @pl.when(t == 0)
    def _init():
        o_ref[:, :] = votes

    @pl.when(t > 0)
    def _acc():
        o_ref[:, :] += votes


def _kernel_cells(sel_ref, th_ref, tbl_ref, o_ref):
    """Per-cell accumulation variant: grid (B-tiles, C, T), output [TB, 1, Cl]
    per cell — tree votes accumulate within a cell, not across cells."""
    t = pl.program_id(2)
    sel = sel_ref[:, 0, :]
    th = th_ref[0, :]
    D = sel.shape[-1]
    bits = (sel > th[None, :]).astype(jnp.float32)
    d_iota = jax.lax.broadcasted_iota(jnp.float32, (1, D), 1)
    powers = jnp.exp2(jnp.float32(D - 1) - d_iota)
    leaf = jnp.sum(bits * powers, axis=-1).astype(jnp.int32)
    n_leaves = tbl_ref.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (sel.shape[0], n_leaves), 1)
    onehot = (iota == leaf[:, None]).astype(jnp.float32)
    votes = jnp.dot(onehot, tbl_ref[0, :, :],
                    preferred_element_type=jnp.float32)

    @pl.when(t == 0)
    def _init():
        o_ref[:, 0, :] = votes

    @pl.when(t > 0)
    def _acc():
        o_ref[:, 0, :] += votes


@functools.partial(jax.jit, static_argnames=("n_cells", "tb", "interpret"))
def forest_infer_cells(sel: jnp.ndarray, thresh: jnp.ndarray,
                       tables: jnp.ndarray, *, n_cells: int, tb: int = DEF_TB,
                       interpret: bool = False) -> jnp.ndarray:
    """Celled forests: sel [B, C·T, D], thresh [C·T, D], tables [C·T, 2^D, Cl]
    → votes [B, C, Cl] (summed over each cell's T trees)."""
    B, CT, D = sel.shape
    n_leaves, Cl = tables.shape[1], tables.shape[2]
    assert CT % n_cells == 0, (CT, n_cells)
    T = CT // n_cells
    assert B % tb == 0, (B, tb)
    grid = (B // tb, n_cells, T)
    return pl.pallas_call(
        _kernel_cells,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, 1, D), lambda b, c, t: (b, c * T + t, 0)),
            pl.BlockSpec((1, D), lambda b, c, t: (c * T + t, 0)),
            pl.BlockSpec((1, n_leaves, Cl), lambda b, c, t: (c * T + t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, 1, Cl), lambda b, c, t: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_cells, Cl), jnp.float32),
        interpret=interpret,
    )(sel.astype(jnp.float32), thresh.astype(jnp.float32),
      tables.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("tb", "interpret"))
def forest_infer(sel: jnp.ndarray, thresh: jnp.ndarray, tables: jnp.ndarray,
                 *, tb: int = DEF_TB, interpret: bool = False) -> jnp.ndarray:
    """sel [B,T,D], thresh [T,D], tables [T,2^D,C] → scores [B,C]."""
    B, T, D = sel.shape
    T2, n_leaves, C = tables.shape
    assert T2 == T and n_leaves == 2 ** D, (tables.shape, D)
    assert B % tb == 0, (B, tb)
    grid = (B // tb, T)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, 1, D), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, D), lambda b, t: (t, 0)),
            pl.BlockSpec((1, n_leaves, C), lambda b, t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tb, C), lambda b, t: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(sel.astype(jnp.float32), thresh.astype(jnp.float32),
      tables.astype(jnp.float32))
