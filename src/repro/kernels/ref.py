"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function has identical semantics to its kernel twin; kernel tests sweep
shapes/dtypes and ``assert_allclose`` against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mbr_intersect(queries: jnp.ndarray, mbrs: jnp.ndarray) -> jnp.ndarray:
    """[B, 4] × [N, 4] → [B, N] bool (closed-rectangle intersection)."""
    q = queries[:, None, :].astype(jnp.float32)
    m = mbrs[None, :, :].astype(jnp.float32)
    return (
        (q[..., 0] <= m[..., 2]) & (m[..., 0] <= q[..., 2])
        & (q[..., 1] <= m[..., 3]) & (m[..., 1] <= q[..., 3])
    )


def traverse_fused(queries: jnp.ndarray, level_mbrs, level_parents
                   ) -> jnp.ndarray:
    """Level-synchronous traversal ground truth: [B, 4] → [B, L] bool.

    ``level_mbrs``: one [N_l, 4] per level, root first (leaf level last);
    ``level_parents``: matching [N_l] i32 (entry 0 unused — the root has no
    parent). A leaf is visited iff every ancestor MBR and its own intersect
    the query; identical to ``core.traversal.visited_leaf_mask``.
    """
    mask = mbr_intersect(queries, level_mbrs[0])
    for mbrs, parent in zip(level_mbrs[1:], level_parents[1:]):
        mask = mask[:, parent] & mbr_intersect(queries, mbrs)
    return mask


def traverse_fused_sliced(queries: jnp.ndarray, level_mbrs, level_parents,
                          starts, widths, tl: int) -> jnp.ndarray:
    """Windowed twin of ``traverse_fused`` — ground truth for the
    ancestor-sliced kernels' window semantics: [B, 4] → [B, L] bool.

    Per leaf tile the walk sees only each internal level's
    ``widths[l]``-wide window at element offset ``starts[l, t] *
    widths[l]`` (the ``AncestorTable`` contract); parent indices are
    rebased window-relative and out-of-window ones masked dead. With a
    correctly built table this equals ``traverse_fused`` exactly — that
    equality is what the tests assert.
    """
    never = jnp.array([1.0, 1.0, 0.0, 0.0], jnp.float32)

    def window(mbrs, parent, s, w):
        n = mbrs.shape[0]
        pad = max(0, s + w - n)
        if pad:
            mbrs = jnp.concatenate(
                [mbrs.astype(jnp.float32),
                 jnp.broadcast_to(never, (pad, 4))])
            parent = jnp.concatenate(
                [parent, jnp.zeros((pad,), parent.dtype)])
        return mbrs[s:s + w], parent[s:s + w]

    n_int = len(level_mbrs) - 1
    L = level_mbrs[-1].shape[0]
    starts = jnp.asarray(starts)
    outs = []
    for t in range(-(-L // tl)):
        mask = None
        prev_s = 0
        for l in range(n_int):
            s = int(starts[l, t]) * widths[l]
            wm, wp = window(jnp.asarray(level_mbrs[l]),
                            jnp.asarray(level_parents[l]), s, widths[l])
            hit = mbr_intersect(queries, wm)
            if l == 0:
                mask = hit
            else:
                rel = wp - prev_s
                ok = (rel >= 0) & (rel < widths[l - 1])
                mask = (mask[:, jnp.clip(rel, 0, widths[l - 1] - 1)]
                        & ok[None, :] & hit)
            prev_s = s
        lm = jnp.asarray(level_mbrs[-1])[t * tl:(t + 1) * tl]
        lp = jnp.asarray(level_parents[-1])[t * tl:(t + 1) * tl]
        rel = lp - prev_s
        ok = (rel >= 0) & (rel < widths[-1])
        outs.append(mask[:, jnp.clip(rel, 0, widths[-1] - 1)]
                    & ok[None, :] & mbr_intersect(queries, lm))
    return jnp.concatenate(outs, axis=1)


def spatial_key(cxy: jnp.ndarray, curve: str = "hilbert",
                order: int = 15) -> jnp.ndarray:
    """Space-filling-curve keys: ``cxy`` [B, 2] f32 in [0, 1) → [B] i32.

    Ground truth for ``kernels.spatial_key``: quantize each normalized
    center to ``order``-bit integer coordinates, then either interleave
    bits (``morton``, x high) or run the classic xy→d Hilbert walk with
    quadrant rotations as selects (``hilbert``).
    """
    n = jnp.int32(1 << order)
    q = jnp.clip((cxy.astype(jnp.float32) * n.astype(jnp.float32))
                 .astype(jnp.int32), 0, n - 1)
    x, y = q[:, 0], q[:, 1]
    if curve == "morton":
        key = jnp.zeros_like(x)
        for i in range(order):
            key = key | (((x >> i) & 1) << (2 * i + 1)) | (((y >> i) & 1)
                                                           << (2 * i))
        return key
    d = jnp.zeros_like(x)
    for i in range(order - 1, -1, -1):
        s = 1 << i
        rx = (x >> i) & 1
        ry = (y >> i) & 1
        d = d + s * s * ((3 * rx) ^ ry)
        swap = ry == 0
        flip = swap & (rx == 1)
        fx = jnp.where(flip, s - 1 - x, x)
        fy = jnp.where(flip, s - 1 - y, y)
        x = jnp.where(swap, fy, fx)
        y = jnp.where(swap, fx, fy)
    return d


def mlp_predict_scores(x: jnp.ndarray, cell_ids: jnp.ndarray,
                       slot_ok: jnp.ndarray, w1: jnp.ndarray,
                       b1: jnp.ndarray, w2: jnp.ndarray, b2: jnp.ndarray,
                       label_map: jnp.ndarray, lmask: jnp.ndarray,
                       n_leaves: int) -> jnp.ndarray:
    """Dense AI-path prediction ground truth: [B, F] → scores [B, n_leaves].

    Gathered per-cell MLP forward (``cell_logits_for``'s contraction
    order), sigmoid, and the ``global_scores`` max-union scatter over the
    full leaf axis — the exact pipeline the fused kernel collapses.
    """
    B, S = cell_ids.shape
    w1g = w1[cell_ids]                              # [B, S, F, H]
    b1g = b1[cell_ids]
    w2g = w2[cell_ids]                              # [B, S, H, Cl]
    b2g = b2[cell_ids]
    h = jnp.maximum(
        jnp.einsum("bf,bsfh->bsh", x.astype(jnp.float32), w1g) + b1g, 0.0)
    probs = jax.nn.sigmoid(jnp.einsum("bsh,bshl->bsl", h, w2g) + b2g)
    lm = label_map[cell_ids]                        # [B, S, Cl]
    ok = slot_ok[:, :, None] & lmask[cell_ids]
    tgt = jnp.where(ok, lm, n_leaves)               # park invalid at L
    Cl = lm.shape[-1]
    flat_t = tgt.reshape(B, S * Cl)
    flat_p = jnp.where(ok, probs, 0.0).reshape(B, S * Cl)
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    out = jnp.zeros((B, n_leaves + 1), probs.dtype)
    out = out.at[rows, flat_t].max(flat_p)
    return out[:, :n_leaves]


def mlp_predict_compact(x: jnp.ndarray, cell_ids: jnp.ndarray,
                        slot_ok: jnp.ndarray, w1: jnp.ndarray,
                        b1: jnp.ndarray, w2: jnp.ndarray, b2: jnp.ndarray,
                        label_map: jnp.ndarray, lmask: jnp.ndarray, *,
                        n_leaves: int, k: int, threshold: float
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Ground truth for ``kernels.mlp_infer``: dense scores → threshold →
    ``compact_mask_counted``. Returns ``(leaf_idx [B, k], valid, count)``.
    """
    from repro.core.traversal import compact_mask_counted
    scores = mlp_predict_scores(x, cell_ids, slot_ok, w1, b1, w2, b2,
                                label_map, lmask, n_leaves)
    return compact_mask_counted(scores > threshold, k)


def delta_contains(queries: jnp.ndarray, pts: jnp.ndarray) -> jnp.ndarray:
    """Dense delta-probe ground truth: [B, 4] × [cap, 2] → [B, cap] bool.

    Closed-rectangle containment (the shared ``geometry`` predicate, so
    the convention cannot drift from the refine path's) of each buffer
    point in each query rect; +inf (unstaged/padding) points never hit —
    the same convention the kernel's tile test relies on.
    """
    from repro.core import geometry as geo
    return geo.jnp_contains_point(
        queries.astype(jnp.float32)[:, None, :],
        pts.astype(jnp.float32)[None, :, :])


def delta_probe(queries: jnp.ndarray, pts: jnp.ndarray, k: int
                ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Ground truth for ``kernels.delta_probe``: dense containment mask →
    ``compact_mask_counted``. Returns ``(slot_idx [B, k], valid, count)``
    with slots in buffer (= insertion) order.
    """
    from repro.core.traversal import compact_mask_counted
    return compact_mask_counted(delta_contains(queries, pts), k)


def leaf_refine(queries: jnp.ndarray, ex: jnp.ndarray, ey: jnp.ndarray,
                leaf_idx: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """queries [B,4], ex/ey [L,M], leaf_idx [B,K], valid [B,K] → [B,K,M]."""
    gx = ex[leaf_idx].astype(jnp.float32)       # [B, K, M]
    gy = ey[leaf_idx].astype(jnp.float32)
    q = queries.astype(jnp.float32)
    x0, y0, x1, y1 = (q[:, i][:, None, None] for i in range(4))
    ok = (gx >= x0) & (gx <= x1) & (gy >= y0) & (gy <= y1)
    return ok & (valid[:, :, None] > 0)


def knn_browse(centers: jnp.ndarray, ex: jnp.ndarray, ey: jnp.ndarray,
               leaf_idx: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """centers [B,3] (cx,cy,r²), ex/ey [L,M], leaf_idx/valid [B,K]
    → d2 [B,K,M] f32 (+inf outside the radius / invalid slots).

    Ground truth for ``kernels.knn_browse``: squared Euclidean distance
    from each gathered leaf entry to the query center, masked to +inf
    when the entry lies outside the probed radius or the slot is
    invalid. +inf-padded entries yield +inf distance by arithmetic —
    the identical term order (dx·dx + dy·dy) keeps the kernel twin
    bit-exact.
    """
    gx = ex[leaf_idx].astype(jnp.float32)       # [B, K, M]
    gy = ey[leaf_idx].astype(jnp.float32)
    q = centers.astype(jnp.float32)
    cx = q[:, 0][:, None, None]
    cy = q[:, 1][:, None, None]
    r2 = q[:, 2][:, None, None]
    dx = gx - cx
    dy = gy - cy
    d2 = dx * dx + dy * dy
    ok = (d2 <= r2) & (valid[:, :, None] > 0)
    return jnp.where(ok, d2, jnp.inf)


def forest_infer(sel: jnp.ndarray, thresh: jnp.ndarray,
                 tables: jnp.ndarray) -> jnp.ndarray:
    """sel [B,T,D], thresh [T,D], tables [T,2^D,C] → scores [B,C]."""
    B, T, D = sel.shape
    bits = (sel.astype(jnp.float32) > thresh[None].astype(jnp.float32))
    powers = 2 ** jnp.arange(D - 1, -1, -1, dtype=jnp.int32)
    leaf = jnp.sum(bits.astype(jnp.int32) * powers[None, None, :], axis=-1)
    # [B, T] leaf ids → gather votes per tree, sum over trees
    votes = jax.vmap(lambda tb, lf: tb[lf], in_axes=(0, 1),
                     out_axes=1)(tables.astype(jnp.float32), leaf)  # [B,T,C]
    return jnp.sum(votes, axis=1)


def forest_infer_percell(sel: jnp.ndarray, thresh: jnp.ndarray,
                         tables: jnp.ndarray) -> jnp.ndarray:
    """Per-tree votes (no cross-tree sum): sel [B,T,D] → [B, T, C]."""
    B, T, D = sel.shape
    bits = (sel.astype(jnp.float32) > thresh[None].astype(jnp.float32))
    powers = 2 ** jnp.arange(D - 1, -1, -1, dtype=jnp.int32)
    leaf = jnp.sum(bits.astype(jnp.int32) * powers[None, None, :], axis=-1)
    return jax.vmap(lambda tb, lf: tb[lf], in_axes=(0, 1),
                    out_axes=1)(tables.astype(jnp.float32), leaf)


def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
         u: jnp.ndarray) -> jnp.ndarray:
    """Naive sequential RWKV-6 scan.

    r/k/w: [BH, T, dk], v: [BH, T, dv], u: [BH, dk] → y [BH, T, dv]
        y_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ)
        S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    """
    r = r.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = w.astype(jnp.float32)
    u = u.astype(jnp.float32)
    BH, T, dk = r.shape
    dv = v.shape[-1]

    def one(rb, kb, vb, wb, ub):
        def step(S, inp):
            rt, kt, vt, wt = inp
            kv = kt[:, None] * vt[None, :]                # [dk, dv]
            yt = rt @ (S + ub[:, None] * kv)              # [dv]
            return wt[:, None] * S + kv, yt

        S0 = jnp.zeros((dk, dv), jnp.float32)
        _, yb = jax.lax.scan(step, S0, (rb, kb, vb, wb))
        return yb

    return jax.vmap(one)(r, k, v, w, u)
