"""Pallas TPU kernel: fused single-pass root→leaf R-tree traversal.

The level-synchronous traversal in ``repro.core.traversal`` launches one
dense ``[B, N_level]`` cross-intersection *per tree level* and round-trips
full boolean frontier masks through HBM between launches. This kernel walks
**all** levels in a single ``pallas_call``:

* Internal levels are tiny (they shrink geometrically from the leaves) and
  replicated, so their MBRs fit in VMEM whole. The frontier mask for a
  query-tile is computed once per query-tile (``j == 0``) and kept resident
  in a VMEM scratch buffer across all leaf tiles of that query-tile — it
  never touches HBM.

* Frontier expansion (``mask[:, parent]``) is rewritten as a one-hot matmul
  so it runs on the MXU instead of a lane-dimension gather (which Mosaic
  does not vectorize): ``alive = mask_f32 @ onehot(parent)``. The one-hot is
  built *inside* the kernel from the ``[1, N]`` int32 parent row with a
  broadcasted-iota compare, so no O(N_prev·N) matrix ever crosses HBM.

* The leaf level is tiled over the grid's minor axis. A ``pl.when`` guard
  skips the per-leaf-tile rectangle-intersection entirely when the one-hot
  expansion shows the whole tile is dead (every parent of every leaf in the
  tile failed), so dead subtrees generate no VPU work — the paper's "skip
  extraneous node accesses", applied to the traversal itself.

Two epilogues share that walk:

* ``traverse_fused_t`` writes the final ``[B, L]`` visited-leaf mask (the
  labels/α/training form — downstream consumers need the dense mask).

* ``traverse_compact_t`` never writes the mask at all: a compaction
  epilogue ranks each query-tile's set leaves by exclusive prefix count
  (the same cumsum-rank scheme as ``core.traversal.compact_mask``, with the
  running per-row rank base carried across leaf tiles in the revisited
  output block) and scatters the first ``k`` leaf ids into a ``[B, K]``
  slot table plus a ``[B, 1]`` per-row count. The serving path feeds those
  slots straight into the scalar-prefetch ``leaf_refine`` kernel, so the
  ``[B, L]`` mask never round-trips through HBM between traversal and
  refinement.

Layout: rectangles arrive transposed/planar (``[4, N]``) as in
``mbr_intersect.py``; parent index rows are ``[1, N]`` int32. ``ops.py``
handles padding (never-intersecting rects; parent = 0) and transposition.
Padding-lane parents point at real (or padding) nodes, which is harmless:
a padding rect can never intersect, so its mask lane is always dead.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEF_TB = 256    # query-tile (sublane axis)
DEF_TL = 512    # leaf-tile (lane axis, multiple of 128)
SUB_TL = 512    # interpret-form early-exit subtile within the leaf tile
LANE = 128      # internal-level width quantum
# Slot-chunk width for the TPU-form compaction epilogue: the rank-equality
# scatter materializes a [TB, TL, COMPACT_KC] compare per chunk, so the
# chunk width bounds that transient (counted by vmem_estimate_compact).
COMPACT_KC = 8
# VMEM budget (bytes) for the TPU-form kernel's resident working set —
# frontier scratch, replicated internal-level operands, and the largest
# one-hot expansion matrix. Real VMEM is ~16 MiB/core; leave headroom for
# double buffering. ops.py estimates the working set per tree and routes
# over-budget trees to the ancestor-sliced form (per-level kernel loop as
# the last resort). Overridable via the REPRO_VMEM_BUDGET env var (bytes;
# read once at import) so the gate can be tuned per platform — and so
# tests can force every dispatch rung deterministically.
VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET"
DEF_VMEM_BUDGET = 8 * 1024 * 1024


def _read_vmem_budget(env: dict | None = None) -> int:
    """Parse the budget override (invalid / non-positive values fall back
    to the default — a typo'd env var must not disable every kernel)."""
    raw = (env if env is not None else os.environ).get(VMEM_BUDGET_ENV, "")
    try:
        v = int(raw)
    except (TypeError, ValueError):
        return DEF_VMEM_BUDGET
    return v if v > 0 else DEF_VMEM_BUDGET


VMEM_BUDGET = _read_vmem_budget()

# ---------------------------------------------------------------------------
# Autotune cache: the constants above are hand-picked fallbacks; a sweep
# (``benchmarks/autotune.py``) measures real tree shapes and caches the
# winning tiles per (form, B, L, height) key. ``ops.py`` consults the cache
# before every fused dispatch and only then falls back to the defaults.
# ---------------------------------------------------------------------------
AUTOTUNE_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DEF_AUTOTUNE_CACHE = os.path.join(os.path.dirname(__file__),
                                  "autotune_cache.json")
_TUNABLE_KEYS = ("tb", "tl", "sub_tl", "kc")


def autotune_cache_path() -> str:
    return os.environ.get(AUTOTUNE_CACHE_ENV, DEF_AUTOTUNE_CACHE)


@functools.lru_cache(maxsize=8)
def _load_autotune(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def tune_key(B: int, L: int, n_levels: int, interp: bool) -> str:
    """Cache key for one dispatch shape (exact match, no interpolation)."""
    return f"{'interp' if interp else 'tpu'}:B{B}:L{L}:H{n_levels}"


def tune_key_sliced(B: int, L: int, n_levels: int, interp: bool) -> str:
    """Cache key for the ancestor-sliced form (own knob space: its ``tl``
    is the slice granularity baked into the table, not a block choice)."""
    return f"sliced-{'interp' if interp else 'tpu'}:B{B}:L{L}:H{n_levels}"


def tuned_tiles(B: int, L: int, n_levels: int, interp: bool) -> dict:
    """Cached tile choice for a shape: subset of {tb, tl, sub_tl, kc}.

    Empty dict when the shape was never swept (or the cache is absent) —
    callers then use the hand-picked defaults. Values are sanitized to the
    kernels' alignment contracts so a stale or hand-edited cache can only
    cost performance, never correctness.
    """
    return tuned_tiles_for_key(tune_key(B, L, n_levels, interp))


def tuned_tiles_for_key(key: str) -> dict:
    """Sanitized cache lookup by explicit key (shared with other kernel
    families — ``mlp_infer`` keys its own form space into the same cache)."""
    ent = _load_autotune(autotune_cache_path()).get(key, {})
    out = {}
    for k in _TUNABLE_KEYS:
        if k in ent:
            v = int(ent[k])
            if k == "tb":
                v = max(8, v // 8 * 8)      # sublane multiple
            if k in ("tl", "sub_tl"):
                v = max(LANE, v // LANE * LANE)
            if k == "kc" and (v < 1 or LANE % v != 0):
                continue   # kc must divide the lane-padded slot width
            out[k] = max(1, v)
    return out


def vmem_estimate(int_widths_padded: Sequence[int], tb: int, tl: int) -> int:
    """Rough VMEM working-set bytes for the fused kernel.

    ``int_widths_padded``: lane-padded internal level widths, root first.
    Counts the frontier scratch, all replicated internal operands (MBRs +
    parent rows), the query/leaf/output tiles, and the largest transient
    one-hot matmul operand (consecutive internal pairs and the leaf
    expansion) — the term the frontier width alone does not bound.
    """
    n_last = int_widths_padded[-1]
    est = tb * n_last * 4                                   # scratch
    est += sum(4 * n * 4 + n * 4 for n in int_widths_padded)  # mbrs+parents
    est += 4 * tb * 4 + 4 * tl * 4 + 1 * tl * 4 + tb * tl   # q, leaf, out
    onehots = [a * b for a, b in zip(int_widths_padded[:-1],
                                     int_widths_padded[1:])]
    onehots.append(n_last * tl)
    est += max(onehots) * 4
    return est


def vmem_estimate_compact(int_widths_padded: Sequence[int], tb: int, tl: int,
                          kp: int, tpu_form: bool = True,
                          kc: int = COMPACT_KC) -> int:
    """VMEM working-set bytes for the fused traversal+compaction kernel.

    The walk terms match ``vmem_estimate``; the compaction epilogue swaps
    the [tb, tl] mask output tile for the [tb, kp] slot table + [tb, 1]
    count, and adds the largest epilogue transient. That transient is
    form-dependent: the TPU form's chunked rank-equality scatter
    materializes a [tb, tl, COMPACT_KC] compare, while the interpret form's
    binary search only needs the [tb, tl] prefix-count — gating the
    interpret run (whose ``tl`` is the whole folded leaf axis) on the TPU
    chunk transient would spuriously push CPU runs onto the per-level
    fallback.
    """
    est = vmem_estimate(int_widths_padded, tb, tl)
    est -= tb * tl                          # no [tb, tl] bool output tile
    est += tb * (kp + 1) * 4                # slot table + count accumulators
    est += tb * tl * (kc if tpu_form else 1) * 4  # epilogue transient
    return est


def vmem_estimate_sliced(widths: Sequence[int], tb: int, tl: int,
                         tpu_form: bool = True) -> int:
    """VMEM working-set bytes for the ancestor-sliced fused traversal.

    ``widths``: per-internal-level *window* widths (the AncestorTable's,
    root first) — the sliced kernel stages one window per level instead of
    the whole level, and recomputes the walk per (query, leaf) tile, so
    there is no persistent frontier scratch; the frontier exists only as a
    ``[tb, widths[-1]]`` transient. The one-hot expansion operands shrink
    to window×window; the interpret form gathers instead (its transient is
    the ``[tb, tl]`` mask), mirroring ``vmem_estimate_compact``'s
    form-awareness so CPU runs aren't gated on MXU-only transients.
    """
    w_last = widths[-1]
    est = sum(4 * w * 4 + w * 4 for w in widths)     # window mbrs + parents
    est += 4 * tb * 4 + 4 * tl * 4 + tl * 4 + tb * tl  # q, leaf, out
    est += tb * w_last * 4                            # frontier transient
    if tpu_form:
        onehots = [a * b for a, b in zip(widths[:-1], widths[1:])]
        onehots.append(w_last * tl)
        est += max(onehots) * 4
    else:
        est += tb * tl * 4
    return est


def vmem_estimate_sliced_compact(widths: Sequence[int], tb: int, tl: int,
                                 kp: int, tpu_form: bool = True,
                                 kc: int = COMPACT_KC) -> int:
    """Sliced-walk analogue of ``vmem_estimate_compact``: same window
    terms as ``vmem_estimate_sliced``, the mask output tile swapped for
    the slot table + count accumulators plus the epilogue transient."""
    est = vmem_estimate_sliced(widths, tb, tl, tpu_form=tpu_form)
    est -= tb * tl                          # no [tb, tl] bool output tile
    est += tb * (kp + 1) * 4                # slot table + count accumulators
    est += tb * tl * (kc if tpu_form else 1) * 4  # epilogue transient
    return est


def _tile_intersect(q, m):
    """q [4, TB] × m [4, TN] values → [TB, TN] bool (closed rectangles).

    Takes materialized values, not refs: each ref index-read costs a masked
    load (emulated one-by-one in interpret mode) — callers read each block
    once and slice the value.
    """
    qx0 = q[0, :][:, None]
    qy0 = q[1, :][:, None]
    qx1 = q[2, :][:, None]
    qy1 = q[3, :][:, None]
    mx0 = m[0, :][None, :]
    my0 = m[1, :][None, :]
    mx1 = m[2, :][None, :]
    my1 = m[3, :][None, :]
    return (qx0 <= mx1) & (mx0 <= qx1) & (qy0 <= my1) & (my0 <= qy1)


def _expand_mxu(mask_f32, parent_row, n_prev):
    """Frontier expansion: alive[b, c] > 0 iff mask[b, parent_row[c]] set.

    mask_f32 [TB, n_prev] (0/1), parent_row [n] i32 → alive [TB, n] f32.
    A gather along the lane dimension is what this *means*, but Mosaic does
    not vectorize lane gathers — so the hardware form is a one-hot matmul on
    the MXU. The one-hot is built in VMEM from the int32 parent row with a
    broadcasted-iota compare; no O(n_prev·n) matrix ever crosses HBM.
    """
    n = parent_row.shape[0]
    onehot = (parent_row[None, :] ==
              jax.lax.broadcasted_iota(jnp.int32, (n_prev, n), 0)
              ).astype(jnp.float32)
    return jax.lax.dot(mask_f32, onehot, preferred_element_type=jnp.float32)


def _walk_internal_tpu(q, int_m, int_p, frontier_ref, n_int: int):
    """TPU-form internal walk: root→last internal level, one-hot MXU
    expansion per level, final frontier written to the VMEM scratch."""
    # Root level: plain intersection (no parent).
    mask = _tile_intersect(q, int_m[0][:, :]).astype(jnp.float32)
    for l in range(1, n_int):
        alive = _expand_mxu(mask, int_p[l - 1][0, :],
                            int_m[l - 1].shape[1])
        hit = _tile_intersect(q, int_m[l][:, :])
        mask = jnp.where((alive > 0.0) & hit, 1.0, 0.0)
    frontier_ref[:, :] = mask


def _leaf_mask_interp(q, int_m, int_p, lm_v, leaf_par, n_int: int,
                      tb: int, tl: int, sub_tl: int = SUB_TL):
    """Interpret-form leaf mask as a *value* (no ref writes).

    Same semantics as the TPU form, restructured for the emulated grid
    loop, which materializes every intermediate and turns any ref-touching
    ``pl.when`` into full-buffer functionalization copies:

    * early exit runs as *value-level* ``lax.cond``s (branches return
      values, touch no refs) — an outer cond over the whole tile, then one
      per SUB-wide leaf subtile, each gated on a bounding box of the
      subtile's leaf MBRs computed in-kernel, so dead subtrees skip their
      intersection entirely;
    * the internal walk runs inside the outer live branch — one
      concatenated intersection over all internal levels, boolean masks end
      to end, lane gathers instead of one-hot matmuls.
    """

    def subtile_hit(sm):
        return jnp.any((q[0, :] <= jnp.max(sm[2, :]))
                       & (jnp.min(sm[0, :]) <= q[2, :])
                       & (q[1, :] <= jnp.max(sm[3, :]))
                       & (jnp.min(sm[1, :]) <= q[3, :]))

    def live():
        int_all = jnp.concatenate([m[:, :] for m in int_m], axis=1)
        hit_all = _tile_intersect(q, int_all)        # [TB, ΣN_l]
        off = int_m[0].shape[1]
        mask = hit_all[:, :off]
        for l in range(1, n_int):
            n = int_m[l].shape[1]
            mask = mask[:, int_p[l - 1][0, :]] & \
                hit_all[:, off:off + n]
            off += n
        outs = []
        for s in range(0, tl, sub_tl):
            e = min(s + sub_tl, tl)
            sm = lm_v[:, s:e]
            outs.append(jax.lax.cond(
                subtile_hit(sm),
                lambda sm=sm, s=s, e=e: mask[:, leaf_par[s:e]]
                & _tile_intersect(q, sm),
                lambda e=e, s=s: jnp.zeros((tb, e - s), jnp.bool_)))
        return outs[0] if len(outs) == 1 else \
            jnp.concatenate(outs, axis=1)

    tile_live = subtile_hit(lm_v)     # O(TB·4) bbox check, reused by callers
    mask = jax.lax.cond(tile_live, live,
                        lambda: jnp.zeros((tb, tl), jnp.bool_))
    return mask, tile_live


def _make_kernel(n_int: int, tb: int, tl: int, tpu_form: bool,
                 sub_tl: int = SUB_TL):
    """Build the mask-output kernel body for ``n_int`` internal levels.

    ``tpu_form=True`` is the hardware graph: one-hot-matmul expansion on the
    MXU, the internal walk run once per query-tile under ``pl.when(j == 0)``
    with the frontier persisted in VMEM scratch, and a ``pl.when`` tile-level
    early exit so leaf tiles under a dead frontier skip the intersection
    (predication is ~free on TPU).

    ``tpu_form=False`` is the branch-free interpret form: same semantics via
    ``_leaf_mask_interp`` — in interpret mode every ``pl.when`` lowers to a
    ``lax.cond`` that functionalizes the output/scratch refs (full-array
    copies per branch), so predication there *costs* rather than saves.
    Tests validate both forms.
    """

    def kernel(*refs):
        q_ref = refs[0]
        int_m = refs[1:1 + n_int]                       # [4, N_l] each
        int_p = refs[1 + n_int:2 * n_int]               # [1, N_l], levels 1..
        leaf_m = refs[2 * n_int]                        # [4, TL]
        leaf_p = refs[2 * n_int + 1]                    # [1, TL]
        o_ref = refs[2 * n_int + 2]                     # [TB, TL] bool
        frontier_ref = refs[2 * n_int + 3]              # [TB, N_last] f32

        q = q_ref[:, :]                                  # [4, TB]

        if tpu_form:
            j = pl.program_id(1)

            @pl.when(j == 0)
            def _walk_internal():
                _walk_internal_tpu(q, int_m, int_p, frontier_ref, n_int)

            frontier = frontier_ref[:, :]                # [TB, N_last]
            alive = _expand_mxu(frontier, leaf_p[0, :], frontier.shape[1])
            any_live = jnp.max(alive) > 0.0

            @pl.when(jnp.logical_not(any_live))
            def _dead_tile():
                o_ref[:, :] = jnp.zeros((tb, tl), jnp.bool_)

            @pl.when(any_live)
            def _live_tile():
                o_ref[:, :] = (alive > 0.0) & _tile_intersect(
                    q, leaf_m[:, :])
        else:
            o_ref[:, :] = _leaf_mask_interp(
                q, int_m, int_p, leaf_m[:, :], leaf_p[0, :], n_int, tb,
                tl, sub_tl)[0]

    return kernel


# The compaction epilogues moved to ``kernels.epilogue`` (they are shared
# with ``mlp_infer`` and ``delta_probe``); the old private names stay
# importable here for back-compat.
from repro.kernels.epilogue import (  # noqa: E402
    compact_epilogue_interp as _compact_epilogue_interp,
    compact_epilogue_tpu as _compact_epilogue_tpu,
)


def _make_compact_kernel(n_int: int, tb: int, tl: int, kp: int, n_j: int,
                         tpu_form: bool, sub_tl: int = SUB_TL,
                         kc: int = COMPACT_KC):
    """Kernel body: fused traversal + compaction epilogue.

    Instead of writing the ``[TB, TL]`` visited mask, each leaf tile ranks
    its set leaves by exclusive prefix count — continued across tiles via a
    running per-row total in the revisited ``[TB, 1]`` count block — and
    scatters the global leaf ids of ranks ``< kp`` into the revisited
    ``[TB, KP]`` slot block (leaf-ID order, exactly ``compact_mask``'s
    cumsum-rank scheme). Both output blocks map to ``(i, 0)`` so they stay
    VMEM-resident across the whole leaf-tile sweep of a query tile: the
    mask never exists outside registers/VMEM.

    ``tpu_form=True`` realizes the scatter as ``COMPACT_KC``-wide chunks of
    rank-equality compares + lane-sum (ranks are unique per row, so sum ==
    select — Mosaic vectorizes dense compare/reduce where it would not a
    lane scatter); each chunk is ``pl.when``-guarded by the tile's
    [min, max] rank range so a tile only touches the slot chunks it can
    actually fill, and the whole epilogue is skipped for dead tiles.
    ``tpu_form=False`` fills slots by value-level rowwise binary search of
    each slot's rank over the tile's inclusive prefix count — the same
    searchsorted scheme as ``compact_mask_counted``, unconditional value
    ops (interpret mode functionalizes ref-touching conds).
    """

    def kernel(*refs):
        q_ref = refs[0]
        int_m = refs[1:1 + n_int]                       # [4, N_l] each
        int_p = refs[1 + n_int:2 * n_int]               # [1, N_l], levels 1..
        leaf_m = refs[2 * n_int]                        # [4, TL]
        leaf_p = refs[2 * n_int + 1]                    # [1, TL]
        idx_ref = refs[2 * n_int + 2]                   # [TB, KP] i32 (i, 0)
        cnt_ref = refs[2 * n_int + 3]                   # [TB, 1] i32 (i, 0)
        frontier_ref = refs[2 * n_int + 4]              # [TB, N_last] f32

        q = q_ref[:, :]                                  # [4, TB]
        j = pl.program_id(1)

        if tpu_form:
            col = j * tl + jax.lax.broadcasted_iota(jnp.int32, (tb, tl), 1)

            @pl.when(j == 0)
            def _init():
                idx_ref[:, :] = jnp.zeros((tb, kp), jnp.int32)
                cnt_ref[:, :] = jnp.zeros((tb, 1), jnp.int32)
                _walk_internal_tpu(q, int_m, int_p, frontier_ref, n_int)

            frontier = frontier_ref[:, :]                # [TB, N_last]
            alive = _expand_mxu(frontier, leaf_p[0, :], frontier.shape[1])
            any_live = jnp.max(alive) > 0.0

            @pl.when(any_live)
            def _live_tile():
                mask = (alive > 0.0) & _tile_intersect(q, leaf_m[:, :])
                _compact_epilogue_tpu(mask, col, idx_ref, cnt_ref, kp, kc)
        else:
            mask, tile_live = _leaf_mask_interp(
                q, int_m, int_p, leaf_m[:, :], leaf_p[0, :], n_int, tb, tl,
                sub_tl)
            if n_j == 1:
                # Whole leaf axis in one tile (the usual interpret fold):
                # no rank base to carry — the epilogue is exactly
                # ``compact_mask_counted``, with a value-level early exit
                # on the traversal's own bbox liveness (information the
                # out-of-kernel compact never has; coarser than
                # ``jnp.any(mask)`` but free — the any() reduction would
                # itself scan the whole tile).
                def live():
                    m = mask.astype(jnp.int32)
                    cs = jnp.cumsum(m, axis=1)
                    targets = 1 + jax.lax.iota(jnp.int32, kp)
                    pos = jax.vmap(lambda c: jnp.searchsorted(
                        c, targets, side="left"))(cs)
                    idx = jnp.where(targets[None, :] <= cs[:, -1][:, None],
                                    pos.astype(jnp.int32), 0)
                    return idx, cs[:, -1][:, None]

                idx, cnt = jax.lax.cond(
                    tile_live, live,
                    lambda: (jnp.zeros((tb, kp), jnp.int32),
                             jnp.zeros((tb, 1), jnp.int32)))
                idx_ref[:, :] = idx
                cnt_ref[:, :] = cnt
            else:
                _compact_epilogue_interp(mask, j, tl, kp, idx_ref, cnt_ref)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("tb", "tl", "sub_tl", "interpret",
                                    "tpu_form"))
def traverse_fused_t(q_t: jnp.ndarray,
                     int_mbrs_t: Sequence[jnp.ndarray],
                     int_parents: Sequence[jnp.ndarray],
                     leaf_mbrs_t: jnp.ndarray,
                     leaf_parent: jnp.ndarray, *,
                     tb: int = DEF_TB, tl: int = DEF_TL,
                     sub_tl: int = SUB_TL,
                     interpret: bool = False,
                     tpu_form: bool | None = None) -> jnp.ndarray:
    """Transposed-layout entry point.

    ``q_t`` [4, B]; ``int_mbrs_t`` one [4, N_l] per internal level (root
    first, each N_l a multiple of 128); ``int_parents`` one [1, N_l] i32 per
    internal level *below the root*; ``leaf_mbrs_t`` [4, L];
    ``leaf_parent`` [1, L] i32 (into the last internal level). B must be a
    multiple of ``tb`` and L of ``tl`` (ops.py pads). Returns [B, L] bool.

    ``tpu_form`` defaults to ``not interpret``; pass ``tpu_form=True`` with
    ``interpret=True`` to validate the exact hardware graph off-TPU.
    """
    if tpu_form is None:
        tpu_form = not interpret
    n_int = len(int_mbrs_t)
    assert n_int >= 1 and len(int_parents) == n_int - 1
    _, B = q_t.shape
    _, L = leaf_mbrs_t.shape
    assert B % tb == 0 and L % tl == 0, (B, L, tb, tl)
    n_last = int_mbrs_t[-1].shape[1]
    grid = (B // tb, L // tl)

    rep = lambda shape: pl.BlockSpec(shape, lambda i, j: (0, 0))  # noqa: E731
    in_specs = [pl.BlockSpec((4, tb), lambda i, j: (0, i))]
    in_specs += [rep((4, m.shape[1])) for m in int_mbrs_t]
    in_specs += [rep((1, p.shape[1])) for p in int_parents]
    in_specs += [
        pl.BlockSpec((4, tl), lambda i, j: (0, j)),
        pl.BlockSpec((1, tl), lambda i, j: (0, j)),
    ]

    args = ([q_t.astype(jnp.float32)]
            + [m.astype(jnp.float32) for m in int_mbrs_t]
            + [p.astype(jnp.int32) for p in int_parents]
            + [leaf_mbrs_t.astype(jnp.float32),
               leaf_parent.astype(jnp.int32)])

    return pl.pallas_call(
        _make_kernel(n_int, tb, tl, tpu_form=tpu_form, sub_tl=sub_tl),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tb, tl), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, L), jnp.bool_),
        scratch_shapes=[pltpu.VMEM((tb, n_last), jnp.float32)],
        interpret=interpret,
    )(*args)


@functools.partial(jax.jit,
                   static_argnames=("k", "tb", "tl", "sub_tl", "kc",
                                    "interpret", "tpu_form"))
def traverse_compact_t(q_t: jnp.ndarray,
                       int_mbrs_t: Sequence[jnp.ndarray],
                       int_parents: Sequence[jnp.ndarray],
                       leaf_mbrs_t: jnp.ndarray,
                       leaf_parent: jnp.ndarray, *,
                       k: int,
                       tb: int = DEF_TB, tl: int = DEF_TL,
                       sub_tl: int = SUB_TL, kc: int = COMPACT_KC,
                       interpret: bool = False,
                       tpu_form: bool | None = None
                       ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Transposed-layout fused traversal + compaction entry point.

    Operand layout and padding contract are identical to
    ``traverse_fused_t``. Returns ``(leaf_idx [B, KP] i32, count [B, 1]
    i32)`` with ``KP = k`` rounded up to ``LANE`` in the TPU form (lane
    tiling) and exactly ``k`` in the interpret form: row ``b``'s first
    ``min(count[b], KP)`` slots hold the ids of its visited leaves in
    leaf-ID order (exactly ``compact_mask``'s cumsum-rank order); slots past
    the count are 0. The ``[B, L]`` visited mask is never written — callers
    slice ``[:, :k]``, derive validity from ``count``, and overflow as
    ``count > k``.
    """
    if tpu_form is None:
        tpu_form = not interpret
    n_int = len(int_mbrs_t)
    assert n_int >= 1 and len(int_parents) == n_int - 1
    _, B = q_t.shape
    _, L = leaf_mbrs_t.shape
    assert B % tb == 0 and L % tl == 0, (B, L, tb, tl)
    kp = (k + LANE - 1) // LANE * LANE if tpu_form else k
    assert kp % kc == 0 or not tpu_form, (kp, kc)
    n_last = int_mbrs_t[-1].shape[1]
    grid = (B // tb, L // tl)

    rep = lambda shape: pl.BlockSpec(shape, lambda i, j: (0, 0))  # noqa: E731
    in_specs = [pl.BlockSpec((4, tb), lambda i, j: (0, i))]
    in_specs += [rep((4, m.shape[1])) for m in int_mbrs_t]
    in_specs += [rep((1, p.shape[1])) for p in int_parents]
    in_specs += [
        pl.BlockSpec((4, tl), lambda i, j: (0, j)),
        pl.BlockSpec((1, tl), lambda i, j: (0, j)),
    ]

    args = ([q_t.astype(jnp.float32)]
            + [m.astype(jnp.float32) for m in int_mbrs_t]
            + [p.astype(jnp.int32) for p in int_parents]
            + [leaf_mbrs_t.astype(jnp.float32),
               leaf_parent.astype(jnp.int32)])

    return pl.pallas_call(
        _make_compact_kernel(n_int, tb, tl, kp, L // tl, tpu_form=tpu_form,
                             sub_tl=sub_tl, kc=kc),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((tb, kp), lambda i, j: (i, 0)),
                   pl.BlockSpec((tb, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, kp), jnp.int32),
                   jax.ShapeDtypeStruct((B, 1), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((tb, n_last), jnp.float32)],
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Ancestor-sliced form: same walk, windowed operands.
#
# The full-VMEM kernels above replicate every internal level into VMEM —
# fine while the tree is small, impossible past the budget. The sliced form
# exploits the flatten's sibling contiguity (each leaf tile's ancestor set
# per level is a contiguous index range): a host-built AncestorTable
# (``core.device_tree.build_ancestor_table``) records one block-aligned
# window per (internal level, leaf tile), the window starts ride in through
# scalar prefetch, and each grid cell's BlockSpecs stage only its tile's
# windows. Parent indices are rebased in-kernel (global − window start);
# out-of-window relative indices can only belong to padding lanes, whose
# never-intersecting MBRs are dead regardless (true-range ancestors land
# in-window by the table's min/max construction). The walk reruns per
# (query, leaf) tile over the small windows instead of persisting a
# frontier scratch across leaf tiles — that rerun is the price of a VMEM
# working set that no longer grows with the tree.
# ---------------------------------------------------------------------------


def _walk_sliced_tpu(q, int_m, int_rel, widths, n_int: int):
    """TPU-form windowed internal walk → frontier value [TB, widths[-1]].

    ``int_m``: per-level window MBR blocks; ``int_rel``: per-level
    window-relative parent rows (levels 1..) as values.
    """
    mask = _tile_intersect(q, int_m[0][:, :]).astype(jnp.float32)
    for l in range(1, n_int):
        alive = _expand_mxu(mask, int_rel[l - 1], widths[l - 1])
        hit = _tile_intersect(q, int_m[l][:, :])
        mask = jnp.where((alive > 0.0) & hit, 1.0, 0.0)
    return mask


def _leaf_mask_interp_sliced(q, int_m, int_rel, lm_v, leaf_rel, widths,
                             n_int: int, tb: int, tl: int,
                             sub_tl: int = SUB_TL):
    """Interpret-form sliced leaf mask as a value (no ref writes).

    Mirrors ``_leaf_mask_interp`` — value-level ``lax.cond`` early exits
    on in-kernel bounding boxes, lane gathers instead of one-hot matmuls —
    but over windowed operands: gathers use clamped window-relative parent
    indices with an explicit in-window validity mask (clamping alone would
    alias padding lanes onto real window slots).
    """

    def subtile_hit(sm):
        return jnp.any((q[0, :] <= jnp.max(sm[2, :]))
                       & (jnp.min(sm[0, :]) <= q[2, :])
                       & (q[1, :] <= jnp.max(sm[3, :]))
                       & (jnp.min(sm[1, :]) <= q[3, :]))

    def live():
        int_all = jnp.concatenate([m[:, :] for m in int_m], axis=1)
        hit_all = _tile_intersect(q, int_all)        # [TB, Σwidths]
        off = widths[0]
        mask = hit_all[:, :off]
        for l in range(1, n_int):
            rel = int_rel[l - 1]
            ok = (rel >= 0) & (rel < widths[l - 1])
            g = mask[:, jnp.clip(rel, 0, widths[l - 1] - 1)]
            mask = g & ok[None, :] & hit_all[:, off:off + widths[l]]
            off += widths[l]
        outs = []
        w_last = widths[-1]
        for s in range(0, tl, sub_tl):
            e = min(s + sub_tl, tl)
            sm = lm_v[:, s:e]
            rel = leaf_rel[s:e]
            ok = (rel >= 0) & (rel < w_last)
            outs.append(jax.lax.cond(
                subtile_hit(sm),
                lambda sm=sm, rel=rel, ok=ok:
                mask[:, jnp.clip(rel, 0, w_last - 1)] & ok[None, :]
                & _tile_intersect(q, sm),
                lambda e=e, s=s: jnp.zeros((tb, e - s), jnp.bool_)))
        return outs[0] if len(outs) == 1 else \
            jnp.concatenate(outs, axis=1)

    tile_live = subtile_hit(lm_v)
    mask = jax.lax.cond(tile_live, live,
                        lambda: jnp.zeros((tb, tl), jnp.bool_))
    return mask, tile_live


def _sliced_refs(refs, n_int: int):
    """Unpack the sliced kernels' ref list (scalar-prefetch ref first)."""
    s_ref = refs[0]
    q_ref = refs[1]
    int_m = refs[2:2 + n_int]                        # [4, w_l] windows
    int_p = refs[2 + n_int:1 + 2 * n_int]            # [1, w_l], levels 1..
    leaf_m = refs[1 + 2 * n_int]                     # [4, TL]
    leaf_p = refs[2 + 2 * n_int]                     # [1, TL]
    return s_ref, q_ref, int_m, int_p, leaf_m, leaf_p


def _sliced_rel_rows(s_ref, int_p, leaf_p, widths, n_int: int, j):
    """Window-relative parent rows (values): global − window start."""
    int_rel = [int_p[l - 1][0, :] - s_ref[l - 1, j] * widths[l - 1]
               for l in range(1, n_int)]
    leaf_rel = leaf_p[0, :] - s_ref[n_int - 1, j] * widths[n_int - 1]
    return int_rel, leaf_rel


def _make_sliced_kernel(n_int: int, widths, tb: int, tl: int,
                        tpu_form: bool, sub_tl: int = SUB_TL):
    """Mask-output kernel body over windowed operands.

    Same forms as ``_make_kernel``; the walk runs per grid cell over the
    tile's windows (no frontier scratch — nothing persists across ``j``),
    with the same ``pl.when`` dead-tile early exit on the leaf expansion.
    """

    def kernel(*refs):
        s_ref, q_ref, int_m, int_p, leaf_m, leaf_p = _sliced_refs(refs,
                                                                  n_int)
        o_ref = refs[3 + 2 * n_int]                  # [TB, TL] bool
        j = pl.program_id(1)
        q = q_ref[:, :]
        int_rel, leaf_rel = _sliced_rel_rows(s_ref, int_p, leaf_p, widths,
                                             n_int, j)

        if tpu_form:
            frontier = _walk_sliced_tpu(q, int_m, int_rel, widths, n_int)
            alive = _expand_mxu(frontier, leaf_rel, widths[-1])
            any_live = jnp.max(alive) > 0.0

            @pl.when(jnp.logical_not(any_live))
            def _dead_tile():
                o_ref[:, :] = jnp.zeros((tb, tl), jnp.bool_)

            @pl.when(any_live)
            def _live_tile():
                o_ref[:, :] = (alive > 0.0) & _tile_intersect(
                    q, leaf_m[:, :])
        else:
            o_ref[:, :] = _leaf_mask_interp_sliced(
                q, int_m, int_rel, leaf_m[:, :], leaf_rel, widths, n_int,
                tb, tl, sub_tl)[0]

    return kernel


def _make_sliced_compact_kernel(n_int: int, widths, tb: int, tl: int,
                                kp: int, tpu_form: bool,
                                sub_tl: int = SUB_TL, kc: int = COMPACT_KC):
    """Sliced traversal + the shared compaction epilogues.

    Identical slot semantics to ``_make_compact_kernel`` (revisited
    ``(i, 0)`` output blocks carry the running rank base across leaf
    tiles); only the walk's operands differ. The interpret form always
    uses the cross-tile epilogue — the sliced form exists precisely
    because the leaf axis spans multiple tiles.
    """

    def kernel(*refs):
        s_ref, q_ref, int_m, int_p, leaf_m, leaf_p = _sliced_refs(refs,
                                                                  n_int)
        idx_ref = refs[3 + 2 * n_int]                # [TB, KP] i32 (i, 0)
        cnt_ref = refs[4 + 2 * n_int]                # [TB, 1] i32 (i, 0)
        j = pl.program_id(1)
        q = q_ref[:, :]
        int_rel, leaf_rel = _sliced_rel_rows(s_ref, int_p, leaf_p, widths,
                                             n_int, j)

        if tpu_form:
            col = j * tl + jax.lax.broadcasted_iota(jnp.int32, (tb, tl), 1)

            @pl.when(j == 0)
            def _init():
                idx_ref[:, :] = jnp.zeros((tb, kp), jnp.int32)
                cnt_ref[:, :] = jnp.zeros((tb, 1), jnp.int32)

            frontier = _walk_sliced_tpu(q, int_m, int_rel, widths, n_int)
            alive = _expand_mxu(frontier, leaf_rel, widths[-1])
            any_live = jnp.max(alive) > 0.0

            @pl.when(any_live)
            def _live_tile():
                mask = (alive > 0.0) & _tile_intersect(q, leaf_m[:, :])
                _compact_epilogue_tpu(mask, col, idx_ref, cnt_ref, kp, kc)
        else:
            mask, _ = _leaf_mask_interp_sliced(
                q, int_m, int_rel, leaf_m[:, :], leaf_rel, widths, n_int,
                tb, tl, sub_tl)
            _compact_epilogue_interp(mask, j, tl, kp, idx_ref, cnt_ref)

    return kernel


def _sliced_grid_spec(n_int: int, widths, tb: int, tl: int, grid,
                      out_specs):
    """PrefetchScalarGridSpec shared by both sliced entry points: the
    ``[n_int, n_tiles]`` window-start table is the scalar-prefetch operand,
    and every internal level's BlockSpec indexes its block by the tile's
    prefetched start (index maps receive grid indices then the scalar
    ref)."""
    in_specs = [pl.BlockSpec((4, tb), lambda i, j, s: (0, i))]
    in_specs += [pl.BlockSpec((4, widths[l]),
                              lambda i, j, s, l=l: (0, s[l, j]))
                 for l in range(n_int)]
    in_specs += [pl.BlockSpec((1, widths[l]),
                              lambda i, j, s, l=l: (0, s[l, j]))
                 for l in range(1, n_int)]
    in_specs += [
        pl.BlockSpec((4, tl), lambda i, j, s: (0, j)),
        pl.BlockSpec((1, tl), lambda i, j, s: (0, j)),
    ]
    return pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=out_specs)


@functools.partial(jax.jit,
                   static_argnames=("widths", "tb", "tl", "sub_tl",
                                    "interpret", "tpu_form"))
def traverse_fused_sliced_t(starts: jnp.ndarray,
                            q_t: jnp.ndarray,
                            int_mbrs_t: Sequence[jnp.ndarray],
                            int_parents: Sequence[jnp.ndarray],
                            leaf_mbrs_t: jnp.ndarray,
                            leaf_parent: jnp.ndarray, *,
                            widths: tuple, tb: int = DEF_TB,
                            tl: int = DEF_TL, sub_tl: int = SUB_TL,
                            interpret: bool = False,
                            tpu_form: bool | None = None) -> jnp.ndarray:
    """Ancestor-sliced transposed-layout entry point → [B, L] bool.

    ``starts`` [n_int, L//tl] i32 block-index window starts (the
    AncestorTable's, sharded rows matching the leaf shard if any);
    ``widths`` the matching static window widths. ``int_mbrs_t`` /
    ``int_parents`` follow ``traverse_fused_t``'s layout but each level
    must be padded to a multiple of its window width (ops.py does). B must
    be a multiple of ``tb`` and L of ``tl``.
    """
    if tpu_form is None:
        tpu_form = not interpret
    n_int = len(int_mbrs_t)
    assert n_int >= 1 and len(int_parents) == n_int - 1
    assert len(widths) == n_int and starts.shape[0] == n_int
    _, B = q_t.shape
    _, L = leaf_mbrs_t.shape
    assert B % tb == 0 and L % tl == 0, (B, L, tb, tl)
    assert starts.shape[1] == L // tl, (starts.shape, L, tl)
    for m, w in zip(int_mbrs_t, widths):
        assert m.shape[1] % w == 0, (m.shape, w)
    grid = (B // tb, L // tl)

    args = ([q_t.astype(jnp.float32)]
            + [m.astype(jnp.float32) for m in int_mbrs_t]
            + [p.astype(jnp.int32) for p in int_parents]
            + [leaf_mbrs_t.astype(jnp.float32),
               leaf_parent.astype(jnp.int32)])

    return pl.pallas_call(
        _make_sliced_kernel(n_int, widths, tb, tl, tpu_form=tpu_form,
                            sub_tl=sub_tl),
        grid_spec=_sliced_grid_spec(
            n_int, widths, tb, tl, grid,
            pl.BlockSpec((tb, tl), lambda i, j, s: (i, j))),
        out_shape=jax.ShapeDtypeStruct((B, L), jnp.bool_),
        interpret=interpret,
    )(starts.astype(jnp.int32), *args)


@functools.partial(jax.jit,
                   static_argnames=("k", "widths", "tb", "tl", "sub_tl",
                                    "kc", "interpret", "tpu_form"))
def traverse_compact_sliced_t(starts: jnp.ndarray,
                              q_t: jnp.ndarray,
                              int_mbrs_t: Sequence[jnp.ndarray],
                              int_parents: Sequence[jnp.ndarray],
                              leaf_mbrs_t: jnp.ndarray,
                              leaf_parent: jnp.ndarray, *,
                              k: int, widths: tuple, tb: int = DEF_TB,
                              tl: int = DEF_TL, sub_tl: int = SUB_TL,
                              kc: int = COMPACT_KC,
                              interpret: bool = False,
                              tpu_form: bool | None = None
                              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Ancestor-sliced traversal + compaction → ``(leaf_idx [B, KP] i32,
    count [B, 1] i32)``; operand/slot contracts as ``traverse_compact_t``
    (KP lane-rounded in TPU form, exactly ``k`` interp), windows as
    ``traverse_fused_sliced_t``.
    """
    if tpu_form is None:
        tpu_form = not interpret
    n_int = len(int_mbrs_t)
    assert n_int >= 1 and len(int_parents) == n_int - 1
    assert len(widths) == n_int and starts.shape[0] == n_int
    _, B = q_t.shape
    _, L = leaf_mbrs_t.shape
    assert B % tb == 0 and L % tl == 0, (B, L, tb, tl)
    assert starts.shape[1] == L // tl, (starts.shape, L, tl)
    kp = (k + LANE - 1) // LANE * LANE if tpu_form else k
    assert kp % kc == 0 or not tpu_form, (kp, kc)
    grid = (B // tb, L // tl)

    args = ([q_t.astype(jnp.float32)]
            + [m.astype(jnp.float32) for m in int_mbrs_t]
            + [p.astype(jnp.int32) for p in int_parents]
            + [leaf_mbrs_t.astype(jnp.float32),
               leaf_parent.astype(jnp.int32)])

    return pl.pallas_call(
        _make_sliced_compact_kernel(n_int, widths, tb, tl, kp,
                                    tpu_form=tpu_form, sub_tl=sub_tl,
                                    kc=kc),
        grid_spec=_sliced_grid_spec(
            n_int, widths, tb, tl, grid,
            [pl.BlockSpec((tb, kp), lambda i, j, s: (i, 0)),
             pl.BlockSpec((tb, 1), lambda i, j, s: (i, 0))]),
        out_shape=[jax.ShapeDtypeStruct((B, kp), jnp.int32),
                   jax.ShapeDtypeStruct((B, 1), jnp.int32)],
        interpret=interpret,
    )(starts.astype(jnp.int32), *args)
