"""Pallas TPU kernel: refinement of predicted/visited leaves.

This kernel embodies the paper's core I/O saving on TPU: only the leaf tiles
named in ``leaf_idx`` are pulled HBM→VMEM (via scalar-prefetch BlockSpec
index maps); extraneous leaves generate **no memory traffic at all**. The
per-entry containment test then runs on the VPU over the fetched tile.

Inputs (planar entry layout — see mbr_intersect.py for rationale):
  ``leaf_idx`` [B, K] i32   — leaves to refine per query (scalar-prefetched)
  ``queries``  [B, 4] f32
  ``ex``/``ey``[L, M] f32   — entry coordinates, +inf padded
  ``valid``    [B, K] i32   — slot validity
Output:
  ``inside``   [B, K, M] bool — exact containment per fetched entry
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, q_ref, valid_ref, ex_ref, ey_ref, o_ref):
    # q_ref: [1, 4]; ex/ey_ref: [1, M]; valid_ref: [1, 1]; o_ref: [1, 1, M]
    x0 = q_ref[0, 0]
    y0 = q_ref[0, 1]
    x1 = q_ref[0, 2]
    y1 = q_ref[0, 3]
    ex = ex_ref[0, :]
    ey = ey_ref[0, :]
    ok = (ex >= x0) & (ex <= x1) & (ey >= y0) & (ey <= y1)
    o_ref[0, 0, :] = ok & (valid_ref[0, 0] > 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def leaf_refine(queries: jnp.ndarray, ex: jnp.ndarray, ey: jnp.ndarray,
                leaf_idx: jnp.ndarray, valid: jnp.ndarray, *,
                interpret: bool = False) -> jnp.ndarray:
    """queries [B,4], ex/ey [L,M], leaf_idx [B,K], valid [B,K] → [B,K,M]."""
    B, K = leaf_idx.shape
    L, M = ex.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, 4), lambda b, k, idx: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, k, idx: (b, k)),
            pl.BlockSpec((1, M), lambda b, k, idx: (idx[b, k], 0)),
            pl.BlockSpec((1, M), lambda b, k, idx: (idx[b, k], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, M), lambda b, k, idx: (b, k, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, M), jnp.bool_),
        interpret=interpret,
    )(leaf_idx.astype(jnp.int32), queries.astype(jnp.float32),
      valid.astype(jnp.int32), ex.astype(jnp.float32), ey.astype(jnp.float32))
