"""Pallas TPU kernel: refinement of predicted/visited leaves.

This kernel embodies the paper's core I/O saving on TPU: only the leaf tiles
named in ``leaf_idx`` are pulled HBM→VMEM (via scalar-prefetch BlockSpec
index maps); extraneous leaves generate **no memory traffic at all**. The
per-entry containment test then runs on the VPU over the fetched tile.

Two grid forms, one semantics:

* ``fold_k=False`` (the TPU form): a ``(B, K)`` grid, one cell per
  (query, leaf slot), each DMA-ing exactly one named ``[1, M]`` leaf tile.
  That per-slot DMA *is* the paper's saving on hardware — but interpret
  mode emulates every grid cell in sequence, so B·K cells cost seconds on
  CPU for what is microseconds of VPU work.
* ``fold_k=True`` (the interpret form): the grid folds away entirely — one
  kernel invocation over the whole ``[B, K, M]`` slab, gathered at the XLA
  level outside the kernel. Same outputs bit for bit; the gather trades
  the targeted DMA for an O(B·K·M) HBM gather, which is exactly the right
  trade when the "DMA" is an emulated memcpy anyway.

Inputs (planar entry layout — see mbr_intersect.py for rationale):
  ``leaf_idx`` [B, K] i32   — leaves to refine per query (scalar-prefetched)
  ``queries``  [B, 4] f32
  ``ex``/``ey``[L, M] f32   — entry coordinates, +inf padded
  ``valid``    [B, K] i32   — slot validity
Output:
  ``inside``   [B, K, M] bool — exact containment per fetched entry
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, q_ref, valid_ref, ex_ref, ey_ref, o_ref):
    # q_ref: [1, 4]; ex/ey_ref: [1, M]; valid_ref: [1, 1]; o_ref: [1, 1, M]
    x0 = q_ref[0, 0]
    y0 = q_ref[0, 1]
    x1 = q_ref[0, 2]
    y1 = q_ref[0, 3]
    ex = ex_ref[0, :]
    ey = ey_ref[0, :]
    ok = (ex >= x0) & (ex <= x1) & (ey >= y0) & (ey <= y1)
    o_ref[0, 0, :] = ok & (valid_ref[0, 0] > 0)


def _kernel_folded(q_ref, valid_ref, gx_ref, gy_ref, o_ref):
    # whole-array blocks: q [B, 4]; valid [B, K]; gx/gy/o [B, K, M]
    q = q_ref[:, :]
    gx = gx_ref[:, :, :]
    gy = gy_ref[:, :, :]
    v = valid_ref[:, :]
    x0 = q[:, 0][:, None, None]
    y0 = q[:, 1][:, None, None]
    x1 = q[:, 2][:, None, None]
    y1 = q[:, 3][:, None, None]
    ok = (gx >= x0) & (gx <= x1) & (gy >= y0) & (gy <= y1)
    o_ref[:, :, :] = ok & (v[:, :, None] > 0)


@functools.partial(jax.jit, static_argnames=("interpret", "fold_k"))
def leaf_refine(queries: jnp.ndarray, ex: jnp.ndarray, ey: jnp.ndarray,
                leaf_idx: jnp.ndarray, valid: jnp.ndarray, *,
                interpret: bool = False,
                fold_k: bool | None = None) -> jnp.ndarray:
    """queries [B,4], ex/ey [L,M], leaf_idx [B,K], valid [B,K] → [B,K,M].

    ``fold_k`` defaults to ``interpret``: the (B, K) scalar-prefetch grid on
    hardware, the folded (B,) grid when emulating. Both forms are
    bit-identical (tested); pass ``fold_k`` explicitly to pin a form.
    """
    if fold_k is None:
        fold_k = interpret
    B, K = leaf_idx.shape
    L, M = ex.shape
    if fold_k:
        gx = ex[leaf_idx]                       # [B, K, M] XLA-level gather
        gy = ey[leaf_idx]
        # Whole-array blocks, no grid: the emulated grid loop is pure
        # overhead off-TPU, so the folded form runs the kernel body once.
        return pl.pallas_call(
            _kernel_folded,
            out_shape=jax.ShapeDtypeStruct((B, K, M), jnp.bool_),
            interpret=interpret,
        )(queries.astype(jnp.float32), valid.astype(jnp.int32),
          gx.astype(jnp.float32), gy.astype(jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K),
        in_specs=[
            pl.BlockSpec((1, 4), lambda b, k, idx: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, k, idx: (b, k)),
            pl.BlockSpec((1, M), lambda b, k, idx: (idx[b, k], 0)),
            pl.BlockSpec((1, M), lambda b, k, idx: (idx[b, k], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, M), lambda b, k, idx: (b, k, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, M), jnp.bool_),
        interpret=interpret,
    )(leaf_idx.astype(jnp.int32), queries.astype(jnp.float32),
      valid.astype(jnp.int32), ex.astype(jnp.float32), ey.astype(jnp.float32))
