"""Pallas TPU kernel: chunked RWKV-6 (Finch) linear-attention scan.

Recurrence per head (state S ∈ R[dk, dv], data-dependent per-channel decay
w_t ∈ (0,1)^dk, bonus u ∈ R^dk):

    y_t = (r_t ⊙ 1) · (S_{t-1} + (u ⊙ k_t) v_tᵀ)
    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ

A naive scan is O(T) sequential steps of rank-1 updates — memory-bound and
MXU-hostile. The chunked form processes C tokens per step:

  inter:  y_i += (r_i ⊙ exp(cum_i)) @ S0              (MXU, exponent ≤ 0 ⇒ stable)
  intra:  y_i += Σ_{j<i} [Σ_c r_ic k_jc e^{cum_ic − cum_{j+1,c}}] v_j
  bonus:  y_i += (r_i ⊙ u ⊙ k_i) · v_i
  state:  S ← diag(e^{cum_C}) S0 + (k ⊙ e^{cum_C − cum_{j+1}})ᵀ v   (stable matmul)

where cum_i = Σ_{s<i} log w_s (exclusive). The intra term keeps the exponent
per-channel and ≤ 0, so it is **exactly stable** for arbitrarily strong decay
(no FLA-style overflow risk); it runs on the VPU as a [C, C, dk] contraction.
The grid is (B·H, T/C); the state lives in a VMEM scratch ref that persists
across the sequential chunk dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEF_CHUNK = 64


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[:, :] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)            # [C, dk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)            # [C, dv]
    w = w_ref[0].astype(jnp.float32)            # [C, dk] decay ∈ (0,1)
    u = u_ref[0].astype(jnp.float32)            # [dk]
    S0 = s_ref[:, :]                            # [dk, dv]

    C = r.shape[0]
    logw = jnp.log(w)
    cum_inc = jnp.cumsum(logw, axis=0)          # cum_{i+1} (inclusive)
    cum = cum_inc - logw                        # cum_i (exclusive), cum_0 = 0

    # --- inter-chunk: contribution of carried state
    r_dec = r * jnp.exp(cum)                    # exponent ≤ 0
    y = jnp.dot(r_dec, S0, preferred_element_type=jnp.float32)   # [C, dv]

    # --- intra-chunk: strictly-causal pairwise scores (stable, per-channel)
    # scores[i, j] = Σ_c r[i,c] k[j,c] exp(cum[i,c] − cum_inc[j,c]),  j < i
    expo = cum[:, None, :] - cum_inc[None, :, :]          # [C, C, dk]
    causal = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    expo = jnp.where(causal[:, :, None], expo, -jnp.inf)  # exponent ≤ 0
    scores = jnp.sum(r[:, None, :] * k[None, :, :] * jnp.exp(expo), axis=-1)
    y = y + jnp.dot(scores, v, preferred_element_type=jnp.float32)

    # --- bonus (current token) term: y_i += (Σ_c r_ic u_c k_ic) v_i
    bonus = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)  # [C, 1]
    y = y + bonus * v

    y_ref[0, :, :] = y.astype(y_ref.dtype)

    # --- state update (stable: exponents ≤ 0)
    total = cum_inc[-1, :]                                 # [dk]
    k_dec = k * jnp.exp(total[None, :] - cum_inc)          # [C, dk]
    s_ref[:, :] = jnp.exp(total)[:, None] * S0 + jnp.dot(
        k_dec.T, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, w: jnp.ndarray,
         u: jnp.ndarray, *, chunk: int = DEF_CHUNK,
         interpret: bool = False) -> jnp.ndarray:
    """r/k/w: [BH, T, dk], v: [BH, T, dv], u: [BH, dk] → y [BH, T, dv]."""
    BH, T, dk = r.shape
    dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    grid = (BH, T // chunk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, dv), jnp.float32),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
