"""Pallas TPU kernel: probe the device-side insert delta store.

The freshness subsystem (``repro.core.delta``) absorbs dynamic inserts
into a fixed-capacity append-only point buffer instead of mutating the
served tree. Every query batch must then check that buffer too — points
staged since the last repack are invisible to both the R and AI paths.
This kernel is that check, kept to the serving contract PR 5 settled on:
the only HBM output is a compact ``[B, K]`` slot table of hit positions
plus per-row counts — the dense ``[B, cap]`` query×buffer containment
mask lives tile-by-tile in VMEM and never reaches the serving HLO.

Input layout (planar, like the traversal kernels): queries as ``[4, B]``
f32 rows and buffer points as ``[2, cap]`` f32 rows. Unstaged/padding
slots hold +inf coordinates, so the closed-rectangle containment test
fails on them without the kernel ever consulting the staged count — the
wrapper's padding and the store's capacity padding share one convention.

The compaction epilogue is the shared cumsum-rank machinery from
``traverse_fused`` (slots in buffer order = insertion order): the TPU
form scatters via ``kc``-wide rank-equality chunks guarded by the tile's
rank range, the interpret form binary-searches slot ranks over the
tile's prefix count; both are bit-identical to
``compact_mask_counted(contains(q, pts), k)`` — the jnp oracle in
``ref.delta_probe``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.epilogue import (
    compact_epilogue_interp as _compact_epilogue_interp,
    compact_epilogue_tpu as _compact_epilogue_tpu,
)
from repro.kernels.traverse_fused import (COMPACT_KC, LANE,
                                          tuned_tiles_for_key)

DEF_TB = 256    # query tile (sublane axis)
DEF_TN = 512    # buffer tile (lane axis, multiple of 128)


def tune_key_delta(B: int, cap: int, interp: bool) -> str:
    """Autotune-cache key for the delta-probe form space (same cache file
    as the traversal/mlp forms; see ``benchmarks/autotune``)."""
    return f"delta-{'interp' if interp else 'tpu'}:B{B}:N{cap}"


def tuned_tiles_delta(B: int, cap: int, interp: bool) -> dict:
    return tuned_tiles_for_key(tune_key_delta(B, cap, interp))


def vmem_estimate_delta(tb: int, tn: int, kp: int, tpu_form: bool = True,
                        kc: int = COMPACT_KC) -> int:
    """Rough VMEM working-set bytes for one probe tile.

    Query tile + buffer-point tile + containment mask + the compaction
    epilogue transient (form-dependent, exactly as
    ``vmem_estimate_compact``) + the revisited slot/count blocks.
    """
    est = 4 * tb * 4 + 2 * tn * 4                 # q tile, point tile
    est += tb * tn                                # containment mask
    est += tb * tn * (kc if tpu_form else 1) * 4  # epilogue transient
    est += tb * (kp + 1) * 4                      # slot table + count
    return est


def _tile_contains(q, p):
    """q [4, TB] × p [2, TN] → [TB, TN] bool closed-rect containment.

    Padding points are +inf, so ``px <= qx1`` fails and they can never
    hit — the count input the host tracks stays out of the kernel.
    """
    px = p[0, :][None, :]
    py = p[1, :][None, :]
    return ((q[0, :][:, None] <= px) & (px <= q[2, :][:, None])
            & (q[1, :][:, None] <= py) & (py <= q[3, :][:, None]))


def _make_probe_kernel(tb: int, tn: int, kp: int, tpu_form: bool,
                       kc: int = COMPACT_KC):
    """Kernel body: containment over one buffer tile + compaction epilogue.

    Output blocks (slot table ``[TB, KP]`` + count ``[TB, 1]``) map to
    ``(i, 0)`` so they stay VMEM-resident across the buffer-tile sweep of
    a query tile, exactly as ``traverse_compact_t``'s epilogue blocks do.
    """

    def kernel(q_ref, p_ref, idx_ref, cnt_ref):
        q = q_ref[:, :]                               # [4, TB]
        j = pl.program_id(1)

        if tpu_form:
            col = j * tn + jax.lax.broadcasted_iota(jnp.int32, (tb, tn), 1)

            @pl.when(j == 0)
            def _init():
                idx_ref[:, :] = jnp.zeros((tb, kp), jnp.int32)
                cnt_ref[:, :] = jnp.zeros((tb, 1), jnp.int32)

            mask = _tile_contains(q, p_ref[:, :])
            # buffer tiles are mostly padding until the store fills — one
            # any() reduce buys skipping the whole chunked scatter
            @pl.when(jnp.any(mask))
            def _live_tile():
                _compact_epilogue_tpu(mask, col, idx_ref, cnt_ref, kp, kc)
        else:
            # the shared interpret epilogue handles the single-tile fold
            # too (j == 0 masks the uninitialized output reads), so there
            # is no special case — unlike traverse_fused there is no
            # traversal-liveness early exit to exploit here
            mask = _tile_contains(q, p_ref[:, :])
            _compact_epilogue_interp(mask, j, tn, kp, idx_ref, cnt_ref)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("k", "tb", "tn", "kc", "interpret",
                                    "tpu_form"))
def delta_probe_t(q_t: jnp.ndarray, pts_t: jnp.ndarray, *, k: int,
                  tb: int = DEF_TB, tn: int = DEF_TN, kc: int = COMPACT_KC,
                  interpret: bool = False, tpu_form: bool | None = None
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Transposed-layout entry point.

    ``q_t`` [4, B] f32 query rects; ``pts_t`` [2, cap] f32 buffer points
    (+inf on unstaged/padding slots). B must be a multiple of ``tb`` and
    cap of ``tn`` (ops.py pads). Returns ``(slot_idx [B, KP] i32,
    count [B, 1] i32)`` with ``KP = k`` rounded up to ``LANE`` in the TPU
    form and exactly ``k`` in the interpret form: row ``b``'s first
    ``min(count[b], KP)`` slots hold the buffer positions of its hits in
    insertion order; slots past the count are 0. The ``[B, cap]``
    containment mask is never written.
    """
    if tpu_form is None:
        tpu_form = not interpret
    _, B = q_t.shape
    _, N = pts_t.shape
    assert B % tb == 0 and N % tn == 0, (B, N, tb, tn)
    kp = (k + LANE - 1) // LANE * LANE if tpu_form else k
    assert kp % kc == 0 or not tpu_form, (kp, kc)
    grid = (B // tb, N // tn)

    return pl.pallas_call(
        _make_probe_kernel(tb, tn, kp, tpu_form=tpu_form, kc=kc),
        grid=grid,
        in_specs=[pl.BlockSpec((4, tb), lambda i, j: (0, i)),
                  pl.BlockSpec((2, tn), lambda i, j: (0, j))],
        out_specs=[pl.BlockSpec((tb, kp), lambda i, j: (i, 0)),
                   pl.BlockSpec((tb, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, kp), jnp.int32),
                   jax.ShapeDtypeStruct((B, 1), jnp.int32)],
        interpret=interpret,
    )(q_t.astype(jnp.float32), pts_t.astype(jnp.float32))
