"""Shared compaction-epilogue helpers for the fused Pallas kernels.

Three kernels end in the same move: a ``[TB, TL]`` boolean tile of "this
(query, column) pair is selected" must become a compact per-row slot table
``[TB, KP]`` of the selected columns in column order, plus a running
per-row count — without ever materializing the mask outside VMEM. The
fused R-path traversal (``traverse_fused``), the fused MLP prediction
(``mlp_infer``) and the delta-buffer probe (``delta_probe``) all import
these two epilogues; this module is the single home so the rank scheme
cannot drift between kernels (it used to live in ``traverse_fused`` with
the other two importing it across kernel modules).

Both forms realize ``compact_mask_counted``'s cumsum-rank scheme per
tile, carrying the running per-row total across tiles in the *revisited*
output blocks (both output blocks map to ``(i, 0)`` in every caller, so
they stay VMEM-resident across the column-tile sweep):

* ``compact_epilogue_tpu`` — the Mosaic-friendly hardware form: chunked
  rank-equality compares + lane-sum (ranks are unique per row, so sum ==
  select), each ``kc``-wide chunk ``pl.when``-guarded by the tile's
  [min, max] rank range;
* ``compact_epilogue_interp`` — the interpret-mode form: value-level
  rowwise binary search of each slot's rank over the tile's inclusive
  prefix count (interpret mode functionalizes ref-touching conds, so the
  scatter must be unconditional value ops).

Pure code motion from ``traverse_fused``; the old ``_compact_epilogue_*``
names remain importable from there.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def compact_epilogue_tpu(mask, col, idx_ref, cnt_ref, kp: int, kc: int):
    """TPU-form cumsum-rank compaction epilogue over one ``[TB, TL]`` tile.

    Ranks the tile's set lanes by exclusive prefix count continued from the
    running per-row total in ``cnt_ref`` (the revisited ``[TB, 1]`` output
    block) and scatters ``col`` values of ranks ``< kp`` into ``idx_ref``
    (the revisited ``[TB, KP]`` slot block) as ``kc``-wide chunks of
    rank-equality compares + lane-sum — ranks are unique per row, so sum ==
    select, and Mosaic vectorizes dense compare/reduce where it would not a
    lane scatter. Each chunk is ``pl.when``-guarded by the tile's
    [min, max] rank range. Callers guard the whole epilogue on tile
    liveness; shared by ``traverse_compact_t``, ``mlp_infer`` and
    ``delta_probe``. ``mask`` is the tile's set-lane mask, ``col`` the
    value to scatter (global leaf ids / buffer slot ids).
    """
    tb_, tl_ = mask.shape
    m = mask.astype(jnp.int32)
    base = cnt_ref[:, 0][:, None]            # [TB, 1]
    rank = base + jnp.cumsum(m, axis=1) - m  # global exclusive
    cnt_ref[:, 0] = base[:, 0] + jnp.sum(m, axis=1)
    w = jnp.where(mask, col, 0)
    sl = jnp.where(mask, rank, -1)           # -1 never matches
    lo = jnp.min(base)                       # tile's rank range
    hi = jnp.max(sl)
    for s in range(0, kp, kc):
        @pl.when((lo < s + kc) & (hi >= s))
        def _chunk(s=s):
            kio = s + jax.lax.broadcasted_iota(
                jnp.int32, (tb_, tl_, kc), 2)
            hit = sl[:, :, None] == kio
            contrib = jnp.sum(
                jnp.where(hit, w[:, :, None], 0), axis=1)
            idx_ref[:, s:s + kc] = \
                idx_ref[:, s:s + kc] + contrib


def compact_epilogue_interp(mask, j, tl: int, kp: int, idx_ref, cnt_ref):
    """Interpret-form compaction epilogue: value-level rowwise binary
    search of each slot's rank over the tile's inclusive prefix count
    (``compact_mask_counted``'s scheme), with the running rank base carried
    across tiles in the revisited output blocks. Output blocks are
    uninitialized before the first visit — the ``j == 0`` reads are masked
    at value level (no ref-touching cond). Shared by ``traverse_compact_t``,
    ``mlp_infer`` and ``delta_probe``.
    """
    tb_ = mask.shape[0]
    m = mask.astype(jnp.int32)
    prev_idx = jnp.where(j == 0, 0, idx_ref[:, :])
    prev_cnt = jnp.where(j == 0, 0, cnt_ref[:, :])
    base = prev_cnt[:, 0]                        # [TB]
    cs = jnp.cumsum(m, axis=1)                   # [TB, TL]
    # slot t - 1 holds the column whose inclusive prefix count first
    # reaches t - base; slots filled by earlier tiles keep their value,
    # later slots wait for a later tile.
    targets = 1 + jax.lax.broadcasted_iota(jnp.int32, (tb_, kp), 1)
    rel = targets - base[:, None]                # [TB, KP]
    pos = jax.vmap(lambda c, t: jnp.searchsorted(
        c, t, side="left"))(cs, rel)
    newly = (rel >= 1) & (rel <= cs[:, -1][:, None])
    idx_ref[:, :] = jnp.where(
        newly, j * tl + pos.astype(jnp.int32), prev_idx)
    cnt_ref[:, :] = (base + cs[:, -1])[:, None]
