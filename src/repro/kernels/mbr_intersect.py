"""Pallas TPU kernel: batched rectangle-intersection mask.

``queries`` [B, 4] × ``mbrs`` [N, 4] → [B, N] bool. This is the innermost op
of every traversal level and of grid-cell routing — the spatial analogue of a
matmul's MACs. The kernel tiles B and N so both operand tiles and the [TB, TN]
output tile live in VMEM; the comparison runs on the VPU with the lane
dimension over N (TN multiple of 128).

Layout note: rectangles are passed *transposed* as four planar vectors
(xmin/ymin/xmax/ymax), i.e. ``q_t`` [4, B] and ``m_t`` [4, N]. A [B, 4]
array would waste a 128-lane register row per rectangle; the planar layout
broadcasts each coordinate across lanes for free. ``ops.py`` handles the
transpose + padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEF_TB = 256   # query-tile (sublane axis)
DEF_TN = 512   # mbr-tile (lane axis, multiple of 128)


def _kernel(q_ref, m_ref, o_ref):
    # q_ref: [4, TB] f32; m_ref: [4, TN] f32; o_ref: [TB, TN] bool
    qx0 = q_ref[0, :][:, None]   # [TB, 1]
    qy0 = q_ref[1, :][:, None]
    qx1 = q_ref[2, :][:, None]
    qy1 = q_ref[3, :][:, None]
    mx0 = m_ref[0, :][None, :]   # [1, TN]
    my0 = m_ref[1, :][None, :]
    mx1 = m_ref[2, :][None, :]
    my1 = m_ref[3, :][None, :]
    o_ref[:, :] = (qx0 <= mx1) & (mx0 <= qx1) & (qy0 <= my1) & (my0 <= qy1)


@functools.partial(jax.jit, static_argnames=("tb", "tn", "interpret"))
def mbr_intersect_t(q_t: jnp.ndarray, m_t: jnp.ndarray, *, tb: int = DEF_TB,
                    tn: int = DEF_TN, interpret: bool = False) -> jnp.ndarray:
    """Transposed-layout entry point: ``q_t`` [4, B], ``m_t`` [4, N] → [B, N].

    B must be a multiple of ``tb`` and N of ``tn`` (ops.py pads).
    """
    _, B = q_t.shape
    _, N = m_t.shape
    assert B % tb == 0 and N % tn == 0, (B, N, tb, tn)
    grid = (B // tb, N // tn)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4, tb), lambda i, j: (0, i)),
            pl.BlockSpec((4, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.bool_),
        interpret=interpret,
    )(q_t.astype(jnp.float32), m_t.astype(jnp.float32))
