"""The AI-tree (paper §III): predict true leaves, access only those, refine.

Query path (Fig. 5/6):
  1. grid-route the query to its overlapped cells (≤ ``max_cells``);
  2. run those cells' models, union their per-leaf scores (max-combine);
  3. threshold → predicted leaf set (≤ ``max_pred``);
  4. fetch ONLY predicted leaves and refine entries exactly (never a false
     positive, §III-C);
  5. raise the fallback flag when the prediction is unusable — empty set,
     a predicted leaf with zero qualifying entries (the paper's
     misprediction signal), grid/prediction overflow — the caller then runs
     the classical R-path for those queries, keeping results exact.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.core.device_tree import DeviceTree
from repro.core.grid import Grid, cells_of_queries
from repro.core.classifiers.mlp import (MLPBank, cell_logits_for,
                                        global_scores)
from repro.core.classifiers.forest import Forest, cell_probs_for
from repro.core.classifiers.knn import KNNBank, cell_probs_for as knn_probs
from repro.core import traversal


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AITree:
    grid: Grid
    bank: Union[MLPBank, Forest, KNNBank]
    # Per-cell serve-eligibility guard: cell ``c``'s model may answer on
    # the AI path iff ``cell_ok[c]``. ``build.fit_airtree`` sets it from
    # the per-cell exact-fit flags (a cell whose training queries were
    # not all answered exactly can under-predict *silently* — the
    # blind-spot ROADMAP documented); the freshness monitor further
    # clears cells that received inserts since the bank was fit. Queries
    # overlapping any not-ok cell are demoted to the exact R path by the
    # hybrid/engine routing (see ``hybrid_query`` / ``engine._ai_path``).
    cell_ok: jnp.ndarray
    # ``kind`` names the bank family and selects the inference path:
    # "mlp" (MLPBank, the TPU-native stacked experts — the only kind with a
    # fused prediction kernel), "forest" (Forest, paper-faithful oblivious
    # trees) or "knn" (KNNBank, memorization-complete nearest-stored-query).
    kind: str = dataclasses.field(metadata=dict(static=True))
    max_cells: int = dataclasses.field(metadata=dict(static=True))
    max_pred: int = dataclasses.field(metadata=dict(static=True))
    threshold: float = dataclasses.field(metadata=dict(static=True))


def bank_n_cells(bank) -> int:
    """Cell count of any bank family (the guard/label leading axis)."""
    if isinstance(bank, KNNBank):
        return bank.feats.shape[0]
    if isinstance(bank, MLPBank):
        return bank.w1.shape[0]
    return bank.feat_idx.shape[0]


def update_bank_cells(bank, cells, **rows):
    """Functional per-cell-slot splice: return a new bank whose rows at
    ``cells`` ([Csub] i32 global cell ids) are replaced by the given
    ``[Csub, ...]`` arrays, all other cells' buffers untouched.

    The write side of the cell-granular refit pipeline
    (``build.refit_cells``): a sub-stack trained on just the changed
    cells lands in the live bank with one scatter per buffer — no full
    retrain, no bank reallocation. Field names must be per-cell buffers
    of the bank family (leading axis C); globals like ``mu``/``sd`` are
    rejected since splicing them would silently retarget *every* cell.
    """
    cells = jnp.asarray(cells, jnp.int32)
    per_cell = {
        MLPBank: ("w1", "b1", "w2", "b2", "label_map", "lmask"),
        KNNBank: ("feats", "labels", "label_map", "lmask"),
    }.get(type(bank))
    if per_cell is None:
        raise NotImplementedError(
            f"update_bank_cells: {type(bank).__name__} has no per-cell "
            "splice (forest banks refit whole)")
    updates = {}
    for name, val in rows.items():
        if name not in per_cell:
            raise ValueError(f"{name!r} is not a per-cell buffer of "
                             f"{type(bank).__name__} (allowed: {per_cell})")
        cur = getattr(bank, name)
        val = jnp.asarray(val, cur.dtype)
        if val.shape != (cells.shape[0],) + cur.shape[1:]:
            raise ValueError(f"{name}: row shape {val.shape} does not match "
                             f"({cells.shape[0]},) + {cur.shape[1:]}")
        updates[name] = cur.at[cells].set(val)
    return dataclasses.replace(bank, **updates)


def make_aitree(grid: Grid, bank, *, max_cells: int = 4, max_pred: int = 64,
                threshold: float = 0.5, cell_ok=None) -> AITree:
    kind = {MLPBank: "mlp", Forest: "forest", KNNBank: "knn"}[type(bank)]
    if cell_ok is None:
        # all-eligible default keeps hand-built trees' dispatch unchanged;
        # fit_airtree installs the real per-cell fit flags
        cell_ok = jnp.ones((bank_n_cells(bank),), jnp.bool_)
    return AITree(grid=grid, bank=bank, cell_ok=jnp.asarray(cell_ok),
                  kind=kind, max_cells=max_cells, max_pred=max_pred,
                  threshold=threshold)


def cell_slot_probs(ait: AITree, queries: jnp.ndarray,
                    cell_ids: jnp.ndarray) -> jnp.ndarray:
    """Per-(query, cell-slot) classifier scores: [B, S] ids → [B, S, Cl]."""
    if ait.kind == "mlp":
        return jax.nn.sigmoid(cell_logits_for(ait.bank, queries, cell_ids))
    if ait.kind == "knn":
        return knn_probs(ait.bank, queries, cell_ids)
    return cell_probs_for(ait.bank, queries, cell_ids)


def predict_scores(ait: AITree, queries: jnp.ndarray, n_leaves: int
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[B, 4] → (leaf scores [B, L], cell_overflow [B]).

    The dense prediction path — kept as the fused kernel's oracle and for
    consumers that need the full score table (labels, α, training,
    ``pred_mask``). The serving path uses ``predict_compact``.
    """
    cell_ids, valid, overflow = cells_of_queries(
        ait.grid, queries, ait.max_cells)
    probs = cell_slot_probs(ait, queries, cell_ids)
    scores = global_scores(ait.bank, probs, valid, cell_ids, n_leaves)
    return scores, overflow


def predict_compact(ait: AITree, queries: jnp.ndarray, n_leaves: int, *,
                    use_kernel: bool = False,
                    tile_b=None, tile_l=None
                    ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                               jnp.ndarray]:
    """Prediction straight to the compact slot table: [B, 4] →
    ``(leaf_idx [B, max_pred] i32, valid [B, max_pred] bool, n_pred [B]
    i32, cell_overflow [B] bool)``.

    Semantically ``compact_mask_counted(predict_scores > threshold,
    max_pred)`` plus the cell-routing overflow flag. With ``use_kernel``
    and an MLP bank the whole pipeline runs inside the fused Pallas
    kernel (``kernels.mlp_infer``) and the dense ``[B, L]`` score table
    is never materialized — absent from the lowered HLO. kNN/forest banks
    and the no-kernel path run the dense oracle and compact it with the
    identical scheme (bit-identical, next rung of the fallback ladder).
    ``tile_b``/``tile_l`` override the kernel's tile choice
    (testing/tuning only).
    """
    if ait.kind == "mlp" and use_kernel:
        cell_ids, valid, overflow = cells_of_queries(
            ait.grid, queries, ait.max_cells)
        from repro.kernels import ops as kops
        idx, v, cnt = kops.mlp_predict_compact(
            queries, ait.bank, cell_ids, valid, n_leaves=n_leaves,
            k=ait.max_pred, threshold=ait.threshold, tb=tile_b, tl=tile_l)
        return idx, v, cnt, overflow
    scores, overflow = predict_scores(ait, queries, n_leaves)
    idx, v, cnt = traversal.compact_mask_counted(
        scores > ait.threshold, ait.max_pred)
    return idx, v, cnt, overflow


def _refine_and_flag(ait: AITree, tree: DeviceTree, queries: jnp.ndarray,
                     leaf_idx: jnp.ndarray, valid: jnp.ndarray,
                     n_pred: jnp.ndarray, cell_over: jnp.ndarray,
                     max_results: int, use_kernel: bool):
    """Shared tail of the AI query pipelines: refine the predicted slot
    table, gather result ids, and assemble the paper's fallback signals
    (empty prediction, mispredicted zero-count leaf, cell/prediction
    overflow, result truncation). One implementation so ``ai_query`` and
    ``ai_query_compact`` cannot drift apart on the fallback convention.
    Returns ``(counts, n_pred_clamped, n_results, result_ids, fallback,
    mispredict)`` — the misprediction signal rides along separately so the
    maintenance policy can tell "model predicted a dead leaf" (drift
    evidence against the query's cell) apart from the structural fallbacks.
    """
    pred_over = n_pred > ait.max_pred
    ref = traversal.refine_leaves(tree, queries, leaf_idx, valid,
                                  use_kernel=use_kernel)
    empty = n_pred == 0
    # paper's misprediction signal: a predicted leaf with no qualifying entry
    mispredict = jnp.any((ref.counts == 0) & valid, axis=-1)
    result_ids, trunc = traversal.gather_result_ids(tree, ref, max_results)
    fallback = empty | mispredict | cell_over | pred_over | trunc
    n_results = jnp.sum(ref.counts * valid.astype(jnp.int32), axis=-1)
    return (ref.counts, jnp.minimum(n_pred, ait.max_pred), n_results,
            result_ids, fallback, mispredict)


def primary_cell_ids(ait: AITree, queries: jnp.ndarray) -> jnp.ndarray:
    """[B] i32 — each query's anchor grid cell (its lower-left corner's
    cell), or -1 for cell-window overflow. The per-query attribution key
    the serving stats carry so the freshness monitor can aggregate guard/
    mispredict/delta-hit evidence *per cell* and target maintenance
    (refit/demote/promote) at cell granularity.
    """
    cell_ids, valid, _ = cells_of_queries(ait.grid, queries, ait.max_cells)
    return jnp.where(valid[:, 0], cell_ids[:, 0], -1).astype(jnp.int32)


class AIQueryResult(NamedTuple):
    pred_mask: jnp.ndarray     # [B, L] predicted leaves
    counts: jnp.ndarray        # [B, K] qualifying entries per accessed leaf
    n_pred: jnp.ndarray        # [B] leaves accessed by the AI path
    n_results: jnp.ndarray     # [B] qualifying points found
    result_ids: jnp.ndarray    # [B, max_results] i32, -1 pad
    fallback: jnp.ndarray      # [B] bool — run the exact R-path instead
    mispredict: jnp.ndarray    # [B] bool — fallback specifically because a
    #                            predicted leaf held no qualifying entry
    cell_id: jnp.ndarray       # [B] i32 anchor cell (-1 on window overflow)


@functools.partial(jax.jit, static_argnames=("max_results", "use_kernel"))
def ai_query(ait: AITree, tree: DeviceTree, queries: jnp.ndarray, *,
             max_results: int = 512, use_kernel: bool = False
             ) -> AIQueryResult:
    queries = queries.astype(jnp.float32)
    L = tree.n_leaves
    scores, cell_over = predict_scores(ait, queries, L)
    pred = scores > ait.threshold                           # [B, L]
    # counted compaction: one scan yields slots, validity, and the row
    # count that feeds n_pred / the empty and overflow fallback signals
    leaf_idx, valid, n_pred = traversal.compact_mask_counted(
        pred, ait.max_pred)
    counts, n_pred_c, n_results, result_ids, fallback, mis = \
        _refine_and_flag(ait, tree, queries, leaf_idx, valid, n_pred,
                         cell_over, max_results, use_kernel)
    return AIQueryResult(
        pred_mask=pred,
        counts=counts,
        n_pred=n_pred_c,
        n_results=n_results,
        result_ids=result_ids,
        fallback=fallback,
        mispredict=mis,
        cell_id=primary_cell_ids(ait, queries),
    )


class AICompactResult(NamedTuple):
    leaf_idx: jnp.ndarray      # [B, max_pred] predicted leaves (ID order)
    valid: jnp.ndarray         # [B, max_pred] slot validity
    counts: jnp.ndarray        # [B, max_pred] qualifying entries per slot
    n_pred: jnp.ndarray        # [B] leaves accessed by the AI path
    n_results: jnp.ndarray     # [B] qualifying points found
    result_ids: jnp.ndarray    # [B, max_results] i32, -1 pad
    fallback: jnp.ndarray      # [B] bool — run the exact R-path instead
    mispredict: jnp.ndarray    # [B] bool — fallback specifically because a
    #                            predicted leaf held no qualifying entry
    cell_id: jnp.ndarray       # [B] i32 anchor cell (-1 on window overflow)


@functools.partial(jax.jit, static_argnames=("max_results", "use_kernel",
                                             "tile_b", "tile_l"))
def ai_query_compact(ait: AITree, tree: DeviceTree, queries: jnp.ndarray, *,
                     max_results: int = 512, use_kernel: bool = False,
                     tile_b=None, tile_l=None) -> AICompactResult:
    """Serving-path AI query: fused predict+compact → refine.

    The ``ai_query`` variant for the hot path, mirroring what
    ``range_query_compact`` is to ``range_query``: prediction lands
    directly in the ``[B, max_pred]`` slot table that feeds the
    scalar-prefetch refine kernel, so with ``use_kernel`` (MLP banks) the
    dense ``[B, L]`` score table never round-trips through HBM and is
    absent from the lowered HLO. Per-field bit-identical to ``ai_query``
    on every shared field — including the fallback convention: *empty*
    prediction, the paper's misprediction signal (a predicted leaf with
    zero qualifying entries), cell/prediction overflow, and result
    truncation. Use ``ai_query`` when ``pred_mask`` itself is needed
    (exact-fit evaluation, labels).
    """
    queries = queries.astype(jnp.float32)
    leaf_idx, valid, n_pred, cell_over = predict_compact(
        ait, queries, tree.n_leaves, use_kernel=use_kernel,
        tile_b=tile_b, tile_l=tile_l)
    counts, n_pred_c, n_results, result_ids, fallback, mis = \
        _refine_and_flag(ait, tree, queries, leaf_idx, valid, n_pred,
                         cell_over, max_results, use_kernel)
    return AICompactResult(
        leaf_idx=leaf_idx,
        valid=valid,
        counts=counts,
        n_pred=n_pred_c,
        n_results=n_results,
        result_ids=result_ids,
        fallback=fallback,
        mispredict=mis,
        cell_id=primary_cell_ids(ait, queries),
    )
