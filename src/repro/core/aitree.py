"""The AI-tree (paper §III): predict true leaves, access only those, refine.

Query path (Fig. 5/6):
  1. grid-route the query to its overlapped cells (≤ ``max_cells``);
  2. run those cells' models, union their per-leaf scores (max-combine);
  3. threshold → predicted leaf set (≤ ``max_pred``);
  4. fetch ONLY predicted leaves and refine entries exactly (never a false
     positive, §III-C);
  5. raise the fallback flag when the prediction is unusable — empty set,
     a predicted leaf with zero qualifying entries (the paper's
     misprediction signal), grid/prediction overflow — the caller then runs
     the classical R-path for those queries, keeping results exact.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Union

import jax
import jax.numpy as jnp

from repro.core.device_tree import DeviceTree
from repro.core.grid import Grid, cells_of_queries
from repro.core.classifiers.mlp import (MLPBank, cell_logits_for,
                                        global_scores)
from repro.core.classifiers.forest import Forest, cell_probs_for
from repro.core.classifiers.knn import KNNBank, cell_probs_for as knn_probs
from repro.core import traversal


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AITree:
    grid: Grid
    bank: Union[MLPBank, Forest]
    kind: str = dataclasses.field(metadata=dict(static=True))  # "mlp"|"forest"
    max_cells: int = dataclasses.field(metadata=dict(static=True))
    max_pred: int = dataclasses.field(metadata=dict(static=True))
    threshold: float = dataclasses.field(metadata=dict(static=True))


def make_aitree(grid: Grid, bank, *, max_cells: int = 4, max_pred: int = 64,
                threshold: float = 0.5) -> AITree:
    kind = {MLPBank: "mlp", Forest: "forest", KNNBank: "knn"}[type(bank)]
    return AITree(grid=grid, bank=bank, kind=kind, max_cells=max_cells,
                  max_pred=max_pred, threshold=threshold)


def predict_scores(ait: AITree, queries: jnp.ndarray, n_leaves: int
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[B, 4] → (leaf scores [B, L], cell_overflow [B])."""
    cell_ids, valid, overflow = cells_of_queries(
        ait.grid, queries, ait.max_cells)
    if ait.kind == "mlp":
        probs = jax.nn.sigmoid(cell_logits_for(ait.bank, queries, cell_ids))
    elif ait.kind == "knn":
        probs = knn_probs(ait.bank, queries, cell_ids)
    else:
        probs = cell_probs_for(ait.bank, queries, cell_ids)
    scores = global_scores(ait.bank, probs, valid, cell_ids, n_leaves)
    return scores, overflow


class AIQueryResult(NamedTuple):
    pred_mask: jnp.ndarray     # [B, L] predicted leaves
    counts: jnp.ndarray        # [B, K] qualifying entries per accessed leaf
    n_pred: jnp.ndarray        # [B] leaves accessed by the AI path
    n_results: jnp.ndarray     # [B] qualifying points found
    result_ids: jnp.ndarray    # [B, max_results] i32, -1 pad
    fallback: jnp.ndarray      # [B] bool — run the exact R-path instead


@functools.partial(jax.jit, static_argnames=("max_results", "use_kernel"))
def ai_query(ait: AITree, tree: DeviceTree, queries: jnp.ndarray, *,
             max_results: int = 512, use_kernel: bool = False
             ) -> AIQueryResult:
    queries = queries.astype(jnp.float32)
    L = tree.n_leaves
    scores, cell_over = predict_scores(ait, queries, L)
    pred = scores > ait.threshold                           # [B, L]
    # counted compaction: one scan yields slots, validity, and the row
    # count that feeds n_pred / the empty and overflow fallback signals
    leaf_idx, valid, n_pred = traversal.compact_mask_counted(
        pred, ait.max_pred)
    pred_over = n_pred > ait.max_pred
    ref = traversal.refine_leaves(tree, queries, leaf_idx, valid,
                                  use_kernel=use_kernel)
    empty = n_pred == 0
    # paper's misprediction signal: a predicted leaf with no qualifying entry
    mispredict = jnp.any((ref.counts == 0) & valid, axis=-1)
    result_ids, trunc = traversal.gather_result_ids(tree, ref, max_results)
    fallback = empty | mispredict | cell_over | pred_over | trunc
    return AIQueryResult(
        pred_mask=pred,
        counts=ref.counts,
        n_pred=jnp.minimum(n_pred, ait.max_pred),
        n_results=jnp.sum(ref.counts * valid.astype(jnp.int32), axis=-1),
        result_ids=result_ids,
        fallback=fallback,
    )
