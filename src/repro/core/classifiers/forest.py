"""Oblivious decision forests — the paper-faithful tree classifier family.

The paper uses sklearn multi-label decision trees (max_depth 30). Pointer
trees cannot run on a TPU, so we use the closest TPU-executable member of the
family: **oblivious** trees (one (feature, threshold) test per depth level,
shared across the whole level). Training is greedy top-down on host numpy;
inference is fully vectorized and runs through the Pallas
``forest_infer`` kernel (one-hot × leaf-table matmuls on the MXU).

Multi-label handling: each tree leaf stores the mean multi-hot label vector
of the training queries that land in it; forest prediction is the average
over trees, thresholded at 0.5 — the standard multi-label decision-tree
reduction the paper's classifier also uses.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Forest:
    """A bank of per-cell oblivious forests, stacked for batched inference.

    Shapes: ``C`` cells × ``T`` trees × depth ``D`` × ``Cl`` local labels.
    """
    feat_idx: jnp.ndarray   # [C, T, D] i32
    thresh: jnp.ndarray     # [C, T, D] f32
    tables: jnp.ndarray     # [C, T, 2^D, Cl] f32 leaf label means
    label_map: jnp.ndarray  # [C, Cl] i32
    lmask: jnp.ndarray      # [C, Cl] bool

    @property
    def n_cells(self) -> int:
        return self.feat_idx.shape[0]

    @property
    def n_trees(self) -> int:
        return self.feat_idx.shape[1]

    @property
    def depth(self) -> int:
        return self.feat_idx.shape[2]

    def byte_size(self) -> int:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in
                   (self.feat_idx, self.thresh, self.tables, self.label_map))


def _fit_oblivious_tree(X: np.ndarray, Y: np.ndarray, depth: int,
                        n_thresholds: int, rng: np.random.Generator
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Greedy level-wise fit. X [n, F], Y [n, Cl] → (feat [D], th [D],
    table [2^D, Cl]). Split criterion: sum of per-leaf label variance
    (Brier impurity), the multi-label generalization of gini.
    """
    n, F = X.shape
    Cl = Y.shape[1]
    leaf = np.zeros(n, np.int64)
    feats = np.zeros(depth, np.int32)
    ths = np.zeros(depth, np.float32)
    for d in range(depth):
        best = (np.inf, 0, 0.0)
        n_leaves = 2 ** d
        for f in range(F):
            xs = X[:, f]
            qs = np.unique(np.quantile(
                xs, np.linspace(0.05, 0.95, n_thresholds)))
            for t in qs:
                bit = (xs > t).astype(np.int64)
                nl = leaf * 2 + bit
                # impurity = Σ_leaf Σ_label n_l p(1-p)
                imp = 0.0
                sums = np.zeros((n_leaves * 2, Cl))
                cnts = np.zeros(n_leaves * 2)
                np.add.at(sums, nl, Y)
                np.add.at(cnts, nl, 1.0)
                nz = cnts > 0
                p = sums[nz] / cnts[nz, None]
                imp = float(np.sum(cnts[nz, None] * p * (1 - p)))
                if imp < best[0]:
                    best = (imp, f, float(t))
        feats[d] = best[1]
        ths[d] = best[2]
        leaf = leaf * 2 + (X[:, best[1]] > best[2]).astype(np.int64)
    table = np.zeros((2 ** depth, Cl), np.float32)
    cnts = np.zeros(2 ** depth)
    np.add.at(table, leaf, Y)
    np.add.at(cnts, leaf, 1.0)
    nz = cnts > 0
    table[nz] /= cnts[nz, None]
    return feats, ths, table


def fit_forest(feats_pc: np.ndarray, labels_pc: np.ndarray, qmask: np.ndarray,
               label_map: np.ndarray, lmask: np.ndarray, *, n_trees: int = 1,
               depth: int = 8, n_thresholds: int = 16, bootstrap: bool = False,
               seed: int = 0) -> Forest:
    """Fit one oblivious forest per non-empty cell.

    Inputs are the padded stacks from ``CellDataset``: feats [C, Qp, F],
    labels [C, Qp, Cl]. ``n_trees > 1`` uses bootstrap bagging (the binary
    *random forest* router reuses this with ``bootstrap=True``).
    """
    C, Qp, F = feats_pc.shape
    Cl = labels_pc.shape[-1]
    rng = np.random.default_rng(seed)
    fi = np.zeros((C, n_trees, depth), np.int32)
    th = np.full((C, n_trees, depth), np.inf, np.float32)  # inf → always-left
    tb = np.zeros((C, n_trees, 2 ** depth, Cl), np.float32)
    for c in range(C):
        sel = qmask[c]
        if not sel.any():
            continue
        X, Y = feats_pc[c][sel], labels_pc[c][sel]
        for t in range(n_trees):
            if bootstrap and X.shape[0] > 1:
                idx = rng.integers(0, X.shape[0], X.shape[0])
                Xt, Yt = X[idx], Y[idx]
            else:
                Xt, Yt = X, Y
            fi[c, t], th[c, t], tb[c, t] = _fit_oblivious_tree(
                Xt, Yt, depth, n_thresholds, rng)
    return Forest(feat_idx=jnp.asarray(fi), thresh=jnp.asarray(th),
                  tables=jnp.asarray(tb), label_map=jnp.asarray(label_map),
                  lmask=jnp.asarray(lmask))


def cell_probs_for(forest: Forest, feats: jnp.ndarray,
                   cell_ids: jnp.ndarray) -> jnp.ndarray:
    """Per-(query, cell-slot) forest prediction: [B, F] × [B, S] → [B, S, Cl].

    Gathered formulation for mixed batches (single-device path). The
    expert-sharded engine path uses ``cell_probs_dense`` + the Pallas kernel.
    """
    fi = forest.feat_idx[cell_ids]            # [B, S, T, D]
    th = forest.thresh[cell_ids]
    tb = forest.tables[cell_ids]              # [B, S, T, 2^D, Cl]
    B, S, T, D = fi.shape
    # gather feature values feats[b, fi[b,s,t,d]]
    sel = jax.vmap(lambda fvec, fidx: fvec[fidx])(feats, fi.reshape(B, -1))
    sel = sel.reshape(B, S, T, D)
    bits = (sel > th).astype(jnp.int32)
    powers = 2 ** jnp.arange(D - 1, -1, -1, dtype=jnp.int32)
    leaf = jnp.sum(bits * powers, axis=-1)    # [B, S, T]
    votes = jnp.take_along_axis(
        tb, leaf[..., None, None], axis=3)[..., 0, :]      # [B, S, T, Cl]
    return jnp.mean(votes, axis=2)


def cell_probs_dense(forest: Forest, feats: jnp.ndarray,
                     use_kernel: bool = True) -> jnp.ndarray:
    """All-cells dense prediction: [B, F] → [B, C, Cl] (engine path).

    Flattens (cell, tree) → one kernel launch; per-cell vote sums come back
    from the celled kernel variant.
    """
    from repro.kernels import ops as kops
    C, T, D = forest.feat_idx.shape
    Cl = forest.tables.shape[-1]
    fi = forest.feat_idx.reshape(C * T, D)
    th = forest.thresh.reshape(C * T, D)
    tb = forest.tables.reshape(C * T, 2 ** D, Cl)
    if use_kernel:
        votes = kops.forest_infer_cells(feats, fi, th, tb, n_cells=C)
    else:
        from repro.kernels import ref
        sel = feats[:, fi]
        flat = ref.forest_infer_percell(sel, th, tb)       # [B, C*T, Cl]
        votes = flat.reshape(feats.shape[0], C, T, Cl).sum(axis=2)
    return votes / T
