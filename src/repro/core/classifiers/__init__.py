"""Learned components of the AI-tree: multi-label cell experts + binary router."""
