"""The binary high/low-overlap query router (paper §IV, §V-C2).

The paper uses a scikit-learn random forest trained to *generalize* (80/20
split, ~80% accuracy). We implement the random forest as bagged oblivious
trees (host-trained, device-evaluated via the forest kernel) over simple
geometric features of the query rectangle.

Label convention: ``1`` ⇔ high-overlap ⇔ α ≤ τ ⇔ route to the AI-tree.
(The paper writes it with 0/1 flipped; only the routing decision matters.)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.classifiers.forest import _fit_oblivious_tree


def router_features_jnp(queries: jnp.ndarray) -> jnp.ndarray:
    """[Q, 4] rects → [Q, 6] features: corners + width/height.

    The single source of truth for the router's feature map — the device
    inference path (``predict_proba``) and the host trainer both call it,
    so the two can never drift (they used to be separate inline copies).
    """
    q = queries.astype(jnp.float32)
    return jnp.concatenate(
        [q, (q[:, 2] - q[:, 0])[:, None], (q[:, 3] - q[:, 1])[:, None]],
        axis=1)


def router_features(queries: np.ndarray) -> np.ndarray:
    """Host-side wrapper over the shared jnp feature fn (trainer path)."""
    return np.asarray(
        router_features_jnp(jnp.asarray(queries, jnp.float32)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Router:
    feat_idx: jnp.ndarray   # [T, D] i32
    thresh: jnp.ndarray     # [T, D] f32
    tables: jnp.ndarray     # [T, 2^D, 1] f32 — P(high-overlap) per leaf
    tau: float = dataclasses.field(metadata=dict(static=True))

    def byte_size(self) -> int:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in (self.feat_idx, self.thresh, self.tables))


def predict_proba(router: Router, queries: jnp.ndarray) -> jnp.ndarray:
    """[B, 4] → [B] P(high-overlap). Runs the Pallas forest kernel."""
    from repro.kernels import ops as kops
    feats = router_features_jnp(queries)
    votes = kops.forest_infer(feats, router.feat_idx, router.thresh,
                              router.tables)          # [B, 1] summed votes
    return votes[:, 0] / router.feat_idx.shape[0]


def route_high(router: Router, queries: jnp.ndarray,
               threshold: float = 0.5) -> jnp.ndarray:
    """[B, 4] → [B] bool — True ⇒ send to the AI-tree."""
    return predict_proba(router, queries) > threshold


@dataclasses.dataclass
class RouterReport:
    train_acc: float
    test_acc: float
    n_train: int
    n_test: int
    base_rate: float  # fraction of high-overlap queries overall


def train_router(queries: np.ndarray, alpha: np.ndarray, *, tau: float = 0.75,
                 n_trees: int = 16, depth: int = 6, n_thresholds: int = 16,
                 test_frac: float = 0.2, seed: int = 0
                 ) -> Tuple[Router, RouterReport]:
    """80/20 split training (paper §V-C2); reports both-set accuracy."""
    rng = np.random.default_rng(seed)
    X = router_features(queries)
    y = (np.asarray(alpha) <= tau).astype(np.float32)[:, None]
    n = X.shape[0]
    perm = rng.permutation(n)
    n_test = max(1, int(n * test_frac))
    test, train = perm[:n_test], perm[n_test:]
    Xtr, ytr = X[train], y[train]

    fis, ths, tbs = [], [], []
    for t in range(n_trees):
        idx = rng.integers(0, Xtr.shape[0], Xtr.shape[0])  # bootstrap
        fi, th, tb = _fit_oblivious_tree(
            Xtr[idx], ytr[idx], depth, n_thresholds, rng)
        fis.append(fi)
        ths.append(th)
        tbs.append(tb)
    router = Router(
        feat_idx=jnp.asarray(np.stack(fis)),
        thresh=jnp.asarray(np.stack(ths)),
        tables=jnp.asarray(np.stack(tbs)),
        tau=float(tau),
    )

    def acc(idx: np.ndarray) -> float:
        p = np.asarray(predict_proba(router, jnp.asarray(queries[idx],
                                                         jnp.float32)))
        return float(np.mean((p > 0.5) == (y[idx, 0] > 0.5)))

    report = RouterReport(
        train_acc=acc(train), test_acc=acc(test), n_train=len(train),
        n_test=len(test), base_rate=float(y.mean()))
    return router, report
