"""Stacked multi-label MLP experts — the TPU-native cell classifier.

One tiny MLP per non-empty grid cell, all cells stacked into single tensors
``[C, ...]`` so that (a) expert-parallel sharding over the ``model`` mesh
axis is a plain array partition and (b) inference over all local cells is a
dense einsum on the MXU — no per-query parameter gathers.

The paper intentionally **overfits** its per-cell models (§III-B); we train
with full-batch AdamW until the training workload is exactly fit (predicted
set == true set under the 0.5 threshold) or an epoch cap is hit. Residual
misfit is absorbed by the hybrid fallback rule, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.celldata import CellDataset


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MLPBank:
    w1: jnp.ndarray         # [C, F, H]
    b1: jnp.ndarray         # [C, H]
    w2: jnp.ndarray         # [C, H, Cl]
    b2: jnp.ndarray         # [C, Cl]
    mu: jnp.ndarray         # [F] feature normalizer
    sd: jnp.ndarray         # [F]
    label_map: jnp.ndarray  # [C, Cl] i32 (-1 pad)
    lmask: jnp.ndarray      # [C, Cl] bool

    @property
    def n_cells(self) -> int:
        return self.w1.shape[0]

    @property
    def n_local_labels(self) -> int:
        return self.w2.shape[-1]

    def byte_size(self) -> int:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in
                   (self.w1, self.b1, self.w2, self.b2, self.label_map))


def init_bank(ds: CellDataset, hidden: int = 64, seed: int = 0) -> MLPBank:
    C, _, F = ds.feats.shape
    Cl = ds.max_labels
    rng = np.random.default_rng(seed)
    flat = ds.feats[ds.qmask]
    mu = flat.mean(axis=0) if flat.size else np.zeros((F,), np.float32)
    sd = flat.std(axis=0) + 1e-6 if flat.size else np.ones((F,), np.float32)
    return MLPBank(
        w1=jnp.asarray(rng.normal(0, 1.0 / np.sqrt(F), (C, F, hidden)),
                       jnp.float32),
        b1=jnp.zeros((C, hidden), jnp.float32),
        w2=jnp.asarray(rng.normal(0, 1.0 / np.sqrt(hidden), (C, hidden, Cl)),
                       jnp.float32),
        b2=jnp.zeros((C, Cl), jnp.float32),
        mu=jnp.asarray(mu, jnp.float32),
        sd=jnp.asarray(sd, jnp.float32),
        label_map=jnp.asarray(ds.label_map),
        lmask=jnp.asarray(ds.lmask),
    )


def cell_logits(bank: MLPBank, feats: jnp.ndarray) -> jnp.ndarray:
    """Dense all-cells forward: feats [..., B, F] → logits [..., B, C, Cl]."""
    x = (feats - bank.mu) / bank.sd
    h = jnp.maximum(
        jnp.einsum("...bf,cfh->...bch", x, bank.w1) + bank.b1, 0.0)
    return jnp.einsum("...bch,chl->...bcl", h, bank.w2) + bank.b2


def cell_logits_for(bank: MLPBank, feats: jnp.ndarray,
                    cell_ids: jnp.ndarray) -> jnp.ndarray:
    """Gathered forward for (query, cell-slot) pairs.

    feats [B, F], cell_ids [B, S] → logits [B, S, Cl]. Used by the
    single-device path where B·S ≪ B·C.
    """
    x = (feats - bank.mu) / bank.sd
    w1 = bank.w1[cell_ids]                    # [B, S, F, H]
    b1 = bank.b1[cell_ids]
    w2 = bank.w2[cell_ids]                    # [B, S, H, Cl]
    b2 = bank.b2[cell_ids]
    h = jnp.maximum(jnp.einsum("bf,bsfh->bsh", x, w1) + b1, 0.0)
    return jnp.einsum("bsh,bshl->bsl", h, w2) + b2


def global_scores(bank: MLPBank, probs: jnp.ndarray, slot_valid: jnp.ndarray,
                  cell_ids: jnp.ndarray, n_leaves: int) -> jnp.ndarray:
    """Union of per-cell predictions (paper: union of model outputs).

    probs [B, S, Cl] sigmoid scores, slot_valid [B, S], cell_ids [B, S]
    → [B, n_leaves] max-combined scores over the models a query overlaps.
    """
    B, S, Cl = probs.shape
    lm = bank.label_map[cell_ids]                         # [B, S, Cl]
    ok = slot_valid[:, :, None] & bank.lmask[cell_ids]
    tgt = jnp.where(ok, lm, n_leaves)                     # park invalid at L
    flat_t = tgt.reshape(B, S * Cl)
    flat_p = jnp.where(ok, probs, 0.0).reshape(B, S * Cl)
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    out = jnp.zeros((B, n_leaves + 1), probs.dtype)
    out = out.at[rows, flat_t].max(flat_p)
    return out[:, :n_leaves]


# ---------------------------------------------------------------------------
# training — cell-granular by construction (the online-refit contract)
#
# Every coupling between cells is removed so that training a *subset* of
# cells reproduces, bit for bit, what training the full bank would have
# given those cells (``build.refit_cells ≡ build.fit_airtree`` on the
# retrained cells — property-tested):
#   * init: each cell's weights come from its own fold-in rng stream
#     ``default_rng((seed, cell_id, tensor))`` — independent of which
#     other cells are in the batch;
#   * normalizer: ``mu``/``sd`` derive from the grid geometry, not from
#     the pooled workload features;
#   * loss: per-cell mean summed over cells, so each cell's gradient is
#     exactly what it would be trained alone (the old global-mask mean
#     rescaled every cell's gradient by the other cells' mask counts);
#   * early stop: per-cell freeze — a cell that reaches exact fit at a
#     ``check_every`` boundary stops updating (params *and* Adam state
#     held), so its final weights do not depend on how long the other
#     cells keep training. Adam is elementwise, so per-cell trajectories
#     are independent given the decoupled gradients.
# ---------------------------------------------------------------------------

def grid_norm(grid) -> tuple[np.ndarray, np.ndarray]:
    """Feature normalizer derived from the grid bbox alone: rect corners
    centered on the bbox center and scaled by its half-extents. Workload-
    independent, so a cell's normalized features — and hence its whole
    training trajectory — never change when other cells' queries do."""
    b = np.asarray(grid.bbox, np.float32)
    cx, cy = (b[0] + b[2]) / 2, (b[1] + b[3]) / 2
    hx = max((b[2] - b[0]) / 2, 1e-6)
    hy = max((b[3] - b[1]) / 2, 1e-6)
    return (np.array([cx, cy, cx, cy], np.float32),
            np.array([hx, hy, hx, hy], np.float32))


def init_cell_params(cell_ids: np.ndarray, n_feats: int, hidden: int,
                     n_labels: int, seed: int = 0) -> dict:
    """Per-cell fold-in init: cell ``c``'s weights come from rng streams
    keyed ``(seed, c, tensor)`` — identical whether ``c`` is initialized
    alone or inside the full bank."""
    w1, w2 = [], []
    for c in np.asarray(cell_ids, np.int64):
        r1 = np.random.default_rng((seed, int(c), 0))
        r2 = np.random.default_rng((seed, int(c), 1))
        w1.append(r1.normal(0, 1.0 / np.sqrt(n_feats),
                            (n_feats, hidden)).astype(np.float32))
        w2.append(r2.normal(0, 1.0 / np.sqrt(hidden),
                            (hidden, n_labels)).astype(np.float32))
    C = len(w1)
    return {"w1": jnp.asarray(np.stack(w1)),
            "b1": jnp.zeros((C, hidden), jnp.float32),
            "w2": jnp.asarray(np.stack(w2)),
            "b2": jnp.zeros((C, n_labels), jnp.float32)}


def _cell_logits_p(params: dict, feats, mu, sd) -> jnp.ndarray:
    x = (feats - mu) / sd
    h = jnp.maximum(jnp.einsum("cqf,cfh->cqh", x, params["w1"])
                    + params["b1"][:, None, :], 0.0)
    return jnp.einsum("cqh,chl->cql", h, params["w2"]) \
        + params["b2"][:, None, :]


def _bce_cells(params: dict, feats, labels, qmask, lmask, live, mu, sd
               ) -> jnp.ndarray:
    """Decoupled loss: per-cell masked mean, summed over live cells."""
    z = jnp.clip(_cell_logits_p(params, feats, mu, sd), -30, 30)
    ce = jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
    # positive-class upweighting: multi-hot targets are sparse
    w = jnp.where(labels > 0, 4.0, 1.0)
    m = (qmask[:, :, None] & lmask[:, None, :]).astype(jnp.float32)
    per = jnp.sum(ce * w * m, axis=(1, 2)) \
        / jnp.maximum(jnp.sum(m, axis=(1, 2)), 1.0)
    return jnp.sum(per * live)


def cell_fit_fractions(params: dict, feats, labels, qmask, lmask, mu, sd,
                       threshold: float = 0.5) -> jnp.ndarray:
    """[C] per-cell fraction of valid training queries whose predicted set
    equals the true set. Cells with no valid query are vacuously 1.0."""
    logits = _cell_logits_p(params, feats, mu, sd)
    pred = (jax.nn.sigmoid(logits) > threshold) & lmask[:, None, :]
    ok = jnp.all(pred == (labels > 0.5), axis=-1) | ~qmask
    n = jnp.sum(qmask, axis=1)
    return jnp.where(n > 0,
                     jnp.sum(ok & qmask, axis=1) / jnp.maximum(n, 1), 1.0)


@jax.jit
def _update_cells(params, opt_m, opt_v, t, live, feats, labels, qmask,
                  lmask, mu, sd, lr, weight_decay):
    loss, g = jax.value_and_grad(_bce_cells)(
        params, feats, labels, qmask, lmask, live, mu, sd)
    b1c, b2c = 0.9, 0.999
    opt_m2 = jax.tree.map(lambda m_, g_: b1c * m_ + (1 - b1c) * g_,
                          opt_m, g)
    opt_v2 = jax.tree.map(lambda v_, g_: b2c * v_ + (1 - b2c) * g_ ** 2,
                          opt_v, g)
    mhat = jax.tree.map(lambda m_: m_ / (1 - b1c ** t), opt_m2)
    vhat = jax.tree.map(lambda v_: v_ / (1 - b2c ** t), opt_v2)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + 1e-8)
                                    + weight_decay * p),
        params, mhat, vhat)

    def keep_live(new_a, old_a):
        lv = live.astype(bool).reshape((-1,) + (1,) * (new_a.ndim - 1))
        return jnp.where(lv, new_a, old_a)

    # frozen cells hold params AND optimizer state: their trajectory ended
    # at their own freeze epoch, independent of the loop's total length
    params = jax.tree.map(keep_live, new, params)
    opt_m = jax.tree.map(keep_live, opt_m2, opt_m)
    opt_v = jax.tree.map(keep_live, opt_v2, opt_v)
    return params, opt_m, opt_v, loss


_fit_cells_j = jax.jit(cell_fit_fractions)


@dataclasses.dataclass
class TrainReport:
    epochs: int
    final_loss: float
    exact_fit: float


def train_cells(feats: np.ndarray, labels: np.ndarray, qmask: np.ndarray,
                lmask: np.ndarray, mu: np.ndarray, sd: np.ndarray,
                cell_ids: np.ndarray, *, hidden: int = 64, lr: float = 3e-3,
                weight_decay: float = 0.0, max_epochs: int = 3000,
                check_every: int = 200, target_fit: float = 1.0,
                seed: int = 0) -> Tuple[dict, TrainReport]:
    """Train a stack of per-cell experts over ``[C, Qp, ...]`` data rows.

    ``cell_ids`` names each row's *global* cell id — the fold-in init key —
    so a sub-stack of changed cells trains bit-identically to the same
    cells inside the full bank (see the module docstring). Returns the
    trained ``{w1, b1, w2, b2}`` rows and a ``TrainReport``.

    ``target_fit < 1.0`` keeps the legacy aggregate early stop; note that
    stopping before every cell froze makes the still-live cells' params
    depend on the co-trained set, so the refit-equivalence guarantee only
    holds at the default ``target_fit=1.0`` (where the stop condition —
    every cell exactly fit — is itself per-cell).
    """
    Cl = labels.shape[-1]
    params = init_cell_params(cell_ids, feats.shape[-1], hidden, Cl,
                              seed=seed)
    feats_j = jnp.asarray(feats, jnp.float32)
    labels_j = jnp.asarray(labels, jnp.float32)
    qmask_j = jnp.asarray(qmask)
    lmask_j = jnp.asarray(lmask)
    mu_j = jnp.asarray(mu, jnp.float32)
    sd_j = jnp.asarray(sd, jnp.float32)
    opt_m = jax.tree.map(jnp.zeros_like, params)
    opt_v = jax.tree.map(jnp.zeros_like, params)
    live = jnp.ones((feats.shape[0],), jnp.float32)

    loss = np.inf
    fit = 0.0
    epoch = 0
    for epoch in range(1, max_epochs + 1):
        params, opt_m, opt_v, loss = _update_cells(
            params, opt_m, opt_v, jnp.float32(epoch), live, feats_j,
            labels_j, qmask_j, lmask_j, mu_j, sd_j, jnp.float32(lr),
            jnp.float32(weight_decay))
        if epoch % check_every == 0 or epoch == max_epochs:
            fr = _fit_cells_j(params, feats_j, labels_j, qmask_j, lmask_j,
                              mu_j, sd_j)
            live = jnp.where(fr >= 1.0, 0.0, live)
            nq = np.asarray(jnp.sum(qmask_j, axis=1))
            frh = np.asarray(fr)
            fit = float((frh * nq).sum() / max(nq.sum(), 1))
            if not bool(np.any(np.asarray(live) > 0)) or fit >= target_fit:
                break
    return params, TrainReport(epochs=epoch, final_loss=float(loss),
                               exact_fit=float(fit))


def train_bank(ds: CellDataset, *, hidden: int = 64, lr: float = 3e-3,
               weight_decay: float = 0.0, max_epochs: int = 3000,
               check_every: int = 200, target_fit: float = 1.0,
               seed: int = 0) -> Tuple[MLPBank, TrainReport]:
    """Full-bank fit: ``train_cells`` over every grid cell + assembly.

    Kept as the one-shot entry point; the incremental path
    (``build.refit_cells``) runs the identical per-cell pipeline on a
    row subset and splices the results into the live bank."""
    C = ds.feats.shape[0]
    mu, sd = grid_norm(ds.grid)
    params, rep = train_cells(
        ds.feats, ds.labels, ds.qmask, ds.lmask, mu, sd,
        np.arange(C, dtype=np.int64), hidden=hidden, lr=lr,
        weight_decay=weight_decay, max_epochs=max_epochs,
        check_every=check_every, target_fit=target_fit, seed=seed)
    bank = MLPBank(
        w1=params["w1"], b1=params["b1"], w2=params["w2"], b2=params["b2"],
        mu=jnp.asarray(mu), sd=jnp.asarray(sd),
        label_map=jnp.asarray(ds.label_map), lmask=jnp.asarray(ds.lmask))
    return bank, rep


def exact_fit_fraction(bank: MLPBank, feats, labels, qmask, lmask,
                       threshold: float = 0.5) -> jnp.ndarray:
    """Fraction of (valid) training queries whose predicted set == true set."""
    params = {"w1": bank.w1, "b1": bank.b1, "w2": bank.w2, "b2": bank.b2}
    fr = cell_fit_fractions(params, feats, labels, qmask, lmask, bank.mu,
                            bank.sd, threshold)
    n = jnp.sum(qmask, axis=1)
    return jnp.sum(fr * n) / jnp.maximum(jnp.sum(n), 1)
