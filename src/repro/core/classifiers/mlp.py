"""Stacked multi-label MLP experts — the TPU-native cell classifier.

One tiny MLP per non-empty grid cell, all cells stacked into single tensors
``[C, ...]`` so that (a) expert-parallel sharding over the ``model`` mesh
axis is a plain array partition and (b) inference over all local cells is a
dense einsum on the MXU — no per-query parameter gathers.

The paper intentionally **overfits** its per-cell models (§III-B); we train
with full-batch AdamW until the training workload is exactly fit (predicted
set == true set under the 0.5 threshold) or an epoch cap is hit. Residual
misfit is absorbed by the hybrid fallback rule, exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.celldata import CellDataset


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MLPBank:
    w1: jnp.ndarray         # [C, F, H]
    b1: jnp.ndarray         # [C, H]
    w2: jnp.ndarray         # [C, H, Cl]
    b2: jnp.ndarray         # [C, Cl]
    mu: jnp.ndarray         # [F] feature normalizer
    sd: jnp.ndarray         # [F]
    label_map: jnp.ndarray  # [C, Cl] i32 (-1 pad)
    lmask: jnp.ndarray      # [C, Cl] bool

    @property
    def n_cells(self) -> int:
        return self.w1.shape[0]

    @property
    def n_local_labels(self) -> int:
        return self.w2.shape[-1]

    def byte_size(self) -> int:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in
                   (self.w1, self.b1, self.w2, self.b2, self.label_map))


def init_bank(ds: CellDataset, hidden: int = 64, seed: int = 0) -> MLPBank:
    C, _, F = ds.feats.shape
    Cl = ds.max_labels
    rng = np.random.default_rng(seed)
    flat = ds.feats[ds.qmask]
    mu = flat.mean(axis=0) if flat.size else np.zeros((F,), np.float32)
    sd = flat.std(axis=0) + 1e-6 if flat.size else np.ones((F,), np.float32)
    return MLPBank(
        w1=jnp.asarray(rng.normal(0, 1.0 / np.sqrt(F), (C, F, hidden)),
                       jnp.float32),
        b1=jnp.zeros((C, hidden), jnp.float32),
        w2=jnp.asarray(rng.normal(0, 1.0 / np.sqrt(hidden), (C, hidden, Cl)),
                       jnp.float32),
        b2=jnp.zeros((C, Cl), jnp.float32),
        mu=jnp.asarray(mu, jnp.float32),
        sd=jnp.asarray(sd, jnp.float32),
        label_map=jnp.asarray(ds.label_map),
        lmask=jnp.asarray(ds.lmask),
    )


def cell_logits(bank: MLPBank, feats: jnp.ndarray) -> jnp.ndarray:
    """Dense all-cells forward: feats [..., B, F] → logits [..., B, C, Cl]."""
    x = (feats - bank.mu) / bank.sd
    h = jnp.maximum(
        jnp.einsum("...bf,cfh->...bch", x, bank.w1) + bank.b1, 0.0)
    return jnp.einsum("...bch,chl->...bcl", h, bank.w2) + bank.b2


def cell_logits_for(bank: MLPBank, feats: jnp.ndarray,
                    cell_ids: jnp.ndarray) -> jnp.ndarray:
    """Gathered forward for (query, cell-slot) pairs.

    feats [B, F], cell_ids [B, S] → logits [B, S, Cl]. Used by the
    single-device path where B·S ≪ B·C.
    """
    x = (feats - bank.mu) / bank.sd
    w1 = bank.w1[cell_ids]                    # [B, S, F, H]
    b1 = bank.b1[cell_ids]
    w2 = bank.w2[cell_ids]                    # [B, S, H, Cl]
    b2 = bank.b2[cell_ids]
    h = jnp.maximum(jnp.einsum("bf,bsfh->bsh", x, w1) + b1, 0.0)
    return jnp.einsum("bsh,bshl->bsl", h, w2) + b2


def global_scores(bank: MLPBank, probs: jnp.ndarray, slot_valid: jnp.ndarray,
                  cell_ids: jnp.ndarray, n_leaves: int) -> jnp.ndarray:
    """Union of per-cell predictions (paper: union of model outputs).

    probs [B, S, Cl] sigmoid scores, slot_valid [B, S], cell_ids [B, S]
    → [B, n_leaves] max-combined scores over the models a query overlaps.
    """
    B, S, Cl = probs.shape
    lm = bank.label_map[cell_ids]                         # [B, S, Cl]
    ok = slot_valid[:, :, None] & bank.lmask[cell_ids]
    tgt = jnp.where(ok, lm, n_leaves)                     # park invalid at L
    flat_t = tgt.reshape(B, S * Cl)
    flat_p = jnp.where(ok, probs, 0.0).reshape(B, S * Cl)
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    out = jnp.zeros((B, n_leaves + 1), probs.dtype)
    out = out.at[rows, flat_t].max(flat_p)
    return out[:, :n_leaves]


# ---------------------------------------------------------------------------
# training (full-batch AdamW over the stacked experts; overfit on purpose)
# ---------------------------------------------------------------------------

def _bce(bank: MLPBank, feats, labels, qmask, lmask) -> jnp.ndarray:
    logits = jnp.einsum("cqh,chl->cql", jnp.maximum(
        jnp.einsum("cqf,cfh->cqh", (feats - bank.mu) / bank.sd, bank.w1)
        + bank.b1[:, None, :], 0.0), bank.w2) + bank.b2[:, None, :]
    # positive-class upweighting: multi-hot targets are sparse
    z = jnp.clip(logits, -30, 30)
    ce = jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
    w = jnp.where(labels > 0, 4.0, 1.0)
    m = qmask[:, :, None] & lmask[:, None, :]
    return jnp.sum(ce * w * m) / jnp.maximum(jnp.sum(m), 1)


def exact_fit_fraction(bank: MLPBank, feats, labels, qmask, lmask,
                       threshold: float = 0.5) -> jnp.ndarray:
    """Fraction of (valid) training queries whose predicted set == true set."""
    logits = jnp.einsum("cqh,chl->cql", jnp.maximum(
        jnp.einsum("cqf,cfh->cqh", (feats - bank.mu) / bank.sd, bank.w1)
        + bank.b1[:, None, :], 0.0), bank.w2) + bank.b2[:, None, :]
    pred = (jax.nn.sigmoid(logits) > threshold) & lmask[:, None, :]
    tgt = labels > 0.5
    ok = jnp.all(pred == tgt, axis=-1) | ~qmask
    return jnp.sum(ok & qmask) / jnp.maximum(jnp.sum(qmask), 1)


@dataclasses.dataclass
class TrainReport:
    epochs: int
    final_loss: float
    exact_fit: float


def train_bank(ds: CellDataset, *, hidden: int = 64, lr: float = 3e-3,
               weight_decay: float = 0.0, max_epochs: int = 3000,
               check_every: int = 200, target_fit: float = 1.0,
               seed: int = 0) -> Tuple[MLPBank, TrainReport]:
    bank = init_bank(ds, hidden=hidden, seed=seed)
    feats = jnp.asarray(ds.feats)
    labels = jnp.asarray(ds.labels)
    qmask = jnp.asarray(ds.qmask)
    lmask = jnp.asarray(ds.lmask)

    params = {"w1": bank.w1, "b1": bank.b1, "w2": bank.w2, "b2": bank.b2}
    opt_m = jax.tree.map(jnp.zeros_like, params)
    opt_v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def update(params, opt_m, opt_v, t):
        def lf(p):
            b = dataclasses.replace(bank, **p)
            return _bce(b, feats, labels, qmask, lmask)
        loss, g = jax.value_and_grad(lf)(params)
        b1c, b2c = 0.9, 0.999
        opt_m = jax.tree.map(lambda m_, g_: b1c * m_ + (1 - b1c) * g_, opt_m, g)
        opt_v = jax.tree.map(lambda v_, g_: b2c * v_ + (1 - b2c) * g_ ** 2,
                             opt_v, g)
        mhat = jax.tree.map(lambda m_: m_ / (1 - b1c ** t), opt_m)
        vhat = jax.tree.map(lambda v_: v_ / (1 - b2c ** t), opt_v)
        params = jax.tree.map(
            lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + 1e-8)
                                        + weight_decay * p),
            params, mhat, vhat)
        return params, opt_m, opt_v, loss

    @jax.jit
    def fit_of(params):
        b = dataclasses.replace(bank, **params)
        return exact_fit_fraction(b, feats, labels, qmask, lmask)

    loss = np.inf
    fit = 0.0
    epoch = 0
    for epoch in range(1, max_epochs + 1):
        params, opt_m, opt_v, loss = update(params, opt_m, opt_v, epoch)
        if epoch % check_every == 0 or epoch == max_epochs:
            fit = float(fit_of(params))
            if fit >= target_fit:
                break
    bank = dataclasses.replace(bank, **params)
    return bank, TrainReport(epochs=epoch, final_loss=float(loss),
                             exact_fit=float(fit))
