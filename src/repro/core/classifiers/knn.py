"""Per-cell exact-memorization classifier (nearest-stored-query lookup).

The paper's sklearn decision trees (max_depth 30) effectively *memorize* the
training workload — that is what gives the AI-tree its 100% training-set
accuracy (§V-B3). Oblivious trees (our TPU-executable tree family) share one
split per level and cannot always reach perfect memorization. This module
provides the memorization-complete equivalent: each cell stores its training
queries and their label sets; at query time the nearest stored query (L∞
over the rectangle corners) within ε answers. Distance computation is a
batched matmul-like reduction — MXU/VPU friendly — and unseen queries
(distance > ε) yield an empty prediction, which triggers the hybrid's exact
fallback, preserving correctness on any workload.

This is the configuration to compare against the paper's perfect-fit
numbers; ``forest`` is the paper-faithful classifier *family*, ``knn`` is
the paper-faithful classifier *behaviour*.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.celldata import CellDataset


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KNNBank:
    feats: jnp.ndarray      # [C, Qp, F] stored queries (+inf padded)
    labels: jnp.ndarray     # [C, Qp, Cl] stored multi-hot label sets
    label_map: jnp.ndarray  # [C, Cl] i32
    lmask: jnp.ndarray      # [C, Cl] bool
    eps: float = dataclasses.field(metadata=dict(static=True))

    @property
    def n_cells(self) -> int:
        return self.feats.shape[0]

    def byte_size(self) -> int:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize for a in
                   (self.feats, self.labels, self.label_map))


def fit_knn(ds: CellDataset, eps: float = 1e-6) -> KNNBank:
    feats = ds.feats.copy()
    feats[~ds.qmask] = np.inf          # padding can never be nearest
    return KNNBank(
        feats=jnp.asarray(feats),
        labels=jnp.asarray(ds.labels),
        label_map=jnp.asarray(ds.label_map),
        lmask=jnp.asarray(ds.lmask),
        eps=float(eps),
    )


def cell_probs_for(bank: KNNBank, queries: jnp.ndarray,
                   cell_ids: jnp.ndarray) -> jnp.ndarray:
    """[B, 4] × [B, S] → [B, S, Cl] — nearest stored query's labels, or 0s.

    Only the winning row's label vector is gathered ([B,S,Cl], not
    [B,S,Qp,Cl]) — stored-label traffic is Qp× smaller than the naive
    gather, which dominated the engine's HBM bytes (EXPERIMENTS.md §Perf).
    """
    stored = bank.feats[cell_ids]                  # [B, S, Qp, F]
    q = queries.astype(jnp.float32)[:, None, None, :]
    d = jnp.max(jnp.abs(jnp.where(jnp.isfinite(stored), stored, 1e30) - q),
                axis=-1)                           # [B, S, Qp] L∞
    best = jnp.argmin(d, axis=-1)                  # [B, S]
    bestd = jnp.min(d, axis=-1)
    hit = (bestd <= bank.eps)[..., None]           # [B, S, 1]
    picked = bank.labels[cell_ids, best]           # [B, S, Cl]
    return jnp.where(hit, picked, 0.0)
