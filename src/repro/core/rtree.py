"""Host-side R-tree builder (numpy) — the classical substrate of the paper.

The paper (§V-B1) constructs the R-tree with *one-at-a-time tuple insertion*
(to replicate a dynamic environment), Guttman's **linear** node-splitting
algorithm, and ``m = M/2``. That exact build path is implemented here, plus an
STR bulk loader as a beyond-paper option for fast test setup.

The host tree is a *builder*; query serving happens on device via the
flattened structure-of-arrays form (see ``device_tree.py`` / ``traversal.py``).
A reference host ``query()`` is kept for ground-truth label preparation
(§III-A4) and for property tests.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core import geometry as geo


@dataclasses.dataclass
class RTreeStats:
    n_points: int
    n_leaves: int
    n_internal: int
    height: int  # number of levels, root = level 0
    max_entries: int
    min_entries: int
    array_bytes: int  # serialized structure-of-arrays footprint


class RTree:
    """Dynamic R-tree with Guttman linear split.

    Nodes live in parallel python/numpy storage:

    * ``self.mbrs``     — [cap, 4] float64 node MBRs
    * ``self.children`` — list of lists; for internal nodes: child node ids,
                          for leaves: entry (point) ids
    * ``self.is_leaf``  — list of bool
    * ``self.parent``   — list of Optional[int]
    """

    def __init__(self, max_entries: int = 200, min_entries: Optional[int] = None):
        if max_entries < 4:
            raise ValueError("max_entries must be >= 4")
        self.M = int(max_entries)
        self.m = int(min_entries) if min_entries is not None else self.M // 2
        if not (1 <= self.m <= self.M // 2):
            raise ValueError("min_entries must be in [1, M/2]")
        self._cap = 1024
        self.mbrs = np.full((self._cap, 4), np.nan, dtype=np.float64)
        self.children: List[List[int]] = []
        self.is_leaf: List[bool] = []
        self.parent: List[Optional[int]] = []
        self.n_nodes = 0
        self.root = self._new_node(is_leaf=True)
        self.points: Optional[np.ndarray] = None  # set by build()/insert_all()
        self._n_points = 0

    # -- node storage -------------------------------------------------------

    def _new_node(self, is_leaf: bool) -> int:
        if self.n_nodes == self._cap:
            self._cap *= 2
            grown = np.full((self._cap, 4), np.nan, dtype=np.float64)
            grown[: self.n_nodes] = self.mbrs[: self.n_nodes]
            self.mbrs = grown
        nid = self.n_nodes
        self.n_nodes += 1
        self.children.append([])
        self.is_leaf.append(is_leaf)
        self.parent.append(None)
        return nid

    # -- insertion (paper path) --------------------------------------------

    def insert_all(self, points: np.ndarray, progress_every: int = 0) -> "RTree":
        """One-at-a-time insertion of ``points`` [N, 2] (paper §V-B1)."""
        points = np.asarray(points, dtype=np.float64)
        if self.points is None:
            self.points = points
        else:
            self.points = np.concatenate([self.points, points], axis=0)
        for i in range(points.shape[0]):
            self._insert_one(self._n_points + i, points[i])
            if progress_every and (i + 1) % progress_every == 0:
                print(f"  inserted {i + 1}/{points.shape[0]}")
        self._n_points += points.shape[0]
        return self

    def _insert_one(self, pid: int, pt: np.ndarray) -> None:
        rect = np.array([pt[0], pt[1], pt[0], pt[1]], dtype=np.float64)
        leaf = self._choose_leaf(rect)
        self.children[leaf].append(pid)
        self._enlarge_upward(leaf, rect)
        if len(self.children[leaf]) > self.M:
            self._split(leaf)

    def _choose_leaf(self, rect: np.ndarray) -> int:
        node = self.root
        while not self.is_leaf[node]:
            kids = self.children[node]
            kid_mbrs = self.mbrs[kids]
            enl = geo.np_enlargement(kid_mbrs, rect[None, :])
            areas = geo.np_area(kid_mbrs)
            # least enlargement; ties by least area (Guttman).
            best = np.lexsort((areas, enl))[0]
            node = kids[best]
        return node

    def _enlarge_upward(self, node: int, rect: np.ndarray) -> None:
        cur: Optional[int] = node
        while cur is not None:
            mbr = self.mbrs[cur]
            if np.isnan(mbr[0]):
                self.mbrs[cur] = rect
            else:
                new = geo.np_union(mbr, rect)
                if np.array_equal(new, mbr):
                    return  # ancestors already cover it
                self.mbrs[cur] = new
            cur = self.parent[cur]

    # -- Guttman linear split ------------------------------------------------

    def _entry_rects(self, node: int) -> np.ndarray:
        """MBRs of a node's entries: child node MBRs or degenerate point rects."""
        if self.is_leaf[node]:
            pts = self.points[self.children[node]]
            return np.concatenate([pts, pts], axis=1)  # [k, 4]
        return self.mbrs[self.children[node]].copy()

    @staticmethod
    def _linear_pick_seeds(rects: np.ndarray) -> Tuple[int, int]:
        """Greatest normalized separation along any dimension (Guttman LINEAR)."""
        best_sep, seeds = -np.inf, (0, 1)
        for lo_ax, hi_ax in ((geo.XMIN, geo.XMAX), (geo.YMIN, geo.YMAX)):
            width = rects[:, hi_ax].max() - rects[:, lo_ax].min()
            if width <= 0:
                continue
            # entry with highest low side vs entry with lowest high side
            hi_lo = int(np.argmax(rects[:, lo_ax]))
            lo_hi = int(np.argmin(rects[:, hi_ax]))
            if hi_lo == lo_hi:
                continue
            sep = (rects[hi_lo, lo_ax] - rects[lo_hi, hi_ax]) / width
            if sep > best_sep:
                best_sep, seeds = sep, (hi_lo, lo_hi)
        if seeds[0] == seeds[1]:  # fully degenerate input; arbitrary split
            seeds = (0, 1)
        return seeds

    def _split(self, node: int) -> None:
        entries = self.children[node]
        rects = self._entry_rects(node)
        k = len(entries)
        s1, s2 = self._linear_pick_seeds(rects)
        g1, g2 = [s1], [s2]
        mbr1, mbr2 = rects[s1].copy(), rects[s2].copy()
        rest = [i for i in range(k) if i not in (s1, s2)]
        for i in rest:
            need1 = self.m - len(g1)
            need2 = self.m - len(g2)
            remaining = k - len(g1) - len(g2)
            if need1 >= remaining:  # must all go to g1 to reach min fill
                g1.append(i)
                mbr1 = geo.np_union(mbr1, rects[i])
                continue
            if need2 >= remaining:
                g2.append(i)
                mbr2 = geo.np_union(mbr2, rects[i])
                continue
            d1 = geo.np_enlargement(mbr1, rects[i])
            d2 = geo.np_enlargement(mbr2, rects[i])
            if d1 < d2 or (d1 == d2 and geo.np_area(mbr1) <= geo.np_area(mbr2)):
                g1.append(i)
                mbr1 = geo.np_union(mbr1, rects[i])
            else:
                g2.append(i)
                mbr2 = geo.np_union(mbr2, rects[i])

        sibling = self._new_node(is_leaf=self.is_leaf[node])
        ids = entries  # original entry ids
        self.children[node] = [ids[i] for i in g1]
        self.children[sibling] = [ids[i] for i in g2]
        self.mbrs[node] = mbr1
        self.mbrs[sibling] = mbr2
        if not self.is_leaf[node]:
            for c in self.children[sibling]:
                self.parent[c] = sibling

        par = self.parent[node]
        if par is None:  # root split → grow tree
            new_root = self._new_node(is_leaf=False)
            self.children[new_root] = [node, sibling]
            self.parent[node] = new_root
            self.parent[sibling] = new_root
            self.mbrs[new_root] = geo.np_union(mbr1, mbr2)
            self.root = new_root
        else:
            self.parent[sibling] = par
            self.children[par].append(sibling)
            # parent MBR already covers both halves (it covered the original)
            if len(self.children[par]) > self.M:
                self._split(par)

    # -- STR bulk load (beyond-paper fast path) ------------------------------

    @classmethod
    def str_bulk(cls, points: np.ndarray, max_entries: int = 200,
                 min_entries: Optional[int] = None, fill: float = 0.7) -> "RTree":
        """Sort-Tile-Recursive bulk load. Produces a packed tree quickly; used
        by tests and as a baseline-quality comparison (the paper's dynamic
        build deliberately has worse overlap)."""
        points = np.asarray(points, dtype=np.float64)
        tree = cls(max_entries=max_entries, min_entries=min_entries)
        tree.points = points
        tree._n_points = points.shape[0]
        cap = max(2, int(tree.M * fill))
        n = points.shape[0]
        # --- leaf level via STR tiling
        order = np.argsort(points[:, 0], kind="stable")
        n_leaves = int(np.ceil(n / cap))
        n_slices = int(np.ceil(np.sqrt(n_leaves)))
        per_slice = int(np.ceil(n / n_slices))
        leaf_ids: List[int] = []
        for s in range(n_slices):
            sl = order[s * per_slice:(s + 1) * per_slice]
            if sl.size == 0:
                continue
            sl = sl[np.argsort(points[sl, 1], kind="stable")]
            for o in range(0, sl.size, cap):
                grp = sl[o:o + cap]
                nid = tree._new_node(is_leaf=True)
                tree.children[nid] = grp.tolist()
                tree.mbrs[nid] = geo.np_mbr_of_points(points[grp])
                leaf_ids.append(nid)
        # --- build upward
        level = leaf_ids
        while len(level) > 1:
            nxt: List[int] = []
            for o in range(0, len(level), cap):
                grp = level[o:o + cap]
                nid = tree._new_node(is_leaf=False)
                tree.children[nid] = grp
                for c in grp:
                    tree.parent[c] = nid
                tree.mbrs[nid] = geo.np_mbr_of_rects(tree.mbrs[grp])
                nxt.append(nid)
            level = nxt
        tree.root = level[0]
        # drop the unused node 0 created by __init__ if it is empty & orphaned
        if tree.root != 0 and not tree.children[0]:
            tree.mbrs[0] = np.array([np.inf, np.inf, -np.inf, -np.inf])
        return tree

    # -- host reference query (ground truth for labels & tests) --------------

    def query(self, rect: np.ndarray) -> Tuple[List[int], List[int], np.ndarray]:
        """Classical recursive range query.

        Returns ``(visited_leaf_node_ids, true_leaf_node_ids, result_point_ids)``
        where *visited* leaves are every leaf whose MBR intersects ``rect`` and
        *true* leaves are those actually containing qualifying points (§III-A2).
        """
        rect = np.asarray(rect, dtype=np.float64)
        visited: List[int] = []
        true: List[int] = []
        results: List[np.ndarray] = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            mbr = self.mbrs[node]
            if np.isnan(mbr[0]) or not geo.np_intersects(mbr, rect):
                continue
            if self.is_leaf[node]:
                visited.append(node)
                if self.children[node]:
                    pts_idx = np.asarray(self.children[node])
                    inside = geo.np_contains_point(rect, self.points[pts_idx])
                    if inside.any():
                        true.append(node)
                        results.append(pts_idx[inside])
            else:
                # push in reverse so traversal order matches DFS child order
                stack.extend(reversed(self.children[node]))
        out = np.concatenate(results) if results else np.empty((0,), dtype=np.int64)
        return visited, true, out

    # -- introspection --------------------------------------------------------

    def leaves_dfs(self) -> List[int]:
        """Leaf node ids in DFS order (§III-A1 — consecutive sibling IDs)."""
        order: List[int] = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            if self.is_leaf[node]:
                order.append(node)
            else:
                stack.extend(reversed(self.children[node]))
        return order

    def height(self) -> int:
        h, node = 1, self.root
        while not self.is_leaf[node]:
            node = self.children[node][0]
            h += 1
        return h

    def stats(self) -> RTreeStats:
        n_leaves = sum(1 for i in range(self.n_nodes) if self.is_leaf[i] and
                       (self.children[i] or i == self.root))
        n_internal = sum(1 for i in range(self.n_nodes) if not self.is_leaf[i])
        entry_bytes = sum(len(self.children[i]) for i in range(self.n_nodes)) * 8
        mbr_bytes = self.n_nodes * 4 * 8
        return RTreeStats(
            n_points=self._n_points,
            n_leaves=n_leaves,
            n_internal=n_internal,
            height=self.height(),
            max_entries=self.M,
            min_entries=self.m,
            array_bytes=entry_bytes + mbr_bytes,
        )

    # -- invariants (property tests) -----------------------------------------

    def check_invariants(self) -> None:
        """Raise AssertionError if any classical R-tree invariant is violated."""
        assert self.points is not None
        depth_of: dict = {self.root: 0}
        stack = [self.root]
        leaf_depths = set()
        seen_points: List[int] = []
        while stack:
            node = stack.pop()
            mbr = self.mbrs[node]
            kids = self.children[node]
            if node != self.root and not self.is_leaf[node]:
                assert self.m <= len(kids) <= self.M, (
                    f"internal fill {len(kids)} outside [{self.m},{self.M}]")
            if self.is_leaf[node]:
                leaf_depths.add(depth_of[node])
                if node != self.root:
                    assert self.m <= len(kids) <= self.M, (
                        f"leaf fill {len(kids)} outside [{self.m},{self.M}]")
                if kids:
                    pts = self.points[kids]
                    got = geo.np_mbr_of_points(pts)
                    assert np.allclose(got, mbr), "leaf MBR != tight MBR of points"
                    seen_points.extend(kids)
            else:
                assert kids, "internal node with no children"
                kid_mbr = geo.np_mbr_of_rects(self.mbrs[kids])
                assert np.allclose(kid_mbr, mbr), "internal MBR != union of children"
                for c in kids:
                    assert self.parent[c] == node, "parent pointer broken"
                    depth_of[c] = depth_of[node] + 1
                stack.extend(kids)
        assert len(leaf_depths) <= 1, f"unbalanced: leaf depths {leaf_depths}"
        assert sorted(seen_points) == list(range(self._n_points)), (
            "points lost or duplicated across leaves")
