"""Batched, level-synchronous R-tree range queries on device.

This is the TPU-native replacement for root-to-leaf pointer chasing: the
frontier at each level is a ``[B, N_l]`` boolean mask; expansion to the next
level is one gather (child → parent) plus one batched rectangle-intersection.

With ``use_kernel=True`` the whole root→leaf walk runs as one fused Pallas
kernel (``repro.kernels.traverse_fused``) — the frontier stays in VMEM across
levels instead of round-tripping [B, N_l] masks through HBM per level. The
pure-jnp per-level path doubles as its oracle. Mask→index compaction is
sort-free (prefix-count ranks + rowwise scatter), replacing the former
``top_k``-shaped implementations, which are kept as ``*_topk`` oracles.

Also implements the *refinement* step (exact point-in-rect filtering of the
visited/predicted leaves) and the overlap ratio α = TN/VN (§III-A2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import geometry as geo
from repro.core.device_tree import DeviceTree


def _cross_intersect(queries: jnp.ndarray, mbrs: jnp.ndarray,
                     use_kernel: bool) -> jnp.ndarray:
    """[B,4] × [N,4] → [B,N] bool."""
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.mbr_intersect(queries, mbrs)
    return geo.jnp_cross_intersects(queries, mbrs)


def visited_leaf_mask(tree: DeviceTree, queries: jnp.ndarray,
                      use_kernel: bool = False) -> jnp.ndarray:
    """Leaves the classical R-tree would visit for each query: [B, L] bool.

    Exactly reproduces the recursive traversal's visited set: a leaf is
    visited iff every ancestor MBR (and its own) intersects the query.

    With ``use_kernel`` the whole walk runs as a single fused ``pallas_call``
    (``repro.kernels.traverse_fused``): the internal frontier never leaves
    VMEM and only the final [B, L] mask is materialized. Without it, the
    level-by-level jnp path below doubles as the oracle.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.traverse_fused(
            queries, [lv.mbrs for lv in tree.levels],
            [lv.parent for lv in tree.levels],
            slices=getattr(tree, "aslices", None))
    return visited_leaf_mask_per_level(tree, queries, use_kernel=False)


def visited_leaf_mask_per_level(tree: DeviceTree, queries: jnp.ndarray,
                                use_kernel: bool = False) -> jnp.ndarray:
    """Level-synchronous traversal: one [B, N_l] intersection per level.

    The pre-fusion hot path, kept as the fused kernel's benchmark baseline
    and oracle (``ops.traverse_fused`` falls back to this same loop shape,
    kernel-accelerated, when a tree's working set exceeds the VMEM
    budget). ``use_kernel`` here only accelerates each level's
    cross-intersection; frontier masks still round-trip through HBM.
    """
    mask = _cross_intersect(queries, tree.levels[0].mbrs, use_kernel)  # [B, 1]
    for level in tree.levels[1:]:
        parent_alive = mask[:, level.parent]                 # [B, N_l]
        hit = _cross_intersect(queries, level.mbrs, use_kernel)
        mask = parent_alive & hit
    return mask


class RefineResult(NamedTuple):
    counts: jnp.ndarray      # [B, K] qualifying points per (query, leaf slot)
    inside: jnp.ndarray      # [B, K, M_pad] bool, per-entry containment
    leaf_idx: jnp.ndarray    # [B, K] leaf ids refined (padding slots arbitrary)
    valid: jnp.ndarray       # [B, K] slot validity


def compact_mask_counted(mask: jnp.ndarray, k: int
                         ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """[B, L] bool → (indices [B, k] i32, valid [B, k] bool, count [B] i32).

    Takes the first ``k`` set leaves per row (leaf-ID order). Sort-free:
    the ``j``-th set bit's column is the first position where the row's
    inclusive prefix count reaches ``j + 1``, i.e. a rowwise binary search
    of ``1..k`` over the cumsum — O(B·(L + k·log L)), no sort and no
    scatter (a rowwise scatter is equivalent but an order of magnitude
    slower under XLA:CPU; see EXPERIMENTS.md). ``count`` is the row's
    total set bits, so overflow (``count > k``) and validity come for free
    from the same scan — callers no longer re-reduce the mask.

    This is the canonical compaction scheme; the fused traversal kernel's
    epilogue (``kernels.traverse_fused.traverse_compact_t``) implements the
    identical rank semantics inside VMEM and is tested bit-identical.
    """
    m = mask.astype(jnp.int32)
    cs = jnp.cumsum(m, axis=-1)                          # inclusive prefix
    count = cs[:, -1]                                    # = sum, one pass
    targets = jnp.arange(1, k + 1, dtype=jnp.int32)
    idx = jax.vmap(
        lambda c: jnp.searchsorted(c, targets, side="left"))(cs)
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < count[:, None]
    return jnp.where(valid, idx.astype(jnp.int32), 0), valid, count


def compact_mask(mask: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[B, L] bool → (indices [B, k] i32, valid [B, k] bool).

    Thin wrapper over ``compact_mask_counted`` for callers that don't need
    the per-row count; overflow is reported via ``overflowed()`` and
    handled by the exact fallback path.
    """
    idx, valid, _ = compact_mask_counted(mask, k)
    return idx, valid


def compact_candidates(ids: jnp.ndarray, ok: jnp.ndarray, k: int
                       ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """First ``k`` **distinct** ids (ascending) among masked candidates.

    ``ids`` [B, N] i32 (≥ 0 where ``ok``), ``ok`` [B, N] bool →
    ``(slots [B, k] i32, valid [B, k] bool, count [B] i32)`` with
    ``count`` the distinct-id total. Bit-compatible with
    ``compact_mask_counted(scatter(ids into [B, L]), k)`` — same slot
    order, zero-filled invalid slots, same count — but without ever
    materializing a ``[B, L]`` table: dedup and ranking are O(N²)
    pairwise compares, the right trade when the candidate list is small
    (N ≪ L — per-cell label slots, gathered shard top-k lists). This is
    what lets the engine's AI path keep the compact slot table as the
    only inter-stage format.
    """
    B, N = ids.shape
    ids = ids.astype(jnp.int32)
    eq = ids[:, :, None] == ids[:, None, :]          # [B, i, j]
    earlier = jnp.tril(jnp.ones((N, N), jnp.bool_), -1)  # j < i
    dup = jnp.any(eq & earlier[None] & ok[:, None, :], axis=-1)
    rep = ok & ~dup                                  # first occurrence per id
    count = jnp.sum(rep.astype(jnp.int32), axis=-1)
    less = ids[:, None, :] < ids[:, :, None]         # id_j < id_i
    rank = jnp.sum((rep[:, None, :] & less).astype(jnp.int32), axis=-1)
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    slot = jnp.where(rep & (rank < k), rank, k)      # park the rest at k
    slots = jnp.zeros((B, k + 1), jnp.int32).at[rows, slot].max(
        jnp.where(rep, ids, 0))[:, :k]
    valid = jnp.arange(k, dtype=jnp.int32)[None, :] < count[:, None]
    return jnp.where(valid, slots, 0), valid, count


def compact_mask_topk(mask: jnp.ndarray, k: int
                      ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-optimization ``top_k``-based compaction (equivalence oracle)."""
    k_eff = min(k, mask.shape[-1])
    vals, idx = jax.lax.top_k(mask.astype(jnp.int32), k_eff)
    if k_eff < k:  # pad slots so callers keep a static [B, k] shape
        idx = jnp.pad(idx, ((0, 0), (0, k - k_eff)))
        vals = jnp.pad(vals, ((0, 0), (0, k - k_eff)))
    return idx.astype(jnp.int32), vals > 0


def overflowed(mask: jnp.ndarray, k: int) -> jnp.ndarray:
    """[B, L] → [B] bool: more than ``k`` leaves set (compact would truncate)."""
    return jnp.sum(mask.astype(jnp.int32), axis=-1) > k


def refine_leaves(tree: DeviceTree, queries: jnp.ndarray, leaf_idx: jnp.ndarray,
                  valid: jnp.ndarray, use_kernel: bool = False) -> RefineResult:
    """Exact containment test over the entries of selected leaves.

    ``queries``: [B, 4]; ``leaf_idx``: [B, K]; ``valid``: [B, K].
    Guarantees no false positives (paper §III-C): every reported entry is
    re-checked against the query rectangle.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        inside = kops.leaf_refine(queries, tree.leaf_entries, leaf_idx, valid)
    else:
        pts = tree.leaf_entries[leaf_idx]                   # [B, K, M, 2]
        inside = geo.jnp_contains_point(queries[:, None, None, :], pts)
        inside = inside & valid[:, :, None]
    counts = jnp.sum(inside.astype(jnp.int32), axis=-1)     # [B, K]
    return RefineResult(counts=counts, inside=inside, leaf_idx=leaf_idx,
                        valid=valid)


class CompactVisit(NamedTuple):
    leaf_idx: jnp.ndarray    # [B, k] i32 — first k visited leaves, ID order
    valid: jnp.ndarray       # [B, k] bool slot validity
    n_visited: jnp.ndarray   # [B] i32 total visited leaves (may exceed k)
    overflow: jnp.ndarray    # [B] bool — more than k leaves visited


def visited_leaves_compact(tree: DeviceTree, queries: jnp.ndarray, k: int,
                           use_kernel: bool = False,
                           tile_b: Optional[int] = None,
                           tile_l: Optional[int] = None) -> CompactVisit:
    """Classical visited set, compacted: first ``k`` visited leaves per row.

    With ``use_kernel`` this runs the fused traversal kernel's compaction
    epilogue (``kernels.ops.traverse_compact``): the ``[B, L]`` visited
    mask stays in VMEM and only the ``[B, k]`` slot table plus per-row
    counts reach HBM — the serving-path form. Without it, the jnp oracle
    materializes the mask and compacts it with the identical cumsum-rank
    scheme. ``tile_b``/``tile_l`` override the kernel's tile choice
    (testing/tuning only).
    """
    if use_kernel:
        from repro.kernels import ops as kops
        idx, valid, count = kops.traverse_compact(
            queries, [lv.mbrs for lv in tree.levels],
            [lv.parent for lv in tree.levels], k, tb=tile_b, tl=tile_l,
            slices=getattr(tree, "aslices", None))
    else:
        mask = visited_leaf_mask_per_level(tree, queries, use_kernel=False)
        idx, valid, count = compact_mask_counted(mask, k)
    return CompactVisit(leaf_idx=idx, valid=valid, n_visited=count,
                        overflow=count > k)


class QueryResult(NamedTuple):
    visited: jnp.ndarray        # [B, L] bool — classical visited set
    true_leaves: jnp.ndarray    # [B, L] bool — leaves with qualifying points
    n_visited: jnp.ndarray      # [B] i32
    n_true: jnp.ndarray         # [B] i32
    n_results: jnp.ndarray      # [B] i32 total qualifying points
    result_ids: jnp.ndarray     # [B, max_results] i32, -1 padded
    truncated: jnp.ndarray      # [B] bool — static bounds overflowed


def scatter_rows(base: jnp.ndarray, idx: jnp.ndarray,
                 vals: jnp.ndarray) -> jnp.ndarray:
    """Rowwise scatter: base [B, L], idx [B, K], vals [B, K] → [B, L]."""
    B = base.shape[0]
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    return base.at[rows, idx].max(vals)


def gather_result_ids(tree: DeviceTree, refine: RefineResult,
                      max_results: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Flatten qualifying entry ids to [B, max_results] (padded with -1).

    Sort-free, same scheme as ``compact_mask``: the ``j``-th qualifying
    entry's flat (leaf-slot, entry) position is a rowwise binary search of
    ``j + 1`` over the inclusive prefix count; entries past the bound are
    simply never searched for.
    """
    ids = tree.leaf_entry_ids[refine.leaf_idx]              # [B, K, M]
    B = ids.shape[0]
    flat_ids = ids.reshape(B, -1)
    flat_in = refine.inside.reshape(B, -1).astype(jnp.int32)
    cs = jnp.cumsum(flat_in, axis=-1)
    targets = jnp.arange(1, max_results + 1, dtype=jnp.int32)
    pos = jax.vmap(
        lambda c: jnp.searchsorted(c, targets, side="left"))(cs)
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    n_in = cs[:, -1]
    valid = targets[None, :] <= n_in[:, None]
    safe = jnp.minimum(pos, flat_ids.shape[-1] - 1).astype(jnp.int32)
    out = jnp.where(valid, flat_ids[rows, safe], -1)
    trunc = n_in > max_results
    return out, trunc


def gather_result_ids_topk(tree: DeviceTree, refine: RefineResult,
                           max_results: int
                           ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-optimization ``top_k``-based gather (equivalence oracle)."""
    ids = tree.leaf_entry_ids[refine.leaf_idx]              # [B, K, M]
    B = ids.shape[0]
    flat_ids = ids.reshape(B, -1)
    flat_in = refine.inside.reshape(B, -1)
    key = flat_in.astype(jnp.int32)
    take, slot = jax.lax.top_k(key, max_results)            # first hits
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    out = jnp.where(take > 0, flat_ids[rows, slot], -1)
    trunc = jnp.sum(flat_in.astype(jnp.int32), axis=-1) > max_results
    return out, trunc


@functools.partial(jax.jit, static_argnames=("max_visited", "max_results",
                                             "use_kernel"))
def range_query(tree: DeviceTree, queries: jnp.ndarray, *,
                max_visited: int = 256, max_results: int = 512,
                use_kernel: bool = False) -> QueryResult:
    """Full classical batched range query: traverse → compact → refine.

    This is the **R** path of the "AI+R"-tree. It also produces the
    (visited, true) leaf sets that define α and the training labels.
    """
    queries = queries.astype(jnp.float32)
    visited = visited_leaf_mask(tree, queries, use_kernel)   # [B, L]
    leaf_idx, valid, n_vis = compact_mask_counted(visited, max_visited)
    ref = refine_leaves(tree, queries, leaf_idx, valid, use_kernel)
    B, L = visited.shape
    true_rows = scatter_rows(
        jnp.zeros((B, L), dtype=jnp.int32), leaf_idx,
        (ref.counts > 0).astype(jnp.int32) * valid.astype(jnp.int32))
    true_leaves = true_rows > 0
    result_ids, trunc_r = gather_result_ids(tree, ref, max_results)
    trunc_v = n_vis > max_visited
    return QueryResult(
        visited=visited,
        true_leaves=true_leaves,
        n_visited=n_vis,
        n_true=jnp.sum(true_leaves.astype(jnp.int32), axis=-1),
        n_results=jnp.sum(ref.counts * valid.astype(jnp.int32), axis=-1),
        result_ids=result_ids,
        truncated=trunc_v | trunc_r,
    )


class CompactQueryResult(NamedTuple):
    leaf_idx: jnp.ndarray       # [B, max_visited] i32 compacted visited set
    valid: jnp.ndarray          # [B, max_visited] bool slot validity
    n_visited: jnp.ndarray      # [B] i32
    n_true: jnp.ndarray         # [B] i32
    n_results: jnp.ndarray      # [B] i32 total qualifying points
    result_ids: jnp.ndarray     # [B, max_results] i32, -1 padded
    truncated: jnp.ndarray      # [B] bool — static bounds overflowed


@functools.partial(jax.jit, static_argnames=("max_visited", "max_results",
                                             "use_kernel", "tile_b",
                                             "tile_l"))
def range_query_compact(tree: DeviceTree, queries: jnp.ndarray, *,
                        max_visited: int = 256, max_results: int = 512,
                        use_kernel: bool = True,
                        tile_b: Optional[int] = None,
                        tile_l: Optional[int] = None) -> CompactQueryResult:
    """Serving-path classical range query: traverse+compact → refine.

    The ``range_query`` variant for the hot path: the traversal kernel's
    compaction epilogue hands the first ``max_visited`` visited leaf ids
    straight to the scalar-prefetch refine kernel, so the ``[B, L]``
    visited mask never round-trips through HBM (and is absent from the
    lowered HLO on the kernel path). Use ``range_query`` when the dense
    visited/true masks themselves are needed — labels, α, training.

    Per-field bit-identical to ``range_query`` (``n_visited``/``n_true``/
    ``n_results``/``result_ids``/``truncated`` and the compacted slots).
    """
    queries = queries.astype(jnp.float32)
    cv = visited_leaves_compact(tree, queries, max_visited,
                                use_kernel=use_kernel,
                                tile_b=tile_b, tile_l=tile_l)
    ref = refine_leaves(tree, queries, cv.leaf_idx, cv.valid, use_kernel)
    result_ids, trunc_r = gather_result_ids(tree, ref, max_results)
    validi = cv.valid.astype(jnp.int32)
    return CompactQueryResult(
        leaf_idx=cv.leaf_idx,
        valid=cv.valid,
        n_visited=cv.n_visited,
        # compacted slots hold distinct leaves, so the slot-level count is
        # the leaf-level count — no [B, L] scatter needed
        n_true=jnp.sum((ref.counts > 0).astype(jnp.int32) * validi, axis=-1),
        n_results=jnp.sum(ref.counts * validi, axis=-1),
        result_ids=result_ids,
        truncated=cv.overflow | trunc_r,
    )


def alpha(n_true: jnp.ndarray, n_visited: jnp.ndarray) -> jnp.ndarray:
    """Overlap ratio α = TN(Q)/VN(Q) ∈ [0, 1] (§III-A2).

    Queries that visit no leaves (empty region) get α = 1 — nothing was
    extraneous, so they are maximally low-overlap.
    """
    return jnp.where(n_visited > 0, n_true / jnp.maximum(n_visited, 1), 1.0)
