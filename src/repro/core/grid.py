"""The model-index grid (paper §III-B, Fig. 6): "indexing the learned models".

A G×G uniform grid over query space; one learned model per *non-empty* cell
(cells no training query touches get no model). At query time the models
whose cells overlap the query rectangle are executed and their predictions
unioned.

The grid is deterministic integer lattice math — its own routing never needs
learning. It is exactly an MoE router with spatial dispatch; the expert-
parallel sharding of the per-cell models reuses the same layout as
``repro.models.moe``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Grid:
    """Uniform G×G grid over the data/query bounding box."""
    bbox: jnp.ndarray  # [4] f32 (xmin, ymin, xmax, ymax)
    g: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_cells(self) -> int:
        return self.g * self.g

    def cell_width(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return ((self.bbox[2] - self.bbox[0]) / self.g,
                (self.bbox[3] - self.bbox[1]) / self.g)


def fit_grid(points_or_queries: np.ndarray, g: int,
             margin: float = 1e-3) -> Grid:
    """Fit the grid bbox over data points [N,2] or query rects [Q,4]."""
    a = np.asarray(points_or_queries, dtype=np.float32)
    if a.shape[-1] == 2:
        lo, hi = a.min(axis=0), a.max(axis=0)
    else:
        lo = a[:, :2].min(axis=0)
        hi = a[:, 2:].max(axis=0)
    span = np.maximum(hi - lo, 1e-9)
    bbox = np.concatenate([lo - margin * span, hi + margin * span])
    return Grid(bbox=jnp.asarray(bbox, jnp.float32), g=int(g))


def cell_range(grid: Grid, queries: jnp.ndarray) -> jnp.ndarray:
    """[B, 4] query rects → [B, 4] i32 (cx0, cy0, cx1, cy1) cell index ranges."""
    q = queries.astype(jnp.float32)
    cw, ch = grid.cell_width()
    gx0, gy0 = grid.bbox[0], grid.bbox[1]
    cx0 = jnp.clip(jnp.floor((q[:, 0] - gx0) / cw), 0, grid.g - 1)
    cy0 = jnp.clip(jnp.floor((q[:, 1] - gy0) / ch), 0, grid.g - 1)
    cx1 = jnp.clip(jnp.floor((q[:, 2] - gx0) / cw), 0, grid.g - 1)
    cy1 = jnp.clip(jnp.floor((q[:, 3] - gy0) / ch), 0, grid.g - 1)
    return jnp.stack([cx0, cy0, cx1, cy1], axis=-1).astype(jnp.int32)


def cells_of_queries(grid: Grid, queries: jnp.ndarray, max_cells: int
                     ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Overlapped cell ids per query, statically bounded.

    ``max_cells`` must be a perfect square (the window is √max × √max).
    Returns ``(cell_ids [B, max_cells] i32, valid [B, max_cells] bool,
    overflow [B] bool)``. ``overflow`` marks queries spanning a wider cell
    window than the static bound — those take the exact R-tree path (the
    same escape hatch as the paper's misprediction rule). In the paper's
    workloads queries are tiny relative to cells, so 2×2 suffices (a rect
    overlaps at most 4 cells unless it is wider than a cell).
    """
    side = int(round(np.sqrt(max_cells)))
    assert side * side == max_cells, "max_cells must be a perfect square"
    B = queries.shape[0]
    cr = cell_range(grid, queries)                          # [B, 4]
    nx = cr[:, 2] - cr[:, 0] + 1                            # [B]
    ny = cr[:, 3] - cr[:, 1] + 1
    d = jnp.arange(side, dtype=jnp.int32)
    # side×side window anchored at (cx0, cy0); offsets clamped into range so
    # every id is in-bounds (duplicates are masked by ``valid``).
    ox = jnp.minimum(d[None, :], nx[:, None] - 1)           # [B, side]
    oy = jnp.minimum(d[None, :], ny[:, None] - 1)
    cx = cr[:, 0:1] + ox
    cy = cr[:, 1:2] + oy
    ids = (cy[:, :, None] * grid.g + cx[:, None, :]).reshape(B, -1)
    valid = ((d[None, :, None] < ny[:, None, None])
             & (d[None, None, :] < nx[:, None, None])).reshape(B, -1)
    overflow = (nx > side) | (ny > side)
    return ids, valid & ~overflow[:, None], overflow


def bucket_queries_by_cell(grid: Grid, queries: np.ndarray, max_cells: int
                           ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host twin of ``cells_of_queries`` (used at training time)."""
    ids, valid, overflow = jax.jit(
        cells_of_queries, static_argnames=("max_cells",))(
            grid, jnp.asarray(queries, jnp.float32), max_cells=max_cells)
    return np.asarray(ids), np.asarray(valid), np.asarray(overflow)
