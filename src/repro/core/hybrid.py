"""The "AI+R"-tree (paper §IV): router-dispatched hybrid of AI- and R-paths.

For each query the binary router predicts high-/low-overlap; high-overlap
queries take the AI path (predicted leaves only), low-overlap queries take
the classical R path. AI-path queries whose prediction is unusable fall back
to the R path (exactness). Per-query *leaf access* counts are tracked the
way the paper costs them: the AI path pays its predicted accesses, plus the
full R-tree visit set if it had to fall back.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.aitree import AITree, ai_query_compact
from repro.core.classifiers.router import Router, route_high
from repro.core.device_tree import DeviceTree
from repro.core.grid import cells_of_queries
from repro.core import traversal


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HybridTree:
    tree: DeviceTree
    ait: AITree
    router: Router


class HybridResult(NamedTuple):
    routed_high: jnp.ndarray    # [B] router verdict (True → AI path)
    used_ai: jnp.ndarray        # [B] answered by the AI path (no fallback)
    n_results: jnp.ndarray      # [B] qualifying points
    result_ids: jnp.ndarray     # [B, max_results]
    leaf_accesses: jnp.ndarray  # [B] paper cost unit (leaf I/Os)
    n_visited_r: jnp.ndarray    # [B] classical visit count (for α / reporting)
    n_true: jnp.ndarray         # [B] true leaf count
    truncated: jnp.ndarray      # [B] R-path static bounds overflowed — the
    #                             scheduler re-serves these on a wide-bound
    #                             tier (mirrors ServeStats.r_truncated)
    guarded: jnp.ndarray        # [B] routed-high but demoted to the R path
    #                             by the cell guard (fit < 1 or stale cell —
    #                             mirrors ServeStats.guarded)
    mispredict: jnp.ndarray     # [B] AI-path attempt hit the paper's
    #                             misprediction signal (a predicted leaf
    #                             with zero qualifying entries) — per-cell
    #                             drift evidence for the maintenance policy
    cell_id: jnp.ndarray        # [B] i32 anchor grid cell of the query
    #                             (-1 on cell-window overflow) — the key
    #                             the monitor aggregates signals under


def guard_demoted(ait: AITree, queries: jnp.ndarray) -> jnp.ndarray:
    """[B] bool: query overlaps a cell the guard holds back from the AI
    path (``cell_ok`` False — under-fit at build time, or stale since the
    freshness monitor saw inserts land there). Shared by ``hybrid_query``
    and (shard-local + psum) the engine's ``_ai_path``.
    """
    cell_ids, valid, _ = cells_of_queries(ait.grid, queries, ait.max_cells)
    return jnp.any(valid & ~ait.cell_ok[cell_ids], axis=-1)


def is_point_query(queries: jnp.ndarray) -> jnp.ndarray:
    """[B, 4] → [B] bool: degenerate rects (zero extent on both axes).

    Device-side twin of ``schedule.point_query_mask`` — the detection
    that dispatches the point-query fast path.
    """
    q = queries.astype(jnp.float32)
    return (q[:, 0] == q[:, 2]) & (q[:, 1] == q[:, 3])


@functools.partial(jax.jit, static_argnames=("max_visited", "max_results",
                                             "use_kernel", "force_path",
                                             "guard"))
def point_query(h: HybridTree, queries: jnp.ndarray, *,
                max_visited: int = 32, max_results: int = 64,
                use_kernel: bool = False, force_path: str = "auto",
                guard: bool = True) -> HybridResult:
    """Point-query fast path: degenerate rects served with single-cell
    AI routing and a narrowed traversal.

    A zero-extent query overlaps exactly one grid cell, so the AI path's
    cell window collapses to ``max_cells=1`` — no window overflow, one
    bank gather instead of ``max_cells`` — and the classical visit set is
    a root-to-leaf containment stack, so ``max_visited``/``max_results``
    shrink to point-sized bounds. No wide tier: the narrowed bounds must
    cover every row (callers assert ``truncated`` stays empty — the
    launch driver and the smoke gate both do) instead of re-serving.
    Everything else — router, guard, fallback, cost accounting — is
    ``hybrid_query`` exactly; the result is a plain ``HybridResult``.
    """
    ait1 = dataclasses.replace(h.ait, max_cells=1)
    h1 = dataclasses.replace(h, ait=ait1)
    return hybrid_query(h1, queries, max_visited=max_visited,
                        max_results=max_results, use_kernel=use_kernel,
                        force_path=force_path, guard=guard)


@functools.partial(jax.jit, static_argnames=("max_visited", "max_results",
                                             "use_kernel", "force_path",
                                             "guard"))
def hybrid_query(h: HybridTree, queries: jnp.ndarray, *,
                 max_visited: int = 256, max_results: int = 512,
                 use_kernel: bool = False, force_path: str = "auto",
                 guard: bool = True) -> HybridResult:
    """Masked single-dispatch execution of both paths.

    ``force_path``: "auto" (router), "ai" (AI-tree only + fallback), or "r"
    (classical only) — the latter two give the paper's standalone baselines.

    ``guard`` (auto routing only): demote queries overlapping a not-ok
    cell (``AITree.cell_ok``) to the exact R path *before* prediction.
    This closes the under-prediction blind spot: a bank with
    ``exact_fit < 1`` can predict a strict subset of the true leaves with
    every predicted leaf still yielding hits — no fallback signal fires
    and results are silently dropped. The forced baselines bypass the
    guard (they measure the raw paths).
    """
    queries = queries.astype(jnp.float32)
    B = queries.shape[0]

    if force_path == "r":
        high = jnp.zeros((B,), bool)
    elif force_path == "ai":
        high = jnp.ones((B,), bool)
    else:
        high = route_high(h.router, queries)

    if guard and force_path == "auto":
        demoted = high & guard_demoted(h.ait, queries)
    else:
        demoted = jnp.zeros((B,), bool)
    eligible = high & ~demoted

    # serving-path compact AI query: prediction lands in the [B, max_pred]
    # slot table (bit-identical to the dense ai_query on all shared fields;
    # the [B, L] score table exists only on the kernel-free oracle rung)
    ai = ai_query_compact(h.ait, h.tree, queries, max_results=max_results,
                          use_kernel=use_kernel)
    r = traversal.range_query(h.tree, queries, max_visited=max_visited,
                              max_results=max_results, use_kernel=use_kernel)

    used_ai = eligible & ~ai.fallback
    n_results = jnp.where(used_ai, ai.n_results, r.n_results)
    result_ids = jnp.where(used_ai[:, None], ai.result_ids, r.result_ids)
    # cost accounting (paper §IV-A): AI path pays prediction + its accesses;
    # a fallback additionally pays the classical visit set. Guard-demoted
    # rows never reach prediction, so they pay the classical cost only.
    leaf_accesses = jnp.where(
        eligible,
        ai.n_pred + jnp.where(ai.fallback, r.n_visited, 0),
        r.n_visited,
    )
    return HybridResult(
        routed_high=high,
        used_ai=used_ai,
        n_results=n_results,
        result_ids=result_ids,
        leaf_accesses=leaf_accesses,
        n_visited_r=r.n_visited,
        n_true=r.n_true,
        # only flag rows the R path answered — used_ai rows are exact
        # (AI-side truncation already forces fallback)
        truncated=r.truncated & ~used_ai,
        guarded=demoted,
        # only rows that actually attempted the AI path can mispredict —
        # drift evidence must not be charged to guarded/low-overlap rows
        mispredict=eligible & ai.mispredict,
        cell_id=ai.cell_id,
    )
