"""Serving-time freshness/fit monitor + the live serving-state owner.

The guard tier has two inputs, tracked here:

* **fit** — the per-cell exact-fit flags ``build.fit_airtree`` measured
  at training time (a cell whose training queries were not all answered
  exactly can under-predict silently);
* **staleness** — inserts that landed in a cell *since the bank was
  fit*: the cell's model has never seen those points, so its predictions
  there are unfounded even if its fit was perfect.

``FreshnessMonitor`` ANDs the two into the ``cell_ok`` mask the
router-side guard consults (``AITree.cell_ok``): stale or ``fit < 1``
cells are demoted to the exact R path, which closes the under-prediction
blind spot for drifted *and* under-trained banks in one mechanism.

Beyond the guard inputs, the monitor is the serving side's **policy
engine**: every served batch feeds per-cell rolling counters (traffic,
guard rate, mispredict rate, delta-hit rate — aggregated per serve
segment, summarized by the rolling median over a window of segments),
and a pluggable ``MaintenancePolicy`` turns those signals into
between-segment maintenance decisions — which stale cells to refit
next (``build.refit_cells`` chunks), when to repack the delta buffer,
and which cells to force-demote off / promote back onto the AI path.

``FreshServer`` owns the whole live state — hybrid tree, delta store,
monitor — and is what the scheduler drives for a mixed read/write
stream: ``serve``/``serve_wide`` answer batches (tree paths + delta
probe, merged), ``insert`` stages points and bumps staleness, ``repack``
swaps in a fresh bulk-loaded tree between batches. Without a
``FitState`` the legacy contract holds: after a repack the *entire*
bank is marked stale (``str_bulk`` renumbers every leaf, so the bank's
label space refers to a tree that no longer exists) and stays guarded
until a full refit. With a ``FitState`` (``BuildReport.fit_state``)
the repack instead runs a span-diff (``core.spans``): surviving leaf
ids are renamed inside the bank, only cells whose leaf span actually
moved go stale, and the policy retrains them incrementally through
``refit_cells`` — the AI path recovers cell by cell with no full
``fit_airtree`` on the serve path.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import delta as deltalib
from repro.core import telemetry
from repro.core.grid import Grid, cell_range
from repro.core.hybrid import HybridResult, HybridTree, hybrid_query

# module-level jit so staging doesn't retrace per insert batch (a fresh
# jax.jit wrapper per call would discard the trace cache every time)
_cell_range_j = jax.jit(cell_range)


class FreshResult(NamedTuple):
    """``HybridResult`` + the delta-probe count (mirrors
    ``ServeStats.delta_hits`` so mixed-stream reporting is uniform
    across the hybrid and engine servers)."""
    routed_high: "jax.Array"
    used_ai: "jax.Array"
    n_results: "jax.Array"
    result_ids: "jax.Array"
    leaf_accesses: "jax.Array"
    n_visited_r: "jax.Array"
    n_true: "jax.Array"
    truncated: "jax.Array"
    guarded: "jax.Array"
    mispredict: "jax.Array"
    cell_id: "jax.Array"
    delta_hits: "jax.Array"     # [B] buffer hits (already in n_results)


assert FreshResult._fields[:len(HybridResult._fields)] == \
    HybridResult._fields, "FreshResult must prefix-extend HybridResult"


class FreshnessStats(NamedTuple):
    """Aggregate monitor state, as surfaced per stream by launch/serve."""
    n_cells: int
    fit_cells: int       # cells with exact training fit
    stale_cells: int     # cells with inserts since the bank was fit
    ok_cells: int        # fit AND fresh — serve-eligible on the AI path
    n_inserts: int       # staged since the monitor was (re)fit
    n_repacks: int
    delta_fill: int      # points currently staged in the buffer
    span_stale_cells: int = 0   # cells awaiting an incremental refit
    demoted_cells: int = 0      # cells force-demoted by the policy


# the per-cell serve counters one segment accumulates before the window
# rolls — the monitor's unit of rolling-rate aggregation
_SERVE_FIELDS = ("n", "guarded", "mispredict", "used_ai", "delta_hits")


class FreshnessMonitor:
    """Host-side per-cell fit/staleness tracking over the model grid,
    plus the rolling serve-signal counters the maintenance policy reads.

    Guard state (ANDed into ``cell_ok``):

    * ``fit_ok`` — certificate flags from the last (re)fit;
    * ``stale`` — insert counters (points staged since the fit — only a
      repack can absorb them into the tree);
    * ``span_stale`` — cells whose leaf span moved under a repack and
      that no refit chunk has retrained yet (span-diff invalidation);
    * ``forced_demote`` — policy demotions (drift evidence the span
      diff cannot see, e.g. a workload shift inside an unchanged span).

    Serve signals: ``note_serve`` accumulates per-cell counters for the
    current segment; ``roll_segment`` closes it into a bounded window,
    and ``rolling``/``traffic`` summarize the window with the rolling
    *median* (robust to one-segment spikes — a single anomalous batch
    cannot trigger a demotion cascade).
    """

    def __init__(self, grid: Grid, fit_ok: np.ndarray, *, window: int = 8):
        self._grid = grid
        self.fit_ok = np.asarray(fit_ok, bool).copy()
        assert self.fit_ok.shape == (grid.n_cells,), \
            (self.fit_ok.shape, grid.g)
        self.stale = np.zeros_like(self.fit_ok, dtype=np.int64)
        self.span_stale = np.zeros_like(self.fit_ok, dtype=bool)
        self.forced_demote = np.zeros_like(self.fit_ok, dtype=bool)
        self.demoted_at = np.zeros_like(self.fit_ok, dtype=np.int64)
        self.n_inserts = 0
        self.n_repacks = 0
        self.seg_counter = 0
        # the rolling-window machinery lives in core.telemetry so the
        # streaming runtime's latency stats share one implementation
        self._window = telemetry.SegmentWindow(
            grid.n_cells, _SERVE_FIELDS, window=window)

    # -- serve-signal accumulation ----------------------------------------

    def note_serve(self, stats) -> None:
        """Accumulate one served batch's per-query signals per cell.

        ``stats`` is any pytree with ``cell_id``/``guarded``/
        ``mispredict``/``used_ai``/``delta_hits`` fields ([B] arrays —
        ``FreshResult`` and ``engine.ServeStats`` both qualify). Rows
        with ``cell_id < 0`` (cell-window overflow) have no anchor cell
        and are dropped; scheduler pad rows are counted (they repeat a
        real query, so they only re-weight that query's own cell).
        """
        cid = np.asarray(stats.cell_id).ravel().astype(np.int64)
        keep = cid >= 0
        cid = cid[keep]
        self._window.add(cid, {
            f: np.asarray(getattr(stats, f)).ravel()[keep]
            for f in _SERVE_FIELDS[1:]})

    def roll_segment(self) -> None:
        """Close the current segment into the rolling window."""
        self._window.roll()
        self.seg_counter += 1

    def rolling(self, field: str) -> np.ndarray:
        """[C] f64 rolling-median per-cell *rate* of ``field`` over the
        window (count / queries, per segment; segments where a cell saw
        no traffic don't vote — all-quiet cells rate 0)."""
        if field not in _SERVE_FIELDS[1:]:
            raise ValueError(f"unknown serve field {field!r}")
        return self._window.rate(field)

    def traffic(self) -> np.ndarray:
        """[C] f64 rolling-median per-cell queries per segment."""
        return self._window.count_median()

    def _cells_of_points(self, points: np.ndarray) -> np.ndarray:
        # map points as degenerate rects through the grid's own
        # ``cell_range`` so the monitor's cell attribution can never
        # drift from the convention serving queries are routed by;
        # out-of-bbox points clamp into the edge cells (conservative —
        # the edge cell's model never trained on that region either)
        p = np.asarray(points, np.float32).reshape(-1, 2)
        rects = jnp.asarray(np.concatenate([p, p], axis=1))
        cr = np.asarray(_cell_range_j(self._grid, rects))
        return cr[:, 1].astype(np.int64) * self._grid.g + cr[:, 0]

    def note_inserts(self, points: np.ndarray) -> None:
        """Inserts landed: bump the receiving cells' staleness."""
        cells = self._cells_of_points(points)
        np.add.at(self.stale, cells, 1)
        self.n_inserts += int(cells.shape[0])

    def note_repack(self, changed: Optional[np.ndarray] = None) -> None:
        """The tree was rebuilt. Legacy contract (``changed=None``):
        every cell goes stale — bulk load renumbers all leaves, so the
        whole bank's label space refers to a tree that no longer
        exists. Span-diff contract (``changed`` = [C] bool from
        ``build.refit_cells``'s diff): surviving leaves were renamed
        inside the bank, so *only* cells whose leaf span moved are
        stale; the insert counters reset (every staged point is in the
        tree now, and a repack-received cell's span provably changed —
        the receiving leaf intersects that cell — so no insert evidence
        is lost by the fold)."""
        if changed is None:
            self.stale[:] = max(1, int(self.stale.max()))
        else:
            self.stale[:] = 0
            self.span_stale = np.asarray(changed, bool).copy()
        self.n_repacks += 1

    def note_refit_cells(self, cell_ok: np.ndarray,
                         still_stale: np.ndarray) -> None:
        """An incremental ``build.refit_cells`` chunk landed: replace
        the certificate flags wholesale (re-certification can flip
        cells *outside* the chunk — a shared query's verdict changed)
        and narrow ``span_stale`` to the cells the chunk left behind.
        Insert counters are untouched: a refit trains on the tree, not
        the buffer, so points staged since the last repack still guard
        their cells."""
        self.fit_ok = np.asarray(cell_ok, bool).copy()
        self.span_stale = np.asarray(still_stale, bool).copy()

    # -- policy levers ------------------------------------------------------

    def force_demote(self, cells: np.ndarray) -> None:
        """Policy demotion: hold ``cells`` off the AI path regardless of
        their certificates (drift evidence the span diff cannot see)."""
        cells = np.asarray(cells, np.int64)
        self.forced_demote[cells] = True
        self.demoted_at[cells] = self.seg_counter

    def clear_demote(self, cells: np.ndarray) -> None:
        self.forced_demote[np.asarray(cells, np.int64)] = False

    def note_refit(self, fit_ok: np.ndarray,
                   grid: Optional[Grid] = None) -> None:
        """The bank was refit on the current tree: staleness resets and
        the fit flags are replaced by the new evaluation's. Pass ``grid``
        when the refit's hill-climb landed on a different grid size — the
        monitor re-anchors to it (flags and staleness are per-cell, so
        they cannot survive a geometry change anyway)."""
        if grid is not None:
            self._grid = grid
        self.fit_ok = np.asarray(fit_ok, bool).copy()
        assert self.fit_ok.shape == (self._grid.n_cells,), \
            (self.fit_ok.shape, self._grid.g)
        self.stale = np.zeros_like(self.fit_ok, dtype=np.int64)
        self.span_stale = np.zeros_like(self.fit_ok, dtype=bool)
        self.forced_demote = np.zeros_like(self.fit_ok, dtype=bool)
        self.demoted_at = np.zeros_like(self.fit_ok, dtype=np.int64)
        self.n_inserts = 0
        if self.fit_ok.shape[0] != self._window.n_keys:
            self._window.clear(n_keys=self.fit_ok.shape[0])

    def cell_ok(self) -> np.ndarray:
        """[C] bool: serve-eligible = certified fit AND no inserts since
        AND span current AND not policy-demoted."""
        return self.fit_ok & (self.stale == 0) & ~self.span_stale \
            & ~self.forced_demote

    def guard_array(self) -> jnp.ndarray:
        return jnp.asarray(self.cell_ok())

    def stats(self, delta_fill: int = 0) -> FreshnessStats:
        ok = self.cell_ok()
        return FreshnessStats(
            n_cells=int(ok.size), fit_cells=int(self.fit_ok.sum()),
            stale_cells=int(((self.stale > 0) | self.span_stale).sum()),
            ok_cells=int(ok.sum()),
            n_inserts=self.n_inserts, n_repacks=self.n_repacks,
            delta_fill=delta_fill,
            span_stale_cells=int(self.span_stale.sum()),
            demoted_cells=int(self.forced_demote.sum()))


class MaintenanceDecision(NamedTuple):
    """One between-segments verdict from a ``MaintenancePolicy``."""
    repack: bool             # merge the delta buffer into a fresh tree
    refit: np.ndarray        # i64 cells to retrain this segment (chunk)
    demote: np.ndarray       # i64 cells to force off the AI path
    promote: np.ndarray      # i64 demoted cells to retrain + readmit
    refit_skipped: int = 0   # cells the server could not refit (no
    #                          FitState — cell-granular refit disabled)


class MaintenancePolicy:
    """Strategy interface: rolling per-cell signals → maintenance."""

    def decide(self, monitor: FreshnessMonitor, *, delta_fill: int,
               delta_capacity: int) -> MaintenanceDecision:
        raise NotImplementedError


@dataclasses.dataclass
class DefaultPolicy(MaintenancePolicy):
    """Stats-driven maintenance defaults.

    * **repack** when the delta buffer passes ``repack_at`` of its
      capacity (ahead of the forced repack-before-overflow, so the
      span diff + chunked refits amortize across quiet segments);
    * **refit** up to ``refit_chunk`` span-stale cells per segment,
      hottest first (rolling-median traffic) — recovery effort follows
      the workload, so the cells that cost the most guarded R-path
      serves come back to the AI path first;
    * **demote** serve-eligible cells whose rolling mispredict rate
      exceeds ``demote_mispredict`` (with at least ``min_traffic``
      queries/segment of evidence) — drift *inside* an unchanged span
      that certificates can't see;
    * **promote** demoted cells after ``promote_after`` segments by
      scheduling a forced refit (retrain + recertify readmits them
      only if the new certificates hold; ``0`` disables).
    """
    refit_chunk: int = 4
    repack_at: float = 0.75
    demote_mispredict: float = 0.25
    min_traffic: float = 4.0
    promote_after: int = 2

    def decide(self, monitor: FreshnessMonitor, *, delta_fill: int,
               delta_capacity: int) -> MaintenanceDecision:
        repack = bool(delta_capacity > 0 and delta_fill
                      >= self.repack_at * delta_capacity)
        traffic = monitor.traffic()
        stale = np.flatnonzero(monitor.span_stale)
        if self.refit_chunk and stale.size > self.refit_chunk:
            hot = np.argsort(-traffic[stale], kind="stable")
            stale = np.sort(stale[hot[:self.refit_chunk]])
        mis = monitor.rolling("mispredict")
        demote = np.flatnonzero(
            monitor.cell_ok() & (traffic >= self.min_traffic)
            & (mis > self.demote_mispredict))
        if self.promote_after:
            age = monitor.seg_counter - monitor.demoted_at
            promote = np.flatnonzero(monitor.forced_demote
                                     & (age >= self.promote_after))
        else:
            promote = np.zeros((0,), np.int64)
        return MaintenanceDecision(
            repack=repack, refit=stale.astype(np.int64),
            demote=demote.astype(np.int64),
            promote=promote.astype(np.int64))


def _note_refit_skipped(server, d: MaintenanceDecision,
                        n_cells: int) -> MaintenanceDecision:
    """Record a policy-decided refit the server couldn't run (no
    ``FitState``). The skip count rides on the decision — visible in the
    ``maintenance`` log and ``MixedReport.maintenance`` — and the
    human-facing notice prints once per server lifetime, not once per
    segment."""
    if not getattr(server, "_refit_skip_noticed", False):
        server._refit_skip_noticed = True
        print("# policy: cell-granular refit disabled (no FitState) — "
              "refit/promote cells stay guarded; skip counts recorded "
              "in the maintenance log")
    return d._replace(refit_skipped=int(n_cells))


class FreshServer:
    """Live serving state for a mixed read/write stream (single-device
    hybrid path; the distributed engine composes the same pieces via
    ``make_serve_step``'s ``delta_xy`` argument).

    Functionalized jax under a stateful host shell: every batch serves
    through jit'd closures over the *current* (hybrid, delta) pair;
    ``insert``/``repack`` swap that pair between batches, never under a
    running step. ``serve``/``serve_wide`` realize the scheduler's
    two-tier contract (``HybridResult.truncated``), with the wide tier's
    bounds — including the delta slot bound — scaled by ``wide_factor``.
    """

    trunc_field = "truncated"

    def __init__(self, points: np.ndarray, hybrid: HybridTree, *,
                 delta_cap: int = 4096, max_visited: int = 64,
                 max_results: int = 512, delta_k: int = 64,
                 wide_factor: int = 8, use_kernel: bool = False,
                 guard: bool = True,
                 refit_fn: Optional[Callable] = None,
                 fit_state=None,
                 policy: Optional[MaintenancePolicy] = None):
        self.points = np.asarray(points, np.float64)
        self.max_entries = hybrid.tree.max_entries
        self.monitor = FreshnessMonitor(hybrid.ait.grid,
                                        np.asarray(hybrid.ait.cell_ok))
        self.delta = deltalib.make_delta(delta_cap,
                                         base=self.points.shape[0])
        self.hybrid = hybrid
        self._mv, self._mr = int(max_visited), int(max_results)
        self._dk, self._wf = int(delta_k), int(wide_factor)
        self._uk, self._guard = bool(use_kernel), bool(guard)
        # refit_fn(device_tree) -> (HybridTree, cell_fit [C] bool) — e.g.
        # a relabel + build.fit_airtree closure; None keeps the stale bank
        # guarded (R-path serving) after repacks
        self._refit_fn = refit_fn
        # fit_state: the build.FitState snapshot from BuildReport — turns
        # repacks into span-diffs and unlocks incremental refit_cells;
        # policy: between-segment maintenance (None = manual only)
        self.fit_state = fit_state
        self.policy = policy
        self.maintenance = []   # (segment, MaintenanceDecision) log
        self.refits = []        # build.RefitReport log
        self._sync_guard()

    # -- serving -----------------------------------------------------------

    def _serve(self, q: jnp.ndarray, widen: int) -> "jax.Array":
        mv, mr = self._mv * widen, self._mr * widen
        dk = self._dk * widen
        res = hybrid_query(self.hybrid, q, max_visited=mv, max_results=mr,
                           use_kernel=self._uk, guard=self._guard)
        hits = deltalib.probe(self.delta.xy, q, k=dk, base=self.delta.base,
                              use_kernel=self._uk)
        merged = deltalib.merge_hybrid_result(res, hits)
        return FreshResult(*merged, delta_hits=hits.count)

    def serve(self, q) -> "jax.Array":
        res = self._serve(jnp.asarray(q), 1)
        # narrow tier sees every query exactly once (the wide tier only
        # re-serves truncated rows) — the one place signal feeding stays
        # double-count-free
        self.monitor.note_serve(res)
        return res

    def serve_wide(self, q) -> "jax.Array":
        return self._serve(jnp.asarray(q), self._wf)

    # -- writes ------------------------------------------------------------

    @property
    def delta_fill(self) -> int:
        return self.delta.n

    def _sync_guard(self) -> None:
        ait = dataclasses.replace(self.hybrid.ait,
                                  cell_ok=self.monitor.guard_array())
        self.hybrid = dataclasses.replace(self.hybrid, ait=ait)

    def insert(self, points: np.ndarray) -> None:
        """Stage inserts into the delta buffer (between batches); the
        receiving cells go stale and drop off the AI path. A batch the
        buffer cannot absorb forces a repack first (this is the
        repack-before-overflow guarantee ``stage_inserts`` documents);
        a single batch larger than the whole capacity still raises."""
        m = np.asarray(points, np.float32).reshape(-1, 2).shape[0]
        if self.delta.n + m > self.delta.capacity:
            self.repack()
        self.delta = deltalib.stage_inserts(self.delta, points)
        self.monitor.note_inserts(points)
        self._sync_guard()

    def repack(self) -> None:
        """Online repack: swap in a fresh bulk-loaded tree holding every
        staged point and empty the buffer. With a ``fit_state`` the swap
        runs an *empty-chunk* ``build.refit_cells`` — span diff, leaf-id
        renames inside the live bank, certificate invalidation — so only
        span-changed cells go stale (unchanged cells keep serving the AI
        path through the repack); retraining is left to later chunks.
        Without one, the legacy contract: guard the whole bank until
        ``refit_fn`` (or a manual full refit) lands."""
        _, dtree, allp, self.delta = deltalib.repack(
            self.points, self.delta, max_entries=self.max_entries)
        self.points = allp
        if self.fit_state is not None:
            from repro.core import build as buildlib
            self.hybrid = dataclasses.replace(self.hybrid, tree=dtree)
            self.hybrid, self.fit_state, rep = buildlib.refit_cells(
                self.hybrid, self.fit_state,
                cells=np.zeros((0,), np.int64))
            self.refits.append(rep)
            self.monitor.note_repack(
                changed=self.fit_state.cell_stale.copy())
            self.monitor.note_refit_cells(
                np.asarray(self.hybrid.ait.cell_ok),
                self.fit_state.cell_stale.copy())
        elif self._refit_fn is not None:
            self.monitor.note_repack()
            hybrid, cell_fit = self._refit_fn(dtree)
            self.hybrid = hybrid
            # the refit's grid search may land on a different grid size —
            # re-anchor the monitor to the refit hybrid's own grid
            self.monitor.note_refit(np.asarray(cell_fit, bool),
                                    grid=hybrid.ait.grid)
        else:
            self.monitor.note_repack()
            self.hybrid = dataclasses.replace(self.hybrid, tree=dtree)
        self._sync_guard()

    # -- incremental maintenance -------------------------------------------

    def refit_cells(self, cells: Optional[np.ndarray] = None):
        """Retrain a chunk of stale cells in place (requires
        ``fit_state``); ``None`` = all currently stale. Returns the
        ``build.RefitReport``."""
        if self.fit_state is None:
            raise ValueError("refit_cells needs a FitState "
                             "(build with fit_airtree and pass "
                             "BuildReport.fit_state)")
        from repro.core import build as buildlib
        self.hybrid, self.fit_state, rep = buildlib.refit_cells(
            self.hybrid, self.fit_state, cells)
        self.refits.append(rep)
        self.monitor.note_refit_cells(np.asarray(self.hybrid.ait.cell_ok),
                                      self.fit_state.cell_stale.copy())
        self._sync_guard()
        return rep

    def on_segment(self) -> Optional[MaintenanceDecision]:
        """Between-segments hook the scheduler calls after each serve
        segment: roll the signal window, ask the policy, apply the
        decision (repack / demote / promote / refit chunk)."""
        self.monitor.roll_segment()
        if self.policy is None:
            return None
        d = self.policy.decide(self.monitor, delta_fill=self.delta.n,
                               delta_capacity=self.delta.capacity)
        if d.repack:
            self.repack()
        if d.demote.size:
            self.monitor.force_demote(d.demote)
        if d.promote.size:
            self.monitor.clear_demote(d.promote)
        cells = np.union1d(d.refit, d.promote).astype(np.int64)
        if cells.size and self.fit_state is not None:
            # a repack above may have widened the stale set; the chunk
            # is still sound — refit_cells re-diffs and retrains exactly
            # these cells against the new tree
            self.refit_cells(cells)
        else:
            if cells.size:
                d = _note_refit_skipped(self, d, cells.size)
            self._sync_guard()
        self.maintenance.append((self.monitor.seg_counter, d))
        return d

    def stats(self) -> FreshnessStats:
        return self.monitor.stats(delta_fill=self.delta.n)


class EngineFreshServer:
    """The ``FreshServer`` shape over the shard_map engine: serves through
    ``engine.make_serve_step``'s two tiers with the replicated delta
    buffer as the step's ``delta_xy`` argument. Tree and guard swaps
    re-pad for the mesh (``pad_tree_for_sharding``) between batches; the
    jit'd steps take (hybrid, queries, delta) as *arguments*, so staging
    inserts never retraces — only a repack's leaf-count change does.
    """

    trunc_field = "r_truncated"

    def __init__(self, points: np.ndarray, hybrid: HybridTree, mesh, cfg, *,
                 kind: str, n_model: int, delta_cap: int = 4096,
                 wide_factor: int = 8, fit_state=None,
                 policy: Optional[MaintenancePolicy] = None):
        from repro.core import engine as eng
        self.points = np.asarray(points, np.float64)
        self.max_entries = hybrid.tree.max_entries
        self.monitor = FreshnessMonitor(hybrid.ait.grid,
                                        np.asarray(hybrid.ait.cell_ok))
        self.delta = deltalib.make_delta(delta_cap,
                                         base=self.points.shape[0])
        self.hybrid = hybrid
        self._n_model = int(n_model)
        self.fit_state = fit_state
        self.policy = policy
        self.maintenance = []
        self.refits = []
        narrow, wide = eng.make_two_tier_steps(
            mesh, cfg, kind=kind, wide_factor=wide_factor)
        self._jnarrow = jax.jit(narrow)
        self._jwide = jax.jit(wide)
        self._repad()

    def _repad(self) -> None:
        """Full mesh re-pad — needed when the *tree* changes (repack).
        Guard-only updates go through ``_sync_guard``, which swaps just
        the padded eligibility mask instead of re-concatenating every
        leaf/bank array per staged insert batch."""
        from repro.core import engine as eng
        self._sync_hybrid()
        self._h_p = eng.pad_tree_for_sharding(self.hybrid, self._n_model)

    def _sync_hybrid(self) -> None:
        ait = dataclasses.replace(self.hybrid.ait,
                                  cell_ok=self.monitor.guard_array())
        self.hybrid = dataclasses.replace(self.hybrid, ait=ait)

    def _sync_guard(self) -> None:
        self._sync_hybrid()
        ok = self.hybrid.ait.cell_ok
        Cp = self._h_p.ait.cell_ok.shape[0]
        ok_p = jnp.concatenate(
            [ok, jnp.zeros((Cp - ok.shape[0],), ok.dtype)]) \
            if Cp != ok.shape[0] else ok
        self._h_p = dataclasses.replace(
            self._h_p, ait=dataclasses.replace(self._h_p.ait, cell_ok=ok_p))

    def serve(self, q) -> "jax.Array":
        out = self._jnarrow(self._h_p, jnp.asarray(q), self.delta.xy)
        self.monitor.note_serve(out)   # narrow tier only — see FreshServer
        return out

    def serve_wide(self, q) -> "jax.Array":
        return self._jwide(self._h_p, jnp.asarray(q), self.delta.xy)

    @property
    def delta_fill(self) -> int:
        return self.delta.n

    def insert(self, points: np.ndarray) -> None:
        m = np.asarray(points, np.float32).reshape(-1, 2).shape[0]
        if self.delta.n + m > self.delta.capacity:
            self.repack()     # repack-before-overflow, as FreshServer
        self.delta = deltalib.stage_inserts(self.delta, points)
        self.monitor.note_inserts(points)
        self._sync_guard()

    def repack(self) -> None:
        _, dtree, allp, self.delta = deltalib.repack(
            self.points, self.delta, max_entries=self.max_entries)
        self.points = allp
        self.hybrid = dataclasses.replace(self.hybrid, tree=dtree)
        if self.fit_state is not None:
            # span-diff swap, as FreshServer.repack: renames survive in
            # the bank, only span-changed cells go stale
            from repro.core import build as buildlib
            self.hybrid, self.fit_state, rep = buildlib.refit_cells(
                self.hybrid, self.fit_state,
                cells=np.zeros((0,), np.int64))
            self.refits.append(rep)
            self.monitor.note_repack(
                changed=self.fit_state.cell_stale.copy())
            self.monitor.note_refit_cells(
                np.asarray(self.hybrid.ait.cell_ok),
                self.fit_state.cell_stale.copy())
        else:
            self.monitor.note_repack()
        self._repad()

    def refit_cells(self, cells: Optional[np.ndarray] = None):
        """Incremental chunk refit (requires ``fit_state``) + mesh
        re-pad — the spliced bank rows must land in the padded copy the
        jit'd steps actually serve from."""
        if self.fit_state is None:
            raise ValueError("refit_cells needs a FitState "
                             "(build with fit_airtree and pass "
                             "BuildReport.fit_state)")
        from repro.core import build as buildlib
        self.hybrid, self.fit_state, rep = buildlib.refit_cells(
            self.hybrid, self.fit_state, cells)
        self.refits.append(rep)
        self.monitor.note_refit_cells(np.asarray(self.hybrid.ait.cell_ok),
                                      self.fit_state.cell_stale.copy())
        self._repad()
        return rep

    def on_segment(self) -> Optional[MaintenanceDecision]:
        """Between-segments maintenance hook — same contract as
        ``FreshServer.on_segment``."""
        self.monitor.roll_segment()
        if self.policy is None:
            return None
        d = self.policy.decide(self.monitor, delta_fill=self.delta.n,
                               delta_capacity=self.delta.capacity)
        if d.repack:
            self.repack()
        if d.demote.size:
            self.monitor.force_demote(d.demote)
        if d.promote.size:
            self.monitor.clear_demote(d.promote)
        cells = np.union1d(d.refit, d.promote).astype(np.int64)
        if cells.size and self.fit_state is not None:
            self.refit_cells(cells)
        else:
            if cells.size:
                d = _note_refit_skipped(self, d, cells.size)
            self._sync_guard()
        self.maintenance.append((self.monitor.seg_counter, d))
        return d

    def stats(self) -> FreshnessStats:
        return self.monitor.stats(delta_fill=self.delta.n)
