"""Serving-time freshness/fit monitor + the live serving-state owner.

The guard tier has two inputs, tracked here:

* **fit** — the per-cell exact-fit flags ``build.fit_airtree`` measured
  at training time (a cell whose training queries were not all answered
  exactly can under-predict silently);
* **staleness** — inserts that landed in a cell *since the bank was
  fit*: the cell's model has never seen those points, so its predictions
  there are unfounded even if its fit was perfect.

``FreshnessMonitor`` ANDs the two into the ``cell_ok`` mask the
router-side guard consults (``AITree.cell_ok``): stale or ``fit < 1``
cells are demoted to the exact R path, which closes the under-prediction
blind spot for drifted *and* under-trained banks in one mechanism.

``FreshServer`` owns the whole live state — hybrid tree, delta store,
monitor — and is what the scheduler drives for a mixed read/write
stream: ``serve``/``serve_wide`` answer batches (tree paths + delta
probe, merged), ``insert`` stages points and bumps staleness, ``repack``
swaps in a fresh bulk-loaded tree between batches. After a repack the
*entire* bank is marked stale: ``str_bulk`` renumbers every leaf, so the
bank's label space refers to a tree that no longer exists — the guard
demoting everything to the R path is exactly what keeps serving correct
until a refit (``refit`` recomputes labels + fit flags on the new tree).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import delta as deltalib
from repro.core.grid import Grid, cell_range
from repro.core.hybrid import HybridResult, HybridTree, hybrid_query

# module-level jit so staging doesn't retrace per insert batch (a fresh
# jax.jit wrapper per call would discard the trace cache every time)
_cell_range_j = jax.jit(cell_range)


class FreshResult(NamedTuple):
    """``HybridResult`` + the delta-probe count (mirrors
    ``ServeStats.delta_hits`` so mixed-stream reporting is uniform
    across the hybrid and engine servers)."""
    routed_high: "jax.Array"
    used_ai: "jax.Array"
    n_results: "jax.Array"
    result_ids: "jax.Array"
    leaf_accesses: "jax.Array"
    n_visited_r: "jax.Array"
    n_true: "jax.Array"
    truncated: "jax.Array"
    guarded: "jax.Array"
    delta_hits: "jax.Array"     # [B] buffer hits (already in n_results)


assert FreshResult._fields[:len(HybridResult._fields)] == \
    HybridResult._fields, "FreshResult must prefix-extend HybridResult"


class FreshnessStats(NamedTuple):
    """Aggregate monitor state, as surfaced per stream by launch/serve."""
    n_cells: int
    fit_cells: int       # cells with exact training fit
    stale_cells: int     # cells with inserts since the bank was fit
    ok_cells: int        # fit AND fresh — serve-eligible on the AI path
    n_inserts: int       # staged since the monitor was (re)fit
    n_repacks: int
    delta_fill: int      # points currently staged in the buffer


class FreshnessMonitor:
    """Host-side per-cell fit/staleness tracking over the model grid."""

    def __init__(self, grid: Grid, fit_ok: np.ndarray):
        self._grid = grid
        self.fit_ok = np.asarray(fit_ok, bool).copy()
        assert self.fit_ok.shape == (grid.n_cells,), \
            (self.fit_ok.shape, grid.g)
        self.stale = np.zeros_like(self.fit_ok, dtype=np.int64)
        self.n_inserts = 0
        self.n_repacks = 0

    def _cells_of_points(self, points: np.ndarray) -> np.ndarray:
        # map points as degenerate rects through the grid's own
        # ``cell_range`` so the monitor's cell attribution can never
        # drift from the convention serving queries are routed by;
        # out-of-bbox points clamp into the edge cells (conservative —
        # the edge cell's model never trained on that region either)
        p = np.asarray(points, np.float32).reshape(-1, 2)
        rects = jnp.asarray(np.concatenate([p, p], axis=1))
        cr = np.asarray(_cell_range_j(self._grid, rects))
        return cr[:, 1].astype(np.int64) * self._grid.g + cr[:, 0]

    def note_inserts(self, points: np.ndarray) -> None:
        """Inserts landed: bump the receiving cells' staleness."""
        cells = self._cells_of_points(points)
        np.add.at(self.stale, cells, 1)
        self.n_inserts += int(cells.shape[0])

    def note_repack(self) -> None:
        """The tree was rebuilt: every cell's label space is now wrong
        (bulk load renumbers all leaves), so the whole bank goes stale
        until a refit."""
        self.stale[:] = max(1, int(self.stale.max()))
        self.n_repacks += 1

    def note_refit(self, fit_ok: np.ndarray,
                   grid: Optional[Grid] = None) -> None:
        """The bank was refit on the current tree: staleness resets and
        the fit flags are replaced by the new evaluation's. Pass ``grid``
        when the refit's hill-climb landed on a different grid size — the
        monitor re-anchors to it (flags and staleness are per-cell, so
        they cannot survive a geometry change anyway)."""
        if grid is not None:
            self._grid = grid
        self.fit_ok = np.asarray(fit_ok, bool).copy()
        assert self.fit_ok.shape == (self._grid.n_cells,), \
            (self.fit_ok.shape, self._grid.g)
        self.stale = np.zeros_like(self.fit_ok, dtype=np.int64)
        self.n_inserts = 0

    def cell_ok(self) -> np.ndarray:
        """[C] bool: serve-eligible = exact fit AND no inserts since."""
        return self.fit_ok & (self.stale == 0)

    def guard_array(self) -> jnp.ndarray:
        return jnp.asarray(self.cell_ok())

    def stats(self, delta_fill: int = 0) -> FreshnessStats:
        ok = self.cell_ok()
        return FreshnessStats(
            n_cells=int(ok.size), fit_cells=int(self.fit_ok.sum()),
            stale_cells=int((self.stale > 0).sum()), ok_cells=int(ok.sum()),
            n_inserts=self.n_inserts, n_repacks=self.n_repacks,
            delta_fill=delta_fill)


class FreshServer:
    """Live serving state for a mixed read/write stream (single-device
    hybrid path; the distributed engine composes the same pieces via
    ``make_serve_step``'s ``delta_xy`` argument).

    Functionalized jax under a stateful host shell: every batch serves
    through jit'd closures over the *current* (hybrid, delta) pair;
    ``insert``/``repack`` swap that pair between batches, never under a
    running step. ``serve``/``serve_wide`` realize the scheduler's
    two-tier contract (``HybridResult.truncated``), with the wide tier's
    bounds — including the delta slot bound — scaled by ``wide_factor``.
    """

    trunc_field = "truncated"

    def __init__(self, points: np.ndarray, hybrid: HybridTree, *,
                 delta_cap: int = 4096, max_visited: int = 64,
                 max_results: int = 512, delta_k: int = 64,
                 wide_factor: int = 8, use_kernel: bool = False,
                 guard: bool = True,
                 refit_fn: Optional[Callable] = None):
        self.points = np.asarray(points, np.float64)
        self.max_entries = hybrid.tree.max_entries
        self.monitor = FreshnessMonitor(hybrid.ait.grid,
                                        np.asarray(hybrid.ait.cell_ok))
        self.delta = deltalib.make_delta(delta_cap,
                                         base=self.points.shape[0])
        self.hybrid = hybrid
        self._mv, self._mr = int(max_visited), int(max_results)
        self._dk, self._wf = int(delta_k), int(wide_factor)
        self._uk, self._guard = bool(use_kernel), bool(guard)
        # refit_fn(device_tree) -> (HybridTree, cell_fit [C] bool) — e.g.
        # a relabel + build.fit_airtree closure; None keeps the stale bank
        # guarded (R-path serving) after repacks
        self._refit_fn = refit_fn
        self._sync_guard()

    # -- serving -----------------------------------------------------------

    def _serve(self, q: jnp.ndarray, widen: int) -> "jax.Array":
        mv, mr = self._mv * widen, self._mr * widen
        dk = self._dk * widen
        res = hybrid_query(self.hybrid, q, max_visited=mv, max_results=mr,
                           use_kernel=self._uk, guard=self._guard)
        hits = deltalib.probe(self.delta.xy, q, k=dk, base=self.delta.base,
                              use_kernel=self._uk)
        merged = deltalib.merge_hybrid_result(res, hits)
        return FreshResult(*merged, delta_hits=hits.count)

    def serve(self, q) -> "jax.Array":
        return self._serve(jnp.asarray(q), 1)

    def serve_wide(self, q) -> "jax.Array":
        return self._serve(jnp.asarray(q), self._wf)

    # -- writes ------------------------------------------------------------

    @property
    def delta_fill(self) -> int:
        return self.delta.n

    def _sync_guard(self) -> None:
        ait = dataclasses.replace(self.hybrid.ait,
                                  cell_ok=self.monitor.guard_array())
        self.hybrid = dataclasses.replace(self.hybrid, ait=ait)

    def insert(self, points: np.ndarray) -> None:
        """Stage inserts into the delta buffer (between batches); the
        receiving cells go stale and drop off the AI path. A batch the
        buffer cannot absorb forces a repack first (this is the
        repack-before-overflow guarantee ``stage_inserts`` documents);
        a single batch larger than the whole capacity still raises."""
        m = np.asarray(points, np.float32).reshape(-1, 2).shape[0]
        if self.delta.n + m > self.delta.capacity:
            self.repack()
        self.delta = deltalib.stage_inserts(self.delta, points)
        self.monitor.note_inserts(points)
        self._sync_guard()

    def repack(self) -> None:
        """Online repack: swap in a fresh bulk-loaded tree holding every
        staged point, empty the buffer, and (without a refit) guard the
        whole bank — its labels refer to the old tree's leaf ids."""
        _, dtree, allp, self.delta = deltalib.repack(
            self.points, self.delta, max_entries=self.max_entries)
        self.points = allp
        self.monitor.note_repack()
        if self._refit_fn is not None:
            hybrid, cell_fit = self._refit_fn(dtree)
            self.hybrid = hybrid
            # the refit's grid search may land on a different grid size —
            # re-anchor the monitor to the refit hybrid's own grid
            self.monitor.note_refit(np.asarray(cell_fit, bool),
                                    grid=hybrid.ait.grid)
        else:
            self.hybrid = dataclasses.replace(self.hybrid, tree=dtree)
        self._sync_guard()

    def stats(self) -> FreshnessStats:
        return self.monitor.stats(delta_fill=self.delta.n)


class EngineFreshServer:
    """The ``FreshServer`` shape over the shard_map engine: serves through
    ``engine.make_serve_step``'s two tiers with the replicated delta
    buffer as the step's ``delta_xy`` argument. Tree and guard swaps
    re-pad for the mesh (``pad_tree_for_sharding``) between batches; the
    jit'd steps take (hybrid, queries, delta) as *arguments*, so staging
    inserts never retraces — only a repack's leaf-count change does.
    """

    trunc_field = "r_truncated"

    def __init__(self, points: np.ndarray, hybrid: HybridTree, mesh, cfg, *,
                 kind: str, n_model: int, delta_cap: int = 4096,
                 wide_factor: int = 8):
        from repro.core import engine as eng
        self.points = np.asarray(points, np.float64)
        self.max_entries = hybrid.tree.max_entries
        self.monitor = FreshnessMonitor(hybrid.ait.grid,
                                        np.asarray(hybrid.ait.cell_ok))
        self.delta = deltalib.make_delta(delta_cap,
                                         base=self.points.shape[0])
        self.hybrid = hybrid
        self._n_model = int(n_model)
        narrow, wide = eng.make_two_tier_steps(
            mesh, cfg, kind=kind, wide_factor=wide_factor)
        self._jnarrow = jax.jit(narrow)
        self._jwide = jax.jit(wide)
        self._repad()

    def _repad(self) -> None:
        """Full mesh re-pad — needed when the *tree* changes (repack).
        Guard-only updates go through ``_sync_guard``, which swaps just
        the padded eligibility mask instead of re-concatenating every
        leaf/bank array per staged insert batch."""
        from repro.core import engine as eng
        self._sync_hybrid()
        self._h_p = eng.pad_tree_for_sharding(self.hybrid, self._n_model)

    def _sync_hybrid(self) -> None:
        ait = dataclasses.replace(self.hybrid.ait,
                                  cell_ok=self.monitor.guard_array())
        self.hybrid = dataclasses.replace(self.hybrid, ait=ait)

    def _sync_guard(self) -> None:
        self._sync_hybrid()
        ok = self.hybrid.ait.cell_ok
        Cp = self._h_p.ait.cell_ok.shape[0]
        ok_p = jnp.concatenate(
            [ok, jnp.zeros((Cp - ok.shape[0],), ok.dtype)]) \
            if Cp != ok.shape[0] else ok
        self._h_p = dataclasses.replace(
            self._h_p, ait=dataclasses.replace(self._h_p.ait, cell_ok=ok_p))

    def serve(self, q) -> "jax.Array":
        return self._jnarrow(self._h_p, jnp.asarray(q), self.delta.xy)

    def serve_wide(self, q) -> "jax.Array":
        return self._jwide(self._h_p, jnp.asarray(q), self.delta.xy)

    @property
    def delta_fill(self) -> int:
        return self.delta.n

    def insert(self, points: np.ndarray) -> None:
        m = np.asarray(points, np.float32).reshape(-1, 2).shape[0]
        if self.delta.n + m > self.delta.capacity:
            self.repack()     # repack-before-overflow, as FreshServer
        self.delta = deltalib.stage_inserts(self.delta, points)
        self.monitor.note_inserts(points)
        self._sync_guard()

    def repack(self) -> None:
        _, dtree, allp, self.delta = deltalib.repack(
            self.points, self.delta, max_entries=self.max_entries)
        self.points = allp
        self.monitor.note_repack()
        self.hybrid = dataclasses.replace(self.hybrid, tree=dtree)
        self._repad()

    def stats(self) -> FreshnessStats:
        return self.monitor.stats(delta_fill=self.delta.n)
