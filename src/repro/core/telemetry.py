"""Shared streaming-stats primitives for the serving side.

Two consumers, one implementation:

* ``core.monitor.FreshnessMonitor`` aggregates per-cell serve counters
  over a bounded window of serve segments and summarizes them with
  rolling medians — the maintenance policy's signals
  (``SegmentWindow``);
* ``core.runtime.StreamingRuntime`` tracks per-query latency
  distributions (p50/p95/p99), queue depth, and an online estimate of
  the serve-step cost that its deadline-dispatch rule compares slack
  against (``QuantileReservoir`` + ``Ewma``).

Everything here is host-side numpy — these run between jit'd serve
steps, never inside one — and deterministic: the reservoir's eviction
RNG is seeded, so two runs over the same stream report the same
quantiles.
"""
from __future__ import annotations

from collections import deque
from typing import Optional, Sequence

import numpy as np


class Ewma:
    """Bias-corrected exponential moving average.

    ``update`` folds one observation in and returns the corrected mean;
    ``value`` is the current estimate (``default`` until the first
    observation — callers that gate on the estimate, like the runtime's
    dispatch rule, pick their own conservative bootstrap).
    """

    def __init__(self, alpha: float = 0.25, default: float = 0.0):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self.default = float(default)
        self._acc = 0.0
        self._norm = 0.0    # 1 - (1-alpha)^n — the bias correction term
        self.n = 0

    def update(self, x: float) -> float:
        self._acc = (1.0 - self.alpha) * self._acc + self.alpha * float(x)
        self._norm = (1.0 - self.alpha) * self._norm + self.alpha
        self.n += 1
        return self.value

    @property
    def value(self) -> float:
        if self.n == 0:
            return self.default
        return self._acc / self._norm


class QuantileReservoir:
    """Fixed-size uniform reservoir for streaming quantiles.

    Classic reservoir sampling (Vitter's algorithm R) with a seeded
    generator: the first ``size`` observations are kept verbatim, later
    ones evict uniformly at random, so ``quantile`` is exact until the
    reservoir fills and an unbiased estimate after. Memory is O(size)
    no matter how long the stream runs — the property that lets the
    runtime keep per-query latency percentiles over an unbounded
    open-loop stream.
    """

    def __init__(self, size: int = 4096, seed: int = 0):
        if size <= 0:
            raise ValueError(f"size must be positive, got {size}")
        self.size = int(size)
        self._rng = np.random.default_rng(seed)
        self._buf = np.empty((self.size,), np.float64)
        self.n = 0          # observations seen (≥ len(self))

    def __len__(self) -> int:
        return min(self.n, self.size)

    def add(self, x: float) -> None:
        if self.n < self.size:
            self._buf[self.n] = x
        else:
            j = int(self._rng.integers(0, self.n + 1))
            if j < self.size:
                self._buf[j] = x
        self.n += 1

    def extend(self, xs) -> None:
        for x in np.asarray(xs, np.float64).ravel():
            self.add(float(x))

    def quantile(self, q) -> np.ndarray:
        """Quantile(s) of the sample (NaN while empty)."""
        if len(self) == 0:
            return np.full(np.shape(q), np.nan) if np.ndim(q) else np.nan
        return np.quantile(self._buf[:len(self)], q)

    def summary(self) -> dict:
        """The standard latency triple + extremes, as plain floats."""
        if len(self) == 0:
            return {"n": 0, "p50": np.nan, "p95": np.nan, "p99": np.nan,
                    "max": np.nan, "mean": np.nan}
        s = self._buf[:len(self)]
        p50, p95, p99 = np.quantile(s, [0.5, 0.95, 0.99])
        return {"n": self.n, "p50": float(p50), "p95": float(p95),
                "p99": float(p99), "max": float(s.max()),
                "mean": float(s.mean())}


class SegmentWindow:
    """Bounded window of per-key counter segments with rolling-median
    rates — the ``FreshnessMonitor`` aggregation idiom, extracted so the
    maintenance policy and the streaming runtime share it.

    One *segment* accumulates integer counters per key (grid cell, tier,
    ...) for a set of named fields; ``roll`` closes it into a deque of
    at most ``window`` segments. ``rate(field)`` is the per-key rolling
    *median* of per-segment rates (count / ``fields[0]``): robust to a
    single anomalous segment, and segments where a key saw no traffic
    don't vote (all-quiet keys rate 0). ``count_median`` is the rolling
    median of the count field itself.
    """

    def __init__(self, n_keys: int, fields: Sequence[str], *,
                 window: int = 8):
        if len(fields) < 1:
            raise ValueError("need at least the count field")
        self.fields = tuple(fields)
        self.n_keys = int(n_keys)
        self._window = deque(maxlen=int(window))
        self._reset_segment()

    def __len__(self) -> int:
        return len(self._window)

    def __getitem__(self, i: int) -> dict:
        """The i-th closed segment's field->counts dict (read-only use)."""
        return self._window[i]

    def _reset_segment(self) -> None:
        self._seg = {f: np.zeros((self.n_keys,), np.int64)
                     for f in self.fields}

    def add(self, keys: np.ndarray, values: dict) -> None:
        """Accumulate one batch: ``keys`` [M] i64 indexes the count
        field once per row; ``values`` maps the remaining field names to
        [M] addends (missing fields simply don't accumulate)."""
        keys = np.asarray(keys, np.int64).ravel()
        np.add.at(self._seg[self.fields[0]], keys, 1)
        for f, v in values.items():
            if f == self.fields[0]:
                raise ValueError(f"count field {f!r} is implicit")
            np.add.at(self._seg[f], keys,
                      np.asarray(v).ravel().astype(np.int64))

    def roll(self) -> None:
        """Close the current segment into the rolling window."""
        self._window.append(self._seg)
        self._reset_segment()

    def clear(self, n_keys: Optional[int] = None) -> None:
        """Drop all window state (e.g. the key space changed size)."""
        if n_keys is not None:
            self.n_keys = int(n_keys)
        self._window.clear()
        self._reset_segment()

    def rate(self, field: str) -> np.ndarray:
        """[n_keys] f64 rolling-median per-key rate of ``field``."""
        if field not in self.fields[1:]:
            raise ValueError(f"unknown field {field!r}")
        if not self._window:
            return np.zeros((self.n_keys,), np.float64)
        n = np.stack([s[self.fields[0]] for s in self._window]
                     ).astype(np.float64)
        v = np.stack([s[field] for s in self._window]).astype(np.float64)
        rates = np.where(n > 0, v / np.maximum(n, 1), np.nan)
        voters = (n > 0).any(axis=0)
        med = np.zeros((self.n_keys,), np.float64)
        if voters.any():
            med[voters] = np.nanmedian(rates[:, voters], axis=0)
        return med

    def count_median(self) -> np.ndarray:
        """[n_keys] f64 rolling-median per-key count per segment."""
        if not self._window:
            return np.zeros((self.n_keys,), np.float64)
        n = np.stack([s[self.fields[0]] for s in self._window]
                     ).astype(np.float64)
        return np.median(n, axis=0)
