"""Spatial batch scheduler: Hilbert/Morton-ordered serving batches.

The fused traversal kernel's tile-level early exit (and the compaction
epilogue that inherits it) only pays off when a batch's queries are
spatially clustered — real traffic arrives interleaved. This layer
manufactures the locality: incoming queries are keyed on a space-filling
curve (``kernels.ops.spatial_key``), sorted, cut into fixed-size batches
(each batch then covers a compact region, so most leaf tiles are dead for
the whole batch), and served; the inverse permutation restores submission
order, so the caller sees results **bit-identical** to unsorted serving —
the serve step is per-query (every ServeStats row depends only on its own
query), so permuting the batch composition cannot change any row.

The scheduler is also where the engine's two-tier contract lives:
``ServeStats.r_truncated`` rows (R-path ``max_visited`` overflow — their
``n_results`` undercounts) are collected across the whole stream and
re-served on a wide-bound tier, instead of being the caller's problem.

Everything here is host-side orchestration (numpy permutations around
jit'd serve steps); the device-side work stays in the serve step itself.
"""
from __future__ import annotations

from typing import Callable, Iterator, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp


SORT_MODES = ("none", "morton", "hilbert")


def workload_bbox(queries: np.ndarray) -> np.ndarray:
    """[Q, 4] rects → [4] bounding box of the rect *centers*.

    Keys must be computed against one shared frame or they are not
    comparable across batches; the scheduler pins the workload's own
    center extent. Degenerate extents (a single query, or every center
    coincident along an axis) are widened to a unit span around the
    collapsed value: the key normalization divides by the extent, and
    clamping a zero span to an epsilon downstream would amplify f32
    rounding in ``center − lo`` into arbitrary key orderings — a valid
    frame must always have positive area.
    """
    c = (np.asarray(queries)[:, :2] + np.asarray(queries)[:, 2:]) / 2.0
    lo, hi = c.min(axis=0), c.max(axis=0)
    flat = hi - lo <= 0
    lo = np.where(flat, lo - 0.5, lo)
    hi = np.where(flat, hi + 0.5, hi)
    return np.concatenate([lo, hi]).astype(np.float32)


def point_query_mask(queries: np.ndarray) -> np.ndarray:
    """[Q, 4] → [Q] bool: degenerate rects (zero extent on both axes).

    The scheduler-side twin of ``hybrid.is_point_query`` — the detection
    that routes a stream (or the point rows of a mixed stream) onto the
    point-query fast path: single-cell AI routing plus a narrowed
    traversal, no wide tier (a point visits exactly the leaves whose
    MBRs contain it, a set the narrow bound must cover — exactness is
    asserted, not re-served).
    """
    q = np.asarray(queries, np.float32)
    return (q[:, 0] == q[:, 2]) & (q[:, 1] == q[:, 3])


def spatial_keys(queries: np.ndarray, sort: str,
                 bbox: Optional[np.ndarray] = None) -> np.ndarray:
    """[Q, 4] → [Q] i32 curve keys (zeros for ``sort="none"``).

    A caller-supplied ``bbox`` gets the same degenerate-extent guard as
    ``workload_bbox``: zero-extent axes are widened to a unit span so
    the keys stay well-defined (coincident centers all land in one
    curve cell) instead of leaning on the epsilon clamp downstream.
    """
    if sort not in SORT_MODES:
        raise ValueError(f"sort must be one of {SORT_MODES}, got {sort!r}")
    q = np.asarray(queries, np.float32)
    if sort == "none":
        return np.zeros((q.shape[0],), np.int32)
    from repro.kernels import ops
    if bbox is None:
        bbox = workload_bbox(q)
    else:
        bbox = np.asarray(bbox, np.float32).copy()
        flat = bbox[2:] - bbox[:2] <= 0
        bbox[:2] = np.where(flat, bbox[:2] - 0.5, bbox[:2])
        bbox[2:] = np.where(flat, bbox[2:] + 0.5, bbox[2:])
    return np.asarray(ops.spatial_key(jnp.asarray(q),
                                      bbox=jnp.asarray(bbox), curve=sort))


class Schedule(NamedTuple):
    """A batching plan over one query stream."""
    order: np.ndarray    # [Q] i32 — stream position → submission index
    inv: np.ndarray      # [Q] i32 — submission index → stream position
    n_queries: int
    batch: int
    n_batches: int       # ceil(Q / batch); the tail batch is padded
    sort: str


def make_schedule(queries: np.ndarray, batch: int, sort: str = "hilbert",
                  bbox: Optional[np.ndarray] = None) -> Schedule:
    """Key-sorted batch formation. ``sort="none"`` keeps submission order.

    The sort is stable, so equal keys (and the ``none`` mode) preserve
    submission order — scheduling is always a pure permutation.
    """
    q = np.asarray(queries, np.float32)
    n = q.shape[0]
    if n == 0 or batch <= 0:
        raise ValueError(f"need n_queries > 0 and batch > 0, got {n}/{batch}")
    keys = spatial_keys(q, sort, bbox)
    order = np.argsort(keys, kind="stable").astype(np.int32)
    inv = np.empty_like(order)
    inv[order] = np.arange(n, dtype=np.int32)
    return Schedule(order=order, inv=inv, n_queries=n, batch=int(batch),
                    n_batches=-(-n // int(batch)), sort=sort)


def iter_batches(queries: np.ndarray, sched: Schedule
                 ) -> Iterator[tuple[np.ndarray, int]]:
    """Yield ``(q [batch, 4] f32, n_valid)`` per stream batch.

    Every batch has the full static shape (one jit trace); the ragged tail
    is padded by repeating its last valid query — a real rect, so the
    padded rows are well-formed work whose stats are simply dropped.
    """
    q = np.asarray(queries, np.float32)[sched.order]
    for b in range(sched.n_batches):
        lo = b * sched.batch
        chunk = q[lo:lo + sched.batch]
        n_valid = chunk.shape[0]
        if n_valid < sched.batch:
            pad = np.repeat(chunk[-1:], sched.batch - n_valid, axis=0)
            chunk = np.concatenate([chunk, pad], axis=0)
        yield chunk, n_valid


def _rows(tree, sel) -> "jax.tree":
    """Apply a leading-axis selection to every array in a stats pytree."""
    return jax.tree.map(lambda a: np.asarray(a)[sel], tree)


def _merge_rows(narrow, wide, idx: np.ndarray):
    """Replace ``narrow``'s rows at ``idx`` with ``wide``'s, field-wise.

    The wide tier's static bounds are larger, so its slot-table fields
    (compacted leaf ids, result ids, ...) can be wider than the narrow
    tier's. Those are rank-prefix tables — the narrow width is a prefix
    view of the wide one — so wide rows are sliced to the narrow field
    shape: scalar stats (counts, flags) arrive corrected, payload tables
    keep the narrow tier's static width.
    """
    merged = {}
    for f in type(narrow)._fields:
        a = np.asarray(getattr(narrow, f)).copy()
        w = np.asarray(getattr(wide, f))
        if w.shape[1:] != a.shape[1:]:
            if any(ws < ns for ws, ns in zip(w.shape[1:], a.shape[1:])):
                raise ValueError(
                    f"wide tier field {f!r} narrower than narrow tier's: "
                    f"{w.shape} vs {a.shape}")
            w = w[(slice(None),) + tuple(slice(0, n) for n in a.shape[1:])]
        a[idx] = w
        merged[f] = a
    return type(narrow)(**merged)


class ServeReport(NamedTuple):
    """Aggregate result of one scheduled stream."""
    stats: object           # per-query stats pytree, submission order
    n_queries: int
    n_batches: int
    n_reserved: int         # rows re-served on the wide tier
    wide_batches: int
    sort: str


def serve_workload(serve_fn: Callable, queries: np.ndarray, *, batch: int,
                   sort: str = "hilbert",
                   bbox: Optional[np.ndarray] = None,
                   wide_fn: Optional[Callable] = None,
                   trunc_field: str = "r_truncated") -> ServeReport:
    """Serve a full query stream through the spatial scheduler.

    ``serve_fn``: ``[batch, 4] jnp → stats`` pytree of per-query arrays
    (leading axis ``batch``) — e.g. an ``engine.make_serve_step`` closure
    or a jit'd ``hybrid_query`` wrapper. Every query of ``queries`` is
    served exactly once (ragged tails are padded, pad rows dropped) and
    the returned stats are in submission order, bit-identical to serving
    the same stream unsorted.

    Two-tier re-serve: with ``wide_fn`` (same signature, wider bounds),
    rows whose ``trunc_field`` is set are collected across the stream and
    re-served through ``wide_fn``; their stats rows are replaced by the
    wide tier's (slot-table fields sliced to the narrow tier's static
    width — see ``_merge_rows``). ``trunc_field=None`` (or absent from
    the stats) disables the second tier.
    """
    sched = make_schedule(queries, batch, sort, bbox)
    outs = []
    for chunk, n_valid in iter_batches(queries, sched):
        stats = serve_fn(jnp.asarray(chunk))
        outs.append(_rows(stats, np.s_[:n_valid]))
    stream = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *outs)
    result = _rows(stream, sched.inv)   # back to submission order

    n_reserved = wide_batches = 0
    if wide_fn is not None and trunc_field is not None \
            and hasattr(result, trunc_field):
        trunc = np.asarray(getattr(result, trunc_field)).astype(bool)
        idx = np.flatnonzero(trunc)
        n_reserved = int(idx.size)
        if n_reserved:
            wide = serve_workload(wide_fn, np.asarray(queries, np.float32)[idx],
                                  batch=batch, sort=sort, bbox=bbox,
                                  wide_fn=None, trunc_field=None)
            wide_batches = wide.n_batches
            result = _merge_rows(result, wide.stats, idx)
    return ServeReport(stats=result, n_queries=sched.n_queries,
                       n_batches=sched.n_batches, n_reserved=n_reserved,
                       wide_batches=wide_batches, sort=sort)


def visible_segments(report: "MixedReport", base_points: np.ndarray):
    """Yield ``((lo, hi), visible)`` per segment of a mixed stream.

    ``visible`` is the [N, 2] f32 point set the segment's queries could
    see: ``base_points`` plus every chunk the scheduler reports it
    actually staged before that segment (``report.staged``). The one
    place the segment-visibility convention lives — the launch driver's
    oracle, the CI freshness gate and the tests all consume this instead
    of re-deriving the staging policy.
    """
    visible = np.asarray(base_points, np.float32)
    for s, (lo, hi) in enumerate(report.seg_bounds):
        if report.staged[s] is not None:
            visible = np.concatenate([visible, report.staged[s]])
        yield (lo, hi), visible


class MixedReport(NamedTuple):
    """Aggregate result of one mixed read/write stream."""
    stats: object           # per-query stats pytree, submission order
    n_queries: int
    n_batches: int
    n_reserved: int         # rows re-served on the wide tier
    n_inserts: int          # points staged into the delta store
    n_repacks: int          # online repacks performed mid-stream
    #                         (scheduler-initiated via repack_every;
    #                         policy repacks live in ``maintenance``)
    n_segments: int         # insert-delimited spans of the query stream
    seg_bounds: tuple       # per-segment (start, end) submission indices
    staged: tuple           # per-segment insert chunk ([m, 2] f32 or
    #                         None) ACTUALLY staged before segment s,
    #                         plus one trailing after-stream entry —
    #                         oracles derive each segment's visible point
    #                         set from this, never by re-deriving the
    #                         chunking policy
    sort: str
    maintenance: tuple = ()  # per-segment (segment_index, decision)
    #                         entries from the server's ``on_segment``
    #                         hook (maintenance-policy servers only);
    #                         segments with no decision are absent


def serve_mixed_workload(server, queries: np.ndarray,
                         inserts: Optional[np.ndarray], *, batch: int,
                         sort: str = "hilbert",
                         bbox: Optional[np.ndarray] = None,
                         insert_every: int = 1,
                         repack_every: int = 0) -> MixedReport:
    """Serve a query stream with insert batches interleaved.

    ``server`` owns the live serving state (``core.monitor.FreshServer``
    or anything duck-typed like it): ``serve(q)``/``serve_wide(q)``
    answer batches, ``insert(points)`` stages writes, ``repack()`` swaps
    in a rebuilt tree, ``delta_fill`` reports the buffer level and
    ``trunc_field`` names the wide-tier flag.

    The stream is cut into *segments* of ``insert_every`` query batches;
    before each segment after the first, the next chunk of ``inserts``
    is staged (so segment ``s`` sees exactly the first ``s`` chunks —
    deterministic visibility), and a repack fires whenever the buffer
    holds ≥ ``repack_every`` points (0 = never). Inserts with no later
    segment to precede — all of them when the stream fits in one segment
    — are staged after the final segment, so every insert always lands
    in the server (visible to subsequent streams) even though no query
    of *this* stream sees them. Within a segment the delta store is
    frozen, so each segment runs through the ordinary spatial scheduler
    (``serve_workload``) — sorted serving stays bit-identical to
    unsorted *within* the segment, and the two-tier wide re-serve also
    happens per segment (a later re-serve would see a different buffer).
    Stats come back in submission order.
    """
    q = np.asarray(queries, np.float32)
    n = q.shape[0]
    ins = None if inserts is None else np.asarray(inserts, np.float32)
    if bbox is None:
        bbox = workload_bbox(q)
    seg = max(1, int(insert_every)) * int(batch)
    n_segments = -(-n // seg)
    chunks = [None] * (n_segments + 1)
    if ins is not None and ins.shape[0]:
        if n_segments > 1:
            chunks[1:-1] = np.array_split(ins, n_segments - 1)
        else:
            chunks[-1] = ins    # no later segment: stage after the stream

    def _stage(chunk):
        count = 0
        if chunk is not None and chunk.shape[0]:
            server.insert(chunk)
            count = int(chunk.shape[0])
            if repack_every and server.delta_fill >= repack_every:
                server.repack()
                return count, 1
        return count, 0

    outs, bounds, maint = [], [], []
    n_batches = n_reserved = n_inserts = n_repacks = 0
    on_segment = getattr(server, "on_segment", None)
    for s in range(n_segments):
        ni, nr = _stage(chunks[s])
        n_inserts += ni
        n_repacks += nr
        lo, hi = s * seg, min((s + 1) * seg, n)
        rep = serve_workload(server.serve, q[lo:hi], batch=batch, sort=sort,
                             bbox=bbox, wide_fn=server.serve_wide,
                             trunc_field=getattr(server, "trunc_field",
                                                 "truncated"))
        outs.append(rep.stats)
        bounds.append((lo, hi))
        n_batches += rep.n_batches
        n_reserved += rep.n_reserved
        # between-segments maintenance window: the server rolls its
        # signal window and (policy servers) repacks/refits/demotes —
        # never under a running segment, so each segment still serves
        # against frozen state and stays bit-identical under sorting
        if on_segment is not None:
            decision = on_segment()
            if decision is not None:
                maint.append((s, decision))
    ni, nr = _stage(chunks[n_segments])
    n_inserts += ni
    n_repacks += nr
    stats = jax.tree.map(lambda *xs: np.concatenate(xs, axis=0), *outs)
    return MixedReport(stats=stats, n_queries=n, n_batches=n_batches,
                       n_reserved=n_reserved, n_inserts=n_inserts,
                       n_repacks=n_repacks, n_segments=n_segments,
                       seg_bounds=tuple(bounds), staged=tuple(chunks),
                       sort=sort, maintenance=tuple(maint))
