"""Flattened, device-resident form of the R-tree.

The host ``RTree`` (pointer style) is converted to a structure-of-arrays
suitable for batched TPU traversal:

* one ``Level`` per tree depth, nodes ordered so that every parent's children
  are **contiguous** and leaf order equals the paper's DFS leaf-ID order
  (§III-A1 — sibling leaves get consecutive IDs);
* each level stores node MBRs ``[N_l, 4]`` and a ``parent`` index into the
  level above, so frontier expansion is one gather + one rect-intersection;
* the leaf level additionally stores a padded entry tensor ``[L, M_pad, 2]``
  (pad = +inf, so containment tests fail on padding) and the corresponding
  point ids ``[L, M_pad]`` (pad = -1).

All device arrays are float32/int32 — the f64 host build is only a builder.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rtree import RTree


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Level:
    mbrs: jnp.ndarray    # [N_l, 4] f32
    parent: jnp.ndarray  # [N_l] i32 index into previous level


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceTree:
    levels: Tuple[Level, ...]        # levels[0] has exactly 1 node (the root)
    leaf_entries: jnp.ndarray        # [L, M_pad, 2] f32, +inf padded
    leaf_entry_ids: jnp.ndarray      # [L, M_pad] i32, -1 padded
    leaf_counts: jnp.ndarray         # [L] i32
    n_points: int = dataclasses.field(metadata=dict(static=True))
    max_entries: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_leaves(self) -> int:
        return int(self.levels[-1].mbrs.shape[0])

    @property
    def leaf_mbrs(self) -> jnp.ndarray:
        return self.levels[-1].mbrs

    @property
    def height(self) -> int:
        return len(self.levels)

    def byte_size(self) -> int:
        total = 0
        for lv in self.levels:
            total += lv.mbrs.size * 4 + lv.parent.size * 4
        total += self.leaf_entries.size * 4 + self.leaf_entry_ids.size * 4
        total += self.leaf_counts.size * 4
        return total


def flatten(tree: RTree, pad_to: int | None = None) -> DeviceTree:
    """Flatten a host ``RTree`` to a ``DeviceTree``.

    ``pad_to`` overrides the per-leaf entry padding (defaults to ``tree.M``,
    rounded up to a multiple of 8 for clean vector lanes).
    """
    assert tree.points is not None, "flatten() needs a built tree"
    M_pad = pad_to if pad_to is not None else tree.M
    M_pad = int(np.ceil(M_pad / 8) * 8)

    # ---- level-order walk with parent-ordered children (== DFS leaf order)
    level_nodes: List[List[int]] = [[tree.root]]
    while not all(tree.is_leaf[n] for n in level_nodes[-1]):
        nxt: List[int] = []
        for n in level_nodes[-1]:
            assert not tree.is_leaf[n], "unbalanced host tree"
            nxt.extend(tree.children[n])
        level_nodes.append(nxt)

    levels: List[Level] = []
    for depth, nodes in enumerate(level_nodes):
        mbrs = tree.mbrs[nodes].astype(np.float32)
        if depth == 0:
            parent = np.zeros((1,), dtype=np.int32)
        else:
            pos_above = {n: i for i, n in enumerate(level_nodes[depth - 1])}
            parent = np.array(
                [pos_above[tree.parent[n]] for n in nodes], dtype=np.int32)
        levels.append(Level(mbrs=jnp.asarray(mbrs), parent=jnp.asarray(parent)))

    # ---- leaf entries, padded
    leaves = level_nodes[-1]
    L = len(leaves)
    entries = np.full((L, M_pad, 2), np.inf, dtype=np.float32)
    entry_ids = np.full((L, M_pad), -1, dtype=np.int32)
    counts = np.zeros((L,), dtype=np.int32)
    for i, n in enumerate(leaves):
        ids = tree.children[n]
        k = len(ids)
        assert k <= M_pad, f"leaf fill {k} exceeds pad {M_pad}"
        if k:
            entries[i, :k] = tree.points[ids].astype(np.float32)
            entry_ids[i, :k] = np.asarray(ids, dtype=np.int32)
        counts[i] = k

    return DeviceTree(
        levels=tuple(levels),
        leaf_entries=jnp.asarray(entries),
        leaf_entry_ids=jnp.asarray(entry_ids),
        leaf_counts=jnp.asarray(counts),
        n_points=int(tree.points.shape[0]),
        max_entries=tree.M,
    )


def dfs_leaf_index(tree: RTree) -> dict:
    """host-node-id → DFS leaf id (the class label space of the paper)."""
    return {n: i for i, n in enumerate(tree.leaves_dfs())}
