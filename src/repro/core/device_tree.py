"""Flattened, device-resident form of the R-tree.

The host ``RTree`` (pointer style) is converted to a structure-of-arrays
suitable for batched TPU traversal:

* one ``Level`` per tree depth, nodes ordered so that every parent's children
  are **contiguous** and leaf order equals the paper's DFS leaf-ID order
  (§III-A1 — sibling leaves get consecutive IDs);
* each level stores node MBRs ``[N_l, 4]`` and a ``parent`` index into the
  level above, so frontier expansion is one gather + one rect-intersection;
* the leaf level additionally stores a padded entry tensor ``[L, M_pad, 2]``
  (pad = +inf, so containment tests fail on padding) and the corresponding
  point ids ``[L, M_pad]`` (pad = -1).

All device arrays are float32/int32 — the f64 host build is only a builder.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rtree import RTree


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Level:
    mbrs: jnp.ndarray    # [N_l, 4] f32
    parent: jnp.ndarray  # [N_l] i32 index into previous level


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AncestorTable:
    """Per-(internal level, leaf tile) ancestor windows for the sliced walk.

    The level-order flatten gives every parent's children contiguous ids,
    so each ``tl``-wide leaf tile's ancestor set at internal level ``l`` is
    a contiguous index range. ``starts[l, t]`` is the *block index* of the
    ``widths[l]``-wide aligned window containing that range (window element
    offset = ``starts[l, t] * widths[l]`` — Pallas block-spec index maps
    address whole blocks, so windows are block-aligned and ``widths[l]`` is
    the smallest lane-quantum power-of-two width that block-aligns every
    tile's range, capped at the lane-padded level width). The sliced fused
    traversal (``kernels.traverse_fused.traverse_fused_sliced_t``) feeds
    ``starts`` through scalar prefetch and stages only each tile's window
    of every internal level into VMEM — the walk fits the VMEM budget at
    any tree size.
    """
    starts: jnp.ndarray  # [n_int, n_tiles] i32 block-index window starts
    widths: Tuple[int, ...] = dataclasses.field(metadata=dict(static=True))
    tl: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_tiles(self) -> int:
        return int(self.starts.shape[1])


def build_ancestor_table(level_parents, *, tl: int | None = None
                         ) -> "AncestorTable | None":
    """Host-side ancestor-window table for the sliced fused traversal.

    ``level_parents``: one ``[N_l]`` int parent array per tree level, root
    first, leaf level last (``DeviceTree``'s layout — entry 0 of the root's
    array is unused). ``tl`` is the leaf-tile granularity (defaults to the
    kernel's ``DEF_TL``). Returns ``None`` for single-level trees (root ==
    leaves — no internal levels to slice).

    Ranges are computed bottom-up by min/max over each tile's slice (no
    monotonicity assumption on the parent arrays, though the level-order
    flatten produces non-decreasing ones); widths double from the lane
    quantum until every tile's range fits one aligned window, capped at the
    lane-padded level width (cap ⇒ the window degenerates to the whole
    level — full replication, still correct).
    """
    from repro.kernels.traverse_fused import DEF_TL, LANE
    tl = int(tl or DEF_TL)
    parents = [np.asarray(p) for p in level_parents]
    n_int = len(parents) - 1
    if n_int < 1:
        return None
    L = parents[-1].shape[0]
    n_tiles = -(-L // tl)
    los = np.empty((n_int, n_tiles), np.int64)
    his = np.empty((n_int, n_tiles), np.int64)
    lp = parents[-1]
    edges = np.arange(0, L, tl)
    los[n_int - 1] = np.minimum.reduceat(lp, edges)
    his[n_int - 1] = np.maximum.reduceat(lp, edges)
    for l in range(n_int - 1, 0, -1):
        p = parents[l]
        for t in range(n_tiles):
            seg = p[los[l, t]:his[l, t] + 1]
            los[l - 1, t] = seg.min()
            his[l - 1, t] = seg.max()
    widths = []
    starts = np.zeros((n_int, n_tiles), np.int32)
    for l in range(n_int):
        n_l = parents[l].shape[0]
        cap = -(-max(n_l, 1) // LANE) * LANE
        w = LANE
        while w < cap and not np.all(los[l] // w == his[l] // w):
            w *= 2
        if w >= cap:
            w = cap          # whole (lane-padded) level in one window
        else:
            starts[l] = (los[l] // w).astype(np.int32)
        widths.append(int(w))
    return AncestorTable(starts=jnp.asarray(starts), widths=tuple(widths),
                         tl=tl)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceTree:
    levels: Tuple[Level, ...]        # levels[0] has exactly 1 node (the root)
    leaf_entries: jnp.ndarray        # [L, M_pad, 2] f32, +inf padded
    leaf_entry_ids: jnp.ndarray      # [L, M_pad] i32, -1 padded
    leaf_counts: jnp.ndarray         # [L] i32
    n_points: int = dataclasses.field(metadata=dict(static=True))
    max_entries: int = dataclasses.field(metadata=dict(static=True))
    # Ancestor-window table for the sliced fused traversal (None on trees
    # built before/without one — dispatch then falls back; ``flatten``
    # always attaches it, ``engine.pad_tree_for_sharding`` rebuilds or
    # drops it to match the padded/sharded leaf axis).
    aslices: "AncestorTable | None" = None

    @property
    def n_leaves(self) -> int:
        return int(self.levels[-1].mbrs.shape[0])

    @property
    def leaf_mbrs(self) -> jnp.ndarray:
        return self.levels[-1].mbrs

    @property
    def height(self) -> int:
        return len(self.levels)

    def byte_size(self) -> int:
        total = 0
        for lv in self.levels:
            total += lv.mbrs.size * 4 + lv.parent.size * 4
        total += self.leaf_entries.size * 4 + self.leaf_entry_ids.size * 4
        total += self.leaf_counts.size * 4
        return total


def flatten(tree: RTree, pad_to: int | None = None,
            slice_tl: int | None = None) -> DeviceTree:
    """Flatten a host ``RTree`` to a ``DeviceTree``.

    ``pad_to`` overrides the per-leaf entry padding (defaults to ``tree.M``,
    rounded up to a multiple of 8 for clean vector lanes). ``slice_tl``
    overrides the ancestor-window table's leaf-tile granularity (defaults
    to the fused kernel's ``DEF_TL``); the table itself is always attached
    (``None`` only for root==leaf trees).
    """
    assert tree.points is not None, "flatten() needs a built tree"
    M_pad = pad_to if pad_to is not None else tree.M
    M_pad = int(np.ceil(M_pad / 8) * 8)

    # ---- level-order walk with parent-ordered children (== DFS leaf order)
    level_nodes: List[List[int]] = [[tree.root]]
    while not all(tree.is_leaf[n] for n in level_nodes[-1]):
        nxt: List[int] = []
        for n in level_nodes[-1]:
            assert not tree.is_leaf[n], "unbalanced host tree"
            nxt.extend(tree.children[n])
        level_nodes.append(nxt)

    levels: List[Level] = []
    np_parents: List[np.ndarray] = []
    for depth, nodes in enumerate(level_nodes):
        mbrs = tree.mbrs[nodes].astype(np.float32)
        if depth == 0:
            parent = np.zeros((1,), dtype=np.int32)
        else:
            pos_above = {n: i for i, n in enumerate(level_nodes[depth - 1])}
            parent = np.array(
                [pos_above[tree.parent[n]] for n in nodes], dtype=np.int32)
        np_parents.append(parent)
        levels.append(Level(mbrs=jnp.asarray(mbrs), parent=jnp.asarray(parent)))

    # ---- leaf entries, padded
    leaves = level_nodes[-1]
    L = len(leaves)
    entries = np.full((L, M_pad, 2), np.inf, dtype=np.float32)
    entry_ids = np.full((L, M_pad), -1, dtype=np.int32)
    counts = np.zeros((L,), dtype=np.int32)
    for i, n in enumerate(leaves):
        ids = tree.children[n]
        k = len(ids)
        assert k <= M_pad, f"leaf fill {k} exceeds pad {M_pad}"
        if k:
            entries[i, :k] = tree.points[ids].astype(np.float32)
            entry_ids[i, :k] = np.asarray(ids, dtype=np.int32)
        counts[i] = k

    return DeviceTree(
        levels=tuple(levels),
        leaf_entries=jnp.asarray(entries),
        leaf_entry_ids=jnp.asarray(entry_ids),
        leaf_counts=jnp.asarray(counts),
        n_points=int(tree.points.shape[0]),
        max_entries=tree.M,
        aslices=build_ancestor_table(np_parents, tl=slice_tl),
    )


def dfs_leaf_index(tree: RTree) -> dict:
    """host-node-id → DFS leaf id (the class label space of the paper)."""
    return {n: i for i, n in enumerate(tree.leaves_dfs())}
