"""Rectangle algebra used across the AI+R-tree core.

Rectangles are ``(xmin, ymin, xmax, ymax)`` arrays. Two parallel
implementations are provided on purpose:

* ``np_*`` — numpy, used by the host-side R-tree builder / label prep.
* ``jnp_*`` — jax.numpy, used inside jitted traversal / serving code.

Touching intersections count as intersections (closed rectangles), matching
the classical R-tree definition and the paper's range-query semantics.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# Axis indices for readability.
XMIN, YMIN, XMAX, YMAX = 0, 1, 2, 3


# ---------------------------------------------------------------------------
# numpy twins (host side)
# ---------------------------------------------------------------------------

def np_intersects(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Pairwise rect-intersection mask.

    ``a``: [..., 4], ``b``: [..., 4] broadcastable against each other.
    """
    return (
        (a[..., XMIN] <= b[..., XMAX])
        & (b[..., XMIN] <= a[..., XMAX])
        & (a[..., YMIN] <= b[..., YMAX])
        & (b[..., YMIN] <= a[..., YMAX])
    )


def np_cross_intersects(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """All-pairs intersection mask. ``a``: [A, 4], ``b``: [B, 4] → [A, B]."""
    return np_intersects(a[:, None, :], b[None, :, :])


def np_contains_point(rect: np.ndarray, pts: np.ndarray) -> np.ndarray:
    """``rect``: [..., 4], ``pts``: [..., 2] broadcastable → bool mask."""
    return (
        (pts[..., 0] >= rect[..., XMIN])
        & (pts[..., 0] <= rect[..., XMAX])
        & (pts[..., 1] >= rect[..., YMIN])
        & (pts[..., 1] <= rect[..., YMAX])
    )


def np_area(rect: np.ndarray) -> np.ndarray:
    return np.maximum(rect[..., XMAX] - rect[..., XMIN], 0.0) * np.maximum(
        rect[..., YMAX] - rect[..., YMIN], 0.0
    )


def np_union(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """MBR of the union of two rects (broadcasting)."""
    lo = np.minimum(a[..., :2], b[..., :2])
    hi = np.maximum(a[..., 2:], b[..., 2:])
    return np.concatenate([lo, hi], axis=-1)


def np_enlargement(mbr: np.ndarray, rect: np.ndarray) -> np.ndarray:
    """Area growth of ``mbr`` if enlarged to include ``rect`` (broadcasting)."""
    return np_area(np_union(mbr, rect)) - np_area(mbr)


def np_mbr_of_points(pts: np.ndarray) -> np.ndarray:
    """[N, 2] → [4] MBR."""
    lo = pts.min(axis=0)
    hi = pts.max(axis=0)
    return np.concatenate([lo, hi])


def np_mbr_of_rects(rects: np.ndarray) -> np.ndarray:
    """[N, 4] → [4] MBR."""
    lo = rects[:, :2].min(axis=0)
    hi = rects[:, 2:].max(axis=0)
    return np.concatenate([lo, hi])


# ---------------------------------------------------------------------------
# jnp twins (device side)
# ---------------------------------------------------------------------------

def jnp_intersects(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return (
        (a[..., XMIN] <= b[..., XMAX])
        & (b[..., XMIN] <= a[..., XMAX])
        & (a[..., YMIN] <= b[..., YMAX])
        & (b[..., YMIN] <= a[..., YMAX])
    )


def jnp_cross_intersects(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """[A, 4] × [B, 4] → [A, B] bool (pure-jnp oracle; the Pallas kernel in
    ``repro.kernels.mbr_intersect`` is the production path)."""
    return jnp_intersects(a[:, None, :], b[None, :, :])


def jnp_contains_point(rect: jnp.ndarray, pts: jnp.ndarray) -> jnp.ndarray:
    return (
        (pts[..., 0] >= rect[..., XMIN])
        & (pts[..., 0] <= rect[..., XMAX])
        & (pts[..., 1] >= rect[..., YMIN])
        & (pts[..., 1] <= rect[..., YMAX])
    )


def jnp_area(rect: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(rect[..., XMAX] - rect[..., XMIN], 0.0) * jnp.maximum(
        rect[..., YMAX] - rect[..., YMIN], 0.0
    )
