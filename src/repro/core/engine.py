"""Distributed batched serving engine for the "AI+R"-tree.

Sharding layout on the production mesh (pod, data, model):

  * queries                 → split over (pod, data)  — traffic parallelism
  * leaf entries / leaf MBRs→ split over model        — the tree's "pages"
  * grid-cell experts       → split over model        — expert parallelism
  * internal levels, router → replicated              — tiny, read-only

Per-batch collectives (all over ``model``):
  1. ``pmax`` of the AI-path per-leaf score union  (experts live apart)
  2. ``psum`` of per-query refine counts           (leaves live apart)

The R path and AI path both touch only the local leaf shard, so the paper's
"skip extraneous leaf accesses" becomes "skip extraneous HBM traffic on
every shard" — the AI-tree's benefit scales with the mesh.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6: top-level export
    _shard_map = jax.shard_map
except AttributeError:  # older jax: experimental module
    from jax.experimental.shard_map import shard_map as _shard_map
# The replication-check kwarg was renamed check_rep → check_vma; detect it
# from the signature rather than inferring from the export location (some
# versions export jax.shard_map but still take check_rep).
_SHARD_MAP_CHECK_KW = (
    "check_vma" if "check_vma" in inspect.signature(_shard_map).parameters
    else "check_rep")

from repro.core.device_tree import DeviceTree, Level
from repro.core.hybrid import HybridTree
from repro.core import traversal
from repro.core.grid import cells_of_queries
from repro.core.classifiers.knn import KNNBank
from repro.core.classifiers.mlp import MLPBank
from repro.core.classifiers.forest import Forest


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_visited: int = 64        # per-shard compact bound (R path)
    max_pred: int = 16           # per-shard compact bound (AI path)
    max_cells: int = 4
    threshold: float = 0.5
    use_kernel: bool = False
    # AI-path score-union collective:
    #  "pmax"  — paper-faithful dense union: pmax over the full [B, L]
    #            per-leaf score table (simple, collective-heavy);
    #  "topk"  — beyond-paper: each expert shard reduces its local scores to
    #            (leaf id, score) top-k per query, the union runs over the
    #            all-gathered [B, shards·k] candidate lists. Exact whenever
    #            a query's true leaf set per shard ≤ k (guaranteed here by
    #            k = max_pred, since >max_pred predictions fall back anyway).
    # Default "topk": O(B·shards·k) payload vs pmax's O(B·L_glob) table —
    # 2-3.4× faster at every swept shard count and scaling away from pmax
    # past 4 shards (benchmarks/union_scaling.py, union_* rows).
    score_union: str = "topk"
    # Freshness guard: demote queries overlapping a not-ok cell
    # (``AITree.cell_ok`` — under-fit at build time or stale since inserts
    # landed there) to the exact R path before prediction. Default ON: a
    # sub-1.0-fit bank on the ungated AI path silently drops results (the
    # under-prediction blind spot); exact-fit, fresh banks are unaffected
    # (their cell_ok is all-True and the guard never fires).
    guard: bool = True
    # Delta-probe compact slot bound (the insert buffer's per-query hit
    # table). The engine only consumes the exact per-query hit *count*, so
    # this bounds kernel-side slot work, never correctness.
    delta_k: int = 64


def pad_tree_for_sharding(h: HybridTree, n_shards: int) -> HybridTree:
    """Pad leaf-level arrays (and expert cells) to multiples of ``n_shards``.

    Padding leaves get never-intersecting MBRs and +inf entries; padding
    cells get -1 label maps. Semantics are unchanged.
    """
    t = h.tree
    L = t.n_leaves
    Lp = int(np.ceil(L / n_shards) * n_shards)
    if Lp != L:
        pad = Lp - L
        never = jnp.asarray([np.inf, np.inf, -np.inf, -np.inf], jnp.float32)
        leaf = t.levels[-1]
        # padding leaves repeat the last real parent (not 0): their
        # never-rect MBRs keep them dead either way, but the repeat keeps
        # the rebuilt ancestor windows tight (a 0 parent in the last leaf
        # tile would stretch that tile's window back to the level start)
        new_leaf = Level(
            mbrs=jnp.concatenate(
                [leaf.mbrs, jnp.tile(never[None], (pad, 1))]),
            parent=jnp.concatenate(
                [leaf.parent,
                 jnp.broadcast_to(leaf.parent[-1], (pad,))]))
        t = dataclasses.replace(
            t,
            levels=t.levels[:-1] + (new_leaf,),
            leaf_entries=jnp.concatenate(
                [t.leaf_entries,
                 jnp.full((pad,) + t.leaf_entries.shape[1:], jnp.inf,
                          t.leaf_entries.dtype)]),
            leaf_entry_ids=jnp.concatenate(
                [t.leaf_entry_ids,
                 jnp.full((pad,) + t.leaf_entry_ids.shape[1:], -1,
                          jnp.int32)]),
            leaf_counts=jnp.concatenate(
                [t.leaf_counts, jnp.zeros((pad,), jnp.int32)]),
        )
    # Re-anchor the ancestor-window table to the padded leaf axis. Inside
    # shard_map each shard keeps its contiguous run of leaf tiles (starts
    # columns shard with them — ``tree_shardings_p``) while internal
    # levels stay replicated, so the table stays valid *iff* the tile
    # grid divides evenly across shards; otherwise drop it and let
    # dispatch fall back.
    if t.aslices is not None:
        tl_s = t.aslices.tl
        if Lp % tl_s == 0 and (Lp // tl_s) % n_shards == 0:
            from repro.core.device_tree import build_ancestor_table
            t = dataclasses.replace(t, aslices=build_ancestor_table(
                [np.asarray(lv.parent) for lv in t.levels], tl=tl_s))
        else:
            t = dataclasses.replace(t, aslices=None)
    from repro.core.aitree import bank_n_cells
    bank = h.ait.bank
    C = bank_n_cells(bank)
    Cp = int(np.ceil(C / n_shards) * n_shards)
    cell_ok = h.ait.cell_ok
    if Cp != C:
        padc = Cp - C

        def _pad0(a, fill=0):
            return jnp.concatenate(
                [a, jnp.full((padc,) + a.shape[1:], fill, a.dtype)])

        # padding cells are never routed to (cell ids < C), but guard them
        # anyway — False is the safe fill for an eligibility mask
        cell_ok = _pad0(cell_ok, False)
        if isinstance(bank, KNNBank):
            bank = dataclasses.replace(
                bank, feats=_pad0(bank.feats, np.inf),
                labels=_pad0(bank.labels), label_map=_pad0(bank.label_map, -1),
                lmask=_pad0(bank.lmask, False))
        elif isinstance(bank, MLPBank):
            bank = dataclasses.replace(
                bank, w1=_pad0(bank.w1), b1=_pad0(bank.b1), w2=_pad0(bank.w2),
                b2=_pad0(bank.b2), label_map=_pad0(bank.label_map, -1),
                lmask=_pad0(bank.lmask, False))
        else:
            bank = dataclasses.replace(
                bank, feat_idx=_pad0(bank.feat_idx),
                thresh=_pad0(bank.thresh, np.inf), tables=_pad0(bank.tables),
                label_map=_pad0(bank.label_map, -1),
                lmask=_pad0(bank.lmask, False))
    ait = dataclasses.replace(h.ait, bank=bank, cell_ok=cell_ok)
    return dataclasses.replace(h, tree=t, ait=ait)


def tree_shardings(h: HybridTree, mesh, model_axis: str = "model"):
    """NamedSharding pytree matching ``HybridTree`` (for jit in_shardings)."""
    spec = tree_shardings_p(h, model_axis)
    return jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), spec,
                        is_leaf=lambda x: isinstance(x, P))


class ServeStats(NamedTuple):
    n_results: jnp.ndarray      # [B]
    leaf_accesses: jnp.ndarray  # [B]
    routed_high: jnp.ndarray    # [B]
    used_ai: jnp.ndarray        # [B]
    r_truncated: jnp.ndarray    # [B] R-path refine bound overflow — the
    #                             caller re-serves these on the wide-bound
    #                             tier (two-tier serving; keeps max_visited
    #                             small for the common case)
    guarded: jnp.ndarray        # [B] routed-high but demoted to the R path
    #                             by the cell guard (fit < 1 / stale cell)
    delta_hits: jnp.ndarray     # [B] qualifying points found in the insert
    #                             delta buffer (already folded into
    #                             n_results; zeros when no delta store)
    mispredict: jnp.ndarray     # [B] AI-path attempt hit the misprediction
    #                             signal (predicted leaf, zero qualifiers) —
    #                             per-cell drift evidence for the policy
    cell_id: jnp.ndarray        # [B] i32 anchor grid cell (-1 on window
    #                             overflow) — the monitor's aggregation key


class RPathOut(NamedTuple):
    """Per-query R-path stage output (collectives already reduced)."""
    r_counts: jnp.ndarray    # [B] qualifying points via the classical path
    n_visited: jnp.ndarray   # [B] classical visit count (global)
    n_true: jnp.ndarray      # [B] true-leaf count (global)
    r_truncated: jnp.ndarray  # [B] max_visited overflow on any shard


class AIPathOut(NamedTuple):
    """Per-query AI-path stage output (collectives already reduced)."""
    ai_counts: jnp.ndarray   # [B] qualifying points via predicted leaves
    n_pred: jnp.ndarray      # [B] predicted leaf accesses (global)
    fallback: jnp.ndarray    # [B] prediction unusable → R answer
    guarded: jnp.ndarray     # [B] query overlaps a not-ok cell → demoted
    #                          to the R path before prediction
    mispredict: jnp.ndarray  # [B] the misprediction component of fallback
    #                          (a predicted leaf held no qualifying entry)
    cell_id: jnp.ndarray     # [B] i32 anchor cell (-1 on window overflow)


class SlotRefineOut(NamedTuple):
    """Shared refine-stage output over one [B, K] slot table (psum'd)."""
    n_results: jnp.ndarray   # [B] qualifying points across valid slots
    n_hit: jnp.ndarray       # [B] valid slots with ≥ 1 qualifying point
    n_valid: jnp.ndarray     # [B] valid slots


def _refine_slots(h: HybridTree, queries: jnp.ndarray, leaf_idx: jnp.ndarray,
                  valid: jnp.ndarray, cfg: EngineConfig,
                  model_axis: str) -> SlotRefineOut:
    """Shared refine stage: a compact ``[B, K]`` slot table of local leaf
    ids in, globally-reduced per-query counts out.

    Both paths feed this — the slot table is the single inter-path
    contract: the R path's ``visited_leaves_compact`` slots and the AI
    path's predicted slots (fused kernel or oracle, either union mode)
    land here identically. The three reductions cover every downstream
    need: ``n_results`` (answers), ``n_hit`` (the R path's true-leaf
    count), and ``n_valid`` − ``n_hit`` > 0 (the paper's misprediction
    signal — some predicted leaf held no qualifying entry).
    """
    ref = traversal.refine_leaves(h.tree, queries, leaf_idx, valid,
                                  use_kernel=cfg.use_kernel)
    vi = valid.astype(jnp.int32)
    n_results = jax.lax.psum(jnp.sum(ref.counts * vi, -1), model_axis)
    n_hit = jax.lax.psum(
        jnp.sum(((ref.counts > 0) & valid).astype(jnp.int32), -1),
        model_axis)
    n_valid = jax.lax.psum(jnp.sum(vi, -1), model_axis)
    return SlotRefineOut(n_results=n_results, n_hit=n_hit, n_valid=n_valid)


def _r_path(h: HybridTree, queries: jnp.ndarray, cfg: EngineConfig,
            model_axis: str) -> RPathOut:
    """Classical stage over the local leaf shard.

    Fused traverse+compact (with use_kernel, the [B, L_loc] visited
    mask stays in VMEM; only the [B, max_visited] slots + counts
    reach HBM — the jnp path materializes the mask but compacts with
    the identical scheme). Internal levels are replicated, so the
    traversal applies unchanged per shard: the local leaf level's
    parent indices point into the replicated last internal level, and
    the sharding pad's never-intersecting leaf MBRs stay dead
    regardless of their parent slot. Single-level (root == leaf)
    shards are handled downstream — the former engine-local loop
    self-gathered the root mask there.
    """
    cv = traversal.visited_leaves_compact(
        h.tree, queries, cfg.max_visited, use_kernel=cfg.use_kernel)
    r_trunc = jax.lax.psum(cv.overflow.astype(jnp.int32), model_axis) > 0
    ro = _refine_slots(h, queries, cv.leaf_idx, cv.valid, cfg, model_axis)
    n_visited = jax.lax.psum(cv.n_visited, model_axis)    # [B]
    return RPathOut(r_counts=ro.n_results, n_visited=n_visited,
                    n_true=ro.n_hit, r_truncated=r_trunc)


def _ai_slots_topk(h: HybridTree, queries: jnp.ndarray, cfg: EngineConfig,
                   kind: str, loc_ids: jnp.ndarray, local: jnp.ndarray,
                   model_axis: str, n_model: int, L_loc: int, L_glob: int):
    """Per-shard compact prediction slots + shard union (``topk`` mode).

    Beyond-paper: each expert shard compacts its local cells' predictions
    to the first ``max_pred`` **distinct** global leaf ids (leaf-ID
    order) — with an MLP bank under ``use_kernel`` that is the fused
    prediction kernel writing the slot table straight from VMEM; the
    oracle rung runs ``compact_candidates`` over the small [B, S·Cl]
    candidate list. The union reads the all-gathered ``[B, shards·k]``
    slot lists directly (the previous implementation re-top-k'd dense
    per-leaf scores): single-shard meshes need no union at all — the
    shard's slots *are* the answer, so no per-leaf tensor of any size
    exists; multi-shard meshes scatter the gathered ids into the
    ``[B, L_loc]`` local-range mask, which *shrinks* with the mesh (a
    pairwise ``compact_candidates`` dedup here would grow O((shards·k)²)
    transients on the hot path instead). Exact whenever no shard
    overflows its k distinct predictions (guaranteed complete lists);
    overflow falls back — a fallback is never wrong, only slower.

    Returns ``(p_idx, p_valid, n_pred, overflow)`` with ``p_idx`` local
    leaf ids for the shared refine stage and ``n_pred`` the
    globally-deduped predicted-leaf count (sibling cells on *different*
    shards can predict the same leaf, but each distinct leaf lands in
    exactly one shard's range — the psum of local mask counts dedups).
    """
    B = queries.shape[0]
    k = cfg.max_pred
    midx = jax.lax.axis_index(model_axis)
    if kind == "mlp" and cfg.use_kernel:
        from repro.kernels import ops as kops
        g_idx, g_valid, g_cnt = kops.mlp_predict_compact(
            queries, h.ait.bank, loc_ids, local, n_leaves=L_glob, k=k,
            threshold=cfg.threshold)
    else:
        from repro.core.aitree import cell_slot_probs
        probs = cell_slot_probs(h.ait, queries, loc_ids)
        lm = h.ait.bank.label_map[loc_ids]                # [B, S, Cl]
        lok = local[:, :, None] & h.ait.bank.lmask[loc_ids] \
            & (probs > cfg.threshold)
        g_idx, g_valid, g_cnt = traversal.compact_candidates(
            lm.reshape(B, -1), lok.reshape(B, -1), k)
    if n_model == 1:
        return g_idx, g_valid, g_cnt, g_cnt > k
    trunc = jax.lax.psum((g_cnt > k).astype(jnp.int32), model_axis) > 0
    ag_i = jax.lax.all_gather(g_idx, model_axis, axis=1, tiled=True)
    ag_v = jax.lax.all_gather(g_valid, model_axis, axis=1, tiled=True)
    keep = ag_v & (ag_i >= midx * L_loc) & (ag_i < (midx + 1) * L_loc)
    li = jnp.clip(ag_i - midx * L_loc, 0, L_loc - 1)
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    pred_loc = jnp.zeros((B, L_loc), jnp.int32).at[rows, li].max(
        keep.astype(jnp.int32)) > 0
    n_pred = jax.lax.psum(
        jnp.sum(pred_loc.astype(jnp.int32), -1), model_axis)
    p_idx, p_valid, _ = traversal.compact_mask_counted(pred_loc, k)
    return p_idx, p_valid, n_pred, (n_pred > k) | trunc


def _ai_path(h: HybridTree, queries: jnp.ndarray, cfg: EngineConfig,
             kind: str, model_axis: str, n_model: int) -> AIPathOut:
    """Learned stage: per-cell experts → score union → shared refine.

    Both ``score_union`` modes end in the same compact ``[B, max_pred]``
    slot table handed to ``_refine_slots``: ``topk`` builds it without
    ever materializing per-leaf scores (``_ai_slots_topk``); ``pmax``
    keeps the paper-faithful dense ``[B, L_glob]`` union and compacts the
    local slice. ``n_model`` is the static model-axis size
    (``jax.lax.axis_size`` is too new for the supported jax range).
    """
    B = queries.shape[0]
    L_loc = h.tree.levels[-1].mbrs.shape[0]
    midx = jax.lax.axis_index(model_axis)
    # global cell ids per query; translate to local expert slots
    cell_ids, cvalid, cell_over = cells_of_queries(
        h.ait.grid, queries, cfg.max_cells)
    from repro.core.aitree import bank_n_cells
    C_loc = bank_n_cells(h.ait.bank)
    c0 = midx * C_loc
    local = (cell_ids >= c0) & (cell_ids < c0 + C_loc) & cvalid
    loc_ids = jnp.clip(cell_ids - c0, 0, C_loc - 1)
    if cfg.guard:
        # freshness/fit guard over the local expert shard: any overlapped
        # cell with cell_ok False demotes the query (each valid cell is
        # local to exactly one shard, so the psum unions the verdicts)
        bad = jnp.any(local & ~h.ait.cell_ok[loc_ids], axis=-1)
        guarded = jax.lax.psum(bad.astype(jnp.int32), model_axis) > 0
    else:
        guarded = jnp.zeros((B,), bool)
    L_glob = L_loc * n_model
    if cfg.score_union == "pmax":
        # paper-faithful dense union: one pmax over the full score table
        from repro.core.aitree import cell_slot_probs
        from repro.core.classifiers.mlp import global_scores
        probs = cell_slot_probs(h.ait, queries, loc_ids)
        scores = global_scores(h.ait.bank, probs, local, loc_ids, L_glob)
        scores = jax.lax.pmax(scores, model_axis)         # [B, L_glob]
        pred = scores > cfg.threshold
        pred_loc = jax.lax.dynamic_slice_in_dim(
            pred, midx * L_loc, L_loc, 1)
        n_pred = jnp.sum(pred.astype(jnp.int32), -1)      # replicated
        p_idx, p_valid, p_cnt = traversal.compact_mask_counted(
            pred_loc, cfg.max_pred)
        over = (p_cnt > cfg.max_pred) | (n_pred > cfg.max_pred)
        over = jax.lax.psum(over.astype(jnp.int32), model_axis) > 0
    else:
        p_idx, p_valid, n_pred, over = _ai_slots_topk(
            h, queries, cfg, kind, loc_ids, local, model_axis, n_model,
            L_loc, L_glob)
    ro = _refine_slots(h, queries, p_idx, p_valid, cfg, model_axis)
    empty = n_pred == 0
    mis = ro.n_valid > ro.n_hit   # some predicted leaf had no qualifier
    fallback = empty | mis | cell_over | over
    # anchor-cell attribution: global ids on replicated queries, identical
    # on every shard (no collective needed)
    cell_id = jnp.where(cvalid[:, 0], cell_ids[:, 0], -1).astype(jnp.int32)
    return AIPathOut(ai_counts=ro.n_results, n_pred=n_pred,
                     fallback=fallback, guarded=guarded, mispredict=mis,
                     cell_id=cell_id)


def _delta_path(queries: jnp.ndarray, delta_xy: jnp.ndarray,
                cfg: EngineConfig) -> jnp.ndarray:
    """Freshness stage: probe the (replicated) insert delta buffer.

    Returns the per-query exact hit count [B] i32 — staged points are
    invisible to both tree paths, so the count is *added* to whichever
    path answered. With ``use_kernel`` the probe is the Pallas kernel
    (``ops.delta_probe``): the ``[B, cap]`` containment mask stays in
    VMEM and only the compact slot table + counts reach HBM; the jnp
    oracle rung is bit-identical. The buffer is replicated (it is small
    and write-staged on the host), so no collective is needed.
    """
    if cfg.use_kernel:
        from repro.kernels import ops as kops
        _, _, cnt = kops.delta_probe(queries, delta_xy, k=cfg.delta_k)
    else:
        from repro.kernels import ref as kref
        _, _, cnt = kref.delta_probe(queries, delta_xy, cfg.delta_k)
    return cnt


def _route_combine(h: HybridTree, queries: jnp.ndarray, rp: RPathOut,
                   ap: AIPathOut,
                   d_hits: Optional[jnp.ndarray] = None) -> ServeStats:
    """Router dispatch + paper cost accounting over the stage outputs.

    Guard-demoted rows (``ap.guarded``) take the R answer and pay only
    the classical cost — the guard fires before prediction. Delta hits
    (``d_hits``, the freshness stage) add to the chosen path's count:
    staged inserts are invisible to both tree paths by construction.
    """
    from repro.core.classifiers.router import route_high
    high = route_high(h.router, queries)
    demoted = high & ap.guarded
    eligible = high & ~demoted
    used_ai = eligible & ~ap.fallback
    if d_hits is None:
        d_hits = jnp.zeros_like(rp.r_counts)
    n_results = jnp.where(used_ai, ap.ai_counts, rp.r_counts) + d_hits
    leaf_accesses = jnp.where(
        eligible, ap.n_pred + jnp.where(ap.fallback, rp.n_visited, 0),
        rp.n_visited)
    # overflow only matters when the R path supplied the answer: used_ai
    # rows report exact AI-path stats (n_visited stays exact regardless —
    # the compaction count is not truncated), so flagging them would send
    # already-exact rows through the wide tier for bit-identical results
    return ServeStats(n_results=n_results, leaf_accesses=leaf_accesses,
                      routed_high=high, used_ai=used_ai,
                      r_truncated=rp.r_truncated & ~used_ai,
                      guarded=demoted, delta_hits=d_hits,
                      # only rows that attempted the AI path can mispredict
                      mispredict=eligible & ap.mispredict,
                      cell_id=ap.cell_id)


def make_serve_step(mesh, cfg: EngineConfig, *, kind: str,
                    batch_axes=("pod", "data"), model_axis: str = "model"):
    """Build the shard_map'd hybrid serve step for ``mesh``.

    Returned fn: ``(hybrid, queries [B,4], delta_xy=None) → ServeStats``
    with B split over ``batch_axes`` and tree/experts split over
    ``model_axis``. ``delta_xy`` ([cap, 2] f32, +inf on unstaged slots —
    ``core.delta.DeltaStore.xy``) is the replicated insert buffer; when
    passed, the ``_delta_path`` stage probes it and its hits fold into
    ``n_results``. The body is a composition of the stage functions above
    — ``_r_path`` / ``_ai_path`` / ``_delta_path`` / ``_route_combine`` —
    so alternative drivers (the spatial batch scheduler, the two-tier
    wide re-serve, future partial pipelines) can restage them without
    re-deriving the collective layout.
    """
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    n_model = mesh.shape[model_axis]

    def body(h: HybridTree, queries):
        rp = _r_path(h, queries, cfg, model_axis)
        ap = _ai_path(h, queries, cfg, kind, model_axis, n_model)
        return _route_combine(h, queries, rp, ap)

    def body_delta(h: HybridTree, queries, delta_xy):
        rp = _r_path(h, queries, cfg, model_axis)
        ap = _ai_path(h, queries, cfg, kind, model_axis, n_model)
        d = _delta_path(queries, delta_xy, cfg)
        return _route_combine(h, queries, rp, ap, d)

    baxes = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    qspec = P(baxes, None)
    ospec = ServeStats(n_results=P(baxes), leaf_accesses=P(baxes),
                       routed_high=P(baxes), used_ai=P(baxes),
                       r_truncated=P(baxes), guarded=P(baxes),
                       delta_hits=P(baxes), mispredict=P(baxes),
                       cell_id=P(baxes))

    def serve_step(h: HybridTree, queries: jnp.ndarray,
                   delta_xy: Optional[jnp.ndarray] = None) -> ServeStats:
        if delta_xy is None:
            shard = _shard_map(
                body, mesh=mesh,
                in_specs=(tree_shardings_p(h, model_axis), qspec),
                out_specs=ospec,
                **{_SHARD_MAP_CHECK_KW: False})
            return shard(h, queries)
        shard = _shard_map(
            body_delta, mesh=mesh,
            in_specs=(tree_shardings_p(h, model_axis), qspec, P(None, None)),
            out_specs=ospec,
            **{_SHARD_MAP_CHECK_KW: False})
        return shard(h, queries, delta_xy)

    return serve_step


def wide_config(cfg: EngineConfig, factor: int = 8) -> EngineConfig:
    """The wide-bound tier's config: ``max_visited`` scaled by ``factor``."""
    return dataclasses.replace(cfg, max_visited=cfg.max_visited * factor)


def point_config(cfg: EngineConfig, max_visited: int = 32) -> EngineConfig:
    """The point-query fast path's config: single-cell AI routing (a
    degenerate rect overlaps exactly one grid cell, so the cell window
    collapses with no overflow) and a traversal narrowed to point-sized
    bounds. No wide tier pairs with this — the driver asserts
    ``r_truncated`` stays empty instead of re-serving."""
    return dataclasses.replace(cfg, max_cells=1,
                               max_visited=min(cfg.max_visited, max_visited))


def make_point_serve_step(mesh, cfg: EngineConfig, *, kind: str,
                          max_visited: int = 32,
                          batch_axes=("pod", "data"),
                          model_axis: str = "model"):
    """``make_serve_step`` specialized for degenerate-rect point queries
    (see ``point_config``). Same ``(hybrid, queries, delta_xy=None) →
    ServeStats`` closure shape as the range step, so the scheduler and
    the open-loop runtime drive it unchanged."""
    return make_serve_step(mesh, point_config(cfg, max_visited), kind=kind,
                           batch_axes=batch_axes, model_axis=model_axis)


def make_two_tier_steps(mesh, cfg: EngineConfig, *, kind: str,
                        wide_factor: int = 8, batch_axes=("pod", "data"),
                        model_axis: str = "model"):
    """Narrow + wide serve steps realizing the ``r_truncated`` contract.

    The narrow step keeps ``max_visited`` small for the common case;
    queries that overflow it (``ServeStats.r_truncated`` — their
    ``n_results`` undercounts) are collected by the scheduler
    (``core.schedule.serve_workload``) and re-served through the wide
    step, whose bound is ``wide_factor``× larger. Returns
    ``(narrow_step, wide_step)``; both are ``(hybrid, queries) →
    ServeStats`` closures over the same mesh layout.
    """
    narrow = make_serve_step(mesh, cfg, kind=kind, batch_axes=batch_axes,
                             model_axis=model_axis)
    wide = make_serve_step(mesh, wide_config(cfg, wide_factor), kind=kind,
                           batch_axes=batch_axes, model_axis=model_axis)
    return narrow, wide


def tree_shardings_p(h: HybridTree, model_axis: str = "model"):
    """PartitionSpec pytree (not NamedSharding) for shard_map in_specs."""
    rep = P()
    t = h.tree
    lvl_specs = []
    for i, lv in enumerate(t.levels):
        if i == len(t.levels) - 1:
            lvl_specs.append(Level(mbrs=P(model_axis, None),
                                   parent=P(model_axis)))
        else:
            lvl_specs.append(Level(mbrs=rep, parent=rep))
    tree_spec = DeviceTree(
        levels=tuple(lvl_specs),
        leaf_entries=P(model_axis, None, None),
        leaf_entry_ids=P(model_axis, None),
        leaf_counts=P(model_axis),
        n_points=t.n_points, max_entries=t.max_entries,
        # window starts shard along the tile axis with the leaf chunks
        # they describe (internal levels stay replicated, so each shard's
        # columns still hold valid global window indices)
        aslices=None if t.aslices is None else dataclasses.replace(
            t.aslices, starts=P(None, model_axis)))
    bank = h.ait.bank
    if isinstance(bank, KNNBank):
        bank_spec = dataclasses.replace(
            bank, feats=P(model_axis, None, None),
            labels=P(model_axis, None, None), label_map=P(model_axis, None),
            lmask=P(model_axis, None))
    elif isinstance(bank, MLPBank):
        bank_spec = dataclasses.replace(
            bank, w1=P(model_axis, None, None), b1=P(model_axis, None),
            w2=P(model_axis, None, None), b2=P(model_axis, None),
            mu=rep, sd=rep, label_map=P(model_axis, None),
            lmask=P(model_axis, None))
    else:
        bank_spec = dataclasses.replace(
            bank, feat_idx=P(model_axis, None, None),
            thresh=P(model_axis, None, None),
            tables=P(model_axis, None, None, None),
            label_map=P(model_axis, None), lmask=P(model_axis, None))
    ait_spec = dataclasses.replace(
        h.ait, bank=bank_spec, cell_ok=P(model_axis),
        grid=dataclasses.replace(h.ait.grid, bbox=rep))
    router_spec = dataclasses.replace(
        h.router, feat_idx=rep, thresh=rep, tables=rep)
    return HybridTree(tree=tree_spec, ait=ait_spec, router=router_spec)
