"""Cell-span invalidation: which grid cells does a tree change touch?

The online refit pipeline (``build.refit_cells``) retrains only the cells
whose *leaf span* changed across an insert/repack, so the maintenance
loop needs a sound, cheap answer to "did cell ``c``'s world move?". This
module defines that answer:

  * a leaf's **signature** is the sorted tuple of its entry point-ids —
    stable across rebuilds (ids are preserved by ``delta.repack``) and
    unique per leaf (leaves partition the points, so two leaves can only
    share a signature if both are the same set — impossible while they
    are disjoint and non-empty);
  * a cell's **span** is the frozenset of signatures of every leaf whose
    MBR intersects the cell's rectangle *dilated by one cell width per
    side*.

Soundness of the dilation (why an unchanged span ⇒ the cell's model and
certification stay valid): a non-overflow query assigned to cell ``c``
overlaps at most a ``side×side`` window of cells anchored at ``c``
(``grid.cells_of_queries``, side = √max_cells, i.e. 2 for the default
``max_cells=4``), so the query rect — clipped to the grid bbox the
training queries were fit inside — lies within ``c``'s rect dilated by
``side - 1`` cell widths. Every leaf such a query's refinement can touch
intersects the query rect and hence the dilated rect: the cell's true
labels are a function of the span alone. Equal spans ⇒ identical leaf
geometry and contents over everything the cell's queries can see ⇒ the
retrained-model-would-be-identical and the exactness certificates carry
over (after renaming leaf ids through ``leaf_remap``).

An insert always changes the receiving cells' spans: the staged point
lands in some leaf at the next repack, growing that leaf's signature.
"""
from __future__ import annotations

import numpy as np

from repro.core.device_tree import DeviceTree
from repro.core.grid import Grid


def leaf_signatures(dtree: DeviceTree) -> list[bytes]:
    """[L] per-leaf stable identity: sorted entry point-ids as bytes."""
    ids = np.asarray(dtree.leaf_entry_ids)
    counts = np.asarray(dtree.leaf_counts)
    return [np.sort(ids[l, :counts[l]]).astype(np.int64).tobytes()
            for l in range(ids.shape[0])]


def cell_spans(dtree: DeviceTree, grid: Grid, *, dilate: int = 1,
               sigs: list[bytes] | None = None) -> list[frozenset]:
    """[g*g] per-cell leaf spans (cell id = cy * g + cx, as everywhere).

    ``dilate`` is in cell widths per side and must be ≥ ``side - 1`` of
    the serving window (1 for the default ``max_cells=4``).
    """
    g = grid.g
    x0, y0, x1, y1 = (float(v) for v in np.asarray(grid.bbox))
    cw = (x1 - x0) / g
    ch = (y1 - y0) / g
    if sigs is None:
        sigs = leaf_signatures(dtree)
    mbrs = np.asarray(dtree.leaf_mbrs)                     # [L, 4]
    spans: list[frozenset] = []
    for cy in range(g):
        for cx in range(g):
            rx0 = x0 + (cx - dilate) * cw
            ry0 = y0 + (cy - dilate) * ch
            rx1 = x0 + (cx + 1 + dilate) * cw
            ry1 = y0 + (cy + 1 + dilate) * ch
            hit = ((mbrs[:, 0] <= rx1) & (rx0 <= mbrs[:, 2])
                   & (mbrs[:, 1] <= ry1) & (ry0 <= mbrs[:, 3]))
            spans.append(frozenset(sigs[l] for l in np.flatnonzero(hit)))
    return spans


def diff_spans(old_spans: list[frozenset], new_spans: list[frozenset],
               old_sigs: list[bytes], new_sigs: list[bytes]
               ) -> tuple[np.ndarray, np.ndarray]:
    """Compare spans across a tree change.

    Returns ``(changed [C] bool, leaf_remap [L_old] i32)``: ``changed[c]``
    iff cell ``c``'s span differs (its model must retrain and its
    certificates are void); ``leaf_remap[l]`` is the new DFS leaf id of
    the old leaf with signature ``old_sigs[l]``, or -1 if no new leaf has
    that exact point set. Signatures are unique per tree (disjoint
    non-empty point sets), so the remap is well-defined.
    """
    assert len(old_spans) == len(new_spans), "span diff needs equal grids"
    changed = np.array([o != n for o, n in zip(old_spans, new_spans)], bool)
    pos = {s: i for i, s in enumerate(new_sigs)}
    remap = np.array([pos.get(s, -1) for s in old_sigs], np.int32)
    return changed, remap


def remap_label_map(label_map: np.ndarray, lmask: np.ndarray,
                    leaf_remap: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Rewrite a bank's global leaf ids through ``leaf_remap``.

    For cells whose span did NOT change, every in-span leaf survives with
    the same signature, and every label the cell's training queries
    produced is in-span (see module docstring) — so no valid slot maps to
    -1 in practice. Defensively, a slot whose leaf vanished is cleared
    (map -1, mask off): ``global_scores`` then parks it at the out-of-
    range column and it can never score a leaf.
    """
    lm = np.asarray(label_map)
    msk = np.asarray(lmask).copy()
    out = np.where(msk, leaf_remap[np.where(msk, lm, 0)], -1).astype(np.int32)
    msk &= out >= 0
    return out, msk
