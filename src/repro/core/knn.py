"""Batched k-nearest-neighbor serving on the slot-table contract.

The kNN path is *distance browsing over the range machinery*: probe the
tree with the query's ``center ± radius`` box through the fused
traversal's compaction epilogue (``visited_leaves_compact`` — the
``[B, L]`` visited mask never reaches HBM on the kernel path), then
distance-browse exactly the named leaf slots (``kernels.knn_browse`` —
only those entry tiles move HBM→VMEM) and take the k smallest in-radius
distances over the flat ``[B, K·M]`` candidate view.

Exactness argument: every point within distance ``r`` of the center
lies inside the probe box, so it sits in a visited leaf. If the visited
set did not overflow its slot table **and** at least ``k`` candidates
fell within ``r``, the k smallest in-radius distances are the global
k nearest — anything outside ``r`` is farther than all of them. Rows
where either condition fails are flagged ``truncated`` and re-served by
the wide tier of ``make_knn_steps``: the radius **doubles** (and the
slot table widens) instead of a rect widening — the same two-tier
``serve_workload`` machinery the range path uses, with the re-serve
geometry swapped. Residual truncation stays flagged, never silently
approximate.
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device_tree import DeviceTree
from repro.core.traversal import visited_leaves_compact


class KnnResult(NamedTuple):
    neighbor_ids: jnp.ndarray   # [B, k] i32 entry ids, -1 padded
    neighbor_d2: jnp.ndarray    # [B, k] f32 squared distances, +inf padded
    n_within: jnp.ndarray       # [B] i32 candidates within the radius
    n_visited: jnp.ndarray      # [B] i32 leaves the probe box visited
    leaf_accesses: jnp.ndarray  # [B] i32 leaf tiles actually browsed
    truncated: jnp.ndarray      # [B] bool — result not provably exact


def query_centers(queries: jnp.ndarray) -> jnp.ndarray:
    """[B, 4] rects (or [B, 2] points) → [B, 2] f32 centers."""
    q = queries.astype(jnp.float32)
    if q.shape[-1] == 2:
        return q
    return jnp.stack([(q[:, 0] + q[:, 2]) * 0.5,
                      (q[:, 1] + q[:, 3]) * 0.5], axis=1)


@functools.partial(jax.jit, static_argnames=("k", "max_visited",
                                             "use_kernel", "tile_b",
                                             "tile_l"))
def knn_query(tree: DeviceTree, queries: jnp.ndarray, *, k: int,
              radius: float, max_visited: int = 64,
              use_kernel: bool = False, tile_b: int | None = None,
              tile_l: int | None = None) -> KnnResult:
    """Radius-probed exact kNN: queries [B, 4] rects (centers taken) or
    [B, 2] points → ``KnnResult``.

    ``radius`` is the probe radius (data units). A row is exact unless
    ``truncated`` — the visited set overflowed ``max_visited`` slots or
    fewer than ``k`` candidates fell within the radius (see module doc).
    """
    centers = query_centers(queries)
    r = jnp.float32(radius)
    box = jnp.concatenate([centers - r, centers + r], axis=1)
    cv = visited_leaves_compact(tree, box, max_visited,
                                use_kernel=use_kernel, tile_b=tile_b,
                                tile_l=tile_l)
    c3 = jnp.concatenate([centers, jnp.full_like(centers[:, :1], r * r)],
                         axis=1)
    if use_kernel:
        from repro.kernels import ops as kops
        d2 = kops.knn_browse(c3, tree.leaf_entries, cv.leaf_idx, cv.valid)
    else:
        from repro.kernels import ref as kref
        safe_idx = jnp.clip(cv.leaf_idx, 0,
                            tree.leaf_entries.shape[0] - 1)
        d2 = kref.knn_browse(c3, tree.leaf_entries[..., 0],
                             tree.leaf_entries[..., 1], safe_idx, cv.valid)
    B = centers.shape[0]
    flat_d2 = d2.reshape(B, -1)                         # [B, K·M]
    safe_idx = jnp.clip(cv.leaf_idx, 0, tree.leaf_entry_ids.shape[0] - 1)
    flat_ids = tree.leaf_entry_ids[safe_idx].reshape(B, -1)
    n_within = jnp.sum(jnp.isfinite(flat_d2).astype(jnp.int32), axis=-1)
    # top-k smallest: negate and lax.top_k (ties break to the lower flat
    # position, so slot order — hence ids — is deterministic per form)
    kk = min(k, flat_d2.shape[-1])
    neg, pos = jax.lax.top_k(-flat_d2, kk)
    d2k = -neg
    idk = jnp.take_along_axis(flat_ids, pos, axis=-1)
    if kk < k:          # degenerate tiny trees: keep the static [B, k]
        d2k = jnp.pad(d2k, ((0, 0), (0, k - kk)),
                      constant_values=jnp.inf)
        idk = jnp.pad(idk, ((0, 0), (0, k - kk)), constant_values=0)
    hit = jnp.isfinite(d2k)
    return KnnResult(
        neighbor_ids=jnp.where(hit, idk, -1),
        neighbor_d2=jnp.where(hit, d2k, jnp.inf),
        n_within=n_within,
        n_visited=cv.n_visited,
        leaf_accesses=jnp.minimum(cv.n_visited, max_visited),
        truncated=cv.overflow | (n_within < k),
    )


def make_knn_steps(tree: DeviceTree, *, k: int, radius: float,
                   max_visited: int = 64, wide_factor: int = 8,
                   use_kernel: bool = False):
    """Two-tier kNN serve steps for ``schedule.serve_workload``.

    The narrow tier probes at ``radius``; the wide tier doubles the
    radius and widens the slot table by ``wide_factor`` — the kNN
    analogue of ``engine.make_two_tier_steps``'s width widening, wired
    to the same re-serve loop (``trunc_field="truncated"``). Both tiers
    share the static ``[B, k]`` result width, so the merge keeps wide
    rows whole.
    """
    narrow = jax.jit(lambda q: knn_query(
        tree, q, k=k, radius=radius, max_visited=max_visited,
        use_kernel=use_kernel))
    wide = jax.jit(lambda q: knn_query(
        tree, q, k=k, radius=radius * 2.0,
        max_visited=max_visited * wide_factor, use_kernel=use_kernel))
    return narrow, wide


def default_radius(tree: DeviceTree, k: int, margin: float = 2.0) -> float:
    """Density-derived probe radius: for ~uniform data, a disc holding
    ``k`` points has radius ``sqrt(k·A / (π·n))``; ``margin`` buys
    slack so the narrow tier usually resolves in one pass."""
    root = np.asarray(tree.levels[0].mbrs, np.float64)
    area = float(max((root[:, 2].max() - root[:, 0].min())
                     * (root[:, 3].max() - root[:, 1].min()), 1e-12))
    n = max(int(tree.n_points), 1)
    return float(margin * math.sqrt(max(k, 1) * area / (math.pi * n)))


def knn_brute(points: np.ndarray, centers: np.ndarray, k: int
              ) -> tuple[np.ndarray, np.ndarray]:
    """Brute-force oracle: all-pairs f32 distances → ``(d2 [B, k],
    ids [B, k])`` ascending. The arithmetic (dx·dx + dy·dy in f32) is
    evaluated through jnp so XLA applies the identical FMA contraction
    it applies on the serving path — a numpy evaluation of the same
    expression differs by 1 ulp wherever XLA fuses the multiply-add.
    Distances then compare bit-exactly; ids are compared only where
    distances are distinct.
    """
    pts = jnp.asarray(np.asarray(points, np.float32))
    c = jnp.asarray(np.asarray(centers, np.float32))
    kk = min(k, pts.shape[0])

    @functools.partial(jax.jit, static_argnames=("n",))
    def _topk(pts, c, n):
        dx = pts[None, :, 0] - c[:, None, 0]
        dy = pts[None, :, 1] - c[:, None, 1]
        d2 = dx * dx + dy * dy
        return jax.lax.top_k(-d2, n)

    neg, idx = _topk(pts, c, kk)
    out_d2 = np.asarray(-neg)
    idx = np.asarray(idx)
    if kk < k:
        pad = ((0, 0), (0, k - kk))
        out_d2 = np.pad(out_d2, pad, constant_values=np.inf)
        idx = np.pad(idx, pad, constant_values=-1)
    return out_d2, idx.astype(np.int64)
