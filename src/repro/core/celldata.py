"""Per-grid-cell training-set assembly (paper §III-B).

Every query is assigned to each grid cell it overlaps; each non-empty cell
gets its own training set whose label space is *cell-local*: the union of
true leaf IDs seen by that cell's queries. Cell-local labels keep the
classifier heads small (the paper's per-cell decision trees have the same
property implicitly) and map back to global DFS leaf IDs via ``label_map``.

All outputs are padded, stacked arrays ready for expert-parallel training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.grid import Grid, bucket_queries_by_cell
from repro.core.labels import Workload


@dataclasses.dataclass
class CellDataset:
    grid: Grid
    feats: np.ndarray       # [C, Qp, F] f32 — per-cell padded query features
    labels: np.ndarray      # [C, Qp, Cl] f32 — cell-local multi-hot targets
    qmask: np.ndarray       # [C, Qp] bool — query-slot validity
    lmask: np.ndarray       # [C, Cl] bool — label-slot validity
    label_map: np.ndarray   # [C, Cl] i32 — cell-local → global leaf id (-1 pad)
    n_cells_used: int       # non-empty cells (models actually trained)
    label_overflow: np.ndarray  # [C] bool — label space exceeded Cl
    query_overflow: np.ndarray  # [C] bool — query count exceeded Qp

    @property
    def n_cells(self) -> int:
        return self.feats.shape[0]

    @property
    def max_labels(self) -> int:
        return self.labels.shape[-1]


def query_features(queries: np.ndarray) -> np.ndarray:
    """Feature representation (§III-A5): the raw query rectangle. The model
    may normalize internally; the input interface stays the rectangle."""
    return np.asarray(queries, dtype=np.float32)


def build_cell_datasets(grid: Grid, workload: Workload, *,
                        max_cells_per_query: int = 4,
                        max_labels: Optional[int] = None,
                        max_queries: Optional[int] = None) -> CellDataset:
    """Assemble per-cell padded training sets from a labelled workload."""
    ids, valid, _ = bucket_queries_by_cell(
        grid, workload.queries, max_cells_per_query)
    C = grid.n_cells
    per_cell_q: list[list[int]] = [[] for _ in range(C)]
    for qi in range(workload.n_queries):
        for s in range(max_cells_per_query):
            if valid[qi, s]:
                per_cell_q[int(ids[qi, s])].append(qi)

    # label spaces
    true_rows = [np.flatnonzero(workload.true_labels[qi])
                 for qi in range(workload.n_queries)]
    cell_labels: list[np.ndarray] = []
    for c in range(C):
        if per_cell_q[c]:
            u = np.unique(np.concatenate(
                [true_rows[qi] for qi in per_cell_q[c]] or [np.empty(0, np.int64)]))
        else:
            u = np.empty(0, np.int64)
        cell_labels.append(u)

    Cl = max_labels or max(8, max((len(u) for u in cell_labels), default=8))
    Qp = max_queries or max(8, max((len(q) for q in per_cell_q), default=8))

    feats = np.zeros((C, Qp, 4), np.float32)
    labels = np.zeros((C, Qp, Cl), np.float32)
    qmask = np.zeros((C, Qp), bool)
    lmask = np.zeros((C, Cl), bool)
    label_map = np.full((C, Cl), -1, np.int32)
    l_over = np.zeros((C,), bool)
    q_over = np.zeros((C,), bool)
    fx = query_features(workload.queries)
    used = 0
    for c in range(C):
        qs = per_cell_q[c]
        if not qs:
            continue
        used += 1
        u = cell_labels[c]
        if len(u) > Cl:
            l_over[c] = True
            u = u[:Cl]
        if len(qs) > Qp:
            q_over[c] = True
            qs = qs[:Qp]
        pos = {g: i for i, g in enumerate(u)}
        label_map[c, :len(u)] = u
        lmask[c, :len(u)] = True
        for slot, qi in enumerate(qs):
            feats[c, slot] = fx[qi]
            qmask[c, slot] = True
            for g in true_rows[qi]:
                if g in pos:
                    labels[c, slot, pos[g]] = 1.0
    return CellDataset(
        grid=grid, feats=feats, labels=labels, qmask=qmask, lmask=lmask,
        label_map=label_map, n_cells_used=used, label_overflow=l_over,
        query_overflow=q_over)
