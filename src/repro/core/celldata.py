"""Per-grid-cell training-set assembly (paper §III-B).

Every query is assigned to each grid cell it overlaps; each non-empty cell
gets its own training set whose label space is *cell-local*: the union of
true leaf IDs seen by that cell's queries. Cell-local labels keep the
classifier heads small (the paper's per-cell decision trees have the same
property implicitly) and map back to global DFS leaf IDs via ``label_map``.

All outputs are padded, stacked arrays ready for expert-parallel training.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.grid import Grid, bucket_queries_by_cell
from repro.core.labels import Workload


@dataclasses.dataclass
class CellDataset:
    grid: Grid
    feats: np.ndarray       # [C, Qp, F] f32 — per-cell padded query features
    labels: np.ndarray      # [C, Qp, Cl] f32 — cell-local multi-hot targets
    qmask: np.ndarray       # [C, Qp] bool — query-slot validity
    lmask: np.ndarray       # [C, Cl] bool — label-slot validity
    label_map: np.ndarray   # [C, Cl] i32 — cell-local → global leaf id (-1 pad)
    n_cells_used: int       # non-empty cells (models actually trained)
    label_overflow: np.ndarray  # [C] bool — label space exceeded Cl
    query_overflow: np.ndarray  # [C] bool — query count exceeded Qp

    @property
    def n_cells(self) -> int:
        return self.feats.shape[0]

    @property
    def max_labels(self) -> int:
        return self.labels.shape[-1]


def query_features(queries: np.ndarray) -> np.ndarray:
    """Feature representation (§III-A5): the raw query rectangle. The model
    may normalize internally; the input interface stays the rectangle."""
    return np.asarray(queries, dtype=np.float32)


def bucket_cell_queries(grid: Grid, queries: np.ndarray,
                        max_cells_per_query: int) -> list[list[int]]:
    """Per-cell training-query index lists, in ascending query order — the
    canonical row order of every cell's dataset (full build and subset
    rebuild alike, so a rebuilt row block is positionally identical)."""
    ids, valid, _ = bucket_queries_by_cell(grid, queries, max_cells_per_query)
    per_cell_q: list[list[int]] = [[] for _ in range(grid.n_cells)]
    for qi in range(queries.shape[0]):
        for s in range(max_cells_per_query):
            if valid[qi, s]:
                per_cell_q[int(ids[qi, s])].append(qi)
    return per_cell_q


def cell_label_space(per_cell_q: list[int],
                     true_rows: list[np.ndarray]) -> np.ndarray:
    """A cell's local label space: sorted unique global leaf ids over its
    queries' true sets (paper §III-B, cell-local heads)."""
    if per_cell_q:
        return np.unique(np.concatenate(
            [true_rows[qi] for qi in per_cell_q]))
    return np.empty(0, np.int64)


def _assemble_cells(grid: Grid, queries: np.ndarray,
                    true_rows: list[np.ndarray], cells: np.ndarray,
                    Cl: int, Qp: int, *,
                    per_cell_q: list[list[int]]) -> CellDataset:
    """Shared assembly core: padded rows for the listed cells only.

    Row ``i`` of every output array belongs to global cell ``cells[i]``.
    A cell's rows depend on nothing but its own query list, their labels,
    and the (Cl, Qp) pads — so assembling a subset is bit-identical to
    slicing those cells out of the full assembly with the same pads. The
    incremental refit pipeline (``build.refit_cells``) leans on exactly
    this property.
    """
    n = len(cells)
    feats = np.zeros((n, Qp, 4), np.float32)
    labels = np.zeros((n, Qp, Cl), np.float32)
    qmask = np.zeros((n, Qp), bool)
    lmask = np.zeros((n, Cl), bool)
    label_map = np.full((n, Cl), -1, np.int32)
    l_over = np.zeros((n,), bool)
    q_over = np.zeros((n,), bool)
    fx = query_features(queries)
    used = 0
    for i, c in enumerate(cells):
        qs = per_cell_q[int(c)]
        if not qs:
            continue
        used += 1
        u = cell_label_space(qs, true_rows)
        if len(u) > Cl:
            l_over[i] = True
            u = u[:Cl]
        if len(qs) > Qp:
            q_over[i] = True
            qs = qs[:Qp]
        pos = {g: j for j, g in enumerate(u)}
        label_map[i, :len(u)] = u
        lmask[i, :len(u)] = True
        for slot, qi in enumerate(qs):
            feats[i, slot] = fx[qi]
            qmask[i, slot] = True
            for g in true_rows[qi]:
                if g in pos:
                    labels[i, slot, pos[g]] = 1.0
    return CellDataset(
        grid=grid, feats=feats, labels=labels, qmask=qmask, lmask=lmask,
        label_map=label_map, n_cells_used=used, label_overflow=l_over,
        query_overflow=q_over)


def workload_true_rows(workload: Workload) -> list[np.ndarray]:
    """[Q] per-query global true-leaf id arrays (multi-hot → index form)."""
    return [np.flatnonzero(workload.true_labels[qi])
            for qi in range(workload.n_queries)]


def build_cell_datasets(grid: Grid, workload: Workload, *,
                        max_cells_per_query: int = 4,
                        max_labels: Optional[int] = None,
                        max_queries: Optional[int] = None) -> CellDataset:
    """Assemble per-cell padded training sets from a labelled workload."""
    per_cell_q = bucket_cell_queries(grid, workload.queries,
                                     max_cells_per_query)
    true_rows = workload_true_rows(workload)
    Cl = max_labels or max(8, max(
        (len(cell_label_space(q, true_rows)) for q in per_cell_q),
        default=8))
    Qp = max_queries or max(8, max((len(q) for q in per_cell_q), default=8))
    return _assemble_cells(grid, workload.queries, true_rows,
                           np.arange(grid.n_cells), Cl, Qp,
                           per_cell_q=per_cell_q)


def build_cell_subset(grid: Grid, queries: np.ndarray,
                      true_rows: list[np.ndarray], cells: np.ndarray, *,
                      max_cells_per_query: int, max_labels: int,
                      max_queries: int) -> CellDataset:
    """Rebuild just the listed cells' datasets against (possibly fresh)
    ``true_rows``, with the pad shapes pinned to the deployed bank's —
    the data side of ``build.refit_cells``. Row ``i`` ↔ ``cells[i]``."""
    per_cell_q = bucket_cell_queries(grid, queries, max_cells_per_query)
    return _assemble_cells(grid, queries, true_rows,
                           np.asarray(cells, np.int64), max_labels,
                           max_queries, per_cell_q=per_cell_q)
