"""End-to-end "AI+R"-tree construction for a (data, query) workload.

Implements the paper's training protocol:
  * execute the workload on the R-tree to collect (visited, true) labels;
  * hill-climb the grid size (2×2 → max, §III-B / §V-B3) until the cell
    models reach the best exact fit on the training workload;
  * train the binary router on an 80/20 split (§V-C2);
  * assemble the hybrid structure.

The build is **cell-granular end to end**: bucketing, label-space
construction, training (``mlp.train_cells`` / per-cell memorization) and
certification (``cell_fit_flags``) are all per-cell computations with no
cross-cell coupling. ``fit_airtree`` therefore emits a ``FitState``
alongside the tree, and ``refit_cells`` replays the identical pipeline on
just the cells whose leaf span changed (``core.spans``) — relabel →
retrain → splice → re-certify — producing, by construction, bit-identical
bank rows and fit flags to a from-scratch ``fit_airtree`` on the new tree
(property-tested; the router is the one component refit leaves alone).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core import celldata, grid as gridlib, labels
from repro.core import spans as spanslib
from repro.core.aitree import make_aitree, update_bank_cells
from repro.core.classifiers import forest as forestlib
from repro.core.classifiers import mlp as mlplib
from repro.core.classifiers.router import train_router, RouterReport
from repro.core.device_tree import DeviceTree
from repro.core.hybrid import HybridTree


@dataclasses.dataclass
class BuildReport:
    grid_sizes_tried: list
    grid_size: int
    exact_fit: float
    classifier_kind: str
    cells_trained: int
    model_bytes: int
    router_bytes: int
    router: RouterReport
    train_seconds: float
    # Per-cell exact-fit flags of the winning grid ([C] bool): cell c is
    # flagged iff ≥ 1 training query touched it and every touching query
    # was answered exactly. Wired into ``AITree.cell_ok`` so serving can
    # guard sub-1.0-fit cells off the AI path (the under-prediction
    # blind-spot fix); the freshness monitor ANDs its staleness on top.
    cell_fit: Optional[np.ndarray] = None
    # Everything ``refit_cells`` needs to continue this build incrementally
    # (training rows, certificates, spans of the fitted tree, pinned pads).
    fit_state: Optional["FitState"] = None


@dataclasses.dataclass
class FitState:
    """The resumable state of a cell-granular build.

    Host-side, append-free: ``refit_cells`` threads it functionally —
    each call returns an updated copy whose certificates (``exact`` /
    ``exact_valid``) and span snapshot describe the *current* tree, so
    chunked refits (a few cells per serve segment) converge to exactly
    the full-fit state regardless of chunk order.
    """
    queries: np.ndarray           # [Q, 4] f32 training queries (fixed)
    true_rows: list               # [Q] np.int64 arrays — true leaf ids,
    #                               kept current under remap/relabel
    exact: np.ndarray             # [Q] bool — AI path answered exactly
    exact_valid: np.ndarray       # [Q] bool — certificate is current;
    #                               False while any touched cell is stale
    cell_ids: np.ndarray          # [Q, S] i32 bucketing on the fit grid
    cell_valid: np.ndarray        # [Q, S] bool
    overflow: np.ndarray          # [Q] bool — cell-window overflow
    qp: int                       # pinned query pad of the deployed bank
    cl: int                       # pinned label pad of the deployed bank
    spans: list                   # [C] frozensets — cell spans of the
    sigs: list                    # [L] bytes    —  certified tree
    cell_stale: np.ndarray        # [C] bool — span changed, not yet refit
    kind: str
    mlp_hidden: int
    mlp_epochs: int
    target_fit: float
    seed: int
    label_kwargs: dict            # make_workload kwargs for relabelling

    @property
    def n_cells(self) -> int:
        return len(self.spans)

    def exact_fit(self) -> float:
        """Aggregate certified exact fit (uncertified rows count as 0)."""
        return float((self.exact & self.exact_valid).mean())


@dataclasses.dataclass
class RefitReport:
    cells_changed: int        # span-diff invalidations seen this call
    cells_refit: int          # cells actually retrained + respliced
    cells_stale_left: int     # still-stale cells (chunked refit backlog)
    n_relabeled: int          # queries re-run on the R path for labels
    n_recertified: int        # queries whose exactness was re-evaluated
    exact_fit: float          # aggregate certified fit after this call
    train_epochs: int
    train_seconds: float


def _eval_exact_fit(ait, dtree: DeviceTree, wl: labels.Workload,
                    batch: int = 256) -> tuple[float, np.ndarray]:
    """Fraction of workload queries the AI path answers without fallback AND
    with exactly the true leaf set accessed, plus the per-query exactness
    vector ([Q] bool) the per-cell fit flags are derived from."""
    import jax.numpy as jnp
    from repro.core.aitree import ai_query
    exact = np.zeros((wl.n_queries,), bool)
    Q = wl.n_queries
    for o in range(0, Q, batch):
        q = wl.queries[o:o + batch]
        pad = batch - q.shape[0]
        if pad:
            q = np.concatenate([q, np.tile(q[-1:], (pad, 1))])
        res = ai_query(ait, dtree, jnp.asarray(q))
        take = batch - pad
        pred = np.asarray(res.pred_mask)[:take]
        fb = np.asarray(res.fallback)[:take]
        tgt = wl.true_labels[o:o + take]
        exact[o:o + take] = ~fb & np.all(pred == tgt, axis=1)
    return float(exact.mean()), exact


def _eval_exact_rows(ait, dtree: DeviceTree, queries: np.ndarray,
                     true_rows: list, batch: int = 256) -> np.ndarray:
    """Per-query exactness against index-form labels (refit-path twin of
    ``_eval_exact_fit``; same ai_query → pred_mask comparison)."""
    Q = queries.shape[0]
    tgt = np.zeros((Q, dtree.n_leaves), bool)
    for qi, rows in enumerate(true_rows):
        tgt[qi, rows] = True
    exact = np.zeros((Q,), bool)
    import jax.numpy as jnp
    from repro.core.aitree import ai_query
    for o in range(0, Q, batch):
        q = queries[o:o + batch]
        pad = batch - q.shape[0]
        if pad:
            q = np.concatenate([q, np.tile(q[-1:], (pad, 1))])
        res = ai_query(ait, dtree, jnp.asarray(q))
        take = batch - pad
        pred = np.asarray(res.pred_mask)[:take]
        fb = np.asarray(res.fallback)[:take]
        exact[o:o + take] = ~fb & np.all(pred == tgt[o:o + take], axis=1)
    return exact


def cell_fit_flags(grid, queries: np.ndarray, exact: np.ndarray,
                   max_cells: int, n_cells: int) -> np.ndarray:
    """Per-cell exact-fit flags: [C] bool from per-query exactness.

    A cell is serve-eligible iff at least one training query touched it
    and *every* touching query was exact — an untouched cell's model saw
    no data (its predictions are no better than noise) and a cell with
    any inexact query can silently under-predict, so both are guarded.
    Overflowed queries (wider than the static cell window) touch no valid
    cell and so constrain nothing — they always fall back at serving too.
    """
    ids, valid, _ = gridlib.bucket_queries_by_cell(grid, queries, max_cells)
    touched = np.zeros((n_cells,), bool)
    bad = np.zeros((n_cells,), bool)
    touched[ids[valid]] = True
    bad[ids[valid & ~exact[:, None]]] = True
    return touched & ~bad


def eval_cell_fit(ait, dtree: DeviceTree, wl: labels.Workload,
                  batch: int = 256) -> tuple[float, np.ndarray, np.ndarray]:
    """Public fit evaluation: ``(exact_fit, exact [Q] bool, cell_ok [C]
    bool)`` for an assembled AI-tree — what ``fit_airtree`` installs, and
    what a refit after drift/repack recomputes (see ``core.monitor``)."""
    from repro.core.aitree import bank_n_cells
    fit, exact = _eval_exact_fit(ait, dtree, wl, batch=batch)
    cell_ok = cell_fit_flags(ait.grid, wl.queries, exact, ait.max_cells,
                             bank_n_cells(ait.bank))
    return fit, exact, cell_ok


def fit_airtree(dtree: DeviceTree, workload: labels.Workload, *,
                kind: str = "mlp", tau: float = 0.75,
                grid_sizes: Sequence[int] = (2, 4, 6, 8, 10, 14, 20),
                max_cells: int = 4, max_pred: int = 64,
                target_fit: float = 1.0, mlp_hidden: int = 64,
                mlp_epochs: int = 3000, forest_trees: int = 1,
                forest_depth: int = 8, seed: int = 0,
                max_labels: Optional[int] = None,
                max_queries: Optional[int] = None,
                router_workload: Optional[labels.Workload] = None,
                label_kwargs: Optional[dict] = None,
                verbose: bool = False) -> tuple[HybridTree, BuildReport]:
    """Full build. ``max_labels``/``max_queries`` pin the per-cell pads
    (default: tight to this workload) — a refit world and a from-scratch
    world compare bit-identically only under equal pads.
    ``label_kwargs`` records the ``make_workload`` settings the caller
    labelled ``workload`` with, so ``refit_cells`` relabels identically.
    """
    t0 = time.time()
    best = None  # (fit, g, ait, bytes, cells, exact, ds)
    tried = []
    for g in grid_sizes:
        gr = gridlib.fit_grid(workload.queries, g)
        ds = celldata.build_cell_datasets(gr, workload,
                                          max_cells_per_query=max_cells,
                                          max_labels=max_labels,
                                          max_queries=max_queries)
        if kind == "mlp":
            bank, rep = mlplib.train_bank(
                ds, hidden=mlp_hidden, max_epochs=mlp_epochs,
                target_fit=target_fit, seed=seed)
        elif kind == "knn":
            from repro.core.classifiers import knn as knnlib
            bank = knnlib.fit_knn(ds)
        else:
            bank = forestlib.fit_forest(
                ds.feats, ds.labels, ds.qmask, ds.label_map, ds.lmask,
                n_trees=forest_trees, depth=forest_depth, seed=seed)
        nbytes = bank.byte_size()
        ait = make_aitree(gr, bank, max_cells=max_cells, max_pred=max_pred)
        fit, exact = _eval_exact_fit(ait, dtree, workload)
        tried.append((g, round(fit, 4)))
        if verbose:
            print(f"  grid {g}x{g}: exact-fit {fit:.4f} "
                  f"({ds.n_cells_used} cells, {nbytes/1e6:.2f} MB)")
        if best is None or fit > best[0]:
            best = (fit, g, ait, nbytes, ds.n_cells_used, exact, ds)
        if fit >= target_fit:
            break
    fit, g, ait, nbytes, cells, exact, ds = best
    # wire the winning grid's per-cell fit into the serving guard: cells
    # whose training queries were not all exact (or that saw no training
    # query) must not reach the ungated AI path — a sub-1.0 fit deployed
    # without this silently drops results (the under-prediction blind spot)
    import jax.numpy as jnp
    from repro.core.aitree import bank_n_cells
    n_cells = bank_n_cells(ait.bank)
    cell_ok = cell_fit_flags(ait.grid, workload.queries, exact, max_cells,
                             n_cells)
    ait = dataclasses.replace(ait, cell_ok=jnp.asarray(cell_ok))

    # §V-C2: the router is trained to GENERALIZE over the combined-α workload
    rwl = router_workload if router_workload is not None else workload
    router, rrep = train_router(rwl.queries, rwl.alpha, tau=tau, seed=seed)
    hybrid = HybridTree(tree=dtree, ait=ait, router=router)

    ids, valid, overflow = gridlib.bucket_queries_by_cell(
        ait.grid, workload.queries, max_cells)
    sigs = spanslib.leaf_signatures(dtree)
    state = FitState(
        queries=np.asarray(workload.queries, np.float32).copy(),
        true_rows=celldata.workload_true_rows(workload),
        exact=exact.copy(),
        exact_valid=np.ones_like(exact),
        cell_ids=ids, cell_valid=valid, overflow=overflow,
        qp=int(ds.feats.shape[1]), cl=int(ds.max_labels),
        spans=spanslib.cell_spans(dtree, ait.grid, sigs=sigs),
        sigs=sigs,
        cell_stale=np.zeros((n_cells,), bool),
        kind=kind, mlp_hidden=mlp_hidden, mlp_epochs=mlp_epochs,
        target_fit=target_fit, seed=seed,
        label_kwargs=dict(label_kwargs or {}))
    report = BuildReport(
        grid_sizes_tried=tried, grid_size=g, exact_fit=fit,
        classifier_kind=kind, cells_trained=cells, model_bytes=nbytes,
        router_bytes=router.byte_size(), router=rrep,
        train_seconds=time.time() - t0, cell_fit=cell_ok, fit_state=state)
    return hybrid, report


def refit_cells(hybrid: HybridTree, state: FitState,
                cells: Optional[np.ndarray] = None, *, batch: int = 256,
                label_kwargs: Optional[dict] = None, verbose: bool = False
                ) -> tuple[HybridTree, FitState, RefitReport]:
    """Incrementally re-optimize the AI side against ``hybrid.tree``.

    The online continuation of ``fit_airtree``: spans of the (possibly
    repacked) tree are diffed against the certified snapshot in ``state``;
    cells whose span moved are stale. This call relabels the stale-chunk
    queries on the R path, retrains just the chunk's cells (same per-cell
    pipeline, pinned pads), splices the rows into the live bank
    (``update_bank_cells``), re-certifies every query whose touched cells
    are all current again, and recomputes the serving guard. Bit-identical
    to a from-scratch ``fit_airtree`` on the new tree for the retrained
    cells — labels, bank rows, ``cell_ok`` — with the router deliberately
    left as fit (it generalizes over α; drift there is the monitor's
    demote/promote policy's business, not refit's).

    ``cells`` defaults to *all* stale cells; pass a subset to spread the
    work over serve segments (chunked refit) — certificates of queries
    still touching a stale cell stay invalid and those cells stay guarded
    until a later call retrains them. Cells in ``cells`` that are not
    stale are retrained too (forced refit — the policy's promote lever).

    Returns ``(hybrid', state', report)``; all inputs are left untouched
    (functional update).
    """
    if state.kind not in ("mlp", "knn"):
        raise NotImplementedError(
            f"refit_cells: kind={state.kind!r} has no per-cell splice "
            "(forest banks retrain whole via fit_airtree)")
    t0 = time.time()
    import jax.numpy as jnp
    from repro.core.aitree import bank_n_cells

    dtree = hybrid.tree
    ait = hybrid.ait
    bank = ait.bank
    C = bank_n_cells(bank)
    new_sigs = spanslib.leaf_signatures(dtree)
    new_spans = spanslib.cell_spans(dtree, ait.grid, sigs=new_sigs)
    changed, remap = spanslib.diff_spans(state.spans, new_spans,
                                         state.sigs, new_sigs)
    stale = state.cell_stale | changed
    if cells is None:
        cells = np.flatnonzero(stale)
    cells = np.unique(np.asarray(cells, np.int64))
    in_chunk = np.zeros((C,), bool)
    in_chunk[cells] = True

    ids, valid = state.cell_ids, state.cell_valid

    def touch(cell_mask: np.ndarray) -> np.ndarray:
        """[Q] bool — queries with a valid slot on any flagged cell."""
        return (valid & cell_mask[ids]).any(axis=1)

    # -- 1. carry surviving leaf ids across the tree change ----------------
    exact = state.exact.copy()
    exact_valid = state.exact_valid.copy()
    true_rows = list(state.true_rows)
    if state.sigs != new_sigs:
        # rename global leaf ids everywhere they are stored: the bank's
        # label maps (unchanged cells keep serving, exactly renamed) and
        # the cached per-query label rows
        lm, lmk = spanslib.remap_label_map(
            np.asarray(bank.label_map), np.asarray(bank.lmask), remap)
        bank = dataclasses.replace(bank, label_map=jnp.asarray(lm),
                                   lmask=jnp.asarray(lmk))
        for qi, rows in enumerate(true_rows):
            if rows.size:
                r = remap[rows]
                if (r < 0).any():
                    # a true leaf vanished ⇒ some touched cell's span
                    # changed (dilation argument) ⇒ the query is relabeled
                    # when that cell refits; until then: uncertified
                    exact_valid[qi] = False
                    r = r[r >= 0]
                true_rows[qi] = np.sort(r).astype(np.int64)
        exact_valid[touch(changed)] = False
    # any query seeing a stale cell is uncertified until that cell refits
    exact_valid[touch(stale)] = False

    # -- 2. relabel the chunk's queries against the new tree ---------------
    relabel = np.flatnonzero(touch(in_chunk))
    if relabel.size:
        lkw = dict(state.label_kwargs)
        lkw.update(label_kwargs or {})
        sub_wl = labels.make_workload(dtree, state.queries[relabel], **lkw)
        for j, qi in enumerate(celldata.workload_true_rows(sub_wl)):
            true_rows[int(relabel[j])] = qi

    # -- 3. rebuild + retrain just the chunk, splice into the live bank ----
    epochs = 0
    if cells.size:
        sub = celldata.build_cell_subset(
            ait.grid, state.queries, true_rows, cells,
            max_cells_per_query=ait.max_cells, max_labels=state.cl,
            max_queries=state.qp)
        if state.kind == "mlp":
            mu, sd = mlplib.grid_norm(ait.grid)
            params, trep = mlplib.train_cells(
                sub.feats, sub.labels, sub.qmask, sub.lmask, mu, sd, cells,
                hidden=state.mlp_hidden, max_epochs=state.mlp_epochs,
                target_fit=state.target_fit, seed=state.seed)
            epochs = trep.epochs
            bank = update_bank_cells(
                bank, cells, w1=params["w1"], b1=params["b1"],
                w2=params["w2"], b2=params["b2"],
                label_map=sub.label_map, lmask=sub.lmask)
        else:
            from repro.core.classifiers import knn as knnlib
            sub_bank = knnlib.fit_knn(sub, eps=float(bank.eps))
            bank = update_bank_cells(
                bank, cells, feats=sub_bank.feats, labels=sub_bank.labels,
                label_map=sub_bank.label_map, lmask=sub_bank.lmask)
        if verbose:
            print(f"  refit {cells.size} cells ({relabel.size} queries "
                  f"relabeled, {epochs} epochs)")
    post_stale = stale & ~in_chunk

    # -- 4. re-certify queries whose world is current again ----------------
    ait = dataclasses.replace(ait, bank=bank)
    recert = np.flatnonzero(touch(in_chunk) & ~touch(post_stale))
    if recert.size:
        exact[recert] = _eval_exact_rows(
            ait, dtree, state.queries[recert],
            [true_rows[int(qi)] for qi in recert], batch=batch)
        exact_valid[recert] = True

    # -- 5. recompute the serving guard from the refreshed certificates ----
    q_ok = exact & exact_valid
    touched = np.zeros((C,), bool)
    bad = np.zeros((C,), bool)
    touched[ids[valid]] = True
    bad[ids[valid & ~q_ok[:, None]]] = True
    cell_ok = touched & ~bad & ~post_stale
    ait = dataclasses.replace(ait, cell_ok=jnp.asarray(cell_ok))

    state = dataclasses.replace(
        state, true_rows=true_rows, exact=exact, exact_valid=exact_valid,
        spans=new_spans, sigs=new_sigs, cell_stale=post_stale)
    report = RefitReport(
        cells_changed=int(changed.sum()), cells_refit=int(cells.size),
        cells_stale_left=int(post_stale.sum()),
        n_relabeled=int(relabel.size), n_recertified=int(recert.size),
        exact_fit=state.exact_fit(), train_epochs=epochs,
        train_seconds=time.time() - t0)
    return dataclasses.replace(hybrid, ait=ait), state, report
