"""End-to-end "AI+R"-tree construction for a (data, query) workload.

Implements the paper's training protocol:
  * execute the workload on the R-tree to collect (visited, true) labels;
  * hill-climb the grid size (2×2 → max, §III-B / §V-B3) until the cell
    models reach the best exact fit on the training workload;
  * train the binary router on an 80/20 split (§V-C2);
  * assemble the hybrid structure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core import celldata, grid as gridlib, labels
from repro.core.aitree import make_aitree
from repro.core.classifiers import forest as forestlib
from repro.core.classifiers import mlp as mlplib
from repro.core.classifiers.router import train_router, RouterReport
from repro.core.device_tree import DeviceTree
from repro.core.hybrid import HybridTree


@dataclasses.dataclass
class BuildReport:
    grid_sizes_tried: list
    grid_size: int
    exact_fit: float
    classifier_kind: str
    cells_trained: int
    model_bytes: int
    router_bytes: int
    router: RouterReport
    train_seconds: float


def _eval_exact_fit(ait, dtree: DeviceTree, wl: labels.Workload,
                    batch: int = 256) -> float:
    """Fraction of workload queries the AI path answers without fallback AND
    with exactly the true leaf set accessed."""
    import jax.numpy as jnp
    from repro.core.aitree import ai_query
    ok = 0
    Q = wl.n_queries
    for o in range(0, Q, batch):
        q = wl.queries[o:o + batch]
        pad = batch - q.shape[0]
        if pad:
            q = np.concatenate([q, np.tile(q[-1:], (pad, 1))])
        res = ai_query(ait, dtree, jnp.asarray(q))
        take = batch - pad
        pred = np.asarray(res.pred_mask)[:take]
        fb = np.asarray(res.fallback)[:take]
        tgt = wl.true_labels[o:o + take]
        ok += int(np.sum(~fb & np.all(pred == tgt, axis=1)))
    return ok / Q


def fit_airtree(dtree: DeviceTree, workload: labels.Workload, *,
                kind: str = "mlp", tau: float = 0.75,
                grid_sizes: Sequence[int] = (2, 4, 6, 8, 10, 14, 20),
                max_cells: int = 4, max_pred: int = 64,
                target_fit: float = 1.0, mlp_hidden: int = 64,
                mlp_epochs: int = 3000, forest_trees: int = 1,
                forest_depth: int = 8, seed: int = 0,
                router_workload: Optional[labels.Workload] = None,
                verbose: bool = False) -> tuple[HybridTree, BuildReport]:
    t0 = time.time()
    best = None  # (fit, g, ait, bytes, cells)
    tried = []
    for g in grid_sizes:
        gr = gridlib.fit_grid(workload.queries, g)
        ds = celldata.build_cell_datasets(gr, workload,
                                          max_cells_per_query=max_cells)
        if kind == "mlp":
            bank, rep = mlplib.train_bank(
                ds, hidden=mlp_hidden, max_epochs=mlp_epochs,
                target_fit=target_fit, seed=seed)
        elif kind == "knn":
            from repro.core.classifiers import knn as knnlib
            bank = knnlib.fit_knn(ds)
        else:
            bank = forestlib.fit_forest(
                ds.feats, ds.labels, ds.qmask, ds.label_map, ds.lmask,
                n_trees=forest_trees, depth=forest_depth, seed=seed)
        nbytes = bank.byte_size()
        ait = make_aitree(gr, bank, max_cells=max_cells, max_pred=max_pred)
        fit = _eval_exact_fit(ait, dtree, workload)
        tried.append((g, round(fit, 4)))
        if verbose:
            print(f"  grid {g}x{g}: exact-fit {fit:.4f} "
                  f"({ds.n_cells_used} cells, {nbytes/1e6:.2f} MB)")
        if best is None or fit > best[0]:
            best = (fit, g, ait, nbytes, ds.n_cells_used)
        if fit >= target_fit:
            break
    fit, g, ait, nbytes, cells = best

    # §V-C2: the router is trained to GENERALIZE over the combined-α workload
    rwl = router_workload if router_workload is not None else workload
    router, rrep = train_router(rwl.queries, rwl.alpha, tau=tau, seed=seed)
    hybrid = HybridTree(tree=dtree, ait=ait, router=router)
    report = BuildReport(
        grid_sizes_tried=tried, grid_size=g, exact_fit=fit,
        classifier_kind=kind, cells_trained=cells, model_bytes=nbytes,
        router_bytes=router.byte_size(), router=rrep,
        train_seconds=time.time() - t0)
    return hybrid, report
