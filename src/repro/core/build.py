"""End-to-end "AI+R"-tree construction for a (data, query) workload.

Implements the paper's training protocol:
  * execute the workload on the R-tree to collect (visited, true) labels;
  * hill-climb the grid size (2×2 → max, §III-B / §V-B3) until the cell
    models reach the best exact fit on the training workload;
  * train the binary router on an 80/20 split (§V-C2);
  * assemble the hybrid structure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core import celldata, grid as gridlib, labels
from repro.core.aitree import make_aitree
from repro.core.classifiers import forest as forestlib
from repro.core.classifiers import mlp as mlplib
from repro.core.classifiers.router import train_router, RouterReport
from repro.core.device_tree import DeviceTree
from repro.core.hybrid import HybridTree


@dataclasses.dataclass
class BuildReport:
    grid_sizes_tried: list
    grid_size: int
    exact_fit: float
    classifier_kind: str
    cells_trained: int
    model_bytes: int
    router_bytes: int
    router: RouterReport
    train_seconds: float
    # Per-cell exact-fit flags of the winning grid ([C] bool): cell c is
    # flagged iff ≥ 1 training query touched it and every touching query
    # was answered exactly. Wired into ``AITree.cell_ok`` so serving can
    # guard sub-1.0-fit cells off the AI path (the under-prediction
    # blind-spot fix); the freshness monitor ANDs its staleness on top.
    cell_fit: Optional[np.ndarray] = None


def _eval_exact_fit(ait, dtree: DeviceTree, wl: labels.Workload,
                    batch: int = 256) -> tuple[float, np.ndarray]:
    """Fraction of workload queries the AI path answers without fallback AND
    with exactly the true leaf set accessed, plus the per-query exactness
    vector ([Q] bool) the per-cell fit flags are derived from."""
    import jax.numpy as jnp
    from repro.core.aitree import ai_query
    exact = np.zeros((wl.n_queries,), bool)
    Q = wl.n_queries
    for o in range(0, Q, batch):
        q = wl.queries[o:o + batch]
        pad = batch - q.shape[0]
        if pad:
            q = np.concatenate([q, np.tile(q[-1:], (pad, 1))])
        res = ai_query(ait, dtree, jnp.asarray(q))
        take = batch - pad
        pred = np.asarray(res.pred_mask)[:take]
        fb = np.asarray(res.fallback)[:take]
        tgt = wl.true_labels[o:o + take]
        exact[o:o + take] = ~fb & np.all(pred == tgt, axis=1)
    return float(exact.mean()), exact


def cell_fit_flags(grid, queries: np.ndarray, exact: np.ndarray,
                   max_cells: int, n_cells: int) -> np.ndarray:
    """Per-cell exact-fit flags: [C] bool from per-query exactness.

    A cell is serve-eligible iff at least one training query touched it
    and *every* touching query was exact — an untouched cell's model saw
    no data (its predictions are no better than noise) and a cell with
    any inexact query can silently under-predict, so both are guarded.
    Overflowed queries (wider than the static cell window) touch no valid
    cell and so constrain nothing — they always fall back at serving too.
    """
    ids, valid, _ = gridlib.bucket_queries_by_cell(grid, queries, max_cells)
    touched = np.zeros((n_cells,), bool)
    bad = np.zeros((n_cells,), bool)
    touched[ids[valid]] = True
    bad[ids[valid & ~exact[:, None]]] = True
    return touched & ~bad


def eval_cell_fit(ait, dtree: DeviceTree, wl: labels.Workload,
                  batch: int = 256) -> tuple[float, np.ndarray, np.ndarray]:
    """Public fit evaluation: ``(exact_fit, exact [Q] bool, cell_ok [C]
    bool)`` for an assembled AI-tree — what ``fit_airtree`` installs, and
    what a refit after drift/repack recomputes (see ``core.monitor``)."""
    from repro.core.aitree import bank_n_cells
    fit, exact = _eval_exact_fit(ait, dtree, wl, batch=batch)
    cell_ok = cell_fit_flags(ait.grid, wl.queries, exact, ait.max_cells,
                             bank_n_cells(ait.bank))
    return fit, exact, cell_ok


def fit_airtree(dtree: DeviceTree, workload: labels.Workload, *,
                kind: str = "mlp", tau: float = 0.75,
                grid_sizes: Sequence[int] = (2, 4, 6, 8, 10, 14, 20),
                max_cells: int = 4, max_pred: int = 64,
                target_fit: float = 1.0, mlp_hidden: int = 64,
                mlp_epochs: int = 3000, forest_trees: int = 1,
                forest_depth: int = 8, seed: int = 0,
                router_workload: Optional[labels.Workload] = None,
                verbose: bool = False) -> tuple[HybridTree, BuildReport]:
    t0 = time.time()
    best = None  # (fit, g, ait, bytes, cells)
    tried = []
    for g in grid_sizes:
        gr = gridlib.fit_grid(workload.queries, g)
        ds = celldata.build_cell_datasets(gr, workload,
                                          max_cells_per_query=max_cells)
        if kind == "mlp":
            bank, rep = mlplib.train_bank(
                ds, hidden=mlp_hidden, max_epochs=mlp_epochs,
                target_fit=target_fit, seed=seed)
        elif kind == "knn":
            from repro.core.classifiers import knn as knnlib
            bank = knnlib.fit_knn(ds)
        else:
            bank = forestlib.fit_forest(
                ds.feats, ds.labels, ds.qmask, ds.label_map, ds.lmask,
                n_trees=forest_trees, depth=forest_depth, seed=seed)
        nbytes = bank.byte_size()
        ait = make_aitree(gr, bank, max_cells=max_cells, max_pred=max_pred)
        fit, exact = _eval_exact_fit(ait, dtree, workload)
        tried.append((g, round(fit, 4)))
        if verbose:
            print(f"  grid {g}x{g}: exact-fit {fit:.4f} "
                  f"({ds.n_cells_used} cells, {nbytes/1e6:.2f} MB)")
        if best is None or fit > best[0]:
            best = (fit, g, ait, nbytes, ds.n_cells_used, exact)
        if fit >= target_fit:
            break
    fit, g, ait, nbytes, cells, exact = best
    # wire the winning grid's per-cell fit into the serving guard: cells
    # whose training queries were not all exact (or that saw no training
    # query) must not reach the ungated AI path — a sub-1.0 fit deployed
    # without this silently drops results (the under-prediction blind spot)
    import jax.numpy as jnp
    from repro.core.aitree import bank_n_cells
    cell_ok = cell_fit_flags(ait.grid, workload.queries, exact, max_cells,
                             bank_n_cells(ait.bank))
    ait = dataclasses.replace(ait, cell_ok=jnp.asarray(cell_ok))

    # §V-C2: the router is trained to GENERALIZE over the combined-α workload
    rwl = router_workload if router_workload is not None else workload
    router, rrep = train_router(rwl.queries, rwl.alpha, tau=tau, seed=seed)
    hybrid = HybridTree(tree=dtree, ait=ait, router=router)
    report = BuildReport(
        grid_sizes_tried=tried, grid_size=g, exact_fit=fit,
        classifier_kind=kind, cells_trained=cells, model_bytes=nbytes,
        router_bytes=router.byte_size(), router=rrep,
        train_seconds=time.time() - t0, cell_fit=cell_ok)
    return hybrid, report
