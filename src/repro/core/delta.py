"""Device-side insert delta store: dynamic inserts without a rebuild.

The paper's structure is strictly static — the R-tree is bulk-built once
and the AI-tree is overfit to a fixed workload — so a single insert used
to mean a stop-the-world rebuild. This module absorbs inserts into a
fixed-capacity append-only point buffer that serves *alongside* the tree:

* ``stage_inserts`` appends points host-side (the buffer's device form is
  swapped between batches, never mutated under a jit'd step);
* every query batch probes the buffer (``probe`` → ``ops.delta_probe``,
  the Pallas kernel with the compact slot-table contract) and merges the
  hits into its results (``merge_hybrid_result``) — staged points are
  invisible to both the R and AI paths until then;
* ``repack`` merges the buffer into a fresh ``RTree.str_bulk`` →
  ``DeviceTree`` and returns an empty store, so the scheduler can swap
  the tree between batches (the online repack).

ID convention: the point staged into buffer slot ``j`` has global id
``base + j`` where ``base`` is the number of points already in the tree.
``repack`` appends the staged points to the base point array in slot
order, so ``RTree.str_bulk`` assigns exactly those ids — serving with a
populated buffer is bit-identical (result ids included) to serving a
from-scratch bulk load of the same points, which is the subsystem's
correctness anchor (property-tested in ``tests/test_delta.py``).

Unstaged capacity holds +inf coordinates: closed-rect containment fails
on them, so neither the kernel nor the oracle ever needs the staged
count to mask the buffer.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.device_tree import DeviceTree, flatten
from repro.core.rtree import RTree


@dataclasses.dataclass(frozen=True)
class DeltaStore:
    """Host-managed append-only insert buffer (functional updates).

    Not a jax pytree: the serve step takes ``xy`` (the device form)
    directly and the host fields drive staging/repack decisions.
    """
    capacity: int
    base: int            # global id of buffer slot 0 (= points in tree)
    n: int               # staged inserts
    xy: jnp.ndarray      # [capacity, 2] f32, +inf past ``n``

    @property
    def fill(self) -> float:
        return self.n / max(self.capacity, 1)


def make_delta(capacity: int, base: int = 0) -> DeltaStore:
    if capacity < 1:
        raise ValueError(f"delta capacity must be >= 1, got {capacity}")
    xy = jnp.full((capacity, 2), jnp.inf, jnp.float32)
    return DeltaStore(capacity=int(capacity), base=int(base), n=0, xy=xy)


def stage_inserts(store: DeltaStore, points: np.ndarray) -> DeltaStore:
    """Append ``points`` [m, 2]; the staged point ids continue the tree's
    numbering (``store.base + slot``). Raises when the buffer would
    overflow — callers repack before that (``FreshServer`` enforces it).
    """
    pts = np.asarray(points, np.float32).reshape(-1, 2)
    m = pts.shape[0]
    if m == 0:
        return store
    if store.n + m > store.capacity:
        raise ValueError(
            f"delta store overflow: {store.n} staged + {m} new > capacity "
            f"{store.capacity} — repack first")
    xy = np.asarray(store.xy).copy()
    xy[store.n:store.n + m] = pts
    return dataclasses.replace(store, n=store.n + m, xy=jnp.asarray(xy))


def staged_points(store: DeltaStore) -> np.ndarray:
    """The staged inserts as a host array [n, 2] f64 (builder dtype)."""
    return np.asarray(store.xy)[:store.n].astype(np.float64)


class DeltaHits(NamedTuple):
    """Per-query probe result over one batch."""
    slot_idx: jnp.ndarray   # [B, k] i32 buffer slots (insertion order)
    valid: jnp.ndarray      # [B, k] bool slot validity
    count: jnp.ndarray      # [B] i32 full hit total (exact past k)
    ids: jnp.ndarray        # [B, k] i32 global point ids, -1 invalid


def probe(store_xy: jnp.ndarray, queries: jnp.ndarray, *, k: int,
          base: int, use_kernel: bool = False) -> DeltaHits:
    """Probe the buffer for a query batch: [B, 4] → ``DeltaHits``.

    ``use_kernel`` routes through ``ops.delta_probe`` (compact slot table
    straight from VMEM, with its fallback ladder); the jnp oracle rung is
    bit-identical. ``count`` is the full per-row hit total, so result
    counts stay exact even when the slot table overflows ``k``.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        slot_idx, valid, count = kops.delta_probe(queries, store_xy, k=k)
    else:
        from repro.kernels import ref as kref
        slot_idx, valid, count = kref.delta_probe(queries, store_xy, k)
    ids = jnp.where(valid, base + slot_idx, -1)
    return DeltaHits(slot_idx=slot_idx, valid=valid, count=count, ids=ids)


def merge_hybrid_result(res, hits: DeltaHits):
    """Fold delta hits into a ``HybridResult``: counts add exactly, hit
    ids land in the result table's -1 padding (after the tree's ids, up
    to the table's own width), and rows whose merged ids no longer fit
    raise ``truncated`` so the scheduler's wide tier re-serves them.
    ``leaf_accesses`` is untouched — the probe is O(capacity) VPU work,
    not tree I/O (the paper's cost unit); the launch driver reports probe
    cost separately.
    """
    B, k = hits.ids.shape
    mr = res.result_ids.shape[1]
    pos = res.n_results[:, None] + jnp.arange(k, dtype=jnp.int32)[None, :]
    ok = hits.valid & (pos < mr)
    rows = jnp.arange(B, dtype=jnp.int32)[:, None]
    out = jnp.concatenate(
        [res.result_ids, jnp.full((B, 1), -1, jnp.int32)], axis=1)
    out = out.at[rows, jnp.where(ok, pos, mr)].set(
        jnp.where(ok, hits.ids, -1))
    over = (hits.count > k) | (res.n_results + hits.count > mr)
    return res._replace(
        n_results=res.n_results + hits.count,
        result_ids=out[:, :mr],
        truncated=res.truncated | over)


def repack(base_points: np.ndarray, store: DeltaStore, *,
           max_entries: int, min_entries: int | None = None,
           fill: float = 0.7
           ) -> Tuple[RTree, DeviceTree, np.ndarray, DeltaStore]:
    """Online repack: merge the buffer into a fresh ``str_bulk`` tree.

    Returns ``(host_tree, device_tree, all_points, empty_store)`` — the
    caller (the scheduler / ``FreshServer``) swaps the device tree in
    between batches and carries ``all_points`` as the next repack's base.
    Point ids are preserved: the staged points are appended to
    ``base_points`` in slot order, so the rebuilt tree numbers them
    exactly as the probe path already reported them.
    """
    pts = np.asarray(base_points, np.float64)
    if pts.shape[0] != store.base:
        raise ValueError(
            f"repack id contract broken: {pts.shape[0]} base points but "
            f"store.base={store.base}")
    allp = np.concatenate([pts, staged_points(store)], axis=0)
    tree = RTree.str_bulk(allp, max_entries=max_entries,
                          min_entries=min_entries, fill=fill)
    return (tree, flatten(tree), allp,
            make_delta(store.capacity, base=allp.shape[0]))
