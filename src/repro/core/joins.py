"""Batched spatial join: index-nested-loop over the fused traversal.

A spatial join ``outer ⋈ points`` streams the outer-side rectangles
through the same serving machinery as the range path — outer batches
are formed on the Hilbert curve (``schedule.serve_workload``), each
batch runs the fused traversal + compaction epilogue + refine, and the
qualifying (outer, point) pairs come back through the shared
``[B, max_pairs]`` pair-slot table (``range_query_compact``'s
``result_ids``) — the dense ``[B, L]`` mask never appears on the
kernel path, same contract as every other query type.

Overflowing rows (visited-set or pair-table truncation) re-serve on a
wide tier with both bounds scaled by ``wide_factor``. Unlike the range
path's count-only merge, a join's *payload* is the pair table itself —
``schedule._merge_rows`` would slice wide rows back to the narrow
width and silently drop pairs. ``spatial_join`` therefore orchestrates
the two tiers itself: each tier's pairs are flattened host-side at
that tier's full static width before any merge, so the only possible
loss is wide-tier truncation — counted and flagged
(``residual_truncated``), never silent.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedule
from repro.core.device_tree import DeviceTree
from repro.core.traversal import range_query_compact


class JoinStats(NamedTuple):
    """Per-outer-row join stats (a serve-step stats pytree)."""
    n_pairs: "np.ndarray"       # [B] i32 qualifying pairs (full count)
    pair_ids: "np.ndarray"      # [B, max_pairs] i32 point ids, -1 padded
    n_visited: "np.ndarray"     # [B] i32 leaves visited
    leaf_accesses: "np.ndarray"  # [B] i32 leaf tiles actually refined
    truncated: "np.ndarray"     # [B] bool — pair table or visited set overflowed


def join_step(tree: DeviceTree, outer, *, max_pairs: int = 16,
              max_visited: int = 64, use_kernel: bool = False,
              tile_b: Optional[int] = None,
              tile_l: Optional[int] = None) -> JoinStats:
    """One join batch: outer rects [B, 4] → ``JoinStats``."""
    rq = range_query_compact(tree, outer, max_visited=max_visited,
                             max_results=max_pairs, use_kernel=use_kernel,
                             tile_b=tile_b, tile_l=tile_l)
    return JoinStats(
        n_pairs=rq.n_results,
        pair_ids=rq.result_ids,
        n_visited=rq.n_visited,
        leaf_accesses=jnp.minimum(rq.n_visited, max_visited),
        truncated=rq.truncated,
    )


def make_join_steps(tree: DeviceTree, *, max_pairs: int = 16,
                    max_visited: int = 64, wide_factor: int = 8,
                    use_kernel: bool = False
                    ) -> tuple[Callable, Callable]:
    """Two-tier join serve steps (narrow, wide) for the scheduler.

    The wide tier scales both static bounds by ``wide_factor`` — the
    join analogue of ``engine.wide_config``.
    """
    narrow = jax.jit(lambda q: join_step(
        tree, q, max_pairs=max_pairs, max_visited=max_visited,
        use_kernel=use_kernel))
    wide = jax.jit(lambda q: join_step(
        tree, q, max_pairs=max_pairs * wide_factor,
        max_visited=max_visited * wide_factor, use_kernel=use_kernel))
    return narrow, wide


class JoinReport(NamedTuple):
    """Aggregate result of one spatial join."""
    pairs: np.ndarray           # [P, 2] i64 (outer index, point id)
    stats: JoinStats            # per-outer-row stats, submission order
    n_outer: int
    n_pairs: int                # == pairs.shape[0]
    n_batches: int
    n_reserved: int             # outer rows re-served on the wide tier
    residual_truncated: int     # rows still truncated after the wide tier
    sort: str


def _flatten_pairs(stats, rows: np.ndarray) -> np.ndarray:
    """Extract (outer, point) pairs for ``rows`` from a tier's stats at
    that tier's full static pair width."""
    ids = np.asarray(stats.pair_ids)
    npairs = np.asarray(stats.n_pairs)
    out = []
    for local, outer_i in enumerate(rows):
        n = min(int(npairs[local]), ids.shape[1])
        if n:
            out.append(np.stack(
                [np.full((n,), outer_i, np.int64),
                 ids[local, :n].astype(np.int64)], axis=1))
    if not out:
        return np.zeros((0, 2), np.int64)
    return np.concatenate(out, axis=0)


def spatial_join(tree: DeviceTree, outer: np.ndarray, *, batch: int,
                 max_pairs: int = 16, max_visited: int = 64,
                 sort: str = "hilbert", wide_factor: int = 8,
                 use_kernel: bool = False,
                 bbox: Optional[np.ndarray] = None) -> JoinReport:
    """Join every outer rect against the tree's points.

    Outer batches form on the Hilbert curve; truncated rows re-serve on
    the wide tier with pairs kept at the wide tier's full width (see
    module doc). ``pairs`` is sorted by (outer index, point id) so the
    result is order-canonical regardless of batch formation.
    """
    outer = np.asarray(outer, np.float32)
    narrow, wide = make_join_steps(
        tree, max_pairs=max_pairs, max_visited=max_visited,
        wide_factor=wide_factor, use_kernel=use_kernel)
    rep = schedule.serve_workload(narrow, outer, batch=batch, sort=sort,
                                  bbox=bbox, wide_fn=None, trunc_field=None)
    trunc = np.asarray(rep.stats.truncated).astype(bool)
    idx = np.flatnonzero(trunc)
    ok = np.flatnonzero(~trunc)
    pairs = [_flatten_pairs(_tier_rows(rep.stats, ok), ok)]
    n_batches, residual = rep.n_batches, 0
    stats = rep.stats
    if idx.size:
        wrep = schedule.serve_workload(wide, outer[idx], batch=batch,
                                       sort=sort, bbox=bbox, wide_fn=None,
                                       trunc_field=None)
        n_batches += wrep.n_batches
        pairs.append(_flatten_pairs(wrep.stats, idx))
        residual = int(np.asarray(wrep.stats.truncated).sum())
        stats = schedule._merge_rows(stats, wrep.stats, idx)
    allp = np.concatenate(pairs, axis=0)
    if allp.shape[0]:
        order = np.lexsort((allp[:, 1], allp[:, 0]))
        allp = allp[order]
    return JoinReport(pairs=allp, stats=stats, n_outer=outer.shape[0],
                      n_pairs=int(allp.shape[0]), n_batches=n_batches,
                      n_reserved=int(idx.size),
                      residual_truncated=residual, sort=sort)


def _tier_rows(stats, rows: np.ndarray):
    """Row-select a stats pytree (numpy) onto ``rows``."""
    return type(stats)(**{f: np.asarray(getattr(stats, f))[rows]
                          for f in type(stats)._fields})


def join_brute(points: np.ndarray, rects: np.ndarray) -> np.ndarray:
    """Brute-force pair-set oracle: [P, 2] i64 (outer index, point id),
    sorted, via dense closed-rect containment — the join twin of the
    range path's ``np_contains_point`` count oracle."""
    p = np.asarray(points, np.float32)
    r = np.asarray(rects, np.float32)
    inside = ((p[None, :, 0] >= r[:, None, 0])
              & (p[None, :, 0] <= r[:, None, 2])
              & (p[None, :, 1] >= r[:, None, 1])
              & (p[None, :, 1] <= r[:, None, 3]))
    oi, pj = np.nonzero(inside)
    out = np.stack([oi.astype(np.int64), pj.astype(np.int64)], axis=1)
    order = np.lexsort((out[:, 1], out[:, 0]))
    return out[order]
