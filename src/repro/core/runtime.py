"""Open-loop streaming runtime: deadline-bound serving over the scheduler.

``core.schedule.serve_workload`` is closed-loop: the whole workload is
known upfront, pre-sorted on the Hilbert curve, and cut into always-full
batches — the right harness for throughput, the wrong one for latency.
Real traffic is open-loop: queries *arrive* (``data.arrivals`` stamps
them), each carries a deadline measured from its arrival, and waiting to
fill a 256-query batch is exactly the wrong call when the oldest
enqueued query's slack is about to run out.

This runtime layers the open loop over the same serving contracts:

* **Admission queue** — arrivals enter a pending set keyed *incrementally*
  onto the same Hilbert/Morton curve the offline scheduler sorts by
  (one key per query against a fixed workload bbox, inserted in key
  order as it arrives) — every dispatched batch still covers a compact
  curve window, so the fused kernel's tile early-exit keeps paying.
* **Continuous batch formation** — a batch dispatches when it is full,
  OR when the most urgent pending query's deadline slack drops below
  the EWMA-estimated serve-step cost (``telemetry.Ewma`` over measured
  step walltimes): a partially-full batch on time instead of a full
  batch too late. ``formation="full"`` keeps the fixed-full-batch
  policy as the closed-loop baseline (dispatch only full batches until
  arrivals run dry) — the bench compares the two.
* **Deadline-aware tier selection** — rows that overflowed the narrow
  R-path bound re-serve on the wide tier *only if their remaining slack
  covers the EWMA wide-step cost*; otherwise the row keeps its
  best-effort narrow result and is **flagged degraded** (its truncation
  flag also stays set) — never silently dropped. ``formation="full"``
  always re-serves wide (the offline-faithful baseline).

Results are **bit-identical** to offline ``serve_workload`` over the
same admitted query set whenever no deadline forces a degraded row: the
serve step is per-query (each stats row depends only on its own query),
batches are padded with the same repeat-last-row idiom, and wide-tier
rows merge through the same slice-to-narrow-width contract
(``schedule._merge_rows`` semantics). Only the *grouping* of rows into
batches differs — which cannot change any row.

The clock is wall time by default (each step's measured duration is the
simulated service time — honest on interpret-mode CPU, real on TPU); an
injected ``service_time`` model makes the whole run deterministic for
tests, CI smokes, and the ``--check`` regression rows.
"""
from __future__ import annotations

import bisect
import time
from typing import Callable, NamedTuple, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import schedule, telemetry


class RuntimeReport(NamedTuple):
    """Everything one open-loop run produced, submission order."""
    stats: object            # per-query stats pytree (wide rows merged)
    n_queries: int
    n_batches: int           # narrow-tier dispatches
    n_wide_batches: int
    n_degraded: int          # truncated rows whose wide re-serve the
    #                          deadline disallowed — flagged, kept narrow
    n_missed: int            # rows completing after their deadline
    goodput: float           # fraction exact (non-degraded) AND on time
    mean_fill: float         # mean valid rows per narrow batch / batch
    arrival_s: np.ndarray    # [Q] f64 arrival stamps
    done_s: np.ndarray       # [Q] f64 completion stamps
    latency_s: np.ndarray    # [Q] f64 done - arrival
    degraded: np.ndarray     # [Q] bool
    missed: np.ndarray       # [Q] bool
    telemetry: dict          # p50/p95/p99 latency, queue depth, EWMAs
    formation: str
    sort: str


def _np_rows(stats, sel):
    """Materialize a leading-axis selection of a stats pytree to numpy."""
    return jax.tree.map(lambda a: np.asarray(a)[sel], stats)


def _scatter_rows(out_leaves, narrow_shapes, stats, seqs):
    """Scatter one batch's per-row stats into the [Q]-leading outputs,
    slicing wide-tier payload tables down to the narrow tier's static
    width (the ``schedule._merge_rows`` contract)."""
    leaves = jax.tree.leaves(stats)
    for o, ns, l in zip(out_leaves, narrow_shapes, leaves):
        l = np.asarray(l)
        if l.shape[1:] != ns:
            if any(ws < n for ws, n in zip(l.shape[1:], ns)):
                raise ValueError(f"wide tier leaf narrower than narrow "
                                 f"tier's: {l.shape[1:]} vs {ns}")
            l = l[(slice(None),) + tuple(slice(0, n) for n in ns)]
        o[seqs] = l


def run_stream(serve_fn: Callable, queries: np.ndarray,
               arrivals: np.ndarray, *, batch: int,
               deadline_s: Union[float, np.ndarray],
               sort: str = "hilbert",
               bbox: Optional[np.ndarray] = None,
               wide_fn: Optional[Callable] = None,
               trunc_field: str = "r_truncated",
               formation: str = "deadline",
               service_time: Optional[Callable] = None,
               ewma_alpha: float = 0.25,
               reservoir: int = 4096) -> RuntimeReport:
    """Drive one open-loop stream through the serving stack.

    ``serve_fn``/``wide_fn``/``trunc_field`` are exactly
    ``schedule.serve_workload``'s contract (``[batch, 4] jnp → stats``
    pytree with a truncation flag). ``queries`` [Q, 4] arrive at
    ``arrivals`` [Q] seconds (sorted, from ``data.arrivals``), each with
    deadline ``arrival + deadline_s`` (scalar or per-query [Q]).

    ``formation="deadline"`` is the open-loop policy (partial dispatch
    on slack pressure + deadline-gated wide tier); ``"full"`` is the
    fixed-full-batch baseline (waits to fill, always re-serves wide).

    ``service_time(n_valid, tier) -> seconds`` replaces the measured
    step walltime with a model — the run becomes fully deterministic
    (the serve calls still execute; only the clock is simulated).
    """
    if formation not in ("deadline", "full"):
        raise ValueError(f"formation must be deadline|full, "
                         f"got {formation!r}")
    q = np.asarray(queries, np.float32)
    arr = np.asarray(arrivals, np.float64)
    Q = q.shape[0]
    if Q == 0:
        raise ValueError("need at least one query")
    if arr.shape != (Q,):
        raise ValueError(f"arrivals shape {arr.shape} != ({Q},)")
    if np.any(np.diff(arr) < 0):
        raise ValueError("arrivals must be sorted")
    batch = int(batch)
    if batch <= 0:
        raise ValueError(f"batch must be positive, got {batch}")
    deadline_t = arr + np.broadcast_to(
        np.asarray(deadline_s, np.float64), (Q,))
    if bbox is None:
        bbox = schedule.workload_bbox(q)
    # incremental curve keying: one key per query against the shared
    # bbox — identical values to the offline scheduler's sort keys
    keys = schedule.spatial_keys(q, sort, bbox)

    ew_narrow = telemetry.Ewma(ewma_alpha)
    ew_wide = telemetry.Ewma(ewma_alpha)
    lat_q = telemetry.QuantileReservoir(reservoir, seed=0)
    depth_q = telemetry.QuantileReservoir(reservoir, seed=1)

    def _step(fn, chunk, n_valid, tier, ew):
        t0 = time.perf_counter()
        out = fn(jnp.asarray(chunk))
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) if service_time is None \
            else float(service_time(n_valid, tier))
        ew.update(dt)
        return out, dt

    # warmup: compile both tiers off the clock and seed the cost EWMAs —
    # without this the first dispatch decision would compare slack
    # against a zero estimate (and eat the jit compile on the clock)
    warm = np.repeat(q[:1], batch, axis=0)
    _, dt0 = _step(serve_fn, warm, batch, "narrow", ew_narrow)
    if wide_fn is not None:
        _, _ = _step(wide_fn, warm, batch, "wide", ew_wide)

    # pending admission queue, kept key-sorted (incremental Hilbert
    # batch formation): entries are (key, seq) so equal keys fall back
    # to submission order — same tie-break as the offline stable sort
    pending: list = []
    out_leaves = narrow_shapes = treedef = None
    done_s = np.zeros((Q,), np.float64)
    degraded = np.zeros((Q,), bool)
    n_batches = n_wide_batches = 0
    fills: list = []
    now = 0.0
    i = 0           # next arrival index
    n_done = 0

    def _admit(upto: float) -> int:
        nonlocal i
        while i < Q and arr[i] <= upto:
            bisect.insort(pending, (int(keys[i]), i))
            i += 1
        return i

    while n_done < Q:
        if not pending:
            now = max(now, arr[i])      # idle: jump to the next arrival
        _admit(now)
        if not pending:
            continue
        full = len(pending) >= batch
        drained = i == Q
        if not full and not drained:
            if formation == "full":
                now = arr[i]            # baseline waits for a full batch
                continue
            # deadline formation: dispatch a partial batch only when the
            # most urgent pending query's slack no longer covers one
            # EWMA-estimated narrow step; otherwise sleep until either
            # that boundary or the next arrival, whichever is first
            t_urgent = min(deadline_t[s] for _, s in pending)
            boundary = t_urgent - ew_narrow.value
            if now < boundary:
                now = min(arr[i], boundary)
                continue

        # ---- dispatch: contiguous curve window around the most urgent
        depth_q.add(float(len(pending)))
        if len(pending) <= batch:
            j0, k = 0, len(pending)
        else:
            pu = int(np.argmin([deadline_t[s] for _, s in pending]))
            j0 = min(max(pu - batch // 2, 0), len(pending) - batch)
            k = batch
        sel = pending[j0:j0 + k]
        del pending[j0:j0 + k]
        seqs = np.array([s for _, s in sel], np.int64)
        chunk = q[seqs]
        if k < batch:                   # repeat-last-row pad (scheduler
            chunk = np.concatenate(     # idiom; pad stats are dropped)
                [chunk, np.repeat(chunk[-1:], batch - k, axis=0)])
        stats, dt = _step(serve_fn, chunk, k, "narrow", ew_narrow)
        now += dt
        n_batches += 1
        fills.append(k)
        rows = _np_rows(stats, np.s_[:k])
        if out_leaves is None:
            leaves = jax.tree.leaves(rows)
            treedef = jax.tree.structure(rows)
            narrow_shapes = [l.shape[1:] for l in leaves]
            out_leaves = [np.zeros((Q,) + l.shape[1:], l.dtype)
                          for l in leaves]
        _scatter_rows(out_leaves, narrow_shapes, rows, seqs)

        # ---- deadline-aware tier selection over the truncated rows
        re_idx = np.zeros((0,), np.int64)
        if wide_fn is not None and hasattr(rows, trunc_field):
            trunc = np.asarray(getattr(rows, trunc_field)).astype(bool)
            t_idx = seqs[np.flatnonzero(trunc)]
            if t_idx.size:
                if formation == "full":
                    ok = np.ones(t_idx.shape, bool)
                else:
                    slack = deadline_t[t_idx] - now
                    ok = slack >= ew_wide.value
                re_idx = t_idx[ok]
                # rows the wide re-serve would blow the deadline on keep
                # their best-effort narrow result, flagged — their
                # truncation flag stays set too (never silently cleared)
                degraded[t_idx[~ok]] = True
        done_narrow = np.setdiff1d(seqs, re_idx, assume_unique=True)
        done_s[done_narrow] = now
        n_done += done_narrow.size

        for lo in range(0, re_idx.size, batch):
            w_seqs = re_idx[lo:lo + batch]
            kw = w_seqs.size
            wchunk = q[w_seqs]
            if kw < batch:
                wchunk = np.concatenate(
                    [wchunk, np.repeat(wchunk[-1:], batch - kw, axis=0)])
            wstats, dtw = _step(wide_fn, wchunk, kw, "wide", ew_wide)
            now += dtw
            n_wide_batches += 1
            _scatter_rows(out_leaves, narrow_shapes,
                          _np_rows(wstats, np.s_[:kw]), w_seqs)
            done_s[w_seqs] = now
            n_done += kw
        _admit(now)     # arrivals that landed while the step(s) ran

    stats = jax.tree.unflatten(treedef, out_leaves)
    latency = done_s - arr
    lat_q.extend(latency)
    missed = done_s > deadline_t
    good = ~degraded & ~missed
    tele = {
        "latency_s": lat_q.summary(),
        "queue_depth": depth_q.summary(),
        "ewma_narrow_s": ew_narrow.value,
        "ewma_wide_s": ew_wide.value,
        "warm_narrow_s": dt0,
    }
    return RuntimeReport(
        stats=stats, n_queries=Q, n_batches=n_batches,
        n_wide_batches=n_wide_batches, n_degraded=int(degraded.sum()),
        n_missed=int(missed.sum()), goodput=float(good.mean()),
        mean_fill=float(np.mean(fills) / batch), arrival_s=arr,
        done_s=done_s, latency_s=latency, degraded=degraded,
        missed=missed, telemetry=tele, formation=formation, sort=sort)
