"""Training-data preparation for the AI-tree (paper §III-A3..5).

Step 1: execute the query workload on the (device-form) R-tree, capturing for
every query the *visited* leaf IDs and the *true* leaf IDs (Table I).
Step 2: the query rectangle is the feature vector, the true leaf IDs are the
multi-hot class labels (Table II — one-hot per leaf, unioned).

Everything is batched through ``traversal.range_query`` — the DeviceTree's
leaf order *is* the paper's DFS leaf-ID order, so mask columns are labels.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import numpy as np

import jax.numpy as jnp

from repro.core.device_tree import DeviceTree
from repro.core import traversal

# The α buckets the paper evaluates on (§V-B2).
PAPER_ALPHA_BUCKETS = (0.1, 0.25, 0.5, 0.75, 1.0)


@dataclasses.dataclass
class Workload:
    """A labelled query workload over one tree."""
    queries: np.ndarray        # [Q, 4] f32
    visited: np.ndarray        # [Q, L] bool
    true_labels: np.ndarray    # [Q, L] bool — the multi-hot classifier target
    n_visited: np.ndarray      # [Q] i32
    n_true: np.ndarray         # [Q] i32
    n_results: np.ndarray      # [Q] i32
    alpha: np.ndarray          # [Q] f32

    @property
    def n_queries(self) -> int:
        return int(self.queries.shape[0])

    @property
    def n_leaves(self) -> int:
        return int(self.true_labels.shape[1])

    def bucket(self, buckets: Iterable[float] = PAPER_ALPHA_BUCKETS) -> np.ndarray:
        """Assign each query to the nearest α bucket (paper reports per-bucket)."""
        b = np.asarray(list(buckets), dtype=np.float32)
        return b[np.argmin(np.abs(self.alpha[:, None] - b[None, :]), axis=1)]

    def high_overlap(self, tau: float = 0.75) -> np.ndarray:
        """Label 0/1 split of §IV: high-overlap ⇔ α ≤ τ."""
        return self.alpha <= tau

    def subset(self, idx: np.ndarray) -> "Workload":
        return Workload(
            queries=self.queries[idx], visited=self.visited[idx],
            true_labels=self.true_labels[idx], n_visited=self.n_visited[idx],
            n_true=self.n_true[idx], n_results=self.n_results[idx],
            alpha=self.alpha[idx])


def make_workload(tree: DeviceTree, queries: np.ndarray, *,
                  batch_size: int = 256, max_visited: int = 256,
                  max_results: int = 1024, use_kernel: bool = False) -> Workload:
    """Run the workload through the batched traversal and collect labels."""
    queries = np.asarray(queries, dtype=np.float32)
    Q = queries.shape[0]
    vis, tru, nv, nt, nr = [], [], [], [], []
    for o in range(0, Q, batch_size):
        qb = queries[o:o + batch_size]
        pad = batch_size - qb.shape[0]
        if pad:
            qb = np.concatenate([qb, np.zeros((pad, 4), np.float32)], axis=0)
        res = traversal.range_query(
            tree, jnp.asarray(qb), max_visited=max_visited,
            max_results=max_results, use_kernel=use_kernel)
        take = qb.shape[0] - pad
        vis.append(np.asarray(res.visited)[:take])
        tru.append(np.asarray(res.true_leaves)[:take])
        nv.append(np.asarray(res.n_visited)[:take])
        nt.append(np.asarray(res.n_true)[:take])
        nr.append(np.asarray(res.n_results)[:take])
    n_visited = np.concatenate(nv)
    n_true = np.concatenate(nt)
    a = np.where(n_visited > 0, n_true / np.maximum(n_visited, 1), 1.0)
    return Workload(
        queries=queries,
        visited=np.concatenate(vis),
        true_labels=np.concatenate(tru),
        n_visited=n_visited,
        n_true=n_true,
        n_results=np.concatenate(nr),
        alpha=a.astype(np.float32),
    )
