"""Input specifications for every (architecture × shape) dry-run cell.

``input_specs`` returns ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, zero allocation. Modality frontends are stubs per the assignment:
whisper gets precomputed frame embeddings, qwen2-vl gets a precomputed
embedding sequence in place of tokens.

Shape classes (LM shapes are seq_len × global_batch):
  train_4k     seq 4096,   batch 256   → train_step
  prefill_32k  seq 32768,  batch 32    → forward (inference prefill)
  decode_32k   seq 32768,  batch 128   → serve_step (1 token + 32k cache)
  long_500k    seq 524288, batch 1     → serve_step, sub-quadratic archs only
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")

SHAPE_DEFS = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# gradient-accumulation plan per arch for train_4k (activation-memory lever;
# EXPERIMENTS.md memory table). batch 256 must divide by accum.
ACCUM = {
    "llama3-405b": 16,
    "deepseek-v2-236b": 8,
    "qwen2-72b": 4,
    "qwen2-vl-72b": 4,
    "gemma2-9b": 2,
    "deepseek-moe-16b": 4,
    # SSM/hybrid trains materialize f32 scan inputs over the full sequence;
    # microbatching keeps the live set ≪ HBM (see EXPERIMENTS.md memory)
    "rwkv6-3b": 8,
    "hymba-1.5b": 8,
}


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic archs (DESIGN.md policy)."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k dense decode is the "
                       "quadratic blow-up this shape excludes")
    return True, ""


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def bf16(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """Batch ShapeDtypeStructs for train/prefill cells."""
    sd = SHAPE_DEFS[shape]
    B, S = sd["global_batch"], sd["seq_len"]
    specs: Dict[str, Any] = {}
    if cfg.frontend == "vision":
        # stub: precomputed multimodal embedding sequence
        specs["embeds"] = bf16(B, S, cfg.d_model)
    else:
        specs["tokens"] = i32(B, S)
    if cfg.family == "encdec":
        specs["frames"] = bf16(B, cfg.enc_seq, cfg.d_model)
    if sd["kind"] == "train":
        specs["labels"] = i32(B, S)
    return specs


def decode_specs(cfg: ModelConfig, shape: str, cache_dtype=jnp.bfloat16
                 ) -> tuple[Dict[str, Any], Any]:
    """(tokens spec, cache spec pytree) for decode cells."""
    from repro.serving import kvcache
    sd = SHAPE_DEFS[shape]
    B, S = sd["global_batch"], sd["seq_len"]
    tokens = i32(B, 1)
    cache = jax.eval_shape(
        lambda: kvcache.make_cache(cfg, B, seq_len=S, dtype=cache_dtype))
    return {"tokens": tokens}, cache


def state_specs(cfg: ModelConfig, *, dtype=jnp.bfloat16,
                opt_state_dtype=None) -> Any:
    """Abstract TrainState via eval_shape (no allocation)."""
    from repro.training import optimizer as opt, train_loop
    ocfg = opt.AdamWConfig(
        state_dtype=opt_state_dtype
        or (jnp.bfloat16 if cfg.n_params() > 1e11 else jnp.float32))
    return jax.eval_shape(
        lambda: train_loop.init_train_state(
            cfg, jax.random.PRNGKey(0), dtype=dtype, opt_cfg=ocfg)), ocfg
