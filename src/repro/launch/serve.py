"""Spatial serving driver: build an AI+R-tree and stream a full workload.

``python -m repro.launch.serve --points 120000 --queries 4096 [...]``

End-to-end: synthesize (or load) the dataset → dynamic R-tree build →
workload labelling → AI+R training (grid search + router) → **streaming**
hybrid serving of the *entire* query workload through the spatial batch
scheduler (``core.schedule``): queries are Hilbert/Morton-sorted into
fixed-size batches (``--sort none`` keeps arrival order), every query is
served exactly once, results are restored to submission order, and rows
that overflowed the narrow R-path bound are re-served on the wide tier.
Reports aggregate stats over the whole stream plus an oracle check that no
query was dropped. With >1 device, serving dispatches through the
shard_map engine (queries over 'data', tree/experts over 'model').

Open-loop mode (``--arrival poisson|bursty|trace``): instead of draining
the workload closed-loop, queries are stamped with arrival times
(``data.arrivals``) and served by the streaming runtime
(``core.runtime``) under per-query deadlines (``--rate``,
``--deadline-ms``, auto-pinned to the measured capacity when 0):
continuous Hilbert batch formation with deadline-aware partial dispatch
and wide-tier gating (``--formation full`` keeps the fixed-full-batch
baseline). Reports latency p50/p95/p99, goodput, and the degraded-row
accounting, plus the same no-drop oracle.

Mixed read/write mode (``--insert-rate r``): a fraction ``r`` of the
points is held out of the initial build and staged as dynamic inserts
between query segments (``core.schedule.serve_mixed_workload`` over a
``FreshServer``): every batch probes the device-side delta buffer, the
freshness guard demotes stale/under-fit cells to the exact R path, and
``--repack-every N`` triggers the online repack (bulk-reload swap between
batches) once N points are staged. The oracle then checks every query's
result count against brute-force containment over exactly the points
visible to its segment.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build, device_tree as dt, engine, labels, runtime, \
    schedule
from repro.core import geometry as geo
from repro.core.hybrid import hybrid_query
from repro.core.monitor import DefaultPolicy, EngineFreshServer, FreshServer
from repro.core.rtree import RTree
from repro.launch import mesh as pmesh
from repro.data import arrivals as arrv, synth


def make_serve_fns(hyb, args, devices):
    """(narrow_fn, wide_fn, trunc_field, ctx, ai_fused) for the loop.

    Distributed (>1 device and ``--distributed``): the shard_map engine's
    two-tier steps (overflow flag ``ServeStats.r_truncated``). Otherwise:
    jit'd ``hybrid_query`` with the same narrow/wide bound split (flag
    ``HybridResult.truncated``; the wide tier also widens ``max_results``
    so its result-id gather cannot re-truncate). ``ai_fused`` reports
    whether the AI path's prediction actually dispatches the fused
    kernel under *this* configuration — asked of the dispatch gate at
    the shapes it will really see (per-shard for the engine), because
    ``REPRO_KERNELS=off`` or the VMEM gate silently route to the dense
    oracle even with ``--kernel``.
    """
    from repro.kernels import ops as kops
    want_fused = args.kernel and args.classifier == "mlp"
    if args.distributed and len(devices) > 1:
        n = len(devices)
        nd = max(1, n // 2)
        n_model = n // nd
        mesh = jax.make_mesh((nd, n_model), ("data", "model"))
        hyb_s = engine.pad_tree_for_sharding(hyb, n_model)
        cfg = engine.EngineConfig(max_visited=args.max_visited,
                                  use_kernel=args.kernel)
        narrow, wide = engine.make_two_tier_steps(
            mesh, cfg, kind=args.classifier, wide_factor=args.wide_factor)
        ctx = pmesh.set_mesh(mesh)
        fused = want_fused and cfg.score_union == "topk" and \
            kops.mlp_fused_active(
                args.batch // nd, hyb_s.ait.bank, cfg.max_cells,
                hyb_s.tree.n_leaves, cfg.max_pred,
                n_cells=hyb_s.ait.bank.w1.shape[0] // n_model)
        # jit once per tier — the stream re-enters the step per batch
        return (jax.jit(lambda q: narrow(hyb_s, q)),
                jax.jit(lambda q: wide(hyb_s, q)), "r_truncated", ctx,
                fused)

    import contextlib
    mv, mr = args.max_visited, 512
    narrow = jax.jit(lambda q: hybrid_query(hyb, q, max_visited=mv,
                                            max_results=mr,
                                            use_kernel=args.kernel))
    wide = jax.jit(lambda q: hybrid_query(
        hyb, q, max_visited=mv * args.wide_factor,
        max_results=mr * args.wide_factor, use_kernel=args.kernel))
    fused = want_fused and kops.mlp_fused_active(
        args.batch, hyb.ait.bank, hyb.ait.max_cells,
        hyb.tree.n_leaves, hyb.ait.max_pred)
    return narrow, wide, "truncated", contextlib.nullcontext(), fused


def make_fresh_server(base, hyb, args, devices, fit_state=None,
                      policy=None):
    """Build the mixed-stream server: ``FreshServer`` (single-device
    hybrid path) or ``EngineFreshServer`` (shard_map engine, replicated
    delta) plus the mesh context. ``fit_state``/``policy`` turn on the
    online instance-optimization loop (span-diff repacks + incremental
    ``refit_cells`` chunks between segments)."""
    import contextlib
    if args.distributed and len(devices) > 1:
        n = len(devices)
        nd = max(1, n // 2)
        n_model = n // nd
        mesh = jax.make_mesh((nd, n_model), ("data", "model"))
        cfg = engine.EngineConfig(max_visited=args.max_visited,
                                  use_kernel=args.kernel)
        srv = EngineFreshServer(base, hyb, mesh, cfg, kind=args.classifier,
                                n_model=n_model, delta_cap=args.delta_cap,
                                wide_factor=args.wide_factor,
                                fit_state=fit_state, policy=policy)
        return srv, pmesh.set_mesh(mesh)
    srv = FreshServer(base, hyb, delta_cap=args.delta_cap,
                      max_visited=args.max_visited, max_results=512,
                      wide_factor=args.wide_factor, use_kernel=args.kernel,
                      fit_state=fit_state, policy=policy)
    return srv, contextlib.nullcontext()


def serve_mixed(base, extra, hyb, wl, args, rep) -> None:
    """Drive the mixed read/write stream and report freshness stats."""
    fit_state = policy = None
    if args.policy != "none":
        # repack/demote/promote run regardless; without a per-cell
        # FitState (forest banks) the server skips the refit chunks,
        # prints its one-time notice, and records the skip count on
        # each decision (MaintenanceDecision.refit_skipped)
        policy = DefaultPolicy(refit_chunk=args.refit_chunk,
                               repack_at=args.repack_at)
        if rep.fit_state is not None and args.classifier != "forest":
            fit_state = rep.fit_state
    server, ctx = make_fresh_server(base, hyb, args, jax.devices(),
                                    fit_state=fit_state, policy=policy)
    bbox = schedule.workload_bbox(wl.queries)
    with ctx:
        t0 = time.time()
        mixed = schedule.serve_mixed_workload(
            server, wl.queries, extra, batch=args.batch, sort=args.sort,
            bbox=bbox, insert_every=args.insert_every,
            repack_every=args.repack_every)
        dt_s = time.time() - t0
    st = mixed.stats
    fs = server.stats()
    trunc_field = getattr(server, "trunc_field", "truncated")
    acc = float(np.asarray(st.leaf_accesses).mean())
    ai = float(np.asarray(st.used_ai).mean())
    guarded = float(np.asarray(st.guarded).mean())
    d_hits = int(np.asarray(st.delta_hits).sum())
    resid = int(np.asarray(getattr(st, trunc_field)).sum())
    print(f"# mixed stream: {mixed.n_queries} queries / {mixed.n_inserts} "
          f"inserts in {mixed.n_segments} segments ({mixed.n_batches} "
          f"batches, sort={mixed.sort}), {mixed.n_repacks} repacks, "
          f"{mixed.n_reserved} re-served wide, {resid} still truncated")
    print(f"# serve: {mixed.n_queries/dt_s:.0f} queries/s, "
          f"{acc:.2f} leaf accesses/query, {100*ai:.1f}% AI path, "
          f"{100*guarded:.1f}% guard-demoted, {d_hits} delta hits")
    print(f"# freshness: {fs.ok_cells}/{fs.n_cells} cells serve-eligible "
          f"({fs.fit_cells} exact-fit, {fs.stale_cells} stale, "
          f"{fs.demoted_cells} demoted), delta "
          f"fill {fs.delta_fill}/{args.delta_cap}, "
          f"{fs.n_repacks} repacks")
    if policy is not None:
        n_prep = sum(d.repack for _, d in mixed.maintenance)
        n_ref = sum(r.cells_refit for r in server.refits)
        n_dem = sum(d.demote.size for _, d in mixed.maintenance)
        n_pro = sum(d.promote.size for _, d in mixed.maintenance)
        n_skip = sum(d.refit_skipped for _, d in mixed.maintenance)
        print(f"# policy: {n_prep} repacks, {n_ref} cell refits "
              f"({n_skip} skipped), {n_dem} demotions, {n_pro} promotions "
              f"across {len(mixed.maintenance)} segment decisions")
        # recovery curve: guard/AI rates per segment show the AI path
        # coming back chunk by chunk after each span-diff repack
        g = np.asarray(st.guarded)
        u = np.asarray(st.used_ai)
        curve = "  ".join(
            f"{s}:{g[lo:hi].mean():.2f}/{u[lo:hi].mean():.2f}"
            for s, (lo, hi) in enumerate(mixed.seg_bounds))
        print(f"# recovery (seg:guarded/used_ai): {curve}")
    # freshness oracle: each segment's queries against exactly the points
    # visible to it (schedule.visible_segments — the scheduler's actual
    # staging, never re-derived from the policy)
    mism = 0
    got = np.asarray(st.n_results)
    for (lo, hi), visible in schedule.visible_segments(mixed, base):
        for o in range(lo, hi, 256):
            qs = wl.queries[o:min(o + 256, hi)]
            exp = geo.np_contains_point(
                qs[:, None, :], visible[None, :, :]).sum(axis=1)
            mism += int(np.sum(exp != got[o:min(o + 256, hi)]))
    print(f"# oracle: {mism} / {mixed.n_queries} n_results mismatches vs "
          f"per-segment brute-force containment")


def serve_open_loop(narrow_fn, wide_fn, trunc_field, wl, args) -> None:
    """Open-loop serving: stamp arrivals, drive ``runtime.run_stream``,
    report the latency/goodput/degraded accounting plus the no-drop
    oracle (every non-degraded row exact against the workload labels)."""
    q = wl.queries
    # measured full-pipeline step costs pin the auto rate/deadline to
    # this machine's actual capacity (same convention as latency_bench)
    qb = jnp.asarray(q[: args.batch])
    ts = {}
    for name, fn in (("narrow", narrow_fn), ("wide", wide_fn)):
        jax.block_until_ready(fn(qb))
        reps = []
        for _ in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(qb))
            reps.append(time.perf_counter() - t0)
        ts[name] = float(np.median(reps))
    cap_qps = args.batch / (ts["narrow"] + ts["wide"])
    rate = args.rate if args.rate > 0 else 1.5 * cap_qps
    deadline_s = (args.deadline_ms / 1e3 if args.deadline_ms > 0
                  else 6.0 * (ts["narrow"] + ts["wide"]))
    arr = arrv.make_arrivals(args.arrival, q.shape[0], rate,
                             trace=args.trace)
    print(f"# open loop: {args.arrival} arrivals at {rate:.0f} qps "
          f"({rate/cap_qps:.2f}x measured capacity {cap_qps:.0f} qps), "
          f"deadline {deadline_s*1e3:.1f} ms, formation={args.formation}")
    rep = runtime.run_stream(
        narrow_fn, q, arr, batch=args.batch, deadline_s=deadline_s,
        sort=args.sort, wide_fn=wide_fn, trunc_field=trunc_field,
        formation=args.formation)
    lat = rep.telemetry["latency_s"]
    depth = rep.telemetry["queue_depth"]
    print(f"# stream: {rep.n_queries} queries in {rep.n_batches} batches "
          f"(+{rep.n_wide_batches} wide), mean fill "
          f"{100*rep.mean_fill:.0f}%, queue depth p95 {depth['p95']:.0f}")
    print(f"# latency: p50 {lat['p50']*1e3:.1f} ms, "
          f"p95 {lat['p95']*1e3:.1f} ms, p99 {lat['p99']*1e3:.1f} ms")
    print(f"# goodput: {100*rep.goodput:.1f}% exact-and-on-time "
          f"({rep.n_missed} missed deadline, {rep.n_degraded} degraded "
          f"to best-effort narrow — flagged, never dropped)")
    # no-drop oracle: every query completed after it arrived, and every
    # non-degraded row's count matches the labelling pass exactly
    assert np.all(rep.done_s > rep.arrival_s)
    got = np.asarray(rep.stats.n_results)
    mism = int(np.sum(got[~rep.degraded] != wl.n_results[~rep.degraded]))
    print(f"# oracle: 0 dropped; {mism} / {int((~rep.degraded).sum())} "
          f"non-degraded n_results mismatches vs workload labels"
          + (f"; {rep.n_degraded} degraded rows carry their truncation "
             f"flag" if rep.n_degraded else ""))


def _timed_stream(narrow_fn, q, args, *, wide_fn=None, trunc_field=None,
                  bbox=None):
    """Warm both tiers, then time ``--reps`` full-stream repetitions."""
    report = schedule.serve_workload(
        narrow_fn, q, batch=args.batch, sort=args.sort, bbox=bbox,
        wide_fn=wide_fn, trunc_field=trunc_field)
    t0 = time.time()
    for _ in range(args.reps):
        report = schedule.serve_workload(
            narrow_fn, q, batch=args.batch, sort=args.sort, bbox=bbox,
            wide_fn=wide_fn, trunc_field=trunc_field)
    return report, (time.time() - t0) / args.reps


def serve_knn(dtree, pts, args) -> None:
    """kNN stream: distance browsing at a density-derived radius, with
    the radius-doubling wide tier re-serving flagged rows; a brute-force
    k-distance oracle checks a sample bit-exactly (prefix property on
    rows still truncated)."""
    from repro.core import knn as knnlib
    rng = np.random.default_rng(0)
    centers = pts[rng.integers(0, pts.shape[0], args.queries)].astype(
        np.float32)
    q = np.concatenate([centers, centers], axis=1)
    r = knnlib.default_radius(dtree, args.knn_k, margin=args.knn_margin)
    narrow, wide = knnlib.make_knn_steps(
        dtree, k=args.knn_k, radius=r, max_visited=args.max_visited,
        wide_factor=args.wide_factor, use_kernel=args.kernel)
    report, dt_s = _timed_stream(narrow, q, args, wide_fn=wide,
                                 trunc_field="truncated",
                                 bbox=schedule.workload_bbox(q))
    st = report.stats
    resid = int(np.asarray(st.truncated).sum())
    acc = float(np.asarray(st.leaf_accesses).mean())
    print(f"# knn stream: k={args.knn_k}, radius {r:.4g} "
          f"(margin {args.knn_margin}), {report.n_queries} queries in "
          f"{report.n_batches} batches (sort={report.sort}), "
          f"{report.n_reserved} re-served at 2x radius, {resid} still "
          f"truncated (flagged, never approximate)")
    print(f"# serve: {report.n_queries/dt_s:.0f} queries/s, "
          f"{acc:.2f} leaf accesses/query, mean k-distance "
          f"{float(np.sqrt(np.asarray(st.neighbor_d2)[:, -1][~np.asarray(st.truncated)].mean())):.4g}")
    # oracle: sampled rows vs all-pairs brute kNN — d2 must match
    # bit-for-bit (both sides evaluate dx*dx+dy*dy under jit, so XLA's
    # FMA contraction is identical); truncated rows match on the
    # in-radius prefix
    m = min(256, q.shape[0])
    idx = rng.choice(q.shape[0], m, replace=False)
    bd2, _ = knnlib.knn_brute(pts, centers[idx], args.knn_k)
    got = np.asarray(st.neighbor_d2)[idx]
    trunc = np.asarray(st.truncated)[idx]
    nw = np.asarray(st.n_within)[idx]
    mism = 0
    for j in range(m):
        kk = args.knn_k if not trunc[j] else min(int(nw[j]), args.knn_k)
        mism += int(not np.array_equal(got[j, :kk], bd2[j, :kk]))
    print(f"# oracle: {mism} / {m} sampled rows mismatch brute-force "
          f"k-distances (bit-exact)")


def serve_join(dtree, pts, args) -> None:
    """Spatial join stream: index-nested-loop over the fused traversal,
    pairs through the shared compaction epilogue; a brute-force pair-set
    oracle checks a sample exactly."""
    from repro.core import joins
    rng = np.random.default_rng(0)
    outer = synth.synth_queries(pts, args.selectivity, args.queries)
    rep = joins.spatial_join(dtree, outer, batch=args.batch,
                             max_pairs=args.join_pairs,
                             max_visited=args.max_visited, sort=args.sort,
                             wide_factor=args.wide_factor,
                             use_kernel=args.kernel)   # warm both tiers
    t0 = time.time()
    for _ in range(args.reps):
        rep = joins.spatial_join(dtree, outer, batch=args.batch,
                                 max_pairs=args.join_pairs,
                                 max_visited=args.max_visited,
                                 sort=args.sort,
                                 wide_factor=args.wide_factor,
                                 use_kernel=args.kernel)
    dt_s = (time.time() - t0) / args.reps
    print(f"# join stream: {rep.n_outer} outer rects x {pts.shape[0]} "
          f"points -> {rep.n_pairs} pairs "
          f"({rep.n_pairs/max(rep.n_outer,1):.1f}/outer) in "
          f"{rep.n_batches} batches (sort={rep.sort}), {rep.n_reserved} "
          f"re-served wide, {rep.residual_truncated} still truncated")
    print(f"# serve: {rep.n_outer/dt_s:.0f} outer rows/s, "
          f"{rep.n_pairs/dt_s:.0f} pairs/s")
    # oracle: sampled outer rows' pair sets vs dense brute containment;
    # rows the wide tier still truncated are excluded (flagged above)
    m = min(256, outer.shape[0])
    idx = rng.choice(outer.shape[0], m, replace=False)
    still = np.asarray(rep.stats.truncated).astype(bool)
    idx = idx[~still[idx]]
    bp = joins.join_brute(pts, outer[idx])
    remap = {int(o): i for i, o in enumerate(idx)}
    sel = np.isin(rep.pairs[:, 0], idx)
    got = {(remap[int(o)], int(pj)) for o, pj in rep.pairs[sel]}
    brute = {(int(o), int(pj)) for o, pj in bp}
    print(f"# oracle: {len(got ^ brute)} pair mismatches vs brute-force "
          f"containment over {idx.size} sampled outer rows")


def serve_point(hyb, base, args, devices) -> None:
    """Point-query stream: degenerate rects at dataset points served
    with single-cell AI routing and narrowed bounds — no wide tier, so
    exactness is *asserted* (zero truncated rows) instead of re-served."""
    import contextlib
    from repro.core import hybrid as hybmod
    rng = np.random.default_rng(0)
    ppts = base[rng.integers(0, base.shape[0], args.queries)].astype(
        np.float32)
    q = np.concatenate([ppts, ppts], axis=1)
    if args.distributed and len(devices) > 1:
        n = len(devices)
        nd = max(1, n // 2)
        n_model = n // nd
        mesh = jax.make_mesh((nd, n_model), ("data", "model"))
        hyb_s = engine.pad_tree_for_sharding(hyb, n_model)
        cfg = engine.EngineConfig(max_visited=args.max_visited,
                                  use_kernel=args.kernel)
        step = engine.make_point_serve_step(mesh, cfg,
                                            kind=args.classifier)
        narrow = jax.jit(lambda qq: step(hyb_s, qq))
        trunc_field, ctx = "r_truncated", pmesh.set_mesh(mesh)
    else:
        narrow = jax.jit(lambda qq: hybmod.point_query(
            hyb, qq, use_kernel=args.kernel))
        trunc_field, ctx = "truncated", contextlib.nullcontext()
    with ctx:
        report, dt_s = _timed_stream(narrow, q, args,
                                     bbox=schedule.workload_bbox(q))
    st = report.stats
    resid = int(np.asarray(getattr(st, trunc_field)).sum())
    acc = float(np.asarray(st.leaf_accesses).mean())
    ai = float(np.asarray(st.used_ai).mean())
    print(f"# point stream: {report.n_queries} degenerate-rect queries "
          f"in {report.n_batches} batches (sort={report.sort}), "
          f"single-cell AI routing, no wide tier")
    print(f"# serve: {report.n_queries/dt_s:.0f} queries/s, "
          f"{acc:.2f} leaf accesses/query, {100*ai:.1f}% AI path")
    # the narrowed bounds must cover every row — a truncated point query
    # would be silently wrong, so this is an assert, not a re-serve
    assert resid == 0, f"{resid} truncated point queries"
    got = np.asarray(st.n_results)
    # containment in f32 — the serving path (and the tree's leaf
    # entries) is f32 throughout, and a degenerate rect only contains
    # the points that are *bit-equal* at that precision
    bf = base.astype(np.float32)
    mism = 0
    for o in range(0, q.shape[0], 256):
        qs = q[o:o + 256]
        exp = geo.np_contains_point(qs[:, None, :],
                                    bf[None, :, :]).sum(axis=1)
        mism += int(np.sum(exp != got[o:o + 256]))
    print(f"# oracle: 0 truncated (exactness asserted); {mism} / "
          f"{report.n_queries} n_results mismatches vs brute-force "
          f"containment")


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", default="tweets", choices=("tweets",
                                                           "crimes"))
    p.add_argument("--points", type=int, default=120_000)
    p.add_argument("--queries", type=int, default=4096)
    p.add_argument("--selectivity", type=float, default=5e-5)
    p.add_argument("--node-capacity", type=int, default=128)
    p.add_argument("--classifier", default="knn",
                   choices=("knn", "forest", "mlp"))
    p.add_argument("--batch", type=int, default=512)
    p.add_argument("--reps", type=int, default=3,
                   help="timed repetitions of the full stream")
    p.add_argument("--sort", default="hilbert", choices=schedule.SORT_MODES,
                   help="spatial batch scheduling curve (none = arrival "
                        "order)")
    p.add_argument("--max-visited", type=int, default=64,
                   help="narrow-tier R-path bound (overflow re-serves wide)")
    p.add_argument("--wide-factor", type=int, default=8)
    p.add_argument("--kernel", action="store_true",
                   help="serve through the Pallas kernel paths (fused "
                        "traversal/compaction; with --classifier mlp also "
                        "the fused prediction kernel)")
    p.add_argument("--distributed", action="store_true",
                   help="serve through the shard_map engine")
    p.add_argument("--insert-rate", type=float, default=0.0,
                   help="fraction of points held out of the build and "
                        "staged as dynamic inserts during the stream")
    p.add_argument("--insert-every", type=int, default=4,
                   help="query batches per stream segment (inserts land "
                        "between segments)")
    p.add_argument("--repack-every", type=int, default=0,
                   help="online repack once this many inserts are staged "
                        "(0 = never; buffer must then hold them all)")
    p.add_argument("--delta-cap", type=int, default=8192,
                   help="delta store capacity (points)")
    p.add_argument("--arrival", default="closed",
                   choices=("closed", "poisson", "bursty", "trace"),
                   help="closed = drain the workload as fast as it serves "
                        "(the throughput harness); anything else stamps "
                        "arrival times and drives the open-loop runtime "
                        "(core.runtime) under per-query deadlines")
    p.add_argument("--rate", type=float, default=0.0,
                   help="open-loop arrival rate, queries/s (0 = auto: "
                        "1.5x the measured serve capacity)")
    p.add_argument("--deadline-ms", type=float, default=0.0,
                   help="per-query deadline from arrival (0 = auto: 6x "
                        "the measured narrow+wide batch cost)")
    p.add_argument("--trace", default=None,
                   help="timestamp file for --arrival trace (.npy or one "
                        "float per line)")
    p.add_argument("--formation", default="deadline",
                   choices=("deadline", "full"),
                   help="open-loop batch formation: deadline-aware "
                        "partial dispatch, or fixed-full-batch baseline")
    p.add_argument("--policy", default="none", choices=("none", "default"),
                   help="between-segment maintenance policy: span-diff "
                        "repacks + stats-driven incremental refit chunks "
                        "(needs a per-cell classifier: knn or mlp)")
    p.add_argument("--refit-chunk", type=int, default=4,
                   help="max stale cells retrained per segment decision")
    p.add_argument("--repack-at", type=float, default=0.75,
                   help="policy repacks once the delta buffer passes this "
                        "fill fraction")
    p.add_argument("--query-type", default="range",
                   choices=("range", "point", "knn", "join"),
                   help="serving path: range rects (default), point "
                        "lookups (degenerate rects, single-cell AI "
                        "routing, exactness asserted), kNN (distance "
                        "browsing with a radius-doubling wide tier), or "
                        "spatial join (index-nested-loop, pair-slot "
                        "tables)")
    p.add_argument("--knn-k", type=int, default=8,
                   help="neighbors per query for --query-type knn")
    p.add_argument("--knn-margin", type=float, default=2.0,
                   help="probe radius margin over the density estimate "
                        "(larger = fewer wide-tier re-serves)")
    p.add_argument("--join-pairs", type=int, default=16,
                   help="narrow-tier pair-slot width for --query-type "
                        "join")
    args = p.parse_args()
    if args.query_type != "range" and (args.insert_rate > 0
                                       or args.arrival != "closed"):
        p.error("--query-type point/knn/join drive the closed-loop "
                "read-only stream (no --insert-rate / --arrival)")

    gen = synth.tweets_like if args.dataset == "tweets" else synth.crimes_like
    pts = gen(args.points)
    n_ins = int(round(args.insert_rate * pts.shape[0]))
    base, extra = (pts[:-n_ins], pts[-n_ins:]) if n_ins else (pts, None)
    print(f"# dataset {args.dataset}: {pts.shape[0]} points"
          + (f" ({n_ins} held out as inserts)" if n_ins else ""))

    t0 = time.time()
    tree = RTree(max_entries=args.node_capacity).insert_all(base)
    dtree = dt.flatten(tree)
    print(f"# R-tree: {dtree.n_leaves} leaves, height {dtree.height}, "
          f"built in {time.time()-t0:.1f}s")

    if args.query_type == "knn":
        serve_knn(dtree, pts, args)
        return
    if args.query_type == "join":
        serve_join(dtree, pts, args)
        return

    qs = synth.synth_queries(pts, args.selectivity, args.queries)
    wl = labels.make_workload(dtree, qs)
    print(f"# workload: mean α {wl.alpha.mean():.3f}, "
          f"mean visited {wl.n_visited.mean():.1f}")

    hyb, rep = build.fit_airtree(dtree, wl, kind=args.classifier,
                                 verbose=True)
    print(f"# AI+R: grid {rep.grid_size}², exact-fit {rep.exact_fit:.3f} "
          f"({int(rep.cell_fit.sum())}/{rep.cell_fit.size} cells exact), "
          f"router test acc {rep.router.test_acc:.3f}, "
          f"models {rep.model_bytes/1e6:.2f} MB")

    if args.query_type == "point":
        serve_point(hyb, base, args, jax.devices())
        return

    if n_ins:
        serve_mixed(base, extra, hyb, wl, args, rep)
        return

    narrow_fn, wide_fn, trunc_field, ctx, ai_fused = make_serve_fns(
        hyb, args, jax.devices())
    if args.arrival != "closed":
        with ctx:
            serve_open_loop(narrow_fn, wide_fn, trunc_field, wl, args)
        return

    bbox = schedule.workload_bbox(wl.queries)
    with ctx:
        # warm / compile both tiers, then time full-stream repetitions
        report = schedule.serve_workload(
            narrow_fn, wl.queries, batch=args.batch, sort=args.sort,
            bbox=bbox, wide_fn=wide_fn, trunc_field=trunc_field)
        t0 = time.time()
        for _ in range(args.reps):
            report = schedule.serve_workload(
                narrow_fn, wl.queries, batch=args.batch, sort=args.sort,
                bbox=bbox, wide_fn=wide_fn, trunc_field=trunc_field)
        dt_s = (time.time() - t0) / args.reps

    st = report.stats
    acc = float(np.asarray(st.leaf_accesses).mean())
    ai = float(np.asarray(st.used_ai).mean())
    resid = int(np.asarray(getattr(st, trunc_field)).sum())
    print(f"# stream: {report.n_queries} queries in {report.n_batches} "
          f"batches (sort={report.sort}), {report.n_reserved} re-served "
          f"wide ({report.wide_batches} batches), {resid} still truncated")
    print(f"# serve: {report.n_queries/dt_s:.0f} queries/s, "
          f"{acc:.2f} leaf accesses/query, "
          f"{100*ai:.1f}% answered by the AI path")
    # AI-path fusion accounting: with the fused prediction kernel (mlp
    # bank + --kernel) prediction flows through the compact [B, max_pred]
    # slot table and the dense [B, L] score table never materializes;
    # every other configuration still runs the dense-oracle rung, so
    # report the saving only when it actually happened.
    k = hyb.ait.max_pred
    dense_b = report.n_queries * dtree.n_leaves * 4
    slot_b = report.n_queries * (k + 1) * 4
    verdict = ("eliminated" if ai_fused else
               "still materialized on this config — fused path needs "
               "--classifier mlp --kernel (and the kernel dispatch "
               "active)")
    print(f"# AI path: {slot_b/1e3:.0f} KB compact slot tables; "
          f"{dense_b/1e6:.1f} MB dense [B, {dtree.n_leaves}] score tables "
          f"{verdict}")
    # no-drop oracle: the labelling pass already executed every query
    mism = int(np.sum(np.asarray(st.n_results) != wl.n_results))
    print(f"# oracle: {mism} / {report.n_queries} n_results mismatches "
          f"vs workload labels")


if __name__ == "__main__":
    main()
