"""Spatial serving driver: build an AI+R-tree and serve batched queries.

``python -m repro.launch.serve --points 120000 --queries 4096 [...]``

End-to-end: synthesize (or load) the dataset → dynamic R-tree build →
workload labelling → AI+R training (grid search + router) → batched hybrid
serving loop with throughput/leaf-access stats. With >1 device, serving is
dispatched through the shard_map engine (queries over 'data', tree/experts
over 'model').
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build, device_tree as dt, engine, labels
from repro.core.hybrid import hybrid_query
from repro.core.rtree import RTree
from repro.launch import mesh as pmesh
from repro.data import synth


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", default="tweets", choices=("tweets",
                                                           "crimes"))
    p.add_argument("--points", type=int, default=120_000)
    p.add_argument("--queries", type=int, default=4096)
    p.add_argument("--selectivity", type=float, default=5e-5)
    p.add_argument("--node-capacity", type=int, default=128)
    p.add_argument("--classifier", default="knn",
                   choices=("knn", "forest", "mlp"))
    p.add_argument("--batch", type=int, default=512)
    p.add_argument("--reps", type=int, default=3)
    p.add_argument("--distributed", action="store_true",
                   help="serve through the shard_map engine")
    args = p.parse_args()

    gen = synth.tweets_like if args.dataset == "tweets" else synth.crimes_like
    pts = gen(args.points)
    print(f"# dataset {args.dataset}: {pts.shape[0]} points")

    t0 = time.time()
    tree = RTree(max_entries=args.node_capacity).insert_all(pts)
    dtree = dt.flatten(tree)
    print(f"# R-tree: {dtree.n_leaves} leaves, height {dtree.height}, "
          f"built in {time.time()-t0:.1f}s")

    qs = synth.synth_queries(pts, args.selectivity, args.queries)
    wl = labels.make_workload(dtree, qs)
    print(f"# workload: mean α {wl.alpha.mean():.3f}, "
          f"mean visited {wl.n_visited.mean():.1f}")

    hyb, rep = build.fit_airtree(dtree, wl, kind=args.classifier,
                                 verbose=True)
    print(f"# AI+R: grid {rep.grid_size}², exact-fit {rep.exact_fit:.3f}, "
          f"router test acc {rep.router.test_acc:.3f}, "
          f"models {rep.model_bytes/1e6:.2f} MB")

    B = args.batch
    q = jnp.asarray(wl.queries[:B])
    if args.distributed and len(jax.devices()) > 1:
        n = len(jax.devices())
        nd = max(1, n // 2)
        mesh = jax.make_mesh((nd, n // nd), ("data", "model"))
        hyb_s = engine.pad_tree_for_sharding(hyb, n // nd)
        step = engine.make_serve_step(mesh, engine.EngineConfig(),
                                      kind=args.classifier)
        with pmesh.set_mesh(mesh):
            stats = step(hyb_s, q)
            jax.block_until_ready(stats)
            t0 = time.time()
            for _ in range(args.reps):
                stats = step(hyb_s, q)
                jax.block_until_ready(stats)
        dt_s = (time.time() - t0) / args.reps
        acc = float(np.asarray(stats.leaf_accesses).mean())
        ai = float(np.asarray(stats.used_ai).mean())
    else:
        out = hybrid_query(hyb, q)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(args.reps):
            out = hybrid_query(hyb, q)
            jax.block_until_ready(out)
        dt_s = (time.time() - t0) / args.reps
        acc = float(np.asarray(out.leaf_accesses).mean())
        ai = float(np.asarray(out.used_ai).mean())
    print(f"# serve: {B/dt_s:.0f} queries/s, {acc:.2f} leaf accesses/query, "
          f"{100*ai:.1f}% answered by the AI path")


if __name__ == "__main__":
    main()
