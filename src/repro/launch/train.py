"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on whatever devices exist (CPU smoke → TPU pod): builds the
mesh, shards state via the production rules, restores the newest checkpoint
if present (elastic — the mesh may differ from the one that wrote it),
installs the preemption handler, and train-loops with periodic atomic
checkpoints and straggler heartbeats.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import sharding as shd
from repro.training import checkpoint, fault_tolerance, optimizer as opt
from repro.training import train_loop
from repro.models import transformer as tf


def synthetic_batch(cfg, B, S, step, seed=0):
    rng = np.random.default_rng(seed + step)
    batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(1, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.bfloat16)
    if cfg.frontend == "vision":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16)
        batch.pop("tokens")
    return batch


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--reduced", action="store_true",
                   help="shrink the config for CPU runs")
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--mesh", default="auto",
                   help="'auto' (all devices × 1) or 'DxM'")
    p.add_argument("--dtype", default="float32")
    args = p.parse_args()

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    dtype = dict(float32=jnp.float32, bfloat16=jnp.bfloat16)[args.dtype]

    n_dev = len(jax.devices())
    if args.mesh == "auto":
        mesh = jax.make_mesh((n_dev, 1), ("data", "model"))
    else:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))

    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=10,
                           decay_steps=max(args.steps, 100))
    state = train_loop.init_train_state(cfg, jax.random.PRNGKey(0),
                                        dtype=dtype, opt_cfg=ocfg)
    state_sh = shd.params_shardings(state, mesh)
    state = jax.tree.map(jax.device_put, state, state_sh)

    start_step = 0
    run = fault_tolerance.RunState()
    if args.ckpt_dir and checkpoint.latest_step(args.ckpt_dir) is not None:
        state, manifest = checkpoint.restore(args.ckpt_dir, state,
                                             shardings=state_sh)
        run = fault_tolerance.RunState.from_dict(manifest.get("extra", {}))
        start_step = run.step + 1
        print(f"# resumed from step {run.step} "
              f"(data_position {run.data_position})")

    step_fn = jax.jit(
        train_loop.make_train_step(cfg, opt_cfg=ocfg,
                                   accum_steps=args.accum),
        in_shardings=(state_sh, shd.batch_shardings(
            synthetic_batch(cfg, args.batch, args.seq, 0), mesh)),
    )
    handler = fault_tolerance.PreemptionHandler().install()
    monitor = fault_tolerance.StragglerMonitor()

    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = synthetic_batch(cfg, args.batch, args.seq, step)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        monitor.beat(f"host{jax.process_index()}", dt)
        if step % 10 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / dt
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{dt*1e3:.0f} ms ({tok_s:.0f} tok/s)", flush=True)
        want_ckpt = args.ckpt_dir and (
            step % args.ckpt_every == 0 or handler.preempted()
            or step == args.steps - 1)
        if want_ckpt:
            run = fault_tolerance.RunState(
                step=step, data_position=(step + 1) * args.batch)
            checkpoint.save(args.ckpt_dir, step, state,
                            extra=run.to_dict())
        if handler.preempted():
            print(f"# preempted at step {step}; checkpointed and exiting")
            return
    print("# done")


if __name__ == "__main__":
    main()
