import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below may import jax.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

(note: no ``from __future__`` here — the XLA_FLAGS env line must stay the
very first statement of this module.)

For each cell this produces, with zero real allocation (ShapeDtypeStruct
inputs, eval_shape'd states):

  * ``compiled.memory_analysis()``  — proves the cell fits per-device HBM
  * ``compiled.cost_analysis()``    — per-device FLOPs/bytes for §Roofline
  * collective wire bytes           — parsed from the post-SPMD HLO

Results land in ``benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json``;
``benchmarks/roofline.py`` turns them into the §Roofline table.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import sharding as shd
from repro.launch import mesh as pmesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (ACCUM, SHAPE_DEFS, cell_supported,
                                decode_specs, input_specs, state_specs)
from repro.models import transformer as tf
from repro.models.config import ModelConfig

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str) -> Dict[str, Any]:
    """Per-device wire bytes by collective kind (ring formulas).

    all-gather: out·(n-1)/n ; reduce-scatter: out·(n-1) ;
    all-reduce: out·2(n-1)/n ; all-to-all: out·(n-1)/n ;
    collective-permute: out.
    """
    by_kind: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_shape, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(out_shape)
        g = _GROUPS_RE.search(line)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip() != ""])
        else:
            g2 = _GROUPS_LIST_RE.search(line)
            n = int(g2.group(2)) if g2 else 2
        n = max(n, 2)
        if kind == "all-gather":
            wire = nbytes * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = nbytes * (n - 1)
        elif kind == "all-reduce":
            wire = nbytes * 2 * (n - 1) / n
        elif kind == "all-to-all":
            wire = nbytes * (n - 1) / n
        else:  # collective-permute
            wire = nbytes
        by_kind[kind] = by_kind.get(kind, 0.0) + wire
        counts[kind] = counts.get(kind, 0) + 1
    return {"wire_bytes_by_kind": by_kind, "counts": counts,
            "wire_bytes_total": sum(by_kind.values())}


def _mem_dict(mem) -> Dict[str, float]:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            out[attr] = float(getattr(mem, attr))
        except Exception:
            pass
    if not out and mem is not None:
        out["repr"] = str(mem)[:2000]
    return out


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        cost = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    keep = {}
    for k, v in dict(cost).items():
        if k in ("flops", "bytes accessed", "transcendentals",
                 "optimal_seconds") or k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------

def _airtree_cell(shape: str, multi_pod: bool):
    """The paper's engine on the production mesh: batched AI+R serving.

    Fabricated tweets-2M-scale tree (16k leaves × 256 entries), 20×20 grid
    of kNN cell models, 64k queries per batch — all ShapeDtypeStructs.
    """
    import numpy as np
    from repro.core import engine as eng
    from repro.core.device_tree import DeviceTree, Level
    from repro.core.grid import Grid
    from repro.core.aitree import AITree
    from repro.core.hybrid import HybridTree
    from repro.core.classifiers.knn import KNNBank
    from repro.core.classifiers.router import Router
    from repro.launch.specs import f32, i32

    mesh = make_production_mesh(multi_pod=multi_pod)
    union = "topk" if shape.endswith("_topk") else "pmax"
    base_shape = shape.replace("_topk", "")
    B = {"serve_64k": 65536, "serve_8k": 8192}[base_shape]
    L, M, C, Qp, Cl = 16384, 256, 400, 256, 128
    levels = (Level(mbrs=f32(1, 4), parent=i32(1)),
              Level(mbrs=f32(128, 4), parent=i32(128)),
              Level(mbrs=f32(L, 4), parent=i32(L)))
    tree = DeviceTree(levels=levels, leaf_entries=f32(L, M, 2),
                      leaf_entry_ids=i32(L, M), leaf_counts=i32(L),
                      n_points=2_000_000, max_entries=M)
    bank = KNNBank(feats=f32(C, Qp, 4), labels=f32(C, Qp, Cl),
                   label_map=i32(C, Cl), lmask=jax.ShapeDtypeStruct(
                       (C, Cl), jnp.bool_), eps=1e-6)
    ait = AITree(grid=Grid(bbox=f32(4), g=20), bank=bank,
                 cell_ok=jax.ShapeDtypeStruct((C,), jnp.bool_), kind="knn",
                 max_cells=4, max_pred=16, threshold=0.5)
    router = Router(feat_idx=i32(16, 6), thresh=f32(16, 6),
                    tables=f32(16, 2 ** 6, 1), tau=0.75)
    h = HybridTree(tree=tree, ait=ait, router=router)
    # topk variant also runs the tuned per-shard refine bound (32 vs 64):
    # per-shard visited is ~visited_total/16, so 32 is ≥5× headroom; the
    # r_truncated guard re-serves any overflow on a wide-bound tier.
    cfg = eng.EngineConfig(max_visited=64 if union == "pmax" else 32,
                           max_pred=16, score_union=union)
    step = eng.make_serve_step(mesh, cfg, kind="knn")
    q_spec = f32(B, 4)
    with pmesh.set_mesh(mesh):
        lowered = jax.jit(step).lower(h, q_spec)
    meta = dict(arch="airtree", shape=shape,
                mesh="2x16x16" if multi_pod else "16x16", kind="serve",
                seq_len=0, global_batch=B)
    return lowered, mesh, meta


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               remat_policy: str = "dots"):
    """Build (lowered, mesh, meta) for one dry-run cell."""
    if arch == "airtree":
        return _airtree_cell(shape, multi_pod)
    cfg = configs.get_config(arch)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"unsupported cell: {why}")
    mesh = make_production_mesh(multi_pod=multi_pod)
    sd = SHAPE_DEFS[shape]
    meta: Dict[str, Any] = dict(arch=arch, shape=shape,
                                mesh="2x16x16" if multi_pod else "16x16",
                                kind=sd["kind"],
                                seq_len=sd["seq_len"],
                                global_batch=sd["global_batch"])

    if sd["kind"] == "train":
        from repro.training import train_loop
        state_spec, ocfg = state_specs(cfg)
        accum = ACCUM.get(cfg.name, 1)
        meta["accum_steps"] = accum
        step = train_loop.make_train_step(cfg, opt_cfg=ocfg,
                                          accum_steps=accum,
                                          remat_policy=remat_policy)
        batch_spec = input_specs(cfg, shape)
        in_sh = (shd.params_shardings(state_spec, mesh),
                 shd.batch_shardings(batch_spec, mesh))
        with pmesh.set_mesh(mesh):
            lowered = jax.jit(step, in_shardings=in_sh).lower(
                state_spec, batch_spec)
        return lowered, mesh, meta

    params_spec = jax.eval_shape(
        lambda: tf.init_params(cfg, jax.random.PRNGKey(0),
                               dtype=jnp.bfloat16))
    if sd["kind"] == "prefill":
        batch_spec = input_specs(cfg, shape)

        def prefill(params, batch):
            return tf.forward(cfg, params, batch, remat_policy=None)

        in_sh = (shd.params_shardings(params_spec, mesh),
                 shd.batch_shardings(batch_spec, mesh))
        with pmesh.set_mesh(mesh):
            lowered = jax.jit(prefill, in_shardings=in_sh).lower(
                params_spec, batch_spec)
        return lowered, mesh, meta

    # decode
    from repro.serving import decode as dec
    tok_spec, cache_spec = decode_specs(cfg, shape)

    def serve_step(params, cache, tokens):
        return dec.decode_step(cfg, params, cache, tokens)

    in_sh = (shd.params_shardings(params_spec, mesh),
             shd.cache_shardings(cache_spec, mesh),
             shd.batch_shardings(tok_spec, mesh)["tokens"])
    with pmesh.set_mesh(mesh):
        lowered = jax.jit(serve_step, in_shardings=in_sh).lower(
            params_spec, cache_spec, tok_spec["tokens"])
    meta["cache_bytes_global"] = sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(cache_spec)
        if hasattr(x, "size"))
    return lowered, mesh, meta


# ---------------------------------------------------------------------------
# differential cost accounting
#
# XLA's cost_analysis counts a lax.scan body ONCE regardless of trip count,
# so full-depth scanned lowerings under-report FLOPs/bytes/collectives by
# ~L×. True totals are recovered from two small *unrolled* lowerings:
#     body  = f(L=2 units) − f(L=1 unit)          (per metric)
#     total = f(1 unit) + body × (units_full − 1)
# The unit is one scanned step: a layer, a local/global pair (gemma2), or an
# (enc, dec) layer pair (whisper). Known residual undercounts (documented in
# EXPERIMENTS.md): inner time scans (mamba ~<1%) and Pallas custom calls
# (wkv6 state math, ~3% for rwkv6).
# ---------------------------------------------------------------------------

def _cost_variants(cfg: ModelConfig):
    import dataclasses as dc
    if cfg.layer_pattern == "alt_local_global":
        a = dc.replace(cfg, n_layers=2, unroll_layers=True)
        b = dc.replace(cfg, n_layers=4, unroll_layers=True)
        units = cfg.n_layers // 2
    elif cfg.family == "moe":
        nd = cfg.n_dense_layers
        a = dc.replace(cfg, n_layers=nd + 1, unroll_layers=True)
        b = dc.replace(cfg, n_layers=nd + 2, unroll_layers=True)
        units = cfg.n_layers - nd
    elif cfg.family == "encdec":
        a = dc.replace(cfg, n_layers=1, n_enc_layers=1, unroll_layers=True)
        b = dc.replace(cfg, n_layers=2, n_enc_layers=2, unroll_layers=True)
        units = cfg.n_layers   # enc and dec depths are equal (12/12)
    else:
        a = dc.replace(cfg, n_layers=1, unroll_layers=True)
        b = dc.replace(cfg, n_layers=2, unroll_layers=True)
        units = cfg.n_layers
    return a, b, units


def _lower_for_cost(cfg: ModelConfig, shape: str, mesh):
    """Small unrolled lowering for one cost variant (accum forced to 1)."""
    sd = SHAPE_DEFS[shape]
    if sd["kind"] == "train":
        from repro.training import optimizer as opt, train_loop
        ocfg = opt.AdamWConfig()
        state_spec = jax.eval_shape(
            lambda: train_loop.init_train_state(
                cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16,
                opt_cfg=ocfg))
        step = train_loop.make_train_step(cfg, opt_cfg=ocfg, accum_steps=1,
                                          remat_policy="dots")
        batch_spec = input_specs(cfg, shape)
        in_sh = (shd.params_shardings(state_spec, mesh),
                 shd.batch_shardings(batch_spec, mesh))
        with pmesh.set_mesh(mesh):
            return jax.jit(step, in_shardings=in_sh).lower(state_spec,
                                                           batch_spec)
    params_spec = jax.eval_shape(
        lambda: tf.init_params(cfg, jax.random.PRNGKey(0),
                               dtype=jnp.bfloat16))
    if sd["kind"] == "prefill":
        batch_spec = input_specs(cfg, shape)
        fn = lambda p, b: tf.forward(cfg, p, b, remat_policy=None)  # noqa
        in_sh = (shd.params_shardings(params_spec, mesh),
                 shd.batch_shardings(batch_spec, mesh))
        with pmesh.set_mesh(mesh):
            return jax.jit(fn, in_shardings=in_sh).lower(params_spec,
                                                         batch_spec)
    from repro.serving import decode as dec
    tok_spec, cache_spec = decode_specs(cfg, shape)
    fn = lambda p, c, t: dec.decode_step(cfg, p, c, t)  # noqa
    in_sh = (shd.params_shardings(params_spec, mesh),
             shd.cache_shardings(cache_spec, mesh),
             shd.batch_shardings(tok_spec, mesh)["tokens"])
    with pmesh.set_mesh(mesh):
        return jax.jit(fn, in_shardings=in_sh).lower(
            params_spec, cache_spec, tok_spec["tokens"])


def _cost_metrics(lowered) -> Dict[str, float]:
    compiled = lowered.compile()
    cost = _cost_dict(compiled)
    coll = collective_stats(compiled.as_text())
    out = {"flops": cost.get("flops", 0.0),
           "bytes_accessed": cost.get("bytes accessed", 0.0),
           "transcendentals": cost.get("transcendentals", 0.0),
           "wire_bytes_total": coll["wire_bytes_total"]}
    for k, v in coll["wire_bytes_by_kind"].items():
        out[f"wire_{k}"] = v
    return out


def cost_scaled(arch: str, shape: str, *, multi_pod: bool = False
                ) -> Dict[str, Any]:
    """Scaled per-device cost metrics for one cell (see block comment)."""
    cfg = configs.get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    a, b, units = _cost_variants(cfg)
    ma = _cost_metrics(_lower_for_cost(a, shape, mesh))
    mb = _cost_metrics(_lower_for_cost(b, shape, mesh))
    scaled: Dict[str, Any] = {"units": units}
    for k in ma:
        body = mb[k] - ma[k]
        scaled[k] = ma[k] + body * (units - 1)
        scaled[f"{k}_per_unit"] = body
    return scaled


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             out_dir: str = RESULTS_DIR) -> Dict[str, Any]:
    t0 = time.time()
    rec: Dict[str, Any]
    try:
        lowered, mesh, rec = lower_cell(arch, shape, multi_pod=multi_pod)
        rec["lower_seconds"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_seconds"] = round(time.time() - t1, 1)
        rec["n_devices"] = int(mesh.devices.size)
        rec["memory"] = _mem_dict(compiled.memory_analysis())
        rec["cost"] = _cost_dict(compiled)
        rec["collectives"] = collective_stats(compiled.as_text())
        if arch != "airtree":
            cfg = configs.get_config(arch)
            rec["model_params"] = cfg.n_params()
            rec["model_params_active"] = cfg.n_active_params()
        else:
            rec["model_params"] = rec["model_params_active"] = 0
        rec["status"] = "ok"
    except Exception as e:
        rec = dict(arch=arch, shape=shape,
                   mesh="2x16x16" if multi_pod else "16x16",
                   status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    rec["total_seconds"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape}__{rec.get('mesh', 'x')}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--skip-existing", action="store_true")
    p.add_argument("--cost-pass", action="store_true",
                   help="add differential cost_scaled metrics to existing "
                        "cell JSONs (no full-depth recompile)")
    p.add_argument("--out", default=RESULTS_DIR)
    args = p.parse_args()

    cells = []
    if args.all:
        for arch in configs.ARCHS:
            alias = configs.get_config(arch).name
            for shape in SHAPE_DEFS:
                ok, why = cell_supported(configs.get_config(arch), shape)
                if ok:
                    cells.append((alias, shape))
                else:
                    print(f"SKIP {alias} {shape}: {why}")
        cells.append(("airtree", "serve_64k"))
    else:
        cells = [(args.arch, args.shape)]

    if args.cost_pass:
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        for arch, shape in cells:
            if arch == "airtree":
                continue  # no layer scan — raw cost is already exact
            out_file = os.path.join(args.out,
                                    f"{arch}__{shape}__{mesh_tag}.json")
            if not os.path.exists(out_file):
                continue
            with open(out_file) as f:
                rec = json.load(f)
            if rec.get("status") != "ok":
                continue
            if args.skip_existing and "cost_scaled" in rec:
                print(f"SKIP (cost cached) {arch} {shape}")
                continue
            print(f"COST {arch} {shape} {mesh_tag} ...", flush=True)
            t0 = time.time()
            try:
                rec["cost_scaled"] = cost_scaled(arch, shape,
                                                 multi_pod=args.multi_pod)
                rec["cost_scaled"]["seconds"] = round(time.time() - t0, 1)
                print(f"  flops/dev={rec['cost_scaled']['flops']:.3e} "
                      f"coll={rec['cost_scaled']['wire_bytes_total']:.3e}B "
                      f"({rec['cost_scaled']['seconds']}s)", flush=True)
            except Exception as e:
                rec["cost_scaled"] = {"error": f"{type(e).__name__}: {e}"}
                print(f"  ERROR: {e}", flush=True)
            with open(out_file, "w") as f:
                json.dump(rec, f, indent=1, default=str)
        return

    for arch, shape in cells:
        mesh_tag = "2x16x16" if args.multi_pod else "16x16"
        out_file = os.path.join(args.out,
                                f"{arch}__{shape}__{mesh_tag}.json")
        if args.skip_existing and os.path.exists(out_file):
            with open(out_file) as f:
                if json.load(f).get("status") == "ok":
                    print(f"SKIP (cached) {arch} {shape} {mesh_tag}")
                    continue
        print(f"RUN  {arch} {shape} {mesh_tag} ...", flush=True)
        rec = run_cell(arch, shape, multi_pod=args.multi_pod, out_dir=args.out)
        if rec["status"] == "ok":
            fl = rec["cost"].get("flops", 0)
            print(f"  ok in {rec['total_seconds']}s  "
                  f"flops/dev={fl:.3e}  "
                  f"coll={rec['collectives']['wire_bytes_total']:.3e}B",
                  flush=True)
        else:
            print(f"  ERROR: {rec['error']}", flush=True)


if __name__ == "__main__":
    main()
