"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's
``xla_force_host_platform_device_count`` trick to work.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CPU multi-device tests (host platform device count)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """The axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def set_mesh(mesh):
    """Ambient-mesh context manager, portable across jax versions.

    ``jax.set_mesh`` is recent; on older jax the ``Mesh`` object itself is
    the context manager that installs the ambient mesh.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
