"""Parameter/activation sharding rules for the production mesh.

Strategy (baseline, iterated in EXPERIMENTS.md §Perf):
  * TP over ``model``: attention head·d_head projections, FFN hidden dim,
    expert dim (EP), vocab dim of embedding/lm_head;
  * FSDP over ``data``: the d_model axis of every large matrix (ZeRO-3
    style — parameters, grads and optimizer state all shard the same way);
  * replicate across ``pod`` (pure DP between pods);
  * anything small (norms, biases under ~d, LoRA factors) is replicated.

Rules are name-keyed over the flattened pytree path, with divisibility
checks — a dim that does not divide its mesh axis is replicated rather than
mis-sharded (e.g. 8 KV heads on a 16-way model axis ⇒ the flattened
``kv_dim`` axis shards 16-way instead, which every assigned config divides).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


# (regex over "a/b/c" path, spec over the LAST ndim dims of the leaf)
# The leading scan/layer dim (when present) is always unsharded: rules are
# written against the trailing dims and left-padded with None.
_RULES: list[tuple[str, tuple]] = [
    # embeddings
    (r"(^|/)embed$", ("model", "data")),
    (r"(^|/)lm_head$", ("data", "model")),
    (r"(^|/)enc_pos$", (None, None)),        # 1500 rows — replicated
    (r"(^|/)dec_pos$", ("data", None)),      # 32768 rows — shard positions
    # attention (GQA + biases)
    (r"/attn/wq$", ("data", "model")),
    (r"/attn/wk$", ("data", "model")),
    (r"/attn/wv$", ("data", "model")),
    (r"/attn/wo$", ("model", "data")),
    (r"/attn/b[qkv]$", ("model",)),
    (r"/xattn/w[qkv]$", ("data", "model")),
    (r"/xattn/wo$", ("model", "data")),
    (r"/xattn/b[qkv]$", ("model",)),
    # MLA
    (r"/attn/wq_a$", ("data", None)),
    (r"/attn/wq_b$", (None, "model")),
    (r"/attn/wkv_a$", ("data", None)),
    (r"/attn/wkv_b$", (None, "model")),
    # dense MLP
    (r"/mlp/wi$", ("data", "model")),
    (r"/mlp/wg$", ("data", "model")),
    (r"/mlp/wo2$", ("model", "data")),
    # MoE: experts over model (EP), d_model over data
    (r"/moe/router$", ("data", None)),
    (r"/moe/w[ig]$", ("model", "data", None)),
    (r"/moe/wo$", ("model", None, "data")),
    (r"/moe/sh_w[ig]$", ("data", "model")),
    (r"/moe/sh_wo$", ("model", "data")),
    # rwkv6
    (r"/w[rkvg]$", ("data", "model")),
    (r"/wo$", ("model", "data")),
    (r"/wck$", ("data", "model")),
    (r"/wcv$", ("model", "data")),
    (r"/wcr$", ("data", "model")),
    # mamba (hymba)
    (r"/ssm/w_in$", ("data", "model")),
    (r"/ssm/w_out$", ("model", "data")),
    (r"/ssm/w_[BC]$", ("model", None)),
    (r"/ssm/A_log$", ("model", None)),
    (r"/ssm/conv_[wb]$", (None, "model")),
    (r"/ssm/D$", ("model",)),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_leaf(path: str, shape: tuple, mesh) -> P:
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    for pat, dims in _RULES:
        if re.search(pat, path):
            nd = len(shape)
            full = (None,) * (nd - len(dims)) + tuple(dims)
            fixed = []
            for dim_size, ax in zip(shape, full):
                if ax is None or ax not in axis_size:
                    fixed.append(None)
                    continue
                # FSDP extends over the pod axis on multi-pod meshes
                # (ZeRO across pods — halves per-chip state at 2 pods)
                if ax == "data" and "pod" in axis_size:
                    n2 = axis_size["data"] * axis_size["pod"]
                    if dim_size % n2 == 0:
                        fixed.append(("pod", "data"))
                        continue
                if dim_size % axis_size[ax] == 0:
                    fixed.append(ax)
                else:
                    fixed.append(None)   # divisibility fallback: replicate
            return P(*fixed)
    return P()  # norms, scalars, small tensors: replicated


def params_shardings(params: Any, mesh) -> Any:
    """NamedSharding pytree matching ``params`` (or any state pytree whose
    array paths embed the param names, e.g. TrainState(m/v mirror params)."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    specs = [NamedSharding(mesh, spec_for_leaf(_path_str(p), leaf.shape,
                                               mesh))
             for p, leaf in flat[0]]
    return jax.tree_util.tree_unflatten(flat[1], specs)


def batch_shardings(batch: Any, mesh) -> Any:
    """Shard the leading (global-batch) dim over (pod, data)."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ax = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def spec(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        n = int(np.prod([mesh.devices.shape[mesh.axis_names.index(a)]
                         for a in (baxes or ())])) or 1
        if leaf.shape[0] % n != 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(ax, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(spec, batch)


def cache_shardings(cache: Any, mesh, *, seq_axis_min: int = 1024) -> Any:
    """Decode-cache shardings: batch dim over data(+pod), long sequence dims
    over model (KV-head counts generally don't divide 16; the 32k/500k
    sequence always does)."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bax = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    n_b = int(np.prod([mesh.devices.shape[mesh.axis_names.index(a)]
                       for a in (baxes or ())])) or 1
    n_m = mesh.devices.shape[mesh.axis_names.index("model")] \
        if "model" in mesh.axis_names else 1

    def spec(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        dims: list = [None] * leaf.ndim
        # [L, B, ...] layout: try batch on dim 1, longest dim over model
        if leaf.ndim >= 2 and leaf.shape[1] % n_b == 0 and leaf.shape[1] > 1:
            dims[1] = bax
        cand = [i for i in range(2, leaf.ndim)
                if leaf.shape[i] >= seq_axis_min
                and leaf.shape[i] % n_m == 0]
        if cand:
            dims[max(cand, key=lambda i: leaf.shape[i])] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(spec, cache)
