"""Fault-tolerance runtime: preemption handling, heartbeat, stragglers.

Single-controller JAX semantics: every host runs the same program, so fault
tolerance is (a) always-resumable checkpoints (checkpoint.py), (b) a
preemption handler that forces a final checkpoint inside the grace window,
(c) a heartbeat/straggler monitor that flags slow hosts so the scheduler can
evict + elastically resume on a smaller mesh (checkpoints are
mesh-independent, so N-1 resume is a restore, not a rescue).

Everything here is pure-python control plane (no device state), unit-tested
with a fake clock in tests/test_training.py.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Dict, Optional


class PreemptionHandler:
    """SIGTERM-driven graceful shutdown: flip a flag, let the train loop
    checkpoint and exit cleanly within the preemption grace period."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._signals = signals
        self._installed = False

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            signal.signal(s, self._on_signal)
        self._installed = True
        return self

    def _on_signal(self, signum, frame):
        self._requested = True

    def preempted(self) -> bool:
        return self._requested

    def request(self) -> None:   # for tests / manual drain
        self._requested = True


@dataclasses.dataclass
class HostHealth:
    last_beat: float
    step_time_ewma: float
    steps: int


class StragglerMonitor:
    """Per-host step-time EWMA; a host is a straggler when its EWMA exceeds
    ``threshold`` × the fleet median. At 1000+ nodes this is the signal for
    hot-spare swap-in / slow-host eviction; in-process it throttles the
    reporting hook so the job can choose to checkpoint + downscale.
    """

    def __init__(self, ewma: float = 0.9, threshold: float = 1.5,
                 clock: Callable[[], float] = time.monotonic):
        self.ewma = ewma
        self.threshold = threshold
        self.clock = clock
        self.hosts: Dict[str, HostHealth] = {}

    def beat(self, host: str, step_time: float) -> None:
        now = self.clock()
        h = self.hosts.get(host)
        if h is None:
            self.hosts[host] = HostHealth(now, step_time, 1)
        else:
            h.last_beat = now
            h.step_time_ewma = (self.ewma * h.step_time_ewma
                                + (1 - self.ewma) * step_time)
            h.steps += 1

    def _median(self) -> float:
        ts = sorted(h.step_time_ewma for h in self.hosts.values())
        if not ts:
            return 0.0
        return ts[len(ts) // 2]

    def stragglers(self) -> list:
        med = self._median()
        if med <= 0:
            return []
        return [k for k, h in self.hosts.items()
                if h.step_time_ewma > self.threshold * med]

    def dead(self, timeout: float) -> list:
        now = self.clock()
        return [k for k, h in self.hosts.items()
                if now - h.last_beat > timeout]


@dataclasses.dataclass
class RunState:
    """Host-side resumable cursor saved in every checkpoint manifest."""
    step: int = 0
    data_position: int = 0
    rng_seed: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "RunState":
        return cls(**{k: d[k] for k in ("step", "data_position", "rng_seed")
                      if k in d})
