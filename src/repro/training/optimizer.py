"""AdamW with configurable state dtype + gradient clipping + accumulation.

Memory plan knobs for the 405B cell (see EXPERIMENTS.md memory table):
``state_dtype=bfloat16`` halves m/v (the dominant optimizer bytes at scale);
gradient accumulation keeps live activations at microbatch scale. Optional
int8 stochastic-rounding gradient compression for cross-pod all-reduce lives
in ``compression.py`` and hooks in through ``compress_grads``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32       # bf16 at 100B+ scale
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_opt_state(cfg: AdamWConfig, params: Any) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)  # noqa: E731
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: OptState) -> tuple[Any, OptState, dict]:
    """One AdamW step (grads already averaged across data parallel)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm else 1.0
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * u
        return (newp.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    params2 = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    m2 = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    v2 = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params2, OptState(step=step, m=m2, v=v2), metrics


def opt_state_bytes(state: OptState) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(state))
