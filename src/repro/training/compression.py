"""int8 gradient compression with stochastic rounding (cross-pod option).

At 2+ pods the gradient all-reduce crosses the slower inter-pod links; a
per-tensor-scaled int8 encode cuts those bytes 4× (bf16→int8 ≙ 2×; fp32→4×).
Stochastic rounding keeps the quantizer unbiased so SGD/Adam convergence is
preserved in expectation. Used by wrapping the psum:

    g8, scale = encode(g, key)
    g8 = jax.lax.psum(g8.astype(jnp.int32), 'pod')   # int32 accumulate
    g  = decode(g8, jax.lax.psum(scale, 'pod') / npods)

The encode/decode pair is exactly inverse in expectation — property-tested
in tests/test_training.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def encode(g: jnp.ndarray, key: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                      jnp.ndarray]:
    """g → (int8 codes, scale). Stochastic rounding; scale = absmax/127."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-30
    x = g32 / scale
    lo = jnp.floor(x)
    p_up = x - lo
    up = jax.random.uniform(key, g.shape) < p_up
    q = lo + up.astype(jnp.float32)
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def decode(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def encode_tree(grads: Any, key: jnp.ndarray) -> Tuple[Any, Any]:
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    enc = [encode(g, k) for g, k in zip(leaves, keys)]
    qs = jax.tree.unflatten(treedef, [e[0] for e in enc])
    scales = jax.tree.unflatten(treedef, [e[1] for e in enc])
    return qs, scales


def decode_tree(qs: Any, scales: Any) -> Any:
    return jax.tree.map(decode, qs, scales)
