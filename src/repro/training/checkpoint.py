"""Checkpointing: atomic, mesh-independent, elastic-resume capable.

Format: one ``.npz`` per checkpoint step holding every leaf as a full
(unsharded) host array keyed by its pytree path, plus a JSON manifest with
step / data cursor / RNG / config fingerprint. Because leaves are stored
logically (not per-device), a checkpoint written on a 256-chip mesh restores
onto 512 chips, 8 chips, or 1 CPU — resharding happens at ``device_put``
time against whatever shardings the new mesh prescribes (elastic scaling).

Writes are atomic (tmp file + rename); ``keep`` bounds disk usage; restore
picks the newest complete manifest, so a preemption mid-write can never
leave the job unable to resume (fault tolerance contract, tested in
tests/test_training.py).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _path_key(path) -> str:
    parts = []
    for p in path:
        for attr in ("key", "idx", "name"):
            if hasattr(p, attr):
                parts.append(str(getattr(p, attr)))
                break
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flatten(tree: Any) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_key(path)] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Any, flat: dict) -> Any:
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = _path_key(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def save(ckpt_dir: str, step: int, state: Any, *,
         extra: Optional[dict] = None, keep: int = 3) -> str:
    """Atomically write checkpoint ``step``; prune to ``keep`` newest."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    tag = f"step_{step:010d}"
    tmp_fd, tmp_path = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    os.close(tmp_fd)
    np.savez(tmp_path, **flat)
    final_npz = os.path.join(ckpt_dir, tag + ".npz")
    os.replace(tmp_path + ".npz" if os.path.exists(tmp_path + ".npz")
               else tmp_path, final_npz)
    manifest = {"step": step, "time": time.time(), "file": tag + ".npz",
                "extra": extra or {}}
    mtmp = os.path.join(ckpt_dir, tag + ".manifest.tmp")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(ckpt_dir, tag + ".manifest.json"))
    _prune(ckpt_dir, keep)
    return final_npz


def _prune(ckpt_dir: str, keep: int) -> None:
    manifests = sorted(
        f for f in os.listdir(ckpt_dir) if f.endswith(".manifest.json"))
    for m in manifests[:-keep]:
        tag = m.replace(".manifest.json", "")
        for suffix in (".manifest.json", ".npz"):
            p = os.path.join(ckpt_dir, tag + suffix)
            if os.path.exists(p):
                os.remove(p)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in os.listdir(ckpt_dir):
        if f.endswith(".manifest.json"):
            tag = f.replace(".manifest.json", "")
            if os.path.exists(os.path.join(ckpt_dir, tag + ".npz")):
                steps.append(int(tag.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, *,
            step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, dict]:
    """Restore into ``template``'s structure; optionally device_put with
    ``shardings`` (a matching pytree of NamedSharding) for elastic resume
    on a different mesh."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    tag = f"step_{step:010d}"
    with open(os.path.join(ckpt_dir, tag + ".manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(ckpt_dir, tag + ".npz")) as z:
        flat = {k: z[k] for k in z.files}
    state = _unflatten_into(template, flat)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, manifest
