"""Train-step factory: loss → grads (with microbatch accumulation) → AdamW.

The returned ``train_step(state, batch) → (state, metrics)`` is what the
dry-run lowers on the production mesh. Gradient accumulation runs as a
``lax.scan`` over microbatches (constant HLO size), which is the activation
-memory lever for the 405B cell; compute/comm overlap falls out of XLA's
latency-hiding scheduler given the scan structure (grad psum of microbatch i
overlaps with compute of microbatch i+1 under GSPMD).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.training import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.OptState


def init_train_state(cfg: ModelConfig, key, *, dtype=jnp.bfloat16,
                     opt_cfg: Optional[opt.AdamWConfig] = None) -> TrainState:
    params = tf.init_params(cfg, key, dtype=dtype)
    ocfg = opt_cfg or opt.AdamWConfig()
    return TrainState(params=params, opt=opt.init_opt_state(ocfg, params))


def make_train_step(cfg: ModelConfig, *, opt_cfg: Optional[opt.AdamWConfig]
                    = None, accum_steps: int = 1,
                    remat_policy: str = "dots") -> Callable:
    """Build ``train_step(state, batch)``.

    ``batch`` leaves are [global_batch, ...]; with ``accum_steps`` > 1 the
    leading dim is reshaped to [accum, micro, ...] and scanned — gradients
    are averaged across microbatches before one optimizer update.
    """
    ocfg = opt_cfg or opt.AdamWConfig()

    def loss_of(params, batch):
        return tf.loss_fn(cfg, params, batch, remat_policy=remat_policy)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_of)(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_of)(state.params, mb)
                return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params))
            (loss_sum, gsum), _ = jax.lax.scan(body, zero, micro)
            loss = loss_sum / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
        params, ostate, metrics = opt.apply_updates(
            ocfg, state.params, grads, state.opt)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=params, opt=ostate), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, remat_policy: Optional[str] = None
                   ) -> Callable:
    def eval_step(params, batch):
        return tf.loss_fn(cfg, params, batch, remat_policy=remat_policy)
    return eval_step
