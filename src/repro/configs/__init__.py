"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published config;
``reduced(cfg)`` shrinks it for CPU smoke tests (same family/topology,
small widths) — the full configs are exercised only via the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "whisper_small",
    "rwkv6_3b",
    "qwen2_vl_72b",
    "deepseek_moe_16b",
    "deepseek_v2_236b",
    "gemma2_9b",
    "llama3_405b",
    "h2o_danube3_4b",
    "qwen2_72b",
    "hymba_1_5b",
)

ALIASES = {
    "whisper-small": "whisper_small",
    "rwkv6-3b": "rwkv6_3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "gemma2-9b": "gemma2_9b",
    "llama3-405b": "llama3_405b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "qwen2-72b": "qwen2_72b",
    "hymba-1.5b": "hymba_1_5b",
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCHS}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving shrink for CPU smoke tests."""
    d_head = 16
    n_heads = max(2, min(cfg.n_heads, 4))
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_kv = max(1, n_heads // min(ratio, n_heads))
    d_model = 64 if cfg.family != "hybrid" else 64
    changes = dict(
        n_layers=2 if cfg.layer_pattern != "alt_local_global" else 2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=d_head,
        d_ff=128,
        vocab=512,
        window=min(cfg.window, 16) if cfg.window else 0,
    )
    if cfg.family == "ssm":
        changes.update(n_heads=4, n_kv_heads=4, d_model=64)  # dk = 16
    if cfg.use_mla:
        changes.update(kv_lora=32, q_lora=32, rope_head_dim=8,
                       mla_d_nope=16, mla_d_v=16)
    if cfg.family == "moe":
        changes.update(n_experts=min(cfg.n_experts, 8),
                       top_k=min(cfg.top_k, 2), d_expert=32,
                       n_dense_layers=min(cfg.n_dense_layers, 1))
    if cfg.family == "hybrid":
        changes.update(ssm_state=8)
    if cfg.family == "encdec":
        changes.update(n_enc_layers=2, enc_seq=32)
    return dataclasses.replace(cfg, **changes)
