"""qwen2-vl-72b [arXiv:2409.12191]: VLM backbone; M-RoPE/vision stubbed.

The vision tower and dynamic-resolution patching are a frontend stub:
``input_specs`` feeds precomputed patch/text embeddings; the backbone applies
the temporal M-RoPE component (== standard RoPE for text positions).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
    frontend="vision",
)
