"""h2o-danube-3-4b [arXiv:2401.16818]: llama/mistral mix with sliding-window attention."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_head=120,
    d_ff=10240,
    vocab=32000,
    layer_pattern="swa",
    window=4096,
)
