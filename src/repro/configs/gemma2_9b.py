"""gemma2-9b [arXiv:2408.00118]: local/global alternation + logit softcaps."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=14336,
    vocab=256000,
    layer_pattern="alt_local_global",
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    tie_embeddings=True,
)
