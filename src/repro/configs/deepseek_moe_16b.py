"""deepseek-moe-16b [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64 routed top-6."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,            # dense (first) layer FFN
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    d_expert=1408,
    n_dense_layers=1,
)
