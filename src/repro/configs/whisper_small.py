"""whisper-small [arXiv:2212.04356]: enc-dec audio backbone, conv frontend stubbed."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab=51865,
    enc_seq=1500,
    frontend="audio",
    act="gelu",
    qkv_bias=True,
    tie_embeddings=True,
)
