"""llama3-405b [arXiv:2407.21783]: dense GQA at maximum assigned scale."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_head=128,
    d_ff=53248,
    vocab=128256,
    rope_theta=5e5,
)
