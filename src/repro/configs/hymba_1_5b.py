"""hymba-1.5b [arXiv:2411.13676]: parallel SWA-attention + Mamba heads per layer."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    layer_pattern="swa",
    window=1024,
    ssm_state=16,
    ssm_expand=1,
)
