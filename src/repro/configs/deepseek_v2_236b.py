"""deepseek-v2-236b [arXiv:2405.04434]: MLA (kv_lora=512) + 2 shared + 160 routed top-6."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=12288,            # dense (first) layer FFN
    vocab=102400,
    use_mla=True,
    kv_lora=512,
    q_lora=1536,
    rope_head_dim=64,
    mla_d_nope=128,
    mla_d_v=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_expert=1536,
    n_dense_layers=1,
)
