"""rwkv6-3b (Finch) [arXiv:2404.05892]: attention-free, data-dependent decay."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,           # head size 64
    n_kv_heads=40,
    d_head=64,
    d_ff=8960,
    vocab=65536,
)
