"""Datasets and query-workload synthesis for the spatial engine."""
