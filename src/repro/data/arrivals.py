"""Arrival processes for open-loop serving.

The closed-loop harness (``core.schedule.serve_workload``) feeds the
engine a pre-materialized workload as fast as it drains; an open-loop
stream instead *stamps every query with an arrival time* and the runtime
(``core.runtime``) must answer each one under a deadline measured from
that stamp. This module generates the stamps:

* ``poisson_arrivals`` — homogeneous Poisson at a target rate (iid
  exponential gaps), the standard open-loop benchmark process;
* ``bursty_arrivals`` — a two-state MMPP (quiet/burst), for tail-latency
  stress: the mean rate matches ``rate`` but bursts arrive at
  ``burst_factor``× it;
* ``load_trace``/``save_trace`` — replay recorded timestamps (``.npy``
  or one-float-per-line text), rebased to t=0 and sorted, optionally
  resampled to ``n`` queries and rescaled to a target mean rate.

All generators are deterministic under ``seed`` and return cumulative
arrival times in seconds as [n] f64, starting at the first gap (not 0 —
an arrival at exactly t=0 would be special-cased by any queue).
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np


def poisson_arrivals(n: int, rate: float, seed: int = 0) -> np.ndarray:
    """[n] f64 cumulative arrival times of a Poisson process.

    ``rate`` is in queries/second; gaps are iid Exp(rate).
    """
    if n <= 0:
        return np.zeros((0,), np.float64)
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def bursty_arrivals(n: int, rate: float, *, burst_factor: float = 16.0,
                    burst_frac: float = 0.5, switch_every: float = 50.0,
                    seed: int = 0) -> np.ndarray:
    """[n] f64 arrivals of a two-state MMPP with mean rate ``rate``.

    The process alternates between a quiet state and a burst state whose
    instantaneous rate is ``burst_factor``× the quiet one; it spends
    ``burst_frac`` of its arrivals in bursts and switches states every
    ~``switch_every`` arrivals (geometric dwell). The mean rate is
    normalized back to ``rate``, so sweeps compare like with like and
    only the *variance* changes vs ``poisson_arrivals``.
    """
    if n <= 0:
        return np.zeros((0,), np.float64)
    if rate <= 0 or burst_factor < 1.0 or not 0.0 < burst_frac < 1.0:
        raise ValueError(f"bad MMPP parameters: rate={rate}, "
                         f"burst_factor={burst_factor}, "
                         f"burst_frac={burst_frac}")
    rng = np.random.default_rng(seed)
    # state sequence: geometric dwells, burst_frac of arrivals bursty
    state = np.zeros((n,), bool)
    i, in_burst = 0, False
    while i < n:
        dwell_mean = switch_every * (burst_frac if in_burst
                                     else 1.0 - burst_frac) * 2.0
        d = 1 + int(rng.geometric(1.0 / max(dwell_mean, 1.0)))
        state[i:i + d] = in_burst
        i += d
        in_burst = not in_burst
    # per-arrival instantaneous rates, normalized to the target mean gap
    rel = np.where(state, 1.0 / burst_factor, 1.0)   # relative gap sizes
    gaps = rng.exponential(1.0, size=n) * rel
    gaps *= (1.0 / rate) / gaps.mean()
    return np.cumsum(gaps)


def save_trace(path: str, arrivals: np.ndarray) -> None:
    """Persist arrival stamps (``.npy``, or text: one float per line)."""
    a = np.asarray(arrivals, np.float64)
    if path.endswith(".npy"):
        np.save(path, a)
    else:
        np.savetxt(path, a)


def load_trace(path: str, n: Optional[int] = None,
               rate: Optional[float] = None) -> np.ndarray:
    """[n] f64 arrivals replayed from a recorded trace.

    The trace is sorted and rebased so the first gap matches the trace's
    own lead-in. With ``n`` the trace is truncated or tiled (tiling
    shifts each repetition by the trace's span, preserving its rhythm);
    with ``rate`` the stamps are rescaled to that mean arrival rate.

    Edge cases round-trip instead of crashing or emitting NaN gaps: an
    empty trace loads as an empty stream (unless ``n`` demands arrivals
    it cannot supply — that raises), ``n <= 0`` truncates any trace to
    empty, a single-arrival trace tiles on its own lead-in gap, and a
    trace of duplicate stamps tiles with a floor gap so repetitions
    never overlap.
    """
    if not os.path.exists(path):
        raise FileNotFoundError(path)
    if path.endswith(".npy"):
        a = np.load(path)
    else:
        import warnings
        with warnings.catch_warnings():
            # np.loadtxt warns (and returns shape (0,)) on an empty
            # file — an empty trace is a valid stream here
            warnings.simplefilter("ignore", UserWarning)
            a = np.loadtxt(path)
    a = a.astype(np.float64).ravel()
    if n is not None and n <= 0:
        return np.zeros((0,), np.float64)
    if a.size == 0:
        if n is None:
            return np.zeros((0,), np.float64)
        raise ValueError(f"empty trace cannot supply n={n} arrivals: "
                         f"{path}")
    a = np.sort(a)
    a -= a[0]
    span = a[-1] if a[-1] > 0 else 1.0
    gap0 = a[1] - a[0] if a.size > 1 else span
    a += max(gap0, span / max(a.size, 1), 1e-9)    # lead-in: no t=0 arrival
    if n is not None and n != a.size:
        reps = -(-n // a.size)
        # floor the per-rep shift: duplicate-stamp traces have gap0 == 0
        # and would otherwise tile every repetition onto the same instant
        shift = a[-1] + max(gap0, 1e-9)
        a = np.concatenate([a + r * shift for r in range(reps)])[:n]
    if rate is not None:
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        mean_rate = a.size / a[-1]
        a *= mean_rate / rate
    return a


def make_arrivals(kind: str, n: int, rate: float, *, seed: int = 0,
                  trace: Optional[str] = None, **kw) -> np.ndarray:
    """Dispatcher used by the launch driver and the bench harness."""
    if kind == "poisson":
        return poisson_arrivals(n, rate, seed=seed)
    if kind == "bursty":
        return bursty_arrivals(n, rate, seed=seed, **kw)
    if kind == "trace":
        if trace is None:
            raise ValueError("kind='trace' needs a trace path")
        return load_trace(trace, n=n, rate=rate if rate > 0 else None)
    raise ValueError(f"unknown arrival kind {kind!r} "
                     "(expected poisson | bursty | trace)")
