"""Synthetic device-tree level hierarchies (no host RTree build).

Bottom-up construction: leaf MBRs are generated (optionally STR-packed so
sibling leaves are spatially tight, as a bulk-loaded R-tree would be), and
each level above unions ``fanout`` consecutive children — preserving the
contiguous-sibling invariant that ``device_tree.flatten`` guarantees.

Used by the traversal benchmarks and the fused-kernel equivalence tests,
which need controlled shapes (leaf counts off tile multiples, exact depths)
that a real insert-built tree cannot pin down.
"""
from __future__ import annotations

import numpy as np


def synth_levels(L: int, fanout: int, rng: np.random.Generator, *,
                 str_pack: bool = False, leaf_scale: float = 1.0,
                 leaf_width: float = 0.05):
    """Build level arrays for an ``L``-leaf, ``fanout``-ary hierarchy.

    Returns ``(mbrs, parents)``: one ``[N_l, 4]`` float32 and one ``[N_l]``
    int32 array per level, root first, leaf level last (``parents[0]`` is
    unused — the root has no parent).
    """
    sizes = [L]
    while sizes[0] > 1:
        sizes.insert(0, (sizes[0] + fanout - 1) // fanout)
    mbrs = [None] * len(sizes)
    parents = [np.zeros(s, np.int32) for s in sizes]

    lo = rng.uniform(-leaf_scale, leaf_scale, (L, 2))
    w = rng.uniform(0, leaf_width, (L, 2))
    if str_pack:
        # STR packing: sort by x, slab into √L chunks, sort each slab by y
        n_slabs = max(1, int(np.sqrt(L)))
        slab = L // n_slabs + 1
        order = np.argsort(lo[:, 0], kind="stable")
        for s in range(0, L, slab):
            chunk = order[s:s + slab]
            order[s:s + slab] = chunk[np.argsort(lo[chunk, 1],
                                                 kind="stable")]
        lo = lo[order]
        w = w[order]
    mbrs[-1] = np.concatenate([lo, lo + w], 1).astype(np.float32)

    for lvl in range(len(sizes) - 1, 0, -1):
        n, n_par = sizes[lvl], sizes[lvl - 1]
        par = np.minimum(np.arange(n) // fanout, n_par - 1).astype(np.int32)
        parents[lvl] = par
        pm = np.empty((n_par, 4), np.float32)
        for p in range(n_par):
            ch = mbrs[lvl][par == p]
            pm[p] = [ch[:, 0].min(), ch[:, 1].min(),
                     ch[:, 2].max(), ch[:, 3].max()]
        mbrs[lvl - 1] = pm
    return mbrs, parents
