"""Synthetic stand-ins for the paper's UCR-STAR datasets + query synthesis.

The paper evaluates on Tweet locations (2M points) and Chicago Crimes
(872K points). UCR-STAR is not reachable offline, so we generate datasets
with the same statistical character:

* ``tweets_like``  — heavy multi-scale clustering (cities over continents):
  a hierarchical Gaussian mixture (clusters of clusters) + uniform noise.
* ``crimes_like``  — a single metro area: anisotropic street-grid-aligned
  density with hot blocks + uniform urban background.

Query synthesis follows §V-B2: rectangles of fixed *selectivity* (fraction
of the dataset returned), centered on data points (so results are non-empty),
with jittered aspect ratios. A summed-area table gives O(1) approximate
counts for calibrating rectangle sizes; exact counts/α come from executing
the queries on the R-tree afterwards (exactly how the paper categorizes its
workloads).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def tweets_like(n: int = 200_000, seed: int = 0) -> np.ndarray:
    """Hierarchical clustered point cloud in [0, 360] × [-90, 90]-ish."""
    rng = np.random.default_rng(seed)
    n_super = 12                       # continents / regions
    n_sub = 40                         # cities per region
    sup = rng.uniform([0, -60], [360, 70], size=(n_super, 2))
    sub = (sup[rng.integers(0, n_super, n_sub)]
           + rng.normal(0, 8.0, (n_sub, 2)))
    frac_noise = 0.05
    n_noise = int(n * frac_noise)
    n_clustered = n - n_noise
    which = rng.integers(0, n_sub, n_clustered)
    scale = rng.gamma(2.0, 0.35, n_sub)[which][:, None]
    pts = sub[which] + rng.normal(0, 1.0, (n_clustered, 2)) * scale
    noise = rng.uniform([0, -90], [360, 90], size=(n_noise, 2))
    out = np.concatenate([pts, noise]).astype(np.float64)
    rng.shuffle(out)
    return _dedup(out)


def crimes_like(n: int = 87_000, seed: int = 1) -> np.ndarray:
    """Single-metro anisotropic density with hot blocks (Chicago-ish)."""
    rng = np.random.default_rng(seed)
    n_hot = 60
    hot = rng.uniform([0, 0], [40, 60], size=(n_hot, 2))
    weights = rng.gamma(1.5, 1.0, n_hot)
    weights /= weights.sum()
    n_bg = int(n * 0.25)
    which = rng.choice(n_hot, size=n - n_bg, p=weights)
    pts = hot[which] + rng.normal(0, 0.8, (n - n_bg, 2)) * \
        np.array([1.0, 2.5])           # N-S elongated city
    # snap a fraction to a street grid (crime records geocode to blocks)
    snap = rng.uniform(size=n - n_bg) < 0.5
    pts[snap] = np.round(pts[snap] * 20) / 20 + rng.normal(
        0, 0.004, (int(snap.sum()), 2))
    bg = rng.uniform([0, 0], [40, 60], size=(n_bg, 2))
    out = np.concatenate([pts, bg]).astype(np.float64)
    rng.shuffle(out)
    return _dedup(out)


def _dedup(pts: np.ndarray) -> np.ndarray:
    """Paper preprocessing: drop exact duplicates."""
    return np.unique(pts, axis=0)


class SummedAreaTable:
    """O(1) approximate rectangle counts over a point set."""

    def __init__(self, points: np.ndarray, bins: int = 1024):
        self.lo = points.min(axis=0)
        self.hi = points.max(axis=0)
        span = np.maximum(self.hi - self.lo, 1e-12)
        self.scale = bins / span
        self.bins = bins
        ix = np.clip(((points[:, 0] - self.lo[0]) * self.scale[0]).astype(int),
                     0, bins - 1)
        iy = np.clip(((points[:, 1] - self.lo[1]) * self.scale[1]).astype(int),
                     0, bins - 1)
        hist = np.zeros((bins, bins), np.float64)
        np.add.at(hist, (ix, iy), 1.0)
        self.sat = hist.cumsum(0).cumsum(1)

    def count(self, rect: np.ndarray) -> float:
        x0, y0, x1, y1 = rect
        ix0 = int(np.clip((x0 - self.lo[0]) * self.scale[0], 0, self.bins - 1))
        iy0 = int(np.clip((y0 - self.lo[1]) * self.scale[1], 0, self.bins - 1))
        ix1 = int(np.clip((x1 - self.lo[0]) * self.scale[0], 0, self.bins - 1))
        iy1 = int(np.clip((y1 - self.lo[1]) * self.scale[1], 0, self.bins - 1))
        s = self.sat
        tot = s[ix1, iy1]
        if ix0 > 0:
            tot -= s[ix0 - 1, iy1]
        if iy0 > 0:
            tot -= s[ix1, iy0 - 1]
        if ix0 > 0 and iy0 > 0:
            tot += s[ix0 - 1, iy0 - 1]
        return float(tot)


class _GridBuckets:
    """Point buckets on a uniform grid for fast local neighbourhood queries."""

    def __init__(self, points: np.ndarray, bins: int = 256):
        self.pts = points
        self.lo = points.min(axis=0)
        span = np.maximum(points.max(axis=0) - self.lo, 1e-12)
        self.scale = bins / span
        self.bins = bins
        ij = np.clip(((points - self.lo) * self.scale).astype(int),
                     0, bins - 1)
        key = ij[:, 0] * bins + ij[:, 1]
        order = np.argsort(key, kind="stable")
        self.sorted_idx = order
        self.key_sorted = key[order]
        self.starts = np.searchsorted(self.key_sorted,
                                      np.arange(bins * bins))
        self.ends = np.searchsorted(self.key_sorted,
                                    np.arange(bins * bins) + 1)

    def ring(self, cx: int, cy: int, r: int) -> np.ndarray:
        """Point indices in the square ring of cell-radius r around (cx,cy)."""
        b = self.bins
        cells = []
        x0, x1 = max(cx - r, 0), min(cx + r, b - 1)
        y0, y1 = max(cy - r, 0), min(cy + r, b - 1)
        for x in range(x0, x1 + 1):
            for y in range(y0, y1 + 1):
                if r == 0 or x in (cx - r, cx + r) or y in (cy - r, cy + r):
                    k = x * b + y
                    s, e = self.starts[k], self.ends[k]
                    if e > s:
                        cells.append(self.sorted_idx[s:e])
        return np.concatenate(cells) if cells else np.empty(0, np.int64)


def synth_queries(points: np.ndarray, selectivity: float, n_queries: int,
                  seed: int = 0, aspect_jitter: float = 2.0) -> np.ndarray:
    """Fixed-selectivity rectangles centered on random data points.

    Exact calibration: the rectangle half-width is set to the k-th smallest
    anisotropic L∞ distance from the center, so each query returns exactly
    ≈ ``selectivity · N`` points (paper §V-B2: 0.00001 → ~20 of 2M, etc.).
    """
    rng = np.random.default_rng(seed)
    n = points.shape[0]
    k = max(1, int(round(selectivity * n)))
    gb = _GridBuckets(points)
    out = np.empty((n_queries, 4), np.float64)
    centers = points[rng.integers(0, n, n_queries)]
    aspects = np.exp(rng.uniform(-np.log(aspect_jitter),
                                 np.log(aspect_jitter), n_queries))
    span = (points.max(axis=0) - points.min(axis=0))
    ar_base = span[1] / span[0]
    for i, c in enumerate(centers):
        ar = aspects[i] * ar_base
        cx = int(np.clip((c[0] - gb.lo[0]) * gb.scale[0], 0, gb.bins - 1))
        cy = int(np.clip((c[1] - gb.lo[1]) * gb.scale[1], 0, gb.bins - 1))
        got: list[np.ndarray] = []
        total = 0
        r = 0
        # expand rings until we certainly contain the k-th neighbour
        while r < gb.bins:
            ring = gb.ring(cx, cy, r)
            if ring.size:
                got.append(ring)
                total += ring.size
            if total >= k + 1 and r >= 1:
                break
            r += 1
        idx = np.concatenate(got) if got else np.arange(n)
        p = points[idx]
        m = np.maximum(np.abs(p[:, 0] - c[0]), np.abs(p[:, 1] - c[1]) / ar)
        m.sort()
        w = m[min(k - 1, m.size - 1)] * 1.0000001 + 1e-12
        out[i] = (c[0] - w, c[1] - ar * w, c[0] + w, c[1] + ar * w)
    return out.astype(np.float32)


def bucket_by_alpha(workload, buckets=(0.1, 0.25, 0.5, 0.75, 1.0),
                    per_bucket: int = 1000, tol: float = 0.08,
                    seed: int = 0) -> dict:
    """Partition a labelled workload into the paper's α buckets.

    Returns {bucket_value: Workload subset} keeping ≤ per_bucket queries whose
    α lies within ``tol`` of the bucket center (the paper uses "up to 1000
    queries" per α value).
    """
    rng = np.random.default_rng(seed)
    res = {}
    for b in buckets:
        d = np.abs(workload.alpha - b)
        idx = np.flatnonzero(d <= tol)
        if idx.size > per_bucket:
            idx = rng.choice(idx, per_bucket, replace=False)
        res[b] = workload.subset(np.sort(idx))
    return res
