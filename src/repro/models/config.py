"""Model configuration covering all ten assigned architecture families.

One frozen dataclass drives the whole zoo; each ``src/repro/configs/<id>.py``
instantiates it with the published numbers. Divisibility for the production
mesh is handled by padding (``vocab_padded``) and flattened-projection
sharding (head·d_head axes), never by changing the published shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    # --- attention flavour
    qkv_bias: bool = False
    attn_softcap: float = 0.0            # gemma2: 50.0 on attn logits
    logit_softcap: float = 0.0           # gemma2: 30.0 on output logits
    window: int = 0                      # sliding-window size (0 = full)
    layer_pattern: str = "causal"        # causal | alt_local_global | swa
    rope_theta: float = 10_000.0
    # --- MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora: int = 512
    q_lora: int = 0
    rope_head_dim: int = 64
    mla_d_nope: int = 128
    mla_d_v: int = 128
    # --- MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                    # per-expert hidden dim
    n_dense_layers: int = 0              # leading dense layers (deepseek)
    capacity_factor: float = 1.25        # expert capacity vs perfect balance
    # --- SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 1
    # --- encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500                  # stubbed frontend frames
    # --- modality stub: "none" means tokens; otherwise input embeddings
    frontend: str = "none"               # none | audio | vision
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                    # silu | gelu
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    # Python-unroll the layer stacks instead of lax.scan. Used by the
    # dry-run's differential cost accounting: XLA's cost_analysis counts a
    # scan body ONCE regardless of trip count, so true per-step FLOPs /
    # bytes / collective totals are extracted from small unrolled lowerings
    # (L=1 vs L=2) and scaled. Never enable for real full-depth lowerings.
    unroll_layers: bool = False

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up so the embedding shards over 256 lanes/devices."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        if self.use_mla:
            return self.n_heads * (self.mla_d_nope + self.rope_head_dim)
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k decode shape."""
        return (self.family in ("ssm", "hybrid")
                or (self.window > 0 and self.layer_pattern == "swa"))

    def n_params(self) -> int:
        """Approximate parameter count (embedding included once if tied)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            # rwkv6: tm (r,k,v,w,g,out ≈ 6 d²) + ffn (k: d·f, v: f·d, r: d²)
            per = 6 * d * d + 2 * d * f + d * d
            return L * per + emb
        if self.use_mla:
            att = (d * self.q_lora + self.q_lora * self.q_dim if self.q_lora
                   else d * self.q_dim)
            att += d * (self.kv_lora + self.rope_head_dim)
            att += self.kv_lora * self.n_heads * (self.mla_d_nope
                                                  + self.mla_d_v)
            att += self.n_heads * self.mla_d_v * d
        else:
            att = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family in ("moe",):
            dense_ff = 3 * d * f
            moe_ff = (self.n_experts + self.n_shared_experts) * 3 * d * \
                self.d_expert + d * self.n_experts
            n_moe = L - self.n_dense_layers
            ff_total = self.n_dense_layers * dense_ff + n_moe * moe_ff
        else:
            ff_total = L * 3 * d * f
        total = L * att + ff_total + emb
        if self.family == "hybrid":
            di = d * self.ssm_expand
            total += L * (2 * d * di + di * d + di * self.ssm_state * 2)
        if self.family == "encdec":
            total += self.n_enc_layers * (4 * d * d + 3 * d * f)
            total += L * 4 * d * d   # cross-attention
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k + shared only)."""
        if self.family != "moe":
            return self.n_params()
        d, L = self.d_model, self.n_layers
        n_moe = L - self.n_dense_layers
        inactive = n_moe * (self.n_experts - self.top_k) * 3 * d * \
            self.d_expert
        return int(self.n_params() - inactive)
