"""Assigned-architecture substrate: pure-JAX transformer / SSM / MoE zoo."""
