"""Shared primitives: initializers, norms, activations, sharding constraints."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def dense_init(key, shape, scale: Optional[float] = None,
               dtype=jnp.float32) -> jnp.ndarray:
    """Truncated-normal fan-in init (all linear layers)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * scale).astype(dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layernorm(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
              eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """gemma2-style tanh soft capping."""
    if cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


def with_sharding(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """Best-effort activation sharding constraint (no-op off-mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, KeyError, TypeError):
        return x


def shard_batch(x: jnp.ndarray) -> jnp.ndarray:
    """Pin a [batch, ...] activation to the data-parallel layout.

    Applied at layer boundaries so GSPMD never 'helpfully' replicates the
    full global-batch activation between differently-sharded matmuls (the
    §Perf replication-storm fix — worth ~100× collective bytes on the
    train cells). Tries (pod, data) then data; silently no-ops off-mesh.
    """
    rest = (None,) * (x.ndim - 1)
    for spec in (P(("pod", "data"), *rest), P("data", *rest)):
        try:
            return jax.lax.with_sharding_constraint(x, spec)
        except (ValueError, RuntimeError, KeyError, TypeError):
            continue
    return x
