"""Attention flavours: GQA (+bias/softcap/sliding-window), MLA, cross-attn.

Full-sequence attention is computed **blockwise** (flash-style online
softmax over KV chunks) so 32k-token prefill never materializes an [S, S]
score matrix; decode attends densely over the cache (an [B, H, S] row is
cheap). Sliding-window layers restrict the KV chunk range per Q chunk, so
window FLOPs are actually skipped, not just masked.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import softcap
from repro.models.rope import apply_rope

NEG_INF = -2.0 ** 30


def _online_chunk(q, k, v, mask, cap):
    """One flash chunk: q [B,Hq,Tq,D], k/v [B,Hkv,Tk,D], mask [Tq,Tk]|None.

    Returns (scores_max [B,Hq,Tq], exp_sum, acc [B,Hq,Tq,Dv]) partials.
    """
    G = q.shape[1] // k.shape[1]
    B, Hkv, Tk, D = k.shape
    qg = q.reshape(B, Hkv, G, q.shape[2], D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    s = softcap(s, cap)
    if mask is not None:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return m, l, acc


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool, window: int = 0, cap: float = 0.0,
                        q_chunk: int = 1024, kv_chunk: int = 1024
                        ) -> jnp.ndarray:
    """q [B,Hq,S,D], k/v [B,Hkv,S,Dk/Dv] → [B,Hq,S,Dv]. GQA via head groups.

    ``window`` > 0 ⇒ token i attends to (i-window, i]; KV chunks wholly
    outside the window are not computed at all.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    if causal:
        assert Sq == Sk, "causal attention requires equal q/k lengths"
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    n_q = (Sq + q_chunk - 1) // q_chunk
    n_k = (Sk + kv_chunk - 1) // kv_chunk
    out = []
    for qi in range(n_q):
        q0 = qi * q_chunk
        qs = q[:, :, q0:q0 + q_chunk]
        Tq = qs.shape[2]
        # static KV range for this q chunk
        k_hi = n_k if not causal else (q0 + Tq + kv_chunk - 1) // kv_chunk
        k_lo = 0
        if window > 0:
            k_lo = max(0, (q0 - window) // kv_chunk)
        m_run = jnp.full((B, Hkv, G, Tq), NEG_INF, jnp.float32)
        l_run = jnp.zeros((B, Hkv, G, Tq), jnp.float32)
        a_run = jnp.zeros((B, Hkv, G, Tq, Dv), jnp.float32)
        for ki in range(k_lo, k_hi):
            k0 = ki * kv_chunk
            ks = k[:, :, k0:k0 + kv_chunk]
            vs = v[:, :, k0:k0 + kv_chunk]
            Tk = ks.shape[2]
            qpos = q0 + jnp.arange(Tq)
            kpos = k0 + jnp.arange(Tk)
            mask = jnp.ones((Tq, Tk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window > 0:
                mask &= qpos[:, None] - kpos[None, :] < window
            m, l, acc = _online_chunk(qs, ks, vs, mask, cap)
            m_new = jnp.maximum(m_run, m)
            sc_old = jnp.exp(m_run - m_new)
            sc_new = jnp.exp(m - m_new)
            l_run = l_run * sc_old + l * sc_new
            a_run = a_run * sc_old[..., None] + acc * sc_new[..., None]
            m_run = m_new
        o = a_run / jnp.maximum(l_run[..., None], 1e-30)
        out.append(o.reshape(B, Hq, Tq, Dv))
    return jnp.concatenate(out, axis=2).astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, length, *,
                     cap: float = 0.0) -> jnp.ndarray:
    """Single-token decode: q [B,Hq,1,D], caches [B,Hkv,S,D*].

    ``length`` masks the not-yet-written tail of the cache.
    """
    B, Hq, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / jnp.sqrt(D)
    s = softcap(s, cap)
    valid = jnp.arange(S)[None, :] < length[:, None]          # [B, S]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, 1, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# projection helpers (params are dicts of stacked arrays; see transformer.py)
# ---------------------------------------------------------------------------

def gqa_qkv(cfg: ModelConfig, p: dict, x: jnp.ndarray, positions
            ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x [B,S,d] → q [B,H,S,Dh], k/v [B,Hkv,S,Dh] with RoPE applied."""
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dk->bsk", x, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return (q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3))


class MLAProj(NamedTuple):
    q_nope: jnp.ndarray   # [B, H, S, d_nope]
    q_rope: jnp.ndarray   # [B, H, S, d_rope]
    c_kv: jnp.ndarray     # [B, S, kv_lora]    ← the compressed cache
    k_rope: jnp.ndarray   # [B, S, d_rope]     ← shared across heads


def mla_project(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                positions) -> MLAProj:
    """DeepSeek-V2 multi-head latent attention projections."""
    B, S, _ = x.shape
    H = cfg.n_heads
    if cfg.q_lora:
        cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
        q = jnp.einsum("bsr,rq->bsq", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    q = q.reshape(B, S, H, cfg.mla_d_nope + cfg.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [cfg.mla_d_nope], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckr = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv, k_rope = jnp.split(ckr, [cfg.kv_lora], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]
    return MLAProj(q_nope.transpose(0, 2, 1, 3),
                   q_rope.transpose(0, 2, 1, 3), c_kv, k_rope)


def mla_attention(cfg: ModelConfig, p: dict, proj: MLAProj, *,
                  causal: bool = True, q_chunk: int = 1024,
                  kv_chunk: int = 1024) -> jnp.ndarray:
    """Materialize per-head K/V from the latent and run blockwise attention.

    (The decode path instead keeps K/V in latent form — see serving/decode.)
    Returns [B, S, H·d_v].
    """
    B, H, S, _ = proj.q_nope.shape
    wk = p["wkv_b"][:, :H * cfg.mla_d_nope]
    wv = p["wkv_b"][:, H * cfg.mla_d_nope:]
    k_nope = jnp.einsum("bsr,rk->bsk", proj.c_kv, wk).reshape(
        B, S, H, cfg.mla_d_nope).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsr,rk->bsk", proj.c_kv, wv).reshape(
        B, S, H, cfg.mla_d_v).transpose(0, 2, 1, 3)
    k_rope = jnp.broadcast_to(proj.k_rope[:, None],
                              (B, H, S, cfg.rope_head_dim))
    q = jnp.concatenate([proj.q_nope, proj.q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    o = blockwise_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
    return o.transpose(0, 2, 1, 3).reshape(B, S, H * cfg.mla_d_v)
