"""Model zoo core: init / train-forward / decode for all ten architectures.

Pure JAX (no flax): params are nested dicts of arrays; decoder blocks are
stacked ``[L, ...]`` and driven by ``jax.lax.scan`` (one traced layer body →
small HLO even for 126-layer models) with a remat policy around the body.

Families:
  dense   — llama3 / qwen2 / gemma2 / h2o-danube (GQA, softcap, SWA, bias)
  moe     — deepseek-moe / deepseek-v2 (shared+routed experts; v2 adds MLA)
  ssm     — rwkv6 (attention-free; Pallas WKV kernel)
  hybrid  — hymba (parallel SWA-attention + Mamba heads)
  encdec  — whisper (stub audio frontend; cross-attention decoder)

Gemma2's local/global alternation is handled by scanning over layer *pairs*
so chunk scheduling in blockwise attention stays static.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moelib
from repro.models import ssm as ssmlib
from repro.models.config import ModelConfig
from repro.models.layers import (act_fn, dense_init, rmsnorm,
                                 shard_batch, softcap)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_params(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Params = {}
    if cfg.use_mla:
        if cfg.q_lora:
            p["wq_a"] = dense_init(ks[0], (d, cfg.q_lora), dtype=dtype)
            p["wq_b"] = dense_init(ks[1], (cfg.q_lora, cfg.q_dim),
                                   dtype=dtype)
        else:
            p["wq"] = dense_init(ks[0], (d, cfg.q_dim), dtype=dtype)
        p["wkv_a"] = dense_init(
            ks[2], (d, cfg.kv_lora + cfg.rope_head_dim), dtype=dtype)
        p["wkv_b"] = dense_init(
            ks[3], (cfg.kv_lora,
                    cfg.n_heads * (cfg.mla_d_nope + cfg.mla_d_v)),
            dtype=dtype)
        p["wo"] = dense_init(ks[4], (cfg.n_heads * cfg.mla_d_v, d),
                             dtype=dtype)
    else:
        p["wq"] = dense_init(ks[0], (d, cfg.q_dim), dtype=dtype)
        p["wk"] = dense_init(ks[1], (d, cfg.kv_dim), dtype=dtype)
        p["wv"] = dense_init(ks[2], (d, cfg.kv_dim), dtype=dtype)
        p["wo"] = dense_init(ks[3], (cfg.q_dim, d), dtype=dtype)
        if cfg.qkv_bias:
            p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
            p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
            p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def _mlp_params(cfg: ModelConfig, key, dtype, d_ff=None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = {"wi": dense_init(ks[0], (d, d_ff), dtype=dtype),
         "wo2": dense_init(ks[2], (d_ff, d), dtype=dtype)}
    if cfg.act == "silu":  # gated (llama-style); whisper uses plain gelu
        p["wg"] = dense_init(ks[1], (d, d_ff), dtype=dtype)
    return p


def _moe_params(cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 7)
    d, E, de = cfg.d_model, cfg.n_experts, cfg.d_expert
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "wi": dense_init(ks[1], (E, d, de), dtype=dtype),
        "wg": dense_init(ks[2], (E, d, de), dtype=dtype),
        "wo": dense_init(ks[3], (E, de, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        dsh = cfg.n_shared_experts * de
        p["sh_wi"] = dense_init(ks[4], (d, dsh), dtype=dtype)
        p["sh_wg"] = dense_init(ks[5], (d, dsh), dtype=dtype)
        p["sh_wo"] = dense_init(ks[6], (dsh, d), dtype=dtype)
    return p


def _rwkv_params(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dk = d // H
    r = 32  # token-shift LoRA rank
    ks = iter(jax.random.split(key, 32))
    p: Params = {}
    for nm in ("r", "k", "v", "w", "g"):
        p[f"mu_{nm}"] = jnp.full((d,), 0.5, dtype)
        p[f"la_{nm}"] = dense_init(next(ks), (d, r), dtype=dtype)
        p[f"lb_{nm}"] = dense_init(next(ks), (r, d), dtype=dtype)
    for nm in ("wr", "wk", "wv", "wg", "wo"):
        p[nm] = dense_init(next(ks), (d, d), dtype=dtype)
    p["w_base"] = jnp.full((d,), -2.0, dtype)          # decay ≈ exp(-e^-2)
    p["la_wd"] = dense_init(next(ks), (d, 64), dtype=dtype)
    p["lb_wd"] = dense_init(next(ks), (64, d), dtype=dtype)
    p["u"] = dense_init(next(ks), (H, dk), dtype=jnp.float32)
    p["ln_x"] = jnp.zeros((d,), dtype)
    p["mu_ck"] = jnp.full((d,), 0.5, dtype)
    p["mu_cr"] = jnp.full((d,), 0.5, dtype)
    p["wck"] = dense_init(next(ks), (d, cfg.d_ff), dtype=dtype)
    p["wcv"] = dense_init(next(ks), (cfg.d_ff, d), dtype=dtype)
    p["wcr"] = dense_init(next(ks), (d, d), dtype=dtype)
    return p


def _mamba_params(cfg: ModelConfig, key, dtype) -> Params:
    d = cfg.d_model
    di = d * cfg.ssm_expand
    N = cfg.ssm_state
    ks = iter(jax.random.split(key, 9))
    return {
        "w_in": dense_init(next(ks), (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(next(ks), (cfg.ssm_conv, di), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "w_dt_a": dense_init(next(ks), (di, 64), dtype=dtype),
        "w_dt_b": dense_init(next(ks), (64, di), dtype=dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),       # softplus ≈ 0.01
        "w_B": dense_init(next(ks), (di, N), dtype=dtype),
        "w_C": dense_init(next(ks), (di, N), dtype=dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))),
        "D": jnp.ones((di,), dtype),
        "w_out": dense_init(next(ks), (di, d), dtype=dtype),
        "norm_attn": jnp.zeros((d,), dtype),
        "norm_ssm": jnp.zeros((d,), dtype),
        "beta_attn": jnp.ones((), jnp.float32),
        "beta_ssm": jnp.ones((), jnp.float32),
    }


def _block_params(cfg: ModelConfig, key, dtype, moe_layer: bool) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"norm1": jnp.zeros((d,), dtype),
                 "norm2": jnp.zeros((d,), dtype)}
    if cfg.family == "ssm":
        p.update(_rwkv_params(cfg, ks[0], dtype))
        return p
    p["attn"] = _attn_params(cfg, ks[0], dtype)
    if cfg.name.startswith("gemma2"):
        p["norm_post1"] = jnp.zeros((d,), dtype)
        p["norm_post2"] = jnp.zeros((d,), dtype)
    if moe_layer:
        p["moe"] = _moe_params(cfg, ks[1], dtype)
    else:
        p["mlp"] = _mlp_params(cfg, ks[1], dtype)
    if cfg.family == "hybrid":
        p["ssm"] = _mamba_params(cfg, ks[2], dtype)
    return p


def _stack(params_list):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *params_list)


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16,
                stacked: bool = True) -> Params:
    """Initialize the full parameter pytree.

    ``stacked=True`` initializes ONE layer and broadcasts it L times (cheap;
    used for smoke/dry-run). Training from scratch wants per-layer keys
    (``stacked=False`` is not needed — pass unique data instead).
    """
    keys = jax.random.split(key, 8)
    d, Vp = cfg.d_model, cfg.vocab_padded
    params: Params = {
        "embed": dense_init(keys[0], (Vp, d), scale=0.02, dtype=dtype),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], (d, Vp), dtype=dtype)

    n_moe = cfg.n_layers - cfg.n_dense_layers if cfg.family == "moe" else 0
    one = _block_params(cfg, keys[2], dtype,
                        moe_layer=(cfg.family == "moe"))
    L_scan = (n_moe if cfg.family == "moe" else cfg.n_layers)
    if cfg.layer_pattern == "alt_local_global":
        assert cfg.n_layers % 2 == 0
        pair = {"local": one,
                "global": _block_params(cfg, keys[3], dtype, False)}
        params["layers"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers // 2,)
                                       + x.shape), pair)
    else:
        params["layers"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L_scan,) + x.shape), one)
    if cfg.family == "moe" and cfg.n_dense_layers:
        dense_one = _block_params(cfg, keys[4], dtype, moe_layer=False)
        params["dense_layers"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_dense_layers,)
                                       + x.shape), dense_one)
    if cfg.family == "encdec":
        enc_one = {"norm1": jnp.zeros((d,), dtype),
                   "norm2": jnp.zeros((d,), dtype),
                   "attn": _attn_params(cfg, keys[5], dtype),
                   "mlp": _mlp_params(cfg, keys[6], dtype)}
        params["enc_layers"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_enc_layers,)
                                       + x.shape), enc_one)
        params["enc_norm"] = jnp.zeros((d,), dtype)
        params["enc_pos"] = dense_init(keys[7], (cfg.enc_seq, d),
                                       scale=0.02, dtype=dtype)
        # decoder blocks additionally carry cross-attention
        cross = {"norm_x": jnp.zeros((d,), dtype),
                 "xattn": _attn_params(cfg, keys[3], dtype)}
        params["layers"] = {
            **params["layers"],
            **jax.tree.map(lambda x: jnp.broadcast_to(
                x[None], (cfg.n_layers,) + x.shape), cross)}
        # learned decoder positions sized for the largest decode cell
        params["dec_pos"] = dense_init(keys[2], (32768, d), scale=0.02,
                                       dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# train-time forward
# ---------------------------------------------------------------------------

def _mlp(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    a = act_fn(cfg.act)
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if "wg" in p:
        h = a(jnp.einsum("bsd,df->bsf", x, p["wg"])) * h
    else:
        h = a(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo2"])


def _attn_block(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions,
                *, causal: bool, window: int) -> jnp.ndarray:
    B, S, d = x.shape
    if cfg.use_mla:
        proj = attn.mla_project(cfg, p, x, positions)
        o = attn.mla_attention(cfg, p, proj, causal=causal)
    else:
        q, k, v = attn.gqa_qkv(cfg, p, x, positions)
        o = attn.blockwise_attention(q, k, v, causal=causal, window=window,
                                     cap=cfg.attn_softcap)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.q_dim)
    return jnp.einsum("bsq,qd->bsd", o, p["wo"])


def _dense_block(cfg: ModelConfig, p: Params, x: jnp.ndarray, positions, *,
                 window: int, use_moe: bool = False) -> jnp.ndarray:
    x = shard_batch(x)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    a = _attn_block(cfg, p["attn"], h, positions, causal=True, window=window)
    if cfg.family == "hybrid":
        m, _ = ssmlib.mamba_head(
            cfg, p["ssm"], h, ssmlib.mamba_zero_state(cfg, x.shape[0]))
        a = ((p["ssm"]["beta_attn"] *
              rmsnorm(a, p["ssm"]["norm_attn"], cfg.norm_eps)
              + p["ssm"]["beta_ssm"] *
              rmsnorm(m, p["ssm"]["norm_ssm"], cfg.norm_eps)) * 0.5
             ).astype(x.dtype)
    if "norm_post1" in p:
        a = rmsnorm(a, p["norm_post1"], cfg.norm_eps)
    x = x + a
    h = rmsnorm(x, p["norm2"], cfg.norm_eps)
    if use_moe:
        f, _ = moelib.moe_ffn(cfg, p["moe"], h)
    else:
        f = _mlp(cfg, p["mlp"], h)
    if "norm_post2" in p:
        f = rmsnorm(f, p["norm_post2"], cfg.norm_eps)
    return x + f


def _rwkv_block(cfg: ModelConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    x = shard_batch(x)
    B = x.shape[0]
    zeros = jnp.zeros((B, cfg.d_model), x.dtype)
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    tm, _, _ = ssmlib.rwkv_time_mix(
        cfg, p, h, zeros, jnp.zeros((B, cfg.n_heads,
                                     cfg.d_model // cfg.n_heads,
                                     cfg.d_model // cfg.n_heads)))
    x = x + tm
    h = rmsnorm(x, p["norm2"], cfg.norm_eps)
    cm, _ = ssmlib.rwkv_channel_mix(cfg, p, h, zeros)
    return x + cm


def scan_layers(body, x, xs_tree, unroll: bool):
    """lax.scan over stacked layer params, or a Python unroll.

    The unrolled form exists for the dry-run's cost accounting (XLA's
    cost_analysis counts a scan body once regardless of trip count).
    """
    if not unroll:
        return jax.lax.scan(body, x, xs_tree)
    L = jax.tree.leaves(xs_tree)[0].shape[0]
    ys = []
    for layer in range(L):
        sl = jax.tree.map(lambda a: a[layer], xs_tree)
        x, y = body(x, sl)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *z: jnp.stack(z), *ys)
    else:
        ys = None
    return x, ys


def _remat(f, policy: Optional[str]):
    if policy == "none" or policy is None:
        return f
    pol = dict(
        full=None,
        dots=jax.checkpoint_policies.checkpoint_dots,
        dots_no_batch=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    )[policy]
    return jax.checkpoint(f, policy=pol)


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            *, remat_policy: Optional[str] = "dots") -> jnp.ndarray:
    """Training/prefill forward → logits [B, S, vocab_padded].

    ``batch``: {"tokens": [B,S]} or {"embeds": [B,S,d]} (modality stubs),
    plus {"frames": [B,enc_seq,d]} for the enc-dec family.
    """
    if "embeds" in batch:
        x = batch["embeds"].astype(params["embed"].dtype)
        B, S, _ = x.shape
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens]
    x = shard_batch(x)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    enc_out = None
    if cfg.family == "encdec":
        f = batch["frames"].astype(x.dtype)
        e = f + params["enc_pos"][None, :f.shape[1]]

        def enc_body(h, lp):
            hn = rmsnorm(h, lp["norm1"], cfg.norm_eps)
            # bidirectional attention; positions=0 ⇒ RoPE is the identity
            # (whisper uses the learned enc_pos embedding instead)
            q, k, v = attn.gqa_qkv(cfg, lp["attn"], hn, positions=jnp.zeros(
                (B, f.shape[1]), jnp.int32))
            o = attn.blockwise_attention(q, k, v, causal=False, window=0)
            o = o.transpose(0, 2, 1, 3).reshape(B, f.shape[1], cfg.q_dim)
            h = h + jnp.einsum("bsq,qd->bsd", o, lp["attn"]["wo"])
            hn = rmsnorm(h, lp["norm2"], cfg.norm_eps)
            return h + _mlp(cfg, lp["mlp"], hn), None

        e, _ = scan_layers(_remat(enc_body, remat_policy), e,
                           params["enc_layers"], cfg.unroll_layers)
        enc_out = rmsnorm(e, params["enc_norm"], cfg.norm_eps)
        x = x + params["dec_pos"][None, :S]

    window = cfg.window if cfg.layer_pattern == "swa" else 0

    if cfg.family == "ssm":
        def body(h, lp):
            return _rwkv_block(cfg, lp, h), None
        x, _ = scan_layers(_remat(body, remat_policy), x, params["layers"],
                           cfg.unroll_layers)
    elif cfg.layer_pattern == "alt_local_global":
        def body(h, lp):
            h = _dense_block(cfg, lp["local"], h, positions,
                             window=cfg.window)
            h = _dense_block(cfg, lp["global"], h, positions, window=0)
            return h, None
        x, _ = scan_layers(_remat(body, remat_policy), x, params["layers"],
                           cfg.unroll_layers)
    elif cfg.family == "encdec":
        def body(h, lp):
            # self-attention → cross-attention → MLP (whisper block order;
            # the decode path in serving/decode.py mirrors this exactly)
            hn = rmsnorm(h, lp["norm1"], cfg.norm_eps)
            h = h + _attn_block(cfg, lp["attn"], hn, positions, causal=True,
                                window=0)
            hn = rmsnorm(h, lp["norm_x"], cfg.norm_eps)
            q = jnp.einsum("bsd,dq->bsq", hn, lp["xattn"]["wq"]).reshape(
                B, S, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
            k = jnp.einsum("bsd,dk->bsk", enc_out, lp["xattn"]["wk"]).reshape(
                B, -1, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
            v = jnp.einsum("bsd,dk->bsk", enc_out, lp["xattn"]["wv"]).reshape(
                B, -1, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
            o = attn.blockwise_attention(q, k, v, causal=False, window=0)
            o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.q_dim)
            h = h + jnp.einsum("bsq,qd->bsd", o, lp["xattn"]["wo"])
            hn = rmsnorm(h, lp["norm2"], cfg.norm_eps)
            return h + _mlp(cfg, lp["mlp"], hn), None
        x, _ = scan_layers(_remat(body, remat_policy), x, params["layers"],
                           cfg.unroll_layers)
    else:
        use_moe = cfg.family == "moe"
        if use_moe and "dense_layers" in params:
            def dbody(h, lp):
                return _dense_block(cfg, lp, h, positions, window=window,
                                    use_moe=False), None
            x, _ = scan_layers(_remat(dbody, remat_policy), x,
                               params["dense_layers"], cfg.unroll_layers)

        def body(h, lp):
            return _dense_block(cfg, lp, h, positions, window=window,
                                use_moe=use_moe), None
        x, _ = scan_layers(_remat(body, remat_policy), x, params["layers"],
                           cfg.unroll_layers)

    x = shard_batch(rmsnorm(x, params["final_norm"], cfg.norm_eps))
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = shard_batch(jnp.einsum("bsd,dv->bsv", x, head))
    return softcap(logits, cfg.logit_softcap)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict[str, jnp.ndarray],
            *, remat_policy: Optional[str] = "dots") -> jnp.ndarray:
    """Next-token cross entropy over the logical vocab."""
    logits = forward(cfg, params, batch, remat_policy=remat_policy)
    labels = batch["labels"]
    logits = logits[..., :cfg.vocab].astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    return jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
