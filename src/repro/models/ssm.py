"""State-space blocks: RWKV-6 (Finch) and a Mamba head (for Hymba).

RWKV-6 is attention-free: time-mix (the WKV linear-attention scan with
data-dependent per-channel decay — Pallas kernel ``repro.kernels.wkv6``) +
channel-mix. The data-dependent token-shift interpolation uses the low-rank
(LoRA) parameterization of the paper.

The Mamba head is the selective-SSM recurrence (Δ, B, C data-dependent,
diagonal A) with a depthwise causal conv front; Hymba runs it in parallel
with sliding-window attention heads and mean-combines the normalized
outputs (per the Hymba paper).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm


# ---------------------------------------------------------------------------
# RWKV-6
# ---------------------------------------------------------------------------

class RWKVState(NamedTuple):
    tm_shift: jnp.ndarray   # [B, d] last token (time-mix shift)
    cm_shift: jnp.ndarray   # [B, d] last token (channel-mix shift)
    wkv: jnp.ndarray        # [B, H, dk, dv] linear-attention state


def rwkv_zero_state(cfg: ModelConfig, batch: int, dtype=jnp.float32
                    ) -> RWKVState:
    H = cfg.n_heads
    dk = cfg.d_model // H
    return RWKVState(
        tm_shift=jnp.zeros((batch, cfg.d_model), dtype),
        cm_shift=jnp.zeros((batch, cfg.d_model), dtype),
        wkv=jnp.zeros((batch, H, dk, dk), jnp.float32),
    )


def _ddlerp(x, xx, mu, lora_a, lora_b):
    """Data-dependent interpolation (RWKV-6 token shift).

    x/xx: [B,S,d]; mu: [d]; lora_a: [d,r]; lora_b: [r,d].
    """
    base = x + (xx - x) * mu
    dyn = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, lora_a))
    mix = mu + jnp.einsum("bsr,rd->bsd", dyn, lora_b)
    return x + (xx - x) * mix


def rwkv_time_mix(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                  shift_in: jnp.ndarray, wkv_in: jnp.ndarray,
                  use_kernel: bool = True):
    """x [B,S,d] → (out [B,S,d], last_token [B,d], wkv_out).

    For training (S>1) the incoming wkv state is zero (sequence start); for
    decode (S=1) states thread through.
    """
    B, S, d = x.shape
    H = cfg.n_heads
    dk = d // H
    xx = jnp.concatenate([shift_in[:, None, :], x[:, :-1]], axis=1)
    r_in = _ddlerp(x, xx, p["mu_r"], p["la_r"], p["lb_r"])
    k_in = _ddlerp(x, xx, p["mu_k"], p["la_k"], p["lb_k"])
    v_in = _ddlerp(x, xx, p["mu_v"], p["la_v"], p["lb_v"])
    w_in = _ddlerp(x, xx, p["mu_w"], p["la_w"], p["lb_w"])
    g_in = _ddlerp(x, xx, p["mu_g"], p["la_g"], p["lb_g"])

    r = jnp.einsum("bsd,de->bse", r_in, p["wr"])
    k = jnp.einsum("bsd,de->bse", k_in, p["wk"])
    v = jnp.einsum("bsd,de->bse", v_in, p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", g_in, p["wg"]))
    # per-channel decay in (0,1): w = exp(-exp(wl))
    wl = p["w_base"] + jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(jnp.einsum("bsd,dr->bsr", w_in, p["la_wd"])),
        p["lb_wd"])
    w = jnp.exp(-jnp.exp(wl.astype(jnp.float32)))

    def heads(a):
        return a.reshape(B, S, H, dk).transpose(0, 2, 1, 3).reshape(
            B * H, S, dk)

    u = jnp.broadcast_to(p["u"][None], (B, H, dk)).reshape(B * H, dk)
    if S == 1:
        # decode: one recurrence step against the carried state
        rt = heads(r).astype(jnp.float32)[:, 0]
        kt = heads(k).astype(jnp.float32)[:, 0]
        vt = heads(v).astype(jnp.float32)[:, 0]
        wt = heads(w)[:, 0]
        Sst = wkv_in.reshape(B * H, dk, dk)
        kv = kt[:, :, None] * vt[:, None, :]
        y = jnp.einsum("nd,nde->ne", rt, Sst + u[:, :, None] * kv)
        S_new = wt[:, :, None] * Sst + kv
        wkv_out = S_new.reshape(B, H, dk, dk)
        o = y.reshape(B, H, 1, dk)
    else:
        from repro.kernels import ops as kops
        y = kops.wkv6(heads(r).astype(jnp.float32),
                      heads(k).astype(jnp.float32),
                      heads(v).astype(jnp.float32),
                      heads(w), u) if use_kernel else None
        if y is None:
            from repro.kernels import ref as kref
            y = kref.wkv6(heads(r), heads(k), heads(v), heads(w), u)
        o = y.reshape(B, H, S, dk)
        wkv_out = wkv_in  # training path does not thread state across calls
    o = o.transpose(0, 2, 1, 3)                        # [B,S,H,dk]
    # per-head group norm, then output gate + projection
    o = rmsnorm(o, p["ln_x"].reshape(H, dk), cfg.norm_eps)
    o = o.reshape(B, S, d).astype(x.dtype) * g
    out = jnp.einsum("bse,ed->bsd", o, p["wo"])
    return out, x[:, -1, :], wkv_out


def rwkv_channel_mix(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                     shift_in: jnp.ndarray):
    B, S, d = x.shape
    xx = jnp.concatenate([shift_in[:, None, :], x[:, :-1]], axis=1)
    xk = x + (xx - x) * p["mu_ck"]
    xr = x + (xx - x) * p["mu_cr"]
    k = jnp.einsum("bsd,df->bsf", xk, p["wck"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wcv"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wcr"])) * kv
    return out, x[:, -1, :]


# ---------------------------------------------------------------------------
# Mamba head (Hymba's parallel SSM)
# ---------------------------------------------------------------------------

class MambaState(NamedTuple):
    conv: jnp.ndarray   # [B, K-1, di] conv tail
    h: jnp.ndarray      # [B, di, N] SSM state


def mamba_zero_state(cfg: ModelConfig, batch: int, dtype=jnp.float32
                     ) -> MambaState:
    di = cfg.d_model * cfg.ssm_expand
    return MambaState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        h=jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    )


def mamba_head(cfg: ModelConfig, p: dict, x: jnp.ndarray,
               state: MambaState) -> tuple[jnp.ndarray, MambaState]:
    """Selective SSM: x [B,S,d] → (y [B,S,di→d], new state)."""
    B, S, d = x.shape
    di = d * cfg.ssm_expand
    N = cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])       # [B,S,2di]
    xs, z = jnp.split(xz, 2, axis=-1)
    # depthwise causal conv (kernel K) with carried tail
    K = cfg.ssm_conv
    ext = jnp.concatenate([state.conv.astype(xs.dtype), xs], axis=1)
    conv = sum(ext[:, i:i + S] * p["conv_w"][i][None, None, :]
               for i in range(K)) + p["conv_b"]
    xs = jax.nn.silu(conv)
    new_tail = ext[:, -(K - 1):] if K > 1 else state.conv

    dt = jax.nn.softplus(jnp.einsum("bse,er->bsr", xs, p["w_dt_a"])
                         @ p["w_dt_b"] + p["dt_bias"])   # [B,S,di]
    Bm = jnp.einsum("bse,en->bsn", xs, p["w_B"])         # [B,S,N]
    Cm = jnp.einsum("bse,en->bsn", xs, p["w_C"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # [di,N]

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                            # [B,di],[B,di],[B,N]
        dA = jnp.exp(dtt[:, :, None] * A[None])          # [B,di,N]
        h = h * dA + (dtt * xt)[:, :, None] * Bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    h0 = state.h
    xs32 = xs.astype(jnp.float32)
    h_new, ys = jax.lax.scan(
        step, h0,
        (xs32.transpose(1, 0, 2), dt.astype(jnp.float32).transpose(1, 0, 2),
         Bm.astype(jnp.float32).transpose(1, 0, 2),
         Cm.astype(jnp.float32).transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2).astype(x.dtype)            # [B,S,di]
    y = y + xs * p["D"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, MambaState(conv=new_tail, h=h_new)
