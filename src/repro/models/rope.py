"""Rotary position embeddings (interleaved-pair convention).

M-RoPE note (qwen2-vl): the multimodal axes of M-RoPE partition the rotary
channels between temporal/height/width position ids for *vision tokens*. The
vision frontend is a stub in this framework (``input_specs`` provides patch
embeddings), so the backbone applies the temporal component — which is
exactly standard RoPE for text tokens. See DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: [..., S, H, D], positions: [..., S] → same shape."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                      # [D/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(ang)[..., :, None, :]               # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)
