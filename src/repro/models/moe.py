"""Mixture-of-experts block (DeepSeek-style: shared + fine-grained routed).

Dispatch is the sort-based fixed-capacity formulation: every (token, slot)
pair is scattered into an ``[E, C, d]`` buffer ordered by expert, each expert
runs one dense [C, d] × [d, de] matmul (MXU-shaped), and results scatter
back weighted by the router gate. All shapes are static; capacity overflow
drops the lowest-priority duplicates (tracked, and disabled by a capacity
factor ≥ k·E/tokens).

Note the structural identity with the AI-tree's grid-of-models
(``repro.core.grid``): route → gather-to-expert → batched apply → weighted
union. The EP sharding rule (experts over the ``model`` axis) is shared.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import act_fn, with_sharding


class MoEStats(NamedTuple):
    dropped_frac: jnp.ndarray   # fraction of (token, slot) pairs dropped
    load: jnp.ndarray           # [E] tokens per expert (pre-capacity)


def route_topk(scores: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """softmax-after-topk routing (DeepSeek-MoE): [T, E] → ids/gates [T, k]."""
    top, ids = jax.lax.top_k(scores, k)
    gates = jax.nn.softmax(top, axis=-1)
    return ids.astype(jnp.int32), gates


def moe_ffn(cfg: ModelConfig, p: dict, x: jnp.ndarray,
            capacity_factor: float | None = None,
            deterministic_capacity: int | None = None
            ) -> tuple[jnp.ndarray, MoEStats]:
    """x [B, S, d] → [B, S, d].

    Params: ``router`` [d, E]; routed experts ``wi``/``wg`` [E, d, de],
    ``wo`` [E, de, d]; shared experts ``sh_wi``/``sh_wg`` [d, n_sh·de],
    ``sh_wo`` [n_sh·de, d].
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    act = act_fn(cfg.act)

    scores = jax.nn.softmax(
        jnp.einsum("td,de->te", xt.astype(jnp.float32),
                   p["router"].astype(jnp.float32)), axis=-1)
    ids, gates = route_topk(scores, k)                     # [T, k]

    cf = capacity_factor if capacity_factor is not None \
        else cfg.capacity_factor
    C = deterministic_capacity or max(1, int(T * k * cf / E))
    # ---- sort (token, slot) pairs by expert id
    flat_e = ids.reshape(-1)                               # [T·k]
    flat_g = gates.reshape(-1).astype(x.dtype)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position of each pair within its expert segment
    start = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    pos = jnp.arange(T * k, dtype=jnp.int32) - start[se]
    keep = pos < C
    load = jnp.zeros((E,), jnp.int32).at[se].add(1)

    # ---- dispatch into [E, C, d]
    # NOTE(§Perf, refuted hypothesis): forcing the dispatch buffer to
    # P("model", None, None) made the deepseek-v2 cell ~12× MORE
    # collective-bound — GSPMD implemented the token→expert scatter across
    # the forced boundary by all-gathering the token rows on every model
    # shard. Leaving the buffer's layout to propagation (it follows the
    # expert weights via the einsum) is strictly better here.
    buf = jnp.zeros((E, C, d), x.dtype)
    e_idx = jnp.where(keep, se, 0)
    c_idx = jnp.where(keep, pos, C - 1)
    rows = jnp.where(keep[:, None], xt[st], 0).astype(x.dtype)
    buf = buf.at[e_idx, c_idx].add(rows)

    # ---- expert matmuls
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"])             # [E, C, d]

    # ---- combine
    gathered = y[e_idx, c_idx]                             # [T·k, d]
    contrib = jnp.where(keep[:, None], gathered * sg[:, None], 0)
    out = jnp.zeros((T, d), x.dtype).at[st].add(contrib)

    # ---- shared experts (always-on dense path)
    if cfg.n_shared_experts:
        hs = act(jnp.einsum("td,df->tf", xt, p["sh_wg"])) * \
            jnp.einsum("td,df->tf", xt, p["sh_wi"])
        out = out + jnp.einsum("tf,fd->td", hs, p["sh_wo"])

    stats = MoEStats(
        dropped_frac=1.0 - jnp.mean(keep.astype(jnp.float32)),
        load=load)
    return out.reshape(B, S, d), stats
