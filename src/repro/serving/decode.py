"""Single-token decode steps (``serve_step``) for every family.

One new token against a cache of ``seq_len`` — the shape the ``decode_32k``
and ``long_500k`` cells lower. Layers run under ``lax.scan`` with the layer
cache as scanned xs/ys, so the decode HLO is one block body regardless of
depth.

MLA decode uses weight absorption: scores and values are computed directly
against the 512-dim latent cache (q_nope is folded through W_uk, the output
through W_uv), so per-token cache traffic is kv_lora + d_rope bytes — the
DeepSeek-V2 memory win, reproduced structurally.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import ssm as ssmlib
from repro.models.attention import decode_attention
from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm, softcap, act_fn
from repro.models.transformer import scan_layers
from repro.models.rope import apply_rope
from repro.serving.kvcache import Cache

Params = Dict[str, Any]


def _proj_heads(x, w, b, n, d):
    y = jnp.einsum("bd,de->be", x, w)
    if b is not None:
        y = y + b
    return y.reshape(x.shape[0], n, d)


def _gqa_decode(cfg: ModelConfig, p: Params, h: jnp.ndarray, kc, vc, pos,
                window: int):
    """h [B, d] → (attn_out [B, d], new_k, new_v). Ring write if windowed."""
    B = h.shape[0]
    posv = jnp.broadcast_to(pos, (B,))
    q = _proj_heads(h, p["wq"], p.get("bq"), cfg.n_heads, cfg.d_head)
    k = _proj_heads(h, p["wk"], p.get("bk"), cfg.n_kv_heads, cfg.d_head)
    v = _proj_heads(h, p["wv"], p.get("bv"), cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q[:, None], posv[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], posv[:, None], cfg.rope_theta)[:, 0]
    S = kc.shape[2]
    slot = (pos % S) if window else jnp.minimum(pos, S - 1)
    kc = kc.at[:, :, slot].set(k.astype(kc.dtype))
    vc = vc.at[:, :, slot].set(v.astype(vc.dtype))
    length = jnp.minimum(pos + 1, S)
    o = decode_attention(q[:, :, None, :].reshape(B, cfg.n_heads, 1,
                                                  cfg.d_head),
                         kc, vc, jnp.broadcast_to(length, (B,)),
                         cap=cfg.attn_softcap)
    o = o.reshape(B, cfg.q_dim)
    return jnp.einsum("bq,qd->bd", o, p["wo"]), kc, vc


def _mla_decode(cfg: ModelConfig, p: Params, h: jnp.ndarray, ckv, krope,
                pos):
    B = h.shape[0]
    H = cfg.n_heads
    posv = jnp.broadcast_to(pos, (B,))
    if cfg.q_lora:
        q = jnp.einsum("br,rq->bq", jnp.einsum("bd,dr->br", h, p["wq_a"]),
                       p["wq_b"])
    else:
        q = jnp.einsum("bd,dq->bq", h, p["wq"])
    q = q.reshape(B, H, cfg.mla_d_nope + cfg.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [cfg.mla_d_nope], axis=-1)
    q_rope = apply_rope(q_rope[:, None], posv[:, None],
                        cfg.rope_theta)[:, 0]
    ckr = jnp.einsum("bd,dr->br", h, p["wkv_a"])
    c_new, kr_new = jnp.split(ckr, [cfg.kv_lora], axis=-1)
    kr_new = apply_rope(kr_new[:, None, None, :], posv[:, None],
                        cfg.rope_theta)[:, 0, 0]
    ckv = ckv.at[:, pos].set(c_new.astype(ckv.dtype))
    krope = krope.at[:, pos].set(kr_new.astype(krope.dtype))
    # absorbed attention in latent space
    wk = p["wkv_b"][:, :H * cfg.mla_d_nope].reshape(
        cfg.kv_lora, H, cfg.mla_d_nope)
    wv = p["wkv_b"][:, H * cfg.mla_d_nope:].reshape(
        cfg.kv_lora, H, cfg.mla_d_v)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))            # [B, H, kv_lora]
    s = jnp.einsum("bhr,bsr->bhs", q_lat, ckv.astype(jnp.float32))
    s = s + jnp.einsum("bhe,bse->bhs", q_rope.astype(jnp.float32),
                       krope.astype(jnp.float32))
    s = s / jnp.sqrt(cfg.mla_d_nope + cfg.rope_head_dim)
    S = ckv.shape[1]
    valid = jnp.arange(S)[None, None, :] <= pos
    s = jnp.where(valid, s, -2.0 ** 30)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w, ckv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", o_lat, wv.astype(jnp.float32))
    o = o.reshape(B, H * cfg.mla_d_v).astype(h.dtype)
    return jnp.einsum("bq,qd->bd", o, p["wo"]), ckv, krope


def _mlp1(cfg, p, x):
    a = act_fn(cfg.act)
    hdn = jnp.einsum("bd,df->bf", x, p["wi"])
    if "wg" in p:
        hdn = a(jnp.einsum("bd,df->bf", x, p["wg"])) * hdn
    else:
        hdn = a(hdn)
    return jnp.einsum("bf,fd->bd", hdn, p["wo2"])


def _moe1(cfg, p, x):
    """Decode-time MoE: per-token top-k gather (tiny batch — gather is fine)."""
    from repro.models.moe import route_topk
    scores = jax.nn.softmax(
        jnp.einsum("bd,de->be", x.astype(jnp.float32),
                   p["router"].astype(jnp.float32)), -1)
    ids, gates = route_topk(scores, cfg.top_k)            # [B, k]
    wi = p["wi"][ids]                                     # [B, k, d, de]
    wg = p["wg"][ids]
    wo = p["wo"][ids]
    a = act_fn(cfg.act)
    h = a(jnp.einsum("bd,bkdf->bkf", x, wg)) * \
        jnp.einsum("bd,bkdf->bkf", x, wi)
    y = jnp.einsum("bkf,bkfd->bkd", h, wo)
    out = jnp.einsum("bkd,bk->bd", y, gates.astype(x.dtype))
    if cfg.n_shared_experts:
        hs = a(jnp.einsum("bd,df->bf", x, p["sh_wg"])) * \
            jnp.einsum("bd,df->bf", x, p["sh_wi"])
        out = out + jnp.einsum("bf,fd->bd", hs, p["sh_wo"])
    return out


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Cache]:
    """tokens [B, 1] → (logits [B, vocab_padded], new cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = params["embed"][tokens[:, 0]]
    if cfg.family == "encdec":
        x = x + params["dec_pos"][pos]
    window = cfg.window if cfg.layer_pattern == "swa" else 0

    if cfg.family == "ssm":
        def body(h, xs):
            lp, tms, cms, wkv = xs
            hn = rmsnorm(h[:, None], lp["norm1"], cfg.norm_eps)
            tm, tm_new, wkv_new = ssmlib.rwkv_time_mix(
                cfg, lp, hn, tms, wkv)
            h = h + tm[:, 0]
            hn = rmsnorm(h[:, None], lp["norm2"], cfg.norm_eps)
            cm, cm_new = ssmlib.rwkv_channel_mix(cfg, lp, hn, cms)
            return h + cm[:, 0], (tm_new.astype(tms.dtype),
                                  cm_new.astype(cms.dtype), wkv_new)
        x, (tm_s, cm_s, wkv_s) = scan_layers(
            body, x, (params["layers"], cache["tm_shift"],
                      cache["cm_shift"], cache["wkv"]), cfg.unroll_layers)
        new_cache = dict(cache, pos=pos + 1, tm_shift=tm_s, cm_shift=cm_s,
                         wkv=wkv_s)
    elif cfg.use_mla:
        def body(h, xs):
            lp, ckv, krope = xs
            hn = rmsnorm(h, lp["norm1"], cfg.norm_eps)
            a, ckv, krope = _mla_decode(cfg, lp["attn"], hn, ckv, krope, pos)
            h = h + a
            hn = rmsnorm(h, lp["norm2"], cfg.norm_eps)
            f = _moe1(cfg, lp["moe"], hn) if "moe" in lp \
                else _mlp1(cfg, lp["mlp"], hn)
            return h + f, (ckv, krope)
        if "dense_layers" in params:
            xd, (dckv, dkrope) = scan_layers(
                body, x, (params["dense_layers"],
                          cache["ckv"][:cfg.n_dense_layers],
                          cache["krope"][:cfg.n_dense_layers]),
                cfg.unroll_layers)
            x, (mckv, mkrope) = scan_layers(
                body, xd, (params["layers"],
                           cache["ckv"][cfg.n_dense_layers:],
                           cache["krope"][cfg.n_dense_layers:]),
                cfg.unroll_layers)
            ckv = jnp.concatenate([dckv, mckv])
            krope = jnp.concatenate([dkrope, mkrope])
        else:
            x, (ckv, krope) = scan_layers(
                body, x, (params["layers"], cache["ckv"], cache["krope"]),
                cfg.unroll_layers)
        new_cache = dict(cache, pos=pos + 1, ckv=ckv, krope=krope)
    elif cfg.layer_pattern == "alt_local_global":
        def body(h, xs):
            lp, lk, lv, gk, gv = xs
            hn = rmsnorm(h, lp["local"]["norm1"], cfg.norm_eps)
            a, lk, lv = _gqa_decode(cfg, lp["local"]["attn"], hn, lk, lv,
                                    pos, cfg.window)
            if "norm_post1" in lp["local"]:
                a = rmsnorm(a, lp["local"]["norm_post1"], cfg.norm_eps)
            h = h + a
            hn = rmsnorm(h, lp["local"]["norm2"], cfg.norm_eps)
            f = _mlp1(cfg, lp["local"]["mlp"], hn)
            if "norm_post2" in lp["local"]:
                f = rmsnorm(f, lp["local"]["norm_post2"], cfg.norm_eps)
            h = h + f
            hn = rmsnorm(h, lp["global"]["norm1"], cfg.norm_eps)
            a, gk, gv = _gqa_decode(cfg, lp["global"]["attn"], hn, gk, gv,
                                    pos, 0)
            if "norm_post1" in lp["global"]:
                a = rmsnorm(a, lp["global"]["norm_post1"], cfg.norm_eps)
            h = h + a
            hn = rmsnorm(h, lp["global"]["norm2"], cfg.norm_eps)
            f = _mlp1(cfg, lp["global"]["mlp"], hn)
            if "norm_post2" in lp["global"]:
                f = rmsnorm(f, lp["global"]["norm_post2"], cfg.norm_eps)
            return h + f, (lk, lv, gk, gv)
        x, (lk, lv, gk, gv) = scan_layers(
            body, x, (params["layers"], cache["local"]["k"],
                      cache["local"]["v"], cache["global"]["k"],
                      cache["global"]["v"]), cfg.unroll_layers)
        new_cache = dict(cache, pos=pos + 1,
                         local={"k": lk, "v": lv},
                         **{"global": {"k": gk, "v": gv}})
    else:
        def body(h, xs):
            lp = xs[0]
            kc, vc = xs[1], xs[2]
            hn = rmsnorm(h, lp["norm1"], cfg.norm_eps)
            a, kc, vc = _gqa_decode(cfg, lp["attn"], hn, kc, vc, pos, window)
            extra = ()
            if cfg.family == "hybrid":
                conv, ssm_h = xs[3], xs[4]
                st = ssmlib.MambaState(conv=conv, h=ssm_h)
                m, st = ssmlib.mamba_head(cfg, lp["ssm"], hn[:, None], st)
                a = ((lp["ssm"]["beta_attn"] *
                      rmsnorm(a, lp["ssm"]["norm_attn"], cfg.norm_eps)
                      + lp["ssm"]["beta_ssm"] *
                      rmsnorm(m[:, 0], lp["ssm"]["norm_ssm"],
                              cfg.norm_eps)) * 0.5).astype(h.dtype)
                extra = (st.conv, st.h)
            if cfg.family == "encdec":
                xk, xv = xs[3], xs[4]
                h2 = h + a
                hn2 = rmsnorm(h2, lp["norm_x"], cfg.norm_eps)
                q = _proj_heads(hn2, lp["xattn"]["wq"], None, cfg.n_heads,
                                cfg.d_head)
                o = decode_attention(
                    q[:, :, None, :], xk, xv,
                    jnp.full((B,), xk.shape[2], jnp.int32))
                o = o.reshape(B, cfg.q_dim)
                a = a + jnp.einsum("bq,qd->bd", o, lp["xattn"]["wo"])
                extra = (xk, xv)
            h = h + a
            hn = rmsnorm(h, lp["norm2"], cfg.norm_eps)
            f = _moe1(cfg, lp["moe"], hn) if "moe" in lp \
                else _mlp1(cfg, lp["mlp"], hn)
            return h + f, (kc, vc) + extra
        xs_in = [params["layers"], cache["k"], cache["v"]]
        if cfg.family == "hybrid":
            xs_in += [cache["conv"], cache["ssm_h"]]
        if cfg.family == "encdec":
            xs_in += [cache["xk"], cache["xv"]]
        if cfg.family == "moe" and "dense" in cache:
            def dbody(h, xs):
                lp, kc, vc = xs
                hn = rmsnorm(h, lp["norm1"], cfg.norm_eps)
                a, kc, vc = _gqa_decode(cfg, lp["attn"], hn, kc, vc, pos,
                                        window)
                h = h + a
                hn = rmsnorm(h, lp["norm2"], cfg.norm_eps)
                return h + _mlp1(cfg, lp["mlp"], hn), (kc, vc)
            x, (dk_, dv_) = scan_layers(
                dbody, x, (params["dense_layers"], cache["dense"]["k"],
                           cache["dense"]["v"]), cfg.unroll_layers)
        x, ys = scan_layers(body, x, tuple(xs_in), cfg.unroll_layers)
        new_cache = dict(cache, pos=pos + 1, k=ys[0], v=ys[1])
        if cfg.family == "hybrid":
            new_cache.update(conv=ys[2], ssm_h=ys[3])
        if cfg.family == "encdec":
            new_cache.update(xk=ys[2], xv=ys[3])
        if cfg.family == "moe" and "dense" in cache:
            new_cache["dense"] = {"k": dk_, "v": dv_}

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = softcap(jnp.einsum("bd,dv->bv", x, head), cfg.logit_softcap)
    return logits, new_cache


def prefill_via_decode(cfg: ModelConfig, params: Params, cache: Cache,
                       tokens: jnp.ndarray) -> Tuple[jnp.ndarray, Cache]:
    """Sequentially decode a prompt (test/example helper, small scale only)."""
    logits = None
    for t in range(tokens.shape[1]):
        logits, cache = decode_step(cfg, params, cache, tokens[:, t:t + 1])
    return logits, cache
