"""Serving substrate: KV caches and single-token decode steps."""
