"""Decode-time caches for every architecture family.

Layouts (leading L = layers, stacked for lax.scan):

  dense / moe   k,v: [L, B, Hkv, S_cache, Dh]   (S_cache = seq_len, or the
                window size for SWA layers — sub-quadratic archs keep an
                O(window) cache, which is what makes ``long_500k`` feasible)
  gemma2        two stacks: local (window) + global (full) caches
  MLA           ckv: [L, B, S, kv_lora], krope: [L, B, S, d_rope]
                — the compressed latent is all that is stored (the paper's
                memory win), expanded per-head only at score time
  rwkv6         tm/cm shifts [L, B, d] + wkv state [L, B, H, dk, dk] — O(1)
  hymba         window k/v + mamba conv tail/state — O(window + d·N)
  whisper       decoder self k/v + precomputed encoder cross k/v

``pos`` is a scalar step counter shared across the batch (standard batched
decode); ring-buffer writes use ``pos % window`` for windowed layers.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.models.config import ModelConfig

Cache = Dict[str, Any]


def _kv(L, B, Hkv, S, Dh, dtype):
    return {"k": jnp.zeros((L, B, Hkv, S, Dh), dtype),
            "v": jnp.zeros((L, B, Hkv, S, Dh), dtype)}


def make_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> Cache:
    """Allocate the decode cache for a maximum context of ``seq_len``."""
    L, B = cfg.n_layers, batch
    H, Dh = cfg.n_kv_heads, cfg.d_head
    cache: Cache = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        dk = cfg.d_model // cfg.n_heads
        cache.update(
            tm_shift=jnp.zeros((L, B, cfg.d_model), dtype),
            cm_shift=jnp.zeros((L, B, cfg.d_model), dtype),
            wkv=jnp.zeros((L, B, cfg.n_heads, dk, dk), jnp.float32))
        return cache
    if cfg.use_mla:
        cache.update(
            ckv=jnp.zeros((L, B, seq_len, cfg.kv_lora), dtype),
            krope=jnp.zeros((L, B, seq_len, cfg.rope_head_dim), dtype))
        return cache
    if cfg.layer_pattern == "alt_local_global":
        half = L // 2
        Sl = min(cfg.window, seq_len)
        cache["local"] = _kv(half, B, H, Sl, Dh, dtype)
        cache["global"] = _kv(half, B, H, seq_len, Dh, dtype)
        return cache
    S_eff = min(cfg.window, seq_len) if cfg.layer_pattern == "swa" \
        else seq_len
    L_main = L - (cfg.n_dense_layers if cfg.family == "moe" else 0)
    cache.update(_kv(L_main, B, H, S_eff, Dh, dtype))
    if cfg.family == "hybrid":
        di = cfg.d_model * cfg.ssm_expand
        cache.update(
            conv=jnp.zeros((L, B, cfg.ssm_conv - 1, di), dtype),
            ssm_h=jnp.zeros((L, B, di, cfg.ssm_state), jnp.float32))
    if cfg.family == "encdec":
        cache.update(
            xk=jnp.zeros((L, B, H, cfg.enc_seq, Dh), dtype),
            xv=jnp.zeros((L, B, H, cfg.enc_seq, Dh), dtype))
    if cfg.family == "moe" and cfg.n_dense_layers:
        cache["dense"] = _kv(cfg.n_dense_layers, B, H, seq_len, Dh, dtype)
    return cache


def cache_bytes(cache: Cache) -> int:
    import jax
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)
               if hasattr(x, "size"))
