"""Analytic per-device memory plan per (arch × shape) on the 16×16 mesh.

Exact state/cache byte accounting from the sharding rules (no compile):
for every leaf, bytes/device = total_bytes / prod(mesh axis sizes it shards
over). Activation/temp comes from the dry-run's ``memory_analysis`` (which
is per-device, post-SPMD — verified in tests/test_launch.py).

This is the "does it fit 16 GB HBM" table in EXPERIMENTS.md §Dry-run.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json  # noqa: E402
import glob  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

HBM_PER_CHIP = 16 * 2**30  # v5e-class


def leaf_device_bytes(leaf, sharding, mesh) -> float:
    total = leaf.size * leaf.dtype.itemsize
    denom = 1
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = sharding.spec
    for ax in spec:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            denom *= axis_size[a]
    return total / denom


def state_plan(arch: str, shape: str, mesh) -> dict:
    from repro import configs
    from repro.launch import sharding as shd, specs
    from repro.models import transformer as tf
    import jax.numpy as jnp
    cfg = configs.get_config(arch)
    sd = specs.SHAPE_DEFS[shape]
    out = {}
    if sd["kind"] == "train":
        state_spec, _ = specs.state_specs(cfg)
        sh = shd.params_shardings(state_spec, mesh)
        out["state_gib"] = sum(
            leaf_device_bytes(l, s, mesh) for l, s in zip(
                jax.tree.leaves(state_spec), jax.tree.leaves(
                    sh, is_leaf=lambda x: hasattr(x, "spec")))) / 2**30
        # grads live once per microbatch at params dtype
        params_spec = jax.eval_shape(
            lambda: tf.init_params(cfg, jax.random.PRNGKey(0),
                                   dtype=jnp.bfloat16))
        psh = shd.params_shardings(params_spec, mesh)
        out["grads_gib"] = sum(
            leaf_device_bytes(l, s, mesh) for l, s in zip(
                jax.tree.leaves(params_spec), jax.tree.leaves(
                    psh, is_leaf=lambda x: hasattr(x, "spec")))) / 2**30
    else:
        params_spec = jax.eval_shape(
            lambda: tf.init_params(cfg, jax.random.PRNGKey(0),
                                   dtype=jnp.bfloat16))
        psh = shd.params_shardings(params_spec, mesh)
        out["state_gib"] = sum(
            leaf_device_bytes(l, s, mesh) for l, s in zip(
                jax.tree.leaves(params_spec), jax.tree.leaves(
                    psh, is_leaf=lambda x: hasattr(x, "spec")))) / 2**30
        out["grads_gib"] = 0.0
    if sd["kind"] == "decode":
        tok, cache_spec = specs.decode_specs(cfg, shape)
        csh = shd.cache_shardings(cache_spec, mesh)
        out["cache_gib"] = sum(
            leaf_device_bytes(l, s, mesh) for l, s in zip(
                jax.tree.leaves(cache_spec), jax.tree.leaves(
                    csh, is_leaf=lambda x: hasattr(x, "spec")))) / 2**30
    else:
        out["cache_gib"] = 0.0
    return out


def main() -> None:
    from repro.launch.mesh import make_production_mesh
    mesh = make_production_mesh()

    from repro import configs
    from repro.launch.specs import ACCUM, SHAPE_DEFS
    rows = []
    for path in sorted(glob.glob(os.path.join(
            os.path.dirname(__file__), "results", "dryrun",
            "*__16x16.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok" or rec["arch"] == "airtree":
            continue
        plan = state_plan(rec["arch"], rec["shape"], mesh)
        # analytic activation estimate (the HLO temp number from the CPU
        # backend does not model TPU buffer assignment across scans):
        # train: layer-boundary remat saves ≈ 1.5 · L · mb_tokens/dev · d · 2B
        # prefill: one layer's streamed working set ≈ 8 · tokens/dev · d · 2B
        cfg = configs.get_config(rec["arch"])
        sd = SHAPE_DEFS[rec["shape"]]
        if sd["kind"] == "train":
            accum = ACCUM.get(cfg.name, 1)
            mb_tok = sd["global_batch"] * sd["seq_len"] / accum / 16
            act = 1.5 * cfg.n_layers * mb_tok * cfg.d_model * 2 / 2**30
        elif sd["kind"] == "prefill":
            act = 8 * sd["global_batch"] * sd["seq_len"] / 16 \
                * cfg.d_model * 2 / 2**30
        else:
            act = 0.1
        total = plan["state_gib"] + plan["grads_gib"] + \
            plan["cache_gib"] + act
        rows.append((rec["arch"], rec["shape"], plan["state_gib"],
                     plan["grads_gib"], plan["cache_gib"], act, total,
                     "FITS" if total < 16 else "OVER"))
    print("arch,shape,state_gib,grads_gib,cache_gib,act_est_gib,"
          "total_gib,verdict")
    for r in rows:
        print(f"{r[0]},{r[1]},{r[2]:.2f},{r[3]:.2f},{r[4]:.2f},"
              f"{r[5]:.2f},{r[6]:.2f},{r[7]}")


if __name__ == "__main__":
    main()
