"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape) cell on the single-pod mesh, derive the three roofline
terms from the compiled dry-run:

  compute    = HLO_FLOPs_per_dev / 197e12        (bf16 peak per chip)
  memory     = HLO_bytes_per_dev / 819e9         (HBM bandwidth)
  collective = wire_bytes_per_dev / 50e9         (per-link ICI)

The dominant term is the bottleneck; the roofline fraction we report is
compute / dominant — the share of step time the MXUs could be busy if
everything else overlapped perfectly. MODEL_FLOPS uses 6·N·D (train),
2·N·D (prefill) or 2·N_active·B (decode, per step); the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundant compute.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def analyze(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    n_dev = rec["n_devices"]
    flops_dev = rec["cost"].get("flops", 0.0)
    bytes_dev = rec["cost"].get("bytes accessed", 0.0)
    wire_dev = rec["collectives"]["wire_bytes_total"]
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_n = wire_dev / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
    tokens = rec["global_batch"] * (rec["seq_len"] if rec["kind"] != "decode"
                                    else 1)
    n_params = rec["model_params"]
    n_active = rec["model_params_active"]
    if rec["kind"] == "train":
        model_flops = 6.0 * n_active * tokens
    elif rec["kind"] == "prefill":
        model_flops = 2.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * tokens
    hlo_total = flops_dev * n_dev
    useful = model_flops / hlo_total if hlo_total else 0.0
    t_dom = max(t_c, t_m, t_n)
    hints = {
        "compute": "at compute roof — shave remat/redundant FLOPs "
                   "(useful-ratio below) to move it",
        "memory": "HBM-bound — raise arithmetic intensity (fuse, widen "
                  "tiles, bf16 the biggest streams)",
        "collective": "ICI-bound — reshard to shrink the biggest "
                      "collective or overlap it under compute",
    }
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        kind=rec["kind"], n_devices=n_dev,
        t_compute_s=t_c, t_memory_s=t_m, t_collective_s=t_n,
        dominant=dom,
        roofline_fraction=(t_c / t_dom) if t_dom > 0 else 0.0,
        model_flops=model_flops, hlo_flops_total=hlo_total,
        useful_flops_ratio=useful,
        hint=hints[dom],
        collective_counts=rec["collectives"]["counts"],
    )


def load_all(results_dir: str = RESULTS_DIR, mesh: str = "16x16") -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        row = analyze(rec)
        if row:
            rows.append(row)
    return rows


def print_table(rows: list) -> None:
    hdr = ("arch", "shape", "t_comp(s)", "t_mem(s)", "t_coll(s)",
           "dominant", "roofline%", "useful%")
    print(" | ".join(hdr))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(" | ".join([
            r["arch"], r["shape"],
            f"{r['t_compute_s']:.4f}", f"{r['t_memory_s']:.4f}",
            f"{r['t_collective_s']:.4f}", r["dominant"],
            f"{100 * r['roofline_fraction']:.1f}",
            f"{100 * r['useful_flops_ratio']:.1f}",
        ]))


def main() -> list:
    rows = load_all()
    if not rows:
        print("no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return []
    print_table(rows)
    # csv lines for the orchestrator
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        print(f"roofline_{r['arch']}_{r['shape']},"
              f"{r['roofline_fraction']:.4f},dominant={r['dominant']}")
    return rows


if __name__ == "__main__":
    main()
