"""Shared synthetic AI-tree fixtures for the benchmark/autotune harnesses.

One construction of the random (untrained) MLP bank + grid so the
autotune sweep tunes exactly the distribution the benchmark measures —
they used to be two copies that could drift.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def synth_mlp_bank(rng, C: int, L: int, F: int = 4, H: int = 64,
                   Cl: int = 32):
    """Random MLPBank over ``C`` cells and ``L`` global leaves (10% of
    label slots masked off; masked ``label_map`` entries are -1 pads)."""
    from repro.core.classifiers.mlp import MLPBank
    lm = rng.integers(0, L, (C, Cl)).astype(np.int32)
    lmask = rng.uniform(size=(C, Cl)) < 0.9
    lm[~lmask] = -1
    return MLPBank(
        w1=jnp.asarray(rng.normal(0, 1, (C, F, H)), jnp.float32),
        b1=jnp.asarray(rng.normal(0, 1, (C, H)), jnp.float32),
        w2=jnp.asarray(rng.normal(0, 1, (C, H, Cl)), jnp.float32),
        b2=jnp.asarray(rng.normal(0, 0.5, (C, Cl)), jnp.float32),
        mu=jnp.zeros((F,), jnp.float32),
        sd=jnp.ones((F,), jnp.float32),
        label_map=jnp.asarray(lm),
        lmask=jnp.asarray(lmask))


def unit_grid(g: int):
    """g×g grid over the [-1, 1]² fixture query space."""
    from repro.core.grid import Grid
    return Grid(bbox=jnp.asarray([-1, -1, 1, 1], jnp.float32), g=g)
