"""Shard-scaling benchmark: AI-path score union ``pmax`` vs ``topk``.

``python -m benchmarks.union_scaling [--shards 1,2,4,8] [--json FILE]``

The pending ROADMAP question behind ``EngineConfig.score_union``: the
paper-faithful ``pmax`` union reduces a dense ``[B, L_glob]`` per-leaf
score table across expert shards, while the beyond-paper ``topk`` union
all-gathers per-shard ``[B, k]`` candidate lists — O(B·L_glob) vs
O(B·shards·k) collective payload, so ``topk`` should win once the model
axis is wide enough. This harness measures both at increasing model-shard
counts and reports the crossover.

Each shard count runs in a **subprocess** with
``xla_force_host_platform_device_count`` (the flag must be set before jax
initializes, and each count needs a fresh backend). Host "devices" share
the CPU, so absolute wall times are emulation artifacts; the pmax/topk
*ratio* at equal shard count is the trackable signal (collective payload
is real traffic even in emulation). Per-query outputs of the two unions
are asserted identical before timing, sweep after sweep.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def _child(n_shards: int, reps: int) -> None:
    """One shard count: build, serve with both unions, print a JSON line."""
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import build, device_tree as dt, engine, labels
    from repro.core.rtree import RTree
    from repro.data import synth
    from repro.launch import mesh as pmesh

    pts = synth.tweets_like(20_000, seed=0)
    tree = RTree(max_entries=32).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 1e-4, 600, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(8,))

    mesh = jax.make_mesh((1, n_shards), ("data", "model"))
    hyb_p = engine.pad_tree_for_sharding(hyb, n_shards)
    B = 256
    q = jnp.asarray(wl.queries[:B])
    out = {"shards": n_shards}
    stats = {}
    for union in ("pmax", "topk"):
        step = engine.make_serve_step(mesh, engine.EngineConfig(
            max_visited=64, max_pred=32, score_union=union), kind="knn")
        fn = jax.jit(lambda q, step=step: step(hyb_p, q))
        with pmesh.set_mesh(mesh):
            stats[union] = fn(q)
            jax.block_until_ready(stats[union])   # compile + warm
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(q))
                ts.append(time.perf_counter() - t0)
        out[union + "_us"] = float(np.median(ts)) * 1e6
    for f in stats["pmax"]._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(stats["pmax"], f)),
            np.asarray(getattr(stats["topk"], f)), err_msg=f)
    out["speedup_topk"] = out["pmax_us"] / out["topk_us"]
    print("UNION_ROW " + json.dumps(out))


def main(argv=None) -> list:
    p = argparse.ArgumentParser()
    p.add_argument("--shards", default="1,2,4,8")
    p.add_argument("--reps", type=int, default=9)
    p.add_argument("--json", default=None, metavar="FILE",
                   help="merge rows into this benchmark JSON")
    p.add_argument("--child", type=int, default=None, help=argparse.SUPPRESS)
    args = p.parse_args(argv)

    if args.child is not None:
        _child(args.child, args.reps)
        return []

    rows: list = []
    for n in (int(s) for s in args.shards.split(",")):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={n}")
        env.setdefault("PYTHONPATH", "src")
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.union_scaling",
             "--child", str(n), "--reps", str(args.reps)],
            capture_output=True, text=True, env=env)
        line = next((ln for ln in proc.stdout.splitlines()
                     if ln.startswith("UNION_ROW ")), None)
        if line is None:
            print(f"shards={n} FAILED:\n{proc.stdout}\n{proc.stderr}",
                  file=sys.stderr)
            continue
        r = json.loads(line[len("UNION_ROW "):])
        for union in ("pmax", "topk"):
            extra = (f"speedup_topk={r['speedup_topk']:.2f}x"
                     if union == "topk" else "")
            rows.append((f"union_{union}_shards{r['shards']}_us",
                         r[union + "_us"], extra))
        print(f"shards={r['shards']}: pmax {r['pmax_us']:.0f}us "
              f"topk {r['topk_us']:.0f}us "
              f"(topk speedup {r['speedup_topk']:.2f}x)")

    if args.json:
        try:
            with open(args.json) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
        doc["union_scaling"] = {
            name: {"value": val, "derived": extra}
            for name, val, extra in rows}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, default=str)
        print(f"wrote {args.json}")
    return rows


if __name__ == "__main__":
    main()
