"""Tile-size autotune sweep for the fused traversal kernels.

``python -m benchmarks.autotune [--quick] [--out PATH] [--shapes B,L,F;...]``

The hand-picked ``DEF_TB / DEF_TL / SUB_TL / COMPACT_KC`` constants in
``kernels/traverse_fused.py`` are one point in a per-tree-shape trade
space (ROADMAP "Autotuned tile sizes"). This harness sweeps the knobs that
matter for the *current backend's* kernel form on synthetic STR-packed
trees, scores each candidate on a uniform + clustered serving mix (the
two workloads whose balance the tiles actually shift), and writes the
winners to a JSON cache keyed by ``(form, B, L, height)``.
``kernels/ops.py`` consults that cache on every fused dispatch — explicit
caller overrides still win, untuned shapes fall back to the defaults, and
a stale cache can only cost time, never correctness (every candidate is
asserted bit-identical to the default-tile output before it is timed).

Forms: in interpret mode (CPU container) the swept knobs are ``tb`` and
``sub_tl`` (the leaf axis is folded into one tile, so ``tl`` is fixed and
``kc`` unused); on real TPU they are ``tb``/``tl``/``kc``. Cache entries
from one form never leak into the other — the form is part of the key.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import traverse_fused as tf


DEF_SHAPES = ((256, 2048, 4), (256, 4096, 8), (512, 2048, 4))


def _med_time(fn, reps: int = 7) -> float:
    jax.block_until_ready(fn())  # warm / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _workloads(B: int, rng) -> list[jnp.ndarray]:
    """Uniform + clustered query batches (engine_bench's serving mix)."""
    lo = rng.uniform(-1, 1, (B, 2))
    w = rng.uniform(0, 0.05, (B, 2))
    uniform = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    c = rng.uniform(-0.8, 0.6, (1, 2))
    lo = c + rng.uniform(0, 0.15, (B, 2))
    w = rng.uniform(0, 0.02, (B, 2))
    clustered = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    return [uniform, clustered]


def _candidates(B: int, L: int, interp: bool, quick: bool):
    """Knob grid for the current form; the default point is always included."""
    L128 = (max(128, L) + 127) // 128 * 128
    if interp:
        tbs = [min(1024, (max(8, B) + 7) // 8 * 8)] + \
            ([256] if not quick else [])
        tls = [L128] if L128 <= 8192 else [min(tf.DEF_TL, L128)]
        sub_tls = [128, 256, 512] if not quick else [256, 512]
        kcs = [tf.COMPACT_KC]       # unused by the interpret epilogue
    else:
        tbs = [128, 256, 512]
        tls = sorted({min(t, L128) for t in (256, 512, 1024)})
        sub_tls = [tf.SUB_TL]       # unused by the TPU form
        kcs = [4, 8, 16]
    for tb, tl, sub_tl, kc in itertools.product(tbs, tls, sub_tls, kcs):
        yield {"tb": tb, "tl": tl, "sub_tl": sub_tl, "kc": kc}


def sweep_shape(B: int, L: int, fanout: int, k: int, quick: bool,
                rows: list) -> tuple[str, dict]:
    from repro.data.synth_tree import synth_levels

    rng = np.random.default_rng(0)
    mbrs, parents = synth_levels(L, fanout, rng, str_pack=True)
    lm = [jnp.asarray(m) for m in mbrs]
    lp = [jnp.asarray(p) for p in parents]
    n_levels = len(lm)
    interp = jax.default_backend() != "tpu"
    qs = _workloads(B, rng)

    def run(cand, q):
        qp, int_m, int_p, leaf_m, leaf_p = ops._fused_operands(
            q, lm, lp, cand["tb"], cand["tl"])
        return tf.traverse_compact_t(
            qp.T, int_m, int_p, leaf_m, leaf_p, k=k,
            tb=cand["tb"], tl=cand["tl"], sub_tl=cand["sub_tl"],
            kc=cand["kc"], interpret=interp)

    default = {"tb": None, "tl": None, "sub_tl": tf.SUB_TL,
               "kc": tf.COMPACT_KC}
    dtb, dtl, _, _ = ops._fused_tiles(B, L, None, None)
    default["tb"], default["tl"] = dtb, dtl
    ref_out = [jax.tree.map(np.asarray, run(default, q)) for q in qs]

    best, best_t, default_t = None, np.inf, None
    for cand in _candidates(B, L, interp, quick):
        # correctness gate: slots agree wherever valid, counts exactly
        for q, (ri, rc) in zip(qs, ref_out):
            ci, cc = jax.tree.map(np.asarray, run(cand, q))
            np.testing.assert_array_equal(cc, rc)
            np.testing.assert_array_equal(ci[:, :k], ri[:, :k])
        t = sum(_med_time(lambda q=q: run(cand, q)) for q in qs)
        if cand == default:
            default_t = t
        if t < best_t:
            best, best_t = dict(cand), t
    if default_t is None:
        default_t = sum(_med_time(lambda q=q: run(default, q)) for q in qs)
    key = tf.tune_key(B, L, n_levels, interp)
    entry = dict(best, us=best_t * 1e6, default_us=default_t * 1e6)
    rows.append((f"autotune_{key}_us", best_t * 1e6,
                 f"default_us={default_t * 1e6:.0f},"
                 f"tiles=tb{best['tb']}tl{best['tl']}"
                 f"s{best['sub_tl']}kc{best['kc']}"))
    return key, entry


def sweep_mlp_shape(B: int, L: int, g: int, Cl: int, k: int, quick: bool,
                    rows: list) -> tuple[str, dict]:
    """Knob sweep for the fused AI-path prediction kernel (``mlp_infer``).

    Same protocol as the traversal sweep: every candidate is gated
    bit-identical to the default-tile output on the serving mix before it
    is timed; winners land under the ``mlp-`` form keys the
    ``ops.mlp_predict_compact`` dispatch consults.
    """
    from repro.core.grid import cells_of_queries
    from repro.kernels import mlp_infer as mi
    from benchmarks._synth_ai import synth_mlp_bank, unit_grid

    rng = np.random.default_rng(0)
    C = g * g
    bank = synth_mlp_bank(rng, C, L, Cl=Cl)
    grid = unit_grid(g)
    interp = jax.default_backend() != "tpu"
    qs = _workloads(B, rng)
    routed = [jax.jit(cells_of_queries, static_argnames="max_cells")(
        grid, q, max_cells=4)[:2] for q in qs]

    def run(cand, q, cid, ok):
        return ops.mlp_predict_compact(
            q, bank, cid, ok, n_leaves=L, k=k, threshold=0.5,
            tb=cand["tb"], tl=cand["tl"])

    Lp = (max(128, L) + 127) // 128 * 128
    # the baseline must be what ops.mlp_predict_compact would actually
    # dispatch today (same resolution path, like sweep_shape's use of
    # _fused_tiles), not an arbitrary grid point — default_us documents
    # the win over the current dispatch
    dtb, dtl, _, _ = ops._mlp_tiles(B, L, C, Cl, interp)
    default = {"tb": dtb, "tl": dtl}
    if interp:
        cands = [{"tb": tb, "tl": Lp}
                 for tb in ([min(1024, B), 128] if not quick
                            else [min(1024, B)])]
    else:
        cands = [{"tb": tb, "tl": tl}
                 for tb in (128, 256, 512)
                 for tl in sorted({min(t, Lp) for t in (256, 512, 1024)})]
    if default not in cands:
        cands.insert(0, default)
    ref_out = [jax.tree.map(np.asarray, run(default, q, cid, ok))
               for q, (cid, ok) in zip(qs, routed)]

    best, best_t, default_t = None, np.inf, None
    for cand in cands:
        for (q, (cid, ok)), ro in zip(zip(qs, routed), ref_out):
            co = jax.tree.map(np.asarray, run(cand, q, cid, ok))
            for c, r in zip(co, ro):
                np.testing.assert_array_equal(c, r)
        t = sum(_med_time(lambda q=q, cid=cid, ok=ok: run(cand, q, cid, ok))
                for q, (cid, ok) in zip(qs, routed))
        if cand == default:
            default_t = t
        if t < best_t:
            best, best_t = dict(cand), t
    key = mi.tune_key_mlp(B, L, C, Cl, interp)
    entry = dict(best, us=best_t * 1e6, default_us=default_t * 1e6)
    rows.append((f"autotune_{key}_us", best_t * 1e6,
                 f"default_us={default_t * 1e6:.0f},"
                 f"tiles=tb{best['tb']}tl{best['tl']}"))
    return key, entry


def sweep_delta_shape(B: int, cap: int, k: int, quick: bool,
                      rows: list) -> tuple[str, dict]:
    """Knob sweep for the delta-probe kernel (``delta_probe``).

    Same protocol as the other sweeps: every candidate is gated
    bit-identical to the current-dispatch output before it is timed;
    winners land under the ``delta-`` form keys ``ops.delta_probe``
    consults. The buffer is probed half-full — the kernel cost is
    capacity-shaped, not fill-shaped, and half-full exercises both live
    and all-padding tiles.
    """
    from repro.kernels import delta_probe as dpk

    rng = np.random.default_rng(0)
    interp = jax.default_backend() != "tpu"
    qs = _workloads(B, rng)
    pts = np.full((cap, 2), np.inf, np.float32)
    pts[:cap // 2] = rng.uniform(-1, 1, (cap // 2, 2))
    pts = jnp.asarray(pts)

    def run(cand, q):
        return ops.delta_probe(q, pts, k=k, tb=cand["tb"], tn=cand["tn"])

    Np = (max(128, cap) + 127) // 128 * 128
    dtb, dtn, _ = ops._delta_tiles(B, cap, interp)
    default = {"tb": dtb, "tn": dtn}
    if interp:
        cands = [{"tb": tb, "tn": Np}
                 for tb in ([min(1024, B), 128] if not quick
                            else [min(1024, B)])]
    else:
        cands = [{"tb": tb, "tn": tn}
                 for tb in (128, 256, 512)
                 for tn in sorted({min(t, Np) for t in (256, 512, 1024)})]
    if default not in cands:
        cands.insert(0, default)
    ref_out = [jax.tree.map(np.asarray, run(default, q)) for q in qs]

    best, best_t, default_t = None, np.inf, None
    for cand in cands:
        for q, ro in zip(qs, ref_out):
            co = jax.tree.map(np.asarray, run(cand, q))
            for c, r in zip(co, ro):
                np.testing.assert_array_equal(c, r)
        t = sum(_med_time(lambda q=q: run(cand, q)) for q in qs)
        if cand == default:
            default_t = t
        if t < best_t:
            best, best_t = dict(cand), t
    key = dpk.tune_key_delta(B, cap, interp)
    # the cache's lane-axis knob is named ``tl`` across kernel families
    entry = {"tb": best["tb"], "tl": best["tn"], "us": best_t * 1e6,
             "default_us": default_t * 1e6}
    rows.append((f"autotune_{key}_us", best_t * 1e6,
                 f"default_us={default_t * 1e6:.0f},"
                 f"tiles=tb{best['tb']}tn{best['tn']}"))
    return key, entry


def sweep_sliced_shape(B: int, L: int, fanout: int, k: int, quick: bool,
                       rows: list) -> tuple[str, dict]:
    """Knob sweep for the ancestor-sliced traversal form (``sliced-*``
    keys).

    Unlike the other sweeps, the swept ``tl`` is the slice granularity
    baked into the ancestor table — every candidate **rebuilds the
    table** (changing tl changes the windows, hence the whole operand
    layout), and the bit-identity gate runs against the jnp oracle's
    compacted output rather than a default candidate, since no single
    default layout spans all granularities. ``ops._sliced_call`` and the
    on-the-fly table build (``_build_slices_if_concrete``) consult the
    winning entry; tables attached at ``flatten`` time keep their own
    granularity and only pick up the ``tb``/``sub_tl``/``kc`` knobs.
    """
    from repro.core.device_tree import build_ancestor_table
    from repro.core.traversal import compact_mask_counted
    from repro.data.synth_tree import synth_levels
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    mbrs, parents = synth_levels(L, fanout, rng, str_pack=True)
    lm = [jnp.asarray(m) for m in mbrs]
    lp = [jnp.asarray(p) for p in parents]
    n_levels = len(lm)
    interp = jax.default_backend() != "tpu"
    qs = _workloads(B, rng)
    oracle = [jax.tree.map(np.asarray, compact_mask_counted(
        jnp.asarray(ref.traverse_fused(q, lm, lp)), k)) for q in qs]

    tables: dict = {}

    def table(tl):
        if tl not in tables:
            tables[tl] = build_ancestor_table(
                [np.asarray(p) for p in parents], tl=tl)
        return tables[tl]

    def run(cand, q):
        sl = table(cand["tl"])
        qp, im, ip, lmt, lpt = ops._sliced_operands(q, lm, lp, sl,
                                                    cand["tb"])
        return tf.traverse_compact_sliced_t(
            sl.starts, qp.T, im, ip, lmt, lpt, k=k, widths=sl.widths,
            tb=cand["tb"], tl=sl.tl, sub_tl=cand["sub_tl"],
            kc=cand["kc"], interpret=interp)

    if interp:
        # coarse granularities only: interpret unrolls the leaf-tile grid
        # at trace time, so fine slices pay a compile-time cliff
        tbs = [min(1024, (max(8, B) + 7) // 8 * 8)] + \
            ([128] if not quick else [])
        tls = [2048, 4096] if not quick else [4096]
        sub_tls = [256, 512]
        kcs = [tf.COMPACT_KC]       # unused by the interpret epilogue
    else:
        tbs = [128, 256]
        tls = [512, 1024, 2048]
        sub_tls = [tf.SUB_TL]       # unused by the TPU form
        kcs = [4, 8, 16]
    default = {"tb": tbs[0], "tl": tls[-1] if interp else tf.DEF_TL,
               "sub_tl": tf.SUB_TL, "kc": tf.COMPACT_KC}
    cands = [{"tb": tb, "tl": tl, "sub_tl": s, "kc": kc}
             for tb, tl, s, kc in itertools.product(tbs, tls, sub_tls,
                                                    kcs)]
    if default not in cands:
        cands.insert(0, default)

    best, best_t, default_t = None, np.inf, None
    for cand in cands:
        # correctness gate: counts exactly, slots agree wherever valid
        for q, (ri, rv, rc) in zip(qs, oracle):
            ci, cc = jax.tree.map(np.asarray, run(cand, q))
            np.testing.assert_array_equal(cc[:B, 0], rc)
            np.testing.assert_array_equal(np.where(rv, ci[:B, :k], 0),
                                          np.where(rv, ri, 0))
        t = sum(_med_time(lambda q=q: run(cand, q)) for q in qs)
        if cand == default:
            default_t = t
        if t < best_t:
            best, best_t = dict(cand), t
    if default_t is None:
        default_t = sum(_med_time(lambda q=q: run(default, q)) for q in qs)
    key = tf.tune_key_sliced(B, L, n_levels, interp)
    entry = dict(best, us=best_t * 1e6, default_us=default_t * 1e6)
    rows.append((f"autotune_{key}_us", best_t * 1e6,
                 f"default_us={default_t * 1e6:.0f},"
                 f"tiles=tb{best['tb']}tl{best['tl']}"
                 f"s{best['sub_tl']}kc{best['kc']}"))
    return key, entry


def main(argv=None) -> list:
    p = argparse.ArgumentParser()
    p.add_argument("--out", default=tf.autotune_cache_path(),
                   help="JSON cache path (merged, not overwritten)")
    p.add_argument("--quick", action="store_true",
                   help="smaller grid + first shape only")
    p.add_argument("--shapes", default=None,
                   help="semicolon list of B,L,fanout triples")
    p.add_argument("--k", type=int, default=64,
                   help="compaction bound used for timing")
    args = p.parse_args(argv)

    shapes = DEF_SHAPES[:1] if args.quick else DEF_SHAPES
    if args.shapes:
        shapes = tuple(tuple(int(x) for x in s.split(","))
                       for s in args.shapes.split(";"))

    rows: list = []
    cache = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            cache = json.load(f)
    for (B, L, fanout) in shapes:
        key, entry = sweep_shape(B, L, fanout, args.k, args.quick, rows)
        cache[key] = entry
        print(f"{key}: {entry}")
    key, entry = sweep_mlp_shape(256, 2048, 4, 32, args.k, args.quick, rows)
    cache[key] = entry
    print(f"{key}: {entry}")
    key, entry = sweep_delta_shape(256, 4096, args.k, args.quick, rows)
    cache[key] = entry
    print(f"{key}: {entry}")
    # sliced form: swept at a shape past the VMEM budget (the only place
    # the ladder picks it)
    key, entry = sweep_sliced_shape(256, 32768, 4, args.k, args.quick,
                                    rows)
    cache[key] = entry
    print(f"{key}: {entry}")
    with open(args.out, "w") as f:
        json.dump(cache, f, indent=2, sort_keys=True)
    print(f"wrote {args.out} ({len(cache)} shapes)")
    return rows


if __name__ == "__main__":
    main()
