"""Benchmark orchestrator. ``python -m benchmarks.run [--full]``.

One section per paper artifact:
  paper_tables — Figures 7/8 + Tables III/IV (the reproduction)
  engine_bench — batched-serving throughput + kernel microbenches
  roofline     — summarizes the dry-run roofline terms if results exist

Prints ``name,value,derived`` CSV lines per benchmark.
"""
from __future__ import annotations

import argparse
import traceback


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="paper-scale datasets (2M/872k points)")
    p.add_argument("--quick", action="store_true",
                   help="smoke-scale (CI) run")
    p.add_argument("--only", default=None,
                   help="run a single section by name")
    args = p.parse_args()

    sections = []

    def want(name: str) -> bool:
        return args.only is None or args.only == name

    if want("paper_tables"):
        from benchmarks import paper_tables
        print("== paper_tables (Fig 7/8, Tables III/IV) ==")
        try:
            paper_tables.main(full=args.full,
                              quick=args.quick or not args.full)
            sections.append("paper_tables")
        except Exception:
            traceback.print_exc()

    if want("engine_bench"):
        from benchmarks import engine_bench
        print("== engine_bench (beyond-paper throughput) ==")
        try:
            engine_bench.main()
            sections.append("engine_bench")
        except Exception:
            traceback.print_exc()

    if want("roofline"):
        from benchmarks import roofline
        print("== roofline (from dry-run artifacts) ==")
        try:
            roofline.main()
            sections.append("roofline")
        except Exception:
            traceback.print_exc()

    print(f"== done: {', '.join(sections)} ==")


if __name__ == "__main__":
    main()
