"""Benchmark orchestrator. ``python -m benchmarks.run [--full] [--json F]``.

One section per paper artifact:
  paper_tables — Figures 7/8 + Tables III/IV (the reproduction)
  engine_bench — batched-serving throughput + kernel microbenches
  latency_bench — open-loop tail latency + goodput (arrival-rate sweeps)
  roofline     — summarizes the dry-run roofline terms if results exist
  union_scaling — pmax vs topk score union over model shards (subprocess
                  sweep with fake host devices; runs only when named via
                  ``--only union_scaling``)

Prints ``name,value,derived`` CSV lines per benchmark. With ``--json`` the
same rows are also written as structured JSON (name → {value, derived}) so
the perf trajectory is machine-trackable across PRs (see BENCH_engine.json).

With ``--check`` a fresh toy-scale micro run is compared row-by-row
against the committed baseline (``BENCH_engine.json``): any timing row
regressing past ``CHECK_TOLERANCE``× fails the run (nonzero exit) — the
``make bench-smoke`` / CI regression guard. Throughput rows (`_qps`) fail
on the inverse (fresh < baseline / tolerance). The band is wide because
the CI container is noisy shared CPU — the guard catches order-of-
magnitude dispatch regressions (a kernel silently dropping to a fallback
rung), not single-digit-percent drift.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

CHECK_TOLERANCE = 2.0
_BASELINE = os.path.join(os.path.dirname(__file__), os.pardir,
                         "BENCH_engine.json")


def check(baseline_path: str = _BASELINE,
          tolerance: float = CHECK_TOLERANCE) -> int:
    """Compare fresh toy-scale micro rows against the committed baseline.

    Only rows present in both runs are compared (the baseline may carry
    full-scale rows the toy run skips — in particular the wall-clock
    ``lat_open_*`` quantiles, which a shared-CPU container would fail on
    noise alone; the latency guard runs on the deterministic ``lat_sim_*``
    / ``goodput_sim_*`` rows instead). Returns the number of regressions
    (0 == pass).
    """
    from benchmarks import engine_bench, latency_bench

    try:
        with open(baseline_path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        print("check: no readable baseline — nothing to compare")
        return 0
    base = dict(doc.get("engine_bench", {}))
    base.update(doc.get("latency_bench", {}))

    rows: list = []
    engine_bench.traversal_micro(rows)
    engine_bench.compaction_micro(rows)
    engine_bench.ai_fusion_micro(rows)
    engine_bench.scale_bench(rows, quick=True)
    # same scale as the quick run that wrote the baseline — the qps
    # comparison is meaningless across dataset sizes
    engine_bench.query_type_throughput(rows, n_points=20_000, batch=256)
    latency_bench.sim_rows(rows)

    bad = 0
    for name, value, _extra in rows:
        ent = base.get(name)
        if not isinstance(ent, dict) or "value" not in ent:
            continue
        ref = float(ent["value"])
        if ref <= 0 or value <= 0:
            continue
        if name.endswith("_qps") or name.startswith("goodput_"):
            regressed = value < ref / tolerance
            ratio = ref / value
        else:
            regressed = value > ref * tolerance
            ratio = value / ref
        flag = " REGRESSED" if regressed else ""
        print(f"check: {name} fresh={value:.2f} base={ref:.2f} "
              f"x{ratio:.2f}{flag}")
        bad += int(regressed)
    print(f"check: {bad} regression(s) past {tolerance}x")
    return bad


def _rows_to_dict(rows: list) -> dict:
    """Normalize a section's rows to {name: {value, derived}}.

    engine_bench/roofline yield (name, value, extra) tuples; paper_tables
    yields dicts keyed by column — those are passed through under a
    synthetic row name.
    """
    out: dict = {}
    for i, r in enumerate(rows):
        if isinstance(r, dict):
            if "arch" in r and "shape" in r:        # roofline rows
                key = f"{r['arch']}_{r['shape']}"
            else:                                   # paper_tables rows
                name = r.get("dataset", r.get("name", f"row{i}"))
                key = f"{name}_M{r['M']}" if "M" in r else str(name)
            out[key] = r
        else:
            name, value, extra = r
            out[name] = {"value": value, "derived": extra}
    return out


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="paper-scale datasets (2M/872k points)")
    p.add_argument("--quick", action="store_true",
                   help="smoke-scale (CI) run")
    p.add_argument("--only", default=None,
                   help="run a single section by name")
    p.add_argument("--json", default=None, metavar="FILE",
                   help="also write results as structured JSON")
    p.add_argument("--check", action="store_true",
                   help="regression guard: fresh toy-scale micro rows vs "
                        "the committed BENCH_engine.json; nonzero exit on "
                        f">{CHECK_TOLERANCE}x regressions")
    args = p.parse_args()

    if args.check:
        sys.exit(1 if check() else 0)

    sections = []
    results: dict = {}

    def want(name: str) -> bool:
        return args.only is None or args.only == name

    if want("paper_tables"):
        from benchmarks import paper_tables
        print("== paper_tables (Fig 7/8, Tables III/IV) ==")
        try:
            rows = paper_tables.main(full=args.full,
                                     quick=args.quick or not args.full)
            results["paper_tables"] = _rows_to_dict(rows or [])
            sections.append("paper_tables")
        except Exception:
            traceback.print_exc()

    if want("engine_bench"):
        from benchmarks import engine_bench
        print("== engine_bench (beyond-paper throughput) ==")
        try:
            rows = engine_bench.main(quick=args.quick)
            results["engine_bench"] = _rows_to_dict(rows or [])
            sections.append("engine_bench")
        except Exception:
            traceback.print_exc()

    if want("latency_bench"):
        from benchmarks import latency_bench
        print("== latency_bench (open-loop tail latency + goodput) ==")
        try:
            rows = latency_bench.main(quick=args.quick)
            results["latency_bench"] = _rows_to_dict(rows or [])
            sections.append("latency_bench")
        except Exception:
            traceback.print_exc()

    if args.only == "union_scaling":   # explicit-only: forks per shard count
        from benchmarks import union_scaling
        print("== union_scaling (pmax vs topk over model shards) ==")
        try:
            rows = union_scaling.main(
                ["--shards", "1,2" if args.quick else "1,2,4,8"])
            results["union_scaling"] = _rows_to_dict(rows or [])
            sections.append("union_scaling")
        except Exception:
            traceback.print_exc()

    if want("roofline"):
        from benchmarks import roofline
        print("== roofline (from dry-run artifacts) ==")
        try:
            rows = roofline.main()
            results["roofline"] = _rows_to_dict(rows or [])
            sections.append("roofline")
        except Exception:
            traceback.print_exc()

    if args.json:
        doc = {}
        try:    # merge: a partial run (--only) must not drop other sections
            with open(args.json) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            pass
        doc.update(results)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2, default=str)
        print(f"wrote {args.json}")

    print(f"== done: {', '.join(sections)} ==")


if __name__ == "__main__":
    main()
