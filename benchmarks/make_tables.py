"""Render EXPERIMENTS.md tables from dry-run artifacts.

Replaces the <!-- DRYRUN_TABLE -->, <!-- ROOFLINE_TABLE --> and
<!-- MEMPLAN_TABLE --> markers with generated markdown. Idempotent: each
marker line is kept and the generated block below it is refreshed.
"""
import glob
import json
import os
import re

HERE = os.path.dirname(os.path.abspath(__file__))
RESULTS = os.path.join(HERE, "results", "dryrun")
EXP = os.path.join(HERE, "..", "EXPERIMENTS.md")

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def load(mesh):
    out = {}
    for p in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        r = json.load(open(p))
        out[(r.get("arch"), r.get("shape"))] = r
    return out


def dryrun_table():
    single = load("16x16")
    multi = load("2x16x16")
    lines = ["| arch | shape | 16×16 | 2×16×16 | compile s (1-pod) | "
             "HLO temp GiB/dev |", "|---|---|---|---|---|---|"]
    for key in sorted(single):
        r = single[key]
        m = multi.get(key, {})
        if key[1].endswith("_topk") or key[1] == "serve_8k":
            continue
        temp = r.get("memory", {}).get("temp_size_in_bytes", 0) / 2**30
        lines.append(
            f"| {key[0]} | {key[1]} | "
            f"{'✓' if r.get('status') == 'ok' else '✗'} | "
            f"{'✓' if m.get('status') == 'ok' else '—'} | "
            f"{r.get('compile_seconds', '—')} | {temp:.2f} |")
    skips = [
        ("whisper-small", "long_500k"), ("qwen2-vl-72b", "long_500k"),
        ("deepseek-moe-16b", "long_500k"), ("deepseek-v2-236b", "long_500k"),
        ("gemma2-9b", "long_500k"), ("llama3-405b", "long_500k"),
        ("qwen2-72b", "long_500k")]
    for a, s in skips:
        lines.append(f"| {a} | {s} | skip | skip | — | — "
                     f"(full attention; DESIGN.md §4) |")
    return "\n".join(lines)


def roofline_rows():
    rows = []
    for key, r in sorted(load("16x16").items()):
        if r.get("status") != "ok" or key[1] == "serve_8k":
            continue
        cs = r.get("cost_scaled")
        if not cs or "error" in cs:
            cs = {"flops": r["cost"].get("flops", 0),
                  "bytes_accessed": r["cost"].get("bytes accessed", 0),
                  "wire_bytes_total":
                      r["collectives"]["wire_bytes_total"]}
            corrected = False
        else:
            corrected = True
        t_c = cs["flops"] / PEAK_FLOPS
        t_m = cs["bytes_accessed"] / HBM_BW
        t_n = cs["wire_bytes_total"] / ICI_BW
        dom_t = max(t_c, t_m, t_n)
        dom = {t_c: "compute", t_m: "memory", t_n: "collective"}[dom_t]
        tokens = r["global_batch"] * (r["seq_len"]
                                      if r["kind"] != "decode" else 1)
        na = r.get("model_params_active", 0)
        mf = (6.0 if r["kind"] == "train" else 2.0) * na * tokens
        hlo = cs["flops"] * r["n_devices"]
        rows.append(dict(
            arch=key[0], shape=key[1], t_c=t_c, t_m=t_m, t_n=t_n,
            dom=dom, frac=t_c / dom_t if dom_t else 0.0,
            useful=(mf / hlo) if hlo else 0.0, corrected=corrected))
    return rows


def roofline_table():
    lines = ["| arch | shape | t_comp s | t_mem s | t_coll s | dominant | "
             "roofline% | useful% | scan-corr |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in roofline_rows():
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_c']:.4f} | "
            f"{r['t_m']:.4f} | {r['t_n']:.4f} | {r['dom']} | "
            f"{100*r['frac']:.1f} | {100*r['useful']:.1f} | "
            f"{'✓' if r['corrected'] else 'raw'} |")
    return "\n".join(lines)


def insert(marker: str, content: str, text: str) -> str:
    pat = re.compile(
        re.escape(marker) + r"(\n<!-- begin generated -->.*?"
        r"<!-- end generated -->)?", re.S)
    repl = (marker + "\n<!-- begin generated -->\n" + content
            + "\n<!-- end generated -->")
    return pat.sub(lambda _: repl, text, count=1)


def main():
    with open(EXP) as f:
        text = f.read()
    text = insert("<!-- DRYRUN_TABLE -->", dryrun_table(), text)
    text = insert("<!-- ROOFLINE_TABLE -->", roofline_table(), text)
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables refreshed")


if __name__ == "__main__":
    main()
