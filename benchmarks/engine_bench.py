"""Beyond-paper engine benchmarks: batched serving throughput + kernel µbench.

The paper measures per-query latency under a disk cost model; the TPU engine's
native metric is batched throughput (queries/s) and bytes-touched. This
harness reports both, plus microbenchmarks of the Pallas kernel entry points
(interpret mode on CPU — wall numbers are for relative tracking only; the
roofline analysis in EXPERIMENTS.md covers the TPU target).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build, device_tree as dt, labels
from repro.core.hybrid import hybrid_query
from repro.core.rtree import RTree
from repro.data import synth


def _time(fn, reps=5):
    fn()  # warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.time() - t0) / reps


def serving_throughput(rows: list, n_points: int = 120_000,
                       batch: int = 512) -> None:
    pts = synth.tweets_like(n_points, seed=0)
    tree = RTree(max_entries=128).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 5e-5, 4000, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, rep = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(8, 12))
    q = jnp.asarray(wl.queries[:batch])
    for force in ("r", "ai", "auto"):
        dtm = _time(lambda: hybrid_query(hyb, q, force_path=force))
        out = hybrid_query(hyb, q, force_path=force)
        acc = float(np.asarray(out.leaf_accesses).mean())
        # bytes touched ≈ leaf accesses × leaf tile bytes
        tile = dtree.leaf_entries.shape[1] * 2 * 4
        rows.append((f"serve_{force}_qps", batch / dtm,
                     f"leaf_acc={acc:.2f},tile_bytes={tile}"))


def kernel_micro(rows: list) -> None:
    from repro.kernels import ops
    rng = np.random.default_rng(0)

    def rects(n):
        lo = rng.uniform(-1, 1, (n, 2))
        w = rng.uniform(0, 0.3, (n, 2))
        return jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)

    q, m = rects(1024), rects(4096)
    dtm = _time(lambda: ops.mbr_intersect(q, m))
    rows.append(("mbr_intersect_1024x4096_us", dtm * 1e6,
                 f"{1024*4096/dtm/1e9:.2f}Gpairs/s"))

    entries = jnp.asarray(rng.uniform(-1, 1, (4096, 256, 2)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 4096, (256, 32)), jnp.int32)
    val = jnp.ones((256, 32), jnp.int32)
    dtm = _time(lambda: ops.leaf_refine(q[:256], entries, idx, val))
    rows.append(("leaf_refine_256x32x256_us", dtm * 1e6,
                 f"{256*32*256/dtm/1e9:.2f}Gtests/s"))

    feats = q[:, :4]
    fidx = jnp.asarray(rng.integers(0, 4, (16, 8)), jnp.int32)
    th = jnp.asarray(rng.uniform(-1, 1, (16, 8)), jnp.float32)
    tb = jnp.asarray(rng.uniform(0, 1, (16, 256, 128)), jnp.float32)
    dtm = _time(lambda: ops.forest_infer(feats, fidx, th, tb))
    rows.append(("forest_infer_1024x16_us", dtm * 1e6, ""))

    BH, T, dk, dv = 8, 512, 64, 64
    r = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, T, dv)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.3, 0.999, (BH, T, dk)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(BH, dk)), jnp.float32)
    dtm = _time(lambda: ops.wkv6(r, k, v, w, u), reps=2)
    rows.append(("wkv6_8x512x64_us", dtm * 1e6,
                 f"{BH*T/dtm/1e6:.2f}Mtok/s"))


def main() -> list:
    rows: list = []
    serving_throughput(rows)
    kernel_micro(rows)
    for name, val, extra in rows:
        print(f"{name},{val:.2f},{extra}")
    return rows


if __name__ == "__main__":
    main()
