"""Beyond-paper engine benchmarks: batched serving throughput + kernel µbench.

The paper measures per-query latency under a disk cost model; the TPU engine's
native metric is batched throughput (queries/s) and bytes-touched. This
harness reports both, plus microbenchmarks of the Pallas kernel entry points
(interpret mode on CPU — wall numbers are for relative tracking only; the
roofline analysis in EXPERIMENTS.md covers the TPU target).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build, device_tree as dt, labels
from repro.core.hybrid import hybrid_query
from repro.core.rtree import RTree
from repro.data import synth


def _time(fn, reps=5):
    fn()  # warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.time() - t0) / reps


def serving_throughput(rows: list, n_points: int = 120_000,
                       batch: int = 512) -> None:
    pts = synth.tweets_like(n_points, seed=0)
    tree = RTree(max_entries=128).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 5e-5, 4000, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, rep = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(8, 12))
    q = jnp.asarray(wl.queries[:batch])
    for force in ("r", "ai", "auto"):
        dtm = _time(lambda: hybrid_query(hyb, q, force_path=force))
        out = hybrid_query(hyb, q, force_path=force)
        acc = float(np.asarray(out.leaf_accesses).mean())
        # bytes touched ≈ leaf accesses × leaf tile bytes
        tile = dtree.leaf_entries.shape[1] * 2 * 4
        rows.append((f"serve_{force}_qps", batch / dtm,
                     f"leaf_acc={acc:.2f},tile_bytes={tile}"))


def _synth_levels(L: int, fanout: int, rng):
    """STR-packed synthetic hierarchy (spatially tight leaf-ID tiles)."""
    from repro.data.synth_tree import synth_levels
    mbrs, parents = synth_levels(L, fanout, rng, str_pack=True)
    return ([jnp.asarray(m) for m in mbrs],
            [jnp.asarray(p) for p in parents])


def _med_time(fn, reps: int = 15) -> float:
    """Median wall time (s) — robust to the noisy shared-CPU container."""
    jax.block_until_ready(fn())  # warm / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def traversal_micro(rows: list, B: int = 256, L: int = 2048,
                    fanout: int = 4) -> None:
    """Fused single-pass traversal vs per-level kernel path vs jnp oracle.

    Interpret mode on CPU — wall numbers track relative cost only, but the
    fused/per-level ratio is the perf gate for this subsystem: the fused
    kernel replaces H pallas_calls + H−1 HBM mask round-trips with one
    call, and its tile-level early exit skips dead subtrees outright.
    Three workloads: uniform small queries, a spatially clustered serving
    batch (most leaf tiles dead), and an all-dead batch (frontier dies at
    the root).
    """
    import functools

    from repro.core.device_tree import DeviceTree, Level
    from repro.core import traversal
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    mbrs, parents = _synth_levels(L, fanout, rng)
    tree = DeviceTree(
        levels=tuple(Level(mbrs=m, parent=p)
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.zeros((L, 8, 2), jnp.float32),
        leaf_entry_ids=jnp.zeros((L, 8), jnp.int32),
        leaf_counts=jnp.zeros((L,), jnp.int32),
        n_points=0, max_entries=fanout)

    lo = rng.uniform(-1, 1, (B, 2))
    w = rng.uniform(0, 0.05, (B, 2))
    q_uniform = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    c = rng.uniform(-0.8, 0.6, (1, 2))
    lo = c + rng.uniform(0, 0.15, (B, 2))
    w = rng.uniform(0, 0.02, (B, 2))
    q_cluster = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    q_dead = jnp.asarray(
        np.tile(np.array([[50.0, 50.0, 51.0, 51.0]], np.float32), (B, 1)))

    fused = jax.jit(functools.partial(ops.traverse_fused))
    per_level = jax.jit(functools.partial(
        traversal.visited_leaf_mask_per_level, use_kernel=True))
    oracle = jax.jit(functools.partial(
        traversal.visited_leaf_mask_per_level, use_kernel=False))

    lm = [lv.mbrs for lv in tree.levels]
    lp = [lv.parent for lv in tree.levels]
    shape = f"B{B}xL{L}"
    for wl, q in [("uniform", q_uniform), ("clustered", q_cluster),
                  ("alldead", q_dead)]:
        # sanity: identical masks, or the timing comparison is meaningless
        np.testing.assert_array_equal(np.asarray(fused(q, lm, lp)),
                                      np.asarray(oracle(tree, q)))
        t_fused = _med_time(lambda: fused(q, lm, lp))
        t_level = _med_time(lambda: per_level(tree, q))
        rows.append((f"traversal_fused_{wl}_{shape}_us", t_fused * 1e6,
                     f"speedup_vs_per_level={t_level / t_fused:.2f}x"))
        rows.append((f"traversal_per_level_{wl}_{shape}_us", t_level * 1e6,
                     f"levels={len(lm)}"))
    t_oracle = _med_time(lambda: oracle(tree, q_uniform))
    rows.append((f"traversal_oracle_jnp_{shape}_us", t_oracle * 1e6, ""))


def compaction_micro(rows: list, B: int = 256, L: int = 2048,
                     fanout: int = 4, k: int = 64) -> None:
    """Fused-compact epilogue vs mask+compact hand-off (traversal+refine).

    Both sides end with identical scalar-prefetch ``leaf_refine`` inputs;
    the difference under test is the traversal→compaction hand-off: the
    mask+compact path writes the ``[B, L]`` visited mask to HBM and
    re-scans it with the jnp ``compact_mask``, while the fused-compact path
    emits the ``[B, k]`` slot table and per-row counts straight from the
    kernel's VMEM-resident frontier. Interpret mode on CPU — relative cost
    only, same workloads as ``traversal_micro``.
    """
    from repro.core.device_tree import DeviceTree, Level
    from repro.core import traversal
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    mbrs, parents = _synth_levels(L, fanout, rng)
    tree = DeviceTree(
        levels=tuple(Level(mbrs=m, parent=p)
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.asarray(rng.uniform(-1, 1, (L, 8, 2)), jnp.float32),
        leaf_entry_ids=jnp.zeros((L, 8), jnp.int32),
        leaf_counts=jnp.full((L,), 8, jnp.int32),
        n_points=0, max_entries=fanout)
    lm = [lv.mbrs for lv in tree.levels]
    lp = [lv.parent for lv in tree.levels]

    lo = rng.uniform(-1, 1, (B, 2))
    w = rng.uniform(0, 0.05, (B, 2))
    q_uniform = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    c = rng.uniform(-0.8, 0.6, (1, 2))
    lo = c + rng.uniform(0, 0.15, (B, 2))
    w = rng.uniform(0, 0.02, (B, 2))
    q_cluster = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    q_dead = jnp.asarray(
        np.tile(np.array([[50.0, 50.0, 51.0, 51.0]], np.float32), (B, 1)))

    @jax.jit
    def fused_compact(q):
        idx, valid, cnt = ops.traverse_compact(q, lm, lp, k)
        ref = traversal.refine_leaves(tree, q, idx, valid, use_kernel=True)
        return ref.counts, cnt

    @jax.jit
    def mask_compact(q):
        mask = ops.traverse_fused(q, lm, lp)
        idx, valid, cnt = traversal.compact_mask_counted(mask, k)
        ref = traversal.refine_leaves(tree, q, idx, valid, use_kernel=True)
        return ref.counts, cnt

    shape = f"B{B}xL{L}k{k}"
    for wl, q in [("uniform", q_uniform), ("clustered", q_cluster),
                  ("alldead", q_dead)]:
        # sanity: identical outputs, or the timing comparison is meaningless
        fc, fcnt = fused_compact(q)
        mc, mcnt = mask_compact(q)
        np.testing.assert_array_equal(np.asarray(fc), np.asarray(mc))
        np.testing.assert_array_equal(np.asarray(fcnt), np.asarray(mcnt))
        t_fused = _med_time(lambda: fused_compact(q))
        t_mask = _med_time(lambda: mask_compact(q))
        rows.append((f"compact_fused_{wl}_{shape}_us", t_fused * 1e6,
                     f"speedup_vs_mask_compact={t_mask / t_fused:.2f}x"))
        rows.append((f"compact_mask_{wl}_{shape}_us", t_mask * 1e6, ""))


def kernel_micro(rows: list) -> None:
    from repro.kernels import ops
    rng = np.random.default_rng(0)

    def rects(n):
        lo = rng.uniform(-1, 1, (n, 2))
        w = rng.uniform(0, 0.3, (n, 2))
        return jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)

    q, m = rects(1024), rects(4096)
    dtm = _time(lambda: ops.mbr_intersect(q, m))
    rows.append(("mbr_intersect_1024x4096_us", dtm * 1e6,
                 f"{1024*4096/dtm/1e9:.2f}Gpairs/s"))

    entries = jnp.asarray(rng.uniform(-1, 1, (4096, 256, 2)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 4096, (256, 32)), jnp.int32)
    val = jnp.ones((256, 32), jnp.int32)
    dtm = _time(lambda: ops.leaf_refine(q[:256], entries, idx, val))
    rows.append(("leaf_refine_256x32x256_us", dtm * 1e6,
                 f"{256*32*256/dtm/1e9:.2f}Gtests/s"))

    feats = q[:, :4]
    fidx = jnp.asarray(rng.integers(0, 4, (16, 8)), jnp.int32)
    th = jnp.asarray(rng.uniform(-1, 1, (16, 8)), jnp.float32)
    tb = jnp.asarray(rng.uniform(0, 1, (16, 256, 128)), jnp.float32)
    dtm = _time(lambda: ops.forest_infer(feats, fidx, th, tb))
    rows.append(("forest_infer_1024x16_us", dtm * 1e6, ""))

    BH, T, dk, dv = 8, 512, 64, 64
    r = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, T, dv)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.3, 0.999, (BH, T, dk)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(BH, dk)), jnp.float32)
    dtm = _time(lambda: ops.wkv6(r, k, v, w, u), reps=2)
    rows.append(("wkv6_8x512x64_us", dtm * 1e6,
                 f"{BH*T/dtm/1e6:.2f}Mtok/s"))


def main(quick: bool = False) -> list:
    rows: list = []
    serving_throughput(rows, n_points=30_000 if quick else 120_000,
                       batch=256 if quick else 512)
    traversal_micro(rows)
    compaction_micro(rows)
    kernel_micro(rows)
    for name, val, extra in rows:
        print(f"{name},{val:.2f},{extra}")
    return rows


if __name__ == "__main__":
    main()
