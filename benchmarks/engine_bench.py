"""Beyond-paper engine benchmarks: batched serving throughput + kernel µbench.

The paper measures per-query latency under a disk cost model; the TPU engine's
native metric is batched throughput (queries/s) and bytes-touched. This
harness reports both, plus microbenchmarks of the Pallas kernel entry points
(interpret mode on CPU — wall numbers are for relative tracking only; the
roofline analysis in EXPERIMENTS.md covers the TPU target).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build, device_tree as dt, labels
from repro.core.hybrid import hybrid_query
from repro.core.rtree import RTree
from repro.data import synth


def _time(fn, reps=5):
    fn()  # warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.time() - t0) / reps


def serving_throughput(rows: list, n_points: int = 120_000,
                       batch: int = 512) -> None:
    pts = synth.tweets_like(n_points, seed=0)
    tree = RTree(max_entries=128).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 5e-5, 4000, seed=1)
    wl = labels.make_workload(dtree, qs)
    hyb, rep = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(8, 12))
    q = jnp.asarray(wl.queries[:batch])
    for force in ("r", "ai", "auto"):
        dtm = _time(lambda: hybrid_query(hyb, q, force_path=force))
        out = hybrid_query(hyb, q, force_path=force)
        acc = float(np.asarray(out.leaf_accesses).mean())
        # bytes touched ≈ leaf accesses × leaf tile bytes
        tile = dtree.leaf_entries.shape[1] * 2 * 4
        rows.append((f"serve_{force}_qps", batch / dtm,
                     f"leaf_acc={acc:.2f},tile_bytes={tile}"))


def query_type_throughput(rows: list, n_points: int = 120_000,
                          batch: int = 512) -> None:
    """Serving throughput of the non-range query types — kNN, point,
    spatial join — on the same slot-table contract as the range path.
    Emits ``_qps`` rows so ``run.py --check`` guards them with the same
    inverted tolerance as ``serve_*_qps``."""
    from repro.core import hybrid as hybmod, joins
    from repro.core import knn as knnlib

    pts = synth.tweets_like(n_points, seed=0)
    dtree = dt.flatten(RTree.str_bulk(pts, max_entries=32))
    rng = np.random.default_rng(7)
    centers = pts[rng.integers(0, n_points, batch)].astype(np.float32)
    pq = jnp.asarray(np.concatenate([centers, centers], axis=1))

    k = 8
    r = knnlib.default_radius(dtree, k)
    knn_fn = jax.jit(lambda q: knnlib.knn_query(dtree, q, k=k, radius=r,
                                                max_visited=64))
    dtm = _time(lambda: knn_fn(pq))
    out = knn_fn(pq)
    acc = float(np.asarray(out.leaf_accesses).mean())
    rows.append(("knn_serve_qps", batch / dtm,
                 f"k={k},r={r:.3g},leaf_acc={acc:.2f},"
                 f"trunc={int(np.asarray(out.truncated).sum())}"))

    outer = jnp.asarray(synth.synth_queries(pts, 1e-4, batch, seed=8))
    join_fn = jax.jit(lambda q: joins.join_step(dtree, q, max_pairs=32,
                                                max_visited=64))
    dtm = _time(lambda: join_fn(outer))
    out = join_fn(outer)
    rows.append(("join_outer_qps", batch / dtm,
                 f"max_pairs=32,pairs={int(np.asarray(out.n_pairs).sum())}"))

    qs = synth.synth_queries(pts, 5e-5, 1500, seed=9)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = build.fit_airtree(dtree, wl, kind="knn", grid_sizes=(8,))
    pt_fn = jax.jit(lambda q: hybmod.point_query(hyb, q))
    dtm = _time(lambda: pt_fn(pq))
    out = pt_fn(pq)
    assert not np.asarray(out.truncated).any(), \
        "point path truncated — narrowed bounds failed to cover"
    acc = float(np.asarray(out.leaf_accesses).mean())
    rows.append(("point_serve_qps", batch / dtm, f"leaf_acc={acc:.2f}"))


def _synth_levels(L: int, fanout: int, rng):
    """STR-packed synthetic hierarchy (spatially tight leaf-ID tiles)."""
    from repro.data.synth_tree import synth_levels
    mbrs, parents = synth_levels(L, fanout, rng, str_pack=True)
    return ([jnp.asarray(m) for m in mbrs],
            [jnp.asarray(p) for p in parents])


def _med_time(fn, reps: int = 15) -> float:
    """Median wall time (s) — robust to the noisy shared-CPU container."""
    jax.block_until_ready(fn())  # warm / compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _med_time_pair(fa, fb, reps: int = 25) -> tuple[float, float]:
    """Interleaved medians of two competitors — back-to-back sampling
    cancels the container's load drift, which otherwise dwarfs a closely
    matched comparison measured in separate blocks."""
    jax.block_until_ready(fa())
    jax.block_until_ready(fb())
    ta, tb_ = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fa())
        t1 = time.perf_counter()
        jax.block_until_ready(fb())
        ta.append(t1 - t0)
        tb_.append(time.perf_counter() - t1)
    return float(np.median(ta)), float(np.median(tb_))


def traversal_micro(rows: list, B: int = 256, L: int = 2048,
                    fanout: int = 4) -> None:
    """Fused single-pass traversal vs per-level kernel path vs jnp oracle.

    Interpret mode on CPU — wall numbers track relative cost only, but the
    fused/per-level ratio is the perf gate for this subsystem: the fused
    kernel replaces H pallas_calls + H−1 HBM mask round-trips with one
    call, and its tile-level early exit skips dead subtrees outright.
    Three workloads: uniform small queries, a spatially clustered serving
    batch (most leaf tiles dead), and an all-dead batch (frontier dies at
    the root).
    """
    import functools

    from repro.core.device_tree import DeviceTree, Level
    from repro.core import traversal
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    mbrs, parents = _synth_levels(L, fanout, rng)
    tree = DeviceTree(
        levels=tuple(Level(mbrs=m, parent=p)
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.zeros((L, 8, 2), jnp.float32),
        leaf_entry_ids=jnp.zeros((L, 8), jnp.int32),
        leaf_counts=jnp.zeros((L,), jnp.int32),
        n_points=0, max_entries=fanout)

    lo = rng.uniform(-1, 1, (B, 2))
    w = rng.uniform(0, 0.05, (B, 2))
    q_uniform = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    c = rng.uniform(-0.8, 0.6, (1, 2))
    lo = c + rng.uniform(0, 0.15, (B, 2))
    w = rng.uniform(0, 0.02, (B, 2))
    q_cluster = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    q_dead = jnp.asarray(
        np.tile(np.array([[50.0, 50.0, 51.0, 51.0]], np.float32), (B, 1)))

    fused = jax.jit(functools.partial(ops.traverse_fused))
    per_level = jax.jit(functools.partial(
        traversal.visited_leaf_mask_per_level, use_kernel=True))
    oracle = jax.jit(functools.partial(
        traversal.visited_leaf_mask_per_level, use_kernel=False))

    lm = [lv.mbrs for lv in tree.levels]
    lp = [lv.parent for lv in tree.levels]
    shape = f"B{B}xL{L}"
    for wl, q in [("uniform", q_uniform), ("clustered", q_cluster),
                  ("alldead", q_dead)]:
        # sanity: identical masks, or the timing comparison is meaningless
        np.testing.assert_array_equal(np.asarray(fused(q, lm, lp)),
                                      np.asarray(oracle(tree, q)))
        t_fused = _med_time(lambda: fused(q, lm, lp))
        t_level = _med_time(lambda: per_level(tree, q))
        rows.append((f"traversal_fused_{wl}_{shape}_us", t_fused * 1e6,
                     f"speedup_vs_per_level={t_level / t_fused:.2f}x"))
        rows.append((f"traversal_per_level_{wl}_{shape}_us", t_level * 1e6,
                     f"levels={len(lm)}"))
    t_oracle = _med_time(lambda: oracle(tree, q_uniform))
    rows.append((f"traversal_oracle_jnp_{shape}_us", t_oracle * 1e6, ""))


def compaction_micro(rows: list, B: int = 256, L: int = 2048,
                     fanout: int = 4, k: int = 64) -> None:
    """Fused-compact epilogue vs mask+compact hand-off (traversal+refine).

    Both sides end with identical scalar-prefetch ``leaf_refine`` inputs;
    the difference under test is the traversal→compaction hand-off: the
    mask+compact path writes the ``[B, L]`` visited mask to HBM and
    re-scans it with the jnp ``compact_mask``, while the fused-compact path
    emits the ``[B, k]`` slot table and per-row counts straight from the
    kernel's VMEM-resident frontier. Interpret mode on CPU — relative cost
    only, same workloads as ``traversal_micro``.
    """
    from repro.core.device_tree import DeviceTree, Level
    from repro.core import traversal
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    mbrs, parents = _synth_levels(L, fanout, rng)
    tree = DeviceTree(
        levels=tuple(Level(mbrs=m, parent=p)
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.asarray(rng.uniform(-1, 1, (L, 8, 2)), jnp.float32),
        leaf_entry_ids=jnp.zeros((L, 8), jnp.int32),
        leaf_counts=jnp.full((L,), 8, jnp.int32),
        n_points=0, max_entries=fanout)
    lm = [lv.mbrs for lv in tree.levels]
    lp = [lv.parent for lv in tree.levels]

    lo = rng.uniform(-1, 1, (B, 2))
    w = rng.uniform(0, 0.05, (B, 2))
    q_uniform = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    c = rng.uniform(-0.8, 0.6, (1, 2))
    lo = c + rng.uniform(0, 0.15, (B, 2))
    w = rng.uniform(0, 0.02, (B, 2))
    q_cluster = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
    q_dead = jnp.asarray(
        np.tile(np.array([[50.0, 50.0, 51.0, 51.0]], np.float32), (B, 1)))

    @jax.jit
    def fused_compact(q):
        idx, valid, cnt = ops.traverse_compact(q, lm, lp, k)
        ref = traversal.refine_leaves(tree, q, idx, valid, use_kernel=True)
        return ref.counts, cnt

    @jax.jit
    def mask_compact(q):
        mask = ops.traverse_fused(q, lm, lp)
        idx, valid, cnt = traversal.compact_mask_counted(mask, k)
        ref = traversal.refine_leaves(tree, q, idx, valid, use_kernel=True)
        return ref.counts, cnt

    shape = f"B{B}xL{L}k{k}"
    for wl, q in [("uniform", q_uniform), ("clustered", q_cluster),
                  ("alldead", q_dead)]:
        # sanity: identical outputs, or the timing comparison is meaningless
        fc, fcnt = fused_compact(q)
        mc, mcnt = mask_compact(q)
        np.testing.assert_array_equal(np.asarray(fc), np.asarray(mc))
        np.testing.assert_array_equal(np.asarray(fcnt), np.asarray(mcnt))
        t_fused = _med_time(lambda: fused_compact(q))
        t_mask = _med_time(lambda: mask_compact(q))
        rows.append((f"compact_fused_{wl}_{shape}_us", t_fused * 1e6,
                     f"speedup_vs_mask_compact={t_mask / t_fused:.2f}x"))
        rows.append((f"compact_mask_{wl}_{shape}_us", t_mask * 1e6, ""))


def ai_fusion_micro(rows: list, B: int = 256, L: int = 2048, g: int = 4,
                    Cl: int = 32, k: int = 64) -> None:
    """Fused AI-path prediction vs the dense pipeline it replaces.

    ``ai_dense_*`` is the pre-fusion serving form: gathered per-cell MLP
    forward → sigmoid → ``global_scores`` max-union scatter into the
    ``[B, L]`` score table → threshold → ``compact_mask_counted``.
    ``ai_fused_*`` is ``ops.mlp_predict_compact`` — the same semantics in
    one ``pallas_call`` whose only HBM output is the ``[B, k]`` slot
    table + counts (the [B, L] table never materializes; bit-identity is
    asserted before timing). Also rows the query-level pipelines
    (``ai_query`` vs ``ai_query_compact``, refine + gather included).
    Interpret mode on CPU — relative cost only; the derived column
    carries the dense-table bytes the fused form stops moving.
    """
    from repro.core import traversal
    from repro.core.aitree import (ai_query, ai_query_compact, make_aitree,
                                   predict_compact, predict_scores)
    from repro.core.device_tree import DeviceTree, Level
    from benchmarks._synth_ai import synth_mlp_bank, unit_grid

    rng = np.random.default_rng(0)
    bank = synth_mlp_bank(rng, g * g, L, Cl=Cl)
    C = g * g
    grid = unit_grid(g)
    ait = make_aitree(grid, bank, max_cells=4, max_pred=k)
    lo = rng.uniform(-1, 0.9, (B, 2))
    q = jnp.asarray(np.concatenate([lo, lo + 0.05], 1), jnp.float32)

    # both competitors are the full predict pipeline INCLUDING cell
    # routing (timing only one side's cells_of_queries would bias the
    # comparison) — exactly the two rungs predict_compact dispatches
    @jax.jit
    def dense(qq):
        scores, _ = predict_scores(ait, qq, L)
        return traversal.compact_mask_counted(scores > ait.threshold, k)

    @jax.jit
    def fused(qq):
        return predict_compact(ait, qq, L, use_kernel=True)[:3]

    # sanity: identical slots, or the timing comparison is meaningless
    for a, b in zip(fused(q), dense(q)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    shape = f"B{B}xL{L}k{k}"
    t_fused, t_dense = _med_time_pair(lambda: fused(q), lambda: dense(q),
                                      reps=40)
    dense_mb = B * L * 4 / 1e6
    rows.append((f"ai_fused_predict_{shape}_us", t_fused * 1e6,
                 f"speedup_vs_dense={t_dense / t_fused:.2f}x,"
                 f"dense_table_mb={dense_mb:.2f}"))
    rows.append((f"ai_dense_predict_{shape}_us", t_dense * 1e6,
                 f"cells={C},Cl={Cl}"))

    # query level: predict + refine + result gather, dense vs compact
    M = 8
    tree = DeviceTree(
        levels=(Level(mbrs=jnp.asarray(
            np.concatenate([lo2 := rng.uniform(-1, 1, (L, 2)),
                            lo2 + 0.2], 1), jnp.float32),
            parent=jnp.zeros((L,), jnp.int32)),),
        leaf_entries=jnp.asarray(rng.uniform(-1, 1, (L, M, 2)), jnp.float32),
        leaf_entry_ids=jnp.asarray(np.arange(L * M).reshape(L, M),
                                   jnp.int32),
        leaf_counts=jnp.full((L,), M, jnp.int32), n_points=L * M,
        max_entries=M)
    qd = jax.jit(lambda qq: ai_query(ait, tree, qq, max_results=128))
    qf = jax.jit(lambda qq: ai_query_compact(ait, tree, qq, max_results=128,
                                             use_kernel=True))
    rd, rf = qd(q), qf(q)
    np.testing.assert_array_equal(np.asarray(rd.n_results),
                                  np.asarray(rf.n_results))
    np.testing.assert_array_equal(np.asarray(rd.fallback),
                                  np.asarray(rf.fallback))
    t_f, t_d = _med_time_pair(lambda: qf(q), lambda: qd(q), reps=40)
    rows.append((f"ai_fused_query_{shape}_us", t_f * 1e6,
                 f"speedup_vs_dense={t_d / t_f:.2f}x"))
    rows.append((f"ai_dense_query_{shape}_us", t_d * 1e6, ""))


def _sched_traffic(Q: int, kind: str, rng) -> np.ndarray:
    """Serving traffic in *arrival* order: spatially mixed streams.

    ``clustered``: queries draw from a handful of hotspots but arrive
    interleaved (the realistic worst case the scheduler exists for —
    every unsorted batch touches every hotspot). ``uniform``: small rects
    everywhere.
    """
    if kind == "uniform":
        lo = rng.uniform(-1, 1, (Q, 2))
        w = rng.uniform(0, 0.05, (Q, 2))
    else:
        centers = rng.uniform(-0.9, 0.7, (16, 2))
        which = rng.integers(0, centers.shape[0], Q)
        lo = centers[which] + rng.normal(0, 0.01, (Q, 2))
        w = rng.uniform(0, 0.005, (Q, 2))
    q = np.concatenate([lo, lo + w], 1).astype(np.float32)
    rng.shuffle(q)                      # arrival order ≠ spatial order
    return q


def scheduler_bench(rows: list, Q: int = 2048, batch: int = 256,
                    L: int = 4096, fanout: int = 4, k: int = 64,
                    check: bool = True) -> None:
    """Spatial batch scheduler: full-stream serving, sorted vs unsorted.

    The serve step per batch is the kernel-path compact pipeline
    (``range_query_compact``), pinned to the **leaf-tile grid** form
    (``tile_l = DEF_TL``) — the TPU-shaped graph whose ``pl.when`` tile
    early exit is what batch locality feeds. (The interpret-mode default
    folds the leaf axis into one tile, where only the per-subtile exit
    remains and its savings drown in the replicated internal walk — see
    EXPERIMENTS.md "Scheduler locality".) A Hilbert/Morton-ordered stream
    hands the kernel batches whose queries share a compact region, so
    most leaf tiles of most batches are dead before the intersection
    runs. ``live_sub`` in the derived column is the measured fraction of
    (batch × tile) pairs the early exit cannot skip — the locality the
    sort manufactures. Also rows the scheduler's own admission cost (the
    spatial_key kernel).
    """
    import functools

    from repro.core.device_tree import DeviceTree, Level
    from repro.core import schedule, traversal
    from repro.kernels import ops
    from repro.kernels import traverse_fused as tf

    rng = np.random.default_rng(0)
    mbrs, parents = _synth_levels(L, fanout, rng)
    tree = DeviceTree(
        levels=tuple(Level(mbrs=m, parent=p)
                     for m, p in zip(mbrs, parents)),
        leaf_entries=jnp.asarray(rng.uniform(-1, 1, (L, 8, 2)), jnp.float32),
        leaf_entry_ids=jnp.zeros((L, 8), jnp.int32),
        leaf_counts=jnp.full((L,), 8, jnp.int32),
        n_points=0, max_entries=fanout)

    tile_l = min(tf.DEF_TL, L)
    serve_fn = functools.partial(traversal.range_query_compact, tree,
                                 max_visited=k, max_results=64,
                                 use_kernel=True, tile_l=tile_l)
    leaf_mbrs = np.asarray(mbrs[-1])
    sub = tile_l    # early-exit granularity of the gridded form
    shape = f"Q{Q}B{batch}xL{L}"
    for kind in ("clustered", "uniform"):
        q = _sched_traffic(Q, kind, np.random.default_rng(1))
        bbox = schedule.workload_bbox(q)
        base = None
        results = {}
        for sort in ("none", "morton", "hilbert"):
            run = lambda s=sort: schedule.serve_workload(
                serve_fn, q, batch=batch, sort=s, bbox=bbox)
            results[sort] = run()
            t = _med_time(lambda: run(), reps=5)
            # live subtiles per batch: what the early exit cannot skip
            live = tot = 0
            sched = schedule.make_schedule(q, batch, sort, bbox)
            for chunk, _ in schedule.iter_batches(q, sched):
                hit = np.asarray(ops.mbr_intersect(
                    jnp.asarray(chunk), jnp.asarray(leaf_mbrs)))
                nsub = -(-hit.shape[1] // sub)
                for s in range(nsub):
                    tot += 1
                    live += bool(hit[:, s * sub:(s + 1) * sub].any())
            extra = f"live_sub={live / tot:.2f}"
            if sort == "none":
                base = t
            else:
                extra += f",speedup_vs_none={base / t:.2f}x"
            rows.append((f"sched_{sort}_{kind}_{shape}_us", t * 1e6, extra))
        if check:
            # the scheduler must be invisible in the results (and serve
            # every query): sorted == unsorted, field for field
            for sort in ("morton", "hilbert"):
                for f in type(results["none"].stats)._fields:
                    np.testing.assert_array_equal(
                        np.asarray(getattr(results["none"].stats, f)),
                        np.asarray(getattr(results[sort].stats, f)),
                        err_msg=f"{kind}:{sort}:{f}")

    q = jnp.asarray(_sched_traffic(Q, "uniform", np.random.default_rng(2)))
    bbox = jnp.asarray(schedule.workload_bbox(np.asarray(q)))
    for curve in ("hilbert", "morton"):
        t = _med_time(lambda: ops.spatial_key(q, bbox=bbox, curve=curve))
        rows.append((f"spatial_key_{curve}_Q{Q}_us", t * 1e6,
                     f"{Q / t / 1e6:.2f}Mkeys/s"))


def _fresh_world(n_points: int, n_ins: int, n_queries: int, seed: int = 0):
    """Toy mixed read/write world: STR base tree + held-out inserts."""
    from repro.core import build as buildlib
    pts = synth.tweets_like(n_points + n_ins, seed=seed)
    base, extra = pts[:n_points], pts[n_points:]
    dtree = dt.flatten(RTree.str_bulk(base, max_entries=32))
    qs = synth.synth_queries(pts, 2e-4, n_queries, seed=seed + 1)
    wl = labels.make_workload(dtree, qs)
    hyb, _ = buildlib.fit_airtree(dtree, wl, kind="knn", grid_sizes=(6,))
    return base, extra, dtree, wl, hyb


def freshness_bench(rows: list, n_points: int = 30_000, n_ins: int = 2048,
                    batch: int = 256) -> None:
    """Freshness subsystem costs: delta-probe vs buffer fill, staging,
    online repack, and the serving overhead of the delta stage
    (``update_*`` rows; see EXPERIMENTS.md "Freshness")."""
    from repro.core import delta as deltalib
    from repro.core.monitor import FreshServer
    from repro.kernels import ops

    base, extra, dtree, wl, hyb = _fresh_world(n_points, n_ins, 2000)
    q = jnp.asarray(wl.queries[:batch])

    # probe cost vs buffer fill (the [B, cap] mask never leaves VMEM; the
    # cost is capacity-shaped, not fill-shaped — rows document that)
    cap = n_ins
    for fill in (0, cap // 4, cap):
        store = deltalib.make_delta(cap, base=n_points)
        if fill:
            store = deltalib.stage_inserts(store, extra[:fill])
        t = _med_time(lambda s=store: ops.delta_probe(q, s.xy, k=64))
        rows.append((f"update_probe_B{batch}xN{cap}_fill{fill}_us", t * 1e6,
                     f"{batch / t / 1e3:.0f}kprobes/s"))

    # staging throughput (host append + device swap, between batches)
    def stage():
        deltalib.stage_inserts(deltalib.make_delta(cap, base=n_points),
                               extra)
        return jnp.zeros(())
    t = _med_time(stage, reps=7)
    rows.append((f"update_stage_{n_ins}_us", t * 1e6,
                 f"{n_ins / t / 1e3:.0f}kpts/s"))

    # online repack: bulk reload + flatten of base+staged
    store = deltalib.stage_inserts(
        deltalib.make_delta(cap, base=n_points), extra)

    def do_repack():
        deltalib.repack(base, store, max_entries=32)
        return jnp.zeros(())
    t = _med_time(do_repack, reps=3)
    rows.append((f"update_repack_{n_points + n_ins}_us", t * 1e6,
                 f"{(n_points + n_ins) / t / 1e6:.2f}Mpts/s"))

    # serving overhead of the freshness stage: FreshServer (probe + merge
    # + guard) vs the plain read-only hybrid, interleaved timing
    srv = FreshServer(base, hyb, delta_cap=cap, max_visited=128,
                      max_results=512)
    srv.insert(extra[:cap // 2])
    ro = jax.jit(lambda qq: hybrid_query(hyb, qq, max_visited=128))
    tf_, tr = _med_time_pair(lambda: srv.serve(q), lambda: ro(q))
    rows.append((f"update_serve_B{batch}_us", tf_ * 1e6,
                 f"readonly_us={tr * 1e6:.0f},overhead="
                 f"{(tf_ / tr - 1) * 100:.0f}%,qps={batch / tf_:.0f}"))


def freshness_smoke(rows: list) -> None:
    """Toy mixed read/write gate (``make bench-smoke`` / CI): stream
    queries with inserts interleaved and a mid-stream repack, then
    *assert* delta-serving ≡ the from-scratch rebuild oracle — result
    counts per segment against exactly the points visible to it, and the
    post-repack serve bit-identical to a fresh bulk load."""
    import dataclasses

    from repro.core import delta as deltalib, schedule
    from repro.core.monitor import FreshServer

    base, extra, dtree, wl, hyb = _fresh_world(6000, 600, 300)
    srv = FreshServer(base, hyb, delta_cap=1024, max_visited=128,
                      max_results=512)
    t0 = time.time()
    mixed = schedule.serve_mixed_workload(
        srv, wl.queries, extra, batch=64, sort="hilbert", insert_every=1,
        repack_every=400)
    dt_s = time.time() - t0
    assert mixed.n_repacks >= 1, "gate must exercise the online repack"
    # per-segment rebuild oracle: n_results over the visible point set
    # (schedule.visible_segments — the scheduler's actual staging)
    from repro.core import geometry as geo
    got = np.asarray(mixed.stats.n_results)
    for (lo, hi), visible in schedule.visible_segments(mixed, base):
        exp = geo.np_contains_point(
            wl.queries[lo:hi][:, None, :], visible[None, :, :]).sum(axis=1)
        np.testing.assert_array_equal(got[lo:hi], exp,
                                      err_msg=f"segment {lo}:{hi}")
    # repack ≡ rebuild: the swapped tree is bit-identical to a fresh
    # bulk load of the same points, so serving it must be too
    srv.repack()
    rebuilt = dt.flatten(RTree.str_bulk(srv.points, max_entries=32))
    hyb2 = dataclasses.replace(srv.hybrid, tree=rebuilt)
    q = jnp.asarray(wl.queries[:64])
    a = srv.serve(q)
    b = hybrid_query(hyb2, q, max_visited=128, max_results=512)
    for f in type(b)._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"repack vs rebuild: {f}")
    rows.append(("update_smoke_stream_us", dt_s * 1e6,
                 f"{mixed.n_queries}q/{mixed.n_inserts}ins/"
                 f"{mixed.n_repacks}repack,oracle=exact"))


def refit_bench(rows: list, quick: bool = False) -> None:
    """Incremental ``build.refit_cells`` vs a from-scratch fit.

    The instance-optimization loop's cost claim: when a localized change
    dirties ≤ 25% of the grid cells, retraining just those cells (chunk
    relabel + per-cell train + splice + partial recertify) must beat the
    full pipeline (full relabel + all-cell train + full certify) by a
    wide margin — the per-cell training pipeline's bit-determinism makes
    the two *results* identical, so the rows measure pure cost. Both
    sides include their labelling work (refit relabels internally; the
    full side pays ``make_workload``).

    Gate: ≥5x for the knn bank (fit cost scales with the touched query/
    cell set, so the ratio tracks the dirty fraction directly). The mlp
    row is asserted at a lower floor on this CPU harness: the Adam epoch
    loop has a fixed per-step dispatch cost that dominates tiny cell
    batches, flattening the trained-cells ratio (20 vs 100 cells ≈ 3.5x
    wall here); on an accelerator the per-epoch cost is matmul-bound and
    the ratio recovers toward cells_full/cells_chunk."""
    import dataclasses as dc

    from repro.core import build as buildlib

    floor = {"knn": 5.0, "mlp": 2.5}
    for kind in ("knn", "mlp"):
        pts = synth.tweets_like(4000 if quick else 6000, seed=0)
        tree = RTree(max_entries=32).insert_all(pts)
        dtree = dt.flatten(tree)
        qs = synth.synth_queries(pts, 1e-3, 300 if quick else 500, seed=1)
        lkw = {"max_results": 2048}
        wl = labels.make_workload(dtree, qs, **lkw)
        kw = dict(kind=kind, grid_sizes=(10,), label_kwargs=lkw)
        if kind == "mlp":
            kw.update(mlp_hidden=32, mlp_epochs=200 if quick else 400)
        hyb, rep = buildlib.fit_airtree(dtree, wl, **kw)
        state = rep.fit_state

        # localized inserts: one tight cluster in a data corner, through
        # the host tree's dynamic insert path (split cascades included)
        rng = np.random.default_rng(7)
        lo, hi = pts.min(axis=0), pts.max(axis=0)
        corner = lo + 0.02 * (hi - lo)
        newp = (corner + np.abs(rng.normal(0, 0.001, (20, 2)))
                ).astype(np.float32)
        tree.insert_all(newp)
        dtree2 = dt.flatten(tree)
        hyb2 = dc.replace(hyb, tree=dtree2)

        _, s_chk, r_chk = buildlib.refit_cells(hyb2, state)
        frac = r_chk.cells_changed / state.n_cells
        assert frac <= 0.25, \
            f"scenario must stay localized, got {frac:.0%} cells changed"

        def inc():
            buildlib.refit_cells(hyb2, state)
            return jnp.zeros(())

        def full():
            wl2 = labels.make_workload(dtree2, qs, **lkw)
            kwf = dict(kw, max_labels=state.cl, max_queries=state.qp)
            buildlib.fit_airtree(dtree2, wl2, **kwf)
            return jnp.zeros(())

        t_inc = _med_time(inc, reps=3)
        t_full = _med_time(full, reps=3)
        rows.append((f"refit_cells_{kind}_us", t_inc * 1e6,
                     f"cells={r_chk.cells_changed}/{state.n_cells},"
                     f"relabel={r_chk.n_relabeled},"
                     f"speedup_vs_full={t_full / t_inc:.2f}x"))
        rows.append((f"refit_full_{kind}_us", t_full * 1e6,
                     f"queries={qs.shape[0]}"))
        assert t_full / t_inc >= floor[kind], \
            f"incremental refit must be ≥{floor[kind]}x cheaper at " \
            f"≤25% cells changed, got {t_full / t_inc:.2f}x ({kind})"


def refit_recovery_smoke(rows: list) -> None:
    """``make bench-smoke`` gate for the online instance-optimization
    loop: stream queries + localized inserts through a policy-driven
    ``FreshServer`` and *assert* (a) the policy repacked mid-stream,
    (b) the AI path came back within the refit-chunk drain budget after
    the first repack — via incremental ``refit_cells`` alone (a full
    ``fit_airtree`` on the serve path trips the planted raiser), and
    (c) every segment served exactly against its visible points."""
    from repro.core import build as buildlib, schedule
    from repro.core import geometry as geo
    from repro.core.monitor import DefaultPolicy, FreshServer

    pts = synth.tweets_like(3000, seed=0)
    tree = RTree(max_entries=32).insert_all(pts)
    dtree = dt.flatten(tree)
    qs = synth.synth_queries(pts, 1e-3, 150, seed=1)
    lkw = {"max_results": 2048}
    wl = labels.make_workload(dtree, qs, **lkw)
    hyb, rep = buildlib.fit_airtree(dtree, wl, kind="knn", grid_sizes=(4,),
                                    label_kwargs=lkw)
    chunk = 4
    srv = FreshServer(pts, hyb, delta_cap=256, max_visited=256,
                      max_results=512, fit_state=rep.fit_state,
                      policy=DefaultPolicy(refit_chunk=chunk,
                                           repack_at=0.1))
    stream = np.tile(qs, (4, 1))
    rng = np.random.default_rng(5)
    lo, hi = pts.min(axis=0), pts.max(axis=0)
    ins = (lo + 0.02 * (hi - lo)
           + np.abs(rng.normal(0, 0.004, (200, 2)))).astype(np.float32)

    real_fit = buildlib.fit_airtree

    def _raiser(*a, **k):
        raise AssertionError("full fit_airtree ran on the serve path")

    t0 = time.time()
    buildlib.fit_airtree = _raiser
    try:
        mixed = schedule.serve_mixed_workload(
            srv, stream, ins, batch=50, sort="hilbert", insert_every=1,
            repack_every=0)
    finally:
        buildlib.fit_airtree = real_fit
    dt_s = time.time() - t0

    n_repacks = sum(d.repack for _, d in mixed.maintenance)
    assert n_repacks >= 1, "gate must exercise a policy repack"
    n_refit = sum(r.cells_refit for r in srv.refits)
    assert n_refit > 0, "recovery must run through refit_cells chunks"
    # recovery budget: with C cells stale and `chunk` per segment, the
    # drain takes ceil(C / chunk) segments — the AI path must be back
    # within that window after the first repack
    first_rp = next(s for s, d in mixed.maintenance if d.repack)
    budget = -(-rep.fit_state.n_cells // chunk)
    u = np.asarray(mixed.stats.used_ai)
    seg_ai = [u[b:e].mean() for b, e in mixed.seg_bounds]
    window = seg_ai[first_rp + 1:first_rp + 1 + budget]
    assert window and max(window) > 0.2, \
        f"AI path did not recover within {budget} segments: {seg_ai}"
    got = np.asarray(mixed.stats.n_results)
    for (b, e), visible in schedule.visible_segments(mixed, pts):
        exp = geo.np_contains_point(
            stream[b:e][:, None, :], visible[None, :, :]).sum(axis=1)
        np.testing.assert_array_equal(got[b:e], exp,
                                      err_msg=f"segment {b}:{e}")
    rows.append(("refit_recovery_smoke_us", dt_s * 1e6,
                 f"repacks={n_repacks},refit_cells={n_refit},"
                 f"recovered<= {budget}seg,oracle=exact"))


def knn_smoke(rows: list) -> None:
    """kNN gate: two-tier distance browsing vs the brute-force
    k-distance oracle. Every row's reported neighbors must be a
    bit-exact prefix of the brute kNN — full length when not truncated,
    the in-radius prefix otherwise — so nothing is ever silently
    dropped; the deliberately tight narrow radius forces the
    radius-doubling wide tier to actually run."""
    from repro.core import knn as knnlib, schedule

    rng = np.random.default_rng(0)
    pts = rng.normal(size=(4000, 2))
    dtree = dt.flatten(RTree.str_bulk(pts, max_entries=16))
    centers = pts[rng.integers(0, 4000, 160)].astype(np.float32)
    centers += rng.normal(scale=1e-3, size=centers.shape).astype(np.float32)
    q = np.concatenate([centers, centers], axis=1)
    k = 16
    r = knnlib.default_radius(dtree, k, margin=1.0)
    narrow, wide = knnlib.make_knn_steps(dtree, k=k, radius=r,
                                         max_visited=64)
    t0 = time.perf_counter()
    rep = schedule.serve_workload(narrow, q, batch=64, sort="hilbert",
                                  wide_fn=wide, trunc_field="truncated")
    dt_s = time.perf_counter() - t0
    assert rep.n_reserved > 0, "knn smoke: wide tier never exercised"
    bd2, _ = knnlib.knn_brute(pts, centers, k)
    got = np.asarray(rep.stats.neighbor_d2)
    tr = np.asarray(rep.stats.truncated)
    nw = np.asarray(rep.stats.n_within)
    for j in range(q.shape[0]):
        kk = k if not tr[j] else min(int(nw[j]), k)
        assert np.array_equal(got[j, :kk], bd2[j, :kk]), \
            f"knn smoke: row {j} diverged from the brute prefix"
    rows.append(("knn_smoke_stream_us", dt_s * 1e6,
                 f"Q=160,k={k},reserved={rep.n_reserved},"
                 f"residual={int(tr.sum())}"))


def join_smoke(rows: list) -> None:
    """Join gate: ``spatial_join`` vs the brute-force pair-set oracle.
    The canonical (outer, point) pair array must equal brute force
    exactly (zero silent drops), with overflow rows re-served on the
    wide tier and zero residual truncation."""
    from repro.core import joins

    rng = np.random.default_rng(1)
    pts = rng.normal(size=(4000, 2))
    dtree = dt.flatten(RTree.str_bulk(pts, max_entries=16))
    lo = pts[rng.integers(0, 4000, 150)].astype(np.float32)
    wd = rng.uniform(0, 0.2, (150, 2)).astype(np.float32)
    outer = np.concatenate([lo - wd, lo + wd], axis=1)
    t0 = time.perf_counter()
    rep = joins.spatial_join(dtree, outer, batch=64, max_pairs=4,
                             max_visited=64, wide_factor=64)
    dt_s = time.perf_counter() - t0
    assert rep.n_reserved > 0, "join smoke: wide tier never exercised"
    assert rep.residual_truncated == 0, \
        f"join smoke: {rep.residual_truncated} rows stayed truncated"
    bp = joins.join_brute(pts, outer)
    assert np.array_equal(rep.pairs, bp), \
        "join smoke: pair set diverged from brute force"
    rows.append(("join_smoke_stream_us", dt_s * 1e6,
                 f"Q=150,pairs={rep.n_pairs},reserved={rep.n_reserved}"))


def kernel_micro(rows: list) -> None:
    from repro.kernels import ops
    rng = np.random.default_rng(0)

    def rects(n):
        lo = rng.uniform(-1, 1, (n, 2))
        w = rng.uniform(0, 0.3, (n, 2))
        return jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)

    q, m = rects(1024), rects(4096)
    dtm = _time(lambda: ops.mbr_intersect(q, m))
    rows.append(("mbr_intersect_1024x4096_us", dtm * 1e6,
                 f"{1024*4096/dtm/1e9:.2f}Gpairs/s"))

    entries = jnp.asarray(rng.uniform(-1, 1, (4096, 256, 2)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 4096, (256, 32)), jnp.int32)
    val = jnp.ones((256, 32), jnp.int32)
    dtm = _time(lambda: ops.leaf_refine(q[:256], entries, idx, val))
    rows.append(("leaf_refine_256x32x256_us", dtm * 1e6,
                 f"{256*32*256/dtm/1e9:.2f}Gtests/s"))

    feats = q[:, :4]
    fidx = jnp.asarray(rng.integers(0, 4, (16, 8)), jnp.int32)
    th = jnp.asarray(rng.uniform(-1, 1, (16, 8)), jnp.float32)
    tb = jnp.asarray(rng.uniform(0, 1, (16, 256, 128)), jnp.float32)
    dtm = _time(lambda: ops.forest_infer(feats, fidx, th, tb))
    rows.append(("forest_infer_1024x16_us", dtm * 1e6, ""))

    BH, T, dk, dv = 8, 512, 64, 64
    r = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, T, dv)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.3, 0.999, (BH, T, dk)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(BH, dk)), jnp.float32)
    dtm = _time(lambda: ops.wkv6(r, k, v, w, u), reps=2)
    rows.append(("wkv6_8x512x64_us", dtm * 1e6,
                 f"{BH*T/dtm/1e6:.2f}Mtok/s"))


def scale_bench(rows: list, B: int = 64, quick: bool = False) -> None:
    """Large-tree scaling: per-leaf traversal cost of the three dispatch
    forms — full-VMEM fused, ancestor-sliced, per-level fallback — over a
    leaf-count sweep, so the crossover the VMEM gate encodes is measured,
    not assumed.

    Interpret mode on CPU: absolute walls track *relative* cost only.
    Each form is invoked directly (the full form under a raised budget,
    the sliced form through ``ops._sliced_call``) — the ladder would
    otherwise need a different budget override per (form, L) pair. The
    slice granularity is coarse (tl=4096) to bound interpret-mode grid
    unrolling; autotune owns the per-shape choice.
    """
    import functools

    from repro.core.device_tree import build_ancestor_table
    from repro.kernels import ops
    from repro.kernels import traverse_fused as tf

    fanout = 4
    rng = np.random.default_rng(2)
    Ls = (2048, 8192, 32768) if quick else (2048, 8192, 32768, 65536)
    for L in Ls:
        lm, lp = _synth_levels(L, fanout, rng)
        sl = build_ancestor_table([np.asarray(p) for p in lp], tl=4096)
        lo = rng.uniform(-1, 1, (B, 2))
        w = rng.uniform(0, 0.05, (B, 2))
        q = jnp.asarray(np.concatenate([lo, lo + w], 1), jnp.float32)
        L128 = (L + 127) // 128 * 128

        orig = tf.VMEM_BUDGET
        try:
            tf.VMEM_BUDGET = 1 << 40           # decide forms at trace time
            full = jax.jit(functools.partial(ops.traverse_fused,
                                             tb=B, tl=L128))
            t_full = _med_time(lambda: full(q, lm, lp), reps=7)
            sliced = jax.jit(lambda q_, lm_, lp_: ops._sliced_call(
                q_, lm_, lp_, sl, B, True))
            t_sliced = _med_time(lambda: sliced(q, lm, lp), reps=7)
        finally:
            tf.VMEM_BUDGET = orig
        per_level = jax.jit(ops._per_level_kernel_mask)
        t_pl = _med_time(lambda: per_level(q, lm, lp), reps=7)

        extra = f"B={B},fanout={fanout},w_last={sl.widths[-1]}"
        rows.append((f"scale_fused_full_L{L}_perleaf_ns",
                     t_full / L * 1e9, extra))
        rows.append((f"scale_sliced_L{L}_perleaf_ns",
                     t_sliced / L * 1e9, extra))
        rows.append((f"scale_per_level_L{L}_perleaf_ns",
                     t_pl / L * 1e9, extra))


def main(quick: bool = False) -> list:
    rows: list = []
    serving_throughput(rows, n_points=30_000 if quick else 120_000,
                       batch=256 if quick else 512)
    query_type_throughput(rows, n_points=20_000 if quick else 120_000,
                          batch=256 if quick else 512)
    traversal_micro(rows)
    compaction_micro(rows)
    ai_fusion_micro(rows)
    scale_bench(rows, quick=quick)
    freshness_bench(rows, n_points=10_000 if quick else 30_000,
                    n_ins=1024 if quick else 2048)
    refit_bench(rows, quick=quick)
    if not quick:
        # the quick (CI fast-job) run skips this section: the same job
        # already runs it via the dedicated `make bench-smoke` gate
        scheduler_bench(rows)
    kernel_micro(rows)
    for name, val, extra in rows:
        print(f"{name},{val:.2f},{extra}")
    return rows


def smoke() -> list:
    """Toy-scale gates only (the ``make bench-smoke`` / CI fast-job):
    the scheduler streaming loop (asserts sorted ≡ unsorted, so the
    serving loop cannot silently rot) and the mixed read/write freshness
    gate (asserts delta-serving ≡ the from-scratch rebuild oracle and
    repack ≡ rebuild) and the online-refit recovery gate (asserts the
    AI path recovers within ceil(C/chunk) segments after a policy
    repack with full `fit_airtree` hard-disabled, results exact
    throughout) and the query-type gates (kNN brute-prefix oracle and
    join pair-set oracle — zero silent drops on either path)."""
    rows: list = []
    # Q deliberately not a multiple of batch: the gate must exercise the
    # ragged tail's pad-and-drop path, not just full batches
    scheduler_bench(rows, Q=400, batch=128, L=2048, check=True)
    freshness_smoke(rows)
    refit_recovery_smoke(rows)
    knn_smoke(rows)
    join_smoke(rows)
    for name, val, extra in rows:
        print(f"{name},{val:.2f},{extra}")
    return rows


if __name__ == "__main__":
    import argparse
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--smoke", action="store_true",
                   help="scheduler streaming benchmark only, toy scale")
    a = p.parse_args()
    smoke() if a.smoke else main(quick=a.quick)
