"""Reproduction of the paper's experiments (Figures 7/8, Tables III/IV).

Protocol (§V):
  * two datasets (tweets-like, crimes-like — synthetic stand-ins for
    UCR-STAR, see ``repro.data.synth``);
  * R-tree built by one-at-a-time insertion, linear split, m = M/2;
  * synthetic fixed-selectivity range queries, categorized into α buckets
    {0.1, 0.25, 0.5, 0.75, 1.0} by executing them (≤1000 per bucket);
  * per-α-bucket experiments: train the AI+R-tree on that bucket's workload
    (train == test, the paper's instance-optimized setting), then report the
    average per-query time of the R-tree, AI-tree and "AI+R"-tree under the
    paper's cost model: measured CPU time + 13 ms per leaf access (§V-D);
  * Tables III/IV: R-tree byte size vs ML-model byte size per α.

Scale: the default runs a reduced dataset (400k/250k points instead of
2M/872k) so the whole suite stays CPU-friendly; ``--full`` reproduces the
paper's sizes. Ratios (the paper's claim) are scale-stable.
"""
from __future__ import annotations

import argparse
import os
import pickle
import time
from typing import Iterable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build, device_tree as dt, labels
from repro.core.hybrid import hybrid_query
from repro.core.rtree import RTree
from repro.data import synth

CACHE = os.path.join(os.path.dirname(__file__), ".cache")
IO_MS = 13.0  # paper §V-D disk I/O per leaf access


def cached_tree(name: str, pts: np.ndarray, M: int) -> RTree:
    os.makedirs(CACHE, exist_ok=True)
    key = f"{name}_{pts.shape[0]}_{M}.pkl"
    path = os.path.join(CACHE, key)
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    t0 = time.time()
    tree = RTree(max_entries=M).insert_all(pts)
    print(f"#   built {key} in {time.time()-t0:.0f}s")
    with open(path, "wb") as f:
        pickle.dump(tree, f)
    return tree


def _timed_path(hyb, queries: jnp.ndarray, force: str, max_visited: int,
                reps: int = 3) -> tuple[float, float]:
    """Returns (cpu_ms_per_query, mean_leaf_accesses)."""
    out = hybrid_query(hyb, queries, force_path=force,
                       max_visited=max_visited)
    jax.block_until_ready(out)  # compile + warm
    t0 = time.time()
    for _ in range(reps):
        out = hybrid_query(hyb, queries, force_path=force,
                           max_visited=max_visited)
        jax.block_until_ready(out)
    cpu_ms = (time.time() - t0) / reps / queries.shape[0] * 1e3
    return cpu_ms, float(np.asarray(out.leaf_accesses).mean())


def run_dataset(name: str, pts: np.ndarray, *, node_caps: Iterable[int],
                selectivities: Iterable[float], n_queries: int,
                per_bucket: int, classifier: str, tau: float = 0.75,
                grid_sizes=(2, 4, 6, 8, 10, 14, 20), seed: int = 0,
                rows: list | None = None) -> list:
    rows = rows if rows is not None else []
    for M in node_caps:
        tree = cached_tree(name, pts, M)
        dtree = dt.flatten(tree)
        max_vis = min(512, dtree.n_leaves)
        for sel in selectivities:
            qs = synth.synth_queries(pts, sel, n_queries, seed=seed)
            wl = labels.make_workload(dtree, qs, max_visited=max_vis)
            buckets = synth.bucket_by_alpha(wl, per_bucket=per_bucket)
            for a, sub in sorted(buckets.items()):
                if sub.n_queries < 20:
                    continue
                hyb, rep = build.fit_airtree(
                    dtree, sub, kind=classifier, tau=tau,
                    grid_sizes=grid_sizes, router_workload=wl)
                q = jnp.asarray(sub.queries)
                for force, label in (("r", "rtree"), ("ai", "aitree"),
                                     ("auto", "air")):
                    cpu_ms, acc = _timed_path(hyb, q, force, max_vis)
                    total = cpu_ms + IO_MS * acc
                    rows.append(dict(
                        dataset=name, M=M, selectivity=sel, alpha=a,
                        struct=label, cpu_ms=round(cpu_ms, 3),
                        leaf_accesses=round(acc, 2),
                        total_ms=round(total, 2),
                        exact_fit=round(rep.exact_fit, 4),
                        grid=rep.grid_size,
                        model_mb=round(rep.model_bytes / 1e6, 3),
                        router_mb=round(rep.router_bytes / 1e6, 3),
                        rtree_mb=round(tree.stats().array_bytes / 1e6, 2),
                        router_acc=round(rep.router.test_acc, 3),
                    ))
                r = [x for x in rows if x["dataset"] == name and x["M"] == M
                     and x["selectivity"] == sel and x["alpha"] == a]
                by = {x["struct"]: x for x in r}
                speedup = by["rtree"]["total_ms"] / max(
                    by["air"]["total_ms"], 1e-9)
                print(f"# {name} M={M} sel={sel} a={a}: "
                      f"R {by['rtree']['total_ms']}ms "
                      f"AI {by['aitree']['total_ms']}ms "
                      f"AI+R {by['air']['total_ms']}ms "
                      f"(x{speedup:.2f}, fit {rep.exact_fit:.3f})")
    return rows


def print_csv(rows: list) -> None:
    if not rows:
        return
    cols = list(rows[0].keys())
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))


def main(full: bool = False, classifier: str = "knn", quick: bool = False):
    n_tweets = 2_000_000 if full else (60_000 if quick else 400_000)
    n_crimes = 872_000 if full else (40_000 if quick else 250_000)
    n_queries = 1_000 if quick else 5_000
    per_bucket = 200 if quick else 1_000
    caps = (64,) if quick else (200, 400, 800)
    sels = (5e-5,) if quick else (1e-5, 5e-5)
    rows: list = []
    # Fig. 7a/7b (+7c/7d via node caps) — tweets
    run_dataset("tweets", synth.tweets_like(n_tweets), node_caps=caps,
                selectivities=sels, n_queries=n_queries,
                per_bucket=per_bucket, classifier=classifier, rows=rows)
    # Fig. 8a/8b (+8c/8d) — crimes
    run_dataset("crimes", synth.crimes_like(n_crimes), node_caps=caps,
                selectivities=sels, n_queries=n_queries,
                per_bucket=per_bucket, classifier=classifier, rows=rows)
    print_csv(rows)
    return rows


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--quick", action="store_true")
    p.add_argument("--classifier", default="knn",
                   choices=("knn", "forest", "mlp"))
    args = p.parse_args()
    main(full=args.full, classifier=args.classifier, quick=args.quick)
