"""Train a ~100M-parameter model for a few hundred steps on synthetic data.

    PYTHONPATH=src python examples/lm_train.py [--steps 300]

Exercises the full training substrate — AdamW + schedule, grad accumulation,
remat, checkpoint/restore (kill it mid-run and rerun: it resumes) — on a
~100M-param llama-family config derived from h2o-danube-3-4b.
"""
import argparse
import dataclasses

from repro import configs

parser = argparse.ArgumentParser()
parser.add_argument("--steps", type=int, default=300)
parser.add_argument("--ckpt-dir", default="/tmp/repro_lm_train")
args = parser.parse_args()

base = configs.get_config("h2o-danube-3-4b")
cfg100m = dataclasses.replace(
    base, n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
    d_ff=2048, vocab=8192, window=256)
print(f"# config: ~{cfg100m.n_params()/1e6:.0f}M params "
      f"({cfg100m.n_layers}L d={cfg100m.d_model})")

import time  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from repro.launch.train import synthetic_batch  # noqa: E402
from repro.training import checkpoint, optimizer as opt  # noqa: E402
from repro.training import train_loop, fault_tolerance  # noqa: E402

ocfg = opt.AdamWConfig(lr=3e-4, warmup_steps=20, decay_steps=args.steps)
state = train_loop.init_train_state(cfg100m, jax.random.PRNGKey(0),
                                    dtype=jnp.float32, opt_cfg=ocfg)
start = 0
if checkpoint.latest_step(args.ckpt_dir) is not None:
    state, manifest = checkpoint.restore(args.ckpt_dir, state)
    start = manifest["step"] + 1
    print(f"# resumed at step {start}")

step_fn = jax.jit(train_loop.make_train_step(cfg100m, opt_cfg=ocfg,
                                             accum_steps=2))
handler = fault_tolerance.PreemptionHandler().install()
for step in range(start, args.steps):
    batch = synthetic_batch(cfg100m, 8, 256, step)
    t0 = time.time()
    state, metrics = step_fn(state, batch)
    if step % 20 == 0 or step == args.steps - 1:
        print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
              f"({8*256/(time.time()-t0):.0f} tok/s)", flush=True)
    if step % 50 == 0 or handler.preempted() or step == args.steps - 1:
        checkpoint.save(args.ckpt_dir, step, state)
    if handler.preempted():
        break
print("# done — rerun to resume from the checkpoint")
