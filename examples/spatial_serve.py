"""End-to-end serving driver: batched request stream against the AI+R-tree.

    PYTHONPATH=src python examples/spatial_serve.py [--distributed]

This is the deployment-shaped example (the paper's kind is a serving
system): a stream of mixed-α query batches flows through the router-
dispatched hybrid engine; the loop reports running throughput, per-path
traffic split and leaf-I/O savings vs the classical R-tree.
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build, device_tree, engine, labels
from repro.core.hybrid import hybrid_query
from repro.core.rtree import RTree
from repro.launch import mesh as pmesh
from repro.data import synth

parser = argparse.ArgumentParser()
parser.add_argument("--points", type=int, default=100_000)
parser.add_argument("--batches", type=int, default=20)
parser.add_argument("--batch-size", type=int, default=512)
parser.add_argument("--train-queries", type=int, default=3000,
                    help="training queries per selectivity bucket")
parser.add_argument("--insert-rate", type=float, default=0.0,
                    help="fraction of points arriving as dynamic inserts "
                         "during the stream (freshness subsystem demo)")
parser.add_argument("--repack-every", type=int, default=0,
                    help="online repack once this many inserts are staged")
parser.add_argument("--distributed", action="store_true")
args = parser.parse_args()

all_points = synth.tweets_like(args.points, seed=0)
n_ins = int(round(args.insert_rate * args.points))
points = all_points[:-n_ins] if n_ins else all_points
inserts = all_points[-n_ins:] if n_ins else None
tree = RTree(max_entries=128).insert_all(points)
dtree = device_tree.flatten(tree)

# training workload: mixture of selectivities (mixed α population)
train_q = np.concatenate([
    synth.synth_queries(points, s, args.train_queries, seed=i)
    for i, s in enumerate((2e-5, 5e-5, 2e-4))])
workload = labels.make_workload(dtree, train_q)
hybrid, report = build.fit_airtree(dtree, workload, kind="knn")
print(f"# fitted: grid {report.grid_size}², fit {report.exact_fit:.3f}, "
      f"router acc {report.router.test_acc:.2f}")

# serving stream: same workload distribution, shuffled into batches
rng = np.random.default_rng(1)
order = rng.permutation(workload.n_queries)

if inserts is not None:
    # Freshness demo: a mixed read/write stream through the scheduler.
    # Inserts land in the device-side delta buffer between query
    # segments (every query probes it), the guard demotes stale cells to
    # the exact R path, and the online repack folds the buffer into a
    # fresh bulk-loaded tree mid-stream.
    from repro.core import schedule
    from repro.core.monitor import FreshServer
    server = FreshServer(points, hybrid, delta_cap=max(64, n_ins),
                         max_visited=256, max_results=1024)
    stream = workload.queries[
        np.resize(order, args.batches * args.batch_size)]
    t0 = time.time()
    mixed = schedule.serve_mixed_workload(
        server, stream, inserts, batch=args.batch_size, sort="none",
        insert_every=1, repack_every=args.repack_every)
    dt = time.time() - t0
    fs = server.stats()
    print(f"# stream: {mixed.n_queries/dt:8.0f} q/s | "
          f"{int(np.asarray(mixed.stats.delta_hits).sum())} delta hits | "
          f"{100*np.asarray(mixed.stats.guarded).mean():.1f}% "
          f"guard-demoted | delta fill {fs.delta_fill} | "
          f"{fs.ok_cells}/{fs.n_cells} cells eligible")
    print(f"# total: {mixed.n_queries} queries served fresh over "
          f"{mixed.n_inserts} dynamic inserts, {mixed.n_repacks} online "
          f"repacks")
    raise SystemExit(0)

step = None
if args.distributed and len(jax.devices()) > 1:
    n = len(jax.devices())
    mesh = jax.make_mesh((max(1, n // 2), 2), ("data", "model"))
    hybrid_s = engine.pad_tree_for_sharding(hybrid, 2)
    step = engine.make_serve_step(mesh, engine.EngineConfig(), kind="knn")

served = 0
accesses = 0.0
baseline = 0.0
ai_hits = 0
t0 = time.time()
for b in range(args.batches):
    take = order[(b * args.batch_size) % workload.n_queries:][
        :args.batch_size]
    if take.size < args.batch_size:
        take = np.concatenate([take, order[:args.batch_size - take.size]])
    q = jnp.asarray(workload.queries[take])
    if step is not None:
        with pmesh.set_mesh(mesh):
            out = step(hybrid_s, q)
        acc = np.asarray(out.leaf_accesses)
        ai = np.asarray(out.used_ai)
    else:
        out = hybrid_query(hybrid, q)
        acc = np.asarray(out.leaf_accesses)
        ai = np.asarray(out.used_ai)
    base = np.asarray(hybrid_query(hybrid, q, force_path="r").leaf_accesses)
    served += args.batch_size
    accesses += acc.sum()
    baseline += base.sum()
    ai_hits += int(ai.sum())
    if (b + 1) % 5 == 0:
        dt = time.time() - t0
        print(f"# batch {b+1:3d}: {served/dt:8.0f} q/s | "
              f"leaf I/O saved {100*(1-accesses/baseline):5.1f}% | "
              f"AI-path share {100*ai_hits/served:5.1f}%")
print(f"# total: {served} queries, "
      f"{100*(1-accesses/baseline):.1f}% leaf accesses saved vs R-tree")
