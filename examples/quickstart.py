"""Quickstart: build an "AI+R"-tree and answer range queries exactly.

    PYTHONPATH=src python examples/quickstart.py [--points N] [--queries Q]

Walks the whole paper in ~30 lines of user-facing API: data → R-tree →
workload α labelling → AI+R fit → hybrid querying, with the classical
R-path as the correctness oracle. ``--points/--queries`` scale the run
down (``make examples-smoke`` uses toy sizes in CI).
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import build, device_tree, labels
from repro.core.hybrid import hybrid_query
from repro.core.rtree import RTree
from repro.data import synth

parser = argparse.ArgumentParser()
parser.add_argument("--points", type=int, default=50_000)
parser.add_argument("--queries", type=int, default=2000)
args = parser.parse_args()

# 1. a clustered spatial dataset (tweets-like) and a dynamic R-tree
points = synth.tweets_like(args.points, seed=7)
tree = RTree(max_entries=64).insert_all(points)
dtree = device_tree.flatten(tree)
print(f"R-tree: {dtree.n_leaves} leaves, height {dtree.height}")

# 2. a fixed query workload, labelled by executing it (visited/true leaves)
queries = synth.synth_queries(points, selectivity=1e-4,
                              n_queries=args.queries)
workload = labels.make_workload(dtree, queries)
print(f"workload: mean α = {workload.alpha.mean():.3f} "
      f"(low α ⇒ the R-tree wastes leaf accesses)")

# 3. fit the AI+R-tree: grid-of-models + binary router (paper §III/§IV)
hybrid, report = build.fit_airtree(dtree, workload, kind="knn",
                                   verbose=True)
print(f"grid {report.grid_size}x{report.grid_size}, "
      f"exact fit {report.exact_fit:.3f}, "
      f"router acc {report.router.test_acc:.2f}, "
      f"model size {report.model_bytes/1e6:.2f} MB")

# 4. serve a batch through the hybrid; compare leaf accesses vs classical
q = jnp.asarray(workload.queries[:256])
res = hybrid_query(hybrid, q)
classical = hybrid_query(hybrid, q, force_path="r")
print(f"hybrid: {np.asarray(res.leaf_accesses).mean():.2f} "
      f"leaf accesses/query vs classical "
      f"{np.asarray(classical.leaf_accesses).mean():.2f}")

# 5. exactness: identical result sets
assert np.array_equal(np.asarray(res.n_results),
                      np.asarray(classical.n_results))
ids_h = np.sort(np.asarray(res.result_ids), axis=1)
ids_r = np.sort(np.asarray(classical.result_ids), axis=1)
assert np.array_equal(ids_h, ids_r)
print("exactness check passed: hybrid == classical result sets")
