# Local equivalents of the CI gates (.github/workflows/ci.yml).
PYTHONPATH := src

.PHONY: test test-all smoke bench bench-smoke examples-smoke autotune

# Fast default: skips @pytest.mark.slow (subprocess + interpret-heavy
# sweeps). `test-all` is the tier-1 / scheduled-CI full run.
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q -m "not slow"

test-all:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

smoke: test
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --quick --only engine_bench --json BENCH_engine.json

# Toy-scale spatial-scheduler streaming benchmark; asserts sorted serving
# is bit-identical to unsorted, so the serving loop can't silently rot.
# The latency smoke adds the open-loop gates: zero silent drops, degraded
# accounting exact vs a brute-force oracle, and deadline-aware dispatch
# beating fixed-full-batch goodput at overload. Wired into the fast CI job.
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.engine_bench --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.latency_bench --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --check

# Toy-scale run of both user-facing examples (they are living docs — the
# fast CI job executes them so the documented API path can't silently rot).
# spatial_serve runs twice: the read-only stream and the freshness demo
# (--insert-rate: delta-buffer serving + guard + online repack).
examples-smoke:
	PYTHONPATH=$(PYTHONPATH) python examples/quickstart.py --points 4000 --queries 300
	PYTHONPATH=$(PYTHONPATH) python examples/spatial_serve.py --points 4000 --batches 2 --batch-size 128 --train-queries 400
	PYTHONPATH=$(PYTHONPATH) python examples/spatial_serve.py --points 4000 --batches 4 --batch-size 128 --train-queries 400 --insert-rate 0.05 --repack-every 150

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json BENCH_engine.json

# Tile-size sweep for the fused traversal kernels; writes the cache that
# kernels/ops.py consults (src/repro/kernels/autotune_cache.json).
autotune:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.autotune
