# Local equivalents of the CI gates (.github/workflows/ci.yml).
PYTHONPATH := src

.PHONY: test smoke bench

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

smoke: test
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --quick --only engine_bench --json BENCH_engine.json

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json BENCH_engine.json
