# Local equivalents of the CI gates (.github/workflows/ci.yml).
PYTHONPATH := src

.PHONY: test test-all smoke bench

# Fast default: skips @pytest.mark.slow (subprocess + interpret-heavy
# sweeps). `test-all` is the tier-1 / scheduled-CI full run.
test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q -m "not slow"

test-all:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

smoke: test
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --quick --only engine_bench --json BENCH_engine.json

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --json BENCH_engine.json
